//! Ablation: how throughput scales with weight sparsity (the premise of
//! the whole paper — "latency and throughput improvements of up to 10x"
//! from §I — measured on our compiled ResNet-50 plans).
//!
//!   cargo run --release --example sweep_sparsity [-- --full-scale]

use hpipe::arch::S10_2800;
use hpipe::compile::{compile, CompileOptions};
use hpipe::nets::{resnet50, NetConfig};
use hpipe::sparsity::prune_graph;
use hpipe::transform::optimize;
use hpipe::util::timer::Table;

fn main() -> hpipe::util::error::Result<()> {
    let full = std::env::args().any(|a| a == "--full-scale");
    let cfg = if full { NetConfig::imagenet() } else { NetConfig::test_scale() };
    let dsp_target = if full { 5000 } else { 1200 };

    let mut tab = Table::new(&[
        "sparsity",
        "interval (cycles)",
        "throughput (img/s)",
        "dsps",
        "m20ks",
        "speedup vs dense",
    ]);
    let mut dense_interval = 0u64;
    for pct in [0, 25, 50, 70, 85, 90, 95] {
        let mut g = resnet50(cfg);
        if pct > 0 {
            prune_graph(&mut g, pct as f64 / 100.0);
        }
        let (g, _) = optimize(&g);
        let plan = compile(&g, "resnet50", &CompileOptions::new(S10_2800.clone(), dsp_target))?;
        if pct == 0 {
            dense_interval = plan.interval_cycles();
        }
        tab.row(&[
            format!("{pct}%"),
            plan.interval_cycles().to_string(),
            format!("{:.0}", plan.throughput_img_s()),
            plan.totals.dsps.to_string(),
            plan.totals.m20ks.to_string(),
            format!(
                "{:.2}x",
                dense_interval as f64 / plan.interval_cycles() as f64
            ),
        ]);
    }
    tab.print();
    println!(
        "\n(the paper's premise: ~10x headroom from 90% pruning when the\n\
         hardware can skip zeros — HPIPE's gather architecture realizes a\n\
         large fraction of it; lock-step padding absorbs the rest)"
    );
    Ok(())
}
