//! Quickstart: the whole HPIPE flow on a small CNN in ~40 lines.
//!
//!   cargo run --release --example quickstart
//!
//! Builds TinyCNN, prunes it to 50% sparsity, folds batch norms / merges
//! pads, compiles a balanced accelerator plan for a Stratix 10 2800,
//! generates the Verilog + memory-init artifact directory, and runs the
//! cycle-level simulator.

use hpipe::arch::S10_2800;
use hpipe::compile::{codegen, compile, CompileOptions};
use hpipe::nets::{tiny_cnn, NetConfig};
use hpipe::sim::simulate;
use hpipe::sparsity::prune_graph;
use hpipe::transform::optimize;

fn main() -> anyhow::Result<()> {
    // 1. build + prune the network
    let mut graph = tiny_cnn(NetConfig::test_scale());
    let report = prune_graph(&mut graph, 0.5);
    println!(
        "pruned TinyCNN to {:.0}% sparsity",
        report.overall_sparsity() * 100.0
    );

    // 2. compiler front-end: fold BNs, merge pads
    let (graph, log) = optimize(&graph);
    println!("transforms: {log:?}");

    // 3. balance against a DSP budget and plan the hardware
    let opts = CompileOptions::new(S10_2800.clone(), 400);
    let plan = compile(&graph, "tinycnn", &opts)?;
    println!(
        "plan: {} stages, {} DSPs, {} M20Ks, fmax {:.0} MHz, {:.0} img/s",
        plan.stages.len(),
        plan.totals.dsps,
        plan.totals.m20ks,
        plan.fmax_mhz,
        plan.throughput_img_s()
    );

    // 4. generate the accelerator (Verilog netlist + weight mem-init)
    let out = std::env::temp_dir().join("hpipe_quickstart");
    let gen = codegen::generate(&plan, &graph, &out)?;
    println!(
        "generated {} modules + {} mem-init files -> {}",
        gen.modules,
        gen.mem_init_files,
        out.display()
    );

    // 5. cycle-level simulation
    let sim = simulate(&plan, 8).map_err(|e| anyhow::anyhow!("{e}"))?;
    println!(
        "simulated 8 images: latency {:.3} ms, steady-state {:.0} img/s",
        sim.latency_ms(plan.fmax_mhz),
        sim.throughput_img_s(plan.fmax_mhz)
    );
    Ok(())
}
