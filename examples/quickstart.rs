//! Quickstart: the whole HPIPE flow on a small CNN in ~40 lines.
//!
//!   cargo run --release --example quickstart
//!
//! Builds TinyCNN, prunes it to 50% sparsity, folds batch norms / merges
//! pads, compiles a balanced accelerator plan for a Stratix 10 2800,
//! generates the Verilog + memory-init artifact directory, and runs the
//! cycle-level simulator.

use hpipe::arch::S10_2800;
use hpipe::compile::{codegen, compile, CompileOptions};
use hpipe::nets::{tiny_cnn, NetConfig};
use hpipe::sim::simulate;
use hpipe::sparsity::prune_graph;
use hpipe::transform::optimize;

fn main() -> hpipe::util::error::Result<()> {
    // 1. build + prune the network
    let mut graph = tiny_cnn(NetConfig::test_scale());
    let report = prune_graph(&mut graph, 0.5);
    println!(
        "pruned TinyCNN to {:.0}% sparsity",
        report.overall_sparsity() * 100.0
    );

    // 2. compiler front-end: fold BNs, merge pads
    let (graph, log) = optimize(&graph);
    println!("transforms: {log:?}");

    // 3. balance against a DSP budget and plan the hardware
    let opts = CompileOptions::new(S10_2800.clone(), 400);
    let plan = compile(&graph, "tinycnn", &opts)?;
    println!(
        "plan: {} stages, {} DSPs, {} M20Ks, fmax {:.0} MHz, {:.0} img/s",
        plan.stages.len(),
        plan.totals.dsps,
        plan.totals.m20ks,
        plan.fmax_mhz,
        plan.throughput_img_s()
    );

    // 4. generate the accelerator (Verilog netlist + weight mem-init)
    let out = std::env::temp_dir().join("hpipe_quickstart");
    let gen = codegen::generate(&plan, &graph, &out)?;
    println!(
        "generated {} modules + {} mem-init files -> {}",
        gen.modules,
        gen.mem_init_files,
        out.display()
    );

    // 5. cycle-level simulation
    let sim = simulate(&plan, 8)?;
    println!(
        "simulated 8 images: latency {:.3} ms, steady-state {:.0} img/s",
        sim.latency_ms(plan.fmax_mhz),
        sim.throughput_img_s(plan.fmax_mhz)
    );

    // 6. actually execute it: compile a software execution plan (sparse
    //    RLE kernels + fused conv chains) and classify one image. The
    //    kernels dispatch to the widest SIMD tier this CPU supports
    //    (exec::isa; override with HPIPE_ISA=scalar|sse4.1|avx2|fma|
    //    neon|native) — every tier computes the same answer, the scalar
    //    tier is the always-available baseline
    println!("kernel isa: {}", hpipe::exec::isa::describe());
    let exec_plan = hpipe::exec::ExecutionPlan::build(&graph)?;
    let mut rng = hpipe::util::Rng::new(42);
    let mut feeds = std::collections::BTreeMap::new();
    feeds.insert(
        "input".to_string(),
        hpipe::graph::Tensor::randn(&[1, 16, 16, 3], &mut rng, 1.0),
    );
    let (result, took) = hpipe::util::timer::time_once(|| exec_plan.run(&feeds));
    let probs = result?;
    println!(
        "executed through the plan in {took:?}: class {} ({} sparse kernels, {} fused chains)",
        hpipe::interp::argmax(&probs[0])[0],
        exec_plan.stats().sparse_convs,
        exec_plan.stats().fused_chains
    );

    // 7. batch is a first-class plan dimension: a batch-4 plan holds
    //    4x activations in its arena and walks each RLE weight stream
    //    once per *batch*, broadcasting every surviving weight across
    //    all four images — not once per image
    let batched_plan = hpipe::exec::ExecutionPlan::build_batched(&graph, 4)?;
    let images: Vec<hpipe::graph::Tensor> = (0..4)
        .map(|_| hpipe::graph::Tensor::randn(&[1, 16, 16, 3], &mut rng, 1.0))
        .collect();
    let mut batched_feeds = std::collections::BTreeMap::new();
    batched_feeds.insert(
        "input".to_string(),
        hpipe::graph::Tensor::concat_batch(&images.iter().collect::<Vec<_>>()),
    );
    let (bresult, btook) = hpipe::util::timer::time_once(|| batched_plan.run(&batched_feeds));
    let bout = bresult?;
    println!(
        "executed a native batch-{} plan in {btook:?}: output shape {:?} \
         (one weight-stream walk for the whole batch)",
        batched_plan.batch(),
        bout[0].shape
    );

    // 8. profile-guided autotuning: measure what every step *actually*
    //    costs (median-of-K wall times), re-cut the pipeline stages from
    //    the measurements, and size the worker team from measured stage
    //    imbalance + core count — the profile-guided Algorithm 1 (also:
    //    `hpipe tune --net tinycnn` / `hpipe serve --autotune`)
    let plan = hpipe::exec::ExecutionPlan::build(&graph)?;
    let (profile, cuts) = hpipe::exec::tune::tune_plan(&plan, &hpipe::exec::TuneOptions::default());
    println!(
        "autotuned from measured step costs: {} stages (bottleneck {:.3} ms), team {}",
        cuts.stages,
        cuts.bottleneck_ns as f64 / 1e6,
        cuts.team
    );
    let tuned =
        hpipe::exec::PipelinePlan::from_profile(plan, &profile, cuts.stages, cuts.team);
    let touts = tuned.run_stream(&[feeds.clone()])?;
    println!(
        "tuned pipeline classified the image: class {} (identical math, measured cuts)",
        hpipe::interp::argmax(&touts[0][0])[0]
    );

    // 9. serving never wastes a ragged tail: a plan *family* of smaller
    //    batch variants lets a drained tail of k < B images run on the
    //    smallest variant that fits instead of zero-padding to B —
    //    bitwise-identical answers, strictly less compute. The runtime
    //    wires this up per model (`Runtime::with_plan_family`,
    //    `hpipe serve --plan-family`); here is the invariant at plan
    //    level: one image padded onto the batch-2 variant reproduces
    //    the batch-1 answer bit for bit.
    let variant = hpipe::exec::ExecutionPlan::build_batched(&graph, 2)?;
    let one = &feeds["input"];
    let padded = hpipe::graph::Tensor::pad_batch(&one.data, one.data.len(), 2);
    let mut tail_feeds = std::collections::BTreeMap::new();
    tail_feeds.insert(
        "input".to_string(),
        hpipe::graph::Tensor::from_vec(&[2, 16, 16, 3], padded),
    );
    let tail_out = variant.run(&tail_feeds)?;
    let per = tail_out[0].data.len() / 2;
    assert_eq!(
        &tail_out[0].data[..per],
        &probs[0].data[..],
        "tail via the batch-2 variant must be bitwise the batch-1 answer"
    );
    println!(
        "ragged tail: 1 image on the batch-2 variant matches the batch-1 plan bit for bit"
    );

    // 10. serving self-heals: every pipeline stage of a served model is
    //     guarded by its own circuit breaker (HPIPE's per-layer-hardware
    //     granularity). Two faults in one batch trip only the faulting
    //     site — its pipe bypasses to the sequential plan, bitwise the
    //     oracle — and after a cool-down ONE HalfOpen probe re-runs the
    //     pipeline against the oracle, closing the site when the bits
    //     match (failed probes double the cool-down; the probe batch is
    //     always answered from the oracle, so recovery can never change
    //     an answer). Knobs: `hpipe serve --recover-after-ms N
    //     [--no-recover] [--fault-budget N]` / `Runtime::with_recovery`;
    //     the serve report's models[] carries per-model {faults,
    //     retries, trips, recoveries, degraded_now, time_degraded_ns}.
    //     The state machine itself, in five lines:
    use hpipe::util::breaker::{Breaker, BreakerConfig, BreakerState};
    let site = Breaker::new(BreakerConfig::with_cooldown_ms(250));
    site.record_failure(0); // a stage fault: retried, still Closed
    site.record_failure(1); // the retry faults too: the site trips
    assert_eq!(site.state(), BreakerState::Open);
    assert!(!site.try_probe(100_000_000), "cool-down pending: stay on the bypass");
    assert!(site.try_probe(251_000_000), "cool-down over: one probe granted");
    site.record_success(); // probe matched the oracle bitwise
    assert_eq!(site.state(), BreakerState::Closed);
    println!(
        "self-healing: tripped after 2 faults, probed after the 250 ms cool-down, \
         recovered ({} trip, {} recovery)",
        site.trips(),
        site.recoveries()
    );

    // 11. compile once, serve anywhere: a model's fully compiled serving
    //     state — packed panels, pre-decoded RLE streams, pipeline cuts,
    //     calibration — persists as a *plan artifact* (plan.json +
    //     plan.bin), HPIPE's bitstream analog. The artifact is keyed by
    //     a content hash of graph + options + config, any mismatch or
    //     corruption is a typed rejection that falls back to a fresh
    //     compile, and the restored model is bitwise the compiled one.
    //     (CLI: `hpipe compile --plan-cache DIR` then
    //     `hpipe serve --plan-cache DIR`.)
    use hpipe::runtime::Runtime;
    let cache = std::env::temp_dir().join("hpipe_quickstart_plan_cache");
    let _ = std::fs::remove_dir_all(&cache);
    let (mut first, mut second) = (
        Runtime::cpu(&out)?.with_plan_cache(&cache),
        Runtime::cpu(&out)?.with_plan_cache(&cache),
    );
    let (compiled_ok, compile_took) =
        hpipe::util::timer::time_once(|| first.load_graph("tinycnn_b4", &graph, 4));
    compiled_ok?;
    let (restored_ok, restore_took) =
        hpipe::util::timer::time_once(|| second.load_graph("tinycnn_b4", &graph, 4));
    restored_ok?;
    assert_eq!(
        (second.cache_hits, second.cache_misses),
        (1, 0),
        "second cold start must restore from the artifact"
    );
    let (compiled, restored) = (
        first.model("tinycnn_b4").unwrap(),
        second.model("tinycnn_b4").unwrap(),
    );
    let image4: Vec<f32> = batched_feeds["input"].data.clone();
    assert_eq!(
        compiled.run_all(&image4)?,
        restored.run_all(&image4)?,
        "artifact restore must be bitwise the fresh compile"
    );
    let (shared, private) = restored.weight_bytes();
    println!(
        "plan artifact: compiled in {compile_took:?}, restored in {restore_took:?} \
         ({} B shared weights held once across {} plans, {} B plan-private)",
        shared,
        2 + restored.variant_batches().len(),
        private
    );
    Ok(())
}
