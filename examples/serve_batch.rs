//! End-to-end serving driver (the whole-stack validation example).
//!
//!   make artifacts           # trains TinyCNN + lowers it to HLO text
//!   cargo run --release --example serve_batch [-- <requests> <batch>]
//!
//! Loads the AOT-compiled, Pallas-kernel TinyCNN through the PJRT CPU
//! client, serves batched classification requests through the Layer-3
//! coordinator (request queue -> dynamic batcher -> XLA executable), and
//! reports latency percentiles + throughput. Every result is
//! cross-checked against the Rust reference interpreter running the same
//! trained graphdef — proving Layer 1 (kernel), Layer 2 (JAX model),
//! Layer 3 (coordinator) and the AOT path all agree.

use hpipe::coordinator::serve_demo;
use std::path::PathBuf;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let requests: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(128);
    let batch: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(8);
    let artifacts = PathBuf::from(
        std::env::var("HPIPE_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
    );
    if !artifacts.join("manifest.json").exists() {
        anyhow::bail!(
            "artifacts not found at {} — run `make artifacts` first",
            artifacts.display()
        );
    }
    println!("serving {requests} requests (max batch {batch}) from {}", artifacts.display());
    let mut report = serve_demo(&artifacts, requests, batch)?;
    report.print();
    let (agree, total) = report.interp_agreement.unwrap_or((0, 0));
    anyhow::ensure!(
        agree == total,
        "PJRT vs interpreter disagreement: {agree}/{total}"
    );
    println!("OK: all layers agree");
    Ok(())
}
