//! End-to-end serving driver (the whole-stack validation example).
//!
//!   make artifacts           # trains TinyCNN + lowers it to HLO text
//!   cargo run --release --example serve_batch [-- <requests> <batch>]
//!
//! Loads the trained TinyCNN graphdef, compiles it into sparse-aware
//! *natively batched* execution plans (a batch-N model's plan executes
//! all N images per run, walking each RLE weight stream once per batch),
//! and serves dynamic classification batches through the Layer-3
//! coordinator (request queue -> dynamic batcher -> one whole-batch plan
//! execution), reporting latency percentiles + throughput. Every result
//! is cross-checked against the Rust reference interpreter running the
//! same trained graphdef — proving the kernels, the plan compiler and
//! the coordinator all agree. A third argument > 1 streams each batch
//! through that many layer-pipeline stage threads in batched groups; a
//! fourth argument > 1 splits the dominant stage's conv rows across an
//! intra-stage worker team (the software `n_channel_splits` knob); a
//! fifth argument `autotune` replaces both knobs with profile-guided
//! calibration (measured stage cuts + measured team size); a sixth
//! argument sets a per-request deadline in milliseconds (late batches
//! are answered `Expired`, never run) and a seventh bounds the
//! admission queue (see `ServeConfig::queue_cap`). An eighth argument
//! `no-overlap` disables the drain/execute overlap — the feeder thread
//! that accumulates batch i+1 while batch i executes, on by default —
//! and a ninth sets the ragged-tail plan family (`none` pads tails to
//! the full batch; a CSV like `2,4` sets explicit variant batch sizes;
//! unset uses the default {B/4, B/2} family).

use hpipe::coordinator::{serve_demo, ServeConfig};
use std::path::PathBuf;

fn main() -> hpipe::util::error::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let cfg = ServeConfig {
        requests: args.get(1).and_then(|s| s.parse().ok()).unwrap_or(128),
        max_batch: args.get(2).and_then(|s| s.parse().ok()).unwrap_or(8),
        threads: args.get(3).and_then(|s| s.parse().ok()).unwrap_or(1),
        team: args.get(4).and_then(|s| s.parse().ok()).unwrap_or(1),
        autotune: args.get(5).map(|s| s == "autotune").unwrap_or(false),
        deadline_ms: args.get(6).and_then(|s| s.parse().ok()),
        queue_cap: args.get(7).and_then(|s| s.parse().ok()).unwrap_or(0),
        overlap: args.get(8).map(|s| s != "no-overlap").unwrap_or(true),
        plan_family: args.get(9).map(|s| {
            if s == "none" {
                Vec::new()
            } else {
                s.split(',').filter_map(|t| t.trim().parse().ok()).collect()
            }
        }),
        ..Default::default()
    };
    let artifacts = PathBuf::from(
        std::env::var("HPIPE_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
    );
    if !artifacts.join("manifest.json").exists() {
        hpipe::bail!(
            "artifacts not found at {} — run `make artifacts` first",
            artifacts.display()
        );
    }
    println!(
        "serving {} requests (max batch {}, {} pipeline threads, team {}, autotune {}, \
         overlap {}) from {}",
        cfg.requests,
        cfg.max_batch,
        cfg.threads,
        cfg.team,
        cfg.autotune,
        cfg.overlap,
        artifacts.display()
    );
    let mut report = serve_demo(&artifacts, &cfg)?;
    report.print();
    let (agree, total) = report.interp_agreement.unwrap_or((0, 0));
    hpipe::ensure!(
        agree == total,
        "executor vs interpreter disagreement: {agree}/{total}"
    );
    println!("OK: all layers agree");
    Ok(())
}
