//! End-to-end serving driver (the whole-stack validation example).
//!
//!   make artifacts           # trains TinyCNN + lowers it to HLO text
//!   cargo run --release --example serve_batch [-- <requests> <batch>]
//!
//! Loads the trained TinyCNN graphdef, compiles it into sparse-aware
//! *natively batched* execution plans (a batch-N model's plan executes
//! all N images per run, walking each RLE weight stream once per batch),
//! and serves dynamic classification batches through the Layer-3
//! coordinator (request queue -> dynamic batcher -> one whole-batch plan
//! execution), reporting latency percentiles + throughput. Every result
//! is cross-checked against the Rust reference interpreter running the
//! same trained graphdef — proving the kernels, the plan compiler and
//! the coordinator all agree. A third argument > 1 streams each batch
//! through that many layer-pipeline stage threads in batched groups; a
//! fourth argument > 1 splits the dominant stage's conv rows across an
//! intra-stage worker team (the software `n_channel_splits` knob).

use hpipe::coordinator::serve_demo;
use std::path::PathBuf;

fn main() -> hpipe::util::error::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let requests: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(128);
    let batch: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(8);
    let threads: usize = args.get(3).and_then(|s| s.parse().ok()).unwrap_or(1);
    let team: usize = args.get(4).and_then(|s| s.parse().ok()).unwrap_or(1);
    let artifacts = PathBuf::from(
        std::env::var("HPIPE_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
    );
    if !artifacts.join("manifest.json").exists() {
        hpipe::bail!(
            "artifacts not found at {} — run `make artifacts` first",
            artifacts.display()
        );
    }
    println!(
        "serving {requests} requests (max batch {batch}, {threads} pipeline threads, \
         team {team}) from {}",
        artifacts.display()
    );
    let mut report = serve_demo(&artifacts, requests, batch, threads, team)?;
    report.print();
    let (agree, total) = report.interp_agreement.unwrap_or((0, 0));
    hpipe::ensure!(
        agree == total,
        "executor vs interpreter disagreement: {agree}/{total}"
    );
    println!("OK: all layers agree");
    Ok(())
}
