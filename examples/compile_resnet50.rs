//! The paper's flagship configuration: 85%-sparse ResNet-50 compiled for
//! a Stratix 10 2800 with a 5000-DSP target (§IV, §VI-A).
//!
//!   cargo run --release --example compile_resnet50
//!
//! Prints the compile-time story the paper tells: per-layer cycles
//! before/after balancing (Fig 3), the resource totals (Table II row 1),
//! the frequency estimate, and the simulated throughput/latency that
//! feed Fig 8.

use hpipe::arch::S10_2800;
use hpipe::compile::{balance::imbalance, compile, plan_stages, CompileOptions};
use hpipe::nets::{resnet50, NetConfig};
use hpipe::sim::simulate;
use hpipe::sparsity::prune_graph;
use hpipe::transform::optimize;
use hpipe::util::timer::Table;

fn main() -> hpipe::util::error::Result<()> {
    let full = std::env::args().any(|a| a == "--full-scale");
    let cfg = if full { NetConfig::imagenet() } else { NetConfig::test_scale() };
    let dsp_target = if full { 5000 } else { 1200 };

    let t0 = std::time::Instant::now();
    let mut graph = resnet50(cfg);
    prune_graph(&mut graph, 0.85);
    let (graph, log) = optimize(&graph);
    println!(
        "front-end: {} BNs folded, {} pads merged, graph now {} nodes",
        log.batch_norms_split,
        log.pads_merged,
        graph.len()
    );

    // unbalanced reference point (Fig 3 "Unbalanced" bars)
    let opts = CompileOptions::new(S10_2800.clone(), dsp_target);
    let (unbalanced, _) = plan_stages(&graph, &opts)?;

    let plan = compile(&graph, "resnet50", &opts)?;
    println!("compile time: {:?} (paper: \"a few seconds\")", t0.elapsed());

    let (alm_u, m20k_u, dsp_u) = plan.totals.utilization(&plan.device);
    println!(
        "\nresources: ALMs {} ({:.0}%)  M20Ks {} ({:.0}%)  DSPs {} ({:.0}%)  fmax {:.0} MHz",
        plan.totals.alms,
        alm_u * 100.0,
        plan.totals.m20ks,
        m20k_u * 100.0,
        plan.totals.dsps,
        dsp_u * 100.0,
        plan.fmax_mhz
    );

    let unb_interval = unbalanced.iter().map(|s| s.cycles).max().unwrap_or(1);
    println!(
        "balancing: interval {} -> {} cycles ({:.0}x), imbalance {:.1} -> {:.2}",
        unb_interval,
        plan.interval_cycles(),
        unb_interval as f64 / plan.interval_cycles() as f64,
        imbalance(&unbalanced),
        imbalance(&plan.stages)
    );

    let mut tab = Table::new(&["layer", "splits", "cycles (unbal)", "cycles (bal)", "dsps"]);
    for (u, b) in unbalanced.iter().zip(&plan.stages) {
        if !b.is_compute() {
            continue;
        }
        tab.row(&[
            b.name.clone(),
            b.splits.to_string(),
            u.cycles.to_string(),
            b.cycles.to_string(),
            b.resources.dsps.to_string(),
        ]);
    }
    tab.print();

    let sim = simulate(&plan, 12)?;
    println!(
        "\nsimulated: latency {:.3} ms, throughput {:.0} img/s at {:.0} MHz (paper: 4550 img/s @ 580 MHz full-scale)",
        sim.latency_ms(plan.fmax_mhz),
        sim.throughput_img_s(plan.fmax_mhz),
        plan.fmax_mhz
    );
    Ok(())
}
