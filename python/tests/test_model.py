"""L2 model tests: graphdef IO, forward equivalence, TinyCNN training."""

import os

import jax.numpy as jnp
import numpy as np
import pytest

from compile import graphio, model

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_graphdef_roundtrip(tmp_path):
    params = model.tiny_params(seed=5)
    g = model.tiny_graphdef(params)
    graphio.save(g, str(tmp_path))
    g2 = graphio.load(str(tmp_path))
    assert [n.name for n in g.nodes] == [n.name for n in g2.nodes]
    assert g.outputs == g2.outputs
    for a, b in zip(g.nodes, g2.nodes):
        assert a.op == b.op and a.inputs == b.inputs
        if a.tensor is not None:
            np.testing.assert_array_equal(a.tensor, b.tensor)


def test_small_constants_inline(tmp_path):
    g = graphio.GraphDef()
    g.add(graphio.Node("c", "Const", tensor=np.arange(4, dtype=np.float32)))
    g.outputs = ["c"]
    graphio.save(g, str(tmp_path))
    assert not os.path.exists(tmp_path / "weights.bin")
    g2 = graphio.load(str(tmp_path))
    np.testing.assert_array_equal(g2.node("c").tensor, np.arange(4, dtype=np.float32))


def test_forward_pallas_equals_ref():
    params = model.tiny_params(seed=7)
    g = model.tiny_graphdef(params)
    fwd_p = model.build_forward(g, use_pallas=True)
    fwd_r = model.build_forward(g, use_pallas=False)
    x = jnp.asarray(
        np.random.default_rng(0).normal(size=(1, 16, 16, 3)).astype(np.float32)
    )
    a, b = fwd_p(x)[0], fwd_r(x)[0]
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5)


def test_forward_matches_jnp_trainer_path():
    """The graphdef forward must equal the differentiable trainer forward
    (same params, softmax applied to trainer logits)."""
    params = model.tiny_params(seed=9)
    g = model.tiny_graphdef(params)
    fwd = model.build_forward(g, use_pallas=False)
    x = jnp.asarray(
        np.random.default_rng(1).normal(size=(1, 16, 16, 3)).astype(np.float32)
    )
    probs = np.asarray(fwd(x)[0])
    logits = np.asarray(model.tiny_forward_jnp(params, x))
    want = np.asarray(model.ref.softmax(jnp.asarray(logits)))
    np.testing.assert_allclose(probs, want, rtol=1e-5, atol=1e-6)


def test_training_reduces_loss():
    _, history = model.train_tiny(steps=60, batch=32, log_every=10)
    assert history[-1]["loss"] < history[0]["loss"] * 0.7
    assert history[-1]["accuracy"] > 0.3


def test_synthetic_dataset_is_classifiable_structure():
    xs, ys = model.synthetic_dataset(64, seed=3)
    assert xs.shape == (64, 16, 16, 3)
    assert set(np.unique(ys)).issubset(set(range(10)))
    # same class -> similar blob location: correlation within class higher
    c0 = xs[ys == ys[0]]
    if len(c0) > 2:
        a, b = c0[0].reshape(-1), c0[1].reshape(-1)
        other = xs[ys != ys[0]][0].reshape(-1)
        same = np.corrcoef(a, b)[0, 1]
        diff = np.corrcoef(a, other)[0, 1]
        assert same > diff


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACTS, "tinycnn", "graph.json")),
    reason="artifacts not built",
)
def test_artifact_graphdef_loads_and_runs():
    g = graphio.load(os.path.join(ARTIFACTS, "tinycnn"))
    fwd = model.build_forward(g, use_pallas=False)
    x = jnp.zeros((1, 16, 16, 3))
    out = fwd(x)[0]
    assert out.shape == (1, 10)
    s = float(jnp.sum(out))
    assert abs(s - 1.0) < 1e-4


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACTS, "tinycnn", "model.hlo.txt")),
    reason="artifacts not built",
)
def test_hlo_artifact_has_full_constants():
    """Regression for the silent-zero-weights bug: the HLO text must not
    contain elided '{...}' constants (xla_extension 0.5.1 parses those as
    zeros)."""
    with open(os.path.join(ARTIFACTS, "tinycnn", "model.hlo.txt")) as f:
        text = f.read()
    assert "{...}" not in text
    assert "ENTRY" in text


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACTS, "tinycnn", "train_log.json")),
    reason="artifacts not built",
)
def test_train_log_records_descending_loss():
    import json

    with open(os.path.join(ARTIFACTS, "tinycnn", "train_log.json")) as f:
        history = json.load(f)
    assert len(history) >= 5
    assert history[-1]["loss"] < history[0]["loss"]
