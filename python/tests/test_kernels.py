"""L1 kernel correctness: Pallas vs pure-jnp oracle.

Hypothesis sweeps shapes, strides, paddings, sparsities and split counts;
every kernel must match `ref.py` to float tolerance.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import dense_conv, ref, sparse_conv

TOL = dict(rtol=1e-4, atol=1e-4)


def random_weights(rng, kh, kw, ci, co, sparsity):
    w = rng.normal(size=(kh, kw, ci, co)).astype(np.float32)
    if sparsity > 0:
        flat = np.abs(w).reshape(-1)
        k = int(flat.size * sparsity)
        if k > 0:
            thresh = np.sort(flat)[k - 1]
            w[np.abs(w) <= thresh] = 0.0
    return w


@settings(max_examples=25, deadline=None)
@given(
    h=st.integers(4, 12),
    w_=st.integers(4, 12),
    ci=st.integers(1, 6),
    co=st.integers(1, 6),
    k=st.sampled_from([1, 3, 5]),
    stride=st.sampled_from([1, 2]),
    padding=st.sampled_from(["SAME", "VALID"]),
    sparsity=st.sampled_from([0.0, 0.5, 0.85, 0.95]),
    splits=st.integers(1, 4),
    seed=st.integers(0, 2**31),
)
def test_sparse_conv_matches_ref(h, w_, ci, co, k, stride, padding, sparsity, splits, seed):
    if padding == "VALID" and (h < k or w_ < k):
        return
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(1, h, w_, ci)).astype(np.float32))
    w = random_weights(rng, k, k, ci, co, sparsity)
    got = sparse_conv.sparse_conv2d(x, w, (stride, stride), padding, splits=splits)
    want = ref.conv2d(x, jnp.asarray(w), (stride, stride), padding)
    assert got.shape == want.shape
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)


@settings(max_examples=20, deadline=None)
@given(
    h=st.integers(4, 10),
    ci=st.integers(1, 5),
    co=st.integers(1, 5),
    k=st.sampled_from([1, 3]),
    stride=st.sampled_from([1, 2]),
    padding=st.sampled_from(["SAME", "VALID"]),
    seed=st.integers(0, 2**31),
)
def test_dense_conv_matches_ref(h, ci, co, k, stride, padding, seed):
    if padding == "VALID" and h < k:
        return
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(1, h, h, ci)).astype(np.float32))
    w = rng.normal(size=(k, k, ci, co)).astype(np.float32)
    got = dense_conv.dense_conv2d(x, w, (stride, stride), padding)
    want = ref.conv2d(x, jnp.asarray(w), (stride, stride), padding)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)


@settings(max_examples=20, deadline=None)
@given(
    h=st.integers(4, 10),
    c=st.integers(1, 6),
    m=st.integers(1, 2),
    stride=st.sampled_from([1, 2]),
    padding=st.sampled_from(["SAME", "VALID"]),
    seed=st.integers(0, 2**31),
)
def test_depthwise_matches_ref(h, c, m, stride, padding, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(1, h, h, c)).astype(np.float32))
    w = rng.normal(size=(3, 3, c, m)).astype(np.float32)
    if padding == "VALID" and h < 3:
        return
    got = dense_conv.depthwise_conv2d(x, w, (stride, stride), padding)
    want = ref.depthwise_conv2d(x, jnp.asarray(w), (stride, stride), padding)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(1, 4),
    ci=st.integers(1, 32),
    co=st.integers(1, 16),
    seed=st.integers(0, 2**31),
)
def test_matmul_matches_ref(n, ci, co, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n, ci)).astype(np.float32))
    w = rng.normal(size=(ci, co)).astype(np.float32)
    got = dense_conv.matmul(x, w)
    want = ref.matmul(x, jnp.asarray(w))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)


def test_all_zero_weights():
    x = jnp.ones((1, 6, 6, 2))
    w = np.zeros((3, 3, 2, 3), np.float32)
    got = sparse_conv.sparse_conv2d(x, w)
    assert float(jnp.max(jnp.abs(got))) == 0.0


def test_encode_gather_indices_counts():
    rng = np.random.default_rng(1)
    w = random_weights(rng, 3, 3, 8, 4, 0.85)
    vals, kys, kxs, cis = sparse_conv.encode_gather_indices(w, splits=2)
    nnz_encoded = int((vals != 0).sum())
    assert nnz_encoded == int((w != 0).sum())
    # indices in range
    assert kys.max() < 3 and kxs.max() < 3 and cis.max() < 8


def test_lockstep_padding_grows_stream():
    """The §IV nonlinearity: splits pad streams, so L is superlinear."""
    rng = np.random.default_rng(2)
    w = random_weights(rng, 3, 3, 16, 8, 0.9)
    l1 = sparse_conv.encode_gather_indices(w, splits=1)[0].shape[1]
    l8 = sparse_conv.encode_gather_indices(w, splits=8)[0].shape[1]
    assert l8 >= -(-l1 // 8)  # at least ceil(l1/8)


def test_sparse_conv_skips_work():
    """Zero-skipping: stream length tracks nnz, not the dense volume."""
    rng = np.random.default_rng(3)
    dense_w = random_weights(rng, 3, 3, 16, 4, 0.0)
    sparse_w = random_weights(rng, 3, 3, 16, 4, 0.9)
    l_dense = sparse_conv.encode_gather_indices(dense_w)[0].shape[1]
    l_sparse = sparse_conv.encode_gather_indices(sparse_w)[0].shape[1]
    assert l_sparse < l_dense / 4
