"""Test bootstrap: put `python/` on sys.path so `compile.*` imports work
when pytest is invoked from the repo root, and skip hypothesis-based
modules gracefully in environments without the dependency (the offline
image carries jax/numpy only)."""

import os
import sys

sys.path.insert(0, os.path.abspath(os.path.join(os.path.dirname(__file__), "..")))

collect_ignore = []
try:
    import hypothesis  # noqa: F401
except ImportError:
    collect_ignore.append("test_kernels.py")
