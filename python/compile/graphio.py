"""Read/write the `hpipe-graphdef-v1` interchange format.

Mirrors rust/src/graph/graphdef.rs byte-for-byte: `graph.json` structural
description plus `weights.bin` (flat little-endian f32) referenced by
(offset, len); constants of ≤ 16 elements inline in the JSON.
"""

from __future__ import annotations

import json
import os

import numpy as np

INLINE_LIMIT = 16
FORMAT = "hpipe-graphdef-v1"


class Node:
    def __init__(self, name, op, attrs=None, inputs=None, tensor=None):
        self.name = name
        self.op = op
        self.attrs = attrs or {}
        self.inputs = inputs or []
        self.tensor = tensor  # numpy array for Const nodes

    def __repr__(self):
        return f"Node({self.name!r}, {self.op})"


class GraphDef:
    def __init__(self, nodes=None, outputs=None):
        self.nodes: list[Node] = nodes or []
        self.outputs: list[str] = outputs or []

    def node(self, name):
        for n in self.nodes:
            if n.name == name:
                return n
        raise KeyError(name)

    def add(self, node: Node):
        self.nodes.append(node)
        return node.name

    def topo_order(self):
        by_name = {n.name: n for n in self.nodes}
        seen, order = set(), []

        def visit(name):
            if name in seen:
                return
            seen.add(name)
            for i in by_name[name].inputs:
                visit(i)
            order.append(by_name[name])

        for n in self.nodes:
            visit(n.name)
        return order


def save(g: GraphDef, dirpath: str) -> None:
    os.makedirs(dirpath, exist_ok=True)
    blob = bytearray()
    nodes_json = []
    for n in g.nodes:
        jn = {
            "name": n.name,
            "op": n.op,
            "attrs": n.attrs,
            "inputs": n.inputs,
        }
        if n.tensor is not None:
            t = np.asarray(n.tensor, dtype=np.float32)
            jt = {"shape": list(t.shape)}
            if t.size <= INLINE_LIMIT:
                jt["data"] = [float(v) for v in t.reshape(-1)]
            else:
                jt["offset"] = len(blob) // 4
                jt["len"] = int(t.size)
                blob.extend(t.reshape(-1).tobytes())
            jn["tensor"] = jt
        nodes_json.append(jn)
    root = {"format": FORMAT, "nodes": nodes_json, "outputs": g.outputs}
    with open(os.path.join(dirpath, "graph.json"), "w") as f:
        json.dump(root, f, indent=2, sort_keys=True)
        f.write("\n")
    if blob:
        with open(os.path.join(dirpath, "weights.bin"), "wb") as f:
            f.write(bytes(blob))


def load(dirpath: str) -> GraphDef:
    with open(os.path.join(dirpath, "graph.json")) as f:
        root = json.load(f)
    if root.get("format") != FORMAT:
        raise ValueError(f"unrecognized graphdef format: {root.get('format')}")
    blob_path = os.path.join(dirpath, "weights.bin")
    blob = np.fromfile(blob_path, dtype="<f4") if os.path.exists(blob_path) else None
    g = GraphDef(outputs=list(root["outputs"]))
    for jn in root["nodes"]:
        tensor = None
        jt = jn.get("tensor")
        if jt is not None:
            shape = tuple(int(s) for s in jt["shape"])
            if "data" in jt:
                tensor = np.asarray(jt["data"], dtype=np.float32).reshape(shape)
            else:
                off, ln = int(jt["offset"]), int(jt["len"])
                tensor = blob[off : off + ln].reshape(shape).copy()
        g.add(Node(jn["name"], jn["op"], dict(jn.get("attrs", {})), list(jn["inputs"]), tensor))
    return g
