"""Layer-1 Pallas kernel: HPIPE's gather-based sparse direct convolution.

The paper's hot-spot (§III-A, §V-B) rethought for the TPU-style memory
hierarchy (DESIGN.md §Hardware-Adaptation):

* HPIPE stores, per output channel, a runlength-compressed stream of
  nonzero weights and decodes it into *activation gather addresses* at
  runtime, with the stream shared by every output column (one X-mux per
  multiplier). The sparsity pattern is frozen at compile time — the
  weight buffer is a ROM.
* Here the same compile-time-frozen pattern becomes static index arrays
  baked into the program: for each output channel, the padded lock-step
  stream of (k_y, k_x, c_i) positions and values. The kernel gathers
  activations by those indices and multiply-accumulates — zero weights
  are never touched, exactly like the hardware's 0-skipping.
* The pipeline's "one output line at a time" dataflow (§V-A) becomes the
  Pallas grid: one grid step per output line; the BlockSpec index_map
  stages the k_h input lines the line needs from HBM into VMEM, the
  analog of HPIPE's input activation ring buffers.

`interpret=True` everywhere: the CPU PJRT client cannot run Mosaic
custom-calls (see /opt/xla-example/README.md); real-TPU numbers are
estimated from VMEM/MXU structure in EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from . import ref

# Lock-step stream padding mirrors rust/src/sparsity/rle.rs: runlength
# field width caps a single hop; splits pad to the longest stream.
RUNLENGTH_BITS = 4


def encode_gather_indices(w: np.ndarray, splits: int = 1):
    """Compress HWIO weights into per-output-channel gather streams.

    Returns (vals, ky, kx, ci) int/float32 arrays of shape [Co, L] where
    L is the longest padded lock-step stream over all output channels —
    pad entries have value 0 and index (0,0,0). The per-(oc, split)
    stream layout matches rust/src/sparsity/rle.rs::encode_conv, so the
    Rust compiler's cycle counts correspond 1:1 to this kernel's L.
    """
    kh, kw, ci, co = w.shape
    max_run = (1 << RUNLENGTH_BITS) - 1
    streams = []  # per oc: list of (ky, kx, ci, val)
    longest = 0
    for oc in range(co):
        per_split = [[] for _ in range(splits)]
        last_local = [None] * splits
        for row in range(kh * ci):
            ky, c = divmod(row, ci)
            split = row % splits
            local = row // splits
            for kx in range(kw):
                v = w[ky, kx, c, oc]
                if v == 0.0:
                    continue
                gap = local if last_local[split] is None else local - last_local[split]
                pads = 0 if gap == 0 else (gap - 1) // max_run
                per_split[split].extend([(0, 0, 0, 0.0)] * pads)
                per_split[split].append((ky, kx, c, float(v)))
                last_local[split] = local
        # lock-step: all splits padded to the longest split stream, then
        # interleaved (split-major is equivalent for the gather)
        slen = max((len(s) for s in per_split), default=0)
        merged = []
        for s in per_split:
            merged.extend(s + [(0, 0, 0, 0.0)] * (slen - len(s)))
        streams.append(merged)
        longest = max(longest, len(merged))
    vals = np.zeros((co, longest), np.float32)
    kys = np.zeros((co, longest), np.int32)
    kxs = np.zeros((co, longest), np.int32)
    cis = np.zeros((co, longest), np.int32)
    for oc, entries in enumerate(streams):
        for j, (ky, kx, c, v) in enumerate(entries):
            kys[oc, j], kxs[oc, j], cis[oc, j], vals[oc, j] = ky, kx, c, v
    return vals, kys, kxs, cis


def _line_kernel(x_ref, val_ref, ky_ref, kx_ref, ci_ref, o_ref, *, out_w, sw, sh):
    """One grid step = one output line (§V-A's output channel group).

    x_ref:   [H_pad, W_pad, Ci]  padded input (the grid step reads only
             the k_h lines at y*sh — Pallas block windows cannot overlap,
             so the staging window of a real-TPU version is documented in
             EXPERIMENTS.md §Perf instead of expressed in the BlockSpec)
    val_ref: [Co, L]             lock-step weight stream values
    ky/kx/ci_ref: [Co, L]        gather indices (static content)
    o_ref:   [1, out_w, Co]
    """
    y = pl.program_id(0)
    x = x_ref[...]
    val = val_ref[...]
    ky = ky_ref[...]
    kx = kx_ref[...]
    ci = ci_ref[...]
    xs = jnp.arange(out_w) * sw  # output column -> input column base
    # gather: [out_w, Co, L]; the (ky, kx, ci) triple plays the role of
    # the decoded runlength + X-mux select of Fig 6, and y*sh + ky is the
    # input activation ring-buffer address
    g = x[y * sh + ky[None, :, :], xs[:, None, None] + kx[None, :, :], ci[None, :, :]]
    acc = jnp.sum(g * val[None, :, :], axis=-1)  # DSP-chain accumulation
    o_ref[...] = acc[None, :, :]


def sparse_conv2d(x, w, stride=(1, 1), padding="SAME", splits=1, interpret=True):
    """Gather-based sparse conv via pallas_call; drop-in for ref.conv2d.

    `w` must be a concrete (numpy) array — the sparsity pattern is baked
    into the compiled program, as in the hardware.
    """
    w = np.asarray(w)
    kh, kw, ci, co = w.shape
    sh, sw = stride
    in_h, in_w = x.shape[1], x.shape[2]
    t, b, l, r = ref.resolve_padding(padding, in_h, in_w, kh, kw, sh, sw)
    out_h = (in_h + t + b - kh) // sh + 1
    out_w = (in_w + l + r - kw) // sw + 1

    vals, kys, kxs, cis = encode_gather_indices(w, splits)
    # hardware pads with zero lines (Pad Muxes of Fig 6); same here
    xp = jnp.pad(x[0], ((t, b), (l, r), (0, 0)))

    # guard against an all-zero weight tensor (L would be 0)
    if vals.shape[1] == 0:
        vals = np.zeros((co, 1), np.float32)
        kys = np.zeros((co, 1), np.int32)
        kxs = np.zeros((co, 1), np.int32)
        cis = np.zeros((co, 1), np.int32)

    grid = (out_h,)
    kernel = functools.partial(_line_kernel, out_w=out_w, sw=sw, sh=sh)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            # padded input resident; each step reads its k_h-line window
            pl.BlockSpec(xp.shape, lambda y: (0, 0, 0)),
            # the weight streams are resident (weight buffer ROM)
            pl.BlockSpec(vals.shape, lambda y: (0, 0)),
            pl.BlockSpec(kys.shape, lambda y: (0, 0)),
            pl.BlockSpec(kxs.shape, lambda y: (0, 0)),
            pl.BlockSpec(cis.shape, lambda y: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, out_w, co), lambda y: (y, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((out_h, out_w, co), jnp.float32),
        interpret=interpret,
    )(xp, vals, kys, kxs, cis)
    return out[None, ...]


def vmem_footprint_bytes(in_w, ci, kh, co, stream_len):
    """Estimated VMEM bytes one grid step holds (EXPERIMENTS.md §Perf):
    input line window + weight streams + output line."""
    x_block = kh * in_w * ci * 4
    streams = co * stream_len * (4 + 3 * 4)
    out_line = in_w * co * 4
    return x_block + streams + out_line
