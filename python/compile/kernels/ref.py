"""Pure-jnp reference implementations (the L1 correctness oracles).

These mirror the Rust reference interpreter (`rust/src/interp`) exactly:
NHWC activations, HWIO conv weights, TensorFlow SAME/VALID/explicit
padding semantics. Every Pallas kernel in this package is pinned against
these via pytest + hypothesis.
"""

from __future__ import annotations

import jax.numpy as jnp
from jax import lax


def resolve_padding(padding, in_h, in_w, kh, kw, sh, sw):
    """TF-style padding -> (top, bottom, left, right)."""
    if padding == "VALID":
        return (0, 0, 0, 0)
    if padding == "SAME":

        def along(i, k, s):
            out = -(-i // s)
            return max((out - 1) * s + k - i, 0)

        ph, pw = along(in_h, kh, sh), along(in_w, kw, sw)
        return (ph // 2, ph - ph // 2, pw // 2, pw - pw // 2)
    t, b, l, r = padding
    return (int(t), int(b), int(l), int(r))


def conv2d(x, w, stride=(1, 1), padding="SAME"):
    """x: [1,H,W,Ci] f32, w: [kh,kw,Ci,Co]."""
    t, b, l, r = resolve_padding(
        padding, x.shape[1], x.shape[2], w.shape[0], w.shape[1], *stride
    )
    return lax.conv_general_dilated(
        x,
        w,
        window_strides=stride,
        padding=((t, b), (l, r)),
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def depthwise_conv2d(x, w, stride=(1, 1), padding="SAME"):
    """x: [1,H,W,C], w: [kh,kw,C,M] -> [1,H',W',C*M]."""
    c = x.shape[3]
    m = w.shape[3]
    t, b, l, r = resolve_padding(
        padding, x.shape[1], x.shape[2], w.shape[0], w.shape[1], *stride
    )
    return lax.conv_general_dilated(
        x,
        jnp.reshape(w, (w.shape[0], w.shape[1], 1, c * m)),
        window_strides=stride,
        padding=((t, b), (l, r)),
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=c,
    )


def matmul(x, w):
    return x @ w


def bias_add(x, b):
    return x + b


def relu(x):
    return jnp.maximum(x, 0.0)


def relu6(x):
    return jnp.clip(x, 0.0, 6.0)


def max_pool(x, ksize=(2, 2), stride=(2, 2), padding="VALID"):
    t, b, l, r = resolve_padding(
        padding, x.shape[1], x.shape[2], ksize[0], ksize[1], *stride
    )
    return lax.reduce_window(
        x,
        -jnp.inf,
        lax.max,
        (1, ksize[0], ksize[1], 1),
        (1, stride[0], stride[1], 1),
        ((0, 0), (t, b), (l, r), (0, 0)),
    )


def global_mean(x):
    """NHWC -> [N, C]."""
    return jnp.mean(x, axis=(1, 2))


def softmax(x):
    z = x - jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(z)
    return e / jnp.sum(e, axis=-1, keepdims=True)
