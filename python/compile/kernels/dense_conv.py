"""Layer-1 Pallas kernels for the dense paths: direct line-at-a-time
convolution, depthwise convolution, and the classifier matmul.

The dense MobileNet evaluations (Table IV) do not use 0-skipping, so
these kernels stream *all* weights — but keep HPIPE's dataflow: one
output line per grid step, weights resident, MXU-friendly contractions
(the inner op is a [W·kh·kw·Ci] × [kh·kw·Ci, Co] matmul, which on a real
TPU maps onto the 128×128 systolic array the way HPIPE's DSP chains map
onto DSP columns).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from . import ref


def _dense_line_kernel(x_ref, w_ref, o_ref, *, out_w, sw, sh, kh, kw):
    """o[y, x, oc] = sum_{ky,kx,ci} x[y*sh+ky, x*sw+kx, ci] * w[ky,kx,ci,oc]."""
    y = pl.program_id(0)
    x = x_ref[...]
    w = w_ref[...]
    ci = x.shape[-1]
    # im2col the line: [out_w, kh*kw*ci]
    cols = []
    for ky in range(kh):
        for kx in range(kw):
            rows = jax.lax.dynamic_slice_in_dim(x, y * sh + ky, 1, axis=0)[0]
            idx = jnp.arange(out_w) * sw + kx
            cols.append(rows[idx, :])
    patch = jnp.concatenate(cols, axis=-1)  # [out_w, kh*kw*ci]
    wm = w.reshape(kh * kw * ci, -1)  # [kh*kw*ci, co] (HWIO flatten)
    o_ref[...] = (patch @ wm)[None, :, :]


def dense_conv2d(x, w, stride=(1, 1), padding="SAME", interpret=True):
    """Direct dense conv, one output line per grid step."""
    w = jnp.asarray(w)
    kh, kw, ci, co = w.shape
    sh, sw = stride
    t, b, l, r = ref.resolve_padding(padding, x.shape[1], x.shape[2], kh, kw, sh, sw)
    out_h = (x.shape[1] + t + b - kh) // sh + 1
    out_w = (x.shape[2] + l + r - kw) // sw + 1
    xp = jnp.pad(x[0], ((t, b), (l, r), (0, 0)))
    kernel = functools.partial(
        _dense_line_kernel, out_w=out_w, sw=sw, sh=sh, kh=kh, kw=kw
    )
    out = pl.pallas_call(
        kernel,
        grid=(out_h,),
        in_specs=[
            pl.BlockSpec(xp.shape, lambda y: (0, 0, 0)),
            pl.BlockSpec(w.shape, lambda y: (0, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, out_w, co), lambda y: (y, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((out_h, out_w, co), jnp.float32),
        interpret=interpret,
    )(xp, w)
    return out[None, ...]


def _depthwise_line_kernel(x_ref, w_ref, o_ref, *, out_w, sw, sh, kh, kw):
    """Depthwise: per-channel taps, no cross-channel reduction (the
    HPIPE depthwise module has no DSP chain — §V's shift-like unit)."""
    y = pl.program_id(0)
    x = x_ref[...]
    w = w_ref[...]  # [kh, kw, C, M]
    c = x.shape[-1]
    m = w.shape[-1]
    acc = jnp.zeros((out_w, c * m), jnp.float32)
    for ky in range(kh):
        row = jax.lax.dynamic_slice_in_dim(x, y * sh + ky, 1, axis=0)[0]
        for kx in range(kw):
            idx = jnp.arange(out_w) * sw + kx
            a = row[idx, :]  # [out_w, C]
            taps = w[ky, kx]  # [C, M]
            acc = acc + (a[:, :, None] * taps[None, :, :]).reshape(out_w, c * m)
    o_ref[...] = acc[None, :, :]


def depthwise_conv2d(x, w, stride=(1, 1), padding="SAME", interpret=True):
    w = jnp.asarray(w)
    kh, kw, c, m = w.shape
    sh, sw = stride
    t, b, l, r = ref.resolve_padding(padding, x.shape[1], x.shape[2], kh, kw, sh, sw)
    out_h = (x.shape[1] + t + b - kh) // sh + 1
    out_w = (x.shape[2] + l + r - kw) // sw + 1
    xp = jnp.pad(x[0], ((t, b), (l, r), (0, 0)))
    kernel = functools.partial(
        _depthwise_line_kernel, out_w=out_w, sw=sw, sh=sh, kh=kh, kw=kw
    )
    out = pl.pallas_call(
        kernel,
        grid=(out_h,),
        in_specs=[
            pl.BlockSpec(xp.shape, lambda y: (0, 0, 0)),
            pl.BlockSpec(w.shape, lambda y: (0, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, out_w, c * m), lambda y: (y, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((out_h, out_w, c * m), jnp.float32),
        interpret=interpret,
    )(xp, w)
    return out[None, ...]


def _matmul_kernel(x_ref, w_ref, o_ref):
    o_ref[...] = x_ref[...] @ w_ref[...]


def matmul(x, w, interpret=True):
    """Classifier matvec ([N,Ci] @ [Ci,Co]) as a single-step kernel —
    HPIPE implements it as a 1x1x1 convolution (§V-B)."""
    w = jnp.asarray(w)
    return pl.pallas_call(
        _matmul_kernel,
        out_shape=jax.ShapeDtypeStruct((x.shape[0], w.shape[1]), jnp.float32),
        interpret=interpret,
    )(x, w)
