"""AOT build: train TinyCNN, export graphdef + HLO-text artifacts.

This is the ONLY Python entry point in the build (`make artifacts`); the
Rust binary is self-contained afterwards. Outputs under `artifacts/`:

  tinycnn/graph.json + weights.bin   trained TinyCNN graphdef (loaded by
                                     the Rust compiler/simulator/interp)
  tinycnn/train_log.json             loss/accuracy curve of the training
                                     run (end-to-end validation evidence)
  tinycnn/model.hlo.txt              Pallas-kernel inference fn, batch 1
  tinycnn/model_b8.hlo.txt           batch-8 variant for the batcher
  kernels/sparse_conv_demo.hlo.txt   standalone gather-conv kernel
                                     (runtime micro-bench)
  manifest.json                      shapes + metadata for the runtime

HLO *text* is the interchange format — jax>=0.5 serialized protos use
64-bit ids that xla_extension 0.5.1 rejects (see /opt/xla-example).
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import graphio, model
from .kernels import sparse_conv


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True is load-bearing: the default HLO printer
    # elides big weight constants as "{...}", which xla_extension 0.5.1's
    # text parser silently reads back as ZEROS — the whole model would
    # run with zero weights on the Rust side.
    return comp.as_hlo_text(print_large_constants=True)


def lower_tiny(g: graphio.GraphDef, batch: int) -> str:
    """Lower the Pallas-kernel TinyCNN forward at the given batch size.

    The graph itself is batch-1 (HPIPE is a batch-1 pipeline); batching
    for the host-side batcher is a vmap over the same function — the
    Pallas kernels trace once per line regardless.
    """
    fwd = model.build_forward(g, use_pallas=True, interpret=True)
    fn = fwd if batch == 1 else jax.vmap(lambda xi: fwd(xi[None, ...])[0][0])
    spec = (
        jax.ShapeDtypeStruct((1, model.TINY_INPUT, model.TINY_INPUT, 3), jnp.float32)
        if batch == 1
        else jax.ShapeDtypeStruct(
            (batch, model.TINY_INPUT, model.TINY_INPUT, 3), jnp.float32
        )
    )
    lowered = jax.jit(fn).lower(spec)
    return to_hlo_text(lowered)


def lower_sparse_conv_demo() -> tuple[str, dict]:
    """A standalone gather-based sparse conv (16x16x16 -> 16ch, 85%
    sparse) for the runtime micro-benchmark."""
    rng = np.random.default_rng(7)
    w = rng.normal(size=(3, 3, 16, 16)).astype(np.float32)
    flat = np.abs(w).reshape(-1)
    thresh = np.sort(flat)[int(flat.size * 0.85)]
    w[np.abs(w) < thresh] = 0.0

    def fn(x):
        return (sparse_conv.sparse_conv2d(x, w, (1, 1), "SAME", splits=4),)

    spec = jax.ShapeDtypeStruct((1, 16, 16, 16), jnp.float32)
    lowered = jax.jit(fn).lower(spec)
    meta = {
        "input_shape": [1, 16, 16, 16],
        "output_shape": [1, 16, 16, 16],
        "sparsity": float((w == 0).mean()),
    }
    return to_hlo_text(lowered), meta


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts", help="artifacts directory")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch-variants", type=int, nargs="*", default=[1, 8])
    args = ap.parse_args()
    out = os.path.abspath(args.out)
    tiny_dir = os.path.join(out, "tinycnn")
    kern_dir = os.path.join(out, "kernels")
    os.makedirs(tiny_dir, exist_ok=True)
    os.makedirs(kern_dir, exist_ok=True)

    print(f"[aot] training TinyCNN for {args.steps} steps ...")
    params, history = model.train_tiny(steps=args.steps)
    model.save_history(history, os.path.join(tiny_dir, "train_log.json"))
    print(
        f"[aot] trained: loss {history[0]['loss']:.3f} -> {history[-1]['loss']:.3f}, "
        f"val accuracy {history[-1]['accuracy']:.3f}"
    )

    g = model.tiny_graphdef(params)
    graphio.save(g, tiny_dir)
    print(f"[aot] wrote graphdef to {tiny_dir}")

    # cross-check: pallas forward == jnp forward on the trained weights
    fwd_pallas = model.build_forward(g, use_pallas=True)
    fwd_ref = model.build_forward(g, use_pallas=False)
    x = jnp.asarray(
        np.random.default_rng(3).normal(
            size=(1, model.TINY_INPUT, model.TINY_INPUT, 3)
        ).astype(np.float32)
    )
    a, b = fwd_pallas(x)[0], fwd_ref(x)[0]
    err = float(jnp.max(jnp.abs(a - b)))
    assert err < 1e-4, f"pallas/ref mismatch: {err}"
    print(f"[aot] pallas-vs-ref max |err| = {err:.2e}")

    manifest = {
        "models": {},
        "kernels": {},
        "input_shape": [1, model.TINY_INPUT, model.TINY_INPUT, 3],
        "classes": model.TINY_CLASSES,
    }
    for batch in args.batch_variants:
        name = "model.hlo.txt" if batch == 1 else f"model_b{batch}.hlo.txt"
        text = lower_tiny(g, batch)
        with open(os.path.join(tiny_dir, name), "w") as f:
            f.write(text)
        manifest["models"][str(batch)] = f"tinycnn/{name}"
        print(f"[aot] lowered batch={batch}: {len(text)} chars of HLO")

    demo, meta = lower_sparse_conv_demo()
    with open(os.path.join(kern_dir, "sparse_conv_demo.hlo.txt"), "w") as f:
        f.write(demo)
    manifest["kernels"]["sparse_conv_demo"] = {
        "path": "kernels/sparse_conv_demo.hlo.txt",
        **meta,
    }
    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
        f.write("\n")
    print(f"[aot] done -> {out}")


if __name__ == "__main__":
    main()
