"""Layer 2: the JAX model — graphdef -> forward function.

Builds a JAX forward pass from the same `hpipe-graphdef-v1` files the
Rust compiler consumes, dispatching convolutions to the Layer-1 Pallas
kernels (gather-based sparse conv for pruned layers, dense line conv /
depthwise / matmul otherwise). Used by `aot.py` to lower the network to
HLO text once at build time; never imported by the serving path.

Also contains the TinyCNN definition + trainer for the end-to-end
validation model (the Python twin of rust/src/nets/tiny.rs).
"""

from __future__ import annotations

import json

import jax
import jax.numpy as jnp
import numpy as np

from . import graphio
from .kernels import dense_conv, ref, sparse_conv

# A conv layer whose weights are at least this sparse is compiled through
# the gather-based 0-skipping kernel (the paper's threshold is implicit:
# ResNet is pruned, MobileNets run dense).
SPARSE_THRESHOLD = 0.30


def build_forward(g: graphio.GraphDef, use_pallas=True, interpret=True):
    """Return fwd(x) -> tuple of outputs, with all weights baked in.

    With use_pallas=False the pure-jnp reference ops are used instead —
    that variant is the oracle the Pallas build is pytest-compared to.
    """
    order = g.topo_order()

    def fwd(x):
        env = {}
        for n in order:
            op = n.op
            a = n.attrs
            if op == "Placeholder":
                env[n.name] = x
            elif op == "Const":
                env[n.name] = jnp.asarray(n.tensor)
            elif op in ("Conv2D", "DepthwiseConv2dNative"):
                inp = env[n.inputs[0]]
                w = np.asarray(g.node(n.inputs[1]).tensor)
                stride = tuple(a.get("stride", [1, 1]))
                padding = a.get("padding", "SAME")
                if isinstance(padding, list):
                    padding = tuple(padding)
                if op == "Conv2D":
                    sparsity = float((w == 0.0).mean())
                    if use_pallas and sparsity >= SPARSE_THRESHOLD:
                        env[n.name] = sparse_conv.sparse_conv2d(
                            inp, w, stride, padding, interpret=interpret
                        )
                    elif use_pallas:
                        env[n.name] = dense_conv.dense_conv2d(
                            inp, w, stride, padding, interpret=interpret
                        )
                    else:
                        env[n.name] = ref.conv2d(inp, jnp.asarray(w), stride, padding)
                else:
                    if use_pallas:
                        env[n.name] = dense_conv.depthwise_conv2d(
                            inp, w, stride, padding, interpret=interpret
                        )
                    else:
                        env[n.name] = ref.depthwise_conv2d(
                            inp, jnp.asarray(w), stride, padding
                        )
            elif op == "MatMul":
                w = jnp.asarray(g.node(n.inputs[1]).tensor)
                if use_pallas:
                    env[n.name] = dense_conv.matmul(env[n.inputs[0]], w, interpret=interpret)
                else:
                    env[n.name] = ref.matmul(env[n.inputs[0]], w)
            elif op == "BiasAdd":
                env[n.name] = env[n.inputs[0]] + jnp.asarray(g.node(n.inputs[1]).tensor)
            elif op == "MaxPool":
                env[n.name] = ref.max_pool(
                    env[n.inputs[0]],
                    tuple(a["ksize"]),
                    tuple(a["stride"]),
                    a.get("padding", "VALID")
                    if not isinstance(a.get("padding"), list)
                    else tuple(a["padding"]),
                )
            elif op == "Relu":
                env[n.name] = ref.relu(env[n.inputs[0]])
            elif op == "Relu6":
                env[n.name] = ref.relu6(env[n.inputs[0]])
            elif op == "Add":
                env[n.name] = env[n.inputs[0]] + env[n.inputs[1]]
            elif op == "Mean":
                env[n.name] = ref.global_mean(env[n.inputs[0]])
            elif op == "Softmax":
                env[n.name] = ref.softmax(env[n.inputs[0]])
            elif op == "Pad":
                t, b, l, r = a["pads"]
                env[n.name] = jnp.pad(
                    env[n.inputs[0]], ((0, 0), (t, b), (l, r), (0, 0))
                )
            else:
                raise ValueError(f"unsupported op in graphdef: {op}")
        return tuple(env[o] for o in g.outputs)

    return fwd


# ---------------------------------------------------------------------
# TinyCNN (the end-to-end model) — must match rust/src/nets/tiny.rs
# ---------------------------------------------------------------------

TINY_INPUT = 16
TINY_CHANNELS = [16, 32, 64]
TINY_CLASSES = 10


def tiny_params(seed=0):
    """He-init parameter dict for TinyCNN."""
    rng = np.random.default_rng(seed)
    params = {}
    cin = 3
    for i, cout in enumerate(TINY_CHANNELS):
        std = (2.0 / (9 * cin)) ** 0.5
        params[f"conv{i}/weights"] = rng.normal(0, std, (3, 3, cin, cout)).astype(
            np.float32
        )
        params[f"conv{i}/biasadd/bias"] = np.zeros(cout, np.float32)
        cin = cout
    std = (2.0 / cin) ** 0.5
    params["logits/weights"] = rng.normal(0, std, (cin, TINY_CLASSES)).astype(np.float32)
    params["logits/biasadd/bias"] = np.zeros(TINY_CLASSES, np.float32)
    return params


def tiny_forward_jnp(params, x):
    """Differentiable TinyCNN forward in plain jnp (training path)."""
    h = x
    for i in range(len(TINY_CHANNELS)):
        h = ref.conv2d(h, jnp.asarray(params[f"conv{i}/weights"]), (1, 1), "SAME")
        h = h + jnp.asarray(params[f"conv{i}/biasadd/bias"])
        h = ref.relu(h)
        h = ref.max_pool(h, (2, 2), (2, 2), "VALID")
    h = ref.global_mean(h)
    h = ref.matmul(h, jnp.asarray(params["logits/weights"]))
    h = h + jnp.asarray(params["logits/biasadd/bias"])
    return h  # logits


def tiny_graphdef(params) -> graphio.GraphDef:
    """Emit TinyCNN as a graphdef (same node names/topology as tiny.rs)."""
    g = graphio.GraphDef()
    g.add(
        graphio.Node(
            "input", "Placeholder", {"shape": [1, TINY_INPUT, TINY_INPUT, 3]}
        )
    )
    prev = "input"
    for i, cout in enumerate(TINY_CHANNELS):
        wname = f"conv{i}/weights"
        g.add(graphio.Node(wname, "Const", tensor=params[wname]))
        g.add(
            graphio.Node(
                f"conv{i}",
                "Conv2D",
                {"stride": [1, 1], "padding": "SAME"},
                [prev, wname],
            )
        )
        bname = f"conv{i}/biasadd/bias"
        g.add(graphio.Node(bname, "Const", tensor=params[bname]))
        g.add(graphio.Node(f"conv{i}/biasadd", "BiasAdd", {}, [f"conv{i}", bname]))
        g.add(graphio.Node(f"conv{i}/relu", "Relu", {}, [f"conv{i}/biasadd"]))
        g.add(
            graphio.Node(
                f"pool{i}",
                "MaxPool",
                {"ksize": [2, 2], "stride": [2, 2], "padding": "VALID"},
                [f"conv{i}/relu"],
            )
        )
        prev = f"pool{i}"
    g.add(graphio.Node("global_pool", "Mean", {}, [prev]))
    g.add(graphio.Node("logits/weights", "Const", tensor=params["logits/weights"]))
    g.add(graphio.Node("logits", "MatMul", {}, ["global_pool", "logits/weights"]))
    g.add(
        graphio.Node(
            "logits/biasadd/bias", "Const", tensor=params["logits/biasadd/bias"]
        )
    )
    g.add(
        graphio.Node(
            "logits/biasadd", "BiasAdd", {}, ["logits", "logits/biasadd/bias"]
        )
    )
    g.add(graphio.Node("predictions", "Softmax", {}, ["logits/biasadd"]))
    g.outputs = ["predictions"]
    return g


def synthetic_dataset(n, seed=1):
    """10-class synthetic image data: class-dependent Gaussian blobs on a
    noisy background — learnable in a few hundred steps, non-trivial."""
    rng = np.random.default_rng(seed)
    xs = rng.normal(0, 0.35, (n, TINY_INPUT, TINY_INPUT, 3)).astype(np.float32)
    ys = rng.integers(0, TINY_CLASSES, n)
    yy, xx = np.mgrid[0:TINY_INPUT, 0:TINY_INPUT]
    for i in range(n):
        c = int(ys[i])
        # blob position and channel signature derived from the class
        cy, cx = 3 + (c % 3) * 5, 3 + (c // 3) * 4
        blob = np.exp(-(((yy - cy) ** 2 + (xx - cx) ** 2) / 8.0))
        for ch in range(3):
            xs[i, :, :, ch] += blob * (1.0 if (c + ch) % 3 else -1.0) * 2.0
    return xs, ys.astype(np.int32)


def train_tiny(steps=300, batch=64, lr=0.05, seed=0, log_every=20):
    """Train TinyCNN on the synthetic set with SGD + momentum.

    Returns (params, history) where history is a list of
    {step, loss, accuracy} dicts (the logged loss curve required by the
    end-to-end validation deliverable).
    """
    params = tiny_params(seed)
    xs, ys = synthetic_dataset(4096, seed=seed + 1)
    xt, yt = synthetic_dataset(512, seed=seed + 2)

    def loss_fn(p, xb, yb):
        logits = tiny_forward_jnp(p, xb)
        logp = jax.nn.log_softmax(logits)
        return -jnp.mean(logp[jnp.arange(xb.shape[0]), yb])

    grad_fn = jax.jit(jax.value_and_grad(loss_fn))

    @jax.jit
    def accuracy(p, xb, yb):
        return jnp.mean(jnp.argmax(tiny_forward_jnp(p, xb), -1) == yb)

    momentum = {k: np.zeros_like(v) for k, v in params.items()}
    rng = np.random.default_rng(seed + 3)
    history = []
    for step in range(steps):
        idx = rng.integers(0, xs.shape[0], batch)
        loss, grads = grad_fn(params, xs[idx], ys[idx])
        for k in params:
            momentum[k] = 0.9 * momentum[k] + np.asarray(grads[k])
            params[k] = params[k] - lr * momentum[k]
        if step % log_every == 0 or step == steps - 1:
            acc = float(accuracy(params, xt, yt))
            history.append({"step": step, "loss": float(loss), "accuracy": acc})
    return params, history


def save_history(history, path):
    with open(path, "w") as f:
        json.dump(history, f, indent=2)
        f.write("\n")
