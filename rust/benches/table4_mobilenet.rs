//! Table IV reproduction: dense MobileNet comparison.
//!
//! HPIPE(V2) vs Wu et al. on the per-multiplier normalization the paper
//! uses ("divide our throughput by the number of 18x18 multipliers we
//! use and divide their throughput by the number of 27x18 multipliers
//! they use" -> 1.95x), and HPIPE(V1) vs the V100.

use hpipe::arch::{S10_1650, S10_2800};
use hpipe::baselines::{throughput_per_multiplier, PaperHpipe, V100_MOBILENET_V1, WuEtAl};
use hpipe::compile::{compile, CompileOptions};
use hpipe::nets::{build_named, NetConfig};
use hpipe::sim::simulate;
use hpipe::transform::optimize;
use hpipe::util::timer::Table;

fn compile_and_sim(net: &str, cfg: NetConfig, dsp: usize) -> (f64, f64, usize) {
    let g = build_named(net, cfg).unwrap();
    let (g, _) = optimize(&g);
    let plan = compile(&g, net, &CompileOptions::new(S10_2800.clone(), dsp)).unwrap();
    let sim = simulate(&plan, 10).unwrap();
    (
        sim.throughput_img_s(plan.fmax_mhz),
        sim.latency_ms(plan.fmax_mhz),
        plan.totals.dsps,
    )
}

fn main() {
    let full = std::env::var("HPIPE_FULL_SCALE").is_ok();
    let cfg = if full { NetConfig::imagenet() } else { NetConfig::test_scale() };
    println!("=== Table IV: dense MobileNet accelerator comparison ===");

    // V2 at the paper's achieved DSP count (2,964) so the per-multiplier
    // normalization is apples-to-apples, plus at the full 5000 target.
    let (v2_thr, v2_lat, v2_dsps) = compile_and_sim("mobilenet_v2", cfg, PaperHpipe::MOBILENET_V2_DSPS);
    let (v1_thr, v1_lat, v1_dsps) = compile_and_sim("mobilenet_v1", cfg, 5000);

    let mut tab = Table::new(&["", "Wu et al.", "HPIPE ours (V2)", "HPIPE paper (V2)", "V100", "HPIPE ours (V1)", "HPIPE paper (V1)"]);
    tab.row(&[
        "device".into(),
        WuEtAl::DEVICE.into(),
        "S10 2800 (sim)".into(),
        "S10 2800".into(),
        "V100".into(),
        "S10 2800 (sim)".into(),
        "S10 2800".into(),
    ]);
    tab.row(&[
        "DSPs used".into(),
        WuEtAl::DSPS_USED.to_string(),
        v2_dsps.to_string(),
        PaperHpipe::MOBILENET_V2_DSPS.to_string(),
        "-".into(),
        v1_dsps.to_string(),
        PaperHpipe::MOBILENET_V1_DSPS.to_string(),
    ]);
    tab.row(&[
        "precision".into(),
        "8-bit".into(),
        "16-bit".into(),
        "16-bit".into(),
        "8-bit".into(),
        "16-bit".into(),
        "16-bit".into(),
    ]);
    tab.row(&[
        "throughput B=1 (img/s)".into(),
        format!("{:.0}", WuEtAl::THROUGHPUT_B1),
        format!("{v2_thr:.0}"),
        format!("{:.0}", PaperHpipe::MOBILENET_V2_THROUGHPUT),
        format!("{:.0}", V100_MOBILENET_V1.throughput),
        format!("{v1_thr:.0}"),
        format!("{:.0}", PaperHpipe::MOBILENET_V1_THROUGHPUT),
    ]);
    tab.row(&[
        "latency B=1 (ms)".into(),
        "-".into(),
        format!("{v2_lat:.2}"),
        format!("{:.1}", PaperHpipe::MOBILENET_V2_LATENCY_MS),
        format!("{:.2}", V100_MOBILENET_V1.latency_ms),
        format!("{v1_lat:.2}"),
        format!("{:.2}", PaperHpipe::MOBILENET_V1_LATENCY_MS),
    ]);
    tab.print();

    // the per-multiplier normalization (2 mults per S10 DSP, 1 per ZU9)
    let wu = throughput_per_multiplier(WuEtAl::THROUGHPUT_B1, WuEtAl::DSPS_USED);
    let ours = throughput_per_multiplier(v2_thr, v2_dsps * 2);
    let paper = throughput_per_multiplier(
        PaperHpipe::MOBILENET_V2_THROUGHPUT,
        PaperHpipe::MOBILENET_V2_DSPS * 2,
    );
    println!(
        "\nthroughput per 18x18-equivalent multiplier (MobileNet-V2):\n\
         \tWu et al.: {wu:.3}\n\tHPIPE ours: {ours:.3} ({:.2}x Wu; paper claims 1.95x)\n\
         \tHPIPE paper: {paper:.3} ({:.2}x Wu)",
        ours / wu,
        paper / wu
    );
    println!(
        "\nV1 vs V100: ours {:.2}x V100 throughput at {:.1}x the latency\n\
         (paper: 1.12x throughput, 0.43 ms behind in latency, at 2x precision)",
        v1_thr / V100_MOBILENET_V1.throughput,
        v1_lat / V100_MOBILENET_V1.latency_ms
    );
    // the paper's S10-1650 note
    let fits_1650 = v2_dsps <= S10_1650.dsps;
    println!(
        "MobileNet-V2 fits S10 1650: {} ({} of {} DSPs = {:.0}%; paper: 94%)",
        fits_1650,
        v2_dsps,
        S10_1650.dsps,
        100.0 * v2_dsps as f64 / S10_1650.dsps as f64
    );
}
