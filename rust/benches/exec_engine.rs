//! Executor engine benchmark: reference interpreter vs planned-dense vs
//! planned-sparse convolution on a ResNet-50 conv layer, across weight
//! sparsity levels. Emits `BENCH_exec.json` at the repo root so the perf
//! trajectory of the hot path is recorded alongside the code.
//!
//! Acceptance targets (ISSUE 1): planned sparse ≥ 5x faster than
//! `interp::run` at 80% sparsity, and sparse beats planned-dense at
//! ≥ 70% sparsity.

use hpipe::exec::{ExecutionPlan, PlanOptions};
use hpipe::graph::{Graph, Op, Padding, Tensor};
use hpipe::interp;
use hpipe::sparsity::prune_tensor;
use hpipe::util::timer::bench;
use hpipe::util::{Json, Rng};
use std::collections::BTreeMap;
use std::path::Path;

/// res4-style 3x3 conv at test scale: 14x14 spatial, 128 -> 128 channels
/// (the paper's res4 blocks at half width; ~29M MACs dense).
const H: usize = 14;
const CI: usize = 128;
const CO: usize = 128;
const K: usize = 3;

fn conv_graph(w: Tensor) -> Graph {
    let mut g = Graph::new();
    g.op("input", Op::Placeholder { shape: vec![1, H, H, CI] }, &[]);
    g.constant("w", w);
    g.op(
        "conv",
        Op::Conv2D { stride: (1, 1), padding: Padding::Same },
        &["input", "w"],
    );
    g.outputs = vec!["conv".into()];
    g
}

fn main() {
    let mut rng = Rng::new(0xE8EC);
    let feeds: BTreeMap<String, Tensor> = {
        let mut m = BTreeMap::new();
        m.insert("input".into(), Tensor::randn(&[1, H, H, CI], &mut rng, 1.0));
        m
    };
    println!(
        "=== exec engine: interp vs planned-dense vs planned-sparse ({K}x{K} conv, {CI}->{CO} @ {H}x{H}) ==="
    );

    // The interpreter's cost is sparsity-independent (it multiplies the
    // zeros); measure it once, on 80%-pruned weights.
    let w_interp = {
        let mut w = Tensor::randn(&[K, K, CI, CO], &mut rng, 0.1);
        prune_tensor(&mut w, 0.8);
        w
    };
    let g_interp = conv_graph(w_interp);
    let interp_stats = bench("interp/conv", 1, 3, || {
        let _ = interp::run_outputs(&g_interp, &feeds).unwrap();
    });
    let interp_ns = interp_stats.median_ns();

    let mut rows = Json::Arr(vec![]);
    let mut sparse_ns_at = BTreeMap::new();
    let mut dense_ns_at = BTreeMap::new();
    for pct in [0u32, 50, 70, 80, 90] {
        let sparsity = pct as f64 / 100.0;
        let mut w = Tensor::randn(&[K, K, CI, CO], &mut rng, 0.1);
        prune_tensor(&mut w, sparsity);
        let g = conv_graph(w);

        let dense = ExecutionPlan::build_with(&g, &PlanOptions::dense_only()).unwrap();
        let sparse = ExecutionPlan::build_with(&g, &PlanOptions::sparse_always()).unwrap();
        let mut dctx = dense.new_context();
        let mut sctx = sparse.new_context();
        let d = bench(&format!("planned_dense/conv_s{pct}"), 3, 30, || {
            dense.run_with(&mut dctx, &feeds).unwrap();
        });
        let s = bench(&format!("planned_sparse/conv_s{pct}"), 3, 30, || {
            sparse.run_with(&mut sctx, &feeds).unwrap();
        });
        dense_ns_at.insert(pct, d.median_ns());
        sparse_ns_at.insert(pct, s.median_ns());
        println!(
            "  s={sparsity:.2}: interp/dense {:.1}x  interp/sparse {:.1}x  dense/sparse {:.2}x",
            interp_ns / d.median_ns(),
            interp_ns / s.median_ns(),
            d.median_ns() / s.median_ns()
        );
        let mut row = Json::obj();
        row.set("sparsity", Json::from(sparsity))
            .set("interp_ns", Json::from(interp_ns))
            .set("planned_dense_ns", Json::from(d.median_ns()))
            .set("planned_sparse_ns", Json::from(s.median_ns()))
            .set(
                "speedup_dense_vs_interp",
                Json::from(interp_ns / d.median_ns()),
            )
            .set(
                "speedup_sparse_vs_interp",
                Json::from(interp_ns / s.median_ns()),
            )
            .set(
                "speedup_sparse_vs_dense",
                Json::from(d.median_ns() / s.median_ns()),
            );
        rows.push(row);
    }

    let sparse_5x_at_80 = interp_ns / sparse_ns_at[&80] >= 5.0;
    let sparse_beats_dense_at_70 = sparse_ns_at[&70] < dense_ns_at[&70];
    let mut acceptance = Json::obj();
    acceptance
        .set(
            "speedup_sparse_vs_interp_at_0.8",
            Json::from(interp_ns / sparse_ns_at[&80]),
        )
        .set("sparse_ge_5x_interp_at_0.8", Json::from(sparse_5x_at_80))
        .set(
            "sparse_beats_dense_at_0.7",
            Json::from(sparse_beats_dense_at_70),
        );
    let mut root = Json::obj();
    root.set("bench", Json::from("exec_engine/resnet50_conv_layer"))
        .set(
            "layer",
            Json::from_pairs(vec![
                ("kh", Json::from(K)),
                ("kw", Json::from(K)),
                ("ci", Json::from(CI)),
                ("co", Json::from(CO)),
                ("h", Json::from(H)),
                ("w", Json::from(H)),
                ("macs_dense", Json::from(H * H * K * K * CI * CO)),
            ]),
        )
        .set("results", rows)
        .set("acceptance", acceptance);

    let out = Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_exec.json");
    std::fs::write(&out, root.pretty()).expect("writing BENCH_exec.json");
    println!(
        "\nwrote {} (sparse>=5x interp @0.8: {}, sparse beats dense @0.7: {})",
        out.display(),
        sparse_5x_at_80,
        sparse_beats_dense_at_70
    );
}
