//! Executor engine benchmark: reference interpreter vs planned-dense vs
//! planned-sparse convolution on a ResNet-50 conv layer across weight
//! sparsity levels, sequential vs layer-pipelined throughput on a
//! ResNet-50 conv-stack workload at 1/2/4/8 stages, natively batched
//! plans at B ∈ {1, 2, 4, 8} vs the retired run-N-times loop, and the
//! prepacked register-tiled kernels (plan-time weight packing +
//! pre-decoded RLE streams, with an intra-stage worker team on the
//! pipeline's dominant stage) vs the PR 3 kernels on the same conv
//! stack. Emits `BENCH_exec.json` at the repo root so the perf
//! trajectory of the hot path is recorded alongside the code.
//!
//! Acceptance targets: planned sparse ≥ 5x faster than `interp::run` at
//! 80% sparsity, sparse beats planned-dense at ≥ 70% sparsity (ISSUE 1),
//! pipelined throughput at 4 stages beats the sequential planned
//! executor (ISSUE 2), the batch-8 plan (one RLE weight-stream walk per
//! batch) beats running the batch-1 plan 8 times (ISSUE 3), the packed
//! kernels beat the PR 3 kernels both sequentially and pipelined with an
//! intra-stage split (ISSUE 4), and the profile-guided autotuned
//! configuration (measured cuts, measured team, machine-sized stage
//! count) meets or beats the static pipelined@4+team2 configuration
//! (ISSUE 5 — also dumps the calibration as `TUNE_report.json`).
//!
//! A simd section forces the kernel dispatch tier (`exec::isa`) to
//! scalar and back to the widest detected tier over the same packed
//! plans, on both the dense and sparse paths (ISSUE 7); the JSON records
//! the active tier so the perf trajectory is comparable across runners.
//!
//! A persistent-pool section runs the same pipelined plan through
//! long-lived pooled stage workers vs per-run scoped spawns (ISSUE 9):
//! the serving runtime keeps one pool alive across batches, and this
//! proves that never costs throughput.
//!
//! `BENCH_SMOKE=1` caps iterations/images for CI and turns the
//! pipelined-vs-sequential, batched-vs-loop, packed-vs-PR3,
//! tuned-vs-static, simd-vs-scalar and pooled-vs-scoped comparisons
//! into hard gates (nonzero exit on regression).

use hpipe::exec::{
    isa, ExecutionPlan, PipelinePlan, PlanOptions, ProfileOptions, TuneEntry, TuneOptions,
    TuneReport,
};
use hpipe::graph::{Graph, Op, Padding, Tensor};
use hpipe::interp;
use hpipe::sparsity::prune_tensor;
use hpipe::util::timer::bench;
use hpipe::util::{Json, Rng};
use std::collections::BTreeMap;
use std::path::Path;
use std::time::Instant;

/// res4-style 3x3 conv at test scale: 14x14 spatial, 128 -> 128 channels
/// (the paper's res4 blocks at half width; ~29M MACs dense).
const H: usize = 14;
const CI: usize = 128;
const CO: usize = 128;
const K: usize = 3;

/// Conv layers in the pipeline workload (a res4-style conv stack).
const CHAIN_LAYERS: usize = 8;
const CHAIN_SPARSITY: f64 = 0.8;

fn conv_graph(w: Tensor) -> Graph {
    let mut g = Graph::new();
    g.op("input", Op::Placeholder { shape: vec![1, H, H, CI] }, &[]);
    g.constant("w", w);
    g.op(
        "conv",
        Op::Conv2D { stride: (1, 1), padding: Padding::Same },
        &["input", "w"],
    );
    g.outputs = vec!["conv".into()];
    g
}

/// A chain of `layers` conv+bias+relu blocks at res4 scale — the
/// ResNet-50 conv-layer workload the pipeline streams images through.
/// With fusion each block compiles to a single plan step, so the stage
/// partitioner has `layers` equal-cost steps to balance.
fn conv_chain(layers: usize, sparsity: f64, rng: &mut Rng) -> Graph {
    let mut g = Graph::new();
    g.op("input", Op::Placeholder { shape: vec![1, H, H, CI] }, &[]);
    let mut prev = "input".to_string();
    for l in 0..layers {
        let mut w = Tensor::randn(&[K, K, CI, CO], rng, 0.1);
        prune_tensor(&mut w, sparsity);
        g.constant(&format!("w{l}"), w);
        g.constant(&format!("b{l}"), Tensor::randn(&[CO], rng, 0.1));
        let c = g.op(
            &format!("conv{l}"),
            Op::Conv2D { stride: (1, 1), padding: Padding::Same },
            &[&prev, &format!("w{l}")],
        );
        let bi = g.op(&format!("bias{l}"), Op::BiasAdd, &[&c, &format!("b{l}")]);
        prev = g.op(&format!("relu{l}"), Op::Relu, &[&bi]);
    }
    g.outputs = vec![prev];
    g
}

/// Best-of-`reps` throughput (img/s) of a closure that processes
/// `images` images per call. Best-of damps scheduler noise — important
/// for the CI smoke gate on small shared runners.
fn best_img_s<F: FnMut()>(reps: usize, images: usize, mut f: F) -> f64 {
    f(); // warmup
    let mut best = 0.0f64;
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        let dt = t0.elapsed().as_secs_f64();
        best = best.max(images as f64 / dt);
    }
    best
}

fn main() {
    let smoke = std::env::var("BENCH_SMOKE")
        .map(|v| !v.is_empty() && v != "0")
        .unwrap_or(false);
    let (warmup, iters, interp_iters) = if smoke { (1, 5, 1) } else { (3, 30, 3) };
    let (pipe_images, pipe_reps) = if smoke { (12, 3) } else { (32, 4) };

    let mut rng = Rng::new(0xE8EC);
    let feeds: BTreeMap<String, Tensor> = {
        let mut m = BTreeMap::new();
        m.insert("input".into(), Tensor::randn(&[1, H, H, CI], &mut rng, 1.0));
        m
    };
    println!(
        "=== exec engine: interp vs planned-dense vs planned-sparse ({K}x{K} conv, {CI}->{CO} @ {H}x{H}) ==="
    );

    // The interpreter's cost is sparsity-independent (it multiplies the
    // zeros); measure it once, on 80%-pruned weights.
    let w_interp = {
        let mut w = Tensor::randn(&[K, K, CI, CO], &mut rng, 0.1);
        prune_tensor(&mut w, 0.8);
        w
    };
    let g_interp = conv_graph(w_interp);
    let interp_stats = bench("interp/conv", 1, interp_iters, || {
        let _ = interp::run_outputs(&g_interp, &feeds).unwrap();
    });
    let interp_ns = interp_stats.median_ns();

    let mut rows = Json::Arr(vec![]);
    let mut sparse_ns_at = BTreeMap::new();
    let mut dense_ns_at = BTreeMap::new();
    for pct in [0u32, 50, 70, 80, 90] {
        let sparsity = pct as f64 / 100.0;
        let mut w = Tensor::randn(&[K, K, CI, CO], &mut rng, 0.1);
        prune_tensor(&mut w, sparsity);
        let g = conv_graph(w);

        let dense = ExecutionPlan::build_with(&g, &PlanOptions::dense_only()).unwrap();
        let sparse = ExecutionPlan::build_with(&g, &PlanOptions::sparse_always()).unwrap();
        let mut dctx = dense.new_context();
        let mut sctx = sparse.new_context();
        let d = bench(&format!("planned_dense/conv_s{pct}"), warmup, iters, || {
            dense.run_with(&mut dctx, &feeds).unwrap();
        });
        let s = bench(&format!("planned_sparse/conv_s{pct}"), warmup, iters, || {
            sparse.run_with(&mut sctx, &feeds).unwrap();
        });
        dense_ns_at.insert(pct, d.median_ns());
        sparse_ns_at.insert(pct, s.median_ns());
        println!(
            "  s={sparsity:.2}: interp/dense {:.1}x  interp/sparse {:.1}x  dense/sparse {:.2}x",
            interp_ns / d.median_ns(),
            interp_ns / s.median_ns(),
            d.median_ns() / s.median_ns()
        );
        let mut row = Json::obj();
        row.set("sparsity", Json::from(sparsity))
            .set("interp_ns", Json::from(interp_ns))
            .set("planned_dense_ns", Json::from(d.median_ns()))
            .set("planned_sparse_ns", Json::from(s.median_ns()))
            .set(
                "speedup_dense_vs_interp",
                Json::from(interp_ns / d.median_ns()),
            )
            .set(
                "speedup_sparse_vs_interp",
                Json::from(interp_ns / s.median_ns()),
            )
            .set(
                "speedup_sparse_vs_dense",
                Json::from(d.median_ns() / s.median_ns()),
            );
        rows.push(row);
    }

    // ---- sequential vs layer-pipelined throughput (ISSUE 2) ----
    println!(
        "\n=== pipeline: {CHAIN_LAYERS}x ({K}x{K} conv {CI}->{CO} @ {H}x{H}, s={CHAIN_SPARSITY}), \
         {pipe_images} images ==="
    );
    let chain = conv_chain(CHAIN_LAYERS, CHAIN_SPARSITY, &mut rng);
    let per = H * H * CI;
    let flat: Vec<f32> = (0..pipe_images * per)
        .map(|_| rng.normal_f32(0.0, 1.0))
        .collect();

    let seq_plan = ExecutionPlan::build(&chain).unwrap();
    let mut seq_ctx = seq_plan.new_context();
    // Single source of truth for both measurements: the first pass and
    // the smoke gate's retry run the exact same closures.
    let mut measure_seq = || {
        best_img_s(pipe_reps, pipe_images, || {
            for i in 0..pipe_images {
                seq_plan
                    .write_feed(&mut seq_ctx, 0, &flat[i * per..(i + 1) * per])
                    .unwrap();
                seq_plan.execute_steps(&mut seq_ctx);
                std::hint::black_box(seq_plan.output(&seq_ctx, 0).0[0]);
            }
        })
    };
    let measure_pipe = |stages: usize| {
        let pipe = PipelinePlan::from_plan(ExecutionPlan::build(&chain).unwrap(), stages);
        let costs = pipe.stage_costs().to_vec();
        let img_s = best_img_s(pipe_reps, pipe_images, || {
            let out = pipe.run_batch(&flat, pipe_images).unwrap();
            std::hint::black_box(out[0][0]);
        });
        (img_s, costs)
    };

    let mut seq_img_s = measure_seq();
    println!("  sequential: {seq_img_s:.1} img/s");

    let mut stage_rows = Json::Arr(vec![]);
    let mut pipe4_img_s = 0.0f64;
    for stages in [1usize, 2, 4, 8] {
        let (img_s, costs) = measure_pipe(stages);
        if stages == 4 {
            pipe4_img_s = img_s;
        }
        println!(
            "  pipelined @{stages} stages: {img_s:.1} img/s ({:.2}x sequential, \
             stage costs {costs:?})",
            img_s / seq_img_s,
        );
        let mut row = Json::obj();
        row.set("stages", Json::from(stages))
            .set("img_s", Json::from(img_s))
            .set("speedup_vs_sequential", Json::from(img_s / seq_img_s));
        stage_rows.push(row);
    }

    // Smoke gate is strict (>=), but a failed first comparison gets one
    // full re-measure of both sides before the verdict: on shared
    // runners a descheduled stage can sink one measurement, while a
    // genuine regression (pipelining broken => <= 1.0x) fails both
    // attempts. The verdict is decided BEFORE the JSON is written so the
    // uploaded artifact always matches the gate outcome.
    let mut gate_retried = false;
    if smoke && pipe4_img_s < seq_img_s {
        println!("  smoke gate missed on first attempt; re-measuring both sides");
        gate_retried = true;
        seq_img_s = measure_seq();
        let (p4, _) = measure_pipe(4);
        pipe4_img_s = p4;
        println!("  retry: pipelined @4 {pipe4_img_s:.1} vs sequential {seq_img_s:.1} img/s");
    }
    let pipelined_wins = pipe4_img_s >= seq_img_s;

    // ---- natively batched plans vs the run-N-times loop (ISSUE 3) ----
    let batch_images = if smoke { 8usize } else { 32 };
    println!(
        "\n=== batched plans: {CHAIN_LAYERS}x conv chain (s={CHAIN_SPARSITY}), \
         {batch_images} images, batch-B plan vs batch-1 plan run N times ==="
    );
    let flat_b: Vec<f32> = (0..batch_images * per)
        .map(|_| rng.normal_f32(0.0, 1.0))
        .collect();
    // The old serving path: the batch-1 plan executed once per image,
    // re-walking every RLE weight stream N times.
    let loop_plan = ExecutionPlan::build(&chain).unwrap();
    let mut loop_ctx = loop_plan.new_context();
    let mut measure_loop = || {
        best_img_s(pipe_reps, batch_images, || {
            for i in 0..batch_images {
                loop_plan
                    .write_feed(&mut loop_ctx, 0, &flat_b[i * per..(i + 1) * per])
                    .unwrap();
                loop_plan.execute_steps(&mut loop_ctx);
                std::hint::black_box(loop_plan.output(&loop_ctx, 0).0[0]);
            }
        })
    };
    // The batched path: a batch-B plan walks each weight stream once
    // per group and broadcasts every surviving weight across B images.
    let measure_batched = |b: usize| {
        let plan = ExecutionPlan::build_batched(&chain, b).unwrap();
        let mut ctx = plan.new_context();
        let per_group = per * b;
        let groups = batch_images / b;
        best_img_s(pipe_reps, batch_images, || {
            for g in 0..groups {
                plan.write_feed(&mut ctx, 0, &flat_b[g * per_group..(g + 1) * per_group])
                    .unwrap();
                plan.execute_steps(&mut ctx);
                std::hint::black_box(plan.output(&ctx, 0).0[0]);
            }
        })
    };
    let mut loop_img_s = measure_loop();
    println!("  run-N-times loop (B=1 plan): {loop_img_s:.1} img/s");
    let mut measured: Vec<(usize, f64)> = Vec::new();
    for b in [1usize, 2, 4, 8] {
        let img_s = measure_batched(b);
        println!(
            "  batched @B={b}: {img_s:.1} img/s ({:.2}x vs loop)",
            img_s / loop_img_s
        );
        measured.push((b, img_s));
    }
    let mut batched8_img_s = measured.last().unwrap().1;
    // Same retry policy as the pipeline gate: one full re-measure of
    // both sides before a verdict, so a descheduled run on a shared CI
    // runner doesn't fail the gate while a real regression still does.
    let mut batched_gate_retried = false;
    if smoke && batched8_img_s < loop_img_s {
        println!("  batched gate missed on first attempt; re-measuring both sides");
        batched_gate_retried = true;
        loop_img_s = measure_loop();
        batched8_img_s = measure_batched(8);
        measured.last_mut().unwrap().1 = batched8_img_s;
        println!("  retry: batched @8 {batched8_img_s:.1} vs loop {loop_img_s:.1} img/s");
    }
    let batched_wins = batched8_img_s >= loop_img_s;

    // Rows are built AFTER the verdict so the artifact's per-B speedups
    // share the final baseline (self-consistent with the gate outcome).
    let mut batched_rows = Json::Arr(vec![]);
    for &(b, img_s) in &measured {
        let mut row = Json::obj();
        row.set("batch", Json::from(b))
            .set("img_s", Json::from(img_s))
            .set("speedup_vs_loop", Json::from(img_s / loop_img_s));
        batched_rows.push(row);
    }

    // ---- prepacked register-tiled kernels vs the PR 3 kernels (ISSUE 4) ----
    const PACKED_STAGES: usize = 4;
    const PACKED_TEAM: usize = 2;
    println!(
        "\n=== packed kernels: {CHAIN_LAYERS}x conv chain (s={CHAIN_SPARSITY}), \
         {pipe_images} images, prepacked microkernels vs PR 3 kernels ==="
    );
    let measure_seq_with = |opts: &PlanOptions| -> f64 {
        let plan = ExecutionPlan::build_with(&chain, opts).unwrap();
        let mut ctx = plan.new_context();
        best_img_s(pipe_reps, pipe_images, || {
            for i in 0..pipe_images {
                plan.write_feed(&mut ctx, 0, &flat[i * per..(i + 1) * per])
                    .unwrap();
                plan.execute_steps(&mut ctx);
                std::hint::black_box(plan.output(&ctx, 0).0[0]);
            }
        })
    };
    let measure_pipe_with = |opts: &PlanOptions, stages: usize, team: usize| -> f64 {
        let pipe = PipelinePlan::from_plan_team(
            ExecutionPlan::build_with(&chain, opts).unwrap(),
            stages,
            team,
        );
        best_img_s(pipe_reps, pipe_images, || {
            let out = pipe.run_batch(&flat, pipe_images).unwrap();
            std::hint::black_box(out[0][0]);
        })
    };
    let packed_opts = PlanOptions::default();
    let pr3_opts = PlanOptions::unpacked();
    let mut packed_seq = measure_seq_with(&packed_opts);
    let mut pr3_seq = measure_seq_with(&pr3_opts);
    println!(
        "  sequential: packed {packed_seq:.1} vs PR3 {pr3_seq:.1} img/s ({:.2}x)",
        packed_seq / pr3_seq
    );
    let mut packed_pipe = measure_pipe_with(&packed_opts, PACKED_STAGES, PACKED_TEAM);
    let mut pr3_pipe = measure_pipe_with(&pr3_opts, PACKED_STAGES, 1);
    println!(
        "  pipelined @{PACKED_STAGES} stages: packed+team{PACKED_TEAM} {packed_pipe:.1} \
         vs PR3 {pr3_pipe:.1} img/s ({:.2}x)",
        packed_pipe / pr3_pipe
    );
    // Same retry policy as the other gates: one full re-measure of every
    // side before a verdict.
    let mut packed_gate_retried = false;
    if smoke && (packed_seq < pr3_seq || packed_pipe < pr3_pipe) {
        println!("  packed gate missed on first attempt; re-measuring all sides");
        packed_gate_retried = true;
        packed_seq = measure_seq_with(&packed_opts);
        pr3_seq = measure_seq_with(&pr3_opts);
        packed_pipe = measure_pipe_with(&packed_opts, PACKED_STAGES, PACKED_TEAM);
        pr3_pipe = measure_pipe_with(&pr3_opts, PACKED_STAGES, 1);
        println!(
            "  retry: seq packed {packed_seq:.1} vs PR3 {pr3_seq:.1}; \
             pipe packed {packed_pipe:.1} vs PR3 {pr3_pipe:.1} img/s"
        );
    }
    let packed_seq_wins = packed_seq >= pr3_seq;
    let packed_pipe_wins = packed_pipe >= pr3_pipe;

    // ---- profile-guided autotuning vs the static configuration (ISSUE 5) ----
    let cores = hpipe::exec::tune::detected_cores();
    println!(
        "\n=== autotuned: {CHAIN_LAYERS}x conv chain (s={CHAIN_SPARSITY}), {pipe_images} \
         images, measured cuts + measured team ({cores} cores) vs static \
         pipelined@{PACKED_STAGES}+team{PACKED_TEAM} ==="
    );
    let tune_opts = TuneOptions {
        cores: 0, // size to this machine — the knob the tuner replaces
        profile: ProfileOptions {
            warmup: 1,
            runs: if smoke { 3 } else { 5 },
            ..Default::default()
        },
    };
    // Calibrate-then-measure: profile the sequential plan, cut from the
    // measured step costs, and stream the same workload as every other
    // pipeline section.
    let measure_tuned = |opts: &TuneOptions| -> (f64, TuneEntry) {
        let plan = ExecutionPlan::build(&chain).unwrap();
        let entry = TuneEntry::calibrate(&plan, opts);
        let pipe =
            PipelinePlan::from_profile(plan, &entry.profile, entry.cuts.stages, entry.cuts.team);
        let img_s = best_img_s(pipe_reps, pipe_images, || {
            let out = pipe.run_batch(&flat, pipe_images).unwrap();
            std::hint::black_box(out[0][0]);
        });
        (img_s, entry)
    };
    let mut static_img_s = measure_pipe_with(&packed_opts, PACKED_STAGES, PACKED_TEAM);
    let (mut tuned_img_s, mut tune_entry) = measure_tuned(&tune_opts);
    println!(
        "  tuned (stages={} team={}): {tuned_img_s:.1} vs \
         static@{PACKED_STAGES}+team{PACKED_TEAM} {static_img_s:.1} img/s ({:.2}x)",
        tune_entry.cuts.stages,
        tune_entry.cuts.team,
        tuned_img_s / static_img_s
    );
    // Same retry policy as the other gates: a full re-measure of both
    // sides — including a fresh calibration — before a verdict.
    let mut tuned_gate_retried = false;
    if smoke && tuned_img_s < static_img_s {
        println!("  tuned gate missed on first attempt; re-measuring both sides");
        tuned_gate_retried = true;
        static_img_s = measure_pipe_with(&packed_opts, PACKED_STAGES, PACKED_TEAM);
        let (t, e) = measure_tuned(&tune_opts);
        tuned_img_s = t;
        tune_entry = e;
        println!(
            "  retry: tuned (stages={} team={}) {tuned_img_s:.1} vs static {static_img_s:.1} img/s",
            tune_entry.cuts.stages, tune_entry.cuts.team
        );
    }
    let tuned_wins = tuned_img_s >= static_img_s;

    // The calibration that produced the gated number, as a standalone
    // artifact (uploaded by CI next to BENCH_exec.json).
    let tune_report = TuneReport {
        model: "exec_engine/conv_chain".into(),
        cores,
        batch: 1,
        chosen_group: 1,
        entries: vec![tune_entry.clone()],
    };
    let tune_out = Path::new(env!("CARGO_MANIFEST_DIR")).join("TUNE_report.json");
    std::fs::write(&tune_out, tune_report.to_json().pretty()).expect("writing TUNE_report.json");
    println!("  wrote {}", tune_out.display());

    // ---- explicit SIMD tiers vs forced-scalar packed kernels (ISSUE 7) ----
    // Single-threaded here, so forcing the process-global tier is safe;
    // the same packed plans run under the widest detected tier and under
    // the scalar baseline. If the runner has no vector tier at all the
    // comparison is skipped with an explicit line — never silently.
    let widest = *isa::available().last().expect("scalar tier is always available");
    let prior_tier = isa::active().tier();
    let simd_skipped = widest.tier() == isa::Tier::Scalar;
    println!(
        "\n=== simd kernels: widest tier `{}` vs forced scalar, {CHAIN_LAYERS}x conv \
         chain, dense and sparse plans ===",
        widest.name()
    );
    let dense_opts = PlanOptions::dense_only();
    let sparse_opts = PlanOptions::sparse_always();
    let measure_tier = |tier: isa::Tier, opts: &PlanOptions| -> f64 {
        isa::force(tier).expect("tier came from isa::available()");
        measure_seq_with(opts)
    };
    let (mut scalar_dense, mut simd_dense) = (0.0f64, 0.0f64);
    let (mut scalar_sparse, mut simd_sparse) = (0.0f64, 0.0f64);
    let mut simd_gate_retried = false;
    let (simd_dense_wins, simd_sparse_wins);
    if simd_skipped {
        println!("  SKIPPED: widest available tier is scalar (no SIMD on this CPU)");
        simd_dense_wins = true;
        simd_sparse_wins = true;
    } else {
        scalar_dense = measure_tier(isa::Tier::Scalar, &dense_opts);
        simd_dense = measure_tier(widest.tier(), &dense_opts);
        scalar_sparse = measure_tier(isa::Tier::Scalar, &sparse_opts);
        simd_sparse = measure_tier(widest.tier(), &sparse_opts);
        println!(
            "  dense:  {} {simd_dense:.1} vs scalar {scalar_dense:.1} img/s ({:.2}x)",
            widest.name(),
            simd_dense / scalar_dense
        );
        println!(
            "  sparse: {} {simd_sparse:.1} vs scalar {scalar_sparse:.1} img/s ({:.2}x)",
            widest.name(),
            simd_sparse / scalar_sparse
        );
        // Same retry policy as the other gates: one full re-measure of
        // every side before a verdict.
        if smoke && (simd_dense < scalar_dense || simd_sparse < scalar_sparse) {
            println!("  simd gate missed on first attempt; re-measuring all sides");
            simd_gate_retried = true;
            scalar_dense = measure_tier(isa::Tier::Scalar, &dense_opts);
            simd_dense = measure_tier(widest.tier(), &dense_opts);
            scalar_sparse = measure_tier(isa::Tier::Scalar, &sparse_opts);
            simd_sparse = measure_tier(widest.tier(), &sparse_opts);
            println!(
                "  retry: dense {simd_dense:.1} vs {scalar_dense:.1}; \
                 sparse {simd_sparse:.1} vs {scalar_sparse:.1} img/s"
            );
        }
        simd_dense_wins = simd_dense >= scalar_dense;
        simd_sparse_wins = simd_sparse >= scalar_sparse;
        isa::force(prior_tier).expect("restoring the startup tier");
    }

    // ---- persistent stage workers vs per-run scoped spawns (ISSUE 9) ----
    // Identical plan and stage count on both sides; the only difference
    // is whether run_batch spawns-and-joins its stage workers per call
    // or hands the batch to the long-lived pool serving continuously.
    println!(
        "\n=== persistent pool: pooled stage workers vs per-run scoped spawns, \
         {CHAIN_LAYERS}x conv chain @4 stages, {pipe_images} images ==="
    );
    let scoped_pipe = PipelinePlan::from_plan(ExecutionPlan::build(&chain).unwrap(), 4);
    let pooled_pipe = PipelinePlan::from_plan(ExecutionPlan::build(&chain).unwrap(), 4);
    pooled_pipe.enable_persistent_pool();
    let measure_scoped = || {
        best_img_s(pipe_reps, pipe_images, || {
            let out = scoped_pipe.run_batch(&flat, pipe_images).unwrap();
            std::hint::black_box(out[0][0]);
        })
    };
    let measure_pooled = || {
        best_img_s(pipe_reps, pipe_images, || {
            let out = pooled_pipe.run_batch(&flat, pipe_images).unwrap();
            std::hint::black_box(out[0][0]);
        })
    };
    let mut scoped_img_s = measure_scoped();
    let mut pooled_img_s = measure_pooled();
    println!(
        "  pooled {pooled_img_s:.1} vs scoped {scoped_img_s:.1} img/s ({:.2}x)",
        pooled_img_s / scoped_img_s
    );
    // Same retry policy as the other gates: one full re-measure of both
    // sides before a verdict.
    let mut pool_gate_retried = false;
    if smoke && pooled_img_s < scoped_img_s {
        println!("  pool gate missed on first attempt; re-measuring both sides");
        pool_gate_retried = true;
        scoped_img_s = measure_scoped();
        pooled_img_s = measure_pooled();
        println!("  retry: pooled {pooled_img_s:.1} vs scoped {scoped_img_s:.1} img/s");
    }
    let pooled_wins = pooled_img_s >= scoped_img_s;

    let mut pool = Json::obj();
    pool.set("images", Json::from(pipe_images))
        .set("stages", Json::from(4usize))
        .set("scoped_img_s", Json::from(scoped_img_s))
        .set("pooled_img_s", Json::from(pooled_img_s))
        .set(
            "speedup_pooled_vs_scoped",
            Json::from(pooled_img_s / scoped_img_s),
        )
        .set("gate_retried", Json::from(pool_gate_retried))
        .set("pooled_beats_scoped", Json::from(pooled_wins));

    let mut simd = Json::obj();
    simd.set("images", Json::from(pipe_images))
        .set("widest_tier", Json::from(widest.name()))
        .set("skipped_no_simd", Json::from(simd_skipped))
        .set("gate_retried", Json::from(simd_gate_retried))
        .set("simd_beats_scalar_dense", Json::from(simd_dense_wins))
        .set("simd_beats_scalar_sparse", Json::from(simd_sparse_wins));
    if !simd_skipped {
        simd.set("scalar_dense_img_s", Json::from(scalar_dense))
            .set("simd_dense_img_s", Json::from(simd_dense))
            .set("speedup_dense", Json::from(simd_dense / scalar_dense))
            .set("scalar_sparse_img_s", Json::from(scalar_sparse))
            .set("simd_sparse_img_s", Json::from(simd_sparse))
            .set("speedup_sparse", Json::from(simd_sparse / scalar_sparse));
    }

    let mut tuned = Json::obj();
    tuned
        .set("images", Json::from(pipe_images))
        .set("cores", Json::from(cores))
        .set("stages", Json::from(tune_entry.cuts.stages))
        .set("team", Json::from(tune_entry.cuts.team))
        .set("tuned_img_s", Json::from(tuned_img_s))
        .set("static_pipe4_team2_img_s", Json::from(static_img_s))
        .set("speedup_vs_static", Json::from(tuned_img_s / static_img_s))
        .set("gate_retried", Json::from(tuned_gate_retried))
        .set("tuned_beats_static_pipe4_team2", Json::from(tuned_wins));

    let mut packed = Json::obj();
    packed
        .set("images", Json::from(pipe_images))
        .set("packed_seq_img_s", Json::from(packed_seq))
        .set("pr3_seq_img_s", Json::from(pr3_seq))
        .set("speedup_seq", Json::from(packed_seq / pr3_seq))
        .set("stages", Json::from(PACKED_STAGES))
        .set("team", Json::from(PACKED_TEAM))
        .set("packed_pipe_team_img_s", Json::from(packed_pipe))
        .set("pr3_pipe_img_s", Json::from(pr3_pipe))
        .set("speedup_pipe", Json::from(packed_pipe / pr3_pipe))
        .set("gate_retried", Json::from(packed_gate_retried))
        .set("packed_seq_beats_pr3", Json::from(packed_seq_wins))
        .set("packed_pipe_team_beats_pr3", Json::from(packed_pipe_wins));

    let mut batched = Json::obj();
    batched
        .set("images", Json::from(batch_images))
        .set("loop_img_s", Json::from(loop_img_s))
        .set("batched_8_img_s", Json::from(batched8_img_s))
        .set("gate_retried", Json::from(batched_gate_retried))
        .set("batches", batched_rows)
        .set("batched_8_beats_loop", Json::from(batched_wins));

    let mut pipeline = Json::obj();
    pipeline
        .set(
            "workload",
            Json::from_pairs(vec![
                ("layers", Json::from(CHAIN_LAYERS)),
                ("sparsity", Json::from(CHAIN_SPARSITY)),
                ("kh", Json::from(K)),
                ("ci", Json::from(CI)),
                ("co", Json::from(CO)),
                ("h", Json::from(H)),
            ]),
        )
        .set("images", Json::from(pipe_images))
        .set("sequential_img_s", Json::from(seq_img_s))
        .set("pipelined_4_img_s", Json::from(pipe4_img_s))
        .set("gate_retried", Json::from(gate_retried))
        .set("stages", stage_rows)
        .set("pipelined_4_beats_sequential", Json::from(pipelined_wins));

    let sparse_5x_at_80 = interp_ns / sparse_ns_at[&80] >= 5.0;
    let sparse_beats_dense_at_70 = sparse_ns_at[&70] < dense_ns_at[&70];
    let mut acceptance = Json::obj();
    acceptance
        .set(
            "speedup_sparse_vs_interp_at_0.8",
            Json::from(interp_ns / sparse_ns_at[&80]),
        )
        .set("sparse_ge_5x_interp_at_0.8", Json::from(sparse_5x_at_80))
        .set(
            "sparse_beats_dense_at_0.7",
            Json::from(sparse_beats_dense_at_70),
        )
        .set("pipelined_4_beats_sequential", Json::from(pipelined_wins))
        .set("batched_8_beats_loop", Json::from(batched_wins))
        .set("packed_seq_beats_pr3", Json::from(packed_seq_wins))
        .set("packed_pipe_team_beats_pr3", Json::from(packed_pipe_wins))
        .set("tuned_beats_static_pipe4_team2", Json::from(tuned_wins))
        .set("simd_beats_scalar_dense", Json::from(simd_dense_wins))
        .set("simd_beats_scalar_sparse", Json::from(simd_sparse_wins))
        .set("pooled_beats_scoped", Json::from(pooled_wins));
    let mut root = Json::obj();
    root.set("bench", Json::from("exec_engine/resnet50_conv_layer"))
        // the tier the non-forced sections ran under — perf numbers are
        // only comparable across runs with the same tier
        .set("isa", Json::from(isa::active().name()))
        .set(
            "layer",
            Json::from_pairs(vec![
                ("kh", Json::from(K)),
                ("kw", Json::from(K)),
                ("ci", Json::from(CI)),
                ("co", Json::from(CO)),
                ("h", Json::from(H)),
                ("w", Json::from(H)),
                ("macs_dense", Json::from(H * H * K * K * CI * CO)),
            ]),
        )
        .set("results", rows)
        .set("pipeline", pipeline)
        .set("batched", batched)
        .set("packed", packed)
        .set("tuned", tuned)
        .set("simd", simd)
        .set("persistent_pool", pool)
        .set("acceptance", acceptance);

    let out = Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_exec.json");
    std::fs::write(&out, root.pretty()).expect("writing BENCH_exec.json");
    println!(
        "\nwrote {} (sparse>=5x interp @0.8: {}, sparse beats dense @0.7: {}, \
         pipelined@4 beats sequential: {}, batched@8 beats loop: {}, \
         packed beats PR3 seq: {}, packed+team beats PR3 pipe: {}, \
         tuned beats static@4+team2: {}, simd beats scalar dense/sparse: {}/{}, \
         pooled beats scoped: {})",
        out.display(),
        sparse_5x_at_80,
        sparse_beats_dense_at_70,
        pipelined_wins,
        batched_wins,
        packed_seq_wins,
        packed_pipe_wins,
        tuned_wins,
        simd_dense_wins,
        simd_sparse_wins,
        pooled_wins
    );

    let mut failed = false;
    if smoke && !pipelined_wins {
        eprintln!(
            "BENCH_SMOKE gate failed: pipelined @4 stages ({pipe4_img_s:.1} img/s) \
             is slower than sequential ({seq_img_s:.1} img/s) on both attempts"
        );
        failed = true;
    }
    if smoke && !batched_wins {
        eprintln!(
            "BENCH_SMOKE gate failed: batched @B=8 ({batched8_img_s:.1} img/s) \
             is slower than the run-N-times loop ({loop_img_s:.1} img/s) on both attempts"
        );
        failed = true;
    }
    if smoke && !packed_seq_wins {
        eprintln!(
            "BENCH_SMOKE gate failed: packed sequential ({packed_seq:.1} img/s) \
             is slower than the PR 3 kernels ({pr3_seq:.1} img/s) on both attempts"
        );
        failed = true;
    }
    if smoke && !packed_pipe_wins {
        eprintln!(
            "BENCH_SMOKE gate failed: packed pipelined@{PACKED_STAGES}+team{PACKED_TEAM} \
             ({packed_pipe:.1} img/s) is slower than the PR 3 pipeline \
             ({pr3_pipe:.1} img/s) on both attempts"
        );
        failed = true;
    }
    if smoke && !tuned_wins {
        eprintln!(
            "BENCH_SMOKE gate failed: autotuned ({tuned_img_s:.1} img/s) is slower than \
             the static pipelined@{PACKED_STAGES}+team{PACKED_TEAM} configuration \
             ({static_img_s:.1} img/s) on both attempts"
        );
        failed = true;
    }
    if smoke && !simd_dense_wins {
        eprintln!(
            "BENCH_SMOKE gate failed: simd dense tier `{}` ({simd_dense:.1} img/s) is \
             slower than forced-scalar packed kernels ({scalar_dense:.1} img/s) on both \
             attempts",
            widest.name()
        );
        failed = true;
    }
    if smoke && !simd_sparse_wins {
        eprintln!(
            "BENCH_SMOKE gate failed: simd sparse tier `{}` ({simd_sparse:.1} img/s) is \
             slower than forced-scalar packed kernels ({scalar_sparse:.1} img/s) on both \
             attempts",
            widest.name()
        );
        failed = true;
    }
    if smoke && !pooled_wins {
        eprintln!(
            "BENCH_SMOKE gate failed: persistent-pool pipelined ({pooled_img_s:.1} img/s) \
             is slower than per-run scoped workers ({scoped_img_s:.1} img/s) on both \
             attempts"
        );
        failed = true;
    }
    if failed {
        std::process::exit(1);
    }
}
