//! Table I reproduction: the Distribute / Local-Transfer / Pipeline
//! comparison, regenerated as *measured* quantities over a ResNet-50
//! layer suite at 85% sparsity, then reduced back to the paper's
//! Poor/Good/Excellent grades.

use hpipe::baselines::partitioning::{
    evaluate_suite, grade_ratio, grade_utilization, resnet_layer_suite, Axes,
};
use hpipe::util::timer::Table;

fn main() {
    println!("=== Table I: activation distribution/partitioning architectures ===");
    let suite = resnet_layer_suite();
    let s = evaluate_suite(&suite);

    let mut raw = Table::new(&[
        "architecture",
        "act energy (units/img)",
        "addr units",
        "min PE util",
        "weight bytes/img",
        "latency (PE-cycles)",
    ]);
    for (name, a) in [
        ("Distribute", &s.distribute),
        ("Local Transfer", &s.local_transfer),
        ("Pipeline", &s.pipeline),
    ] {
        raw.row(&[
            name.to_string(),
            format!("{:.2e}", a.activation_traffic),
            format!("{:.0}", a.address_units),
            format!("{:.3}", a.pe_utilization),
            format!("{:.2e}", a.weight_traffic),
            format!("{:.2e}", a.latency),
        ]);
    }
    raw.print();

    let best_act = s
        .pipeline
        .activation_traffic
        .min(s.distribute.activation_traffic)
        .min(s.local_transfer.activation_traffic);
    let best_addr = 1.0f64;
    let best_w = s
        .distribute
        .weight_traffic
        .min(s.local_transfer.weight_traffic)
        .min(s.pipeline.weight_traffic);
    let best_lat = s
        .distribute
        .latency
        .min(s.local_transfer.latency)
        .min(s.pipeline.latency);

    let graded_row = |name: &str, a: &Axes| -> Vec<String> {
        vec![
            name.to_string(),
            grade_ratio(a.activation_traffic / best_act, 2.0, 50.0).to_string(),
            grade_ratio(a.address_units / best_addr, 2.0, 100.0).to_string(),
            grade_utilization(a.pe_utilization).to_string(),
            grade_ratio(a.weight_traffic / best_w, 2.0, 8.0).to_string(),
            grade_ratio(a.latency / best_lat, 2.0, 8.0).to_string(),
        ]
    };

    let mut graded = Table::new(&[
        "",
        "Act. Locality",
        "Addr. Computation",
        "Shape Flexibility",
        "Weight Bandwidth",
        "Latency",
    ]);
    graded.row(&graded_row("Distribute", &s.distribute));
    graded.row(&graded_row("Local Transfer", &s.local_transfer));
    graded.row(&graded_row("Pipeline", &s.pipeline));
    println!();
    graded.print();
    println!(
        "\npaper Table I:  Distribute     = Poor / Poor / Good / Excellent / Excellent\n\
         paper Table I:  Local Transfer = Good / Good / Poor / Good      / Excellent\n\
         paper Table I:  Pipeline       = Excellent / Excellent / Excellent / Poor / Good"
    );
}
