//! Ablations of the design choices DESIGN.md calls out:
//!
//!  1. naive vs partition-aware throughput model (§IV: the fix improved
//!     estimates to within 1% and bought 23% throughput);
//!  2. Add skip-path buffer sizing (§V-C deadlock avoidance);
//!  3. gather vs scatter convolution cost (§III-A's argument);
//!  4. compiler hot-path timings (balancer, RLE encode, simulator rate);
//!  5. §VII future work: precision vs performance-per-area on Agilex;
//!  6. software executor: interpreter vs planned dense vs planned sparse
//!     on the whole pruned+folded ResNet-50 (the exec engine's win).

use hpipe::arch::S10_2800;
use hpipe::compile::{compile, CompileOptions};
use hpipe::graph::Op;
use hpipe::nets::{resnet50, NetConfig};
use hpipe::sim::{simulate, SimError};
use hpipe::sparsity::prune_graph;
use hpipe::sparsity::rle::encode_conv;
use hpipe::transform::optimize;
use hpipe::util::timer::bench;
use hpipe::util::Rng;

fn main() {
    let full = std::env::var("HPIPE_FULL_SCALE").is_ok();
    let cfg = if full { NetConfig::imagenet() } else { NetConfig::test_scale() };
    let dsp = if full { 5000 } else { 1200 };

    // ---------- 1. naive vs partition-aware analytic model ----------
    println!("=== ablation 1: throughput model (naive linear vs partition-aware) ===");
    let mut g = resnet50(cfg);
    prune_graph(&mut g, 0.85);
    let (g, _) = optimize(&g);
    let mut naive_opts = CompileOptions::new(S10_2800.clone(), dsp);
    naive_opts.partition_aware = false;
    let naive_plan = compile(&g, "resnet50", &naive_opts).unwrap();
    let aware_plan = compile(&g, "resnet50", &CompileOptions::new(S10_2800.clone(), dsp)).unwrap();

    // The naive plan *believes* its own estimate; judge both plans by the
    // partition-aware cycle model (the "actual" throughput) — re-cost the
    // naive plan's split choices with the true model:
    let aware_sim = simulate(&aware_plan, 8).unwrap();
    let mut naive_recost = naive_plan.clone();
    for (st, orig) in naive_recost.stages.iter_mut().zip(&naive_plan.stages) {
        if let Op::Conv2D { .. } | Op::MatMul = st.op {
            let node = g.get(&orig.name).unwrap();
            let w = g.get(&node.inputs[1]).unwrap().value.as_ref().unwrap();
            let summary = match st.op {
                Op::MatMul => hpipe::compile::throughput::WeightSummary::from_matmul(w),
                _ => hpipe::compile::throughput::WeightSummary::from_conv(w),
            };
            st.cycles = hpipe::compile::throughput::stage_cycles(
                &st.op, &st.geo, st.splits, Some(&summary), true,
            );
        }
    }
    let naive_true_interval = naive_recost.interval_cycles();
    println!(
        "naive-model plan: believed interval {} cyc, true {} cyc — estimate off by {:.0}%",
        naive_plan.interval_cycles(),
        naive_true_interval,
        100.0 * (naive_true_interval as f64 - naive_plan.interval_cycles() as f64)
            / naive_plan.interval_cycles() as f64
    );
    println!(
        "partition-aware plan: believed {} cyc, sim {} cyc ({:+.1}%; paper: within 1%)",
        aware_plan.interval_cycles(),
        aware_sim.steady_interval(),
        100.0 * (aware_sim.steady_interval() as f64 - aware_plan.interval_cycles() as f64)
            / aware_plan.interval_cycles() as f64
    );
    println!(
        "throughput gained by the partition-aware balancer: {:.0}% (paper: 23%)",
        100.0 * (naive_true_interval as f64 / aware_plan.interval_cycles() as f64 - 1.0)
    );
    // The skewed naive plan can even deadlock the line-level pipeline
    // (its stage rates violate the buffer-sizing assumptions):
    match simulate(&naive_recost, 4) {
        Ok(r) => println!(
            "naive plan simulates: steady interval {} cyc",
            r.steady_interval()
        ),
        Err(e) => println!(
            "naive plan pipeline: {} — skewed stage rates break the
             balanced-rate buffer sizing (reinforces §V-C)",
            match e {
                SimError::Deadlock(d) => format!("DEADLOCK at cycle {}", d.at_cycle),
                other => other.to_string(),
            }
        ),
    }

    // ---------- 2. Add buffer sizing ----------
    println!("\n=== ablation 2: Add skip-path buffer sizing (§V-C) ===");
    let mut sabotaged = aware_plan.clone();
    for s in sabotaged.stages.iter_mut() {
        if matches!(s.op, Op::Add) {
            s.buffer_lines = 1;
        }
    }
    match simulate(&sabotaged, 2) {
        Err(SimError::Deadlock(d)) => println!(
            "minimum Add buffers: DEADLOCK at cycle {} ({} stuck stages) — compiler sizing is necessary",
            d.at_cycle,
            d.stuck.len()
        ),
        Ok(r) => println!(
            "minimum Add buffers survived at line granularity (interval {} vs sized {});\n\
             sized buffers still required for sub-line timing margins",
            r.steady_interval(),
            aware_sim.steady_interval()
        ),
        Err(e) => println!("unexpected: {e}"),
    }

    // ---------- 3. gather vs scatter (§III-A) ----------
    println!("\n=== ablation 3: gather vs scatter convolution cost model ===");
    // scatter accumulates into a 3-port buffer in soft logic: per MAC it
    // needs a read + add + write (2 M20K ports + ALM adder) where gather
    // uses the DSP's hardened chain. Count the soft-logic cost over the
    // balanced ResNet plan's multipliers.
    let mults: usize = aware_plan.stages.iter().map(|s| s.mults).sum();
    let gather_alms_per_mult = 26 + 7 * 2; // X-mux slice (our cost model)
    let scatter_alms_per_mult = gather_alms_per_mult + 3 * 16; // 16b add + addr + wr mux
    let scatter_extra_m20k_ports = mults; // one extra port per accumulator lane
    println!(
        "multipliers in plan: {mults}; gather soft logic {} ALMs vs scatter {} ALMs (+{:.0}%)",
        mults * gather_alms_per_mult,
        mults * scatter_alms_per_mult,
        100.0 * (scatter_alms_per_mult as f64 / gather_alms_per_mult as f64 - 1.0)
    );
    println!(
        "scatter also needs ~{} extra M20K ports (quad-port mode halves width to 10b — unusable for 16b accumulation, §III-A)",
        scatter_extra_m20k_ports
    );

    // ---------- §VII: variable precision + Agilex packing ----------
    println!("\n=== ablation 5 (§VII future work): precision vs performance-per-area ===");
    {
        use hpipe::arch::AGILEX_027;
        let s10_16 = compile(&g, "resnet50", &CompileOptions::new(S10_2800.clone(), dsp).with_precision(16)).unwrap();
        let ag_16 = compile(&g, "resnet50", &CompileOptions::new(AGILEX_027.clone(), dsp).with_precision(16)).unwrap();
        let ag_8 = compile(&g, "resnet50", &CompileOptions::new(AGILEX_027.clone(), dsp).with_precision(8)).unwrap();
        let per_area = |p: &hpipe::compile::AcceleratorPlan| {
            p.throughput_img_s() / p.totals.dsps.max(1) as f64
        };
        println!(
            "S10 16-bit:    {:>7.0} img/s, {} DSPs, {:.3} img/s/DSP",
            s10_16.throughput_img_s(), s10_16.totals.dsps, per_area(&s10_16)
        );
        println!(
            "Agilex 16-bit: {:>7.0} img/s, {} DSPs, {:.3} img/s/DSP",
            ag_16.throughput_img_s(), ag_16.totals.dsps, per_area(&ag_16)
        );
        println!(
            "Agilex 8-bit:  {:>7.0} img/s, {} DSPs, {:.3} img/s/DSP",
            ag_8.throughput_img_s(), ag_8.totals.dsps, per_area(&ag_8)
        );
        println!(
            "8-bit vs 16-bit perf/DSP on Agilex: {:.2}x (paper §VII: \"2x or more\")",
            per_area(&ag_8) / per_area(&ag_16)
        );
    }

    // ---------- 4. hot-path timings ----------
    println!("\n=== ablation 4: compiler/simulator hot-path timings ===");
    let mut rng = Rng::new(0xAB);
    let mut w = hpipe::graph::Tensor::randn(&[3, 3, 64, 64], &mut rng, 1.0);
    hpipe::sparsity::prune::prune_tensor(&mut w, 0.85);
    bench("rle_encode/3x3x64x64_s8", 2, 30, || {
        let _ = encode_conv(&w, 8);
    });
    let summary = hpipe::compile::throughput::WeightSummary::from_conv(&w);
    bench("padded_cycles/3x3x64x64_s8", 2, 200, || {
        let _ = summary.padded_cycles(8);
    });
    bench("compile/resnet50", 1, 3, || {
        let _ = compile(&g, "resnet50", &CompileOptions::new(S10_2800.clone(), dsp)).unwrap();
    });
    let events: u64 = aware_sim.stage_lines.iter().sum();
    let s = bench("simulate/resnet50_8img", 1, 5, || {
        let _ = simulate(&aware_plan, 8).unwrap();
    });
    println!(
        "simulator rate: {:.1}M line-events/s",
        events as f64 / (s.median_ns() / 1e9) / 1e6
    );

    // ---------- 6. software execution engine ----------
    println!("\n=== ablation 6: interp vs planned executor (whole pruned ResNet-50) ===");
    {
        use hpipe::exec::{ExecutionPlan, PlanOptions};
        use hpipe::graph::Tensor;
        use std::collections::BTreeMap;
        let mut feeds = BTreeMap::new();
        let in_shape = match &g.get("input").unwrap().op {
            Op::Placeholder { shape } => shape.clone(),
            _ => unreachable!(),
        };
        feeds.insert(
            "input".to_string(),
            Tensor::randn(&in_shape, &mut rng, 1.0),
        );
        let interp_iters = if full { 1 } else { 3 };
        let it = bench("exec_ablation/interp", 1, interp_iters, || {
            let _ = hpipe::interp::run_outputs(&g, &feeds).unwrap();
        });
        let dense = ExecutionPlan::build_with(&g, &PlanOptions::dense_only()).unwrap();
        let sparse = ExecutionPlan::build_with(&g, &PlanOptions::default()).unwrap();
        let mut dctx = dense.new_context();
        let mut sctx = sparse.new_context();
        let d = bench("exec_ablation/planned_dense", 2, 10, || {
            dense.run_with(&mut dctx, &feeds).unwrap();
        });
        let sp = bench("exec_ablation/planned_sparse", 2, 10, || {
            sparse.run_with(&mut sctx, &feeds).unwrap();
        });
        println!(
            "plan composition: {:?}",
            sparse.stats()
        );
        println!(
            "whole-net speedups: dense-plan {:.1}x, sparse-plan {:.1}x over interp (sparse/dense {:.2}x)",
            it.median_ns() / d.median_ns(),
            it.median_ns() / sp.median_ns(),
            d.median_ns() / sp.median_ns()
        );
    }
}
