//! Table II reproduction: resource utilization + frequency for the three
//! compiled accelerators (ResNet-50 sparse, MobileNet-V1/V2 dense) on the
//! Stratix 10 2800, measured vs the paper's published numbers.

use hpipe::arch::S10_2800;
use hpipe::baselines::PaperHpipe;
use hpipe::compile::{compile, CompileOptions};
use hpipe::nets::{build_named, NetConfig};
use hpipe::sparsity::prune_graph;
use hpipe::transform::optimize;
use hpipe::util::timer::Table;

struct PaperRow {
    alms: usize,
    mem_alms: usize,
    regs: usize,
    m20ks: usize,
    dsps: usize,
    mhz: f64,
}

fn main() {
    let full = std::env::var("HPIPE_FULL_SCALE").is_ok() || std::env::var("CI_FULL").is_ok();
    let cfg = if full { NetConfig::imagenet() } else { NetConfig::test_scale() };
    println!(
        "=== Table II: per-CNN resource utilization ({}) ===",
        if full { "full scale — direct Table II comparison" } else { "test scale; run with HPIPE_FULL_SCALE=1 for the Table II numbers" }
    );

    let paper = [
        ("resnet50", 0.85, PaperRow { alms: 591_882, mem_alms: 122_850, regs: 1_417_297, m20ks: 11_278, dsps: 5_022, mhz: 580.0 }),
        ("mobilenet_v1", 0.0, PaperRow { alms: 371_500, mem_alms: 110_950, regs: 874_713, m20ks: 4_283, dsps: 5_133, mhz: 430.0 }),
        ("mobilenet_v2", 0.0, PaperRow { alms: 290_486, mem_alms: 41_550, regs: 766_604, m20ks: 4_512, dsps: 2_964, mhz: 390.0 }),
    ];
    let _ = PaperHpipe::RESNET50_ALMS;

    let mut tab = Table::new(&[
        "CNN", "who", "ALMs", "mem-ALMs", "registers", "M20Ks", "DSPs", "MHz",
    ]);
    for (net, sparsity, p) in paper {
        let mut g = build_named(net, cfg).unwrap();
        if sparsity > 0.0 {
            prune_graph(&mut g, sparsity);
        }
        let (g, _) = optimize(&g);
        let plan = compile(&g, net, &CompileOptions::new(S10_2800.clone(), 5000)).unwrap();
        tab.row(&[
            net.to_string(),
            "ours".into(),
            plan.totals.alms.to_string(),
            plan.totals.mem_alms.to_string(),
            plan.totals.registers.to_string(),
            plan.totals.m20ks.to_string(),
            plan.totals.dsps.to_string(),
            format!("{:.0}", plan.fmax_mhz),
        ]);
        tab.row(&[
            net.to_string(),
            "paper".into(),
            p.alms.to_string(),
            p.mem_alms.to_string(),
            p.regs.to_string(),
            p.m20ks.to_string(),
            p.dsps.to_string(),
            format!("{:.0}", p.mhz),
        ]);
    }
    tab.print();
    println!(
        "\nnotes: ResNet-50 must be memory-bound (M20K% > ALM%/DSP%-gap, paper 96%);\n\
         MobileNet-V2's paper DSP count (2,964 = 51%) reflects input-channel-only\n\
         unrolling — our column-parallel pointwise units reach the DSP target\n\
         instead; see EXPERIMENTS.md for the divergence discussion."
    );
}
