//! Table V reproduction: resource utilization vs Lu et al. (the prior
//! sparse-CNN FPGA accelerator) on ResNet-50.

use hpipe::arch::S10_2800;
use hpipe::baselines::LuEtAl;
use hpipe::compile::{compile, CompileOptions};
use hpipe::nets::{resnet50, NetConfig};
use hpipe::sparsity::prune_graph;
use hpipe::transform::optimize;
use hpipe::util::timer::Table;

fn main() {
    let full = std::env::var("HPIPE_FULL_SCALE").is_ok();
    let cfg = if full { NetConfig::imagenet() } else { NetConfig::test_scale() };
    println!("=== Table V: sparse-CNN FPGA accelerator comparison (ResNet-50) ===");

    let mut g = resnet50(cfg);
    prune_graph(&mut g, 0.85);
    let (g, _) = optimize(&g);
    let plan = compile(&g, "resnet50", &CompileOptions::new(S10_2800.clone(), 5000)).unwrap();
    let (alm_u, m20k_u, dsp_u) = plan.totals.utilization(&plan.device);

    let mut tab = Table::new(&["", "Lu et al. (published)", "HPIPE ours (modeled)", "HPIPE paper"]);
    tab.row(&["device".into(), LuEtAl::DEVICE.into(), plan.device.name.into(), "Intel Stratix 10 2800".into()]);
    tab.row(&["frequency (MHz)".into(), format!("{:.0}", LuEtAl::FREQ_MHZ), format!("{:.0}", plan.fmax_mhz), "580".into()]);
    tab.row(&["logic utilization".into(), format!("{:.0}%", LuEtAl::LOGIC_UTIL * 100.0), format!("{:.0}%", alm_u * 100.0), "63%".into()]);
    tab.row(&["DSP utilization".into(), format!("{:.0}%", LuEtAl::DSP_UTIL * 100.0), format!("{:.0}%", dsp_u * 100.0), "87%".into()]);
    tab.row(&["BRAM utilization".into(), format!("{:.0}%", LuEtAl::BRAM_UTIL * 100.0), format!("{:.0}%", m20k_u * 100.0), "96%".into()]);
    tab.print();

    println!("\nshape checks (paper's qualitative claims):");
    let freq_ratio = plan.fmax_mhz / LuEtAl::FREQ_MHZ;
    println!(
        "  frequency ratio vs Lu: {:.1}x (paper: \"nearly 3x\")  {}",
        freq_ratio,
        if freq_ratio > 2.0 { "OK" } else { "MISS" }
    );
    let dsp_ratio = dsp_u / LuEtAl::DSP_UTIL;
    println!(
        "  DSP-utilization ratio vs Lu: {:.1}x (paper: \"nearly double\")  {}",
        dsp_ratio,
        if dsp_ratio > 1.5 { "OK" } else { "MISS" }
    );
    println!(
        "  logic below Lu's 92% while DSPs above their 45%: {}",
        if alm_u < LuEtAl::LOGIC_UTIL && dsp_u > LuEtAl::DSP_UTIL { "OK" } else { "MISS" }
    );
}
