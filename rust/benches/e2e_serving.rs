//! End-to-end serving benchmark (the L3 hot path + PJRT execution) and
//! the sparse-conv kernel micro-benchmark. Skips gracefully when
//! `make artifacts` has not run.

use hpipe::coordinator::serve_demo;
use hpipe::runtime::Runtime;
use hpipe::util::timer::bench;
use std::path::Path;

fn main() {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("e2e_serving: artifacts/ missing — run `make artifacts` first (skipping)");
        return;
    }
    println!("=== end-to-end serving benchmark (TinyCNN via PJRT) ===");

    // PJRT execute micro-bench: batch-1 and batch-8 models + raw kernel
    let mut rt = Runtime::cpu(&dir).unwrap();
    rt.load_manifest().unwrap();
    let mut rng = hpipe::util::Rng::new(0xB);
    {
        let m1 = rt.model("tinycnn_b1").unwrap();
        let n1: usize = m1.input_shape.iter().product();
        let x1: Vec<f32> = (0..n1).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let s1 = bench("pjrt_execute/tinycnn_b1", 3, 20, || {
            let _ = m1.run(&x1).unwrap();
        });
        let m8 = rt.model("tinycnn_b8").unwrap();
        let n8: usize = m8.input_shape.iter().product();
        let x8: Vec<f32> = (0..n8).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let s8 = bench("pjrt_execute/tinycnn_b8", 3, 20, || {
            let _ = m8.run(&x8).unwrap();
        });
        println!(
            "batching amortization: b8 costs {:.2}x of b1 for 8x the work",
            s8.median_ns() / s1.median_ns()
        );
        let k = rt.model("sparse_conv_demo").unwrap();
        let nk: usize = k.input_shape.iter().product();
        let xk: Vec<f32> = (0..nk).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        bench("pjrt_execute/sparse_conv_demo", 3, 20, || {
            let _ = k.run(&xk).unwrap();
        });
    }
    drop(rt);

    // whole serving path: queue -> batcher -> execute -> respond
    for (requests, batch) in [(64usize, 1usize), (64, 8)] {
        let mut report = serve_demo(&dir, requests, batch).unwrap();
        println!("\nserve_demo requests={requests} max_batch={batch}:");
        report.print();
    }
}
