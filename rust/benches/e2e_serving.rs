//! End-to-end serving benchmark: the L3 hot path (queue -> batcher ->
//! compiled executor -> respond) plus executor micro-benchmarks, with a
//! tuned-vs-static serving comparison (the autotuned row calibrates
//! stage cuts + team from measured step costs at model load) and a
//! machine-readable `BENCH_serve.json` report written next to
//! `BENCH_exec.json`.
//!
//! Uses the trained artifacts when `make artifacts` has run; otherwise
//! synthesizes an equivalent artifact directory (He-init TinyCNN
//! graphdef + manifest) so the benchmark always runs.

use hpipe::coordinator::batcher::BatchPolicy;
use hpipe::coordinator::metrics::ServeReport;
use hpipe::coordinator::{serve_demo, submit, Coordinator, QueuePolicy, Reply, Request, ServeConfig};
use hpipe::graph::graphdef;
use hpipe::nets::{tiny_cnn, NetConfig};
use hpipe::runtime::Runtime;
use hpipe::util::timer::bench;
use hpipe::util::Json;
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

/// Return an artifacts dir, synthesizing one under target/ if needed.
fn artifacts_dir() -> PathBuf {
    let real = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if real.join("manifest.json").exists() {
        return real;
    }
    let dir = Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("target")
        .join("bench_artifacts");
    println!("artifacts/ missing — synthesizing He-init TinyCNN artifacts in target/");
    let g = tiny_cnn(NetConfig::test_scale());
    graphdef::save(&g, &dir.join("tinycnn")).expect("writing graphdef");
    let mut models = Json::obj();
    models
        .set("1", Json::from("tinycnn.graphdef"))
        .set("8", Json::from("tinycnn.graphdef"));
    let mut kernels = Json::obj();
    let mut k = Json::obj();
    k.set("path", Json::from("builtin"))
        .set("input_shape", Json::from(vec![1usize, 16, 16, 8]));
    kernels.set("sparse_conv_demo", k);
    let mut root = Json::obj();
    root.set("input_shape", Json::from(vec![1usize, 16, 16, 3]))
        .set("models", models)
        .set("kernels", kernels);
    std::fs::write(dir.join("manifest.json"), root.pretty()).expect("writing manifest");
    dir
}

fn main() {
    let dir = artifacts_dir();
    println!("=== end-to-end serving benchmark (TinyCNN via compiled executor) ===");

    // executor micro-bench: batch-1 and batch-8 models + sparse kernel
    let mut rt = Runtime::cpu(&dir).unwrap();
    rt.load_manifest().unwrap();
    let mut rng = hpipe::util::Rng::new(0xB);
    {
        let m1 = rt.model("tinycnn_b1").unwrap();
        let n1: usize = m1.input_shape.iter().product();
        let x1: Vec<f32> = (0..n1).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let s1 = bench("exec_plan/tinycnn_b1", 3, 20, || {
            let _ = m1.run(&x1).unwrap();
        });
        let m8 = rt.model("tinycnn_b8").unwrap();
        let n8: usize = m8.input_shape.iter().product();
        let x8: Vec<f32> = (0..n8).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let s8 = bench("exec_plan/tinycnn_b8", 3, 20, || {
            let _ = m8.run(&x8).unwrap();
        });
        println!(
            "batching amortization: b8 costs {:.2}x of b1 for 8x the work",
            s8.median_ns() / s1.median_ns()
        );
        let k = rt.model("sparse_conv_demo").unwrap();
        let nk: usize = k.input_shape.iter().product();
        let xk: Vec<f32> = (0..nk).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        bench("exec_plan/sparse_conv_demo", 3, 20, || {
            let _ = k.run(&xk).unwrap();
        });
    }
    drop(rt);

    // whole serving path: queue -> batcher -> execute -> respond
    // (threads > 1 streams each batch through the layer pipeline;
    // team > 1 splits the dominant stage's convs across a worker team;
    // the final row autotunes — measured cuts + measured team — for the
    // tuned-vs-static comparison)
    let configs: [(&str, ServeConfig); 5] = [
        ("sequential", ServeConfig { requests: 64, max_batch: 1, ..Default::default() }),
        ("batched", ServeConfig { requests: 64, max_batch: 8, ..Default::default() }),
        (
            "static_pipe4",
            ServeConfig { requests: 64, max_batch: 8, threads: 4, ..Default::default() },
        ),
        (
            "static_pipe2_team2",
            ServeConfig { requests: 64, max_batch: 8, threads: 2, team: 2, ..Default::default() },
        ),
        (
            "autotuned",
            ServeConfig { requests: 64, max_batch: 8, autotune: true, ..Default::default() },
        ),
    ];
    let mut serve_json = Json::obj();
    for (name, cfg) in configs {
        let mut report = serve_demo(&dir, &cfg).unwrap();
        println!(
            "\nserve_demo [{name}] requests={} max_batch={} threads={} team={} autotune={}:",
            cfg.requests, cfg.max_batch, cfg.threads, cfg.team, cfg.autotune
        );
        report.print();
        serve_json.set(name, report.to_json());
    }

    // ---- sustained throughput: live request mix ---------------------
    // The serve_demo rows above submit as fast as the queue accepts —
    // a bench-loop number. This section drives a *live* mix (Poisson-ish
    // arrivals, periodic lulls that leave ragged tails, a deadline on
    // every third request) and measures steady-state goodput, where the
    // drain/execute overlap and the plan family actually earn their keep.
    println!("\n=== sustained throughput (live arrivals, ragged tails, mixed deadlines) ===");
    let smoke = std::env::var("BENCH_SMOKE").is_ok();
    // Not a multiple of max_batch, so the request count alone guarantees
    // at least one ragged tail per run.
    let n_live = if smoke { 97 } else { 209 };

    // overlap gate: identical arrival schedule, feeder thread on vs off
    let mut overlap_on = sustained_serve(&dir, true, true, n_live, 0x51);
    let mut overlap_off = sustained_serve(&dir, false, true, n_live, 0x51);
    let mut overlap_retried = false;
    if smoke && goodput(&overlap_on) < goodput(&overlap_off) {
        println!("overlap gate missed on first measurement; re-measuring once");
        overlap_retried = true;
        overlap_on = sustained_serve(&dir, true, true, n_live, 0x52);
        overlap_off = sustained_serve(&dir, false, true, n_live, 0x52);
    }
    // family gate: ragged tails through batch variants vs padded to B
    let mut family_on = sustained_serve(&dir, true, true, n_live, 0x53);
    let mut family_off = sustained_serve(&dir, true, false, n_live, 0x53);
    let mut family_retried = false;
    if smoke && (goodput(&family_on) < goodput(&family_off) || family_on.tail_batches == 0) {
        println!("family gate missed on first measurement; re-measuring once");
        family_retried = true;
        family_on = sustained_serve(&dir, true, true, n_live, 0x54);
        family_off = sustained_serve(&dir, true, false, n_live, 0x54);
    }
    println!(
        "overlap on  : {:>7.0} img/s sustained, inter-batch idle {:?}",
        goodput(&overlap_on),
        Duration::from_nanos(overlap_on.pipeline_idle_ns)
    );
    println!(
        "overlap off : {:>7.0} img/s sustained, inter-batch idle {:?}",
        goodput(&overlap_off),
        Duration::from_nanos(overlap_off.pipeline_idle_ns)
    );
    println!(
        "plan family : {:>7.0} img/s sustained, {} tail batches, {} padded images",
        goodput(&family_on),
        family_on.tail_batches,
        family_on.padded_images
    );
    println!(
        "padded tails: {:>7.0} img/s sustained, {} tail batches, {} padded images",
        goodput(&family_off),
        family_off.tail_batches,
        family_off.padded_images
    );
    let record = |r: &mut ServeReport| {
        let mut j = r.to_json();
        j.set("goodput_img_s", Json::from(goodput(r)));
        j
    };
    let mut sustained = Json::obj();
    sustained
        .set("requests", Json::from(n_live))
        .set("overlap", record(&mut overlap_on))
        .set("drain_then_run", record(&mut overlap_off))
        .set("family", record(&mut family_on))
        .set("padded", record(&mut family_off))
        .set("overlap_gate_retried", Json::from(overlap_retried))
        .set("family_gate_retried", Json::from(family_retried));
    serve_json.set("sustained", sustained);

    let out = Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_serve.json");
    std::fs::write(&out, serve_json.pretty()).expect("writing BENCH_serve.json");
    println!("\nwrote {}", out.display());

    // hard gates, enforced after the JSON is on disk so a failure still
    // leaves the report behind for the CI artifact
    if smoke {
        assert!(
            goodput(&overlap_on) >= goodput(&overlap_off),
            "BENCH_SMOKE gate: drain/execute overlap ({:.0} img/s) must sustain at least \
             the drain-then-run baseline ({:.0} img/s)",
            goodput(&overlap_on),
            goodput(&overlap_off)
        );
        assert!(
            goodput(&family_on) >= goodput(&family_off),
            "BENCH_SMOKE gate: plan-family tail routing ({:.0} img/s) must sustain at \
             least the padded-to-batch baseline ({:.0} img/s)",
            goodput(&family_on),
            goodput(&family_off)
        );
        assert!(
            family_on.tail_batches > 0,
            "BENCH_SMOKE gate: the live mix must exercise ragged tails"
        );
        println!("BENCH_SMOKE sustained gates passed");
    }
}

/// Sustained goodput: requests actually served (not expired at their
/// deadline, not rejected as malformed) per second of serving wall time.
fn goodput(r: &ServeReport) -> f64 {
    (r.requests - r.expired - r.rejected) as f64 / r.wall.as_secs_f64().max(1e-9)
}

/// One sustained-serving run. A client thread generates the live mix —
/// exponential (Poisson-ish) inter-arrivals from the deterministic
/// [`hpipe::util::Rng`], a lull every 13th request longer than the
/// batcher's straggler window (the queue runs dry, so the next batch is
/// a ragged tail), and a 25 ms deadline on every third request — while
/// the coordinator serves continuously. The same seed replays the same
/// schedule, so each gate compares its two configs on identical work.
fn sustained_serve(
    dir: &Path,
    overlap: bool,
    family: bool,
    n_requests: usize,
    seed: u64,
) -> ServeReport {
    let mut runtime = Runtime::cpu(dir).unwrap().with_threads(2);
    if !family {
        runtime = runtime.with_plan_family(&[]);
    }
    runtime.load_manifest().unwrap();
    let per: usize = runtime
        .model("tinycnn_b1")
        .expect("tinycnn_b1 in manifest")
        .input_shape
        .iter()
        .product();
    let policy = BatchPolicy { max_batch: 8, ..Default::default() };
    let mut coordinator = Coordinator::new(runtime, policy);
    coordinator.overlap = overlap;
    let (tx, rx) = std::sync::mpsc::sync_channel::<Request>(n_requests.max(1));
    let (reply_tx, reply_rx) = std::sync::mpsc::channel::<Reply>();
    let client = std::thread::spawn(move || {
        let mut rng = hpipe::util::Rng::new(seed);
        for i in 0..n_requests {
            let data: Vec<f32> = (0..per).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let now = Instant::now();
            let req = Request {
                id: i as u64,
                data,
                submitted: now,
                deadline: (i % 3 == 0).then(|| now + Duration::from_millis(25)),
                reply: reply_tx.clone(),
            };
            assert!(submit(&tx, req, QueuePolicy::Block), "blocking submit");
            let gap_us = -40.0 * (1.0 - rng.f64()).ln();
            std::thread::sleep(Duration::from_micros(gap_us as u64));
            if i % 13 == 12 {
                std::thread::sleep(Duration::from_micros(500));
            }
        }
    });
    let report = coordinator.run(rx).expect("sustained serve");
    client.join().unwrap();
    let answered = reply_rx.try_iter().count();
    assert_eq!(answered, n_requests, "every live request is answered exactly once");
    report
}
