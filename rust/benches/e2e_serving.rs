//! End-to-end serving benchmark: the L3 hot path (queue -> batcher ->
//! compiled executor -> respond) plus executor micro-benchmarks, with a
//! tuned-vs-static serving comparison (the autotuned row calibrates
//! stage cuts + team from measured step costs at model load) and a
//! machine-readable `BENCH_serve.json` report written next to
//! `BENCH_exec.json`.
//!
//! Uses the trained artifacts when `make artifacts` has run; otherwise
//! synthesizes an equivalent artifact directory (He-init TinyCNN
//! graphdef + manifest) so the benchmark always runs.

use hpipe::coordinator::{serve_demo, ServeConfig};
use hpipe::graph::graphdef;
use hpipe::nets::{tiny_cnn, NetConfig};
use hpipe::runtime::Runtime;
use hpipe::util::timer::bench;
use hpipe::util::Json;
use std::path::{Path, PathBuf};

/// Return an artifacts dir, synthesizing one under target/ if needed.
fn artifacts_dir() -> PathBuf {
    let real = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if real.join("manifest.json").exists() {
        return real;
    }
    let dir = Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("target")
        .join("bench_artifacts");
    println!("artifacts/ missing — synthesizing He-init TinyCNN artifacts in target/");
    let g = tiny_cnn(NetConfig::test_scale());
    graphdef::save(&g, &dir.join("tinycnn")).expect("writing graphdef");
    let mut models = Json::obj();
    models
        .set("1", Json::from("tinycnn.graphdef"))
        .set("8", Json::from("tinycnn.graphdef"));
    let mut kernels = Json::obj();
    let mut k = Json::obj();
    k.set("path", Json::from("builtin"))
        .set("input_shape", Json::from(vec![1usize, 16, 16, 8]));
    kernels.set("sparse_conv_demo", k);
    let mut root = Json::obj();
    root.set("input_shape", Json::from(vec![1usize, 16, 16, 3]))
        .set("models", models)
        .set("kernels", kernels);
    std::fs::write(dir.join("manifest.json"), root.pretty()).expect("writing manifest");
    dir
}

fn main() {
    let dir = artifacts_dir();
    println!("=== end-to-end serving benchmark (TinyCNN via compiled executor) ===");

    // executor micro-bench: batch-1 and batch-8 models + sparse kernel
    let mut rt = Runtime::cpu(&dir).unwrap();
    rt.load_manifest().unwrap();
    let mut rng = hpipe::util::Rng::new(0xB);
    {
        let m1 = rt.model("tinycnn_b1").unwrap();
        let n1: usize = m1.input_shape.iter().product();
        let x1: Vec<f32> = (0..n1).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let s1 = bench("exec_plan/tinycnn_b1", 3, 20, || {
            let _ = m1.run(&x1).unwrap();
        });
        let m8 = rt.model("tinycnn_b8").unwrap();
        let n8: usize = m8.input_shape.iter().product();
        let x8: Vec<f32> = (0..n8).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let s8 = bench("exec_plan/tinycnn_b8", 3, 20, || {
            let _ = m8.run(&x8).unwrap();
        });
        println!(
            "batching amortization: b8 costs {:.2}x of b1 for 8x the work",
            s8.median_ns() / s1.median_ns()
        );
        let k = rt.model("sparse_conv_demo").unwrap();
        let nk: usize = k.input_shape.iter().product();
        let xk: Vec<f32> = (0..nk).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        bench("exec_plan/sparse_conv_demo", 3, 20, || {
            let _ = k.run(&xk).unwrap();
        });
    }
    drop(rt);

    // whole serving path: queue -> batcher -> execute -> respond
    // (threads > 1 streams each batch through the layer pipeline;
    // team > 1 splits the dominant stage's convs across a worker team;
    // the final row autotunes — measured cuts + measured team — for the
    // tuned-vs-static comparison)
    let configs: [(&str, ServeConfig); 5] = [
        ("sequential", ServeConfig { requests: 64, max_batch: 1, ..Default::default() }),
        ("batched", ServeConfig { requests: 64, max_batch: 8, ..Default::default() }),
        (
            "static_pipe4",
            ServeConfig { requests: 64, max_batch: 8, threads: 4, ..Default::default() },
        ),
        (
            "static_pipe2_team2",
            ServeConfig { requests: 64, max_batch: 8, threads: 2, team: 2, ..Default::default() },
        ),
        (
            "autotuned",
            ServeConfig { requests: 64, max_batch: 8, autotune: true, ..Default::default() },
        ),
    ];
    let mut serve_json = Json::obj();
    for (name, cfg) in configs {
        let mut report = serve_demo(&dir, &cfg).unwrap();
        println!(
            "\nserve_demo [{name}] requests={} max_batch={} threads={} team={} autotune={}:",
            cfg.requests, cfg.max_batch, cfg.threads, cfg.team, cfg.autotune
        );
        report.print();
        serve_json.set(name, report.to_json());
    }
    let out = Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_serve.json");
    std::fs::write(&out, serve_json.pretty()).expect("writing BENCH_serve.json");
    println!("\nwrote {}", out.display());
}
