//! Fig 3 reproduction: per-layer cycle counts before/after balancing on
//! 85%-sparse ResNet-50 (DSP target 5000, S10 2800), plus the per-layer
//! resource fractions, the §IV model-accuracy claims and the balancer
//! runtime ("a few seconds").
//!
//!   cargo bench --bench fig3_balance            (test-scale: fast)
//!   HPIPE_FULL_SCALE=1 cargo bench --bench fig3_balance

use hpipe::arch::S10_2800;
use hpipe::compile::{balance::imbalance, compile, plan_stages, CompileOptions};
use hpipe::nets::{resnet50, NetConfig};
use hpipe::sim::simulate;
use hpipe::sparsity::prune_graph;
use hpipe::transform::optimize;
use hpipe::util::timer::Table;

fn main() {
    let full = std::env::var("HPIPE_FULL_SCALE").is_ok();
    let cfg = if full { NetConfig::imagenet() } else { NetConfig::test_scale() };
    let dsp_target = if full { 5000 } else { 1200 };
    println!(
        "=== Fig 3: layer latency before/after balancing ({}) ===",
        if full { "full scale" } else { "test scale" }
    );

    let mut g = resnet50(cfg);
    prune_graph(&mut g, 0.85);
    let (g, _) = optimize(&g);
    let opts = CompileOptions::new(S10_2800.clone(), dsp_target);
    let (unbalanced, _) = plan_stages(&g, &opts).unwrap();

    let t0 = std::time::Instant::now();
    let plan = compile(&g, "resnet50", &opts).unwrap();
    let balance_time = t0.elapsed();

    let mut tab = Table::new(&[
        "layer",
        "unbalanced cyc",
        "balanced cyc",
        "splits",
        "%ALM",
        "%M20K",
        "%DSP",
    ]);
    for (u, b) in unbalanced.iter().zip(&plan.stages) {
        if !b.is_compute() {
            continue;
        }
        tab.row(&[
            b.name.clone(),
            u.cycles.to_string(),
            b.cycles.to_string(),
            b.splits.to_string(),
            format!("{:.2}", 100.0 * b.resources.alms as f64 / plan.device.alms as f64),
            format!("{:.2}", 100.0 * b.resources.m20ks as f64 / plan.device.m20ks as f64),
            format!("{:.2}", 100.0 * b.resources.dsps as f64 / plan.device.dsps as f64),
        ]);
    }
    tab.print();

    let unb = unbalanced.iter().map(|s| s.cycles).max().unwrap();
    let bal = plan.interval_cycles();
    println!("\nbalancing gain: {unb} -> {bal} cycles = {:.1}x (paper: 30x)", unb as f64 / bal as f64);
    println!(
        "imbalance (max/median compute stage): {:.2} -> {:.2} (paper: \"within 10%\")",
        imbalance(&unbalanced),
        imbalance(&plan.stages)
    );
    println!("balancer + planning runtime: {balance_time:?} (paper: \"a few seconds\")");

    // §IV: analytic estimate vs "actual" (our cycle simulator)
    let images = 6;
    let sim = simulate(&plan, images).unwrap();
    let busy = sim.stage_busy[plan.bottleneck] as f64 / images as f64;
    let predicted = plan.stages[plan.bottleneck].cycles as f64;
    println!(
        "analytic vs simulated bottleneck cycles: {predicted:.0} vs {busy:.0} ({:+.2}% error; paper: within 1%)",
        100.0 * (predicted - busy) / busy
    );
    println!(
        "simulated steady interval {} cycles vs analytic {} ({:+.1}%)",
        sim.steady_interval(),
        bal,
        100.0 * (sim.steady_interval() as f64 - bal as f64) / bal as f64
    );
}
