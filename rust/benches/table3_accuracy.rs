//! Table III reproduction: precision/format vs accuracy.
//!
//! The paper's accuracy column comes from the 50k-image ImageNet
//! validation set on physical hardware; we have neither, so the measured
//! analog is the fixed-point executor's fidelity versus the f32 oracle
//! on classification tasks — sweeping the precision ladder (8/11/16-bit)
//! that the table's accelerators use — plus the transform-equivalence
//! check (the paper's "no impact to either top 1 or top 5 accuracy"
//! claim for BN folding).

use hpipe::graph::Tensor;
use hpipe::interp::fixed::{run_fixed, PrecisionConfig};
use hpipe::nets::{build_named, NetConfig};
use hpipe::sparsity::prune_graph;
use hpipe::transform::{equiv, optimize};
use hpipe::util::timer::Table;
use hpipe::util::Rng;
use std::collections::BTreeMap;

fn main() {
    println!("=== Table III: precision / sparsity / accuracy ===");
    let published = hpipe::baselines::table3_published();
    let mut pub_tab = Table::new(&["accelerator", "sparsity", "winograd", "precision", "format", "top-1 (published)"]);
    for r in &published {
        pub_tab.row(&[
            r.name.to_string(),
            format!("{:.0}%", r.sparsity * 100.0),
            if r.winograd { "Yes" } else { "No" }.to_string(),
            format!("{}-bit", r.precision_bits),
            r.format.to_string(),
            r.top1.map(|a| format!("{:.1}%", a * 100.0)).unwrap_or("-".into()),
        ]);
    }
    pub_tab.print();

    // measured: fixed-point fidelity ladder on TinyCNN + sparse ResNet
    println!("\nmeasured fixed-point fidelity (argmax agreement with f32 oracle, 40 random inputs):");
    let mut tab = Table::new(&["network", "bits", "argmax agreement", "max |err|"]);
    for net in ["tinycnn", "resnet50"] {
        let mut g = build_named(net, NetConfig::test_scale()).unwrap();
        if net == "resnet50" {
            prune_graph(&mut g, 0.85);
        }
        let (g, _) = optimize(&g);
        let input_shape = match &g.get("input").unwrap().op {
            hpipe::graph::Op::Placeholder { shape } => shape.clone(),
            _ => unreachable!(),
        };
        for bits in [8u32, 11, 16] {
            let trials = if net == "resnet50" { 8 } else { 40 };
            let mut rng = Rng::new(0x333 + bits as u64);
            let mut agree = 0;
            let mut max_err = 0f32;
            for _ in 0..trials {
                let mut feeds = BTreeMap::new();
                feeds.insert(
                    "input".to_string(),
                    Tensor::randn(&input_shape, &mut rng, 1.0),
                );
                let r = run_fixed(&g, &feeds, &PrecisionConfig::uniform(bits, bits / 2)).unwrap();
                if r.argmax_match {
                    agree += 1;
                }
                max_err = max_err.max(r.max_abs_error);
            }
            tab.row(&[
                net.to_string(),
                bits.to_string(),
                format!("{agree}/{trials}"),
                format!("{max_err:.5}"),
            ]);
        }
    }
    tab.print();

    // the BN-folding "no accuracy impact" claim, measured as numerical
    // equivalence of the transformed graph
    let g = build_named("resnet50", NetConfig::test_scale()).unwrap();
    let (opt, _) = optimize(&g);
    match equiv::assert_equivalent(&g, &opt, 3, 1e-3) {
        Ok(()) => println!(
            "\nBN folding equivalence: PASS (paper: \"no impact to either top 1 or top 5 accuracy\")"
        ),
        Err(e) => println!("\nBN folding equivalence: FAIL — {e}"),
    }
}
