//! Cold-start benchmark for the plan-artifact cache: how long from
//! process start to "every model loaded and ready to serve", compiled
//! fresh vs restored from on-disk artifacts.
//!
//! The fresh path runs the full load pipeline — graphdef decode, const
//! fold, RLE encode, panel pack, and (because this bench loads with
//! `--autotune` semantics) the profile-guided calibration passes. The
//! cached path replays none of it: packed panels, pre-decoded streams,
//! measured cuts and the calibration report all come off disk. Under
//! `BENCH_SMOKE=1` the cached cold start is gated at >= 5x faster than
//! the fresh one (re-measured once before failing, like the serving
//! gates), after `BENCH_coldstart.json` is already on disk for the CI
//! artifact.
//!
//! The bench also proves the failure contract on a corrupted *copy* of
//! the cache: truncation and a bit flip must both surface as typed
//! `GraphError::Artifact` rejections, and a runtime pointed at the
//! corrupted cache must fall back to a fresh compile and come up
//! serving anyway.

use hpipe::artifact;
use hpipe::exec::TuneOptions;
use hpipe::graph::{graphdef, GraphError};
use hpipe::nets::{tiny_cnn, NetConfig};
use hpipe::runtime::Runtime;
use hpipe::util::Json;
use std::fs;
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

/// Return an artifacts dir, synthesizing one under target/ if needed.
fn artifacts_dir() -> PathBuf {
    let real = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if real.join("manifest.json").exists() {
        return real;
    }
    let dir = Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("target")
        .join("coldstart_artifacts");
    println!("artifacts/ missing — synthesizing He-init TinyCNN artifacts in target/");
    let g = tiny_cnn(NetConfig::test_scale());
    graphdef::save(&g, &dir.join("tinycnn")).expect("writing graphdef");
    let mut models = Json::obj();
    models
        .set("1", Json::from("tinycnn.graphdef"))
        .set("8", Json::from("tinycnn.graphdef"));
    let mut kernels = Json::obj();
    let mut k = Json::obj();
    k.set("path", Json::from("builtin"))
        .set("input_shape", Json::from(vec![1usize, 16, 16, 8]));
    kernels.set("sparse_conv_demo", k);
    let mut root = Json::obj();
    root.set("input_shape", Json::from(vec![1usize, 16, 16, 3]))
        .set("models", models)
        .set("kernels", kernels);
    std::fs::write(dir.join("manifest.json"), root.pretty()).expect("writing manifest");
    dir
}

/// One cold start: construct the runtime (autotuned, plan-cached) and
/// load every manifest model. Returns (wall, cache hits, cache misses).
fn cold_start(dir: &Path, cache: &Path) -> (Duration, usize, usize) {
    let t0 = Instant::now();
    let mut rt = Runtime::cpu(dir)
        .unwrap()
        .with_autotune(TuneOptions::default())
        .with_plan_cache(cache);
    rt.load_manifest().expect("cold start must come up serving");
    (t0.elapsed(), rt.cache_hits, rt.cache_misses)
}

fn median(mut v: Vec<u64>) -> u64 {
    v.sort_unstable();
    v[v.len() / 2]
}

/// Median fresh (cache cleared before every run) and cached (artifact
/// present) cold-start times in nanoseconds.
fn measure(dir: &Path, cache: &Path) -> (u64, u64) {
    let mut fresh = Vec::new();
    for _ in 0..3 {
        let _ = fs::remove_dir_all(cache);
        let (d, hits, misses) = cold_start(dir, cache);
        assert!(hits == 0 && misses > 0, "cleared cache must miss");
        fresh.push(d.as_nanos() as u64);
    }
    let mut cached = Vec::new();
    for _ in 0..5 {
        let (d, hits, misses) = cold_start(dir, cache);
        assert!(
            misses == 0 && hits > 0,
            "warm cache must restore every model ({hits} hits, {misses} misses)"
        );
        cached.push(d.as_nanos() as u64);
    }
    (median(fresh), median(cached))
}

fn copy_tree(src: &Path, dst: &Path) {
    fs::create_dir_all(dst).unwrap();
    for e in fs::read_dir(src).unwrap() {
        let e = e.unwrap();
        let to = dst.join(e.file_name());
        if e.path().is_dir() {
            copy_tree(&e.path(), &to);
        } else {
            fs::copy(e.path(), &to).unwrap();
        }
    }
}

/// Apply `damage` to every model's `plan.bin` under `cache`; returns
/// how many binaries were damaged.
fn corrupt_bins(cache: &Path, damage: impl Fn(&mut Vec<u8>)) -> usize {
    let mut n = 0;
    for e in fs::read_dir(cache).unwrap() {
        let bin = e.unwrap().path().join("plan.bin");
        if let Ok(mut bytes) = fs::read(&bin) {
            if bytes.is_empty() {
                continue;
            }
            damage(&mut bytes);
            fs::write(&bin, &bytes).unwrap();
            n += 1;
        }
    }
    n
}

/// Every artifact under `cache` must now be rejected with the *typed*
/// error (`GraphError::Artifact`), loaded with its own recorded key.
fn assert_typed_rejections(cache: &Path, what: &str) -> usize {
    let mut n = 0;
    for e in fs::read_dir(cache).unwrap() {
        let dir = e.unwrap().path();
        // only artifacts with a binary payload were damaged
        match fs::read(dir.join("plan.bin")) {
            Ok(b) if !b.is_empty() => {}
            _ => continue,
        }
        let Ok(text) = fs::read_to_string(dir.join("plan.json")) else { continue };
        let root = Json::parse(&text).unwrap();
        let key = u64::from_str_radix(root.get("key").as_str().unwrap(), 16).unwrap();
        let err = artifact::load(&dir, key).unwrap_err();
        assert!(
            matches!(err, GraphError::Artifact(_)),
            "{what}: expected GraphError::Artifact for {}, got {err:?}",
            dir.display()
        );
        n += 1;
    }
    n
}

fn main() {
    let dir = artifacts_dir();
    let cache = Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("target")
        .join("coldstart_plan_cache");
    let smoke = std::env::var("BENCH_SMOKE").is_ok();
    println!("=== cold start: fresh compile vs plan-artifact restore ===");

    let (mut fresh_ns, mut cached_ns) = measure(&dir, &cache);
    let mut retried = false;
    if smoke && fresh_ns < 5 * cached_ns {
        println!("cold-start gate missed on first measurement; re-measuring once");
        retried = true;
        let (f, c) = measure(&dir, &cache);
        fresh_ns = f;
        cached_ns = c;
    }
    let speedup = fresh_ns as f64 / cached_ns.max(1) as f64;
    println!(
        "fresh compile : {:?} (fold + encode + pack + profile)",
        Duration::from_nanos(fresh_ns)
    );
    println!("cached restore: {:?}", Duration::from_nanos(cached_ns));
    println!("speedup       : {speedup:.1}x");

    // ---- failure contract on a corrupted copy of the cache ----------
    let corrupt = Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("target")
        .join("coldstart_plan_cache_corrupt");
    let _ = fs::remove_dir_all(&corrupt);
    copy_tree(&cache, &corrupt);
    // truncation: drop the second half of every plan.bin
    let truncated = corrupt_bins(&corrupt, |b| b.truncate(b.len() / 2));
    assert!(truncated > 0, "the cache must hold binary payloads");
    let truncate_typed = assert_typed_rejections(&corrupt, "truncate");
    // ...and a runtime pointed at the damage still comes up, compiling
    // fresh (which re-persists pristine artifacts into the copy)
    let (_, hits, misses) = cold_start(&dir, &corrupt);
    assert!(hits == 0 && misses > 0, "truncated cache must fall back to fresh compile");
    // bit flip: one byte, deep in the re-saved pristine payload
    let flipped = corrupt_bins(&corrupt, |b| {
        let i = b.len() / 3;
        b[i] ^= 0x10;
    });
    assert!(flipped > 0);
    let bitflip_typed = assert_typed_rejections(&corrupt, "bit flip");
    let (_, hits, misses) = cold_start(&dir, &corrupt);
    assert!(hits == 0 && misses > 0, "bit-flipped cache must fall back to fresh compile");
    let _ = fs::remove_dir_all(&corrupt);
    println!(
        "corruption: {truncate_typed} truncated + {bitflip_typed} bit-flipped artifacts \
         rejected typed, runtime fell back to fresh compile both times"
    );

    // report first, gates after — a failed gate still leaves the JSON
    // behind for the CI artifact
    let mut root = Json::obj();
    root.set("fresh_cold_start_ns", Json::from(fresh_ns as f64))
        .set("cached_cold_start_ns", Json::from(cached_ns as f64))
        .set("speedup", Json::from(speedup))
        .set("required_speedup", Json::from(5.0))
        .set("gate_retried", Json::from(retried))
        .set("truncate_typed_rejections", Json::from(truncate_typed))
        .set("bitflip_typed_rejections", Json::from(bitflip_typed))
        .set("corrupt_fallback_served", Json::from(true));
    let out = Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_coldstart.json");
    fs::write(&out, root.pretty()).expect("writing BENCH_coldstart.json");
    println!("wrote {}", out.display());

    if smoke {
        assert!(
            fresh_ns >= 5 * cached_ns,
            "BENCH_SMOKE gate: cached cold start ({:?}) must be >= 5x faster than a \
             fresh compile ({:?}); measured {speedup:.1}x",
            Duration::from_nanos(cached_ns),
            Duration::from_nanos(fresh_ns)
        );
        println!("BENCH_SMOKE cold-start gate passed ({speedup:.1}x)");
    }
}
