//! Fig 8 reproduction: throughput vs latency on ResNet-50 — HPIPE (our
//! compiled+simulated plan) against the V100 batch sweep, Brainwave and
//! DLA-Like (published numbers + the paper's A10→S10 scaling).

use hpipe::arch::S10_2800;
use hpipe::baselines::{
    scale_point, v100_resnet50_curve, PaperHpipe, BRAINWAVE_A10, BRAINWAVE_S10_SCALE,
    DLA_A10, DLA_S10_SCALE,
};
use hpipe::compile::{compile, CompileOptions};
use hpipe::nets::{resnet50, NetConfig};
use hpipe::sim::simulate;
use hpipe::sparsity::prune_graph;
use hpipe::transform::optimize;
use hpipe::util::timer::Table;

fn main() {
    let full = std::env::var("HPIPE_FULL_SCALE").is_ok();
    let cfg = if full { NetConfig::imagenet() } else { NetConfig::test_scale() };
    let dsp_target = if full { 5000 } else { 1200 };
    println!("=== Fig 8: throughput vs latency, ResNet-50 ===");

    let mut g = resnet50(cfg);
    prune_graph(&mut g, 0.85);
    let (g, _) = optimize(&g);
    let plan = compile(&g, "resnet50", &CompileOptions::new(S10_2800.clone(), dsp_target)).unwrap();
    let sim = simulate(&plan, 12).unwrap();
    let hpipe_thr = sim.throughput_img_s(plan.fmax_mhz);
    let hpipe_lat = sim.latency_ms(plan.fmax_mhz);

    let mut tab = Table::new(&["accelerator", "batch", "latency (ms)", "throughput (img/s)"]);
    tab.row(&[
        format!("HPIPE (ours, {})", if full { "full" } else { "test-scale" }),
        "1".into(),
        format!("{hpipe_lat:.2}"),
        format!("{hpipe_thr:.0}"),
    ]);
    for p in v100_resnet50_curve() {
        tab.row(&[
            "V100".into(),
            p.batch.to_string(),
            format!("{:.2}", p.latency_ms),
            format!("{:.0}", p.throughput),
        ]);
    }
    let bw = scale_point(BRAINWAVE_A10, BRAINWAVE_S10_SCALE);
    tab.row(&["Brainwave (A10, published)".into(), "1".into(), format!("{:.2}", BRAINWAVE_A10.latency_ms), format!("{:.0}", BRAINWAVE_A10.throughput)]);
    tab.row(&["Brainwave (S10, scaled)".into(), "1".into(), format!("{:.2}", bw.latency_ms), format!("{:.0}", bw.throughput)]);
    let dla = scale_point(DLA_A10, DLA_S10_SCALE);
    tab.row(&["DLA-Like (A10, published)".into(), "1".into(), format!("{:.2}", DLA_A10.latency_ms), format!("{:.0}", DLA_A10.throughput)]);
    tab.row(&["DLA-Like (S10, scaled)".into(), "1".into(), format!("{:.2}", dla.latency_ms), format!("{:.0}", dla.throughput)]);
    tab.print();

    let v100_b1 = v100_resnet50_curve()[0];
    let v100_b8 = v100_resnet50_curve()[3];
    println!("\nheadline ratios (ours / paper):");
    println!(
        "  HPIPE vs V100@B1 throughput: {:.1}x  (paper: {:.1}x, \"nearly 4x\")",
        hpipe_thr / v100_b1.throughput,
        PaperHpipe::RESNET50_THROUGHPUT / v100_b1.throughput
    );
    println!(
        "  V100@B8 reaches {:.0}% of HPIPE with {:.1}x the latency (paper: 72% at 2.2x)",
        100.0 * v100_b8.throughput / hpipe_thr,
        v100_b8.latency_ms / hpipe_lat
    );
    println!(
        "  HPIPE vs Brainwave(S10): {:.1}x (paper 1.6x)   vs DLA-Like(S10): {:.1}x (paper 7.4x)",
        hpipe_thr / bw.throughput,
        hpipe_thr / dla.throughput
    );
    if !full {
        println!("  (test-scale network: absolute img/s is higher than the paper's\n   224x224 model; the ordering and ratios are the reproduction target.\n   Set HPIPE_FULL_SCALE=1 for the full-resolution run.)");
    }
}
