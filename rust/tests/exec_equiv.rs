//! Equivalence property test: the compiled executor must match the
//! reference-interpreter oracle (≤ 1e-4 relative) on randomized
//! TinyCNN-style and ResNet-block graphs, across sparsity levels
//! 0.0–0.9, across plan options (dense/sparse kernels, fusion on/off,
//! RLE split counts), and both before and after the transform passes.
//! The layer-pipelined executor is held to a harder bar: across stage
//! counts it must match the *sequential plan bit for bit* (same kernels
//! in the same order), and match the interpreter to the same tolerance.
//!
//! Batched plans (ISSUE 3) and worker teams (ISSUE 4) split into two
//! bars, documented per test:
//!
//! * **bitwise** wherever per-element accumulation order is provably
//!   unchanged: the sparse kernels (one accumulator per output channel,
//!   walk order fixed at plan build — batch-, tile- and team-invariant),
//!   the pipeline (same kernels, same order) and the intra-stage worker
//!   team (disjoint output rows, same order per row);
//! * **ULP-bounded** on dense-conv/matmul paths compared across batch
//!   sizes: the register-tiled microkernel's per-element order is
//!   batch-invariant *today*, but the contract we pin is a tight ULP
//!   bound, leaving the microkernel free to retile its accumulation.

use hpipe::exec::tune::tune_plan;
use hpipe::exec::{
    ExecutionPlan, PipelinePlan, PlanOptions, ProfileOptions, StepProfile, TuneOptions,
};
use hpipe::graph::{Graph, Op, Padding, Tensor};
use hpipe::interp;
use hpipe::nets::{tiny_cnn, NetBuilder, NetConfig};
use hpipe::sparsity::prune_graph;
use hpipe::transform::optimize;
use hpipe::util::prop::{assert_close, assert_ulp_close, Cases};
use hpipe::util::Rng;
use std::collections::BTreeMap;

/// ULP budget for dense microkernel paths compared across batch sizes.
/// Accumulation order is batch-invariant today (so observed drift is 0),
/// but the pinned contract is rounding-level closeness, not bit equality.
const DENSE_ULPS: u32 = 8;

/// Randomized small CNN: conv+bias+relu stages with random widths,
/// strides and optional pools, then GAP -> FC -> softmax.
fn random_cnn(rng: &mut Rng, size: usize) -> Graph {
    let mut b = NetBuilder::new(rng.next_u64());
    let mut h = 8 + (size % 3) * 4; // 8 / 12 / 16
    let c0 = 2 + rng.below(3);
    let x = b.input("input", h, h, c0);
    let mut prev = x;
    let mut cin = c0;
    let depth = 1 + rng.below(3);
    for i in 0..depth {
        let cout = 4 * (1 + rng.below(3));
        let stride = 1 + rng.below(2);
        let c = b.conv(&format!("conv{i}"), &prev, 3, cin, cout, stride, Padding::Same);
        h = h.div_ceil(stride);
        let bi = b.bias(&format!("conv{i}/biasadd"), &c, cout);
        prev = b.relu(&format!("conv{i}/relu"), &bi);
        if h >= 2 && rng.chance(0.5) {
            prev = b.g.op(
                &format!("pool{i}"),
                Op::MaxPool { ksize: (2, 2), stride: (2, 2), padding: Padding::Valid },
                &[&prev],
            );
            h = (h - 2) / 2 + 1;
        }
        cin = cout;
    }
    b.head(&prev, cin, 5);
    b.g
}

/// Randomized ResNet bottleneck block (BN after every conv, optional
/// projection shortcut with stride, Add + Relu), preceded by a
/// standalone Pad half the time so pad-merging paths get exercised.
fn random_resnet_block(rng: &mut Rng) -> Graph {
    let mut b = NetBuilder::new(rng.next_u64());
    let hw = 8;
    let cin = 8 * (1 + rng.below(2));
    let mid = 4 * (1 + rng.below(2));
    let x = b.input("input", hw, hw, cin);
    let stem = if rng.chance(0.5) {
        let p = b.g.op("stem_pad", Op::Pad { pads: (1, 1, 1, 1) }, &[&x]);
        let c = b.conv("stem", &p, 3, cin, cin, 1, Padding::Valid);
        let bn = b.bn("stem_bn", &c, cin);
        b.relu("stem_relu", &bn)
    } else {
        x
    };
    let use_proj = rng.chance(0.5);
    let (stride, out_c) = if use_proj {
        (1 + rng.below(2), 8 * (1 + rng.below(2)))
    } else {
        (1, cin)
    };
    let shortcut = if use_proj {
        let sc = b.conv("proj", &stem, 1, cin, out_c, stride, Padding::Same);
        b.bn("proj_bn", &sc, out_c)
    } else {
        stem.clone()
    };
    let c_a = b.conv("branch2a", &stem, 1, cin, mid, stride, Padding::Same);
    let bn_a = b.bn("bn2a", &c_a, mid);
    let r_a = b.relu("relu2a", &bn_a);
    let c_b = b.conv("branch2b", &r_a, 3, mid, mid, 1, Padding::Same);
    let bn_b = b.bn("bn2b", &c_b, mid);
    let r_b = b.relu("relu2b", &bn_b);
    let c_c = b.conv("branch2c", &r_b, 1, mid, out_c, 1, Padding::Same);
    let bn_c = b.bn("bn2c", &c_c, out_c);
    let add = b.g.op("res_add", Op::Add, &[&shortcut, &bn_c]);
    let out = b.relu("res_relu", &add);
    b.g.outputs = vec![out];
    b.g
}

fn random_options(rng: &mut Rng) -> PlanOptions {
    PlanOptions {
        sparse_threshold: *rng.choose(&[0.0, 0.3, 0.5, 2.0]),
        fuse: rng.chance(0.8),
        splits: 1 + rng.below(4),
        ..Default::default()
    }
}

fn check_equivalence(g: &Graph, opts: &PlanOptions, rng: &mut Rng) -> Result<(), String> {
    let plan = ExecutionPlan::build_with(g, opts).map_err(|e| e.to_string())?;
    let feeds = g.random_feeds(rng);
    let got = plan.run(&feeds).map_err(|e| e.to_string())?;
    let want = interp::run_outputs(g, &feeds).map_err(|e| e.to_string())?;
    if got.len() != want.len() {
        return Err(format!("output count {} vs {}", got.len(), want.len()));
    }
    for (i, (a, b)) in got.iter().zip(&want).enumerate() {
        if a.shape != b.shape {
            return Err(format!("output {i} shape {:?} vs {:?}", a.shape, b.shape));
        }
        assert_close(&a.data, &b.data, 1e-5, 1e-4)
            .map_err(|e| format!("output {i}: {e}"))?;
    }
    Ok(())
}

#[test]
fn prop_random_cnn_matches_interp_across_sparsity() {
    Cases::new(24).seed(0xE0).run(|rng, size| {
        let mut g = random_cnn(rng, size);
        let sparsity = rng.f64() * 0.9;
        prune_graph(&mut g, sparsity);
        let g = if rng.chance(0.5) { optimize(&g).0 } else { g };
        check_equivalence(&g, &random_options(rng), rng)
            .map_err(|e| format!("sparsity {sparsity:.2}: {e}"))
    });
}

#[test]
fn prop_resnet_block_matches_interp_across_sparsity() {
    Cases::new(24).seed(0xE1).run(|rng, _size| {
        let mut g = random_resnet_block(rng);
        let sparsity = rng.f64() * 0.9;
        prune_graph(&mut g, sparsity);
        let g = if rng.chance(0.5) { optimize(&g).0 } else { g };
        check_equivalence(&g, &random_options(rng), rng)
            .map_err(|e| format!("sparsity {sparsity:.2}: {e}"))
    });
}

/// Fusion must not fire when the conv's value is observed by a second
/// consumer (here: a residual Add reads the conv output directly).
#[test]
fn multi_consumer_conv_is_not_fused_incorrectly() {
    let mut b = NetBuilder::new(77);
    let x = b.input("input", 6, 6, 4);
    let c = b.conv("conv", &x, 3, 4, 4, 1, Padding::Same);
    let bi = b.bias("bias", &c, 4);
    let r = b.relu("relu", &bi);
    // second reader of the raw conv output
    let skip = b.g.op("skip", Op::Add, &[&c, &r]);
    b.g.outputs = vec![skip, c.clone()];
    let g = b.g;
    let mut rng = Rng::new(3);
    check_equivalence(&g, &PlanOptions::default(), &mut rng).unwrap();
}

/// Pipelined execution across stage counts {1, 2, 4} and sparsity
/// {0.0, 0.5, 0.9}: every image streamed through the pipeline must
/// match the interpreter oracle, for randomized CNNs and random plan
/// options (ISSUE 2 satellite).
#[test]
fn prop_pipeline_matches_interp_across_stage_counts_and_sparsity() {
    let mut case = 0u64;
    for &sparsity in &[0.0f64, 0.5, 0.9] {
        for &stages in &[1usize, 2, 4] {
            for rep in 0..2usize {
                case += 1;
                let mut rng = Rng::new(0xB1BE ^ case.wrapping_mul(0x9E3779B97F4A7C15));
                let mut g = random_cnn(&mut rng, rep + 1);
                prune_graph(&mut g, sparsity);
                let opts = random_options(&mut rng);
                let pipe = PipelinePlan::build(&g, &opts, stages).unwrap();
                let images: Vec<BTreeMap<String, Tensor>> =
                    (0..3).map(|_| g.random_feeds(&mut rng)).collect();
                let got = pipe.run_stream(&images).unwrap();
                for (i, fm) in images.iter().enumerate() {
                    let want = interp::run_outputs(&g, fm).unwrap();
                    assert_eq!(got[i].len(), want.len());
                    for (a, b) in got[i].iter().zip(&want) {
                        assert_eq!(a.shape, b.shape);
                        assert_close(&a.data, &b.data, 1e-5, 1e-4)
                            .map_err(|e| {
                                format!(
                                    "sparsity {sparsity} stages {stages} rep {rep} \
                                     image {i}: {e}"
                                )
                            })
                            .unwrap();
                    }
                }
            }
        }
    }
}

/// ResNet bottleneck blocks have skip paths whose values cross stage
/// cuts far from where they were produced — the hard case for the
/// boundary-liveness analysis (§V-C's skip-path buffering in hardware).
#[test]
fn prop_pipeline_resnet_block_matches_interp() {
    for (case, &stages) in [2usize, 3, 4].iter().enumerate() {
        let mut rng = Rng::new(0x5C1B + case as u64);
        let mut g = random_resnet_block(&mut rng);
        prune_graph(&mut g, 0.5);
        let pipe = PipelinePlan::build(&g, &PlanOptions::default(), stages).unwrap();
        let images: Vec<BTreeMap<String, Tensor>> =
            (0..4).map(|_| g.random_feeds(&mut rng)).collect();
        let got = pipe.run_stream(&images).unwrap();
        for (i, fm) in images.iter().enumerate() {
            let want = interp::run_outputs(&g, fm).unwrap();
            for (a, b) in got[i].iter().zip(&want) {
                assert_eq!(a.shape, b.shape);
                assert_close(&a.data, &b.data, 1e-5, 1e-4)
                    .map_err(|e| format!("stages {stages} image {i}: {e}"))
                    .unwrap();
            }
        }
    }
}

/// Stress: many images in flight through a 4-stage pipeline. Per-image
/// outputs must equal the sequential plan's *bit for bit* — the same
/// kernels run in the same order, so any divergence is a race or a
/// boundary-handoff bug, not float noise.
#[test]
fn pipeline_stress_images_match_sequential_bitwise() {
    let mut g = tiny_cnn(NetConfig::test_scale());
    prune_graph(&mut g, 0.7);
    let seq = ExecutionPlan::build(&g).unwrap();
    let pipe = PipelinePlan::build(&g, &PlanOptions::default(), 4).unwrap();
    assert!(pipe.num_stages() > 1);
    let mut rng = Rng::new(0x57E5);
    let images: Vec<BTreeMap<String, Tensor>> =
        (0..64).map(|_| g.random_feeds(&mut rng)).collect();
    let got = pipe.run_stream(&images).unwrap();
    assert_eq!(got.len(), images.len());
    for (i, fm) in images.iter().enumerate() {
        let want = seq.run(fm).unwrap();
        for (a, b) in got[i].iter().zip(&want) {
            assert_eq!(a.shape, b.shape, "image {i}");
            assert_eq!(a.data, b.data, "image {i}");
        }
    }
}

/// Stack per-image feed maps into the `[B, ...]` feed block a batch-B
/// plan consumes.
fn batch_feeds(images: &[BTreeMap<String, Tensor>]) -> BTreeMap<String, Tensor> {
    let mut batched = BTreeMap::new();
    for name in images[0].keys() {
        let parts: Vec<&Tensor> = images.iter().map(|m| &m[name]).collect();
        batched.insert(name.clone(), Tensor::concat_batch(&parts));
    }
    batched
}

/// Tentpole acceptance (ISSUE 3 + 4): a batch-B plan must equal B
/// sequential batch-1 runs of the same plan options — across
/// B ∈ {1, 3, 8} × sparsity {0.0, 0.5, 0.9} on randomized CNNs.
///
/// Which bar applies is documented by construction (ISSUE 4 satellite):
/// when every conv/matmul takes the sparse kernel
/// (`sparse_threshold == 0.0`) the comparison is **bitwise** — sparse
/// per-channel accumulators walk a plan-time-fixed entry order that
/// batching cannot perturb. Plans with dense-conv paths are held to a
/// [`DENSE_ULPS`] **ULP bound** instead: the register-tiled microkernel
/// owns its accumulation layout, and rounding-level closeness (not bit
/// equality) is the cross-batch contract.
#[test]
fn prop_batched_plan_matches_sequential() {
    let mut case = 0u64;
    for &sparsity in &[0.0f64, 0.5, 0.9] {
        for &batch in &[1usize, 3, 8] {
            case += 1;
            let mut rng = Rng::new(0xBA7C4ED ^ case.wrapping_mul(0x9E3779B97F4A7C15));
            let mut g = random_cnn(&mut rng, case as usize % 3);
            prune_graph(&mut g, sparsity);
            let opts = random_options(&mut rng);
            let all_sparse = opts.sparse_threshold == 0.0;
            let plan1 = ExecutionPlan::build_with(&g, &opts).unwrap();
            let planb = ExecutionPlan::build_with(&g, &opts.with_batch(batch)).unwrap();
            assert_eq!(planb.batch(), batch);
            let images: Vec<BTreeMap<String, Tensor>> =
                (0..batch).map(|_| g.random_feeds(&mut rng)).collect();
            let got = planb.run(&batch_feeds(&images)).unwrap();
            let want: Vec<Vec<Tensor>> = images.iter().map(|m| plan1.run(m).unwrap()).collect();
            for (oi, out) in got.iter().enumerate() {
                assert_eq!(out.shape[0], batch * want[0][oi].shape[0]);
                let per = out.data.len() / batch;
                for (b, w) in want.iter().enumerate() {
                    let (a, e) = (&out.data[b * per..(b + 1) * per], &w[oi].data[..]);
                    if all_sparse {
                        assert_eq!(
                            a, e,
                            "sparsity {sparsity} batch {batch} output {oi} image {b}"
                        );
                    } else {
                        assert_ulp_close(a, e, DENSE_ULPS)
                            .map_err(|err| {
                                format!(
                                    "sparsity {sparsity} batch {batch} output {oi} \
                                     image {b}: {err}"
                                )
                            })
                            .unwrap();
                    }
                }
            }
        }
    }
}

/// Batched ResNet bottleneck blocks: residual Adds, folded batch norms,
/// standalone Pads and projection shortcuts. Default options mix dense
/// and sparse convs, so the cross-batch bar is the dense ULP bound (the
/// comparison was bitwise under the PR 3 axpy kernels; the register-
/// tiled microkernel owns its accumulation layout — see module docs).
#[test]
fn prop_batched_resnet_block_matches_sequential_within_ulps() {
    for (case, &batch) in [2usize, 4].iter().enumerate() {
        let mut rng = Rng::new(0xB10C + case as u64);
        let mut g = random_resnet_block(&mut rng);
        prune_graph(&mut g, 0.6);
        let plan1 = ExecutionPlan::build(&g).unwrap();
        let planb = ExecutionPlan::build_batched(&g, batch).unwrap();
        let images: Vec<BTreeMap<String, Tensor>> =
            (0..batch).map(|_| g.random_feeds(&mut rng)).collect();
        let got = planb.run(&batch_feeds(&images)).unwrap();
        for (oi, out) in got.iter().enumerate() {
            let per = out.data.len() / batch;
            for (b, m) in images.iter().enumerate() {
                let want = plan1.run(m).unwrap();
                assert_ulp_close(
                    &out.data[b * per..(b + 1) * per],
                    &want[oi].data[..],
                    DENSE_ULPS,
                )
                .map_err(|e| format!("batch {batch} output {oi} image {b}: {e}"))
                .unwrap();
            }
        }
    }
}

/// A batched Add that reads a *folded constant* (per-image shape) must
/// see it tiled across the batch, not zipped short.
#[test]
fn batched_plan_tiles_folded_consts_across_batch() {
    let mut g = Graph::new();
    let mut rng = Rng::new(0x71_1E);
    g.op("input", Op::Placeholder { shape: vec![1, 4, 4, 2] }, &[]);
    g.constant("cx", Tensor::randn(&[1, 4, 4, 2], &mut rng, 1.0));
    g.constant("w", Tensor::randn(&[1, 1, 2, 2], &mut rng, 1.0));
    g.op(
        "cconv",
        Op::Conv2D { stride: (1, 1), padding: Padding::Same },
        &["cx", "w"],
    );
    g.op("crelu", Op::Relu, &["cconv"]);
    g.op("sum", Op::Add, &["input", "crelu"]);
    g.outputs = vec!["sum".into()];
    let plan1 = ExecutionPlan::build(&g).unwrap();
    let planb = ExecutionPlan::build_batched(&g, 3).unwrap();
    let images: Vec<BTreeMap<String, Tensor>> =
        (0..3).map(|_| g.random_feeds(&mut rng)).collect();
    let got = planb.run(&batch_feeds(&images)).unwrap();
    let per = got[0].data.len() / 3;
    assert_ne!(per, 0);
    for (b, m) in images.iter().enumerate() {
        let want = plan1.run(m).unwrap();
        assert_eq!(&got[0].data[b * per..(b + 1) * per], &want[0].data[..], "image {b}");
    }
}

/// Batched depthwise convolution (MobileNet-style separable block).
#[test]
fn batched_depthwise_matches_sequential_bitwise() {
    let mut g = Graph::new();
    let mut rng = Rng::new(0xD47);
    g.op("input", Op::Placeholder { shape: vec![1, 8, 8, 4] }, &[]);
    g.constant("dw", Tensor::randn(&[3, 3, 4, 2], &mut rng, 0.3));
    g.constant("db", Tensor::randn(&[8], &mut rng, 0.1));
    g.op(
        "depthwise",
        Op::DepthwiseConv2d { stride: (2, 2), padding: Padding::Same },
        &["input", "dw"],
    );
    g.op("bias", Op::BiasAdd, &["depthwise", "db"]);
    g.op("relu", Op::Relu6, &["bias"]);
    g.outputs = vec!["relu".into()];
    let plan1 = ExecutionPlan::build(&g).unwrap();
    let planb = ExecutionPlan::build_batched(&g, 5).unwrap();
    let images: Vec<BTreeMap<String, Tensor>> =
        (0..5).map(|_| g.random_feeds(&mut rng)).collect();
    let got = planb.run(&batch_feeds(&images)).unwrap();
    let per = got[0].data.len() / 5;
    for (b, m) in images.iter().enumerate() {
        let want = plan1.run(m).unwrap();
        assert_eq!(&got[0].data[b * per..(b + 1) * per], &want[0].data[..], "image {b}");
    }
}

/// Batched groups through the multi-stage pipeline (ISSUE 3 satellite
/// stress test): 16 groups of 3 images stream through a 4-stage
/// pipeline built over a batch-3 plan — each boundary handoff carries a
/// whole batched tensor set — and every image must match the sequential
/// batch-1 plan. Cross-batch comparison on a mixed dense/sparse graph,
/// so the dense ULP bound applies (see module docs).
#[test]
fn batched_pipeline_stress_matches_sequential_within_ulps() {
    let mut g = tiny_cnn(NetConfig::test_scale());
    prune_graph(&mut g, 0.7);
    let seq = ExecutionPlan::build(&g).unwrap();
    let (b, groups) = (3usize, 16usize);
    let pipe = PipelinePlan::build(&g, &PlanOptions::batched(b), 4).unwrap();
    assert_eq!(pipe.plan().batch(), b);
    assert!(pipe.num_stages() > 1);
    let in_shape = match &g.get("input").unwrap().op {
        Op::Placeholder { shape } => shape.clone(),
        _ => unreachable!(),
    };
    let per: usize = in_shape.iter().product();
    let mut rng = Rng::new(0x57E55);
    let n_images = b * groups;
    let input: Vec<f32> = (0..n_images * per).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let outs = pipe.run_batch(&input, n_images).unwrap();
    for i in 0..n_images {
        let mut feeds = BTreeMap::new();
        feeds.insert(
            "input".to_string(),
            Tensor::from_vec(&in_shape, input[i * per..(i + 1) * per].to_vec()),
        );
        let want = seq.run(&feeds).unwrap();
        for (oi, w) in want.iter().enumerate() {
            let po = w.data.len();
            assert_ulp_close(&outs[oi][i * po..(i + 1) * po], &w.data[..], DENSE_ULPS)
                .map_err(|e| format!("image {i} output {oi}: {e}"))
                .unwrap();
        }
    }
}

/// Partial groups can't stream: a batch-4 plan refuses 6 images.
#[test]
fn pipeline_run_batch_rejects_partial_groups() {
    let g = tiny_cnn(NetConfig::test_scale());
    let pipe = PipelinePlan::build(&g, &PlanOptions::batched(4), 2).unwrap();
    let in_shape = match &g.get("input").unwrap().op {
        Op::Placeholder { shape } => shape.clone(),
        _ => unreachable!(),
    };
    let per: usize = in_shape.iter().product();
    assert!(pipe.run_batch(&vec![0.0; 6 * per], 6).is_err());
    assert!(pipe.run_batch(&vec![0.0; 4 * per], 0).is_err());
}

/// The prepacked kernels (ISSUE 4) vs the PR 3 baseline kernels: packed
/// sparse entries are k-sorted while the baseline walks stream order, so
/// this comparison is FP-tolerance (reordered sums), not bitwise — but
/// both must match on every randomized graph × sparsity × plan option.
#[test]
fn prop_packed_plan_matches_unpacked_baseline() {
    Cases::new(18).seed(0xE4).run(|rng, size| {
        let mut g = random_cnn(rng, size);
        let sparsity = rng.f64() * 0.9;
        prune_graph(&mut g, sparsity);
        let opts = random_options(rng);
        let baseline_opts = PlanOptions { packed: false, ..opts };
        let packed = ExecutionPlan::build_with(&g, &opts).map_err(|e| e.to_string())?;
        let baseline =
            ExecutionPlan::build_with(&g, &baseline_opts).map_err(|e| e.to_string())?;
        let feeds = g.random_feeds(rng);
        let got = packed.run(&feeds).map_err(|e| e.to_string())?;
        let want = baseline.run(&feeds).map_err(|e| e.to_string())?;
        for (i, (a, b)) in got.iter().zip(&want).enumerate() {
            if a.shape != b.shape {
                return Err(format!("output {i} shape {:?} vs {:?}", a.shape, b.shape));
            }
            assert_close(&a.data, &b.data, 1e-5, 1e-4)
                .map_err(|e| format!("sparsity {sparsity:.2} output {i}: {e}"))?;
        }
        Ok(())
    });
}

/// ISSUE 4 tentpole: intra-stage worker teams split conv/matmul output
/// rows across scoped threads with per-element accumulation order
/// unchanged, so pipelined-with-team execution must match the
/// sequential plan **bit for bit** across stage counts, team sizes and
/// sparsity levels (bitwise bar — see module docs).
#[test]
fn team_pipeline_stress_matches_sequential_bitwise() {
    for &(stages, team, sparsity) in
        &[(1usize, 3usize, 0.0f64), (2, 2, 0.5), (4, 2, 0.9), (4, 4, 0.7)]
    {
        let mut g = tiny_cnn(NetConfig::test_scale());
        prune_graph(&mut g, sparsity);
        let seq = ExecutionPlan::build(&g).unwrap();
        let pipe =
            PipelinePlan::from_plan_team(ExecutionPlan::build(&g).unwrap(), stages, team);
        assert_eq!(pipe.team(), team);
        assert!(!pipe.team_steps().is_empty(), "no steps marked for the team");
        let mut rng = Rng::new(0x7E44 ^ (stages as u64) ^ ((team as u64) << 8));
        let images: Vec<BTreeMap<String, Tensor>> =
            (0..12).map(|_| g.random_feeds(&mut rng)).collect();
        let got = pipe.run_stream(&images).unwrap();
        for (i, fm) in images.iter().enumerate() {
            let want = seq.run(fm).unwrap();
            for (a, b) in got[i].iter().zip(&want) {
                assert_eq!(a.shape, b.shape);
                assert_eq!(
                    a.data, b.data,
                    "stages={stages} team={team} sparsity={sparsity} image={i}"
                );
            }
        }
    }
}

/// All three axes composed: a batch-2 plan, 3 pipeline stages and a
/// 2-thread worker team on the dominant stage. Identical plan on both
/// sides (team changes nothing per element), so the bar is bitwise.
#[test]
fn batched_team_pipeline_matches_sequential_bitwise() {
    let mut g = tiny_cnn(NetConfig::test_scale());
    prune_graph(&mut g, 0.7);
    let b = 2usize;
    let seq = ExecutionPlan::build_batched(&g, b).unwrap();
    let pipe = PipelinePlan::from_plan_team(ExecutionPlan::build_batched(&g, b).unwrap(), 3, 2);
    let in_shape = match &g.get("input").unwrap().op {
        Op::Placeholder { shape } => shape.clone(),
        _ => unreachable!(),
    };
    let per: usize = in_shape.iter().product();
    let mut bshape = in_shape.clone();
    bshape[0] = b;
    let (groups, n_images) = (4usize, 4 * b);
    let mut rng = Rng::new(0xB7EA);
    let input: Vec<f32> = (0..n_images * per).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let outs = pipe.run_batch(&input, n_images).unwrap();
    for gi in 0..groups {
        let mut feeds = BTreeMap::new();
        feeds.insert(
            "input".to_string(),
            Tensor::from_vec(&bshape, input[gi * b * per..(gi + 1) * b * per].to_vec()),
        );
        let want = seq.run(&feeds).unwrap();
        for (oi, w) in want.iter().enumerate() {
            let po = w.data.len();
            assert_eq!(
                &outs[oi][gi * po..(gi + 1) * po],
                &w.data[..],
                "group {gi} output {oi}"
            );
        }
    }
}

/// ISSUE 5 tentpole invariance: a stage cut is a *scheduling* decision,
/// never a numerical one. Pipelines cut from **arbitrary** measured-cost
/// profiles (random synthetic [`StepProfile`]s — the adversarial stand-in
/// for whatever a real profiling pass measures) × team {1, 2, 4} × batch
/// {1, 3, 8} × sparsity {0.0, 0.5, 0.9} must match the same-batch
/// sequential plan **bit for bit**: identical kernels in identical
/// per-element order on both sides, whatever the cuts.
#[test]
fn prop_tuned_cuts_match_sequential_bitwise() {
    let mut case = 0u64;
    for &sparsity in &[0.0f64, 0.5, 0.9] {
        for &batch in &[1usize, 3, 8] {
            for &team in &[1usize, 2, 4] {
                case += 1;
                let mut rng = Rng::new(0x7C4ED ^ case.wrapping_mul(0x9E3779B97F4A7C15));
                let mut g = tiny_cnn(NetConfig::test_scale());
                prune_graph(&mut g, sparsity);
                let seq = ExecutionPlan::build_batched(&g, batch).unwrap();
                let plan = ExecutionPlan::build_batched(&g, batch).unwrap();
                let n_steps = plan.step_names().len();
                // arbitrary "measured" costs — any cut must be harmless
                let costs: Vec<u64> =
                    (0..n_steps).map(|_| 1 + rng.below(1_000) as u64).collect();
                let profile = StepProfile::synthetic(&plan, costs);
                let stages = 1 + rng.below(4);
                let pipe = PipelinePlan::from_profile(plan, &profile, stages, team);
                let in_shape = match &g.get("input").unwrap().op {
                    Op::Placeholder { shape } => shape.clone(),
                    _ => unreachable!(),
                };
                let per: usize = in_shape.iter().product();
                let mut bshape = in_shape.clone();
                bshape[0] = batch;
                let (groups, n_images) = (3usize, 3 * batch);
                let input: Vec<f32> =
                    (0..n_images * per).map(|_| rng.normal_f32(0.0, 1.0)).collect();
                let outs = pipe.run_batch(&input, n_images).unwrap();
                for gi in 0..groups {
                    let mut feeds = BTreeMap::new();
                    feeds.insert(
                        "input".to_string(),
                        Tensor::from_vec(
                            &bshape,
                            input[gi * batch * per..(gi + 1) * batch * per].to_vec(),
                        ),
                    );
                    let want = seq.run(&feeds).unwrap();
                    for (oi, w) in want.iter().enumerate() {
                        let po = w.data.len();
                        assert_eq!(
                            &outs[oi][gi * po..(gi + 1) * po],
                            &w.data[..],
                            "sparsity={sparsity} batch={batch} team={team} \
                             stages={stages} group={gi} output={oi}"
                        );
                    }
                }
            }
        }
    }
}

/// The real tuner end to end (profile → choose → cut → serve): its
/// chosen configuration is held to the same bitwise bar.
#[test]
fn tuner_chosen_cuts_execute_bitwise() {
    let mut g = tiny_cnn(NetConfig::test_scale());
    prune_graph(&mut g, 0.7);
    let seq = ExecutionPlan::build(&g).unwrap();
    let plan = ExecutionPlan::build(&g).unwrap();
    let opts = TuneOptions {
        cores: 4,
        profile: ProfileOptions { warmup: 1, runs: 2, ..Default::default() },
    };
    let (profile, cuts) = tune_plan(&plan, &opts);
    let pipe = PipelinePlan::from_profile(plan, &profile, cuts.stages, cuts.team);
    assert_eq!(pipe.num_stages(), cuts.stages);
    assert_eq!(pipe.team(), cuts.team);
    let mut rng = Rng::new(0x7D3);
    let images: Vec<BTreeMap<String, Tensor>> =
        (0..8).map(|_| g.random_feeds(&mut rng)).collect();
    let got = pipe.run_stream(&images).unwrap();
    for (i, fm) in images.iter().enumerate() {
        let want = seq.run(fm).unwrap();
        for (a, b) in got[i].iter().zip(&want) {
            assert_eq!(a.shape, b.shape);
            assert_eq!(a.data, b.data, "stages={} team={} image={i}", cuts.stages, cuts.team);
        }
    }
}

/// ISSUE 8 tentpole bar: ragged-tail routing must be *invisible* in the
/// outputs. A drained tail of k < B images served through the smallest
/// plan-family variant whose batch fits (zero-padded up to that
/// variant, [`Tensor::pad_batch`]) must equal the padded-to-B
/// baseline's first k images **bit for bit**, across k × sparsity
/// {0.0, 0.5, 0.9}. Batched kernels never mix accumulation across
/// images (the cross-batch invariance the batch tests above pin), so
/// zero-pad rows cannot perturb the real images — which is exactly what
/// lets `runtime::LoadedModel::run_tail` pick whichever variant fits
/// without changing any answer.
#[test]
fn prop_ragged_tail_variant_matches_padded_baseline_bitwise() {
    const B: usize = 8;
    const FAMILY: [usize; 3] = [2, 4, B];
    for &sparsity in &[0.0f64, 0.5, 0.9] {
        let mut g = tiny_cnn(NetConfig::test_scale());
        prune_graph(&mut g, sparsity);
        let in_shape = match &g.get("input").unwrap().op {
            Op::Placeholder { shape } => shape.clone(),
            _ => unreachable!(),
        };
        let per: usize = in_shape.iter().product();
        let plans: BTreeMap<usize, ExecutionPlan> = FAMILY
            .iter()
            .map(|&vb| (vb, ExecutionPlan::build_batched(&g, vb).unwrap()))
            .collect();
        // run a k-image tail zero-padded up to the vb-batch plan
        let run_padded = |vb: usize, tail: &[f32]| -> Vec<Vec<f32>> {
            let padded = Tensor::pad_batch(tail, per, vb);
            let mut bshape = in_shape.clone();
            bshape[0] = vb;
            let mut feeds = BTreeMap::new();
            feeds.insert("input".to_string(), Tensor::from_vec(&bshape, padded));
            plans[&vb].run(&feeds).unwrap().into_iter().map(|t| t.data).collect()
        };
        let mut rng = Rng::new(0x7A11 ^ (sparsity * 10.0) as u64);
        for &k in &[1usize, 2, 3, 4, 5, 7] {
            let tail: Vec<f32> = (0..k * per).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let vb = FAMILY.into_iter().find(|&v| v >= k).unwrap();
            let via_variant = run_padded(vb, &tail);
            let baseline = run_padded(B, &tail);
            assert_eq!(via_variant.len(), baseline.len());
            for (oi, (a, b)) in via_variant.iter().zip(&baseline).enumerate() {
                let (pa, pb) = (a.len() / vb, b.len() / B);
                assert_eq!(pa, pb, "per-image output size, output {oi}");
                assert_eq!(
                    &a[..k * pa],
                    &b[..k * pb],
                    "sparsity={sparsity} k={k} variant_batch={vb} output={oi}"
                );
            }
        }
    }
}

/// Sparsity extremes: fully dense weights through the sparse kernel and
/// 90%-pruned weights through the dense kernel must both still match.
#[test]
fn kernel_choice_never_changes_results() {
    let mut rng = Rng::new(11);
    for sparsity in [0.0, 0.9] {
        let mut g = random_cnn(&mut rng, 2);
        prune_graph(&mut g, sparsity);
        for opts in [PlanOptions::dense_only(), PlanOptions::sparse_always()] {
            check_equivalence(&g, &opts, &mut rng)
                .map_err(|e| format!("sparsity {sparsity}: {e}"))
                .unwrap();
        }
    }
}
