//! Equivalence property test: the compiled executor must match the
//! reference-interpreter oracle (≤ 1e-4 relative) on randomized
//! TinyCNN-style and ResNet-block graphs, across sparsity levels
//! 0.0–0.9, across plan options (dense/sparse kernels, fusion on/off,
//! RLE split counts), and both before and after the transform passes.
//! The layer-pipelined executor is held to a harder bar: across stage
//! counts it must match the *sequential plan bit for bit* (same kernels
//! in the same order), and match the interpreter to the same tolerance.

use hpipe::exec::{ExecutionPlan, PipelinePlan, PlanOptions};
use hpipe::graph::{Graph, Op, Padding, Tensor};
use hpipe::interp;
use hpipe::nets::{tiny_cnn, NetBuilder, NetConfig};
use hpipe::sparsity::prune_graph;
use hpipe::transform::optimize;
use hpipe::util::prop::{assert_close, Cases};
use hpipe::util::Rng;
use std::collections::BTreeMap;

/// Randomized small CNN: conv+bias+relu stages with random widths,
/// strides and optional pools, then GAP -> FC -> softmax.
fn random_cnn(rng: &mut Rng, size: usize) -> Graph {
    let mut b = NetBuilder::new(rng.next_u64());
    let mut h = 8 + (size % 3) * 4; // 8 / 12 / 16
    let c0 = 2 + rng.below(3);
    let x = b.input("input", h, h, c0);
    let mut prev = x;
    let mut cin = c0;
    let depth = 1 + rng.below(3);
    for i in 0..depth {
        let cout = 4 * (1 + rng.below(3));
        let stride = 1 + rng.below(2);
        let c = b.conv(&format!("conv{i}"), &prev, 3, cin, cout, stride, Padding::Same);
        h = h.div_ceil(stride);
        let bi = b.bias(&format!("conv{i}/biasadd"), &c, cout);
        prev = b.relu(&format!("conv{i}/relu"), &bi);
        if h >= 2 && rng.chance(0.5) {
            prev = b.g.op(
                &format!("pool{i}"),
                Op::MaxPool { ksize: (2, 2), stride: (2, 2), padding: Padding::Valid },
                &[&prev],
            );
            h = (h - 2) / 2 + 1;
        }
        cin = cout;
    }
    b.head(&prev, cin, 5);
    b.g
}

/// Randomized ResNet bottleneck block (BN after every conv, optional
/// projection shortcut with stride, Add + Relu), preceded by a
/// standalone Pad half the time so pad-merging paths get exercised.
fn random_resnet_block(rng: &mut Rng) -> Graph {
    let mut b = NetBuilder::new(rng.next_u64());
    let hw = 8;
    let cin = 8 * (1 + rng.below(2));
    let mid = 4 * (1 + rng.below(2));
    let x = b.input("input", hw, hw, cin);
    let stem = if rng.chance(0.5) {
        let p = b.g.op("stem_pad", Op::Pad { pads: (1, 1, 1, 1) }, &[&x]);
        let c = b.conv("stem", &p, 3, cin, cin, 1, Padding::Valid);
        let bn = b.bn("stem_bn", &c, cin);
        b.relu("stem_relu", &bn)
    } else {
        x
    };
    let use_proj = rng.chance(0.5);
    let (stride, out_c) = if use_proj {
        (1 + rng.below(2), 8 * (1 + rng.below(2)))
    } else {
        (1, cin)
    };
    let shortcut = if use_proj {
        let sc = b.conv("proj", &stem, 1, cin, out_c, stride, Padding::Same);
        b.bn("proj_bn", &sc, out_c)
    } else {
        stem.clone()
    };
    let c_a = b.conv("branch2a", &stem, 1, cin, mid, stride, Padding::Same);
    let bn_a = b.bn("bn2a", &c_a, mid);
    let r_a = b.relu("relu2a", &bn_a);
    let c_b = b.conv("branch2b", &r_a, 3, mid, mid, 1, Padding::Same);
    let bn_b = b.bn("bn2b", &c_b, mid);
    let r_b = b.relu("relu2b", &bn_b);
    let c_c = b.conv("branch2c", &r_b, 1, mid, out_c, 1, Padding::Same);
    let bn_c = b.bn("bn2c", &c_c, out_c);
    let add = b.g.op("res_add", Op::Add, &[&shortcut, &bn_c]);
    let out = b.relu("res_relu", &add);
    b.g.outputs = vec![out];
    b.g
}

fn random_options(rng: &mut Rng) -> PlanOptions {
    PlanOptions {
        sparse_threshold: *rng.choose(&[0.0, 0.3, 0.5, 2.0]),
        fuse: rng.chance(0.8),
        splits: 1 + rng.below(4),
    }
}

fn check_equivalence(g: &Graph, opts: &PlanOptions, rng: &mut Rng) -> Result<(), String> {
    let plan = ExecutionPlan::build_with(g, opts).map_err(|e| e.to_string())?;
    let feeds = g.random_feeds(rng);
    let got = plan.run(&feeds).map_err(|e| e.to_string())?;
    let want = interp::run_outputs(g, &feeds).map_err(|e| e.to_string())?;
    if got.len() != want.len() {
        return Err(format!("output count {} vs {}", got.len(), want.len()));
    }
    for (i, (a, b)) in got.iter().zip(&want).enumerate() {
        if a.shape != b.shape {
            return Err(format!("output {i} shape {:?} vs {:?}", a.shape, b.shape));
        }
        assert_close(&a.data, &b.data, 1e-5, 1e-4)
            .map_err(|e| format!("output {i}: {e}"))?;
    }
    Ok(())
}

#[test]
fn prop_random_cnn_matches_interp_across_sparsity() {
    Cases::new(24).seed(0xE0).run(|rng, size| {
        let mut g = random_cnn(rng, size);
        let sparsity = rng.f64() * 0.9;
        prune_graph(&mut g, sparsity);
        let g = if rng.chance(0.5) { optimize(&g).0 } else { g };
        check_equivalence(&g, &random_options(rng), rng)
            .map_err(|e| format!("sparsity {sparsity:.2}: {e}"))
    });
}

#[test]
fn prop_resnet_block_matches_interp_across_sparsity() {
    Cases::new(24).seed(0xE1).run(|rng, _size| {
        let mut g = random_resnet_block(rng);
        let sparsity = rng.f64() * 0.9;
        prune_graph(&mut g, sparsity);
        let g = if rng.chance(0.5) { optimize(&g).0 } else { g };
        check_equivalence(&g, &random_options(rng), rng)
            .map_err(|e| format!("sparsity {sparsity:.2}: {e}"))
    });
}

/// Fusion must not fire when the conv's value is observed by a second
/// consumer (here: a residual Add reads the conv output directly).
#[test]
fn multi_consumer_conv_is_not_fused_incorrectly() {
    let mut b = NetBuilder::new(77);
    let x = b.input("input", 6, 6, 4);
    let c = b.conv("conv", &x, 3, 4, 4, 1, Padding::Same);
    let bi = b.bias("bias", &c, 4);
    let r = b.relu("relu", &bi);
    // second reader of the raw conv output
    let skip = b.g.op("skip", Op::Add, &[&c, &r]);
    b.g.outputs = vec![skip, c.clone()];
    let g = b.g;
    let mut rng = Rng::new(3);
    check_equivalence(&g, &PlanOptions::default(), &mut rng).unwrap();
}

/// Pipelined execution across stage counts {1, 2, 4} and sparsity
/// {0.0, 0.5, 0.9}: every image streamed through the pipeline must
/// match the interpreter oracle, for randomized CNNs and random plan
/// options (ISSUE 2 satellite).
#[test]
fn prop_pipeline_matches_interp_across_stage_counts_and_sparsity() {
    let mut case = 0u64;
    for &sparsity in &[0.0f64, 0.5, 0.9] {
        for &stages in &[1usize, 2, 4] {
            for rep in 0..2usize {
                case += 1;
                let mut rng = Rng::new(0xB1BE ^ case.wrapping_mul(0x9E3779B97F4A7C15));
                let mut g = random_cnn(&mut rng, rep + 1);
                prune_graph(&mut g, sparsity);
                let opts = random_options(&mut rng);
                let pipe = PipelinePlan::build(&g, &opts, stages).unwrap();
                let images: Vec<BTreeMap<String, Tensor>> =
                    (0..3).map(|_| g.random_feeds(&mut rng)).collect();
                let got = pipe.run_stream(&images).unwrap();
                for (i, fm) in images.iter().enumerate() {
                    let want = interp::run_outputs(&g, fm).unwrap();
                    assert_eq!(got[i].len(), want.len());
                    for (a, b) in got[i].iter().zip(&want) {
                        assert_eq!(a.shape, b.shape);
                        assert_close(&a.data, &b.data, 1e-5, 1e-4)
                            .map_err(|e| {
                                format!(
                                    "sparsity {sparsity} stages {stages} rep {rep} \
                                     image {i}: {e}"
                                )
                            })
                            .unwrap();
                    }
                }
            }
        }
    }
}

/// ResNet bottleneck blocks have skip paths whose values cross stage
/// cuts far from where they were produced — the hard case for the
/// boundary-liveness analysis (§V-C's skip-path buffering in hardware).
#[test]
fn prop_pipeline_resnet_block_matches_interp() {
    for (case, &stages) in [2usize, 3, 4].iter().enumerate() {
        let mut rng = Rng::new(0x5C1B + case as u64);
        let mut g = random_resnet_block(&mut rng);
        prune_graph(&mut g, 0.5);
        let pipe = PipelinePlan::build(&g, &PlanOptions::default(), stages).unwrap();
        let images: Vec<BTreeMap<String, Tensor>> =
            (0..4).map(|_| g.random_feeds(&mut rng)).collect();
        let got = pipe.run_stream(&images).unwrap();
        for (i, fm) in images.iter().enumerate() {
            let want = interp::run_outputs(&g, fm).unwrap();
            for (a, b) in got[i].iter().zip(&want) {
                assert_eq!(a.shape, b.shape);
                assert_close(&a.data, &b.data, 1e-5, 1e-4)
                    .map_err(|e| format!("stages {stages} image {i}: {e}"))
                    .unwrap();
            }
        }
    }
}

/// Stress: many images in flight through a 4-stage pipeline. Per-image
/// outputs must equal the sequential plan's *bit for bit* — the same
/// kernels run in the same order, so any divergence is a race or a
/// boundary-handoff bug, not float noise.
#[test]
fn pipeline_stress_images_match_sequential_bitwise() {
    let mut g = tiny_cnn(NetConfig::test_scale());
    prune_graph(&mut g, 0.7);
    let seq = ExecutionPlan::build(&g).unwrap();
    let pipe = PipelinePlan::build(&g, &PlanOptions::default(), 4).unwrap();
    assert!(pipe.num_stages() > 1);
    let mut rng = Rng::new(0x57E5);
    let images: Vec<BTreeMap<String, Tensor>> =
        (0..64).map(|_| g.random_feeds(&mut rng)).collect();
    let got = pipe.run_stream(&images).unwrap();
    assert_eq!(got.len(), images.len());
    for (i, fm) in images.iter().enumerate() {
        let want = seq.run(fm).unwrap();
        for (a, b) in got[i].iter().zip(&want) {
            assert_eq!(a.shape, b.shape, "image {i}");
            assert_eq!(a.data, b.data, "image {i}");
        }
    }
}

/// Sparsity extremes: fully dense weights through the sparse kernel and
/// 90%-pruned weights through the dense kernel must both still match.
#[test]
fn kernel_choice_never_changes_results() {
    let mut rng = Rng::new(11);
    for sparsity in [0.0, 0.9] {
        let mut g = random_cnn(&mut rng, 2);
        prune_graph(&mut g, sparsity);
        for opts in [PlanOptions::dense_only(), PlanOptions::sparse_always()] {
            check_equivalence(&g, &opts, &mut rng)
                .map_err(|e| format!("sparsity {sparsity}: {e}"))
                .unwrap();
        }
    }
}
