//! Equivalence property test: the compiled executor must match the
//! reference-interpreter oracle (≤ 1e-4 relative) on randomized
//! TinyCNN-style and ResNet-block graphs, across sparsity levels
//! 0.0–0.9, across plan options (dense/sparse kernels, fusion on/off,
//! RLE split counts), and both before and after the transform passes.

use hpipe::exec::{ExecutionPlan, PlanOptions};
use hpipe::graph::{Graph, Op, Padding};
use hpipe::interp;
use hpipe::nets::NetBuilder;
use hpipe::sparsity::prune_graph;
use hpipe::transform::optimize;
use hpipe::util::prop::{assert_close, Cases};
use hpipe::util::Rng;
use std::collections::BTreeMap;

/// Randomized small CNN: conv+bias+relu stages with random widths,
/// strides and optional pools, then GAP -> FC -> softmax.
fn random_cnn(rng: &mut Rng, size: usize) -> Graph {
    let mut b = NetBuilder::new(rng.next_u64());
    let mut h = 8 + (size % 3) * 4; // 8 / 12 / 16
    let c0 = 2 + rng.below(3);
    let x = b.input("input", h, h, c0);
    let mut prev = x;
    let mut cin = c0;
    let depth = 1 + rng.below(3);
    for i in 0..depth {
        let cout = 4 * (1 + rng.below(3));
        let stride = 1 + rng.below(2);
        let c = b.conv(&format!("conv{i}"), &prev, 3, cin, cout, stride, Padding::Same);
        h = h.div_ceil(stride);
        let bi = b.bias(&format!("conv{i}/biasadd"), &c, cout);
        prev = b.relu(&format!("conv{i}/relu"), &bi);
        if h >= 2 && rng.chance(0.5) {
            prev = b.g.op(
                &format!("pool{i}"),
                Op::MaxPool { ksize: (2, 2), stride: (2, 2), padding: Padding::Valid },
                &[&prev],
            );
            h = (h - 2) / 2 + 1;
        }
        cin = cout;
    }
    b.head(&prev, cin, 5);
    b.g
}

/// Randomized ResNet bottleneck block (BN after every conv, optional
/// projection shortcut with stride, Add + Relu), preceded by a
/// standalone Pad half the time so pad-merging paths get exercised.
fn random_resnet_block(rng: &mut Rng) -> Graph {
    let mut b = NetBuilder::new(rng.next_u64());
    let hw = 8;
    let cin = 8 * (1 + rng.below(2));
    let mid = 4 * (1 + rng.below(2));
    let x = b.input("input", hw, hw, cin);
    let stem = if rng.chance(0.5) {
        let p = b.g.op("stem_pad", Op::Pad { pads: (1, 1, 1, 1) }, &[&x]);
        let c = b.conv("stem", &p, 3, cin, cin, 1, Padding::Valid);
        let bn = b.bn("stem_bn", &c, cin);
        b.relu("stem_relu", &bn)
    } else {
        x
    };
    let use_proj = rng.chance(0.5);
    let (stride, out_c) = if use_proj {
        (1 + rng.below(2), 8 * (1 + rng.below(2)))
    } else {
        (1, cin)
    };
    let shortcut = if use_proj {
        let sc = b.conv("proj", &stem, 1, cin, out_c, stride, Padding::Same);
        b.bn("proj_bn", &sc, out_c)
    } else {
        stem.clone()
    };
    let c_a = b.conv("branch2a", &stem, 1, cin, mid, stride, Padding::Same);
    let bn_a = b.bn("bn2a", &c_a, mid);
    let r_a = b.relu("relu2a", &bn_a);
    let c_b = b.conv("branch2b", &r_a, 3, mid, mid, 1, Padding::Same);
    let bn_b = b.bn("bn2b", &c_b, mid);
    let r_b = b.relu("relu2b", &bn_b);
    let c_c = b.conv("branch2c", &r_b, 1, mid, out_c, 1, Padding::Same);
    let bn_c = b.bn("bn2c", &c_c, out_c);
    let add = b.g.op("res_add", Op::Add, &[&shortcut, &bn_c]);
    let out = b.relu("res_relu", &add);
    b.g.outputs = vec![out];
    b.g
}

fn random_options(rng: &mut Rng) -> PlanOptions {
    PlanOptions {
        sparse_threshold: *rng.choose(&[0.0, 0.3, 0.5, 2.0]),
        fuse: rng.chance(0.8),
        splits: 1 + rng.below(4),
    }
}

fn check_equivalence(g: &Graph, opts: &PlanOptions, rng: &mut Rng) -> Result<(), String> {
    let plan = ExecutionPlan::build_with(g, opts).map_err(|e| e.to_string())?;
    let mut feeds = BTreeMap::new();
    for n in &g.nodes {
        if let Op::Placeholder { shape } = &n.op {
            feeds.insert(
                n.name.clone(),
                hpipe::graph::Tensor::randn(shape, rng, 1.0),
            );
        }
    }
    let got = plan.run(&feeds).map_err(|e| e.to_string())?;
    let want = interp::run_outputs(g, &feeds).map_err(|e| e.to_string())?;
    if got.len() != want.len() {
        return Err(format!("output count {} vs {}", got.len(), want.len()));
    }
    for (i, (a, b)) in got.iter().zip(&want).enumerate() {
        if a.shape != b.shape {
            return Err(format!("output {i} shape {:?} vs {:?}", a.shape, b.shape));
        }
        assert_close(&a.data, &b.data, 1e-5, 1e-4)
            .map_err(|e| format!("output {i}: {e}"))?;
    }
    Ok(())
}

#[test]
fn prop_random_cnn_matches_interp_across_sparsity() {
    Cases::new(24).seed(0xE0).run(|rng, size| {
        let mut g = random_cnn(rng, size);
        let sparsity = rng.f64() * 0.9;
        prune_graph(&mut g, sparsity);
        let g = if rng.chance(0.5) { optimize(&g).0 } else { g };
        check_equivalence(&g, &random_options(rng), rng)
            .map_err(|e| format!("sparsity {sparsity:.2}: {e}"))
    });
}

#[test]
fn prop_resnet_block_matches_interp_across_sparsity() {
    Cases::new(24).seed(0xE1).run(|rng, _size| {
        let mut g = random_resnet_block(rng);
        let sparsity = rng.f64() * 0.9;
        prune_graph(&mut g, sparsity);
        let g = if rng.chance(0.5) { optimize(&g).0 } else { g };
        check_equivalence(&g, &random_options(rng), rng)
            .map_err(|e| format!("sparsity {sparsity:.2}: {e}"))
    });
}

/// Fusion must not fire when the conv's value is observed by a second
/// consumer (here: a residual Add reads the conv output directly).
#[test]
fn multi_consumer_conv_is_not_fused_incorrectly() {
    let mut b = NetBuilder::new(77);
    let x = b.input("input", 6, 6, 4);
    let c = b.conv("conv", &x, 3, 4, 4, 1, Padding::Same);
    let bi = b.bias("bias", &c, 4);
    let r = b.relu("relu", &bi);
    // second reader of the raw conv output
    let skip = b.g.op("skip", Op::Add, &[&c, &r]);
    b.g.outputs = vec![skip, c.clone()];
    let g = b.g;
    let mut rng = Rng::new(3);
    check_equivalence(&g, &PlanOptions::default(), &mut rng).unwrap();
}

/// Sparsity extremes: fully dense weights through the sparse kernel and
/// 90%-pruned weights through the dense kernel must both still match.
#[test]
fn kernel_choice_never_changes_results() {
    let mut rng = Rng::new(11);
    for sparsity in [0.0, 0.9] {
        let mut g = random_cnn(&mut rng, 2);
        prune_graph(&mut g, sparsity);
        for opts in [PlanOptions::dense_only(), PlanOptions::sparse_always()] {
            check_equivalence(&g, &opts, &mut rng)
                .map_err(|e| format!("sparsity {sparsity}: {e}"))
                .unwrap();
        }
    }
}
