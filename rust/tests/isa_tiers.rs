//! Cross-tier kernel equivalence (ISSUE 7): every SIMD dispatch tier the
//! host CPU supports must compute the same answer as the scalar tier-0
//! baseline — bitwise for the sparse kernels and the non-fused dense
//! tiers, within 8 ulp for fused (FMA/NEON) dense tiers.
//!
//! These tests pin the kernel *edge tails* — M % MR, N % NR and K % KC
//! remainders, odd sparsity patterns, co not a multiple of OCB, position
//! counts straddling MT tiles — on every available tier via the
//! explicit `*_on` kernel entry points (the active tier is
//! process-global and the test binary is multi-threaded, so tests never
//! call `isa::force`). The CI `isa-matrix` job complements this from the
//! outside: it re-runs the whole suite under each `HPIPE_ISA`-forced
//! tier, and [`hpipe_isa_env_override_is_honored`] proves the forcing
//! actually took effect.

use hpipe::exec::isa;
use hpipe::exec::kernels::{
    gemm_panels_bias_act_on, pack_a, pack_b, packed_a_len, Act, KC, MR, NR,
};
use hpipe::exec::sparse::{
    pack_rle, sparse_matmul_packed, sparse_packed_rows_on, transpose_k_major, MT, OCB,
};
use hpipe::graph::Tensor;
use hpipe::sparsity::prune_tensor;
use hpipe::sparsity::rle::encode_matmul;
use hpipe::util::prop::{assert_ulp_close, Cases};
use hpipe::util::Rng;

/// Dense GEMM across every tier, with shapes chosen to hit all the
/// remainder paths: M % MR ∈ {0..MR-1} (pad rows in the last A-panel),
/// N % NR ∈ {0..NR-1} (pad lanes in the last B-panel), K crossing 0, 1
/// and 2 KC block boundaries, under several weight sparsities.
#[test]
fn dense_tiers_match_scalar_across_edge_tails() {
    let tiers = isa::available();
    assert_eq!(tiers[0].tier(), isa::Tier::Scalar);
    Cases::new(40).seed(0x15A7).run(|rng, size| {
        let m = 1 + (size * 3 + rng.below(4)) % (3 * MR + 2);
        let n = 1 + (size * 5 + rng.below(8)) % (2 * NR + 3);
        let k = 1 + rng.below(3) * KC + rng.below(17);
        let sparsity = *rng.choose(&[0.0, 0.5, 0.9, 0.97]);
        let a = Tensor::randn(&[m, k], rng, 1.0);
        let mut b = Tensor::randn(&[k, n], rng, 1.0);
        prune_tensor(&mut b, sparsity);
        let bias: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let act = *rng.choose(&[Act::None, Act::Relu, Act::Relu6]);
        let pb = pack_b(b.as_slice(), k, n);
        let mut ap = vec![0.0f32; packed_a_len(m, k)];
        pack_a(a.as_slice(), m, k, &mut ap);
        // scalar reference (tier 0) through the same panel walk
        let mut want = vec![0.0f32; m * n];
        gemm_panels_bias_act_on(tiers[0], &ap, &pb, m, Some(&bias), act, &mut want);
        for tier in &tiers[1..] {
            let mut got = vec![0.0f32; m * n];
            gemm_panels_bias_act_on(tier, &ap, &pb, m, Some(&bias), act, &mut got);
            if tier.fused_dense() {
                assert_ulp_close(&got, &want, 8)
                    .map_err(|e| format!("m={m} n={n} k={k} tier={}: {e}", tier.name()))?;
            } else if got != want {
                return Err(format!(
                    "m={m} n={n} k={k} sp={sparsity} tier={}: not bitwise-equal to scalar",
                    tier.name()
                ));
            }
        }
        Ok(())
    });
}

/// The sparse position-axis kernel must be *bitwise* scalar-equal on
/// every tier (no sparse tier fuses), across odd sparsity patterns,
/// bundle tails (co % OCB != 0) and position counts straddling MT tiles.
#[test]
fn sparse_tiers_are_bitwise_scalar_across_odd_patterns() {
    let tiers = isa::available();
    Cases::new(24).seed(0x5B1D).run(|rng, size| {
        let m = 1 + (size * 31 + rng.below(9)) % (2 * MT + 5);
        let ci = 1 + (size * 7 + rng.below(11)) % 53;
        let co = 1 + (size * 3 + rng.below(5)) % (3 * OCB + 2);
        let sparsity = *rng.choose(&[0.0, 0.5, 0.9, 0.97]);
        let mut w = Tensor::randn(&[ci, co], rng, 1.0);
        prune_tensor(&mut w, sparsity);
        let pr = pack_rle(&encode_matmul(&w, 1 + rng.below(3)));
        let bias: Vec<f32> = (0..co).map(|_| rng.normal_f32(0.0, 0.5)).collect();
        let act = *rng.choose(&[Act::None, Act::Relu]);
        // synthetic K-major patch matrix covering all m positions
        let patches: Vec<f32> = (0..ci * m).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let mut want = vec![0.0f32; m * co];
        sparse_packed_rows_on(tiers[0], &patches, m, 0, m, &pr, Some(&bias), act, &mut want);
        for tier in &tiers[1..] {
            let mut got = vec![0.0f32; m * co];
            sparse_packed_rows_on(tier, &patches, m, 0, m, &pr, Some(&bias), act, &mut got);
            if got != want {
                return Err(format!(
                    "m={m} ci={ci} co={co} sp={sparsity} tier={}: sparse not bitwise",
                    tier.name()
                ));
            }
        }
        // split ranges (the worker-team path) stay bitwise per tier too
        for tier in &tiers {
            let mut parts = vec![0.0f32; m * co];
            let mut m0 = 0usize;
            let split = 1 + rng.below(MT + 3);
            for chunk in parts.chunks_mut(split * co) {
                let rows = chunk.len() / co;
                sparse_packed_rows_on(
                    tier,
                    &patches,
                    m,
                    m0,
                    m0 + rows,
                    &pr,
                    Some(&bias),
                    act,
                    chunk,
                );
                m0 += rows;
            }
            if parts != want {
                return Err(format!(
                    "m={m} co={co} split={split} tier={}: team split not bitwise",
                    tier.name()
                ));
            }
        }
        Ok(())
    });
}

/// The transposed position-axis matmul path must agree bitwise with the
/// row-major baseline walk on every tier: both visit each (row, channel)
/// pair's bundle entries in the same plan-time order.
#[test]
fn transposed_matmul_path_matches_row_major_on_every_tier() {
    let mut rng = Rng::new(0x7125);
    let (n, ci, co) = (MT + 21, 40usize, 2 * OCB + 3);
    let mut w = Tensor::randn(&[ci, co], &mut rng, 1.0);
    prune_tensor(&mut w, 0.8);
    let pr = pack_rle(&encode_matmul(&w, 2));
    let x = Tensor::randn(&[n, ci], &mut rng, 1.0);
    let bias: Vec<f32> = (0..co).map(|_| rng.normal_f32(0.0, 0.5)).collect();
    let mut want = vec![0.0f32; n * co];
    sparse_matmul_packed(x.as_slice(), n, ci, co, &pr, Some(&bias), Act::Relu6, &mut want);
    let mut xt = vec![0.0f32; ci * n];
    transpose_k_major(x.as_slice(), n, ci, &mut xt);
    for tier in isa::available() {
        let mut got = vec![0.0f32; n * co];
        sparse_packed_rows_on(tier, &xt, n, 0, n, &pr, Some(&bias), Act::Relu6, &mut got);
        assert_eq!(got, want, "tier {}", tier.name());
    }
}

/// When the CI isa-matrix job exports `HPIPE_ISA=<tier>`, the process
/// must actually run that tier — a forced tier silently falling back to
/// native would make the whole matrix vacuous. Unset/`native` must
/// resolve to a supported tier.
#[test]
fn hpipe_isa_env_override_is_honored() {
    let active = isa::active();
    assert!(isa::supported(active.tier()), "active tier must be executable");
    match std::env::var("HPIPE_ISA") {
        Ok(v) if !v.is_empty() && v != "native" => {
            if let Ok(Some(requested)) = isa::Tier::parse(&v) {
                if isa::supported(requested) {
                    assert_eq!(
                        active.tier(),
                        requested,
                        "HPIPE_ISA={v} was set and supported but the active tier is {}",
                        active.name()
                    );
                } else {
                    // valid-but-unsupported requests degrade to scalar,
                    // never silently to native
                    assert_eq!(active.tier(), isa::Tier::Scalar);
                }
            }
        }
        _ => {} // native selection covered by the supported() assert
    }
}
