//! Fault-injection property tests (`--features fault-inject`).
//!
//! The robustness twin of `exec_equiv`: instead of proving the happy
//! path bitwise-correct, these tests inject deterministic panics and
//! latency into the serving hot paths (`util::fault`) and prove the
//! recovery contract:
//!
//! * a panic in ANY pipeline stage, across team sizes and plan batches,
//!   surfaces as a typed `GraphError::StageFault` for that run only —
//!   the pipeline never wedges and the plan stays reusable;
//! * repeated faults trip the faulting site's circuit breaker and the
//!   model bypasses that pipe with outputs bitwise-identical to the
//!   sequential oracle — sticky under `--no-recover`, and under the
//!   default self-healing ladder a transient fault recovers: trip,
//!   cool-down, HalfOpen probe answered from the oracle, un-degrade
//!   (the `chaos_transient_*` / `chaos_persistent_*` matrix);
//! * the persistent stage-worker pool survives a hundred faulty runs
//!   without leaking a single OS thread;
//! * end-to-end serving under injected faults completes with zero lost
//!   responses and the fault counters recorded in the `ServeReport`
//!   (the `chaos_` tests — CI runs them as the chaos smoke);
//! * injected batcher latency plus tight deadlines expires every
//!   request with a typed answer, never silence;
//! * the drain/execute overlap (a feeder thread between batcher and
//!   executor, on by default) must not wedge: stage faults plus a
//!   client hangup mid-batch still flush every request and produce the
//!   report.
//!
//! Without the feature this file compiles to an empty test binary.

#![cfg(feature = "fault-inject")]

use hpipe::coordinator::batcher::BatchPolicy;
use hpipe::coordinator::{serve_demo, Coordinator, Request, ServeConfig};
use hpipe::exec::{ExecutionPlan, PipelinePlan};
use hpipe::graph::{graphdef, GraphError, Op, Tensor};
use hpipe::nets::{tiny_cnn, NetConfig};
use hpipe::runtime::{LoadedModel, Runtime};
use hpipe::util::breaker::BreakerConfig;
use hpipe::util::fault;
use hpipe::util::{Json, Rng};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::{Mutex, MutexGuard};

// The fault harness is process-global: every test that arms real sites
// holds this gate for its whole body so concurrent test threads never
// see each other's fault plans.
static GATE: Mutex<()> = Mutex::new(());

fn gate() -> MutexGuard<'static, ()> {
    GATE.lock().unwrap_or_else(|e| e.into_inner())
}

/// The TinyCNN placeholder's per-image shape (leading dim 1).
fn input_shape(g: &hpipe::graph::Graph) -> Vec<usize> {
    match &g.get("input").expect("tinycnn has an input").op {
        Op::Placeholder { shape } => shape.clone(),
        _ => panic!("tinycnn input is not a placeholder"),
    }
}

fn det_input(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect()
}

/// Synthesize a serving artifact directory under `target/` (the
/// `e2e_serving` bench pattern): He-init TinyCNN graphdef + manifest
/// with batch-1 and batch-8 model entries.
fn synth_artifacts(subdir: &str) -> PathBuf {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("target").join(subdir);
    let g = tiny_cnn(NetConfig::test_scale());
    graphdef::save(&g, &dir.join("tinycnn")).expect("writing graphdef");
    let mut models = Json::obj();
    models
        .set("1", Json::from("tinycnn.graphdef"))
        .set("8", Json::from("tinycnn.graphdef"));
    let mut root = Json::obj();
    root.set("input_shape", Json::from(input_shape(&g)))
        .set("models", models)
        .set("kernels", Json::obj());
    std::fs::write(dir.join("manifest.json"), root.pretty()).expect("writing manifest");
    dir
}

/// Tentpole property: a panic injected into ANY stage, for every
/// (team, plan-batch) combination, fails that run with a typed
/// `StageFault` naming the stage — and the same `PipelinePlan` then
/// serves a clean run bitwise-identical to the pre-fault baseline.
#[test]
fn stage_panic_never_wedges_any_configuration() {
    let _g = gate();
    fault::silence_expected_panics();
    let g = tiny_cnn(NetConfig::test_scale());
    let per: usize = input_shape(&g).iter().product();
    let n_images = 8;
    let input = det_input(n_images * per, 0xFA17);
    for &team in &[1usize, 2, 4] {
        for &group in &[1usize, 2] {
            let plan = ExecutionPlan::build_batched(&g, group).unwrap();
            let pipe = PipelinePlan::from_plan_team(plan, 3, team);
            let clean = pipe.run_batch(&input, n_images).unwrap();
            for stage in 0..pipe.num_stages() {
                fault::arm(&format!("pipeline.stage#{stage}=1"));
                match pipe.run_batch(&input, n_images) {
                    Err(GraphError::StageFault { stage: s, msg, .. }) => {
                        assert_eq!(s, stage, "fault must name the faulting stage");
                        assert!(msg.contains("injected fault"), "unexpected fault: {msg}");
                    }
                    other => panic!(
                        "team {team} group {group} stage {stage}: expected StageFault, \
                         got {:?}",
                        other.map(|o| o.len())
                    ),
                }
                fault::disarm();
                let again = pipe.run_batch(&input, n_images).unwrap();
                assert_eq!(again, clean, "plan must stay reusable after an isolated fault");
            }
        }
    }
}

/// The degrade ladder end to end under `--no-recover` (the sticky
/// escape hatch): one transient fault is absorbed by the retry; a
/// persistent fault trips the faulting site's breaker and the model
/// bypasses the pipe — permanently, since probes are disabled — with
/// outputs bitwise-identical to the per-image sequential oracle.
#[test]
fn repeated_faults_degrade_to_bitwise_sequential_fallback() {
    let _g = gate();
    fault::silence_expected_panics();
    let g = tiny_cnn(NetConfig::test_scale());
    let mut m = LoadedModel::from_graph_with("tinycnn_b8", &g, 8, 2, 1).unwrap();
    m.set_breaker_config(BreakerConfig { recover: false, ..Default::default() });
    assert!(m.serves_pipelined());
    let shape = input_shape(&g);
    let per: usize = shape.iter().product();
    let input = det_input(8 * per, 0xDE6);
    let clean = m.run_all(&input).unwrap();

    // rung one: a single-shot fault costs one retry, not the run
    fault::arm("pipeline.stage#0=1");
    let retried = m.run_all(&input).unwrap();
    fault::disarm();
    assert_eq!(retried, clean);
    let fs = m.fault_stats();
    assert_eq!(fs.faults, 1);
    assert_eq!(fs.retries, 1);
    assert!(!fs.degraded, "one absorbed fault must not degrade the model");

    // rung two: a persistent fault defeats the retry -> sequential
    fault::arm("pipeline.stage#0=1+");
    let degraded = m.run_all(&input).unwrap();
    fault::disarm();
    assert!(m.is_degraded());
    assert!(m.fault_stats().faults >= 3);

    // degraded outputs == the per-image sequential oracle, bitwise
    let oracle = ExecutionPlan::build(&g).unwrap();
    let mut want: Vec<f32> = Vec::new();
    for i in 0..8 {
        let mut feeds = BTreeMap::new();
        feeds.insert(
            "input".to_string(),
            Tensor::from_vec(&shape, input[i * per..(i + 1) * per].to_vec()),
        );
        let outs = oracle.run(&feeds).unwrap();
        want.extend_from_slice(&outs[0].data);
    }
    assert_eq!(degraded.len(), 1);
    assert_eq!(degraded[0], want, "degraded outputs must be bitwise-sequential");

    // sticky under --no-recover: probes are never granted, so the
    // demoted model never touches the faulting pipeline again
    fault::arm("pipeline.stage#0=1+");
    let after = m.run_all(&input).unwrap();
    assert_eq!(fault::fired(), 0, "degraded model must bypass the pipeline sites");
    fault::disarm();
    assert_eq!(after, degraded);
    let fs = m.fault_stats();
    assert_eq!((fs.trips, fs.recoveries), (1, 0), "no probe, no recovery");
}

/// The self-healing ladder, deterministic: a transient fault (two stage
/// hits, then the site heals forever) trips the breaker, the batch is
/// answered from the sequential oracle, and with a zero cool-down the
/// very next batch is the HalfOpen probe — answered from the oracle,
/// closing the site when the healed pipeline's bits match. The model
/// un-degrades and finishes pipelined.
#[test]
fn chaos_transient_fault_trips_probes_and_recovers() {
    let _g = gate();
    fault::silence_expected_panics();
    let g = tiny_cnn(NetConfig::test_scale());
    let mut m = LoadedModel::from_graph_with("tinycnn_b8", &g, 8, 2, 1).unwrap();
    m.set_breaker_config(BreakerConfig::with_cooldown_ms(0));
    let per: usize = input_shape(&g).iter().product();
    let input = det_input(8 * per, 0x5E1F);
    let clean = m.run_all(&input).unwrap();

    // two faults in one batch (attempt + retry) trip stage 0's breaker;
    // the site then heals forever
    fault::arm("pipeline.stage#0=2,heal");
    let tripped = m.run_all(&input).unwrap();
    assert_eq!(tripped, clean, "bypassed batch must be bitwise the oracle");
    assert!(m.is_degraded(), "two faults in one batch must trip the site");
    let fs = m.fault_stats();
    assert_eq!((fs.faults, fs.retries, fs.trips, fs.recoveries), (2, 1, 1, 0));

    // cool-down 0: the next batch is the probe — answered from the
    // oracle while the healed pipeline re-validates bitwise
    let probed = m.run_all(&input).unwrap();
    assert_eq!(probed, clean, "probe batch is answered from the oracle");
    let fs = m.fault_stats();
    assert_eq!((fs.trips, fs.recoveries), (1, 1), "matching probe recovers");
    assert!(!fs.degraded, "recovered model must report healthy");
    assert!(fs.time_degraded_ns > 0, "the degraded interval is accounted");

    // recovered: back on the pipelined path, bitwise as before the fault
    let after = m.run_all(&input).unwrap();
    fault::disarm();
    assert_eq!(after, clean);
    assert_eq!(m.fault_stats().faults, 2, "no new faults after recovery");
}

/// A persistent fault defeats recovery: every cool-down probe faults
/// again, re-opening the breaker with the cool-down doubled (each
/// failed probe is a fresh trip), the model stays degraded, and every
/// answered batch remains bitwise the sequential oracle.
#[test]
fn chaos_persistent_fault_backs_off_and_stays_degraded() {
    let _g = gate();
    fault::silence_expected_panics();
    let g = tiny_cnn(NetConfig::test_scale());
    let mut m = LoadedModel::from_graph_with("tinycnn_b8", &g, 8, 2, 1).unwrap();
    // 1 ns cool-down: every post-trip batch is granted a probe, so each
    // run exercises the probe-failure -> back-off -> re-open edge
    m.set_breaker_config(BreakerConfig { cooldown_ns: 1, ..Default::default() });
    let per: usize = input_shape(&g).iter().product();
    let input = det_input(8 * per, 0xBADD);
    let clean = m.run_all(&input).unwrap();

    fault::arm("pipeline.stage#0=1+");
    for round in 0..4 {
        let outs = m.run_all(&input).unwrap();
        assert_eq!(outs, clean, "round {round}: outputs must stay bitwise-oracle");
        assert!(m.is_degraded(), "round {round}: persistent fault keeps the site open");
    }
    fault::disarm();
    let fs = m.fault_stats();
    assert!(fs.trips >= 2, "failed probes must re-trip the site, got {}", fs.trips);
    assert_eq!(fs.recoveries, 0, "a persistently faulting site must never recover");
    assert!(fs.degraded, "the model must still be degraded");
}

/// Read this process's live OS-thread count (Linux procfs).
#[cfg(target_os = "linux")]
fn thread_count() -> usize {
    std::fs::read_to_string("/proc/self/status")
        .ok()
        .and_then(|s| {
            s.lines()
                .find(|l| l.starts_with("Threads:"))
                .and_then(|l| l.split_whitespace().nth(1))
                .and_then(|n| n.parse().ok())
        })
        .expect("/proc/self/status reports a Threads: line")
}

/// Persistent-pool stress: one pool of stage workers serves 100
/// consecutive runs, several of which panic mid-stage, and the
/// process-wide OS thread count must not grow — faulted workers rebuild
/// state in place instead of leaking replacements run over run.
#[test]
#[cfg(target_os = "linux")]
fn chaos_persistent_pool_survives_faulty_runs_without_leaking_threads() {
    let _g = gate();
    fault::silence_expected_panics();
    let g = tiny_cnn(NetConfig::test_scale());
    let plan = ExecutionPlan::build_batched(&g, 8).unwrap();
    let pipe = PipelinePlan::from_plan_team(plan, 3, 1);
    pipe.enable_persistent_pool();
    assert!(pipe.persistent_pool_active());
    let per: usize = input_shape(&g).iter().product();
    let input = det_input(8 * per, 0x7EAD);
    let clean = pipe.run_batch(&input, 8).unwrap();
    let baseline = thread_count();

    // the first stage-1 hits panic a pooled worker mid-run, then the
    // site heals: a mix of faulty and clean runs through one pool
    fault::arm("pipeline.stage#1=6,heal");
    let mut faulted = 0usize;
    for _ in 0..100 {
        match pipe.run_batch(&input, 8) {
            Ok(out) => assert_eq!(out, clean, "clean runs must stay bitwise-stable"),
            Err(GraphError::StageFault { stage, .. }) => {
                assert_eq!(stage, 1, "fault must name the armed stage");
                faulted += 1;
            }
            Err(e) => panic!("unexpected non-stage error: {e:?}"),
        }
    }
    fault::disarm();
    assert!(faulted >= 1, "the armed site must have fired");
    assert!(faulted <= 6, "a healed site must stop firing, got {faulted} faults");

    let after = thread_count();
    assert!(
        after <= baseline + 2,
        "persistent pool leaked threads: {baseline} -> {after}"
    );
    pipe.disable_persistent_pool();
    assert!(!pipe.persistent_pool_active());
    assert_eq!(pipe.run_batch(&input, 8).unwrap(), clean);
}

/// Chaos smoke (CI runs the `chaos_` tests as a dedicated step): serve
/// end-to-end with stage 0 persistently panicking. Serving must
/// complete, answer every request, record the faults, and end with the
/// pipelined model degraded — zero lost responses.
#[test]
fn chaos_serve_completes_with_faults_recorded() {
    let _g = gate();
    fault::silence_expected_panics();
    let dir = synth_artifacts("chaos_artifacts");
    fault::arm("pipeline.stage#0=1+");
    // team = 2 makes every loaded model (batch-1 included) serve
    // through the pipeline, so the armed stage site fires no matter how
    // the dynamic batches happen to form.
    let cfg = ServeConfig {
        requests: 32,
        max_batch: 8,
        threads: 2,
        team: 2,
        ..Default::default()
    };
    let result = serve_demo(&dir, &cfg);
    fault::disarm();
    let mut report = result.expect("serving must survive injected stage faults");
    assert_eq!(report.requests, 32, "every request must be answered");
    assert!(report.faults >= 1, "injected stage faults must be recorded");
    assert!(report.degraded >= 1, "the pipelined model must have degraded");
    // degraded classifications still agree with the interpreter
    let (agree, total) = report.interp_agreement.unwrap();
    assert_eq!(agree, total);
    // and the counters survive the JSON round-trip
    let parsed = Json::parse(&report.to_json().pretty()).unwrap();
    assert!(parsed.get("faults").as_usize().unwrap() >= 1);
    assert!(parsed.get("degraded").as_usize().unwrap() >= 1);
}

/// Chaos end-to-end recovery: serve with a *transient* stage fault (two
/// hits — one batch's attempt and retry — then the site heals) and a
/// zero cool-down. The serving model must trip, probe on its next
/// batch, close the breaker, finish the run pipelined, and the report's
/// per-model health must show `{trips >= 1, recoveries >= 1,
/// degraded_now: false}` with every classification agreeing with the
/// interpreter oracle — plus the fault-budget warning, since two faults
/// exceed a budget of one.
#[test]
fn chaos_serve_transient_fault_recovers_with_health_report() {
    let _g = gate();
    fault::silence_expected_panics();
    let dir = synth_artifacts("chaos_artifacts_recovery");
    fault::arm("pipeline.stage#0=2,heal");
    let cfg = ServeConfig {
        requests: 32,
        max_batch: 8,
        threads: 2,
        team: 2,
        recover_after_ms: Some(0),
        // tails pad to the full batch: every multi-image batch routes
        // through the one primary pipe, so trip and probe are ordered
        plan_family: Some(vec![]),
        fault_budget: Some(1),
        ..Default::default()
    };
    let result = serve_demo(&dir, &cfg);
    fault::disarm();
    let mut report = result.expect("recovery serving must complete");
    assert_eq!(report.requests, 32, "every request must be answered");
    assert!(report.faults >= 2, "the transient fault must be recorded");
    assert!(report.recoveries >= 1, "the healed site must probe shut");
    assert_eq!(report.degraded, 0, "no model may still be degraded at the end");
    let sick: Vec<_> = report.models.iter().filter(|h| h.trips > 0).collect();
    assert_eq!(sick.len(), 1, "exactly one model absorbed the transient fault");
    let h = sick[0];
    assert!(h.recoveries >= 1, "model '{}' must have recovered", h.name);
    assert!(!h.degraded_now, "model '{}' must end healthy", h.name);
    assert!(h.time_degraded_ns > 0, "the bypassed interval is accounted");
    assert!(h.over_budget, "2 faults must exceed --fault-budget 1");
    // recovered classifications still agree with the interpreter
    let (agree, total) = report.interp_agreement.unwrap();
    assert_eq!(agree, total);
    // and the health survives the JSON round-trip
    let parsed = Json::parse(&report.to_json().pretty()).unwrap();
    assert!(parsed.get("recoveries").as_usize().unwrap() >= 1);
    let models = parsed.get("models").as_arr().unwrap();
    assert!(models.iter().any(|m| {
        m.get("trips").as_usize().unwrap_or(0) >= 1
            && m.get("recoveries").as_usize().unwrap_or(0) >= 1
            && m.get("degraded_now").as_bool() == Some(false)
            && m.get("over_budget").as_bool() == Some(true)
    }));
}

/// Chaos for the always-fed loop (ISSUE 8): overlap on (the default),
/// stage 0 persistently panicking, AND the client hanging up with a
/// ragged 5-of-8 tail in flight. The feeder must hand off its final
/// partial batch, the executor must absorb the faults through the
/// degrade ladder, and the run must end with every request answered and
/// a report produced — no feeder/executor deadlock, nothing lost.
#[test]
fn chaos_overlap_stage_faults_and_hangup_mid_batch_flush_cleanly() {
    let _g = gate();
    fault::silence_expected_panics();
    let dir = synth_artifacts("chaos_artifacts_overlap");
    let mut runtime = Runtime::cpu(&dir).unwrap().with_threads(2).with_team(2);
    runtime.load_manifest().unwrap();
    let per: usize = runtime
        .model("tinycnn_b1")
        .expect("manifest loads the batch-1 model")
        .input_shape
        .iter()
        .product();
    let policy = BatchPolicy { max_batch: 8, ..Default::default() };
    let coordinator = Coordinator::new(runtime, policy);
    assert!(coordinator.overlap, "drain/execute overlap must be the default");
    let (tx, rx) = std::sync::mpsc::sync_channel::<Request>(8);
    let (reply_tx, reply_rx) = std::sync::mpsc::channel();
    fault::arm("pipeline.stage#0=1+");
    let server = std::thread::spawn(move || coordinator.run(rx));
    for i in 0..5u64 {
        let req = Request {
            id: i,
            data: det_input(per, 0xC4A05 + i),
            submitted: std::time::Instant::now(),
            deadline: None,
            reply: reply_tx.clone(),
        };
        tx.send(req).expect("queue accepts the partial batch");
    }
    // hang up mid-batch: 5 < max_batch requests in flight, no flush
    // signal other than the disconnect itself
    drop(tx);
    drop(reply_tx);
    let report = server
        .join()
        .expect("serving thread must not panic")
        .expect("overlap serving must survive injected stage faults");
    fault::disarm();
    let replies: Vec<_> = reply_rx.iter().collect();
    assert_eq!(replies.len(), 5, "hangup mid-batch still answers every request");
    assert!(
        replies.iter().all(|r| r.is_ok()),
        "the degrade ladder must serve the flushed tail"
    );
    assert_eq!(report.requests, 5);
    assert!(report.faults >= 1, "injected stage faults must be recorded");
    assert!(report.degraded >= 1, "the model must end demoted to sequential");
}

/// Injected batcher latency + tight deadlines: every request expires
/// before execution and is answered with the typed `Expired` refusal —
/// counted in the report, none lost, clean shutdown.
#[test]
fn chaos_drain_latency_expires_deadlined_requests() {
    let _g = gate();
    fault::silence_expected_panics();
    let dir = synth_artifacts("chaos_artifacts_expiry");
    fault::arm("batcher.drain=1+:sleep25");
    let cfg = ServeConfig {
        requests: 8,
        max_batch: 8,
        deadline_ms: Some(5),
        ..Default::default()
    };
    let result = serve_demo(&dir, &cfg);
    fault::disarm();
    let mut report = result.expect("expiry must not kill the server");
    assert_eq!(report.requests, 8, "expired requests are answered, not lost");
    assert_eq!(report.expired, 8, "every deadline-bound request must expire");
    let parsed = Json::parse(&report.to_json().pretty()).unwrap();
    assert_eq!(parsed.get("expired").as_usize(), Some(8));
}
