//! Fault-injection property tests (`--features fault-inject`).
//!
//! The robustness twin of `exec_equiv`: instead of proving the happy
//! path bitwise-correct, these tests inject deterministic panics and
//! latency into the serving hot paths (`util::fault`) and prove the
//! recovery contract:
//!
//! * a panic in ANY pipeline stage, across team sizes and plan batches,
//!   surfaces as a typed `GraphError::StageFault` for that run only —
//!   the pipeline never wedges and the plan stays reusable;
//! * repeated faults demote a `LoadedModel` to its sequential batch-1
//!   fallback, whose outputs are bitwise-identical to the sequential
//!   oracle;
//! * end-to-end serving under injected faults completes with zero lost
//!   responses and the fault counters recorded in the `ServeReport`
//!   (the `chaos_` tests — CI runs them as the chaos smoke);
//! * injected batcher latency plus tight deadlines expires every
//!   request with a typed answer, never silence;
//! * the drain/execute overlap (a feeder thread between batcher and
//!   executor, on by default) must not wedge: stage faults plus a
//!   client hangup mid-batch still flush every request and produce the
//!   report.
//!
//! Without the feature this file compiles to an empty test binary.

#![cfg(feature = "fault-inject")]

use hpipe::coordinator::batcher::BatchPolicy;
use hpipe::coordinator::{serve_demo, Coordinator, Request, ServeConfig};
use hpipe::exec::{ExecutionPlan, PipelinePlan};
use hpipe::graph::{graphdef, GraphError, Op, Tensor};
use hpipe::nets::{tiny_cnn, NetConfig};
use hpipe::runtime::{LoadedModel, Runtime};
use hpipe::util::fault;
use hpipe::util::{Json, Rng};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::{Mutex, MutexGuard};

// The fault harness is process-global: every test that arms real sites
// holds this gate for its whole body so concurrent test threads never
// see each other's fault plans.
static GATE: Mutex<()> = Mutex::new(());

fn gate() -> MutexGuard<'static, ()> {
    GATE.lock().unwrap_or_else(|e| e.into_inner())
}

/// The TinyCNN placeholder's per-image shape (leading dim 1).
fn input_shape(g: &hpipe::graph::Graph) -> Vec<usize> {
    match &g.get("input").expect("tinycnn has an input").op {
        Op::Placeholder { shape } => shape.clone(),
        _ => panic!("tinycnn input is not a placeholder"),
    }
}

fn det_input(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect()
}

/// Synthesize a serving artifact directory under `target/` (the
/// `e2e_serving` bench pattern): He-init TinyCNN graphdef + manifest
/// with batch-1 and batch-8 model entries.
fn synth_artifacts(subdir: &str) -> PathBuf {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("target").join(subdir);
    let g = tiny_cnn(NetConfig::test_scale());
    graphdef::save(&g, &dir.join("tinycnn")).expect("writing graphdef");
    let mut models = Json::obj();
    models
        .set("1", Json::from("tinycnn.graphdef"))
        .set("8", Json::from("tinycnn.graphdef"));
    let mut root = Json::obj();
    root.set("input_shape", Json::from(input_shape(&g)))
        .set("models", models)
        .set("kernels", Json::obj());
    std::fs::write(dir.join("manifest.json"), root.pretty()).expect("writing manifest");
    dir
}

/// Tentpole property: a panic injected into ANY stage, for every
/// (team, plan-batch) combination, fails that run with a typed
/// `StageFault` naming the stage — and the same `PipelinePlan` then
/// serves a clean run bitwise-identical to the pre-fault baseline.
#[test]
fn stage_panic_never_wedges_any_configuration() {
    let _g = gate();
    fault::silence_expected_panics();
    let g = tiny_cnn(NetConfig::test_scale());
    let per: usize = input_shape(&g).iter().product();
    let n_images = 8;
    let input = det_input(n_images * per, 0xFA17);
    for &team in &[1usize, 2, 4] {
        for &group in &[1usize, 2] {
            let plan = ExecutionPlan::build_batched(&g, group).unwrap();
            let pipe = PipelinePlan::from_plan_team(plan, 3, team);
            let clean = pipe.run_batch(&input, n_images).unwrap();
            for stage in 0..pipe.num_stages() {
                fault::arm(&format!("pipeline.stage#{stage}=1"));
                match pipe.run_batch(&input, n_images) {
                    Err(GraphError::StageFault { stage: s, msg, .. }) => {
                        assert_eq!(s, stage, "fault must name the faulting stage");
                        assert!(msg.contains("injected fault"), "unexpected fault: {msg}");
                    }
                    other => panic!(
                        "team {team} group {group} stage {stage}: expected StageFault, \
                         got {:?}",
                        other.map(|o| o.len())
                    ),
                }
                fault::disarm();
                let again = pipe.run_batch(&input, n_images).unwrap();
                assert_eq!(again, clean, "plan must stay reusable after an isolated fault");
            }
        }
    }
}

/// The degrade ladder end to end: one transient fault is absorbed by
/// the retry; a persistent fault demotes the model to its sequential
/// batch-1 plan, sticky, with outputs bitwise-identical to the
/// per-image sequential oracle.
#[test]
fn repeated_faults_degrade_to_bitwise_sequential_fallback() {
    let _g = gate();
    fault::silence_expected_panics();
    let g = tiny_cnn(NetConfig::test_scale());
    let m = LoadedModel::from_graph_with("tinycnn_b8", &g, 8, 2, 1).unwrap();
    assert!(m.serves_pipelined());
    let shape = input_shape(&g);
    let per: usize = shape.iter().product();
    let input = det_input(8 * per, 0xDE6);
    let clean = m.run_all(&input).unwrap();

    // rung one: a single-shot fault costs one retry, not the run
    fault::arm("pipeline.stage#0=1");
    let retried = m.run_all(&input).unwrap();
    fault::disarm();
    assert_eq!(retried, clean);
    let fs = m.fault_stats();
    assert_eq!(fs.faults, 1);
    assert_eq!(fs.retries, 1);
    assert!(!fs.degraded, "one absorbed fault must not degrade the model");

    // rung two: a persistent fault defeats the retry -> sequential
    fault::arm("pipeline.stage#0=1+");
    let degraded = m.run_all(&input).unwrap();
    fault::disarm();
    assert!(m.is_degraded());
    assert!(m.fault_stats().faults >= 3);

    // degraded outputs == the per-image sequential oracle, bitwise
    let oracle = ExecutionPlan::build(&g).unwrap();
    let mut want: Vec<f32> = Vec::new();
    for i in 0..8 {
        let mut feeds = BTreeMap::new();
        feeds.insert(
            "input".to_string(),
            Tensor::from_vec(&shape, input[i * per..(i + 1) * per].to_vec()),
        );
        let outs = oracle.run(&feeds).unwrap();
        want.extend_from_slice(&outs[0].data);
    }
    assert_eq!(degraded.len(), 1);
    assert_eq!(degraded[0], want, "degraded outputs must be bitwise-sequential");

    // sticky: the demoted model never touches the faulting pipeline again
    fault::arm("pipeline.stage#0=1+");
    let after = m.run_all(&input).unwrap();
    assert_eq!(fault::fired(), 0, "degraded model must bypass the pipeline sites");
    fault::disarm();
    assert_eq!(after, degraded);
}

/// Chaos smoke (CI runs the `chaos_` tests as a dedicated step): serve
/// end-to-end with stage 0 persistently panicking. Serving must
/// complete, answer every request, record the faults, and end with the
/// pipelined model degraded — zero lost responses.
#[test]
fn chaos_serve_completes_with_faults_recorded() {
    let _g = gate();
    fault::silence_expected_panics();
    let dir = synth_artifacts("chaos_artifacts");
    fault::arm("pipeline.stage#0=1+");
    // team = 2 makes every loaded model (batch-1 included) serve
    // through the pipeline, so the armed stage site fires no matter how
    // the dynamic batches happen to form.
    let cfg = ServeConfig {
        requests: 32,
        max_batch: 8,
        threads: 2,
        team: 2,
        ..Default::default()
    };
    let result = serve_demo(&dir, &cfg);
    fault::disarm();
    let mut report = result.expect("serving must survive injected stage faults");
    assert_eq!(report.requests, 32, "every request must be answered");
    assert!(report.faults >= 1, "injected stage faults must be recorded");
    assert!(report.degraded >= 1, "the pipelined model must have degraded");
    // degraded classifications still agree with the interpreter
    let (agree, total) = report.interp_agreement.unwrap();
    assert_eq!(agree, total);
    // and the counters survive the JSON round-trip
    let parsed = Json::parse(&report.to_json().pretty()).unwrap();
    assert!(parsed.get("faults").as_usize().unwrap() >= 1);
    assert!(parsed.get("degraded").as_usize().unwrap() >= 1);
}

/// Chaos for the always-fed loop (ISSUE 8): overlap on (the default),
/// stage 0 persistently panicking, AND the client hanging up with a
/// ragged 5-of-8 tail in flight. The feeder must hand off its final
/// partial batch, the executor must absorb the faults through the
/// degrade ladder, and the run must end with every request answered and
/// a report produced — no feeder/executor deadlock, nothing lost.
#[test]
fn chaos_overlap_stage_faults_and_hangup_mid_batch_flush_cleanly() {
    let _g = gate();
    fault::silence_expected_panics();
    let dir = synth_artifacts("chaos_artifacts_overlap");
    let mut runtime = Runtime::cpu(&dir).unwrap().with_threads(2).with_team(2);
    runtime.load_manifest().unwrap();
    let per: usize = runtime
        .model("tinycnn_b1")
        .expect("manifest loads the batch-1 model")
        .input_shape
        .iter()
        .product();
    let policy = BatchPolicy { max_batch: 8, ..Default::default() };
    let coordinator = Coordinator::new(runtime, policy);
    assert!(coordinator.overlap, "drain/execute overlap must be the default");
    let (tx, rx) = std::sync::mpsc::sync_channel::<Request>(8);
    let (reply_tx, reply_rx) = std::sync::mpsc::channel();
    fault::arm("pipeline.stage#0=1+");
    let server = std::thread::spawn(move || coordinator.run(rx));
    for i in 0..5u64 {
        let req = Request {
            id: i,
            data: det_input(per, 0xC4A05 + i),
            submitted: std::time::Instant::now(),
            deadline: None,
            reply: reply_tx.clone(),
        };
        tx.send(req).expect("queue accepts the partial batch");
    }
    // hang up mid-batch: 5 < max_batch requests in flight, no flush
    // signal other than the disconnect itself
    drop(tx);
    drop(reply_tx);
    let report = server
        .join()
        .expect("serving thread must not panic")
        .expect("overlap serving must survive injected stage faults");
    fault::disarm();
    let replies: Vec<_> = reply_rx.iter().collect();
    assert_eq!(replies.len(), 5, "hangup mid-batch still answers every request");
    assert!(
        replies.iter().all(|r| r.is_ok()),
        "the degrade ladder must serve the flushed tail"
    );
    assert_eq!(report.requests, 5);
    assert!(report.faults >= 1, "injected stage faults must be recorded");
    assert!(report.degraded >= 1, "the model must end demoted to sequential");
}

/// Injected batcher latency + tight deadlines: every request expires
/// before execution and is answered with the typed `Expired` refusal —
/// counted in the report, none lost, clean shutdown.
#[test]
fn chaos_drain_latency_expires_deadlined_requests() {
    let _g = gate();
    fault::silence_expected_panics();
    let dir = synth_artifacts("chaos_artifacts_expiry");
    fault::arm("batcher.drain=1+:sleep25");
    let cfg = ServeConfig {
        requests: 8,
        max_batch: 8,
        deadline_ms: Some(5),
        ..Default::default()
    };
    let result = serve_demo(&dir, &cfg);
    fault::disarm();
    let mut report = result.expect("expiry must not kill the server");
    assert_eq!(report.requests, 8, "expired requests are answered, not lost");
    assert_eq!(report.expired, 8, "every deadline-bound request must expire");
    let parsed = Json::parse(&report.to_json().pretty()).unwrap();
    assert_eq!(parsed.get("expired").as_usize(), Some(8));
}
