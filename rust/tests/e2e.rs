//! End-to-end integration: trained artifacts -> compiled-executor
//! runtime -> coordinator, cross-validated against the reference
//! interpreter.
//!
//! These tests need `make artifacts` to have run; they skip (pass with a
//! note) when artifacts/ is absent so `cargo test` works on a fresh
//! checkout. (The executor itself is covered without artifacts by
//! `exec_equiv.rs` and the in-crate unit tests.)

use hpipe::coordinator::{serve_demo, ServeConfig};
use hpipe::graph::{graphdef, Op, Tensor};
use hpipe::interp;
use hpipe::runtime::Runtime;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
        None
    }
}

#[test]
fn executor_matches_reference_interpreter() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::cpu(&dir).unwrap();
    rt.load_manifest().unwrap();
    let graph = graphdef::load(&dir.join("tinycnn")).unwrap();
    let model = rt.model("tinycnn_b1").expect("batch-1 model");

    let mut rng = hpipe::util::Rng::new(42);
    for trial in 0..5 {
        let input: Vec<f32> = (0..16 * 16 * 3).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let got = model.run(&input).unwrap();
        let mut feeds = BTreeMap::new();
        feeds.insert(
            "input".to_string(),
            Tensor::from_vec(&[1, 16, 16, 3], input.clone()),
        );
        let outs = interp::run_outputs(&graph, &feeds).unwrap();
        assert_eq!(got.len(), outs[0].data.len());
        for (i, (a, b)) in got.iter().zip(&outs[0].data).enumerate() {
            assert!(
                (a - b).abs() < 1e-3,
                "trial {trial} [{i}]: exec {a} vs interp {b}"
            );
        }
    }
}

#[test]
fn batch8_model_matches_batch1() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::cpu(&dir).unwrap();
    rt.load_manifest().unwrap();
    let m1 = rt.model("tinycnn_b1").unwrap();
    let m8 = rt.model("tinycnn_b8").unwrap();
    let per = 16 * 16 * 3;
    let mut rng = hpipe::util::Rng::new(7);
    let batch: Vec<f32> = (0..8 * per).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let out8 = m8.run(&batch).unwrap();
    for i in 0..8 {
        let out1 = m1.run(&batch[i * per..(i + 1) * per]).unwrap();
        for (j, (a, b)) in out1.iter().zip(&out8[i * 10..(i + 1) * 10]).enumerate() {
            assert!((a - b).abs() < 1e-4, "image {i} class {j}: {a} vs {b}");
        }
    }
}

#[test]
fn serve_demo_end_to_end() {
    let Some(dir) = artifacts_dir() else { return };
    // threads = 2 exercises the pipelined batch path end to end;
    // team = 2 additionally splits the dominant stage's conv rows
    let cfg = ServeConfig {
        requests: 24,
        max_batch: 4,
        threads: 2,
        team: 2,
        ..Default::default()
    };
    let mut report = serve_demo(&dir, &cfg).unwrap();
    assert_eq!(report.requests, 24);
    assert!(report.batches >= 24 / 4);
    let (agree, total) = report.interp_agreement.unwrap();
    assert_eq!(agree, total, "executor and interpreter must classify alike");
    assert!(report.latency.percentile(50.0).as_micros() > 0);
    // the pipelined serving model surfaces per-stage occupancy counters
    assert!(!report.stages.is_empty());
    assert!(report.stages.iter().any(|s| s.items > 0));
}

#[test]
fn serve_demo_autotuned_end_to_end() {
    let Some(dir) = artifacts_dir() else { return };
    // calibrate-then-serve: measured cuts + measured team, and the
    // classifications must still agree with the interpreter exactly
    let cfg = ServeConfig { requests: 24, max_batch: 4, autotune: true, ..Default::default() };
    let mut report = serve_demo(&dir, &cfg).unwrap();
    assert_eq!(report.requests, 24);
    let (agree, total) = report.interp_agreement.unwrap();
    assert_eq!(agree, total, "autotuned executor must classify like the interpreter");
    // machine-readable report parses back
    let parsed = hpipe::util::Json::parse(&report.to_json().pretty()).unwrap();
    assert_eq!(parsed.get("requests").as_usize(), Some(24));
}

#[test]
fn python_graphdef_matches_rust_tiny_builder_topology() {
    let Some(dir) = artifacts_dir() else { return };
    let py = graphdef::load(&dir.join("tinycnn")).unwrap();
    let rs = hpipe::nets::tiny_cnn(hpipe::nets::NetConfig::test_scale());
    // same op multiset per type (weights differ: trained vs He-init)
    let count = |g: &hpipe::graph::Graph, t: &str| {
        g.nodes.iter().filter(|n| n.op.type_name() == t).count()
    };
    for ty in ["Conv2D", "BiasAdd", "Relu", "MaxPool", "Mean", "MatMul", "Softmax"] {
        assert_eq!(count(&py, ty), count(&rs, ty), "op {ty}");
    }
    // identical node names for the compute stages
    for name in ["conv0", "conv1", "conv2", "pool2", "logits", "predictions"] {
        assert!(py.get(name).is_some(), "missing {name}");
        assert!(rs.get(name).is_some(), "missing {name}");
    }
}

#[test]
fn trained_tinycnn_compiles_and_simulates() {
    // The Python-trained network goes through the FULL HPIPE compiler:
    // prune -> fold -> balance -> codegen -> cycle simulation.
    let Some(dir) = artifacts_dir() else { return };
    let mut graph = graphdef::load(&dir.join("tinycnn")).unwrap();
    hpipe::sparsity::prune_graph(&mut graph, 0.5);
    let (graph, _) = hpipe::transform::optimize(&graph);
    let opts = hpipe::compile::CompileOptions::new(hpipe::arch::S10_2800.clone(), 300);
    let plan = hpipe::compile::compile(&graph, "tinycnn-trained", &opts).unwrap();
    assert!(plan.totals.dsps > 0 && plan.totals.dsps <= 300);
    let sim = hpipe::sim::simulate(&plan, 4).unwrap();
    assert_eq!(sim.completion_cycles.len(), 4);
}

#[test]
fn kernel_artifact_runs() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::cpu(&dir).unwrap();
    rt.load_manifest().unwrap();
    let k = rt.model("sparse_conv_demo").expect("kernel artifact");
    let n: usize = k.input_shape.iter().product();
    let out = k.run(&vec![1.0; n]).unwrap();
    assert!(out.iter().any(|&v| v != 0.0), "kernel output all zero");
}

#[test]
fn tiny_graphdef_has_placeholder_input() {
    let Some(dir) = artifacts_dir() else { return };
    let g = graphdef::load(&dir.join("tinycnn")).unwrap();
    match &g.get("input").unwrap().op {
        Op::Placeholder { shape } => assert_eq!(shape, &vec![1, 16, 16, 3]),
        op => panic!("unexpected input op {op:?}"),
    }
}
