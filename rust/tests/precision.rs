//! §VII future-work feature: variable precision compilation and the
//! Agilex low-precision DSP packing ("these features could provide
//! further performance improvements per area of 2x or more").

use hpipe::arch::{device_by_name, AGILEX_027, S10_2800};
use hpipe::compile::{compile, CompileOptions};
use hpipe::nets::{resnet50, NetConfig};
use hpipe::sparsity::prune_graph;
use hpipe::transform::optimize;

fn optimized_resnet() -> hpipe::graph::Graph {
    let mut g = resnet50(NetConfig::test_scale());
    prune_graph(&mut g, 0.85);
    optimize(&g).0
}

#[test]
fn agilex_device_registered() {
    let d = device_by_name("agilex_027").unwrap();
    assert_eq!(d.mults_per_dsp_at(16), 2);
    assert_eq!(d.mults_per_dsp_at(8), 4, "8-bit packs 2x (§VII / [28])");
    // Stratix 10 never packs
    assert_eq!(S10_2800.mults_per_dsp_at(8), 2);
}

#[test]
fn eight_bit_on_agilex_halves_dsp_cost() {
    let g = optimized_resnet();
    let o16 =
        CompileOptions::new(AGILEX_027.clone(), 1200).with_precision(16);
    let o8 = CompileOptions::new(AGILEX_027.clone(), 1200).with_precision(8);
    let p16 = compile(&g, "resnet50", &o16).unwrap();
    let p8 = compile(&g, "resnet50", &o8).unwrap();
    // the same DSP budget buys more multipliers at 8-bit (2x per DSP;
    // at test scale unroll caps bind before the full 2x materializes)
    let m16: usize = p16.stages.iter().map(|s| s.mults).sum();
    let m8: usize = p8.stages.iter().map(|s| s.mults).sum();
    assert!(m8 > m16, "8-bit mults {m8} vs 16-bit {m16}");
    assert!(
        p8.interval_cycles() <= p16.interval_cycles(),
        "8-bit interval {} vs 16-bit {}",
        p8.interval_cycles(),
        p16.interval_cycles()
    );
    // and pays half the DSPs per multiplier on compute stages
    let per_mult_16 = p16.totals.dsps as f64 / m16 as f64;
    let per_mult_8 = p8.totals.dsps as f64 / m8 as f64;
    assert!(
        per_mult_8 < 0.65 * per_mult_16,
        "DSP/mult: 8-bit {per_mult_8:.3} vs 16-bit {per_mult_16:.3}"
    );
}

#[test]
fn lower_precision_shrinks_weight_memory() {
    let g = optimized_resnet();
    let o16 = CompileOptions::new(S10_2800.clone(), 800).with_precision(16);
    let o8 = CompileOptions::new(S10_2800.clone(), 800).with_precision(8);
    let p16 = compile(&g, "resnet50", &o16).unwrap();
    let p8 = compile(&g, "resnet50", &o8).unwrap();
    // identical splits would shrink memory by (8+8)/(16+8); splits can
    // differ slightly, so check the aggregate moves the right way
    assert!(
        (p8.totals.m20ks as f64) < 0.9 * p16.totals.m20ks as f64,
        "8-bit m20ks {} vs 16-bit {}",
        p8.totals.m20ks,
        p16.totals.m20ks
    );
}

#[test]
fn per_layer_precision_study_fixed_point() {
    // variable precision end to end: crush one layer to 6 bits via the
    // PrecisionConfig override and confirm the error is localized (the
    // network still classifies like f32 most of the time at 16-bit
    // elsewhere), mirroring the paper's per-operation annotations.
    use hpipe::graph::{FixedFormat, Tensor};
    use hpipe::interp::fixed::{run_fixed, PrecisionConfig};
    let g = hpipe::nets::tiny_cnn(NetConfig::test_scale());
    let mut rng = hpipe::util::Rng::new(0x5E7);
    let mut uniform_err = 0f32;
    let mut override_err = 0f32;
    for _ in 0..10 {
        let mut feeds = std::collections::BTreeMap::new();
        feeds.insert(
            "input".to_string(),
            Tensor::randn(&[1, 16, 16, 3], &mut rng, 1.0),
        );
        let base = run_fixed(&g, &feeds, &PrecisionConfig::paper_16bit()).unwrap();
        let mut cfg = PrecisionConfig::paper_16bit();
        cfg.overrides.insert("conv2/weights".into(), FixedFormat::q(6, 4));
        let over = run_fixed(&g, &feeds, &cfg).unwrap();
        uniform_err = uniform_err.max(base.max_abs_error);
        override_err = override_err.max(over.max_abs_error);
    }
    assert!(override_err > uniform_err, "override had no effect");
    assert!(override_err < 0.5, "6-bit single layer should degrade, not destroy");
}
