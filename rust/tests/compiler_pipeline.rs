//! Integration tests across the compiler stack: every supported network
//! goes prune -> transform -> compile -> codegen -> simulate, and the
//! pieces must agree with each other.

use hpipe::arch::{S10_1650, S10_2800};
use hpipe::compile::{balance::imbalance, codegen, compile, CompileOptions};
use hpipe::nets::{build_named, NetConfig};
use hpipe::sim::simulate;
use hpipe::sparsity::prune_graph;
use hpipe::transform::{equiv, optimize};

fn pipeline(net: &str, sparsity: f64, dsp: usize) -> (hpipe::graph::Graph, hpipe::compile::AcceleratorPlan) {
    let mut g = build_named(net, NetConfig::test_scale()).unwrap();
    if sparsity > 0.0 {
        prune_graph(&mut g, sparsity);
    }
    let (g, log) = optimize(&g);
    assert!(log.all_bns_folded(&g), "{net}: BNs left behind");
    let plan = compile(&g, net, &CompileOptions::new(S10_2800.clone(), dsp)).unwrap();
    (g, plan)
}

#[test]
fn every_network_compiles_and_simulates() {
    for (net, sp) in [
        ("resnet50", 0.85),
        ("mobilenet_v1", 0.0),
        ("mobilenet_v2", 0.0),
        ("tinycnn", 0.5),
    ] {
        let (_, plan) = pipeline(net, sp, 600);
        let sim = simulate(&plan, 3).unwrap_or_else(|e| panic!("{net}: {e}"));
        assert_eq!(sim.completion_cycles.len(), 3, "{net}");
        // simulated interval should be within 2x of the analytic one
        let ratio = sim.steady_interval() as f64 / plan.interval_cycles() as f64;
        assert!(
            (0.5..2.5).contains(&ratio),
            "{net}: sim/analytic interval ratio {ratio}"
        );
    }
}

#[test]
fn pruning_then_folding_preserves_semantics() {
    let mut g = build_named("resnet50", NetConfig::test_scale()).unwrap();
    prune_graph(&mut g, 0.85);
    let (opt, _) = optimize(&g);
    equiv::assert_equivalent(&g, &opt, 2, 1e-3).unwrap();
}

#[test]
fn balanced_beats_unbalanced_interval() {
    // Fig 3's headline: balancing brings a large interval improvement.
    let (_, unbalanced) = pipeline("resnet50", 0.85, 0);
    let (_, balanced) = pipeline("resnet50", 0.85, 1500);
    let gain =
        unbalanced.interval_cycles() as f64 / balanced.interval_cycles() as f64;
    assert!(gain > 3.0, "balancing gain only {gain:.1}x");
    assert!(imbalance(&balanced.stages) < imbalance(&unbalanced.stages));
}

#[test]
fn codegen_emits_consistent_artifacts() {
    let (g, plan) = pipeline("tinycnn", 0.5, 300);
    let dir = std::env::temp_dir().join(format!("hpipe_it_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let report = codegen::generate(&plan, &g, &dir).unwrap();
    assert_eq!(report.modules, plan.stages.len());
    let plan_json = std::fs::read_to_string(dir.join("plan.json")).unwrap();
    let parsed = hpipe::util::Json::parse(&plan_json).unwrap();
    assert_eq!(
        parsed.get("stages").as_arr().unwrap().len(),
        plan.stages.len()
    );
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn smaller_device_caps_dsp_budget() {
    let g = build_named("mobilenet_v2", NetConfig::test_scale()).unwrap();
    let (g, _) = optimize(&g);
    let big = compile(&g, "m", &CompileOptions::new(S10_2800.clone(), 5000)).unwrap();
    let small = compile(&g, "m", &CompileOptions::new(S10_1650.clone(), 3000)).unwrap();
    assert!(small.totals.dsps <= big.totals.dsps.max(3000));
}

#[test]
fn analytic_model_matches_simulator_per_stage() {
    // §IV: "improved our estimates to within 1% of the actual throughput"
    // — our analytic cycles and the event simulator agree on the
    // bottleneck stage's cycle count exactly (same model), and the
    // end-to-end interval within line-handshake quantization.
    let (_, plan) = pipeline("resnet50", 0.85, 1000);
    let sim = simulate(&plan, 6).unwrap();
    let bottleneck = &plan.stages[plan.bottleneck];
    // simulator busy cycles for the bottleneck across 6 images
    let busy = sim.stage_busy[plan.bottleneck];
    let predicted = bottleneck.cycles * 6;
    let err = (busy as f64 - predicted as f64).abs() / predicted as f64;
    assert!(err < 0.05, "bottleneck busy {busy} vs predicted {predicted}");
}
