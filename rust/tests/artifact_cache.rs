//! Integration tests for the plan-artifact cache (compile-once,
//! serve-anywhere) and the refcounted shared weight store.
//!
//! The bars, in order:
//!
//! * **bitwise restore**: a model restored from its on-disk artifact
//!   must produce bit-identical outputs to the freshly compiled model —
//!   across batch sizes, sparsity levels, and every ragged-tail route
//!   (family variant, latency plan, padded fallback);
//! * **typed rejection**: stale keys, truncation and bit flips are all
//!   `GraphError::Artifact`, and `Runtime::load_graph` falls back to a
//!   fresh compile that still serves — a bad cache costs time, never
//!   correctness or availability;
//! * **one copy of each weight**: every store entry's Arc strong count
//!   is exactly (number of plans sharing the store) + 1, compiled or
//!   restored — plan-family variants add O(arena), not O(weights);
//! * **fault history**: `faults.json` survives restarts, surfaces as
//!   `restored_faults`, and never re-trips a breaker.

use hpipe::artifact::{self, CacheSpec};
use hpipe::exec::{PlanOptions, ProfileOptions, TuneOptions};
use hpipe::graph::GraphError;
use hpipe::nets::{tiny_cnn, NetConfig};
use hpipe::runtime::{LoadedModel, Runtime};
use hpipe::sparsity::prune_graph;
use hpipe::transform::optimize;
use hpipe::util::{Json, Rng};
use std::fs;
use std::path::{Path, PathBuf};

fn temp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("hpipe_plancache_{tag}_{}", std::process::id()));
    let _ = fs::remove_dir_all(&d);
    fs::create_dir_all(&d).unwrap();
    d
}

/// TinyCNN at test scale, optionally pruned, after the transform passes
/// — the same shape `Runtime::load_manifest` serves.
fn graph(sparsity: f64) -> hpipe::graph::Graph {
    let mut g = tiny_cnn(NetConfig::test_scale());
    if sparsity > 0.0 {
        prune_graph(&mut g, sparsity);
    }
    let (g, _) = optimize(&g);
    g
}

/// f32 outputs as raw bit patterns: `assert_eq!` on these is a strict
/// bitwise comparison (no -0.0 / NaN equality holes).
fn bits(outs: &[Vec<f32>]) -> Vec<Vec<u32>> {
    outs.iter()
        .map(|o| o.iter().map(|x| x.to_bits()).collect())
        .collect()
}

fn block_for(m: &LoadedModel, batch: usize, seed: u64) -> (Vec<f32>, usize) {
    let per: usize = m.input_shape.iter().product::<usize>() / batch;
    let mut rng = Rng::new(seed);
    let block = (0..batch * per).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    (block, per)
}

#[test]
fn artifact_restore_is_bitwise_identical_across_batch_sparsity_and_tails() {
    for &batch in &[1usize, 3, 8] {
        for &sparsity in &[0.0f64, 0.5, 0.9] {
            let g = graph(sparsity);
            let tag = format!("bitwise_{batch}_{}", (sparsity * 10.0) as u32);
            let dir = temp_dir(&tag);
            let mut fresh_rt = Runtime::cpu(Path::new(".")).unwrap().with_plan_cache(&dir);
            fresh_rt.load_graph("m", &g, batch).unwrap();
            assert_eq!((fresh_rt.cache_hits, fresh_rt.cache_misses), (0, 1));
            let mut cached_rt = Runtime::cpu(Path::new(".")).unwrap().with_plan_cache(&dir);
            cached_rt.load_graph("m", &g, batch).unwrap();
            assert_eq!(
                (cached_rt.cache_hits, cached_rt.cache_misses),
                (1, 0),
                "expected a cache hit for batch={batch} sparsity={sparsity}"
            );
            let fresh = fresh_rt.model("m").unwrap();
            let cached = cached_rt.model("m").unwrap();
            assert_eq!(fresh.variant_batches(), cached.variant_batches());
            let (block, per) = block_for(fresh, batch, 0xA1 + batch as u64);
            assert_eq!(
                bits(&fresh.run_all(&block).unwrap()),
                bits(&cached.run_all(&block).unwrap()),
                "full batch, batch={batch} sparsity={sparsity}"
            );
            // every ragged tail routes identically: a family variant,
            // the latency plan (k=1), or the padded fallback
            for k in 1..batch {
                let a = fresh.run_tail(&block[..k * per], k).unwrap();
                let b = cached.run_tail(&block[..k * per], k).unwrap();
                assert_eq!(bits(&a), bits(&b), "tail k={k}, batch={batch} sparsity={sparsity}");
            }
            let _ = fs::remove_dir_all(&dir);
        }
    }
}

#[test]
fn corrupted_truncated_and_stale_artifacts_reject_typed_and_fall_back() {
    let g = graph(0.5);
    let dir = temp_dir("corrupt");
    let mut rt = Runtime::cpu(Path::new(".")).unwrap().with_plan_cache(&dir);
    rt.load_graph("m", &g, 8).unwrap();
    assert_eq!(rt.cache_misses, 1);
    // the key `load_graph` used: default plan options, the default
    // family {B/4, B/2} = {2, 4}, the runtime's default threads/team
    let spec = CacheSpec {
        opts: PlanOptions::default(),
        batch: 8,
        family: vec![2, 4],
        threads: 1,
        team: 1,
        autotune: false,
        tune_cores: 0,
    };
    let key = artifact::cache_key(&g, &spec);
    let model_dir = dir.join("m");
    artifact::load(&model_dir, key).expect("pristine artifact must load with its own key");
    // stale key (config or graph changed) -> typed rejection
    let err = artifact::load(&model_dir, key ^ 1).unwrap_err();
    assert!(matches!(err, GraphError::Artifact(_)), "stale key: {err:?}");
    // truncation -> typed rejection
    let bin_path = model_dir.join("plan.bin");
    let pristine = fs::read(&bin_path).unwrap();
    fs::write(&bin_path, &pristine[..pristine.len() / 2]).unwrap();
    let err = artifact::load(&model_dir, key).unwrap_err();
    assert!(matches!(err, GraphError::Artifact(_)), "truncation: {err:?}");
    // ...and load_graph falls back to a fresh compile that still
    // serves (re-persisting a pristine artifact as it goes)
    let mut rt2 = Runtime::cpu(Path::new(".")).unwrap().with_plan_cache(&dir);
    rt2.load_graph("m", &g, 8).unwrap();
    assert_eq!((rt2.cache_hits, rt2.cache_misses), (0, 1));
    let m = rt2.model("m").unwrap();
    let (block, _) = block_for(m, 8, 7);
    m.run_all(&block).unwrap();
    // bit flip (in the artifact rt2 just re-saved) -> typed rejection
    let mut flipped = fs::read(&bin_path).unwrap();
    let i = flipped.len() / 3;
    flipped[i] ^= 0x10;
    fs::write(&bin_path, &flipped).unwrap();
    let err = artifact::load(&model_dir, key).unwrap_err();
    assert!(matches!(err, GraphError::Artifact(_)), "bit flip: {err:?}");
    let mut rt3 = Runtime::cpu(Path::new(".")).unwrap().with_plan_cache(&dir);
    rt3.load_graph("m", &g, 8).unwrap();
    assert_eq!((rt3.cache_hits, rt3.cache_misses), (0, 1));
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn config_or_graph_change_invalidates_the_cache_key() {
    let g = graph(0.0);
    let dir = temp_dir("config");
    let mut rt = Runtime::cpu(Path::new(".")).unwrap().with_plan_cache(&dir);
    rt.load_graph("m", &g, 4).unwrap();
    assert_eq!(rt.cache_misses, 1);
    // same config -> hit
    let mut same = Runtime::cpu(Path::new(".")).unwrap().with_plan_cache(&dir);
    same.load_graph("m", &g, 4).unwrap();
    assert_eq!((same.cache_hits, same.cache_misses), (1, 0));
    // different team -> stale key -> recompiled (and re-persisted)
    let mut other = Runtime::cpu(Path::new(".")).unwrap().with_team(2).with_plan_cache(&dir);
    other.load_graph("m", &g, 4).unwrap();
    assert_eq!((other.cache_hits, other.cache_misses), (0, 1));
    // different graph bytes (pruned weights) -> stale key
    let mut pruned = Runtime::cpu(Path::new(".")).unwrap().with_team(2).with_plan_cache(&dir);
    pruned.load_graph("m", &graph(0.5), 4).unwrap();
    assert_eq!((pruned.cache_hits, pruned.cache_misses), (0, 1));
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn plan_family_variants_share_one_copy_of_each_weight() {
    let g = graph(0.5);
    // without variants: primary + latency share the store
    let base = LoadedModel::from_graph("m", &g, 8).unwrap();
    // with variants {2, 4}: two more plans join the same store
    let mut m = LoadedModel::from_graph("m", &g, 8).unwrap();
    m.add_plan_family(&g, &[2, 4]).unwrap();
    assert_eq!(m.variant_batches(), vec![2, 4]);
    let n_plans = 2 + m.variant_batches().len();
    let refs = m.store().refcounts();
    assert!(!refs.is_empty(), "store must hold the model's weights");
    for (key, count) in &refs {
        assert_eq!(
            *count,
            n_plans + 1,
            "store entry {key}: expected {n_plans} plans + the store itself, got {count}"
        );
    }
    // the variants added zero weight entries and zero weight bytes —
    // their cost is plan-private (arenas), not shared weights
    assert_eq!(m.store().len(), base.store().len());
    assert_eq!(m.store().total_bytes(), base.store().total_bytes());
    let (shared, _) = m.weight_bytes();
    assert_eq!(shared, m.store().total_bytes());

    // the same invariant must hold for a model restored from disk
    let dir = temp_dir("refcounts");
    let family = [2usize, 4];
    let mk = || {
        Runtime::cpu(Path::new("."))
            .unwrap()
            .with_plan_family(&family)
            .with_plan_cache(&dir)
    };
    let mut rt = mk();
    rt.load_graph("m", &g, 8).unwrap();
    let mut rt2 = mk();
    rt2.load_graph("m", &g, 8).unwrap();
    assert_eq!((rt2.cache_hits, rt2.cache_misses), (1, 0));
    let restored = rt2.model("m").unwrap();
    for (key, count) in &restored.store().refcounts() {
        assert_eq!(*count, n_plans + 1, "restored store entry {key}: got {count}");
    }
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn autotuned_artifact_restores_measured_cuts_without_reprofiling() {
    let g = graph(0.5);
    let dir = temp_dir("tuned");
    let opts = TuneOptions {
        cores: 2,
        profile: ProfileOptions { warmup: 0, runs: 1, ..Default::default() },
    };
    let mk = || {
        Runtime::cpu(Path::new("."))
            .unwrap()
            .with_autotune(opts)
            .with_plan_cache(&dir)
    };
    let mut rt = mk();
    rt.load_graph("m", &g, 8).unwrap();
    assert_eq!(rt.cache_misses, 1);
    let mut rt2 = mk();
    rt2.load_graph("m", &g, 8).unwrap();
    assert_eq!((rt2.cache_hits, rt2.cache_misses), (1, 0));
    let (a, b) = (rt.model("m").unwrap(), rt2.model("m").unwrap());
    // the calibration report came back from disk, and the restored
    // cuts reproduce the tuned pipeline exactly
    assert!(b.tune_report().is_some(), "restored model keeps its TuneReport");
    assert_eq!(a.pipeline().num_stages(), b.pipeline().num_stages());
    let (block, _) = block_for(a, 8, 0xB2);
    assert_eq!(bits(&a.run_all(&block).unwrap()), bits(&b.run_all(&block).unwrap()));
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn fault_history_persists_across_restarts_without_retripping() {
    let g = graph(0.0);
    let dir = temp_dir("faults");
    {
        let mut rt = Runtime::cpu(Path::new(".")).unwrap().with_plan_cache(&dir);
        rt.load_graph("m", &g, 4).unwrap();
        assert_eq!(rt.persist_faults(), 1);
    }
    // splice in a history as if a previous run faulted and tripped
    let path = dir.join("m").join("faults.json");
    fs::write(
        &path,
        r#"{"faults": 9, "retries": 3, "trips": 2, "recoveries": 1,
            "time_degraded_ns": 5000, "last_cooldown_ns": 100000}"#,
    )
    .unwrap();
    let mut rt = Runtime::cpu(Path::new(".")).unwrap().with_plan_cache(&dir);
    rt.load_graph("m", &g, 4).unwrap();
    assert_eq!(rt.cache_hits, 1);
    let m = rt.model("m").unwrap();
    let restored = m.restored_faults();
    assert_eq!(restored.faults, 9);
    assert_eq!(restored.retries, 3);
    assert_eq!(restored.trips, 2);
    assert_eq!(restored.recoveries, 1);
    assert_eq!(restored.time_degraded_ns, 5_000);
    assert_eq!(m.restored_cooldown_ns(), 100_000);
    // history informs reporting only — breakers start closed
    assert!(!m.is_degraded(), "restored history must not re-trip breakers");
    let (block, _) = block_for(m, 4, 11);
    m.run_all(&block).unwrap();
    // persisting merges the restored history with this run's counters
    assert_eq!(rt.persist_faults(), 1);
    let j = Json::parse(&fs::read_to_string(&path).unwrap()).unwrap();
    assert_eq!(j.get("faults").as_f64(), Some(9.0));
    assert_eq!(j.get("trips").as_f64(), Some(2.0));
    assert_eq!(j.get("last_cooldown_ns").as_f64(), Some(100_000.0));
    let _ = fs::remove_dir_all(&dir);
}
