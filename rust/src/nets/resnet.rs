//! ResNet-50 V1 graph builder (official TensorFlow r1.11 structure).
//!
//! The graph deliberately includes the nodes the HPIPE compiler has to
//! clean up: a standalone `Pad` before the 7×7 stem conv (the official
//! model's "fixed padding"), `FusedBatchNorm` after every convolution,
//! and `MaxPool` between the stem BN and the first bottleneck — the exact
//! op sandwich Fig 5 of the paper shows. Layer names follow the
//! caffe-style scheme used in the paper's Fig 3 (res2a_branch2a, …).

use super::{NetBuilder, NetConfig};
use crate::graph::{Graph, Padding};

/// Stage specification: (blocks, base output channels of the 1x1s).
const STAGES: [(usize, usize); 4] = [(3, 64), (4, 128), (6, 256), (3, 512)];
const EXPANSION: usize = 4;

/// Build ResNet-50 V1. ~25.5M parameters at full scale.
pub fn resnet50(cfg: NetConfig) -> Graph {
    let mut b = NetBuilder::new(cfg.seed);
    let stem_c = cfg.ch(64);

    let x = b.input("input", cfg.input_size, cfg.input_size, 3);
    // Official model: fixed pad 3 then 7x7/2 VALID (not SAME) — gives the
    // compiler a Pad node to merge (§IV "merge padding operations").
    let pad = b.g.op(
        "conv1_pad",
        crate::graph::Op::Pad { pads: (3, 3, 3, 3) },
        &[&x],
    );
    let c1 = b.conv("conv1", &pad, 7, 3, stem_c, 2, Padding::Valid);
    let bn1 = b.bn("bn_conv1", &c1, stem_c);
    let r1 = b.relu("conv1_relu", &bn1);
    let pool1 = b.g.op(
        "pool1",
        crate::graph::Op::MaxPool {
            ksize: (3, 3),
            stride: (2, 2),
            padding: Padding::Same,
        },
        &[&r1],
    );

    let mut prev = pool1;
    let mut prev_c = stem_c;
    for (stage_idx, &(blocks, base)) in STAGES.iter().enumerate() {
        let stage = stage_idx + 2; // res2..res5
        let mid_c = cfg.ch(base);
        let out_c = cfg.ch(base * EXPANSION);
        for block in 0..blocks {
            let tag = (b'a' + block as u8) as char;
            let prefix = format!("res{stage}{tag}");
            let stride = if stage > 2 && block == 0 { 2 } else { 1 };

            // Projection shortcut on the first block of each stage.
            let shortcut = if block == 0 {
                let sc = b.conv(
                    &format!("{prefix}_branch1"),
                    &prev,
                    1,
                    prev_c,
                    out_c,
                    stride,
                    Padding::Same,
                );
                b.bn(&format!("bn{stage}{tag}_branch1"), &sc, out_c)
            } else {
                prev.clone()
            };

            let c_a = b.conv(
                &format!("{prefix}_branch2a"),
                &prev,
                1,
                prev_c,
                mid_c,
                stride,
                Padding::Same,
            );
            let bn_a = b.bn(&format!("bn{stage}{tag}_branch2a"), &c_a, mid_c);
            let r_a = b.relu(&format!("{prefix}_branch2a_relu"), &bn_a);

            let c_b = b.conv(
                &format!("{prefix}_branch2b"),
                &r_a,
                3,
                mid_c,
                mid_c,
                1,
                Padding::Same,
            );
            let bn_b = b.bn(&format!("bn{stage}{tag}_branch2b"), &c_b, mid_c);
            let r_b = b.relu(&format!("{prefix}_branch2b_relu"), &bn_b);

            let c_c = b.conv(
                &format!("{prefix}_branch2c"),
                &r_b,
                1,
                mid_c,
                out_c,
                1,
                Padding::Same,
            );
            let bn_c = b.bn(&format!("bn{stage}{tag}_branch2c"), &c_c, out_c);

            let add = b.g.op(
                &format!("{prefix}"),
                crate::graph::Op::Add,
                &[&shortcut, &bn_c],
            );
            prev = b.relu(&format!("{prefix}_relu"), &add);
            prev_c = out_c;
        }
    }

    b.head(&prev, prev_c, cfg.classes);
    b.g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Op;

    #[test]
    fn full_scale_structure() {
        let g = resnet50(NetConfig::imagenet());
        g.validate().unwrap();
        let convs = g
            .nodes
            .iter()
            .filter(|n| matches!(n.op, Op::Conv2D { .. }))
            .count();
        // 1 stem + 16 blocks × 3 + 4 projection shortcuts = 53 convs
        assert_eq!(convs, 53);
        let bns = g
            .nodes
            .iter()
            .filter(|n| matches!(n.op, Op::FusedBatchNorm { .. }))
            .count();
        assert_eq!(bns, 53);
        // ~25.5M parameters (conv weights + BN params + FC)
        let params = g.param_count();
        assert!(
            (24_000_000..28_000_000).contains(&params),
            "params={params}"
        );
        // ~3.8 GMACs at 224x224 (paper/literature figure ~3.86e9 +
        // shortcut projections)
        let macs = g.macs().unwrap();
        assert!(
            (3_500_000_000..4_300_000_000u64).contains(&macs),
            "macs={macs}"
        );
    }

    #[test]
    fn spatial_shapes_match_reference() {
        let g = resnet50(NetConfig::imagenet());
        let s = g.infer_shapes().unwrap();
        assert_eq!(s["conv1"], vec![1, 112, 112, 64]);
        assert_eq!(s["pool1"], vec![1, 56, 56, 64]);
        assert_eq!(s["res2c_relu"], vec![1, 56, 56, 256]);
        assert_eq!(s["res3d_relu"], vec![1, 28, 28, 512]);
        assert_eq!(s["res4f_relu"], vec![1, 14, 14, 1024]);
        assert_eq!(s["res5c_relu"], vec![1, 7, 7, 2048]);
        assert_eq!(s["predictions"], vec![1, 1000]);
    }

    #[test]
    fn test_scale_runs_in_interpreter() {
        use std::collections::BTreeMap;
        let cfg = NetConfig::test_scale();
        let g = resnet50(cfg);
        g.validate().unwrap();
        let mut feeds = BTreeMap::new();
        let mut rng = crate::util::Rng::new(1);
        feeds.insert(
            "input".to_string(),
            crate::graph::Tensor::randn(&[1, 32, 32, 3], &mut rng, 1.0),
        );
        let outs = crate::interp::run_outputs(&g, &feeds).unwrap();
        assert_eq!(outs[0].shape, vec![1, 10]);
        let s: f32 = outs[0].data.iter().sum();
        assert!((s - 1.0).abs() < 1e-4, "softmax sums to {s}");
    }

    /// Smoke path through the compiled executor: the full (pruned +
    /// folded) test-scale network must classify identically to the
    /// interpreter oracle.
    #[test]
    fn test_scale_runs_in_executor() {
        use std::collections::BTreeMap;
        let mut g = resnet50(NetConfig::test_scale());
        crate::sparsity::prune_graph(&mut g, 0.85);
        let (g, _) = crate::transform::optimize(&g);
        let plan = crate::exec::ExecutionPlan::build(&g).unwrap();
        assert!(plan.stats().sparse_convs > 0, "{:?}", plan.stats());
        let mut feeds = BTreeMap::new();
        let mut rng = crate::util::Rng::new(8);
        feeds.insert(
            "input".to_string(),
            crate::graph::Tensor::randn(&[1, 32, 32, 3], &mut rng, 1.0),
        );
        let got = plan.run(&feeds).unwrap();
        let want = crate::interp::run_outputs(&g, &feeds).unwrap();
        assert_eq!(
            crate::interp::argmax(&got[0]),
            crate::interp::argmax(&want[0])
        );
        for (a, b) in got[0].data.iter().zip(&want[0].data) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn has_pad_node_for_compiler_to_merge() {
        let g = resnet50(NetConfig::test_scale());
        assert!(matches!(
            g.get("conv1_pad").unwrap().op,
            Op::Pad { pads: (3, 3, 3, 3) }
        ));
    }
}
