//! MobileNet-V1 and MobileNet-V2 graph builders.
//!
//! These are the paper's *dense* evaluation targets (Table IV): no
//! pruning, but heavy use of `DepthwiseConv2d` + pointwise `Conv2D` —
//! the layer mix that exercises HPIPE's depthwise module and (for V2)
//! exhausts the input-channel unroll dimension, reproducing the paper's
//! "we ran out of input channels to unroll" 51%-DSP result.

use super::{NetBuilder, NetConfig};
use crate::graph::{Graph, Op, Padding};

/// MobileNet-V1 separable-block schedule: (stride, output channels).
const V1_BLOCKS: [(usize, usize); 13] = [
    (1, 64),
    (2, 128),
    (1, 128),
    (2, 256),
    (1, 256),
    (2, 512),
    (1, 512),
    (1, 512),
    (1, 512),
    (1, 512),
    (1, 512),
    (2, 1024),
    (1, 1024),
];

/// Build MobileNet-V1 (~4.2M params at full scale).
pub fn mobilenet_v1(cfg: NetConfig) -> Graph {
    let mut b = NetBuilder::new(cfg.seed ^ 0xA1);
    let x = b.input("input", cfg.input_size, cfg.input_size, 3);
    let mut c = cfg.ch(32);
    let conv0 = b.conv("Conv2d_0", &x, 3, 3, c, 2, Padding::Same);
    let bn0 = b.bn("Conv2d_0/BatchNorm", &conv0, c);
    let mut prev = b.relu6("Conv2d_0/Relu6", &bn0);

    for (i, &(stride, cout)) in V1_BLOCKS.iter().enumerate() {
        let n = i + 1;
        let co = cfg.ch(cout);
        let dw = b.depthwise(
            &format!("Conv2d_{n}_depthwise"),
            &prev,
            3,
            c,
            stride,
            Padding::Same,
        );
        let dwbn = b.bn(&format!("Conv2d_{n}_depthwise/BatchNorm"), &dw, c);
        let dwr = b.relu6(&format!("Conv2d_{n}_depthwise/Relu6"), &dwbn);
        let pw = b.conv(
            &format!("Conv2d_{n}_pointwise"),
            &dwr,
            1,
            c,
            co,
            1,
            Padding::Same,
        );
        let pwbn = b.bn(&format!("Conv2d_{n}_pointwise/BatchNorm"), &pw, co);
        prev = b.relu6(&format!("Conv2d_{n}_pointwise/Relu6"), &pwbn);
        c = co;
    }

    b.head(&prev, c, cfg.classes);
    b.g
}

/// MobileNet-V2 inverted-residual schedule:
/// (expansion t, output channels c, repeats n, first stride s).
const V2_BLOCKS: [(usize, usize, usize, usize); 7] = [
    (1, 16, 1, 1),
    (6, 24, 2, 2),
    (6, 32, 3, 2),
    (6, 64, 4, 2),
    (6, 96, 3, 1),
    (6, 160, 3, 2),
    (6, 320, 1, 1),
];

/// Build MobileNet-V2 (~3.5M params at full scale).
pub fn mobilenet_v2(cfg: NetConfig) -> Graph {
    let mut b = NetBuilder::new(cfg.seed ^ 0xA2);
    let x = b.input("input", cfg.input_size, cfg.input_size, 3);
    let stem_c = cfg.ch(32);
    let conv0 = b.conv("Conv", &x, 3, 3, stem_c, 2, Padding::Same);
    let bn0 = b.bn("Conv/BatchNorm", &conv0, stem_c);
    let mut prev = b.relu6("Conv/Relu6", &bn0);
    let mut c = stem_c;

    let mut block_id = 0usize;
    for &(t, cout, n, s) in V2_BLOCKS.iter() {
        let co = cfg.ch(cout);
        for rep in 0..n {
            let stride = if rep == 0 { s } else { 1 };
            let prefix = if block_id == 0 {
                "expanded_conv".to_string()
            } else {
                format!("expanded_conv_{block_id}")
            };
            let expanded = c * t;

            // Expansion 1x1 (skipped when t == 1, as in the real model).
            let mut h = prev.clone();
            let mut hc = c;
            if t != 1 {
                let e = b.conv(&format!("{prefix}/expand"), &h, 1, c, expanded, 1, Padding::Same);
                let ebn = b.bn(&format!("{prefix}/expand/BatchNorm"), &e, expanded);
                h = b.relu6(&format!("{prefix}/expand/Relu6"), &ebn);
                hc = expanded;
            }

            let dw = b.depthwise(
                &format!("{prefix}/depthwise"),
                &h,
                3,
                hc,
                stride,
                Padding::Same,
            );
            let dwbn = b.bn(&format!("{prefix}/depthwise/BatchNorm"), &dw, hc);
            let dwr = b.relu6(&format!("{prefix}/depthwise/Relu6"), &dwbn);

            // Linear projection (no activation).
            let p = b.conv(&format!("{prefix}/project"), &dwr, 1, hc, co, 1, Padding::Same);
            let pbn = b.bn(&format!("{prefix}/project/BatchNorm"), &p, co);

            prev = if stride == 1 && c == co {
                b.g.op(&format!("{prefix}/add"), Op::Add, &[&prev, &pbn])
            } else {
                pbn
            };
            c = co;
            block_id += 1;
        }
    }

    // Final 1x1 to 1280 channels.
    let last_c = cfg.ch(1280);
    let convl = b.conv("Conv_1", &prev, 1, c, last_c, 1, Padding::Same);
    let bnl = b.bn("Conv_1/BatchNorm", &convl, last_c);
    let rl = b.relu6("Conv_1/Relu6", &bnl);
    b.head(&rl, last_c, cfg.classes);
    b.g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn v1_structure() {
        let g = mobilenet_v1(NetConfig::imagenet());
        g.validate().unwrap();
        let dw = g
            .nodes
            .iter()
            .filter(|n| matches!(n.op, Op::DepthwiseConv2d { .. }))
            .count();
        assert_eq!(dw, 13);
        let params = g.param_count();
        assert!((3_800_000..4_800_000).contains(&params), "params={params}");
        // ~570 MMACs
        let macs = g.macs().unwrap();
        assert!(
            (500_000_000..650_000_000u64).contains(&macs),
            "macs={macs}"
        );
        let s = g.infer_shapes().unwrap();
        assert_eq!(s["Conv2d_13_pointwise/Relu6"], vec![1, 7, 7, 1024]);
    }

    #[test]
    fn v2_structure() {
        let g = mobilenet_v2(NetConfig::imagenet());
        g.validate().unwrap();
        let dw = g
            .nodes
            .iter()
            .filter(|n| matches!(n.op, Op::DepthwiseConv2d { .. }))
            .count();
        assert_eq!(dw, 17); // 1+2+3+4+3+3+1 inverted residual blocks
        let adds = g
            .nodes
            .iter()
            .filter(|n| matches!(n.op, Op::Add))
            .count();
        assert_eq!(adds, 10); // repeats with stride 1 and matching dims
        let params = g.param_count();
        assert!((3_000_000..4_000_000).contains(&params), "params={params}");
        let s = g.infer_shapes().unwrap();
        assert_eq!(s["Conv_1/Relu6"], vec![1, 7, 7, 1280]);
    }

    #[test]
    fn v2_test_scale_interprets() {
        use std::collections::BTreeMap;
        let cfg = NetConfig::test_scale();
        let g = mobilenet_v2(cfg);
        let mut feeds = BTreeMap::new();
        let mut rng = crate::util::Rng::new(2);
        feeds.insert(
            "input".to_string(),
            crate::graph::Tensor::randn(&[1, 32, 32, 3], &mut rng, 1.0),
        );
        let outs = crate::interp::run_outputs(&g, &feeds).unwrap();
        assert_eq!(outs[0].shape, vec![1, 10]);
    }

    /// Smoke path through the compiled executor (covers the depthwise
    /// kernel, Relu6 fusion and the V2 inverted-residual Adds).
    #[test]
    fn v1_and_v2_test_scale_run_in_executor() {
        use std::collections::BTreeMap;
        for (seed, g) in [
            (31u64, mobilenet_v1(NetConfig::test_scale())),
            (32, mobilenet_v2(NetConfig::test_scale())),
        ] {
            let plan = crate::exec::ExecutionPlan::build(&g).unwrap();
            let mut feeds = BTreeMap::new();
            let mut rng = crate::util::Rng::new(seed);
            feeds.insert(
                "input".to_string(),
                crate::graph::Tensor::randn(&[1, 32, 32, 3], &mut rng, 1.0),
            );
            let got = plan.run(&feeds).unwrap();
            let want = crate::interp::run_outputs(&g, &feeds).unwrap();
            for (a, b) in got[0].data.iter().zip(&want[0].data) {
                assert!((a - b).abs() < 1e-4, "{a} vs {b}");
            }
        }
    }

    #[test]
    fn v1_channel_progression() {
        let g = mobilenet_v1(NetConfig::imagenet());
        let s = g.infer_shapes().unwrap();
        assert_eq!(s["Conv2d_0"], vec![1, 112, 112, 32]);
        assert_eq!(s["Conv2d_1_pointwise"], vec![1, 112, 112, 64]);
        assert_eq!(s["Conv2d_6_depthwise"], vec![1, 14, 14, 256]);
    }
}
