//! TinyCNN — the end-to-end validation model.
//!
//! A small CNN classifier used to prove the whole stack composes: the
//! same topology is built in Python (`python/compile/model.py`), trained
//! for a few hundred steps on synthetic data, exported as a graphdef,
//! and served by the Rust coordinator through the compiled execution
//! engine. The Rust builder below is
//! structurally identical (a test in `rust/tests/` cross-checks against
//! the Python-exported graphdef when artifacts are present), so the
//! compiler/simulator pipeline can also run on it.

use super::{NetBuilder, NetConfig};
use crate::graph::{Graph, Op, Padding};

/// Input resolution of TinyCNN (kept small so interpret-mode Pallas
/// lowering and the naive interpreter are both fast).
pub const TINY_INPUT: usize = 16;
/// Channel plan: stem and two stages.
pub const TINY_CHANNELS: [usize; 3] = [16, 32, 64];
pub const TINY_CLASSES: usize = 10;

/// Build TinyCNN. `cfg.classes`/`cfg.seed` are honored; resolution and
/// widths are fixed so Rust and Python always agree structurally.
pub fn tiny_cnn(cfg: NetConfig) -> Graph {
    let mut b = NetBuilder::new(cfg.seed ^ 0x717);
    let x = b.input("input", TINY_INPUT, TINY_INPUT, 3);

    let mut prev = x;
    let mut cin = 3;
    for (i, &cout) in TINY_CHANNELS.iter().enumerate() {
        let c = b.conv(
            &format!("conv{i}"),
            &prev,
            3,
            cin,
            cout,
            1,
            Padding::Same,
        );
        let bi = b.bias(&format!("conv{i}/biasadd"), &c, cout);
        let r = b.relu(&format!("conv{i}/relu"), &bi);
        prev = b.g.op(
            &format!("pool{i}"),
            Op::MaxPool {
                ksize: (2, 2),
                stride: (2, 2),
                padding: Padding::Valid,
            },
            &[&r],
        );
        cin = cout;
    }

    b.head(&prev, cin, TINY_CLASSES);
    b.g
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeMap;

    #[test]
    fn structure_and_shapes() {
        let g = tiny_cnn(NetConfig::test_scale());
        g.validate().unwrap();
        let s = g.infer_shapes().unwrap();
        assert_eq!(s["pool0"], vec![1, 8, 8, 16]);
        assert_eq!(s["pool1"], vec![1, 4, 4, 32]);
        assert_eq!(s["pool2"], vec![1, 2, 2, 64]);
        assert_eq!(s["predictions"], vec![1, TINY_CLASSES]);
        // small enough to train/serve: well under 100k params
        assert!(g.param_count() < 100_000, "params={}", g.param_count());
    }

    #[test]
    fn runs_end_to_end_in_interpreter() {
        let g = tiny_cnn(NetConfig::test_scale());
        let mut rng = crate::util::Rng::new(4);
        let mut feeds = BTreeMap::new();
        feeds.insert(
            "input".to_string(),
            crate::graph::Tensor::randn(&[1, TINY_INPUT, TINY_INPUT, 3], &mut rng, 1.0),
        );
        let outs = crate::interp::run_outputs(&g, &feeds).unwrap();
        let s: f32 = outs[0].data.iter().sum();
        assert!((s - 1.0).abs() < 1e-5);
    }

    /// Same smoke path through the compiled executor (the serving-side
    /// twin of `runs_end_to_end_in_interpreter`).
    #[test]
    fn runs_end_to_end_in_executor() {
        let g = tiny_cnn(NetConfig::test_scale());
        let plan = crate::exec::ExecutionPlan::build(&g).unwrap();
        let mut rng = crate::util::Rng::new(4);
        let mut feeds = BTreeMap::new();
        feeds.insert(
            "input".to_string(),
            crate::graph::Tensor::randn(&[1, TINY_INPUT, TINY_INPUT, 3], &mut rng, 1.0),
        );
        let outs = plan.run(&feeds).unwrap();
        let s: f32 = outs[0].data.iter().sum();
        assert!((s - 1.0).abs() < 1e-5);
        // fused conv/bias/relu chains must have been formed
        assert!(plan.stats().fused_chains >= 3);
    }

    #[test]
    fn deterministic_given_seed() {
        let a = tiny_cnn(NetConfig::test_scale());
        let b = tiny_cnn(NetConfig::test_scale());
        let wa = a.get("conv0/weights").unwrap().value.as_ref().unwrap();
        let wb = b.get("conv0/weights").unwrap().value.as_ref().unwrap();
        assert_eq!(wa.data, wb.data);
    }
}
