//! Builders for the networks the paper evaluates.
//!
//! §V: "the official TensorFlow ResNet-50 V1 (r1.11), MobileNet-V1, and
//! MobileNet-V2 models". We reconstruct those graphs node-for-node
//! (including the `FusedBatchNorm` and `Pad` nodes the compiler must fold
//! away), with synthetically-initialized weights (He-normal — see
//! DESIGN.md §Hardware-Adaptation for why this preserves the paper's
//! compile/balance/simulate behaviour). Every builder takes a [`NetConfig`]
//! so tests can build reduced-resolution / reduced-width variants that the
//! reference interpreter can execute quickly.

pub mod mobilenet;
pub mod resnet;
pub mod tiny;

use crate::graph::{Graph, Op, Padding, Tensor};
use crate::util::Rng;

pub use mobilenet::{mobilenet_v1, mobilenet_v2};
pub use resnet::resnet50;
pub use tiny::tiny_cnn;

/// Scaling knobs shared by all builders.
#[derive(Clone, Copy, Debug)]
pub struct NetConfig {
    /// Input spatial resolution (paper: 224).
    pub input_size: usize,
    /// Channel width multiplier (paper: 1.0).
    pub width: f64,
    /// Number of classes (paper: 1000 ImageNet classes).
    pub classes: usize,
    /// RNG seed for weight init.
    pub seed: u64,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig {
            input_size: 224,
            width: 1.0,
            classes: 1000,
            seed: 0x411,
        }
    }
}

impl NetConfig {
    /// Full-size ImageNet configuration (the paper's).
    pub fn imagenet() -> NetConfig {
        NetConfig::default()
    }

    /// Small configuration usable by the f32 interpreter in tests.
    pub fn test_scale() -> NetConfig {
        NetConfig {
            input_size: 32,
            width: 0.25,
            classes: 10,
            seed: 7,
        }
    }

    /// Apply the width multiplier, keeping channel counts divisible by 8
    /// (MobileNet convention) and at least 8.
    pub fn ch(&self, base: usize) -> usize {
        let scaled = (base as f64 * self.width).round() as usize;
        (scaled.div_ceil(8) * 8).max(8)
    }
}

/// Helper that accumulates a graph plus deterministic weight init.
pub struct NetBuilder {
    pub g: Graph,
    pub rng: Rng,
}

impl NetBuilder {
    pub fn new(seed: u64) -> NetBuilder {
        NetBuilder {
            g: Graph::new(),
            rng: Rng::new(seed),
        }
    }

    pub fn input(&mut self, name: &str, h: usize, w: usize, c: usize) -> String {
        self.g.op(name, Op::Placeholder { shape: vec![1, h, w, c] }, &[])
    }

    /// Conv2D with He-initialized weights.
    #[allow(clippy::too_many_arguments)] // full conv signature mirrors the op
    pub fn conv(
        &mut self,
        name: &str,
        input: &str,
        k: usize,
        cin: usize,
        cout: usize,
        stride: usize,
        padding: Padding,
    ) -> String {
        let fan_in = k * k * cin;
        let std = (2.0 / fan_in as f64).sqrt() as f32;
        let w = Tensor::randn(&[k, k, cin, cout], &mut self.rng, std);
        let wname = format!("{name}/weights");
        self.g.constant(&wname, w);
        self.g.op(
            name,
            Op::Conv2D { stride: (stride, stride), padding },
            &[input, &wname],
        )
    }

    pub fn depthwise(
        &mut self,
        name: &str,
        input: &str,
        k: usize,
        cin: usize,
        stride: usize,
        padding: Padding,
    ) -> String {
        let std = (2.0 / (k * k) as f64).sqrt() as f32;
        let w = Tensor::randn(&[k, k, cin, 1], &mut self.rng, std);
        let wname = format!("{name}/depthwise_weights");
        self.g.constant(&wname, w);
        self.g.op(
            name,
            Op::DepthwiseConv2d { stride: (stride, stride), padding },
            &[input, &wname],
        )
    }

    /// FusedBatchNorm with realistic inference-time statistics. Scale is
    /// kept strictly positive so the compiler's move-Mul-past-ReLU
    /// transformation is valid (§IV).
    pub fn bn(&mut self, name: &str, input: &str, c: usize) -> String {
        let mk = |rng: &mut Rng, f: &mut dyn FnMut(&mut Rng) -> f32| {
            Tensor {
                shape: vec![c],
                data: (0..c).map(|_| f(rng)).collect(),
            }
        };
        let scale = mk(&mut self.rng, &mut |r| 0.05 + r.normal_f32(1.0, 0.1).abs());
        let offset = mk(&mut self.rng, &mut |r| r.normal_f32(0.0, 0.1));
        let mean = mk(&mut self.rng, &mut |r| r.normal_f32(0.0, 0.1));
        let var = mk(&mut self.rng, &mut |r| 0.5 + r.normal_f32(1.0, 0.1).abs());
        let sn = self.g.constant(&format!("{name}/gamma"), scale);
        let on = self.g.constant(&format!("{name}/beta"), offset);
        let mn = self.g.constant(&format!("{name}/moving_mean"), mean);
        let vn = self.g.constant(&format!("{name}/moving_variance"), var);
        self.g.op(
            name,
            Op::FusedBatchNorm { epsilon: 1.001e-5 },
            &[input, &sn, &on, &mn, &vn],
        )
    }

    pub fn bias(&mut self, name: &str, input: &str, c: usize) -> String {
        let b = Tensor::randn(&[c], &mut self.rng, 0.05);
        let bname = format!("{name}/bias");
        self.g.constant(&bname, b);
        self.g.op(name, Op::BiasAdd, &[input, &bname])
    }

    pub fn relu(&mut self, name: &str, input: &str) -> String {
        self.g.op(name, Op::Relu, &[input])
    }

    pub fn relu6(&mut self, name: &str, input: &str) -> String {
        self.g.op(name, Op::Relu6, &[input])
    }

    /// Classifier head: global-average-pool -> FC -> bias -> softmax.
    pub fn head(&mut self, input: &str, cin: usize, classes: usize) -> String {
        let gap = self.g.op("global_pool", Op::Mean, &[input]);
        let std = (2.0 / cin as f64).sqrt() as f32;
        let w = Tensor::randn(&[cin, classes], &mut self.rng, std);
        self.g.constant("logits/weights", w);
        let fc = self.g.op("logits", Op::MatMul, &[&gap, "logits/weights"]);
        let fcb = self.bias("logits/biasadd", &fc, classes);
        let out = self.g.op("predictions", Op::Softmax, &[&fcb]);
        self.g.outputs = vec![out.clone()];
        out
    }
}

/// Names of all the networks the CLI / benches can build, with builders.
pub fn build_named(name: &str, cfg: NetConfig) -> Option<Graph> {
    match name {
        "resnet50" => Some(resnet50(cfg)),
        "mobilenet_v1" => Some(mobilenet_v1(cfg)),
        "mobilenet_v2" => Some(mobilenet_v2(cfg)),
        "tinycnn" => Some(tiny_cnn(cfg)),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn width_rounding() {
        let cfg = NetConfig { width: 0.25, ..NetConfig::default() };
        assert_eq!(cfg.ch(64), 16);
        assert_eq!(cfg.ch(24), 8);
        assert_eq!(cfg.ch(4), 8); // floor of 8
        let full = NetConfig::default();
        assert_eq!(full.ch(64), 64);
    }

    #[test]
    fn builder_conv_bn_relu_chain_validates() {
        let mut b = NetBuilder::new(1);
        let x = b.input("input", 16, 16, 3);
        let c = b.conv("conv1", &x, 3, 3, 8, 1, Padding::Same);
        let n = b.bn("conv1/bn", &c, 8);
        let r = b.relu("conv1/relu", &n);
        b.g.outputs = vec![r];
        b.g.validate().unwrap();
        let shapes = b.g.infer_shapes().unwrap();
        assert_eq!(shapes["conv1/relu"], vec![1, 16, 16, 8]);
    }

    #[test]
    fn bn_scales_strictly_positive() {
        let mut b = NetBuilder::new(2);
        let x = b.input("input", 4, 4, 16);
        b.bn("bn", &x, 16);
        let gamma = b.g.get("bn/gamma").unwrap().value.as_ref().unwrap();
        assert!(gamma.data.iter().all(|&v| v > 0.0));
    }

    #[test]
    fn build_named_dispatch() {
        let cfg = NetConfig::test_scale();
        for name in ["resnet50", "mobilenet_v1", "mobilenet_v2", "tinycnn"] {
            let g = build_named(name, cfg).unwrap();
            g.validate().unwrap_or_else(|e| panic!("{name}: {e}"));
        }
        assert!(build_named("vgg", cfg).is_none());
    }
}
