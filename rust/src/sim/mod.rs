//! Cycle-level simulator of the HPIPE layer pipeline.
//!
//! Stands in for the Stratix 10 device (DESIGN.md §Hardware-Adaptation):
//! every plan stage becomes a pipeline station that consumes input
//! *lines* into a bounded ring buffer (per Fig 6), produces one output
//! line every `cycles_per_line` cycles once `k_h` lines are buffered, and
//! exerts the paper's coarse backpressure when a downstream buffer is
//! full. The simulation is event-driven at line granularity — the cycle
//! cost *within* a line comes from the compiler's partition-aware model,
//! which is exact for the lock-step weight streams — so simulating
//! hundreds of images through a 100-stage ResNet takes milliseconds.
//!
//! Outputs: per-stage busy cycles (Fig 3), end-to-end latency and
//! steady-state throughput (Fig 8), buffer high-water marks, and deadlock
//! diagnosis (§V-C's Add skip-path hazard).

use crate::compile::AcceleratorPlan;
use crate::graph::Op;
use std::collections::BinaryHeap;

/// Result of simulating a plan.
#[derive(Debug, Clone)]
pub struct SimReport {
    pub images: usize,
    /// Completion cycle of each image at the final stage.
    pub completion_cycles: Vec<u64>,
    /// Cycle at which the first stage began admitting each image.
    pub admission_cycles: Vec<u64>,
    /// Per-stage total busy cycles across the run.
    pub stage_busy: Vec<u64>,
    /// Per-stage output-line count (sanity).
    pub stage_lines: Vec<u64>,
    /// Per-stage, per-input-slot buffer high-water mark in lines.
    pub buffer_peak: Vec<Vec<u64>>,
    pub total_cycles: u64,
}

impl SimReport {
    /// Latency of image 0 in cycles (admission to completion).
    pub fn first_image_latency(&self) -> u64 {
        self.completion_cycles[0] - self.admission_cycles[0]
    }

    /// Steady-state initiation interval: completion spacing of the last
    /// two images.
    pub fn steady_interval(&self) -> u64 {
        let n = self.completion_cycles.len();
        if n < 2 {
            return self.completion_cycles[0];
        }
        self.completion_cycles[n - 1] - self.completion_cycles[n - 2]
    }

    pub fn throughput_img_s(&self, fmax_mhz: f64) -> f64 {
        fmax_mhz * 1e6 / self.steady_interval() as f64
    }

    pub fn latency_ms(&self, fmax_mhz: f64) -> f64 {
        self.first_image_latency() as f64 / (fmax_mhz * 1e6) * 1e3
    }
}

/// Deadlock diagnosis.
#[derive(Debug, Clone)]
pub struct Deadlock {
    pub at_cycle: u64,
    /// Names of stages with pending work that cannot progress.
    pub stuck: Vec<String>,
}

#[derive(Debug)]
pub enum SimError {
    Deadlock(Deadlock),
    Empty,
}

impl std::fmt::Display for SimError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SimError::Deadlock(d) => write!(
                f,
                "pipeline deadlock at cycle {}: stuck stages {:?}",
                d.at_cycle, d.stuck
            ),
            SimError::Empty => write!(f, "plan has no stages"),
        }
    }
}

impl std::error::Error for SimError {}

struct Station {
    /// Producer station index per input slot.
    inputs: Vec<usize>,
    /// Consumers: (station, input slot).
    consumers: Vec<(usize, usize)>,
    /// Input lines per image, per slot (producer's out lines).
    in_lines: Vec<u64>,
    /// Buffer capacity (lines) per input slot.
    capacity: Vec<u64>,
    out_lines: u64,
    stride: u64,
    /// Lines that must be buffered before an output line can start
    /// (k_h for convs, the full image for Mean).
    window: u64,
    cycles_per_line: u64,
    is_source: bool,

    // ---- state ----
    img: u64,
    line: u64,
    busy: bool,
    received: Vec<u64>,
    freed: Vec<u64>,
    peak: Vec<u64>,
    busy_cycles: u64,
    lines_done: u64,
}

impl Station {
    /// Absolute input line count needed (slot-independent window).
    fn need(&self, slot: usize) -> u64 {
        let within = (self.line * self.stride + self.window).min(self.in_lines[slot]);
        self.img * self.in_lines[slot] + within
    }

    fn can_free_after(&self, slot: usize) -> u64 {
        let within = if self.line + 1 >= self.out_lines {
            self.in_lines[slot]
        } else {
            ((self.line + 1) * self.stride).min(self.in_lines[slot])
        };
        self.img * self.in_lines[slot] + within
    }
}

/// Simulate `images` images through the plan. Returns the report or a
/// deadlock diagnosis.
pub fn simulate(plan: &AcceleratorPlan, images: usize) -> Result<SimReport, SimError> {
    if plan.stages.is_empty() {
        return Err(SimError::Empty);
    }
    let n = plan.stages.len();
    let name_to_idx: std::collections::BTreeMap<&str, usize> = plan
        .stages
        .iter()
        .enumerate()
        .map(|(i, s)| (s.name.as_str(), i))
        .collect();

    let mut stations: Vec<Station> = plan
        .stages
        .iter()
        .map(|s| {
            let inputs: Vec<usize> = s.inputs.iter().map(|i| name_to_idx[i.as_str()]).collect();
            let out_lines = match s.op {
                Op::Mean | Op::MatMul | Op::BiasAdd | Op::Softmax
                    if s.geo.out_h <= 1 =>
                {
                    1
                }
                _ => s.geo.out_h as u64,
            };
            let window = match s.op {
                Op::Mean => u64::MAX, // resolved below: whole image
                _ => s.geo.kh as u64,
            };
            Station {
                in_lines: vec![0; inputs.len()], // filled after
                capacity: vec![s.buffer_lines as u64; inputs.len()],
                inputs,
                consumers: Vec::new(),
                out_lines,
                stride: s.geo.stride as u64,
                window,
                cycles_per_line: (s.cycles / out_lines.max(1)).max(1),
                is_source: matches!(s.op, Op::Placeholder { .. }),
                img: 0,
                line: 0,
                busy: false,
                received: Vec::new(),
                freed: Vec::new(),
                peak: Vec::new(),
                busy_cycles: 0,
                lines_done: 0,
            }
        })
        .collect();

    // Wire consumers and per-slot line counts.
    for i in 0..n {
        let inputs = stations[i].inputs.clone();
        for (slot, &p) in inputs.iter().enumerate() {
            stations[p].consumers.push((i, slot));
            let pl = stations[p].out_lines;
            stations[i].in_lines[slot] = pl;
        }
        let slots = stations[i].inputs.len();
        stations[i].received = vec![0; slots];
        stations[i].freed = vec![0; slots];
        stations[i].peak = vec![0; slots];
        if stations[i].window == u64::MAX {
            // Mean: needs the producer's whole image
            stations[i].window = stations[i].in_lines.first().copied().unwrap_or(1);
            stations[i].stride = stations[i].window.max(1);
        }
        // a window can never exceed the image; capacity must hold it
        for slot in 0..slots {
            let w = stations[i].window.min(stations[i].in_lines[slot]);
            if stations[i].capacity[slot] < w {
                stations[i].capacity[slot] = w;
            }
        }
    }

    let images = images as u64;
    let last = n - 1;
    let mut completions: Vec<u64> = Vec::with_capacity(images as usize);
    let mut admissions: Vec<u64> = Vec::with_capacity(images as usize);

    // event heap: (completion_time, station) — min-heap via Reverse
    let mut heap: BinaryHeap<std::cmp::Reverse<(u64, usize)>> = BinaryHeap::new();
    let mut t: u64 = 0;

    let can_start = |st: &Station, stations: &Vec<Station>| -> bool {
        if st.busy || st.img >= images {
            return false;
        }
        // inputs available
        if !st.is_source {
            for slot in 0..st.inputs.len() {
                if st.received[slot] < st.need(slot) {
                    return false;
                }
            }
        }
        // downstream space for this line
        for &(c, slot) in &st.consumers {
            let cs = &stations[c];
            if cs.received[slot] - cs.freed[slot] >= cs.capacity[slot] {
                return false;
            }
        }
        true
    };

    // Worklist scheduler: a station's eligibility only changes when (a)
    // one of its producers delivers a line, (b) one of its consumers
    // frees buffer space, or (c) it finishes its own line — so after
    // each completion only {self, producers, consumers} need re-checking
    // (O(degree) per event instead of O(stations), the perf-pass fix
    // recorded in EXPERIMENTS.md §Perf).
    let mut try_start = |i: usize, t: u64, stations: &mut Vec<Station>,
                         heap: &mut BinaryHeap<std::cmp::Reverse<(u64, usize)>>,
                         admissions: &mut Vec<u64>| {
        let ok = can_start(&stations[i], stations);
        if ok {
            let st = &mut stations[i];
            st.busy = true;
            let done = t + st.cycles_per_line;
            st.busy_cycles += st.cycles_per_line;
            if st.is_source && st.line == 0 {
                admissions.push(t);
            }
            heap.push(std::cmp::Reverse((done, i)));
        }
    };

    // seed: every station gets one chance at t = 0
    for i in 0..n {
        try_start(i, 0, &mut stations, &mut heap, &mut admissions);
    }

    loop {
        // advance to the next completion
        let Some(std::cmp::Reverse((time, i))) = heap.pop() else {
            // nothing in flight: either done or deadlocked
            let all_done = stations.iter().all(|s| s.img >= images);
            if all_done {
                break;
            }
            let stuck: Vec<String> = stations
                .iter()
                .enumerate()
                .filter(|(_, s)| s.img < images)
                .map(|(i, _)| plan.stages[i].name.clone())
                .collect();
            return Err(SimError::Deadlock(Deadlock { at_cycle: t, stuck }));
        };
        t = time;

        // complete station i's line
        {
            // free input lines
            let frees: Vec<(usize, u64)> = {
                let st = &stations[i];
                (0..st.inputs.len())
                    .map(|slot| (slot, st.can_free_after(slot)))
                    .collect()
            };
            let st = &mut stations[i];
            for (slot, f) in frees {
                if f > st.freed[slot] {
                    st.freed[slot] = f;
                }
            }
            st.busy = false;
            st.lines_done += 1;
            st.line += 1;
            let finished_image = st.line >= st.out_lines;
            if finished_image {
                st.line = 0;
                st.img += 1;
            }
            if finished_image && i == last {
                completions.push(t);
            }
        }
        // deliver the line to consumers
        let consumers = stations[i].consumers.clone();
        for &(c, slot) in &consumers {
            let cs = &mut stations[c];
            cs.received[slot] += 1;
            let occ = cs.received[slot] - cs.freed[slot];
            if occ > cs.peak[slot] {
                cs.peak[slot] = occ;
            }
        }

        // re-check only the affected stations
        try_start(i, t, &mut stations, &mut heap, &mut admissions);
        for &(c, _) in &consumers {
            try_start(c, t, &mut stations, &mut heap, &mut admissions);
        }
        let producers = stations[i].inputs.clone();
        for p in producers {
            try_start(p, t, &mut stations, &mut heap, &mut admissions);
        }
    }

    // admissions only recorded for stage 0 starts of line 0 — pad if the
    // source stage wasn't stage index 0 (shouldn't happen: topo order).
    while admissions.len() < images as usize {
        admissions.push(*admissions.last().unwrap_or(&0));
    }

    Ok(SimReport {
        images: images as usize,
        completion_cycles: completions,
        admission_cycles: admissions,
        stage_busy: stations.iter().map(|s| s.busy_cycles).collect(),
        stage_lines: stations.iter().map(|s| s.lines_done).collect(),
        buffer_peak: stations.iter().map(|s| s.peak.clone()).collect(),
        total_cycles: t,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::S10_2800;
    use crate::compile::{compile, CompileOptions};
    use crate::nets::{resnet50, tiny_cnn, NetConfig};
    use crate::sparsity::prune_graph;
    use crate::transform::optimize;

    fn tiny_plan(dsp: usize) -> AcceleratorPlan {
        let g = tiny_cnn(NetConfig::test_scale());
        let (g, _) = optimize(&g);
        compile(&g, "tinycnn", &CompileOptions::new(S10_2800.clone(), dsp)).unwrap()
    }

    #[test]
    fn tiny_simulates_and_completes() {
        let plan = tiny_plan(300);
        let r = simulate(&plan, 8).unwrap();
        assert_eq!(r.completion_cycles.len(), 8);
        // completions strictly increasing
        assert!(r.completion_cycles.windows(2).all(|w| w[0] < w[1]));
        // every stage produced lines for every image
        for (i, &lines) in r.stage_lines.iter().enumerate() {
            assert!(lines > 0, "stage {} idle", plan.stages[i].name);
        }
    }

    #[test]
    fn steady_interval_close_to_bottleneck() {
        let plan = tiny_plan(300);
        let r = simulate(&plan, 12).unwrap();
        let predicted = plan.interval_cycles();
        let measured = r.steady_interval();
        // the event-level sim should match the analytic bottleneck within
        // ~25% (the paper's model is within 1% of *its* RTL simulation;
        // ours adds handshake quantization at line granularity)
        let ratio = measured as f64 / predicted as f64;
        assert!(
            (0.8..1.6).contains(&ratio),
            "measured {measured} vs predicted {predicted} (ratio {ratio:.2})"
        );
    }

    #[test]
    fn latency_exceeds_interval() {
        let plan = tiny_plan(300);
        let r = simulate(&plan, 4).unwrap();
        assert!(r.first_image_latency() >= r.steady_interval());
    }

    #[test]
    fn more_dsps_more_throughput() {
        let slow = simulate(&tiny_plan(16), 6).unwrap();
        let fast = simulate(&tiny_plan(2000), 6).unwrap();
        assert!(
            fast.steady_interval() < slow.steady_interval(),
            "fast {} vs slow {}",
            fast.steady_interval(),
            slow.steady_interval()
        );
    }

    #[test]
    fn resnet_skip_paths_do_not_deadlock() {
        let mut g = resnet50(NetConfig::test_scale());
        prune_graph(&mut g, 0.85);
        let (g, _) = optimize(&g);
        let plan = compile(&g, "resnet50", &CompileOptions::new(S10_2800.clone(), 800)).unwrap();
        let r = simulate(&plan, 3).unwrap();
        assert_eq!(r.completion_cycles.len(), 3);
    }

    #[test]
    fn undersized_add_buffers_deadlock() {
        let mut g = resnet50(NetConfig::test_scale());
        prune_graph(&mut g, 0.85);
        let (g, _) = optimize(&g);
        let mut plan =
            compile(&g, "resnet50", &CompileOptions::new(S10_2800.clone(), 800)).unwrap();
        // sabotage: shrink every Add buffer to the bare window minimum
        for s in plan.stages.iter_mut() {
            if matches!(s.op, Op::Add) {
                s.buffer_lines = 1;
            }
        }
        match simulate(&plan, 2) {
            Err(SimError::Deadlock(d)) => {
                assert!(!d.stuck.is_empty());
            }
            Ok(r) => {
                // If line-granular timing still squeaks through, the skip
                // buffer must at least have hit its (tiny) capacity.
                let add_idx: Vec<usize> = plan
                    .stages
                    .iter()
                    .enumerate()
                    .filter(|(_, s)| matches!(s.op, Op::Add))
                    .map(|(i, _)| i)
                    .collect();
                let peak = add_idx
                    .iter()
                    .map(|&i| r.buffer_peak[i].iter().copied().max().unwrap_or(0))
                    .max()
                    .unwrap();
                assert!(peak >= 1, "sabotage had no effect");
            }
            Err(e) => panic!("unexpected: {e}"),
        }
    }

    #[test]
    fn buffer_peaks_respect_capacity() {
        let plan = tiny_plan(300);
        let r = simulate(&plan, 5).unwrap();
        for (i, peaks) in r.buffer_peak.iter().enumerate() {
            for (slot, &p) in peaks.iter().enumerate() {
                // capacity may have been raised to the window internally
                let cap = plan.stages[i].buffer_lines.max(plan.stages[i].geo.kh) as u64;
                assert!(
                    p <= cap.max(plan.stages[i].geo.out_h as u64),
                    "stage {} slot {slot}: peak {p} cap {cap}",
                    plan.stages[i].name
                );
            }
        }
    }
}
