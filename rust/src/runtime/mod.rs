//! Model runtime: load graphdef artifacts and execute them through the
//! compiled execution engine.
//!
//! The request-path half of the serving architecture: artifacts produced
//! by the Python side (`python/compile/aot.py` writes `manifest.json`
//! next to the trained `tinycnn` graphdef) are loaded once at startup,
//! compiled into [`ExecutionPlan`]s — topo order resolved, buffers
//! pre-bound, per-layer kernels selected, RLE sparse streams encoded —
//! and executed for every inference with zero per-image allocations.
//! This replaced the earlier PJRT/XLA path: the offline build has no
//! `xla` crate, and the compiled executor is the project's own
//! sparse-aware hot path (see `exec` module docs for the plan-vs-oracle
//! role split).
//!
//! Batch is a **native plan dimension**: a batch-N model compiles its
//! plan *for N images* ([`crate::exec::PlanOptions::batch`]) so one
//! execution runs the whole batch — each RLE weight stream is walked
//! once and each dense weight tile is loaded once per batch, not per
//! image (the weight-traffic amortization HPIPE's PCIe DMA batching
//! only gave to transfers). With `threads > 1` the batch is *streamed*
//! through the layer-pipelined executor
//! ([`crate::exec::PipelinePlan`]) in sub-batch groups — the software
//! twin of the paper's all-layers-concurrent dataflow, with batched
//! boundary tensors at every cut — while a batch-1 latency plan is kept
//! for single-image requests ([`LoadedModel::run_one`]: lowest latency,
//! no batching or handoff cost).
//!
//! Between those extremes sits the **ragged-tail plan family**
//! ([`LoadedModel::run_tail`]): a few smaller batch variants of the
//! same graph (default {B/4, B/2}) so a drained tail of k < B requests
//! executes on the smallest plan that fits instead of being zero-padded
//! to B — bitwise-identical outputs, strictly less compute, which
//! matters most exactly where sparsity makes per-image work cheap.

use crate::artifact::{self, CacheSpec, ModelArtifact, PipelineSpec};
use crate::exec::{
    ExecContext, ExecutionPlan, PipelinePlan, PlanOptions, TuneEntry, TuneOptions, TuneReport,
    WeightStore,
};
use crate::graph::{graphdef, Graph, GraphError, Op, Tensor};
use crate::sparsity::prune_tensor;
use crate::util::breaker::{Breaker, BreakerConfig};
use crate::util::error::{Context, Result};
use crate::util::{Json, Rng};
use std::cell::RefCell;
use std::collections::{BTreeMap, BTreeSet};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

/// A compiled executable plus its I/O metadata.
pub struct LoadedModel {
    pub name: String,
    pub batch: usize,
    /// Pipeline stages (worker threads) used for batch serving; 1 means
    /// fully sequential execution.
    pub threads: usize,
    /// Intra-stage worker-team size: conv / matmul steps of the
    /// pipeline's dominant stage split their output rows across this
    /// many scoped threads (the software `n_channel_splits` knob).
    /// 1 disables splitting — exact PR 3 behavior.
    pub team: usize,
    /// Input shape with the leading dim set to `batch`.
    pub input_shape: Vec<usize>,
    /// Layer pipeline over the *batched* plan. The plan's native batch
    /// is the model's group size: the whole `batch` with `threads == 1`,
    /// a sub-batch divisor when the pipeline needs several groups in
    /// flight to keep its stages busy.
    pipeline: PipelinePlan,
    /// Batch-1 plan for the single-image latency path ([`Self::run_one`]);
    /// `None` when the batched plan is itself batch-1.
    latency: Option<ExecutionPlan>,
    /// Sequential-path context, allocated on first sequential run —
    /// models that only ever serve through the pipeline never pay for
    /// the full arena.
    ctx: RefCell<Option<ExecContext>>,
    /// Context for the latency plan, allocated on first `run_one`.
    latency_ctx: RefCell<Option<ExecContext>>,
    /// Calibration report when the model was loaded through
    /// [`Self::autotuned`]; `None` on the static (model-driven) path.
    tune: Option<TuneReport>,
    /// Stage faults observed across this model's pipelined runs (each
    /// failed `run_batch` attempt counts one). Atomic — the
    /// coordinator's feeder thread reads fault state through `&self`.
    faults: AtomicU64,
    /// Faulted runs that were retried (rung one of the recovery ladder).
    retries: AtomicU64,
    /// Per-stage circuit breakers guarding the primary pipeline — one
    /// per stage, the same site granularity `util::fault` injects at.
    /// A tripped site bypasses *this pipe* (sequential fallback) until
    /// its cool-down probe closes it again ([`Self::run_probe`]); the
    /// tail variants keep their own banks and their pipelined paths.
    breakers: Vec<Breaker>,
    /// Breaker tunables (cool-down, back-off cap, `--no-recover`),
    /// shared by the primary bank and every tail variant's.
    breaker_cfg: BreakerConfig,
    /// epoch-ns when the model last *entered* degraded (any breaker not
    /// closed); 0 while fully healthy. Drives
    /// [`FaultStats::time_degraded_ns`].
    degraded_since_ns: AtomicU64,
    /// Nanoseconds spent degraded across already-closed intervals.
    time_degraded_ns: AtomicU64,
    /// Ragged-tail plan family: 1-stage pipelines over smaller batched
    /// plans, ascending by batch. A drained tail of k < `batch` images
    /// routes to the smallest variant that fits instead of zero-padding
    /// to the full batch ([`Self::run_tail`]). Empty = pad to `batch`.
    variants: Vec<PipelinePlan>,
    /// Breaker bank per tail variant (parallel to `variants`): a
    /// tripped primary never condemns the tails, and vice versa.
    variant_breakers: Vec<Vec<Breaker>>,
    /// Tail executions that took a batched tail path (family variant or
    /// pad-to-batch fallback; the k=1 latency path doesn't count).
    tail_runs: AtomicU64,
    /// Zero images padded onto those tail executions — the wasted
    /// compute the plan family exists to shrink.
    padded_images: AtomicU64,
    /// Refcounted shared weight store: the primary plan, the latency
    /// plan, and every tail variant hold `Arc`s into this one copy of
    /// each const tensor, RLE stream, and packed panel. Also the unit
    /// of artifact persistence ([`Self::to_artifact`]).
    store: WeightStore,
    /// Fault history restored from a previous serve's `faults.json`
    /// (plan cache only); all-zero when none was found.
    restored: FaultStats,
    /// Largest live breaker cool-down persisted by the previous serve —
    /// how backed-off this model was when that process exited.
    restored_cooldown_ns: u64,
}

/// Ragged-tail accounting for one model (see [`LoadedModel::run_tail`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TailStats {
    /// Tail executions served through a batched (variant or padded) plan.
    pub tail_runs: u64,
    /// Zero images padded onto those executions.
    pub padded_images: u64,
}

/// Cumulative fault accounting for one model — the self-healing
/// ladder's observable state (see [`LoadedModel::run_all`]). This is
/// what the coordinator charges against a `--fault-budget`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FaultStats {
    /// Stage faults observed (every failed pipelined attempt,
    /// including failed cool-down probes).
    pub faults: u64,
    /// Faulted runs that were retried once before bypassing the pipe.
    pub retries: u64,
    /// Circuit-breaker trips across every site (primary stages + tail
    /// variants): entries into the sequential bypass.
    pub trips: u64,
    /// Successful cool-down probes: sites that closed again.
    pub recoveries: u64,
    /// True while any site is open *right now* — no longer sticky; a
    /// probe can clear it (`--no-recover` restores PR 6 stickiness).
    pub degraded: bool,
    /// Total time any site spent bypassed, including the currently
    /// open interval.
    pub time_degraded_ns: u64,
}

/// Per-batch routing decision from one pipe's breaker bank.
enum Route {
    /// Every site closed: the guarded pipelined path.
    Pipelined,
    /// One open site's cool-down elapsed and this call won the CAS:
    /// run HalfOpen, bitwise-gated against the sequential oracle.
    Probe(usize),
    /// At least one site open and no probe due: sequential bypass.
    Sequential,
}

/// One breaker per pipeline stage — the per-site granularity of the
/// self-healing ladder (site = stage index, matching the
/// `pipeline.stage#idx` fault-injection key).
fn breaker_bank(cfg: BreakerConfig, stages: usize) -> Vec<Breaker> {
    (0..stages).map(|_| Breaker::new(cfg)).collect()
}

/// The breaker site a pipelined failure charges: the faulting stage
/// for a [`GraphError::StageFault`], site 0 for anything else (clamped
/// so a malformed stage index can never panic the ladder).
fn fault_stage(err: &GraphError, stages: usize) -> usize {
    match err {
        GraphError::StageFault { stage, .. } => (*stage).min(stages.saturating_sub(1)),
        _ => 0,
    }
}

/// Images per plan execution for a `batch`-image model served through
/// `threads` pipeline stages. With one stage the whole batch is one
/// execution (maximal weight amortization, zero handoffs); with a
/// pipeline, the largest divisor of `batch` that still leaves at least
/// `threads` groups in flight, so every stage has work while each group
/// still amortizes weight traffic. When the batch is too small for
/// `threads` groups even at group 1, fall back to the largest divisor
/// leaving at least two groups — a partially filled pipeline still
/// overlaps, and per-image groups would forfeit all batch
/// amortization. (Prime batches with `threads > 1` are stuck at group
/// 1: uniform groups admit no middle ground between per-image and
/// whole-batch; remainder groups are the ragged-tail ROADMAP
/// follow-on.)
fn group_size(batch: usize, threads: usize) -> usize {
    if threads <= 1 {
        return batch.max(1);
    }
    let largest = |min_groups: usize| {
        (1..=batch)
            .rev()
            .find(|d| batch % d == 0 && batch / d >= min_groups)
    };
    largest(threads).or_else(|| largest(2)).unwrap_or(1)
}

/// The single batch-1 Placeholder every servable graph must have:
/// returns its (name, per-image shape). Shared by the static and
/// autotuned load paths so violations surface as errors either way.
fn single_placeholder(graph: &Graph) -> Result<(String, Vec<usize>)> {
    let placeholders: Vec<(String, Vec<usize>)> = graph
        .nodes
        .iter()
        .filter_map(|n| match &n.op {
            Op::Placeholder { shape } => Some((n.name.clone(), shape.clone())),
            _ => None,
        })
        .collect();
    crate::ensure!(
        placeholders.len() == 1,
        "graph must have exactly one Placeholder input, found {}",
        placeholders.len()
    );
    let (input_name, per_image_shape) = placeholders.into_iter().next().unwrap();
    crate::ensure!(
        per_image_shape.first() == Some(&1),
        "placeholder '{input_name}' must have batch dim 1, has shape {per_image_shape:?}"
    );
    Ok((input_name, per_image_shape))
}

/// Build a batch-`group` plan against the model's shared weight store
/// and run the serving-path sanity checks.
fn checked_batched_plan(
    graph: &Graph,
    group: usize,
    input_name: &str,
    store: &mut WeightStore,
) -> Result<ExecutionPlan> {
    let plan = ExecutionPlan::build_with_store(graph, &PlanOptions::batched(group), store)?;
    crate::ensure!(plan.num_outputs() >= 1, "graph has no outputs");
    crate::ensure!(
        plan.num_feeds() == 1 && plan.feed_name(0) == input_name,
        "plan feed binding does not match placeholder '{input_name}'"
    );
    Ok(plan)
}

impl LoadedModel {
    /// Compile a graph into a runnable model with the default
    /// single-threaded (sequential) execution.
    pub fn from_graph(name: &str, graph: &Graph, batch: usize) -> Result<LoadedModel> {
        LoadedModel::from_graph_with(name, graph, batch, 1, 1)
    }

    /// Compile a graph into a runnable model whose plan is built *for
    /// the batch*: one execution covers `group_size(batch, threads)`
    /// images natively (no run-N-times loop anywhere). The graph must
    /// have exactly one Placeholder and its leading (batch) dim must be
    /// 1 — both enforced here so violations surface as errors, not
    /// panics in the serving loop. `threads > 1` partitions the plan
    /// into that many pipeline stages for batch runs; `team > 1`
    /// additionally splits the dominant stage's conv rows across an
    /// intra-stage worker team (and engages the pipeline path for batch
    /// runs even at `threads == 1`).
    pub fn from_graph_with(
        name: &str,
        graph: &Graph,
        batch: usize,
        threads: usize,
        team: usize,
    ) -> Result<LoadedModel> {
        let (input_name, per_image_shape) = single_placeholder(graph)?;
        crate::ensure!(batch >= 1, "batch must be >= 1");
        crate::ensure!(threads >= 1, "threads must be >= 1");
        crate::ensure!(team >= 1, "team must be >= 1");
        let group = group_size(batch, threads);
        let mut store = WeightStore::new();
        let plan = checked_batched_plan(graph, group, &input_name, &mut store)?;
        // Deliberately eager: the latency plan must be ready the moment
        // a single-image request arrives, not pay a full compile on the
        // first one. It shares the batched plan's weight store, so the
        // eagerness costs O(arena), not a second copy of every weight
        // const, RLE stream, and packed panel.
        let latency = if group > 1 {
            Some(ExecutionPlan::build_with_store(graph, &PlanOptions::default(), &mut store)?)
        } else {
            None
        };
        let pipeline = PipelinePlan::from_plan_team(plan, threads, team);
        let breaker_cfg = BreakerConfig::default();
        let breakers = breaker_bank(breaker_cfg, pipeline.num_stages());
        let mut input_shape = per_image_shape;
        input_shape[0] = batch;
        Ok(LoadedModel {
            name: name.to_string(),
            batch,
            threads,
            team,
            input_shape,
            pipeline,
            latency,
            ctx: RefCell::new(None),
            latency_ctx: RefCell::new(None),
            tune: None,
            faults: AtomicU64::new(0),
            retries: AtomicU64::new(0),
            breakers,
            breaker_cfg,
            degraded_since_ns: AtomicU64::new(0),
            time_degraded_ns: AtomicU64::new(0),
            variants: Vec::new(),
            variant_breakers: Vec::new(),
            tail_runs: AtomicU64::new(0),
            padded_images: AtomicU64::new(0),
            store,
            restored: FaultStats::default(),
            restored_cooldown_ns: 0,
        })
    }

    /// Calibrate-then-serve: compile, **profile**, and cut the model's
    /// plans from measured step costs instead of the cycle model — the
    /// profile-guided Algorithm 1 variant. No `threads` / `team` knobs:
    /// the stage count comes from the measured bottleneck plateau under
    /// the core budget, the team size from measured stage imbalance and
    /// the cores left over, and the serving group size gets its *own*
    /// profile and cuts (batch-aware repartitioning) — distinct group
    /// sizes are calibrated once each and cached, never re-profiled.
    /// The static model-driven path ([`Self::from_graph_with`]) remains
    /// the default; this is opt-in (`Runtime::with_autotune`,
    /// `hpipe serve --autotune`).
    pub fn autotuned(
        name: &str,
        graph: &Graph,
        batch: usize,
        opts: &TuneOptions,
    ) -> Result<LoadedModel> {
        let (input_name, per_image_shape) = single_placeholder(graph)?;
        crate::ensure!(batch >= 1, "batch must be >= 1");
        let cores = opts.budget();
        // Calibration cache: one (plan, entry) per distinct group-batch
        // size. Pass 2 reuses pass 1's work whenever the group size
        // doesn't change — and every calibration plan shares the one
        // weight store, so profiling extra group sizes costs O(arena).
        let mut store = WeightStore::new();
        let mut cache: BTreeMap<usize, (ExecutionPlan, TuneEntry)> = BTreeMap::new();
        let calibrate = |group: usize,
                         cache: &mut BTreeMap<usize, (ExecutionPlan, TuneEntry)>,
                         store: &mut WeightStore|
         -> Result<()> {
            if let std::collections::btree_map::Entry::Vacant(slot) = cache.entry(group) {
                let plan = checked_batched_plan(graph, group, &input_name, store)?;
                let entry = TuneEntry::calibrate(&plan, opts);
                slot.insert((plan, entry));
            }
            Ok(())
        };
        // Pass 1: the whole batch as one group — its measured costs pick
        // the stage count, which in turn decides the serving group size
        // (stages-in-flight vs weight amortization, as on the static
        // path, but from a measured stage count).
        calibrate(batch, &mut cache, &mut store)?;
        let stages_pass1 = cache[&batch].1.cuts.stages;
        let group = group_size(batch, stages_pass1);
        // Pass 2: the serving group's plan gets its own profile + cuts.
        calibrate(group, &mut cache, &mut store)?;
        let chosen = cache[&group].1.clone();
        // A serving call streams batch/group groups; a pipeline deeper
        // than that never fills (pass 2's flatter per-group profile can
        // ask for more stages than pass 1's group size admits). Cap the
        // depth at groups-in-flight — the static path's `group_size`
        // invariant — and let the freed cores flow into the team.
        let groups_in_flight = (batch / group).max(1);
        let cuts = if chosen.cuts.stages > groups_in_flight {
            crate::exec::tune::choose_cuts_capped(
                &chosen.profile.costs_ns,
                cores,
                groups_in_flight,
            )
        } else {
            chosen.cuts.clone()
        };
        let mut entries: Vec<TuneEntry> = cache.values().map(|(_, e)| e.clone()).collect();
        let (plan, _) = cache.remove(&group).expect("group was calibrated");
        // the report records what actually serves: the capped cuts and
        // the model's counterfactual at the same stage count
        if let Some(e) = entries.iter_mut().find(|e| e.group == group) {
            if e.cuts != cuts {
                e.model_ranges = crate::util::partition::partition_min_bottleneck(
                    &plan.step_costs(),
                    cuts.stages,
                );
                e.cuts = cuts.clone();
            }
        }
        let latency = if group > 1 {
            Some(ExecutionPlan::build_with_store(graph, &PlanOptions::default(), &mut store)?)
        } else {
            None
        };
        let (stages, team) = (cuts.stages, cuts.team);
        let pipeline = PipelinePlan::from_profile(plan, &chosen.profile, stages, team);
        let breaker_cfg = BreakerConfig::default();
        let breakers = breaker_bank(breaker_cfg, pipeline.num_stages());
        let mut input_shape = per_image_shape;
        input_shape[0] = batch;
        Ok(LoadedModel {
            name: name.to_string(),
            batch,
            threads: stages,
            team,
            input_shape,
            pipeline,
            latency,
            ctx: RefCell::new(None),
            latency_ctx: RefCell::new(None),
            tune: Some(TuneReport {
                model: name.to_string(),
                cores,
                batch,
                chosen_group: group,
                entries,
            }),
            faults: AtomicU64::new(0),
            retries: AtomicU64::new(0),
            breakers,
            breaker_cfg,
            degraded_since_ns: AtomicU64::new(0),
            time_degraded_ns: AtomicU64::new(0),
            variants: Vec::new(),
            variant_breakers: Vec::new(),
            tail_runs: AtomicU64::new(0),
            padded_images: AtomicU64::new(0),
            store,
            restored: FaultStats::default(),
            restored_cooldown_ns: 0,
        })
    }

    /// Grow the ragged-tail plan family: one 1-stage batched plan per
    /// size in `sizes` (filtered to `2..batch` and deduplicated — k=1 is
    /// the latency plan's job and k=batch the primary plan's). Autotuned
    /// models reuse the chosen group's measured step costs to size each
    /// variant's worker team (linear cost rescaling, no re-profiling);
    /// static models inherit the configured team. Every variant shares
    /// the primary pipeline's inter-run idle tracker, so a tail run
    /// closes the idle window like any other group.
    pub fn add_plan_family(&mut self, graph: &Graph, sizes: &[usize]) -> Result<()> {
        let (input_name, _) = single_placeholder(graph)?;
        let kept: BTreeSet<usize> = sizes
            .iter()
            .copied()
            .filter(|&s| s > 1 && s < self.batch)
            .collect();
        for v in kept {
            let plan = checked_batched_plan(graph, v, &input_name, &mut self.store)
                .with_context(|| format!("building batch-{v} tail variant"))?;
            let team = match &self.tune {
                Some(report) => {
                    let chosen = report.chosen().expect("autotuned model has a chosen entry");
                    crate::exec::tune::variant_team(&chosen.profile, v, report.cores)
                }
                None => self.team,
            };
            let mut variant = PipelinePlan::from_plan_team(plan, 1, team);
            variant.share_idle_tracker(&self.pipeline);
            self.variant_breakers
                .push(breaker_bank(self.breaker_cfg, variant.num_stages()));
            self.variants.push(variant);
        }
        Ok(())
    }

    /// Re-key every breaker bank to `cfg` (cool-down, back-off cap,
    /// recovery on/off). Serving knobs arrive through the [`Runtime`]
    /// builders right after compilation, so rebuilding the (necessarily
    /// still-untripped) banks in place loses no state.
    pub fn set_breaker_config(&mut self, cfg: BreakerConfig) {
        self.breaker_cfg = cfg;
        self.breakers = breaker_bank(cfg, self.pipeline.num_stages());
        self.variant_breakers = self
            .variants
            .iter()
            .map(|v| breaker_bank(cfg, v.num_stages()))
            .collect();
    }

    /// The calibration report, when this model was loaded through
    /// [`Self::autotuned`].
    pub fn tune_report(&self) -> Option<&TuneReport> {
        self.tune.as_ref()
    }

    /// The refcounted shared weight store backing every plan of this
    /// model (primary, latency, and tail variants).
    pub fn store(&self) -> &WeightStore {
        &self.store
    }

    /// Resident weight memory as `(shared, private)` bytes. Shared is
    /// the store's one copy of each const tensor, RLE stream, and
    /// packed panel; private is what each plan legitimately adds on top
    /// — batch-tiled per-channel constants plus arena/scratch capacity
    /// — summed over the primary, latency, and variant plans. Plan
    /// variants growing `private` by O(arena) while `shared` stays flat
    /// is the observable proof of weight sharing.
    pub fn weight_bytes(&self) -> (usize, usize) {
        let shared = self.store.total_bytes();
        let per_plan = |p: &ExecutionPlan| p.private_weight_bytes() + p.arena_bytes();
        let mut private = per_plan(self.pipeline.plan());
        if let Some(l) = &self.latency {
            private += per_plan(l);
        }
        for v in &self.variants {
            private += per_plan(v.plan());
        }
        (shared, private)
    }

    /// Fault history restored from a previous serve's persisted
    /// `faults.json` (all-zero when none was found).
    pub fn restored_faults(&self) -> FaultStats {
        self.restored
    }

    /// Largest breaker cool-down the previous serve persisted.
    pub fn restored_cooldown_ns(&self) -> u64 {
        self.restored_cooldown_ns
    }

    /// Seed restored fault history (the plan cache's `faults.json`).
    /// Kept separate from the live atomics: breakers start closed —
    /// history informs reporting and fault budgets, it must not
    /// re-trip a site that a restart just reset.
    pub fn set_restored_faults(&mut self, stats: FaultStats, cooldown_ns: u64) {
        self.restored = stats;
        self.restored_cooldown_ns = cooldown_ns;
    }

    /// Largest live cool-down across every breaker site — persisted to
    /// `faults.json` so the next serve can see how backed-off this
    /// model was when the process exited.
    pub fn max_cooldown_ns(&self) -> u64 {
        self.all_breakers().map(|b| b.current_cooldown_ns()).max().unwrap_or(0)
    }

    /// Snapshot this model as a persistable artifact under invalidation
    /// key `key`: the shared weight store (cheap `Arc` clones), the
    /// pipeline shapes of the primary plan and every variant with the
    /// exact per-step costs their cuts were partitioned from, and the
    /// calibration report. [`Self::from_artifact`] is the inverse.
    pub fn to_artifact(&self, key: u64) -> ModelArtifact {
        // The costs the primary pipeline's cuts actually consumed:
        // measured medians for an autotuned model, modeled step costs
        // for a static one. Replaying them through the same DP at the
        // same stage count reproduces the cuts exactly.
        let primary_costs = match &self.tune {
            Some(report) => report
                .chosen()
                .expect("autotuned model has a chosen entry")
                .profile
                .costs_ns
                .clone(),
            None => self.pipeline.plan().step_costs(),
        };
        ModelArtifact {
            key,
            isa: crate::exec::isa::active().name().to_string(),
            batch: self.batch,
            threads: self.threads,
            team: self.team,
            primary: PipelineSpec {
                batch: self.group(),
                stages: self.threads,
                team: self.team,
                costs_ns: primary_costs,
            },
            variants: self
                .variants
                .iter()
                .map(|v| PipelineSpec {
                    batch: v.plan().batch(),
                    stages: 1,
                    team: v.team(),
                    costs_ns: v.plan().step_costs(),
                })
                .collect(),
            has_latency: self.latency.is_some(),
            tune: self.tune.clone(),
            store: self.store.clone(),
        }
    }

    /// Rebuild a runnable model from a loaded artifact: plans are
    /// re-bound against the artifact's prepopulated weight store (topo
    /// order, shapes, and buffer liveness re-derive from the graph —
    /// cheap and graph-validated — while every fold, RLE encode, pack,
    /// and profiling pass is skipped), and each pipeline's cuts are
    /// replayed from the stored per-step costs. Any inconsistency
    /// errors out; the caller falls back to a fresh compile.
    pub fn from_artifact(name: &str, graph: &Graph, art: ModelArtifact) -> Result<LoadedModel> {
        let (input_name, per_image_shape) = single_placeholder(graph)?;
        let ModelArtifact {
            batch,
            threads,
            team,
            primary,
            variants,
            has_latency,
            tune,
            mut store,
            ..
        } = art;
        crate::ensure!(batch >= 1 && threads >= 1 && team >= 1, "artifact config must be >= 1");
        crate::ensure!(
            has_latency == (primary.batch > 1),
            "artifact latency flag disagrees with its group size"
        );
        let plan = checked_batched_plan(graph, primary.batch, &input_name, &mut store)?;
        crate::ensure!(
            primary.costs_ns.len() == plan.step_names().len(),
            "artifact stores {} step costs for a {}-step plan",
            primary.costs_ns.len(),
            plan.step_names().len()
        );
        let latency = if has_latency {
            Some(ExecutionPlan::build_with_store(graph, &PlanOptions::default(), &mut store)?)
        } else {
            None
        };
        let pipeline =
            PipelinePlan::from_static_costs(plan, &primary.costs_ns, primary.stages, primary.team);
        let breaker_cfg = BreakerConfig::default();
        let breakers = breaker_bank(breaker_cfg, pipeline.num_stages());
        let mut model_variants = Vec::with_capacity(variants.len());
        let mut variant_breakers = Vec::with_capacity(variants.len());
        for spec in &variants {
            crate::ensure!(
                spec.batch > 1 && spec.batch < batch,
                "artifact variant batch {} outside 2..{batch}",
                spec.batch
            );
            let vplan = checked_batched_plan(graph, spec.batch, &input_name, &mut store)
                .with_context(|| format!("restoring batch-{} tail variant", spec.batch))?;
            crate::ensure!(
                spec.costs_ns.len() == vplan.step_names().len(),
                "artifact variant step costs disagree with its plan"
            );
            let mut variant =
                PipelinePlan::from_static_costs(vplan, &spec.costs_ns, spec.stages, spec.team);
            variant.share_idle_tracker(&pipeline);
            variant_breakers.push(breaker_bank(breaker_cfg, variant.num_stages()));
            model_variants.push(variant);
        }
        let mut input_shape = per_image_shape;
        input_shape[0] = batch;
        Ok(LoadedModel {
            name: name.to_string(),
            batch,
            threads,
            team,
            input_shape,
            pipeline,
            latency,
            ctx: RefCell::new(None),
            latency_ctx: RefCell::new(None),
            tune,
            faults: AtomicU64::new(0),
            retries: AtomicU64::new(0),
            breakers,
            breaker_cfg,
            degraded_since_ns: AtomicU64::new(0),
            time_degraded_ns: AtomicU64::new(0),
            variants: model_variants,
            variant_breakers,
            tail_runs: AtomicU64::new(0),
            padded_images: AtomicU64::new(0),
            store,
            restored: FaultStats::default(),
            restored_cooldown_ns: 0,
        })
    }

    /// Plan composition counters (sparse vs dense kernels, fusions...).
    pub fn plan_stats(&self) -> crate::exec::PlanStats {
        self.pipeline.plan().stats()
    }

    /// The stage partition backing this model's batch serving path.
    pub fn pipeline(&self) -> &PipelinePlan {
        &self.pipeline
    }

    /// Images per native plan execution (the batched plan's batch dim).
    pub fn group(&self) -> usize {
        self.pipeline.plan().batch()
    }

    /// True when [`Self::run_all`] routes batches through the layer
    /// pipeline (stage threads / worker team), so the pipeline's stage
    /// counters actually accumulate; false for purely sequential models.
    pub fn serves_pipelined(&self) -> bool {
        (self.threads > 1 && self.batch > self.group()) || self.team > 1
    }

    /// Cumulative fault accounting: stage faults seen, retries spent,
    /// breaker trips and recoveries across every bank, and whether any
    /// site is bypassed right now.
    pub fn fault_stats(&self) -> FaultStats {
        let mut trips = 0;
        let mut recoveries = 0;
        let mut degraded = false;
        for b in self.all_breakers() {
            trips += b.trips();
            recoveries += b.recoveries();
            degraded |= !b.is_closed();
        }
        let mut time_degraded_ns = self.time_degraded_ns.load(Ordering::Relaxed);
        let since = self.degraded_since_ns.load(Ordering::Relaxed);
        if since != 0 {
            time_degraded_ns = time_degraded_ns
                .saturating_add(crate::util::timer::epoch_ns().saturating_sub(since));
        }
        FaultStats {
            faults: self.faults.load(Ordering::Relaxed),
            retries: self.retries.load(Ordering::Relaxed),
            trips,
            recoveries,
            degraded,
            time_degraded_ns,
        }
    }

    /// True while any site's breaker is not closed: some path of this
    /// model is currently served by the sequential bypass. No longer
    /// sticky — a cool-down probe can close the site again
    /// (`--no-recover` restores stickiness).
    pub fn is_degraded(&self) -> bool {
        self.all_breakers().any(|b| !b.is_closed())
    }

    fn all_breakers(&self) -> impl Iterator<Item = &Breaker> + '_ {
        self.breakers
            .iter()
            .chain(self.variant_breakers.iter().flatten())
    }

    /// The first trip while fully healthy starts the degrade clock.
    fn note_trip(&self, now_ns: u64) {
        if self.degraded_since_ns.load(Ordering::Relaxed) == 0 {
            self.degraded_since_ns.store(now_ns.max(1), Ordering::Relaxed);
        }
    }

    /// A recovery that leaves every bank closed stops the degrade clock
    /// and banks the interval.
    fn note_recovery(&self, now_ns: u64) {
        if self.all_breakers().all(|b| b.is_closed()) {
            let since = self.degraded_since_ns.swap(0, Ordering::Relaxed);
            if since != 0 {
                self.time_degraded_ns
                    .fetch_add(now_ns.saturating_sub(since), Ordering::Relaxed);
            }
        }
    }

    /// Route one batch by a pipe's breaker bank: pipelined while every
    /// site is closed, a single cool-down probe when one is due,
    /// sequential bypass otherwise.
    fn route(&self, breakers: &[Breaker]) -> Route {
        if breakers.iter().all(|b| b.is_closed()) {
            return Route::Pipelined;
        }
        let now = crate::util::timer::epoch_ns();
        match breakers.iter().position(|b| b.try_probe(now)) {
            Some(site) => Route::Probe(site),
            None => Route::Sequential,
        }
    }

    /// Reject malformed inputs with typed errors before any execution:
    /// a wrong-length or non-finite batch must surface as a refusable
    /// request on the serving path, never as a panic or a NaN cascade
    /// through every in-flight image sharing the batch.
    fn check_input(&self, input: &[f32], expect: usize, shape: &[usize]) -> Result<(), GraphError> {
        if input.len() != expect {
            return Err(GraphError::Shape(
                self.pipeline.plan().feed_name(0).to_string(),
                format!(
                    "input length {} != shape {:?} ({} elements)",
                    input.len(),
                    shape,
                    expect
                ),
            ));
        }
        if let Some(pos) = input.iter().position(|v| !v.is_finite()) {
            return Err(GraphError::Invalid(
                self.pipeline.plan().feed_name(0).to_string(),
                format!("non-finite input value at index {pos}"),
            ));
        }
        Ok(())
    }

    /// Run one batch. `input` is row-major f32 of `input_shape` (with
    /// the leading dim = batch). Returns the output tensor's data
    /// concatenated over the batch. Errors on multi-output graphs so a
    /// second head can never be dropped silently — use
    /// [`Self::run_all`] for those.
    pub fn run(&self, input: &[f32]) -> Result<Vec<f32>, GraphError> {
        let n_outs = self.pipeline.plan().num_outputs();
        if n_outs != 1 {
            return Err(GraphError::Invalid(
                self.name.clone(),
                format!("{n_outs} outputs; run() would drop all but the first — use run_all()"),
            ));
        }
        Ok(self.run_all(input)?.pop().expect("exactly one output"))
    }

    /// Run one batch and return *every* graph output, each concatenated
    /// over the batch. The whole batch is executed through the batched
    /// plan — sequentially in whole-group steps, or streamed through
    /// the layer pipeline when the model was loaded with `threads > 1`.
    ///
    /// Failure semantics (the self-healing ladder): a stage fault in
    /// the pipelined path is retried once on the same (reusable)
    /// [`PipelinePlan`]; if the retry also faults, the faulting stage's
    /// circuit breaker trips and this pipe is bypassed — batches run
    /// the sequential batch-1 plan, bitwise-identical to the oracle —
    /// until the breaker's cool-down elapses, one probe batch
    /// re-validates the pipelined path (HalfOpen, answered from the
    /// oracle either way), and the site closes again.
    /// [`Self::fault_stats`] exposes the whole history. Malformed
    /// inputs return typed [`GraphError`]s without executing anything.
    pub fn run_all(&self, input: &[f32]) -> Result<Vec<Vec<f32>>, GraphError> {
        let expect: usize = self.input_shape.iter().product();
        self.check_input(input, expect, &self.input_shape)?;
        let plan = self.pipeline.plan();
        let group = plan.batch();
        if self.serves_pipelined() {
            // Throughput path: stream the batch through the layer
            // pipeline, several batched groups in flight across stage
            // threads (one boundary handoff per group, not per image).
            // A worker team (team > 1) also routes here — even a 1-stage
            // pipeline then splits its dominant convs across the team.
            return match self.route(&self.breakers) {
                Route::Pipelined => {
                    match self.run_with_ladder(&self.pipeline, &self.breakers, input, self.batch) {
                        Some(outs) => Ok(outs),
                        None => self.run_sequential(input, self.batch),
                    }
                }
                Route::Probe(site) => {
                    self.run_probe(&self.pipeline, &self.breakers[site], input, self.batch)
                }
                Route::Sequential => self.run_sequential(input, self.batch),
            };
        }
        // Sequential path: the plan executes whole groups natively
        // (with threads == 1 the group IS the batch — a single
        // execution, no per-image loop).
        let runs = self.batch / group;
        let per_run = expect / runs;
        let mut guard = self.ctx.borrow_mut();
        let ctx = guard.get_or_insert_with(|| plan.new_context());
        let mut outs: Vec<Vec<f32>> = vec![Vec::new(); plan.num_outputs()];
        for r in 0..runs {
            // Zero-allocation hot path: the group's slice goes straight
            // into the plan's feed slot (single copy, no Tensor wrap).
            plan.write_feed(ctx, 0, &input[r * per_run..(r + 1) * per_run])?;
            plan.execute_steps(ctx);
            for (i, out) in outs.iter_mut().enumerate() {
                let (data, _) = plan.output(ctx, i);
                if out.capacity() == 0 {
                    out.reserve_exact(data.len() * runs);
                }
                out.extend_from_slice(data);
            }
        }
        Ok(outs)
    }

    /// One pipelined execution attempt with the retry-once → trip
    /// ladder (shared by the primary batch path and the tail variants;
    /// each pipe charges its own breaker bank, so a faulting variant
    /// bypasses only itself). `None` means both attempts faulted and
    /// the faulting site's breaker is now open — the caller must take
    /// the sequential fallback.
    fn run_with_ladder(
        &self,
        pipe: &PipelinePlan,
        breakers: &[Breaker],
        input: &[f32],
        n_images: usize,
    ) -> Option<Vec<Vec<f32>>> {
        let first = match pipe.run_batch(input, n_images) {
            Ok(outs) => return Some(outs),
            Err(e) => e,
        };
        // Rung one: the plan is reusable after an isolated stage fault,
        // so a transient panic costs one retry, not the run.
        self.faults.fetch_add(1, Ordering::Relaxed);
        self.retries.fetch_add(1, Ordering::Relaxed);
        let site = fault_stage(&first, breakers.len());
        breakers[site].record_failure(crate::util::timer::epoch_ns());
        let second = match pipe.run_batch(input, n_images) {
            Ok(outs) => {
                // The retry cleared it: a clean pass resets every
                // site's consecutive-failure count.
                for b in breakers {
                    b.record_success();
                }
                return Some(outs);
            }
            Err(e) => e,
        };
        // Rung two: two faults in one batch bypass this pipe — but only
        // the faulting site's breaker trips, and only until its
        // cool-down probe (PR 6 demoted the whole model, forever).
        self.faults.fetch_add(1, Ordering::Relaxed);
        let now = crate::util::timer::epoch_ns();
        let site = fault_stage(&second, breakers.len());
        if !breakers[site].record_failure(now) {
            // The retry faulted at a different site than the first
            // attempt: one consecutive failure there is below the
            // threshold, but the two-faults-in-one-batch contract still
            // demotes the pipe.
            breakers[site].force_trip(now);
        }
        self.note_trip(now);
        eprintln!(
            "model '{}': bypassing the pipelined path at stage {site} after repeated \
             stage faults ({first}; retry: {second})",
            self.name
        );
        None
    }

    /// HalfOpen cool-down probe: one batch through the pipelined plan,
    /// *answered from the sequential oracle either way* — the probe can
    /// never change what the caller receives, only whether the breaker
    /// closes. A probe whose pipelined bits match the oracle closes the
    /// site (a recovery); a faulting or mismatching probe re-opens it
    /// with the cool-down doubled.
    fn run_probe(
        &self,
        pipe: &PipelinePlan,
        breaker: &Breaker,
        input: &[f32],
        n_images: usize,
    ) -> Result<Vec<Vec<f32>>, GraphError> {
        let oracle = self.run_sequential(input, n_images)?;
        match pipe.run_batch(input, n_images) {
            Ok(outs) if outs == oracle => {
                if breaker.record_success() {
                    self.note_recovery(crate::util::timer::epoch_ns());
                }
            }
            probe => {
                if probe.is_err() {
                    self.faults.fetch_add(1, Ordering::Relaxed);
                }
                breaker.record_failure(crate::util::timer::epoch_ns());
            }
        }
        Ok(oracle)
    }

    /// Run a ragged tail of `k < batch` images, sized to the request
    /// stream instead of padding the stream to the plan: k=1 takes the
    /// latency plan, 1 < k < batch routes to the smallest plan-family
    /// variant that fits (zero-padded only up to the variant's batch),
    /// and only a model with no family pads all the way to `batch`.
    /// Outputs are truncated to the k real images and are bitwise those
    /// of the padded-to-batch baseline's first k images — batched
    /// kernels never mix accumulation across images, so the pad rows
    /// cannot perturb real ones. `k == batch` is just [`Self::run_all`].
    pub fn run_tail(&self, input: &[f32], k: usize) -> Result<Vec<Vec<f32>>, GraphError> {
        if k == 0 || k > self.batch {
            return Err(GraphError::Invalid(
                self.name.clone(),
                format!("tail of {k} images outside 1..={}", self.batch),
            ));
        }
        if k == self.batch {
            return self.run_all(input);
        }
        let per: usize = self.input_shape.iter().product::<usize>() / self.batch;
        let mut shape = self.input_shape.clone();
        shape[0] = k;
        self.check_input(input, k * per, &shape)?;
        if k == 1 {
            return self.run_one(input);
        }
        if let Some(idx) = self.variants.iter().position(|v| v.plan().batch() >= k) {
            let (variant, bank) = (&self.variants[idx], &self.variant_breakers[idx]);
            let vb = variant.plan().batch();
            let route = self.route(bank);
            if matches!(route, Route::Sequential) {
                // This variant is bypassed (its own breakers — a
                // tripped primary never demotes the tails): per-image
                // oracle, no padding, no batched-tail accounting.
                return self.run_sequential(input, k);
            }
            self.tail_runs.fetch_add(1, Ordering::Relaxed);
            self.padded_images
                .fetch_add((vb - k) as u64, Ordering::Relaxed);
            let padded = Tensor::pad_batch(input, per, vb);
            let mut outs = match route {
                Route::Probe(site) => self.run_probe(variant, &bank[site], &padded, vb)?,
                _ => match self.run_with_ladder(variant, bank, &padded, vb) {
                    Some(outs) => outs,
                    None => return self.run_sequential(input, k),
                },
            };
            for out in &mut outs {
                let probs = out.len() / vb;
                out.truncate(k * probs);
            }
            return Ok(outs);
        }
        // No family: the padded-to-batch baseline (run_all routes it by
        // the primary bank's breaker state like any other batch).
        self.tail_runs.fetch_add(1, Ordering::Relaxed);
        self.padded_images
            .fetch_add((self.batch - k) as u64, Ordering::Relaxed);
        let padded = Tensor::pad_batch(input, per, self.batch);
        let mut outs = self.run_all(&padded)?;
        for out in &mut outs {
            let probs = out.len() / self.batch;
            out.truncate(k * probs);
        }
        Ok(outs)
    }

    /// Batch sizes of the ragged-tail plan family, ascending. Empty
    /// means tails pad to the full batch (family disabled or the batch
    /// admits no interior sizes).
    pub fn variant_batches(&self) -> Vec<usize> {
        self.variants.iter().map(|v| v.plan().batch()).collect()
    }

    /// Cumulative ragged-tail accounting (tail executions and padded
    /// images) for this model.
    pub fn tail_stats(&self) -> TailStats {
        TailStats {
            tail_runs: self.tail_runs.load(Ordering::Relaxed),
            padded_images: self.padded_images.load(Ordering::Relaxed),
        }
    }

    /// Single-image latency path: executes the batch-1 plan
    /// sequentially (no batching, no pipeline handoffs). `image` holds
    /// one image; returns every output for it.
    pub fn run_one(&self, image: &[f32]) -> Result<Vec<Vec<f32>>, GraphError> {
        let plan = self.latency.as_ref().unwrap_or_else(|| self.pipeline.plan());
        debug_assert_eq!(plan.batch(), 1, "latency plan must be batch-1");
        let per: usize = self.input_shape.iter().product::<usize>() / self.batch;
        self.check_input(image, per, &self.input_shape[1..])?;
        let mut guard = self.latency_ctx.borrow_mut();
        let ctx = guard.get_or_insert_with(|| plan.new_context());
        plan.write_feed(ctx, 0, image)?;
        plan.execute_steps(ctx);
        let mut outs = Vec::with_capacity(plan.num_outputs());
        for i in 0..plan.num_outputs() {
            outs.push(plan.output(ctx, i).0.to_vec());
        }
        Ok(outs)
    }

    /// Degraded fallback: `n_images` images (the whole batch, or a
    /// ragged tail of it), one at a time, through the sequential batch-1
    /// plan — the same plan and kernels the interpreter-equivalence
    /// oracle checks, so degraded outputs are bitwise-identical to
    /// sequential execution by construction. No threads, no handoffs:
    /// slow, but it cannot stage-fault.
    fn run_sequential(&self, input: &[f32], n_images: usize) -> Result<Vec<Vec<f32>>, GraphError> {
        let plan = self.latency.as_ref().unwrap_or_else(|| self.pipeline.plan());
        debug_assert_eq!(plan.batch(), 1, "degraded path needs a batch-1 plan");
        let per = input.len() / n_images.max(1);
        let mut guard = self.latency_ctx.borrow_mut();
        let ctx = guard.get_or_insert_with(|| plan.new_context());
        let mut outs: Vec<Vec<f32>> = vec![Vec::new(); plan.num_outputs()];
        for i in 0..n_images {
            plan.write_feed(ctx, 0, &input[i * per..(i + 1) * per])?;
            plan.execute_steps(ctx);
            for (o, out) in outs.iter_mut().enumerate() {
                let (data, _) = plan.output(ctx, o);
                if out.capacity() == 0 {
                    out.reserve_exact(data.len() * n_images);
                }
                out.extend_from_slice(data);
            }
        }
        Ok(outs)
    }
}

/// The artifact registry: owns every loaded (compiled) model.
pub struct Runtime {
    pub artifacts_dir: PathBuf,
    /// Pipeline stages configured for every model loaded after this is
    /// set (see [`Runtime::with_threads`]); 1 = sequential.
    pub threads: usize,
    /// Intra-stage worker-team size for subsequently loaded models (see
    /// [`Runtime::with_team`]); 1 = no splitting.
    pub team: usize,
    /// When set, subsequently loaded models calibrate through
    /// [`LoadedModel::autotuned`] — measured cuts, measured team, per
    /// group-size repartitioning — and `threads` / `team` are ignored.
    pub autotune: Option<TuneOptions>,
    /// Ragged-tail plan family for subsequently loaded models: `None`
    /// picks the default family ({B/4, B/2} clipped to interior sizes),
    /// `Some(&[])` disables tail variants (tails pad to the full
    /// batch), and explicit sizes are used as given (clipped the same
    /// way). See [`Runtime::with_plan_family`].
    pub plan_family: Option<Vec<usize>>,
    /// Self-healing ladder tunables for subsequently loaded models:
    /// cool-down before a tripped site probes (`--recover-after-ms`)
    /// and whether recovery is enabled at all (`--no-recover`). See
    /// [`Runtime::with_recovery`].
    pub breaker_cfg: BreakerConfig,
    /// Plan-artifact cache directory ([`Runtime::with_plan_cache`]).
    /// When set, [`Runtime::load_graph`] tries
    /// `<dir>/<model>/plan.json` before compiling and persists a fresh
    /// artifact (plus `faults.json` fault history) on a miss.
    pub plan_cache: Option<PathBuf>,
    /// Models restored from a plan artifact by this runtime.
    pub cache_hits: usize,
    /// Models compiled fresh despite a configured plan cache (no
    /// artifact, stale key, or a corrupt/rejected file).
    pub cache_misses: usize,
    models: BTreeMap<String, LoadedModel>,
}

/// Default ragged-tail plan family for a batch-`batch` model: {B/4,
/// B/2}, filtered to interior sizes (k=1 is served by the latency plan
/// and k=B by the primary plan, so only `2..batch` earns a variant).
fn default_family(batch: usize) -> Vec<usize> {
    let mut sizes: Vec<usize> = [batch / 4, batch / 2]
        .into_iter()
        .filter(|&s| s > 1 && s < batch)
        .collect();
    sizes.dedup();
    sizes
}

impl Runtime {
    /// Create a CPU runtime rooted at an artifacts directory. The name
    /// is kept from the PJRT era so call sites read the same.
    pub fn cpu(artifacts_dir: &Path) -> Result<Runtime> {
        Ok(Runtime {
            artifacts_dir: artifacts_dir.to_path_buf(),
            threads: 1,
            team: 1,
            autotune: None,
            plan_family: None,
            breaker_cfg: BreakerConfig::default(),
            plan_cache: None,
            cache_hits: 0,
            cache_misses: 0,
            models: BTreeMap::new(),
        })
    }

    /// Enable the plan-artifact cache rooted at `dir` for subsequently
    /// loaded models: load-or-compile-and-save (see
    /// [`crate::artifact`] for the format and invalidation key).
    pub fn with_plan_cache(mut self, dir: &Path) -> Runtime {
        self.plan_cache = Some(dir.to_path_buf());
        self
    }

    /// Configure the pipeline stage count for subsequently loaded
    /// models (clamped to at least 1).
    pub fn with_threads(mut self, threads: usize) -> Runtime {
        self.threads = threads.max(1);
        self
    }

    /// Configure the intra-stage worker-team size for subsequently
    /// loaded models (clamped to at least 1; 1 = PR 3 behavior).
    pub fn with_team(mut self, team: usize) -> Runtime {
        self.team = team.max(1);
        self
    }

    /// Calibrate subsequently loaded models with the profile-guided
    /// autotuner (overrides `threads` / `team` for those models).
    pub fn with_autotune(mut self, opts: TuneOptions) -> Runtime {
        self.autotune = Some(opts);
        self
    }

    /// Set the ragged-tail plan family for subsequently loaded models.
    /// An empty slice disables tail variants (tails pad to the full
    /// batch — the pre-family behavior); without this call the default
    /// family applies. Sizes outside `2..batch` are ignored per model.
    pub fn with_plan_family(mut self, sizes: &[usize]) -> Runtime {
        self.plan_family = Some(sizes.to_vec());
        self
    }

    /// Configure the self-healing ladder for subsequently loaded
    /// models (cool-down, back-off cap, `recover: false` for PR 6's
    /// sticky degrade).
    pub fn with_recovery(mut self, cfg: BreakerConfig) -> Runtime {
        self.breaker_cfg = cfg;
        self
    }

    pub fn platform(&self) -> String {
        // e.g. "exec-cpu/fma": the active SIMD dispatch tier is part of
        // the platform identity (it changes dense result bits within the
        // documented ulp bound, so reports should record it).
        format!("exec-cpu/{}", crate::exec::isa::active().name())
    }

    /// The invalidation spec for loading `batch`-image models through
    /// this runtime's current configuration (see
    /// [`crate::artifact::cache_key`]).
    fn cache_spec(&self, batch: usize, family: &[usize]) -> CacheSpec {
        CacheSpec {
            opts: PlanOptions::default(),
            batch,
            family: family.to_vec(),
            threads: self.threads,
            team: self.team,
            autotune: self.autotune.is_some(),
            tune_cores: self.autotune.as_ref().map(|o| o.budget()).unwrap_or(0),
        }
    }

    /// Try to restore `name` from the plan cache. `None` means "compile
    /// fresh" — the artifact was absent, stale, or rejected (every
    /// rejection is reported, none is fatal).
    fn try_cached(&self, name: &str, graph: &Graph, batch: usize, family: &[usize]) -> Option<LoadedModel> {
        let dir = self.plan_cache.as_ref()?;
        let key = artifact::cache_key(graph, &self.cache_spec(batch, family));
        let restored = artifact::load(&dir.join(name), key)
            .map_err(crate::util::error::Error::from)
            .and_then(|art| {
                crate::ensure!(
                    art.batch == batch,
                    "artifact batch {} != requested {batch}",
                    art.batch
                );
                LoadedModel::from_artifact(name, graph, art)
            });
        match restored {
            Ok(model) => Some(model),
            Err(e) => {
                eprintln!("model '{name}': plan cache: {e}; compiling fresh");
                None
            }
        }
    }

    /// Restore persisted fault history (`faults.json` next to the plan
    /// artifact) into a freshly loaded model. Absent or unreadable
    /// history is simply skipped — it can delay reporting, never serving.
    fn restore_faults(&self, name: &str, model: &mut LoadedModel) {
        let Some(dir) = &self.plan_cache else { return };
        let path = dir.join(name).join("faults.json");
        let Ok(text) = std::fs::read_to_string(&path) else { return };
        match Json::parse(&text) {
            Ok(j) => {
                let field = |k: &str| j.get(k).as_f64().map(|v| v.max(0.0) as u64).unwrap_or(0);
                let stats = FaultStats {
                    faults: field("faults"),
                    retries: field("retries"),
                    trips: field("trips"),
                    recoveries: field("recoveries"),
                    degraded: false,
                    time_degraded_ns: field("time_degraded_ns"),
                };
                model.set_restored_faults(stats, field("last_cooldown_ns"));
            }
            Err(e) => eprintln!("model '{name}': ignoring {}: {e}", path.display()),
        }
    }

    /// Persist every model's cumulative fault history (restored history
    /// + this process's counters) next to its plan artifact. A no-op
    /// without a plan cache; returns how many models were written.
    pub fn persist_faults(&self) -> usize {
        let Some(dir) = &self.plan_cache else { return 0 };
        let mut written = 0;
        for m in self.models.values() {
            let (prev, cur) = (m.restored_faults(), m.fault_stats());
            let cooldown = m.max_cooldown_ns().max(m.restored_cooldown_ns());
            let mut j = Json::obj();
            j.set("faults", Json::from((prev.faults + cur.faults) as f64))
                .set("retries", Json::from((prev.retries + cur.retries) as f64))
                .set("trips", Json::from((prev.trips + cur.trips) as f64))
                .set("recoveries", Json::from((prev.recoveries + cur.recoveries) as f64))
                .set(
                    "time_degraded_ns",
                    Json::from((prev.time_degraded_ns + cur.time_degraded_ns) as f64),
                )
                .set("last_cooldown_ns", Json::from(cooldown as f64));
            let model_dir = dir.join(&m.name);
            if std::fs::create_dir_all(&model_dir).is_ok()
                && std::fs::write(model_dir.join("faults.json"), j.pretty()).is_ok()
            {
                written += 1;
            }
        }
        written
    }

    /// Compile a graph into a named executable (calibrating it first
    /// when the runtime was configured with [`Runtime::with_autotune`])
    /// — or, with a plan cache configured, restore it from its on-disk
    /// artifact and skip the fold/encode/pack/profile work entirely,
    /// persisting a fresh artifact whenever the cache misses.
    pub fn load_graph(&mut self, name: &str, graph: &Graph, batch: usize) -> Result<()> {
        let sizes = match &self.plan_family {
            Some(sizes) => sizes.clone(),
            None => default_family(batch),
        };
        let mut model = match self.try_cached(name, graph, batch, &sizes) {
            Some(model) => {
                self.cache_hits += 1;
                model
            }
            None => {
                let mut model = match &self.autotune {
                    Some(opts) => LoadedModel::autotuned(name, graph, batch, opts)
                        .with_context(|| format!("calibrating model '{name}'"))?,
                    None => {
                        LoadedModel::from_graph_with(name, graph, batch, self.threads, self.team)
                            .with_context(|| format!("compiling model '{name}'"))?
                    }
                };
                model
                    .add_plan_family(graph, &sizes)
                    .with_context(|| format!("building plan family for '{name}'"))?;
                if let Some(dir) = &self.plan_cache {
                    self.cache_misses += 1;
                    let key = artifact::cache_key(graph, &self.cache_spec(batch, &sizes));
                    if let Err(e) = artifact::save(&dir.join(name), &model.to_artifact(key)) {
                        eprintln!("model '{name}': failed to persist plan artifact: {e}");
                    }
                }
                model
            }
        };
        // One pass re-keys every bank (primary + variants) whether the
        // model was compiled or restored — banks always start closed.
        model.set_breaker_config(self.breaker_cfg);
        self.restore_faults(name, &mut model);
        // Serving models keep their stage workers parked between runs:
        // warm per-stage contexts, no per-batch spawn cost (a no-op for
        // single-stage pipelines).
        if model.serves_pipelined() {
            model.pipeline.enable_persistent_pool();
        }
        self.models.insert(name.to_string(), model);
        Ok(())
    }

    /// Load everything listed in `artifacts/manifest.json` (written by
    /// python/compile/aot.py): every batch variant of the trained
    /// TinyCNN graphdef, plus demo kernel entries.
    ///
    /// The manifest's HLO path fields (`models` values, `kernels[*]
    /// .path`) are ignored: they point at the XLA artifacts the retired
    /// PJRT runtime consumed. Models execute the `tinycnn` graphdef
    /// through compiled plans, and kernel entries get a deterministic
    /// synthetic sparse-conv graph of the declared input shape (so
    /// `sparse_conv_demo` benchmarks the RLE kernel, not the exported
    /// HLO).
    pub fn load_manifest(&mut self) -> Result<Vec<String>> {
        let manifest_path = self.artifacts_dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).with_context(|| {
            format!(
                "reading {} — run `make artifacts` first",
                manifest_path.display()
            )
        })?;
        let root = Json::parse(&text)?;
        let mut loaded = Vec::new();
        if let Some(models) = root.get("models").as_obj() {
            let graph = graphdef::load(&self.artifacts_dir.join("tinycnn"))
                .context("loading tinycnn graphdef")?;
            for batch_str in models.keys() {
                let batch: usize = batch_str.parse().context("batch key")?;
                let name = format!("tinycnn_b{batch}");
                self.load_graph(&name, &graph, batch)?;
                loaded.push(name);
            }
        }
        if let Some(kernels) = root.get("kernels").as_obj() {
            for (kname, spec) in kernels {
                let shape = spec
                    .get("input_shape")
                    .usize_vec()
                    .context("kernel input_shape")?;
                crate::ensure!(
                    shape.len() == 4,
                    "kernel '{kname}': only 4-D (NHWC) demo kernels are supported, \
                     got input_shape {shape:?}"
                );
                let graph = sparse_conv_demo_graph(&shape, 0.8);
                self.load_graph(kname, &graph, 1)?;
                loaded.push(kname.clone());
            }
        }
        Ok(loaded)
    }

    pub fn model(&self, name: &str) -> Option<&LoadedModel> {
        self.models.get(name)
    }

    pub fn model_names(&self) -> Vec<&str> {
        self.models.keys().map(|s| s.as_str()).collect()
    }

    /// Every loaded model, in name order — the coordinator walks this
    /// to fold per-model [`FaultStats`] into its serve report.
    pub fn models(&self) -> impl Iterator<Item = &LoadedModel> {
        self.models.values()
    }

    /// Pick the loaded tinycnn variant with the largest batch ≤ n.
    pub fn best_batch_model(&self, n: usize) -> Option<&LoadedModel> {
        self.models
            .values()
            .filter(|m| m.name.starts_with("tinycnn_b") && m.batch <= n)
            .max_by_key(|m| m.batch)
    }
}

/// A deterministic single-layer sparse conv graph standing in for the
/// former HLO kernel artifact: 3x3 SAME conv, 8 output channels, weights
/// magnitude-pruned to `sparsity` so the plan selects the RLE kernel.
fn sparse_conv_demo_graph(input_shape: &[usize], sparsity: f64) -> Graph {
    let mut g = Graph::new();
    let mut rng = Rng::new(0x5BA25E);
    g.op("input", Op::Placeholder { shape: input_shape.to_vec() }, &[]);
    let ci = input_shape[3];
    let mut w = Tensor::randn(&[3, 3, ci, 8], &mut rng, 0.3);
    prune_tensor(&mut w, sparsity);
    g.constant("w", w);
    g.op(
        "conv",
        Op::Conv2D { stride: (1, 1), padding: crate::graph::Padding::Same },
        &["input", "w"],
    );
    g.outputs = vec!["conv".into()];
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::interp;
    use crate::nets::{tiny_cnn, NetConfig};

    #[test]
    fn loaded_model_matches_interpreter() {
        let g = tiny_cnn(NetConfig::test_scale());
        let m = LoadedModel::from_graph("tinycnn_b1", &g, 1).unwrap();
        let mut rng = Rng::new(21);
        let n: usize = m.input_shape.iter().product();
        let input: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let got = m.run(&input).unwrap();
        let mut feeds = BTreeMap::new();
        feeds.insert(
            "input".to_string(),
            Tensor::from_vec(&m.input_shape, input.clone()),
        );
        let want = interp::run_outputs(&g, &feeds).unwrap();
        assert_eq!(got.len(), want[0].data.len());
        for (a, b) in got.iter().zip(&want[0].data) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn batch_model_is_per_image_consistent() {
        let g = tiny_cnn(NetConfig::test_scale());
        let m1 = LoadedModel::from_graph("tinycnn_b1", &g, 1).unwrap();
        let m4 = LoadedModel::from_graph("tinycnn_b4", &g, 4).unwrap();
        // threads == 1: the whole batch is one native plan execution
        assert_eq!(m4.group(), 4);
        let per: usize = m1.input_shape.iter().product();
        let mut rng = Rng::new(33);
        let block: Vec<f32> = (0..4 * per).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let out4 = m4.run(&block).unwrap();
        let probs = out4.len() / 4;
        for i in 0..4 {
            let out1 = m1.run(&block[i * per..(i + 1) * per]).unwrap();
            assert_eq!(out1, &out4[i * probs..(i + 1) * probs]);
            // the latency path agrees with both
            let one = m4.run_one(&block[i * per..(i + 1) * per]).unwrap();
            assert_eq!(one[0], out1);
        }
    }

    #[test]
    fn group_size_balances_amortization_and_stages() {
        assert_eq!(group_size(8, 1), 8); // sequential: one execution
        assert_eq!(group_size(8, 4), 2); // 4 groups of 2 keep 4 stages busy
        assert_eq!(group_size(8, 2), 4);
        // batch < threads: keep >= 2 groups for overlap, not per-image
        assert_eq!(group_size(4, 8), 2);
        assert_eq!(group_size(1, 4), 1);
        assert_eq!(group_size(6, 2), 3);
        assert_eq!(group_size(7, 2), 1); // prime: no uniform middle ground
    }

    #[test]
    fn multi_output_model_requires_run_all() {
        use crate::graph::Padding;
        let mut g = Graph::new();
        let mut rng = Rng::new(0xA11);
        g.op("input", Op::Placeholder { shape: vec![1, 6, 6, 3] }, &[]);
        g.constant("w", Tensor::randn(&[3, 3, 3, 4], &mut rng, 0.2));
        g.op(
            "conv",
            Op::Conv2D { stride: (1, 1), padding: Padding::Same },
            &["input", "w"],
        );
        g.op("relu", Op::Relu, &["conv"]);
        g.outputs = vec!["conv".into(), "relu".into()];
        let m = LoadedModel::from_graph("twohead", &g, 2).unwrap();
        let n: usize = m.input_shape.iter().product();
        let input: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        // run() must refuse rather than silently drop the second head
        assert!(m.run(&input).is_err());
        let outs = m.run_all(&input).unwrap();
        assert_eq!(outs.len(), 2);
        assert_eq!(outs[0].len(), outs[1].len());
        // relu head is the clamped conv head
        for (c, r) in outs[0].iter().zip(&outs[1]) {
            assert_eq!(c.max(0.0), *r);
        }
    }

    #[test]
    fn invalid_inputs_yield_typed_errors() {
        let g = tiny_cnn(NetConfig::test_scale());
        let m = LoadedModel::from_graph("tinycnn_b1", &g, 1).unwrap();
        let n: usize = m.input_shape.iter().product();
        // wrong length: typed Shape error, nothing executed
        assert!(matches!(
            m.run(&vec![0.0; n - 1]),
            Err(GraphError::Shape(_, _))
        ));
        assert!(matches!(
            m.run_all(&vec![0.0; n + 1]),
            Err(GraphError::Shape(_, _))
        ));
        // non-finite values: typed Invalid error naming the bad index
        let mut bad = vec![0.0; n];
        bad[3] = f32::NAN;
        assert!(matches!(m.run(&bad), Err(GraphError::Invalid(_, _))));
        bad[3] = f32::INFINITY;
        assert!(matches!(m.run_all(&bad), Err(GraphError::Invalid(_, _))));
        assert!(matches!(m.run_one(&bad), Err(GraphError::Invalid(_, _))));
        // rejected requests are not faults and never degrade the model
        assert_eq!(m.fault_stats(), FaultStats::default());
        assert!(!m.is_degraded());
    }

    #[test]
    fn pipelined_model_matches_sequential_model() {
        let g = tiny_cnn(NetConfig::test_scale());
        let seq = LoadedModel::from_graph("seq", &g, 4).unwrap();
        let piped = LoadedModel::from_graph_with("piped", &g, 4, 4, 1).unwrap();
        assert!(piped.pipeline().num_stages() > 1);
        let n: usize = seq.input_shape.iter().product();
        let mut rng = Rng::new(55);
        let input: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        // identical kernel sequence per image: bit-identical outputs
        assert_eq!(seq.run(&input).unwrap(), piped.run(&input).unwrap());
    }

    #[test]
    fn team_model_matches_sequential_model() {
        let g = tiny_cnn(NetConfig::test_scale());
        let seq = LoadedModel::from_graph("seq", &g, 4).unwrap();
        // team without pipeline stages: 1-stage pipeline, split convs
        let solo_team = LoadedModel::from_graph_with("solo", &g, 4, 1, 2).unwrap();
        assert_eq!(solo_team.pipeline().num_stages(), 1);
        assert!(!solo_team.pipeline().team_steps().is_empty());
        // team on top of a multi-stage pipeline
        let piped_team = LoadedModel::from_graph_with("piped", &g, 4, 2, 2).unwrap();
        assert!(piped_team.pipeline().num_stages() > 1);
        let n: usize = seq.input_shape.iter().product();
        let mut rng = Rng::new(56);
        let input: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        // disjoint row ranges, unchanged accumulation order: bitwise
        let want = seq.run(&input).unwrap();
        assert_eq!(want, solo_team.run(&input).unwrap());
        assert_eq!(want, piped_team.run(&input).unwrap());
    }

    #[test]
    fn autotuned_model_serves_measured_cuts() {
        use crate::exec::ProfileOptions;
        let g = tiny_cnn(NetConfig::test_scale());
        let opts = TuneOptions {
            cores: 4,
            profile: ProfileOptions { warmup: 0, runs: 1, ..Default::default() },
        };
        let tuned = LoadedModel::autotuned("tuned", &g, 8, &opts).unwrap();
        let report = tuned.tune_report().unwrap();
        assert_eq!(report.batch, 8);
        assert_eq!(report.cores, 4);
        let chosen = report.chosen().expect("chosen group calibrated");
        // the serving pipeline runs the measured cuts and measured team
        assert_eq!(tuned.pipeline().num_stages(), chosen.cuts.stages);
        assert_eq!(tuned.pipeline().team(), chosen.cuts.team);
        assert_eq!(tuned.group(), report.chosen_group);
        // the chosen group's cuts were measured on ITS plan, not B=1's
        assert_eq!(chosen.profile.batch, report.chosen_group);
        assert_eq!(tuned.pipeline().stage_ranges(), &chosen.cuts.ranges[..]);
        // cuts only move work between threads: results match the static
        // model (cross-batch dense paths are ULP-level, use tolerance)
        let seq = LoadedModel::from_graph("seq", &g, 8).unwrap();
        let n: usize = seq.input_shape.iter().product();
        let mut rng = Rng::new(77);
        let input: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let (a, b) = (seq.run(&input).unwrap(), tuned.run(&input).unwrap());
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-4, "{x} vs {y}");
        }
    }

    #[test]
    fn autotuned_single_core_stays_sequential() {
        use crate::exec::ProfileOptions;
        let g = tiny_cnn(NetConfig::test_scale());
        let opts = TuneOptions {
            cores: 1,
            profile: ProfileOptions { warmup: 0, runs: 1, ..Default::default() },
        };
        let m = LoadedModel::autotuned("solo", &g, 4, &opts).unwrap();
        assert_eq!(m.pipeline().num_stages(), 1);
        assert_eq!(m.pipeline().team(), 1);
        // one group, one calibration entry — nothing re-profiled
        assert_eq!(m.tune_report().unwrap().entries.len(), 1);
    }

    #[test]
    fn autotuning_runtime_loads_calibrated_models() {
        use crate::exec::ProfileOptions;
        let g = tiny_cnn(NetConfig::test_scale());
        let opts = TuneOptions {
            cores: 2,
            profile: ProfileOptions { warmup: 0, runs: 1, ..Default::default() },
        };
        let mut rt = Runtime::cpu(Path::new("/nonexistent")).unwrap().with_autotune(opts);
        rt.load_graph("tinycnn_b4", &g, 4).unwrap();
        let m = rt.model("tinycnn_b4").unwrap();
        assert!(m.tune_report().is_some());
        assert!(m.pipeline().num_stages() <= 2);
    }

    #[test]
    fn wrong_input_length_rejected() {
        let g = tiny_cnn(NetConfig::test_scale());
        let m = LoadedModel::from_graph("m", &g, 1).unwrap();
        assert!(m.run(&[0.0; 7]).is_err());
    }

    #[test]
    fn runtime_registry_and_batch_pick() {
        let g = tiny_cnn(NetConfig::test_scale());
        let mut rt = Runtime::cpu(Path::new("/nonexistent")).unwrap();
        rt.load_graph("tinycnn_b1", &g, 1).unwrap();
        rt.load_graph("tinycnn_b8", &g, 8).unwrap();
        assert_eq!(rt.model_names(), vec!["tinycnn_b1", "tinycnn_b8"]);
        assert_eq!(rt.best_batch_model(3).unwrap().batch, 1);
        assert_eq!(rt.best_batch_model(8).unwrap().batch, 8);
        assert_eq!(rt.best_batch_model(100).unwrap().batch, 8);
    }

    #[test]
    fn ragged_tail_routes_to_smallest_variant_bitwise() {
        let g = tiny_cnn(NetConfig::test_scale());
        let mut m = LoadedModel::from_graph_with("tinycnn_b8", &g, 8, 2, 1).unwrap();
        m.add_plan_family(&g, &default_family(8)).unwrap();
        assert_eq!(m.variant_batches(), vec![2, 4]);
        let per: usize = m.input_shape.iter().product::<usize>() / 8;
        let mut rng = Rng::new(91);
        let block: Vec<f32> = (0..8 * per).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        // padded-to-B baseline for the first k images
        let full = m.run_all(&block).unwrap();
        let probs = full[0].len() / 8;
        for k in [2usize, 3, 4, 5, 7] {
            let before = m.tail_stats();
            let tail = m.run_tail(&block[..k * per], k).unwrap();
            assert_eq!(tail.len(), full.len());
            // bitwise: the tail variant runs the same kernel sequence
            // per image, and pad rows never feed real accumulators
            assert_eq!(tail[0], &full[0][..k * probs], "tail k={k}");
            let after = m.tail_stats();
            assert_eq!(after.tail_runs, before.tail_runs + 1);
            let vb = *[2usize, 4, 8].iter().find(|&&v| v >= k).unwrap();
            assert_eq!(after.padded_images, before.padded_images + (vb - k) as u64);
        }
    }

    #[test]
    fn tail_of_one_takes_the_latency_plan() {
        let g = tiny_cnn(NetConfig::test_scale());
        let mut m = LoadedModel::from_graph("tinycnn_b8", &g, 8).unwrap();
        m.add_plan_family(&g, &default_family(8)).unwrap();
        let per: usize = m.input_shape.iter().product::<usize>() / 8;
        let mut rng = Rng::new(92);
        let image: Vec<f32> = (0..per).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let tail = m.run_tail(&image, 1).unwrap();
        assert_eq!(tail, m.run_one(&image).unwrap());
        // no batched tail execution, no padding — the latency plan ran
        assert_eq!(m.tail_stats(), TailStats::default());
    }

    #[test]
    fn tail_without_family_pads_to_full_batch() {
        let g = tiny_cnn(NetConfig::test_scale());
        let m = LoadedModel::from_graph("tinycnn_b8", &g, 8).unwrap(); // no family
        assert!(m.variant_batches().is_empty());
        let per: usize = m.input_shape.iter().product::<usize>() / 8;
        let mut rng = Rng::new(93);
        let block: Vec<f32> = (0..8 * per).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let full = m.run_all(&block).unwrap();
        let probs = full[0].len() / 8;
        let tail = m.run_tail(&block[..3 * per], 3).unwrap();
        assert_eq!(tail[0], &full[0][..3 * probs]);
        assert_eq!(
            m.tail_stats(),
            TailStats { tail_runs: 1, padded_images: 5 }
        );
    }

    #[test]
    fn tripped_variant_serves_tails_sequentially_without_demoting_the_model() {
        let g = tiny_cnn(NetConfig::test_scale());
        let mut m = LoadedModel::from_graph("tinycnn_b8", &g, 8).unwrap();
        // no-recover: the trip is sticky, so routing stays deterministic
        m.set_breaker_config(BreakerConfig { recover: false, ..Default::default() });
        m.add_plan_family(&g, &[4]).unwrap();
        m.variant_breakers[0][0].force_trip(1);
        assert!(m.is_degraded());
        let per: usize = m.input_shape.iter().product::<usize>() / 8;
        let mut rng = Rng::new(94);
        let block: Vec<f32> = (0..3 * per).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let tail = m.run_tail(&block, 3).unwrap();
        // sequential fallback: per-image latency-plan outputs, bitwise
        for i in 0..3 {
            let one = m.run_one(&block[i * per..(i + 1) * per]).unwrap();
            let probs = tail[0].len() / 3;
            assert_eq!(one[0], &tail[0][i * probs..(i + 1) * probs]);
        }
        // bypassed tails never touch the batched variants
        assert_eq!(m.tail_stats(), TailStats::default());
        // ...while the primary path is untouched by the variant's trip
        let full: Vec<f32> = (0..8 * per).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        assert!(m.run_all(&full).is_ok());
        let stats = m.fault_stats();
        assert_eq!((stats.trips, stats.recoveries), (1, 0));
        assert!(stats.degraded);
    }

    #[test]
    fn tripped_primary_probes_after_cooldown_and_recovers() {
        let g = tiny_cnn(NetConfig::test_scale());
        let mut m = LoadedModel::from_graph_with("piped", &g, 4, 2, 1).unwrap();
        // zero cool-down: the very next batch is allowed to probe
        m.set_breaker_config(BreakerConfig::with_cooldown_ms(0));
        assert!(m.serves_pipelined());
        let n: usize = m.input_shape.iter().product();
        let mut rng = Rng::new(95);
        let input: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let want = m.run_all(&input).unwrap();
        let now = crate::util::timer::epoch_ns();
        m.breakers[0].force_trip(now);
        m.note_trip(now);
        assert!(m.is_degraded());
        // the probe runs HalfOpen, matches the oracle bitwise, and
        // closes the site — the answer is the oracle's either way
        assert_eq!(m.run_all(&input).unwrap(), want);
        assert!(!m.is_degraded());
        let stats = m.fault_stats();
        assert_eq!((stats.trips, stats.recoveries), (1, 1));
        assert!(stats.time_degraded_ns > 0, "degrade interval was clocked");
        // healthy again: later batches take the pipelined path
        assert_eq!(m.run_all(&input).unwrap(), want);
        assert_eq!(m.fault_stats().faults, 0, "no faults in this scenario");
    }

    #[test]
    fn tail_rejects_malformed_requests() {
        let g = tiny_cnn(NetConfig::test_scale());
        let m = LoadedModel::from_graph("tinycnn_b4", &g, 4).unwrap();
        let per: usize = m.input_shape.iter().product::<usize>() / 4;
        assert!(matches!(
            m.run_tail(&vec![0.0; per], 0),
            Err(GraphError::Invalid(_, _))
        ));
        assert!(matches!(
            m.run_tail(&vec![0.0; 5 * per], 5),
            Err(GraphError::Invalid(_, _))
        ));
        assert!(matches!(
            m.run_tail(&vec![0.0; per], 2),
            Err(GraphError::Shape(_, _))
        ));
        let mut bad = vec![0.0; 2 * per];
        bad[1] = f32::NAN;
        assert!(matches!(m.run_tail(&bad, 2), Err(GraphError::Invalid(_, _))));
        assert_eq!(m.tail_stats(), TailStats::default());
    }

    #[test]
    fn runtime_plan_family_config_round_trips() {
        let g = tiny_cnn(NetConfig::test_scale());
        // default: {B/4, B/2}
        let mut rt = Runtime::cpu(Path::new("/nonexistent")).unwrap();
        rt.load_graph("tinycnn_b8", &g, 8).unwrap();
        assert_eq!(rt.model("tinycnn_b8").unwrap().variant_batches(), vec![2, 4]);
        // explicit empty family disables tail variants
        let mut rt = Runtime::cpu(Path::new("/nonexistent")).unwrap().with_plan_family(&[]);
        rt.load_graph("tinycnn_b8", &g, 8).unwrap();
        assert!(rt.model("tinycnn_b8").unwrap().variant_batches().is_empty());
        // explicit sizes are clipped to interior values and deduped
        let mut rt = Runtime::cpu(Path::new("/nonexistent"))
            .unwrap()
            .with_plan_family(&[1, 3, 3, 8, 9, 2]);
        rt.load_graph("tinycnn_b8", &g, 8).unwrap();
        assert_eq!(rt.model("tinycnn_b8").unwrap().variant_batches(), vec![2, 3]);
    }

    #[test]
    fn autotuned_family_reuses_calibration() {
        use crate::exec::ProfileOptions;
        let g = tiny_cnn(NetConfig::test_scale());
        let opts = TuneOptions {
            cores: 4,
            profile: ProfileOptions { warmup: 0, runs: 1, ..Default::default() },
        };
        let mut rt = Runtime::cpu(Path::new("/nonexistent")).unwrap().with_autotune(opts);
        rt.load_graph("tinycnn_b8", &g, 8).unwrap();
        let m = rt.model("tinycnn_b8").unwrap();
        assert_eq!(m.variant_batches(), vec![2, 4]);
        // variant teams come from rescaling the chosen profile — no
        // extra calibration entries beyond pass 1 + pass 2
        assert!(m.tune_report().unwrap().entries.len() <= 2);
    }

    #[test]
    fn demo_kernel_graph_is_sparse_and_runs() {
        let g = sparse_conv_demo_graph(&[1, 8, 8, 4], 0.8);
        let m = LoadedModel::from_graph("sparse_conv_demo", &g, 1).unwrap();
        assert!(m.plan_stats().sparse_convs >= 1);
        let n: usize = m.input_shape.iter().product();
        let out = m.run(&vec![1.0; n]).unwrap();
        assert!(out.iter().any(|&v| v != 0.0));
    }
}
