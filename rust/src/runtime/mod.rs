//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them.
//!
//! The request-path half of the three-layer architecture: Python/JAX
//! lowered the Pallas-kernel model to HLO text once (`make artifacts`);
//! this module compiles it on the PJRT CPU client at startup and executes
//! it for every inference — no Python anywhere near the hot path.
//! Pattern follows /opt/xla-example/load_hlo.

use crate::util::Json;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// A compiled executable plus its I/O metadata.
pub struct LoadedModel {
    pub name: String,
    pub batch: usize,
    pub input_shape: Vec<usize>,
    exe: xla::PjRtLoadedExecutable,
}

impl LoadedModel {
    /// Run one batch. `input` is row-major f32 of `input_shape` (with
    /// the leading dim = batch). Returns the first output tensor's data.
    pub fn run(&self, input: &[f32]) -> Result<Vec<f32>> {
        let expect: usize = self.input_shape.iter().product();
        if input.len() != expect {
            bail!(
                "input length {} != shape {:?} ({} elements)",
                input.len(),
                self.input_shape,
                expect
            );
        }
        let dims: Vec<i64> = self.input_shape.iter().map(|&d| d as i64).collect();
        let lit = xla::Literal::vec1(input).reshape(&dims)?;
        let result = self.exe.execute::<xla::Literal>(&[lit])?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }
}

/// The artifact registry: owns the PJRT client and every loaded model.
pub struct Runtime {
    client: xla::PjRtClient,
    pub artifacts_dir: PathBuf,
    models: BTreeMap<String, LoadedModel>,
}

impl Runtime {
    /// Create a CPU PJRT client.
    pub fn cpu(artifacts_dir: &Path) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime {
            client,
            artifacts_dir: artifacts_dir.to_path_buf(),
            models: BTreeMap::new(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile an HLO-text file into a named executable.
    pub fn load_hlo(
        &mut self,
        name: &str,
        path: &Path,
        batch: usize,
        input_shape: Vec<usize>,
    ) -> Result<()> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        self.models.insert(
            name.to_string(),
            LoadedModel {
                name: name.to_string(),
                batch,
                input_shape,
                exe,
            },
        );
        Ok(())
    }

    /// Load everything listed in `artifacts/manifest.json` (written by
    /// python/compile/aot.py).
    pub fn load_manifest(&mut self) -> Result<Vec<String>> {
        let manifest_path = self.artifacts_dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).with_context(|| {
            format!(
                "reading {} — run `make artifacts` first",
                manifest_path.display()
            )
        })?;
        let root = Json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
        let base_shape = root
            .get("input_shape")
            .usize_vec()
            .context("manifest input_shape")?;
        let mut loaded = Vec::new();
        if let Some(models) = root.get("models").as_obj() {
            for (batch_str, rel) in models {
                let batch: usize = batch_str.parse().context("batch key")?;
                let mut shape = base_shape.clone();
                shape[0] = batch;
                let name = format!("tinycnn_b{batch}");
                let path = self.artifacts_dir.join(rel.as_str().context("model path")?);
                self.load_hlo(&name, &path, batch, shape)?;
                loaded.push(name);
            }
        }
        if let Some(kernels) = root.get("kernels").as_obj() {
            for (kname, spec) in kernels {
                let path = self
                    .artifacts_dir
                    .join(spec.get("path").as_str().context("kernel path")?);
                let shape = spec
                    .get("input_shape")
                    .usize_vec()
                    .context("kernel input_shape")?;
                self.load_hlo(kname, &path, 1, shape)?;
                loaded.push(kname.clone());
            }
        }
        Ok(loaded)
    }

    pub fn model(&self, name: &str) -> Option<&LoadedModel> {
        self.models.get(name)
    }

    pub fn model_names(&self) -> Vec<&str> {
        self.models.keys().map(|s| s.as_str()).collect()
    }

    /// Pick the loaded tinycnn variant with the largest batch ≤ n.
    pub fn best_batch_model(&self, n: usize) -> Option<&LoadedModel> {
        self.models
            .values()
            .filter(|m| m.name.starts_with("tinycnn_b") && m.batch <= n)
            .max_by_key(|m| m.batch)
    }
}

// Integration tests live in rust/tests/e2e.rs (they need artifacts/).
