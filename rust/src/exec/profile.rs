//! Instrumented execution: measure what each plan step *actually* costs.
//!
//! HPIPE's Algorithm 1 allocates multipliers from an analytic throughput
//! model; the model is good enough to balance hardware stages, but our
//! software analog inherits every mismatch between modeled cycles and
//! real wall time — cache behavior, packing effects, allocator and
//! threading noise the cycle model cannot see. This module is the
//! measurement half of the profile-guided tuner (`super::tune`): run
//! deterministic warmup images through the *sequential* plan, time every
//! step with a monotonic scoped timer ([`crate::util::timer::ScopedNs`]),
//! and keep the **median of K** timed passes per step so one descheduled
//! run cannot skew a cut decision.
//!
//! A [`StepProfile`] is captured **per plan** — and a plan is compiled
//! for one batch size — so profiling the batch-B plan is exactly the
//! per-batch-size capture batched repartitioning needs: step costs do
//! not scale uniformly with B (im2col amortization, packed-panel reuse
//! and cache pressure all shift the balance), and the resulting cuts are
//! genuinely different from the B=1 cuts the static path reuses.

use super::ExecutionPlan;
use crate::util::timer::ScopedNs;
use crate::util::{Json, Rng};
use std::sync::atomic::{AtomicU64, Ordering};

/// Knobs for a profiling pass.
#[derive(Clone, Copy, Debug)]
pub struct ProfileOptions {
    /// Untimed executions before measurement (warms caches, faults in
    /// the arena, settles the branch predictors).
    pub warmup: usize,
    /// Timed executions; each step keeps its median over these.
    pub runs: usize,
    /// Seed for the deterministic synthetic warmup images.
    pub seed: u64,
}

impl Default for ProfileOptions {
    fn default() -> Self {
        ProfileOptions { warmup: 1, runs: 5, seed: 0x9F0F11E }
    }
}

/// Measured per-step wall times for one plan at one batch size.
#[derive(Clone, Debug)]
pub struct StepProfile {
    /// Batch dimension of the profiled plan (the group size its cuts
    /// will serve).
    pub batch: usize,
    /// Timed runs each median was taken over.
    pub runs: usize,
    /// Step names, in plan order (diagnostics / report output).
    pub names: Vec<String>,
    /// Median wall time per step in nanoseconds (≥ 1, so a
    /// sub-resolution step still counts as work for the partitioner).
    pub costs_ns: Vec<u64>,
}

impl StepProfile {
    /// Total measured plan time (sum of step medians).
    pub fn total_ns(&self) -> u64 {
        self.costs_ns.iter().sum()
    }

    /// A profile with hand-picked costs for `plan`'s steps — the tuner
    /// tests drive known costs through the cut policy with this, and the
    /// equivalence tests use it to prove results are cut-invariant.
    pub fn synthetic(plan: &ExecutionPlan, costs_ns: Vec<u64>) -> StepProfile {
        assert_eq!(costs_ns.len(), plan.steps.len(), "one cost per plan step");
        StepProfile {
            batch: plan.batch(),
            runs: 0,
            names: plan.step_names().iter().map(|s| s.to_string()).collect(),
            costs_ns,
        }
    }

    /// Machine-readable form (embedded in the `TuneReport` JSON).
    pub fn to_json(&self) -> Json {
        let mut steps = Json::Arr(vec![]);
        for (name, &ns) in self.names.iter().zip(&self.costs_ns) {
            steps.push(Json::from_pairs(vec![
                ("name", Json::from(name.as_str())),
                ("ns", Json::from(ns as f64)),
            ]));
        }
        Json::from_pairs(vec![
            ("batch", Json::from(self.batch)),
            ("runs", Json::from(self.runs)),
            ("total_ns", Json::from(self.total_ns() as f64)),
            ("steps", steps),
        ])
    }

    /// Inverse of [`Self::to_json`] — the artifact cache restores a
    /// saved calibration profile with this instead of re-measuring.
    pub fn from_json(j: &Json) -> Result<StepProfile, String> {
        let batch = j.get("batch").as_usize().ok_or("profile: missing batch")?;
        let runs = j.get("runs").as_usize().ok_or("profile: missing runs")?;
        let steps = j.get("steps").as_arr().ok_or("profile: missing steps")?;
        let mut names = Vec::with_capacity(steps.len());
        let mut costs_ns = Vec::with_capacity(steps.len());
        for s in steps {
            names.push(s.get("name").as_str().ok_or("profile: step name")?.to_string());
            let ns = s.get("ns").as_f64().ok_or("profile: step ns")?;
            if !(ns.is_finite() && ns >= 0.0) {
                return Err("profile: step ns out of range".into());
            }
            costs_ns.push(ns as u64);
        }
        Ok(StepProfile { batch, runs, names, costs_ns })
    }
}

/// Run deterministic warmup images through `plan` sequentially and
/// record per-step wall time: `opts.warmup` untimed passes, then
/// `opts.runs` timed passes, median per step. The context is reused
/// across passes, so measurement happens in the same allocation-free
/// steady state serving runs in.
pub fn profile_plan(plan: &ExecutionPlan, opts: &ProfileOptions) -> StepProfile {
    let mut ctx = plan.new_context();
    let mut rng = Rng::new(opts.seed);
    for i in 0..plan.num_feeds() {
        let len: usize = plan.feeds[i].2.iter().product();
        let data: Vec<f32> = (0..len).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        plan.write_feed(&mut ctx, i, &data).expect("synthetic feed sized to the plan");
    }
    for _ in 0..opts.warmup {
        plan.execute_steps(&mut ctx);
    }
    let runs = opts.runs.max(1);
    let mut samples: Vec<Vec<u64>> = vec![Vec::with_capacity(runs); plan.steps.len()];
    let sink = AtomicU64::new(0);
    for _ in 0..runs {
        for (i, step) in plan.steps.iter().enumerate() {
            sink.store(0, Ordering::Relaxed);
            {
                let _t = ScopedNs::new(&sink);
                plan.exec_step(step, &mut ctx);
            }
            samples[i].push(sink.load(Ordering::Relaxed));
        }
    }
    let costs_ns = samples
        .into_iter()
        .map(|mut s| {
            s.sort_unstable();
            s[s.len() / 2].max(1)
        })
        .collect();
    StepProfile {
        batch: plan.batch(),
        runs,
        names: plan.step_names().iter().map(|s| s.to_string()).collect(),
        costs_ns,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nets::{tiny_cnn, NetConfig};
    use crate::sparsity::prune_graph;

    #[test]
    fn profile_covers_every_step_with_positive_costs() {
        let mut g = tiny_cnn(NetConfig::test_scale());
        prune_graph(&mut g, 0.6);
        let plan = ExecutionPlan::build(&g).unwrap();
        let opts = ProfileOptions { warmup: 1, runs: 3, ..Default::default() };
        let prof = profile_plan(&plan, &opts);
        assert_eq!(prof.costs_ns.len(), plan.steps.len());
        assert_eq!(prof.names, plan.step_names());
        assert_eq!(prof.batch, 1);
        assert_eq!(prof.runs, 3);
        assert!(prof.costs_ns.iter().all(|&c| c >= 1));
        // convolutions must measure as the heavy steps: the largest
        // measured step should dwarf the smallest (softmax / affine)
        let (min, max) = (
            *prof.costs_ns.iter().min().unwrap(),
            *prof.costs_ns.iter().max().unwrap(),
        );
        assert!(max > min, "flat profile: {:?}", prof.costs_ns);
    }

    #[test]
    fn batched_profile_records_its_batch() {
        let g = tiny_cnn(NetConfig::test_scale());
        let plan = ExecutionPlan::build_batched(&g, 4).unwrap();
        let opts = ProfileOptions { warmup: 0, runs: 1, ..Default::default() };
        let prof = profile_plan(&plan, &opts);
        assert_eq!(prof.batch, 4);
    }

    #[test]
    fn profile_json_roundtrips() {
        let g = tiny_cnn(NetConfig::test_scale());
        let plan = ExecutionPlan::build(&g).unwrap();
        let prof = StepProfile::synthetic(&plan, vec![7; plan.steps.len()]);
        let j = prof.to_json();
        let parsed = Json::parse(&j.pretty()).unwrap();
        assert_eq!(parsed.get("batch").as_usize(), Some(1));
        assert_eq!(
            parsed.get("steps").as_arr().unwrap().len(),
            plan.steps.len()
        );
        assert_eq!(
            parsed.get("total_ns").as_f64(),
            Some(7.0 * plan.steps.len() as f64)
        );
    }
}
