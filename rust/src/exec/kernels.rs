//! Dense slice-level kernels for the compiled executor.
//!
//! Every kernel writes *all* elements of its output slice (the arena
//! reuses buffers across nodes, so stale data must never survive) and
//! takes preallocated scratch where it needs any — no allocation happens
//! inside a kernel. Convolutions go through im2col + a k-blocked GEMM so
//! the inner loop is a contiguous axpy the compiler can vectorize; the
//! (kh, kw, ci) patch layout matches the HWIO weight layout, making the
//! weight tensor directly usable as the GEMM B matrix.
//!
//! Batch is a first-class dimension: every geometry carries the plan's
//! batch `n` ([`ConvGeom::n`]) and kernels process all `n` images of a
//! slot per call — im2col emits an [n·M, K] patch matrix feeding *one*
//! GEMM, so each weight tile is read once per batch instead of once per
//! image (the weight-reuse-across-batch the batched plans exist for).
//!
//! # Prepacked, register-tiled GEMM (ISSUE 4) at lane width (ISSUE 7)
//!
//! HPIPE §V bakes each layer's weights into per-layer M20K memories laid
//! out exactly as the layer's PEs consume them — the weight *layout* is
//! decided at compile time, per layer, and never rearranged at runtime.
//! The software analog here is [`PackedB`]: at **plan build time** each
//! dense conv / matmul's HWIO weight matrix is repacked into
//! cache-blocked column panels ([`NR`]-wide, zero-padded at the tail,
//! grouped under [`KC`]-row k-blocks) so the hot loop streams weights in
//! exactly the order the microkernel consumes them. ISSUE 7 added the
//! missing half: the activation stream is packed the same way, at run
//! time — im2col emits straight into [`MR`]-row **A-panels**
//! ([`im2col_a`]; [`pack_a`] for matmul rows), k-major within a panel,
//! zero-padded at the M tail, so the microkernel's A reads are
//! contiguous broadcasts instead of strided gathers and the M-tail edge
//! case disappears from the hot loop (pad rows multiply packed zeros and
//! are simply not written back).
//!
//! The tile loop ([`gemm_panels_bias_act`]) walks both packed streams
//! and hands each `kc`-deep MR×NR tile to the active ISA dispatch table
//! (`exec::isa`): explicit SIMD microkernels selected once per process
//! by runtime CPU-feature detection, with the scalar tier as the
//! always-available baseline (the same role [`gemm_bias_act`], the PR 3
//! axpy kernel kept as benchmark baseline, plays for packing itself).
//!
//! Per-element accumulation order is *unchanged* (ascending k, one
//! accumulator chain per output element, bias-seeded, activation on the
//! final writeback) on every non-fused tier — so plan outputs stay
//! batch-invariant and bitwise tier-independent; the FMA dense tiers
//! round once per fused step and are held to ≤ 8 ulp of scalar instead
//! (see `exec::isa` for the full tier contract).

use crate::graph::{Padding, Tensor};

/// Activation fused into a producing kernel (Conv/MatMul/affine).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Act {
    None,
    Relu,
    Relu6,
}

impl Act {
    #[inline]
    pub fn apply(self, v: f32) -> f32 {
        match self {
            Act::None => v,
            Act::Relu => v.max(0.0),
            Act::Relu6 => v.clamp(0.0, 6.0),
        }
    }

    /// Apply in place over a slice (no-op for `Act::None`).
    #[inline]
    pub fn apply_slice(self, xs: &mut [f32]) {
        match self {
            Act::None => {}
            Act::Relu => {
                for v in xs.iter_mut() {
                    *v = v.max(0.0);
                }
            }
            Act::Relu6 => {
                for v in xs.iter_mut() {
                    *v = v.clamp(0.0, 6.0);
                }
            }
        }
    }
}

/// Pre-resolved geometry of a convolution / pooling window over an NHWC
/// activation. `n` is the batch dimension the plan was compiled for:
/// batched kernels process all `n` images of a slot in one call, sharing
/// one weight-stream walk / GEMM tile pass across the batch.
#[derive(Clone, Debug)]
pub struct ConvGeom {
    /// Batch (images per activation slot).
    pub n: usize,
    pub h: usize,
    pub w: usize,
    pub ci: usize,
    pub kh: usize,
    pub kw: usize,
    pub co: usize,
    pub stride: (usize, usize),
    /// Resolved (top, bottom, left, right) padding.
    pub pad: (usize, usize, usize, usize),
    pub ho: usize,
    pub wo: usize,
}

impl ConvGeom {
    pub fn new(
        x_shape: &[usize],
        kh: usize,
        kw: usize,
        co: usize,
        stride: (usize, usize),
        padding: Padding,
    ) -> ConvGeom {
        let (n, h, w, ci) = (x_shape[0], x_shape[1], x_shape[2], x_shape[3]);
        let pad = padding.resolve(h, w, kh, kw, stride.0, stride.1);
        let ho = (h + pad.0 + pad.1 - kh) / stride.0 + 1;
        let wo = (w + pad.2 + pad.3 - kw) / stride.1 + 1;
        ConvGeom { n, h, w, ci, kh, kw, co, stride, pad, ho, wo }
    }

    /// GEMM K dimension: one im2col patch.
    pub fn patch_len(&self) -> usize {
        self.kh * self.kw * self.ci
    }

    /// Per-image output spatial positions.
    pub fn out_positions(&self) -> usize {
        self.ho * self.wo
    }

    /// GEMM M dimension: output positions across the whole batch.
    pub fn total_positions(&self) -> usize {
        self.n * self.ho * self.wo
    }

    /// True when the input itself is a valid im2col matrix (1x1 kernel,
    /// unit stride, no padding) and the copy can be skipped.
    pub fn identity_patches(&self) -> bool {
        self.kh == 1
            && self.kw == 1
            && self.stride == (1, 1)
            && self.pad == (0, 0, 0, 0)
    }
}

/// Fill `patches` (row-major [n·M, K], K = kh*kw*ci) with im2col patches
/// of all `n` images of `x`. Padding positions become zero.
pub fn im2col(x: &[f32], g: &ConvGeom, patches: &mut [f32]) {
    let k = g.patch_len();
    let m = g.out_positions();
    patches[..g.n * m * k].fill(0.0);
    let (sh, sw) = g.stride;
    let (pt, _, pl, _) = g.pad;
    for img in 0..g.n {
        let xi = &x[img * g.h * g.w * g.ci..][..g.h * g.w * g.ci];
        let pi = &mut patches[img * m * k..][..m * k];
        for oy in 0..g.ho {
            for ky in 0..g.kh {
                let iy = (oy * sh + ky) as isize - pt as isize;
                if !(0..g.h as isize).contains(&iy) {
                    continue;
                }
                let iy = iy as usize;
                for ox in 0..g.wo {
                    let row = &mut pi[(oy * g.wo + ox) * k..][..k];
                    for kx in 0..g.kw {
                        let ix = (ox * sw + kx) as isize - pl as isize;
                        if !(0..g.w as isize).contains(&ix) {
                            continue;
                        }
                        let src = &xi[(iy * g.w + ix as usize) * g.ci..][..g.ci];
                        row[(ky * g.kw + kx) * g.ci..][..g.ci].copy_from_slice(src);
                    }
                }
            }
        }
    }
}

/// im2col transposed: `patches_t` is K-major ([K, n·M]) so each patch
/// *row* k = (ky*kw + kx)*ci + ic is contiguous over the output positions
/// of the *whole batch* — the layout the sparse kernel axpys over (see
/// `exec::sparse`): one decoded weight feeds all `n` images.
pub fn im2col_t(x: &[f32], g: &ConvGeom, patches_t: &mut [f32]) {
    let m = g.out_positions();
    let mt = g.total_positions();
    patches_t[..g.patch_len() * mt].fill(0.0);
    let (sh, sw) = g.stride;
    let (pt, _, pl, _) = g.pad;
    for ky in 0..g.kh {
        for kx in 0..g.kw {
            for ic in 0..g.ci {
                let k = (ky * g.kw + kx) * g.ci + ic;
                let row = &mut patches_t[k * mt..][..mt];
                for img in 0..g.n {
                    let xi = &x[img * g.h * g.w * g.ci..][..g.h * g.w * g.ci];
                    let ri = &mut row[img * m..][..m];
                    for oy in 0..g.ho {
                        let iy = (oy * sh + ky) as isize - pt as isize;
                        if !(0..g.h as isize).contains(&iy) {
                            continue;
                        }
                        let iy = iy as usize;
                        for ox in 0..g.wo {
                            let ix = (ox * sw + kx) as isize - pl as isize;
                            if !(0..g.w as isize).contains(&ix) {
                                continue;
                            }
                            ri[oy * g.wo + ox] = xi[(iy * g.w + ix as usize) * g.ci + ic];
                        }
                    }
                }
            }
        }
    }
}

/// k-blocked GEMM: out[M, N] = a[M, K] · b[K, N], with `out` initialized
/// from the per-column bias (or zero) and `act` applied at the end. The
/// inner loop is a contiguous axpy over a row of `b`; blocking over K
/// keeps the active slice of `b` hot across all M rows.
#[allow(clippy::too_many_arguments)] // kernel ABI: dims + fused epilogue
pub fn gemm_bias_act(
    a: &[f32],
    b: &[f32],
    m: usize,
    k_dim: usize,
    n: usize,
    bias: Option<&[f32]>,
    act: Act,
    out: &mut [f32],
) {
    const KC: usize = 64;
    match bias {
        Some(bv) => {
            for i in 0..m {
                out[i * n..][..n].copy_from_slice(bv);
            }
        }
        None => out[..m * n].fill(0.0),
    }
    let mut k0 = 0;
    while k0 < k_dim {
        let k1 = (k0 + KC).min(k_dim);
        for i in 0..m {
            let arow = &a[i * k_dim..][..k_dim];
            let orow = &mut out[i * n..][..n];
            for kk in k0..k1 {
                let av = arow[kk];
                if av == 0.0 {
                    continue;
                }
                let brow = &b[kk * n..][..n];
                for (o, &bb) in orow.iter_mut().zip(brow) {
                    *o += av * bb;
                }
            }
        }
        k0 = k1;
    }
    act.apply_slice(&mut out[..m * n]);
}

/// Rows of A per register tile (output positions).
pub const MR: usize = 4;
/// Columns of B per packed panel / register tile (output channels).
pub const NR: usize = 16;
/// k-block depth: packed panel rows kept hot across all M rows.
pub const KC: usize = 256;

/// A weight matrix repacked at plan build time into microkernel-native
/// panels — the software analog of baking a layer's weights into its
/// own M20K banks in the layer's consumption order (HPIPE §V-A).
///
/// Layout: for each k-block of up to [`KC`] rows, for each [`NR`]-wide
/// column panel (tail panels zero-padded to full width), the block's
/// rows are stored contiguously as `kc × NR` values. The microkernel
/// therefore reads the packed data strictly sequentially.
#[derive(Clone, Debug)]
pub struct PackedB {
    /// Rows of the source matrix (GEMM K dimension).
    pub k: usize,
    /// Columns of the source matrix (GEMM N dimension).
    pub n: usize,
    /// Number of NR-wide column panels: `ceil(n / NR)`.
    panels: usize,
    data: Vec<f32>,
}

impl PackedB {
    /// f32 elements held by the packed copy (footprint accounting).
    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// The raw panel data (artifact serialization).
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    /// Reassemble a `PackedB` from stored parts (artifact load). The
    /// only structural invariant is the data length — panel layout is
    /// positional — so that is what gets validated; a mismatch means a
    /// corrupt or mislabeled artifact entry.
    pub fn from_parts(k: usize, n: usize, data: Vec<f32>) -> Result<PackedB, String> {
        let panels = n.div_ceil(NR);
        let expect = panels * NR * k;
        if data.len() != expect {
            return Err(format!(
                "PackedB[{k}x{n}]: stored {} f32s, layout needs {expect}",
                data.len()
            ));
        }
        Ok(PackedB { k, n, panels, data })
    }
}

/// Repack a row-major [k, n] matrix (e.g. HWIO conv weights flattened to
/// [kh·kw·ci, co]) into [`PackedB`] panels. Runs at plan build time only.
pub fn pack_b(b: &[f32], k: usize, n: usize) -> PackedB {
    assert!(b.len() >= k * n, "pack_b: matrix shorter than k*n");
    let panels = n.div_ceil(NR);
    let mut data = Vec::with_capacity(k * panels * NR);
    let mut k0 = 0usize;
    while k0 < k {
        let k1 = (k0 + KC).min(k);
        for p in 0..panels {
            let n0 = p * NR;
            for kk in k0..k1 {
                let row = &b[kk * n..][..n];
                for j in 0..NR {
                    data.push(if n0 + j < n { row[n0 + j] } else { 0.0 });
                }
            }
        }
        k0 = k1;
    }
    PackedB { k, n, panels, data }
}

/// Scratch elements needed to hold `m` rows × `k` cols of A packed into
/// MR-row panels (the M tail is zero-padded to a full panel).
pub fn packed_a_len(m: usize, k: usize) -> usize {
    m.div_ceil(MR) * MR * k
}

/// Pack a row-major [m, k] matrix into [`MR`]-row A-panels: panel `p`
/// holds rows `p·MR .. p·MR+MR` k-major, `ap[p·MR·k + kk·MR + r] =
/// a[(p·MR + r)·k + kk]`, tail rows zero-padded. This is the runtime
/// mirror of [`pack_b`]: the microkernel reads `MR` A values per k step
/// as one contiguous load instead of `MR` strided row walks. Pad rows
/// contribute only `0·b` products to lanes that are never written back.
pub fn pack_a(a: &[f32], m: usize, k: usize, ap: &mut [f32]) {
    assert!(a.len() >= m * k, "pack_a: matrix shorter than m*k");
    let ap = &mut ap[..packed_a_len(m, k)];
    ap.fill(0.0);
    for (row, src) in a.chunks_exact(k).enumerate().take(m) {
        let (panel, r) = (row / MR, row % MR);
        let dst = &mut ap[panel * MR * k..][..MR * k];
        for (kk, &v) in src.iter().enumerate() {
            dst[kk * MR + r] = v;
        }
    }
}

/// im2col straight into [`MR`]-row A-panels: bitwise-identical data to
/// [`im2col`] followed by [`pack_a`], without materializing the
/// row-major intermediate. Output position `row = img·M + oy·wo + ox`
/// lands in panel `row / MR`, lane `row % MR`; padding taps and the
/// M-tail pad rows stay zero from the initial fill.
pub fn im2col_a(x: &[f32], g: &ConvGeom, ap: &mut [f32]) {
    let k = g.patch_len();
    let m = g.out_positions();
    ap[..packed_a_len(g.total_positions(), k)].fill(0.0);
    let (sh, sw) = g.stride;
    let (pt, _, pl, _) = g.pad;
    for img in 0..g.n {
        let xi = &x[img * g.h * g.w * g.ci..][..g.h * g.w * g.ci];
        for oy in 0..g.ho {
            for ky in 0..g.kh {
                let iy = (oy * sh + ky) as isize - pt as isize;
                if !(0..g.h as isize).contains(&iy) {
                    continue;
                }
                let iy = iy as usize;
                for ox in 0..g.wo {
                    let row = img * m + oy * g.wo + ox;
                    let dst = &mut ap[(row / MR) * MR * k..][..MR * k];
                    let r = row % MR;
                    for kx in 0..g.kw {
                        let ix = (ox * sw + kx) as isize - pl as isize;
                        if !(0..g.w as isize).contains(&ix) {
                            continue;
                        }
                        let src = &xi[(iy * g.w + ix as usize) * g.ci..][..g.ci];
                        let kbase = (ky * g.kw + kx) * g.ci;
                        for (ic, &v) in src.iter().enumerate() {
                            dst[(kbase + ic) * MR + r] = v;
                        }
                    }
                }
            }
        }
    }
}

/// Register-tiled GEMM over prepacked operands: out[M, N] = ap · pb,
/// bias-seeded and with `act` fused into the final writeback. `ap` is an
/// MR-row A-panel pack of the activation rows ([`pack_a`]/[`im2col_a`]);
/// the MR×NR tiles go through the active `exec::isa` kernel table.
/// A-panels are independent, so callers may hand MR-aligned disjoint row
/// ranges of `ap`/`out` to a worker team (see `ExecutionPlan`
/// intra-stage splitting).
pub fn gemm_panels_bias_act(
    ap: &[f32],
    pb: &PackedB,
    m: usize,
    bias: Option<&[f32]>,
    act: Act,
    out: &mut [f32],
) {
    gemm_panels_bias_act_on(super::isa::active(), ap, pb, m, bias, act, out);
}

/// [`gemm_panels_bias_act`] pinned to an explicit dispatch tier — the
/// entry point cross-tier equivalence tests use, since the active tier
/// is process-global and test binaries are multi-threaded.
pub fn gemm_panels_bias_act_on(
    isa: &super::isa::Isa,
    ap: &[f32],
    pb: &PackedB,
    m: usize,
    bias: Option<&[f32]>,
    act: Act,
    out: &mut [f32],
) {
    crate::util::fault::point("kernel.gemm", 0);
    let (k, n) = (pb.k, pb.n);
    debug_assert!(ap.len() >= packed_a_len(m, k), "gemm_panels: A pack too short");
    debug_assert!(out.len() >= m * n, "gemm_panels: out shorter than m*n");
    let a_panels = m.div_ceil(MR);
    let mut k0 = 0usize;
    let mut block = 0usize; // start of this k-block's panels in pb.data
    while k0 < k {
        let k1 = (k0 + KC).min(k);
        let kc = k1 - k0;
        let (first, last) = (k0 == 0, k1 == k);
        for p in 0..pb.panels {
            let bpanel = &pb.data[block + p * kc * NR..][..kc * NR];
            let n0 = p * NR;
            let nw = (n - n0).min(NR);
            for ai in 0..a_panels {
                let i = ai * MR;
                let mr = (m - i).min(MR);
                let apanel = &ap[ai * MR * k + k0 * MR..][..kc * MR];
                // Seed the tile: first k-block from the bias, later
                // blocks resume from `out`. Pad rows (r >= mr) and pad
                // lanes (j >= nw) stay zero — their products are zero
                // and they are never written back.
                let mut acc = [0.0f32; MR * NR];
                for (r, accr) in acc.chunks_exact_mut(NR).enumerate().take(mr) {
                    if first {
                        if let Some(bv) = bias {
                            accr[..nw].copy_from_slice(&bv[n0..n0 + nw]);
                        }
                    } else {
                        accr[..nw].copy_from_slice(&out[(i + r) * n + n0..][..nw]);
                    }
                }
                isa.dense_tile(apanel, bpanel, kc, &mut acc);
                for (r, accr) in acc.chunks_exact(NR).enumerate().take(mr) {
                    let orow = &mut out[(i + r) * n + n0..][..nw];
                    for (o, &v) in orow.iter_mut().zip(&accr[..nw]) {
                        *o = if last { act.apply(v) } else { v };
                    }
                }
            }
        }
        block += pb.panels * kc * NR;
        k0 = k1;
    }
}

/// Dense Conv2D through the prepacked register-tiled GEMM: im2col all
/// `g.n` images straight into A-panels in `scratch` ([`im2col_a`]; the
/// 1x1/stride-1/no-pad case is a plain [`pack_a`] of the input), then
/// [`gemm_panels_bias_act`] against the plan-time packed weights.
pub fn conv2d_dense_packed(
    x: &[f32],
    g: &ConvGeom,
    pb: &PackedB,
    bias: Option<&[f32]>,
    act: Act,
    scratch: &mut [f32],
    out: &mut [f32],
) {
    let m = g.total_positions();
    debug_assert_eq!(pb.k, g.patch_len());
    debug_assert_eq!(pb.n, g.co);
    if g.identity_patches() {
        pack_a(x, m, pb.k, scratch);
    } else {
        im2col_a(x, g, scratch);
    }
    gemm_panels_bias_act(scratch, pb, m, bias, act, out);
}

/// Dense Conv2D (+ fused bias / activation): im2col all `g.n` images
/// into `scratch`, then one GEMM against the HWIO weights — the weight
/// tiles stay hot across the whole batch's rows. 1x1/stride-1/no-pad
/// convs skip the im2col copy and GEMM directly over the input (which is
/// a valid [n·M, K] patch matrix for any batch).
pub fn conv2d_dense(
    x: &[f32],
    g: &ConvGeom,
    w: &Tensor,
    bias: Option<&[f32]>,
    act: Act,
    scratch: &mut [f32],
    out: &mut [f32],
) {
    let m = g.total_positions();
    let k = g.patch_len();
    if g.identity_patches() {
        gemm_bias_act(x, w.as_slice(), m, k, g.co, bias, act, out);
    } else {
        im2col(x, g, scratch);
        gemm_bias_act(scratch, w.as_slice(), m, k, g.co, bias, act, out);
    }
}

/// Dense depthwise conv (+ fused bias / activation) over all `g.n`
/// images. `mult` is the channel multiplier (weights are
/// [kh, kw, ci, mult]).
///
/// The padding bounds checks are hoisted out of the tap loops: the valid
/// `ky` / `kx` ranges are computed once per output position (two
/// saturating subs and a min each), so interior positions — where the
/// ranges are simply `0..kh` / `0..kw` — run the tap loops branch-free.
/// Skipped taps contributed nothing before, so the per-element
/// accumulation order (and therefore every result bit) is unchanged.
pub fn depthwise_dense(
    x: &[f32],
    g: &ConvGeom,
    mult: usize,
    w: &Tensor,
    bias: Option<&[f32]>,
    act: Act,
    out: &mut [f32],
) {
    let (sh, sw) = g.stride;
    let (pt, _, pl, _) = g.pad;
    let co = g.ci * mult;
    for img in 0..g.n {
        let xi = &x[img * g.h * g.w * g.ci..][..g.h * g.w * g.ci];
        let oi = &mut out[img * g.ho * g.wo * co..][..g.ho * g.wo * co];
        for oy in 0..g.ho {
            // iy = oy*sh + ky - pt must land in [0, h)
            let base_y = oy * sh;
            let ky_lo = pt.saturating_sub(base_y);
            let ky_hi = (g.h + pt).saturating_sub(base_y).min(g.kh);
            for ox in 0..g.wo {
                let base_x = ox * sw;
                let kx_lo = pl.saturating_sub(base_x);
                let kx_hi = (g.w + pl).saturating_sub(base_x).min(g.kw);
                let orow = &mut oi[(oy * g.wo + ox) * co..][..co];
                for ic in 0..g.ci {
                    for im in 0..mult {
                        let mut acc = match bias {
                            Some(b) => b[ic * mult + im],
                            None => 0.0,
                        };
                        for ky in ky_lo..ky_hi {
                            let iy = base_y + ky - pt;
                            for kx in kx_lo..kx_hi {
                                let ix = base_x + kx - pl;
                                acc += xi[(iy * g.w + ix) * g.ci + ic]
                                    * w.data[((ky * g.kw + kx) * g.ci + ic) * mult + im];
                            }
                        }
                        orow[ic * mult + im] = act.apply(acc);
                    }
                }
            }
        }
    }
}

/// MaxPool over NHWC (geom.co == geom.ci == channels), all `g.n` images.
pub fn max_pool(x: &[f32], g: &ConvGeom, out: &mut [f32]) {
    let (sh, sw) = g.stride;
    let (pt, _, pl, _) = g.pad;
    let c = g.ci;
    for img in 0..g.n {
        let xi = &x[img * g.h * g.w * c..][..g.h * g.w * c];
        let oi = &mut out[img * g.ho * g.wo * c..][..g.ho * g.wo * c];
        for oy in 0..g.ho {
            for ox in 0..g.wo {
                let orow = &mut oi[(oy * g.wo + ox) * c..][..c];
                orow.fill(f32::NEG_INFINITY);
                for ky in 0..g.kh {
                    let iy = (oy * sh + ky) as isize - pt as isize;
                    if !(0..g.h as isize).contains(&iy) {
                        continue;
                    }
                    for kx in 0..g.kw {
                        let ix = (ox * sw + kx) as isize - pl as isize;
                        if !(0..g.w as isize).contains(&ix) {
                            continue;
                        }
                        let xrow = &xi[((iy as usize) * g.w + ix as usize) * c..][..c];
                        for (o, &v) in orow.iter_mut().zip(xrow) {
                            if v > *o {
                                *o = v;
                            }
                        }
                    }
                }
            }
        }
    }
}

/// Per-channel affine over the last dim: out[i] = act(x[i]*a[c] + b[c]).
/// Covers BiasAdd (a = None), Mul (b = None), AddC, and the folded
/// FusedBatchNorm (both Some).
pub fn affine(
    x: &[f32],
    ch: usize,
    a: Option<&[f32]>,
    b: Option<&[f32]>,
    act: Act,
    out: &mut [f32],
) {
    debug_assert_eq!(x.len(), out.len(), "affine operand/output length mismatch");
    for (i, (o, &v)) in out.iter_mut().zip(x).enumerate() {
        let c = i % ch;
        let mut y = v;
        if let Some(av) = a {
            y *= av[c];
        }
        if let Some(bv) = b {
            y += bv[c];
        }
        *o = act.apply(y);
    }
}

/// Elementwise unary activation into `out`.
pub fn unary(x: &[f32], act: Act, out: &mut [f32]) {
    debug_assert_eq!(x.len(), out.len(), "unary operand/output length mismatch");
    for (o, &v) in out.iter_mut().zip(x) {
        *o = act.apply(v);
    }
}

/// Elementwise residual add. The zips would silently truncate on a
/// mismatched operand (e.g. a per-image constant that missed batch
/// tiling), leaving stale arena data in the tail — assert instead.
pub fn add(a: &[f32], b: &[f32], out: &mut [f32]) {
    debug_assert_eq!(a.len(), out.len(), "add operand/output length mismatch");
    debug_assert_eq!(b.len(), out.len(), "add operand/output length mismatch");
    for ((o, &x), &y) in out.iter_mut().zip(a).zip(b) {
        *o = x + y;
    }
}

/// Global average pool NHWC -> [n, C], per image (f64 accumulation,
/// matching the reference interpreter bit-for-bit in the common case).
pub fn global_mean(x: &[f32], n: usize, h: usize, w: usize, c: usize, out: &mut [f32]) {
    for img in 0..n {
        let xi = &x[img * h * w * c..][..h * w * c];
        let oi = &mut out[img * c..][..c];
        for ch in 0..c {
            let mut s = 0f64;
            for p in 0..h * w {
                s += xi[p * c + ch] as f64;
            }
            oi[ch] = (s / (h * w) as f64) as f32;
        }
    }
}

/// Spatial zero-pad NHWC, all `n` images.
#[allow(clippy::too_many_arguments)] // kernel ABI: batch + spatial dims
pub fn pad(
    x: &[f32],
    n: usize,
    h: usize,
    w: usize,
    c: usize,
    pads: (usize, usize, usize, usize),
    out: &mut [f32],
) {
    let (t, b, l, r) = pads;
    let (ho, wo) = (h + t + b, w + l + r);
    out[..n * ho * wo * c].fill(0.0);
    for img in 0..n {
        let xi = &x[img * h * w * c..][..h * w * c];
        let oi = &mut out[img * ho * wo * c..][..ho * wo * c];
        for y in 0..h {
            let src = &xi[y * w * c..][..w * c];
            let dst = &mut oi[((y + t) * wo + l) * c..][..w * c];
            dst.copy_from_slice(src);
        }
    }
}

/// Row softmax over an [N, C] tensor.
pub fn softmax(x: &[f32], n: usize, c: usize, out: &mut [f32]) {
    for i in 0..n {
        let src = &x[i * c..][..c];
        let dst = &mut out[i * c..][..c];
        let m = src.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        let mut sum = 0f32;
        for (d, &v) in dst.iter_mut().zip(src) {
            *d = (v - m).exp();
            sum += *d;
        }
        for d in dst.iter_mut() {
            *d /= sum;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparsity::prune::prune_tensor;
    use crate::util::prop::Cases;
    use crate::util::Rng;

    /// Naive triple-loop reference GEMM with the same per-element
    /// accumulation order (ascending k, bias-seeded, act on writeback)
    /// as both the axpy kernel and the packed microkernel — so the
    /// packed kernel must match it *exactly*.
    fn naive_gemm(
        a: &[f32],
        b: &[f32],
        m: usize,
        k: usize,
        n: usize,
        bias: Option<&[f32]>,
        act: Act,
    ) -> Vec<f32> {
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = bias.map_or(0.0, |bv| bv[j]);
                for kk in 0..k {
                    acc += a[i * k + kk] * b[kk * n + j];
                }
                out[i * n + j] = act.apply(acc);
            }
        }
        out
    }

    #[test]
    fn packed_gemm_matches_naive_across_odd_shapes_and_sparsity() {
        use crate::exec::isa;
        Cases::new(36).seed(0x9EAC).run(|rng, size| {
            // Odd shapes on purpose: M tails (m % MR != 0), N panel
            // tails (n % NR != 0) and k spanning multiple KC blocks.
            let m = 1 + (size * 3 + rng.below(5)) % 23;
            let n = 1 + (size * 7 + rng.below(9)) % 37;
            let k = 1 + rng.below(2) * KC + rng.below(19);
            let sparsity = *rng.choose(&[0.0, 0.5, 0.9]);
            let a = Tensor::randn(&[m, k], rng, 1.0);
            let mut b = Tensor::randn(&[k, n], rng, 1.0);
            prune_tensor(&mut b, sparsity);
            let bias: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let act = *rng.choose(&[Act::None, Act::Relu, Act::Relu6]);
            let pb = pack_b(b.as_slice(), k, n);
            assert_eq!(pb.len(), n.div_ceil(NR) * NR * k);
            let mut ap = vec![0.0f32; packed_a_len(m, k)];
            pack_a(a.as_slice(), m, k, &mut ap);
            let want = naive_gemm(a.as_slice(), b.as_slice(), m, k, n, Some(&bias), act);
            for tier in isa::available() {
                let mut got = vec![0.0f32; m * n];
                gemm_panels_bias_act_on(tier, &ap, &pb, m, Some(&bias), act, &mut got);
                if tier.fused_dense() {
                    // one rounding per fused step: ulp bar, not bitwise
                    crate::util::prop::assert_ulp_close(&got, &want, 8).map_err(|e| {
                        format!("m={m} k={k} n={n} tier={}: {e}", tier.name())
                    })?;
                } else if got != want {
                    return Err(format!(
                        "m={m} k={k} n={n} sparsity={sparsity} tier={}: mismatch",
                        tier.name()
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn im2col_a_matches_im2col_then_pack_a_bitwise() {
        Cases::new(12).seed(0xA12C).run(|rng, size| {
            let (h, w) = (3 + size % 5, 3 + (size * 2) % 5);
            let ci = 1 + rng.below(5);
            let co = 1 + rng.below(4);
            let (kh, kw) = (1 + rng.below(3), 1 + rng.below(3));
            let stride = 1 + rng.below(2);
            let shape = [2usize, h, w, ci];
            let x = Tensor::randn(&shape, rng, 1.0);
            let pad = *rng.choose(&[Padding::Same, Padding::Valid]);
            let g = ConvGeom::new(&shape, kh, kw, co, (stride, stride), pad);
            let (mt, k) = (g.total_positions(), g.patch_len());
            let mut direct = vec![f32::NAN; packed_a_len(mt, k)];
            im2col_a(x.as_slice(), &g, &mut direct);
            let mut rows = vec![f32::NAN; mt * k];
            im2col(x.as_slice(), &g, &mut rows);
            let mut staged = vec![f32::NAN; packed_a_len(mt, k)];
            pack_a(&rows, mt, k, &mut staged);
            if direct == staged {
                Ok(())
            } else {
                Err(format!("h={h} w={w} ci={ci} kh={kh} kw={kw} s={stride}"))
            }
        });
    }

    #[test]
    fn packed_gemm_row_ranges_compose() {
        // The intra-stage worker team hands disjoint MR-aligned row
        // ranges of the same packed GEMM to different threads; chunked
        // execution must reproduce the single-call result bit for bit.
        let mut rng = Rng::new(0x7EA3);
        let (m, k, n) = (11usize, KC + 7, 21usize);
        let a = Tensor::randn(&[m, k], &mut rng, 1.0);
        let b = Tensor::randn(&[k, n], &mut rng, 1.0);
        let pb = pack_b(b.as_slice(), k, n);
        let mut ap = vec![0.0f32; packed_a_len(m, k)];
        pack_a(a.as_slice(), m, k, &mut ap);
        let mut full = vec![0.0f32; m * n];
        gemm_panels_bias_act(&ap, &pb, m, None, Act::Relu, &mut full);
        let mut parts = vec![0.0f32; m * n];
        for (t, chunk) in parts.chunks_mut(MR * n).enumerate() {
            let m0 = t * MR; // MR-aligned: sub-range starts on a panel
            let rows = chunk.len() / n;
            let asub = &ap[m0 * k..][..packed_a_len(rows, k)];
            gemm_panels_bias_act(asub, &pb, rows, None, Act::Relu, chunk);
        }
        assert_eq!(full, parts);
    }

    #[test]
    fn depthwise_hoisted_bounds_match_checked_reference() {
        Cases::new(16).seed(0xD3).run(|rng, size| {
            let (h, w) = (3 + size % 5, 3 + (size * 2) % 5);
            let ci = 1 + rng.below(4);
            let mult = 1 + rng.below(2);
            let (kh, kw) = (1 + rng.below(3), 1 + rng.below(3));
            let stride = 1 + rng.below(2);
            let shape = [2usize, h, w, ci];
            let x = Tensor::randn(&shape, rng, 1.0);
            let wt = Tensor::randn(&[kh, kw, ci, mult], rng, 1.0);
            let g = ConvGeom::new(&shape, kh, kw, ci * mult, (stride, stride), Padding::Same);
            let co = ci * mult;
            let mut got = vec![0.0f32; 2 * g.ho * g.wo * co];
            depthwise_dense(x.as_slice(), &g, mult, &wt, None, Act::None, &mut got);
            // Reference: the per-tap bounds-checked loop the hoisted
            // ranges replaced; identical tap order, so bitwise equal.
            let (sh, sw) = g.stride;
            let (pt, _, pl, _) = g.pad;
            let mut want = vec![0.0f32; got.len()];
            for img in 0..2 {
                let xi = &x.as_slice()[img * h * w * ci..][..h * w * ci];
                let oi = &mut want[img * g.ho * g.wo * co..][..g.ho * g.wo * co];
                for oy in 0..g.ho {
                    for ox in 0..g.wo {
                        for ic in 0..ci {
                            for im in 0..mult {
                                let mut acc = 0.0f32;
                                for ky in 0..kh {
                                    let iy = (oy * sh + ky) as isize - pt as isize;
                                    if !(0..h as isize).contains(&iy) {
                                        continue;
                                    }
                                    for kx in 0..kw {
                                        let ix = (ox * sw + kx) as isize - pl as isize;
                                        if !(0..w as isize).contains(&ix) {
                                            continue;
                                        }
                                        acc += xi[((iy as usize) * w + ix as usize) * ci + ic]
                                            * wt.data[((ky * kw + kx) * ci + ic) * mult + im];
                                    }
                                }
                                oi[(oy * g.wo + ox) * co + ic * mult + im] = acc;
                            }
                        }
                    }
                }
            }
            if got == want {
                Ok(())
            } else {
                Err(format!("h={h} w={w} ci={ci} mult={mult} kh={kh} kw={kw} s={stride}"))
            }
        });
    }
}
