//! Dense slice-level kernels for the compiled executor.
//!
//! Every kernel writes *all* elements of its output slice (the arena
//! reuses buffers across nodes, so stale data must never survive) and
//! takes preallocated scratch where it needs any — no allocation happens
//! inside a kernel. Convolutions go through im2col + a k-blocked GEMM so
//! the inner loop is a contiguous axpy the compiler can vectorize; the
//! (kh, kw, ci) patch layout matches the HWIO weight layout, making the
//! weight tensor directly usable as the GEMM B matrix.
//!
//! Batch is a first-class dimension: every geometry carries the plan's
//! batch `n` ([`ConvGeom::n`]) and kernels process all `n` images of a
//! slot per call — im2col emits an [n·M, K] patch matrix feeding *one*
//! GEMM, so each weight tile is read once per batch instead of once per
//! image (the weight-reuse-across-batch the batched plans exist for).

use crate::graph::{Padding, Tensor};

/// Activation fused into a producing kernel (Conv/MatMul/affine).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Act {
    None,
    Relu,
    Relu6,
}

impl Act {
    #[inline]
    pub fn apply(self, v: f32) -> f32 {
        match self {
            Act::None => v,
            Act::Relu => v.max(0.0),
            Act::Relu6 => v.clamp(0.0, 6.0),
        }
    }

    /// Apply in place over a slice (no-op for `Act::None`).
    #[inline]
    pub fn apply_slice(self, xs: &mut [f32]) {
        match self {
            Act::None => {}
            Act::Relu => {
                for v in xs.iter_mut() {
                    *v = v.max(0.0);
                }
            }
            Act::Relu6 => {
                for v in xs.iter_mut() {
                    *v = v.clamp(0.0, 6.0);
                }
            }
        }
    }
}

/// Pre-resolved geometry of a convolution / pooling window over an NHWC
/// activation. `n` is the batch dimension the plan was compiled for:
/// batched kernels process all `n` images of a slot in one call, sharing
/// one weight-stream walk / GEMM tile pass across the batch.
#[derive(Clone, Debug)]
pub struct ConvGeom {
    /// Batch (images per activation slot).
    pub n: usize,
    pub h: usize,
    pub w: usize,
    pub ci: usize,
    pub kh: usize,
    pub kw: usize,
    pub co: usize,
    pub stride: (usize, usize),
    /// Resolved (top, bottom, left, right) padding.
    pub pad: (usize, usize, usize, usize),
    pub ho: usize,
    pub wo: usize,
}

impl ConvGeom {
    pub fn new(
        x_shape: &[usize],
        kh: usize,
        kw: usize,
        co: usize,
        stride: (usize, usize),
        padding: Padding,
    ) -> ConvGeom {
        let (n, h, w, ci) = (x_shape[0], x_shape[1], x_shape[2], x_shape[3]);
        let pad = padding.resolve(h, w, kh, kw, stride.0, stride.1);
        let ho = (h + pad.0 + pad.1 - kh) / stride.0 + 1;
        let wo = (w + pad.2 + pad.3 - kw) / stride.1 + 1;
        ConvGeom { n, h, w, ci, kh, kw, co, stride, pad, ho, wo }
    }

    /// GEMM K dimension: one im2col patch.
    pub fn patch_len(&self) -> usize {
        self.kh * self.kw * self.ci
    }

    /// Per-image output spatial positions.
    pub fn out_positions(&self) -> usize {
        self.ho * self.wo
    }

    /// GEMM M dimension: output positions across the whole batch.
    pub fn total_positions(&self) -> usize {
        self.n * self.ho * self.wo
    }

    /// True when the input itself is a valid im2col matrix (1x1 kernel,
    /// unit stride, no padding) and the copy can be skipped.
    pub fn identity_patches(&self) -> bool {
        self.kh == 1
            && self.kw == 1
            && self.stride == (1, 1)
            && self.pad == (0, 0, 0, 0)
    }
}

/// Fill `patches` (row-major [n·M, K], K = kh*kw*ci) with im2col patches
/// of all `n` images of `x`. Padding positions become zero.
pub fn im2col(x: &[f32], g: &ConvGeom, patches: &mut [f32]) {
    let k = g.patch_len();
    let m = g.out_positions();
    patches[..g.n * m * k].fill(0.0);
    let (sh, sw) = g.stride;
    let (pt, _, pl, _) = g.pad;
    for img in 0..g.n {
        let xi = &x[img * g.h * g.w * g.ci..][..g.h * g.w * g.ci];
        let pi = &mut patches[img * m * k..][..m * k];
        for oy in 0..g.ho {
            for ky in 0..g.kh {
                let iy = (oy * sh + ky) as isize - pt as isize;
                if !(0..g.h as isize).contains(&iy) {
                    continue;
                }
                let iy = iy as usize;
                for ox in 0..g.wo {
                    let row = &mut pi[(oy * g.wo + ox) * k..][..k];
                    for kx in 0..g.kw {
                        let ix = (ox * sw + kx) as isize - pl as isize;
                        if !(0..g.w as isize).contains(&ix) {
                            continue;
                        }
                        let src = &xi[(iy * g.w + ix as usize) * g.ci..][..g.ci];
                        row[(ky * g.kw + kx) * g.ci..][..g.ci].copy_from_slice(src);
                    }
                }
            }
        }
    }
}

/// im2col transposed: `patches_t` is K-major ([K, n·M]) so each patch
/// *row* k = (ky*kw + kx)*ci + ic is contiguous over the output positions
/// of the *whole batch* — the layout the sparse kernel axpys over (see
/// `exec::sparse`): one decoded weight feeds all `n` images.
pub fn im2col_t(x: &[f32], g: &ConvGeom, patches_t: &mut [f32]) {
    let m = g.out_positions();
    let mt = g.total_positions();
    patches_t[..g.patch_len() * mt].fill(0.0);
    let (sh, sw) = g.stride;
    let (pt, _, pl, _) = g.pad;
    for ky in 0..g.kh {
        for kx in 0..g.kw {
            for ic in 0..g.ci {
                let k = (ky * g.kw + kx) * g.ci + ic;
                let row = &mut patches_t[k * mt..][..mt];
                for img in 0..g.n {
                    let xi = &x[img * g.h * g.w * g.ci..][..g.h * g.w * g.ci];
                    let ri = &mut row[img * m..][..m];
                    for oy in 0..g.ho {
                        let iy = (oy * sh + ky) as isize - pt as isize;
                        if !(0..g.h as isize).contains(&iy) {
                            continue;
                        }
                        let iy = iy as usize;
                        for ox in 0..g.wo {
                            let ix = (ox * sw + kx) as isize - pl as isize;
                            if !(0..g.w as isize).contains(&ix) {
                                continue;
                            }
                            ri[oy * g.wo + ox] = xi[(iy * g.w + ix as usize) * g.ci + ic];
                        }
                    }
                }
            }
        }
    }
}

/// k-blocked GEMM: out[M, N] = a[M, K] · b[K, N], with `out` initialized
/// from the per-column bias (or zero) and `act` applied at the end. The
/// inner loop is a contiguous axpy over a row of `b`; blocking over K
/// keeps the active slice of `b` hot across all M rows.
#[allow(clippy::too_many_arguments)] // kernel ABI: dims + fused epilogue
pub fn gemm_bias_act(
    a: &[f32],
    b: &[f32],
    m: usize,
    k_dim: usize,
    n: usize,
    bias: Option<&[f32]>,
    act: Act,
    out: &mut [f32],
) {
    const KC: usize = 64;
    match bias {
        Some(bv) => {
            for i in 0..m {
                out[i * n..][..n].copy_from_slice(bv);
            }
        }
        None => out[..m * n].fill(0.0),
    }
    let mut k0 = 0;
    while k0 < k_dim {
        let k1 = (k0 + KC).min(k_dim);
        for i in 0..m {
            let arow = &a[i * k_dim..][..k_dim];
            let orow = &mut out[i * n..][..n];
            for kk in k0..k1 {
                let av = arow[kk];
                if av == 0.0 {
                    continue;
                }
                let brow = &b[kk * n..][..n];
                for (o, &bb) in orow.iter_mut().zip(brow) {
                    *o += av * bb;
                }
            }
        }
        k0 = k1;
    }
    act.apply_slice(&mut out[..m * n]);
}

/// Dense Conv2D (+ fused bias / activation): im2col all `g.n` images
/// into `scratch`, then one GEMM against the HWIO weights — the weight
/// tiles stay hot across the whole batch's rows. 1x1/stride-1/no-pad
/// convs skip the im2col copy and GEMM directly over the input (which is
/// a valid [n·M, K] patch matrix for any batch).
pub fn conv2d_dense(
    x: &[f32],
    g: &ConvGeom,
    w: &Tensor,
    bias: Option<&[f32]>,
    act: Act,
    scratch: &mut [f32],
    out: &mut [f32],
) {
    let m = g.total_positions();
    let k = g.patch_len();
    if g.identity_patches() {
        gemm_bias_act(x, w.as_slice(), m, k, g.co, bias, act, out);
    } else {
        im2col(x, g, scratch);
        gemm_bias_act(scratch, w.as_slice(), m, k, g.co, bias, act, out);
    }
}

/// Dense depthwise conv (+ fused bias / activation) over all `g.n`
/// images. `mult` is the channel multiplier (weights are
/// [kh, kw, ci, mult]).
pub fn depthwise_dense(
    x: &[f32],
    g: &ConvGeom,
    mult: usize,
    w: &Tensor,
    bias: Option<&[f32]>,
    act: Act,
    out: &mut [f32],
) {
    let (sh, sw) = g.stride;
    let (pt, _, pl, _) = g.pad;
    let co = g.ci * mult;
    for img in 0..g.n {
        let xi = &x[img * g.h * g.w * g.ci..][..g.h * g.w * g.ci];
        let oi = &mut out[img * g.ho * g.wo * co..][..g.ho * g.wo * co];
        for oy in 0..g.ho {
            for ox in 0..g.wo {
                let orow = &mut oi[(oy * g.wo + ox) * co..][..co];
                for ic in 0..g.ci {
                    for im in 0..mult {
                        let mut acc = match bias {
                            Some(b) => b[ic * mult + im],
                            None => 0.0,
                        };
                        for ky in 0..g.kh {
                            let iy = (oy * sh + ky) as isize - pt as isize;
                            if !(0..g.h as isize).contains(&iy) {
                                continue;
                            }
                            for kx in 0..g.kw {
                                let ix = (ox * sw + kx) as isize - pl as isize;
                                if !(0..g.w as isize).contains(&ix) {
                                    continue;
                                }
                                acc += xi[((iy as usize) * g.w + ix as usize) * g.ci + ic]
                                    * w.data[((ky * g.kw + kx) * g.ci + ic) * mult + im];
                            }
                        }
                        orow[ic * mult + im] = act.apply(acc);
                    }
                }
            }
        }
    }
}

/// MaxPool over NHWC (geom.co == geom.ci == channels), all `g.n` images.
pub fn max_pool(x: &[f32], g: &ConvGeom, out: &mut [f32]) {
    let (sh, sw) = g.stride;
    let (pt, _, pl, _) = g.pad;
    let c = g.ci;
    for img in 0..g.n {
        let xi = &x[img * g.h * g.w * c..][..g.h * g.w * c];
        let oi = &mut out[img * g.ho * g.wo * c..][..g.ho * g.wo * c];
        for oy in 0..g.ho {
            for ox in 0..g.wo {
                let orow = &mut oi[(oy * g.wo + ox) * c..][..c];
                orow.fill(f32::NEG_INFINITY);
                for ky in 0..g.kh {
                    let iy = (oy * sh + ky) as isize - pt as isize;
                    if !(0..g.h as isize).contains(&iy) {
                        continue;
                    }
                    for kx in 0..g.kw {
                        let ix = (ox * sw + kx) as isize - pl as isize;
                        if !(0..g.w as isize).contains(&ix) {
                            continue;
                        }
                        let xrow = &xi[((iy as usize) * g.w + ix as usize) * c..][..c];
                        for (o, &v) in orow.iter_mut().zip(xrow) {
                            if v > *o {
                                *o = v;
                            }
                        }
                    }
                }
            }
        }
    }
}

/// Per-channel affine over the last dim: out[i] = act(x[i]*a[c] + b[c]).
/// Covers BiasAdd (a = None), Mul (b = None), AddC, and the folded
/// FusedBatchNorm (both Some).
pub fn affine(
    x: &[f32],
    ch: usize,
    a: Option<&[f32]>,
    b: Option<&[f32]>,
    act: Act,
    out: &mut [f32],
) {
    debug_assert_eq!(x.len(), out.len(), "affine operand/output length mismatch");
    for (i, (o, &v)) in out.iter_mut().zip(x).enumerate() {
        let c = i % ch;
        let mut y = v;
        if let Some(av) = a {
            y *= av[c];
        }
        if let Some(bv) = b {
            y += bv[c];
        }
        *o = act.apply(y);
    }
}

/// Elementwise unary activation into `out`.
pub fn unary(x: &[f32], act: Act, out: &mut [f32]) {
    debug_assert_eq!(x.len(), out.len(), "unary operand/output length mismatch");
    for (o, &v) in out.iter_mut().zip(x) {
        *o = act.apply(v);
    }
}

/// Elementwise residual add. The zips would silently truncate on a
/// mismatched operand (e.g. a per-image constant that missed batch
/// tiling), leaving stale arena data in the tail — assert instead.
pub fn add(a: &[f32], b: &[f32], out: &mut [f32]) {
    debug_assert_eq!(a.len(), out.len(), "add operand/output length mismatch");
    debug_assert_eq!(b.len(), out.len(), "add operand/output length mismatch");
    for ((o, &x), &y) in out.iter_mut().zip(a).zip(b) {
        *o = x + y;
    }
}

/// Global average pool NHWC -> [n, C], per image (f64 accumulation,
/// matching the reference interpreter bit-for-bit in the common case).
pub fn global_mean(x: &[f32], n: usize, h: usize, w: usize, c: usize, out: &mut [f32]) {
    for img in 0..n {
        let xi = &x[img * h * w * c..][..h * w * c];
        let oi = &mut out[img * c..][..c];
        for ch in 0..c {
            let mut s = 0f64;
            for p in 0..h * w {
                s += xi[p * c + ch] as f64;
            }
            oi[ch] = (s / (h * w) as f64) as f32;
        }
    }
}

/// Spatial zero-pad NHWC, all `n` images.
#[allow(clippy::too_many_arguments)] // kernel ABI: batch + spatial dims
pub fn pad(
    x: &[f32],
    n: usize,
    h: usize,
    w: usize,
    c: usize,
    pads: (usize, usize, usize, usize),
    out: &mut [f32],
) {
    let (t, b, l, r) = pads;
    let (ho, wo) = (h + t + b, w + l + r);
    out[..n * ho * wo * c].fill(0.0);
    for img in 0..n {
        let xi = &x[img * h * w * c..][..h * w * c];
        let oi = &mut out[img * ho * wo * c..][..ho * wo * c];
        for y in 0..h {
            let src = &xi[y * w * c..][..w * c];
            let dst = &mut oi[((y + t) * wo + l) * c..][..w * c];
            dst.copy_from_slice(src);
        }
    }
}

/// Row softmax over an [N, C] tensor.
pub fn softmax(x: &[f32], n: usize, c: usize, out: &mut [f32]) {
    for i in 0..n {
        let src = &x[i * c..][..c];
        let dst = &mut out[i * c..][..c];
        let m = src.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        let mut sum = 0f32;
        for (d, &v) in dst.iter_mut().zip(src) {
            *d = (v - m).exp();
            sum += *d;
        }
        for d in dst.iter_mut() {
            *d /= sum;
        }
    }
}
