//! Runtime CPU-feature dispatch for the hot microkernels (ISSUE 7).
//!
//! HPIPE sizes each layer's hardware to the device's real multiplier
//! budget; the software analog is running the packed microkernels at the
//! CPU's actual lane width. This module detects the CPU's vector
//! features **once**, selects the widest available microkernel set
//! through a single kernel-table indirection ([`Isa`]), and lets tests,
//! benches and CI force any tier via the `HPIPE_ISA` environment
//! variable (`scalar|sse4.1|avx2|fma|neon|native`).
//!
//! # Kernel tiers and the scalar-baseline guarantee
//!
//! Every tier implements the same two primitives the packed kernels are
//! built from:
//!
//! * **dense tile** — accumulate one [`MR`]×[`NR`] register tile over a
//!   `kc`-deep packed A-panel × packed B-panel pair
//!   ([`super::kernels::gemm_panels_bias_act`]);
//! * **sparse axpy** — `acc[i] += v * p[i]` over one decoded weight's
//!   position range ([`super::sparse::sparse_packed_rows`]).
//!
//! Tier 0 (`scalar`) is the always-available baseline: plain loops with
//! one rounding per multiply and one per add, per element, in ascending
//! `k` order. The non-fused vector tiers (`sse4.1`, `avx2`, and every
//! sparse path including `fma`/`neon`) vectorize *across output
//! elements* with separate multiply and add instructions, so each
//! element's operation-and-rounding sequence is **unchanged** — those
//! tiers are bit-identical to scalar, and the cross-tier tests
//! (`rust/tests/isa_tiers.rs`) plus the `isa-matrix` CI job hold them to
//! exact equality. Only the fused-multiply-add dense tiers (`fma`,
//! `neon`) round once per FMA instead of twice; they report
//! [`Isa::fused_dense`] and are held to a ≤ 8 ulp bound instead.
//!
//! # Safety audit (the checked-dispatch-only contract)
//!
//! All `#[target_feature]` functions in this module are **private** and
//! `unsafe fn`; the only call path is through the safe [`Isa::dense_tile`]
//! / [`Isa::sparse_axpy`] wrappers, which assert slice lengths before
//! handing raw pointers down. Each per-tier [`Isa`] value is a `static`
//! whose function pointers match its tier, and a tier is only ever
//! selected ([`active`] / [`force`]) after its CPU features were verified
//! by `std::arch` runtime detection — so a `#[target_feature]` body can
//! never execute on a CPU lacking the feature. Safe code outside this
//! module cannot reach the function pointers at all (the fields are
//! private).

#![deny(unsafe_op_in_unsafe_fn)]

use super::kernels::{MR, NR};
use std::sync::atomic::{AtomicU8, Ordering};

/// Dispatch tiers, narrowest to widest. `Sse41`/`Avx2`/`Fma` exist on
/// x86_64, `Neon` on aarch64; [`supported`] is false for the rest, and
/// [`Tier::Scalar`] is available everywhere.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Tier {
    Scalar = 0,
    Sse41 = 1,
    Avx2 = 2,
    Fma = 3,
    Neon = 4,
}

impl Tier {
    /// The `HPIPE_ISA` spelling of this tier.
    pub fn name(self) -> &'static str {
        match self {
            Tier::Scalar => "scalar",
            Tier::Sse41 => "sse4.1",
            Tier::Avx2 => "avx2",
            Tier::Fma => "fma",
            Tier::Neon => "neon",
        }
    }

    fn from_u8(v: u8) -> Tier {
        match v {
            1 => Tier::Sse41,
            2 => Tier::Avx2,
            3 => Tier::Fma,
            4 => Tier::Neon,
            _ => Tier::Scalar,
        }
    }

    /// Parse an `HPIPE_ISA` value. `Ok(None)` means "native" (pick the
    /// widest supported tier); `Err(())` is an unrecognized spelling.
    #[allow(clippy::result_unit_err)] // the one caller turns Err into a warning
    pub fn parse(s: &str) -> Result<Option<Tier>, ()> {
        match s {
            "" | "native" => Ok(None),
            "scalar" => Ok(Some(Tier::Scalar)),
            "sse4.1" => Ok(Some(Tier::Sse41)),
            "avx2" => Ok(Some(Tier::Avx2)),
            "fma" => Ok(Some(Tier::Fma)),
            "neon" => Ok(Some(Tier::Neon)),
            _ => Err(()),
        }
    }
}

/// Dense-tile microkernel ABI: accumulate a `kc`-deep panel pair into an
/// MR×NR accumulator tile. `a` points at `kc*MR` packed A values
/// (`a[kk*MR + r]`), `b` at `kc*NR` packed B values (`b[kk*NR + j]`),
/// `acc` at `MR*NR` row-major accumulators, pre-seeded by the caller.
type DenseTileFn = unsafe fn(a: *const f32, b: *const f32, kc: usize, acc: *mut f32);

/// Sparse-axpy ABI: `acc[i] += v * p[i]` for `i < len`.
type SparseAxpyFn = unsafe fn(v: f32, p: *const f32, acc: *mut f32, len: usize);

/// One dispatch tier's kernel table. The function-pointer fields are
/// private: the only way to run them is through the length-checked safe
/// methods below, and the only [`Isa`] values are the per-tier statics
/// handed out by [`active`] / [`available`] after feature verification.
pub struct Isa {
    tier: Tier,
    /// True when the dense tile uses fused multiply-add (one rounding
    /// per FMA). Tests compare such tiers to scalar within ulps instead
    /// of bitwise; sparse kernels never fuse, on any tier.
    fused_dense: bool,
    dense_tile: DenseTileFn,
    sparse_axpy: SparseAxpyFn,
}

impl Isa {
    pub fn tier(&self) -> Tier {
        self.tier
    }

    pub fn name(&self) -> &'static str {
        self.tier.name()
    }

    pub fn fused_dense(&self) -> bool {
        self.fused_dense
    }

    /// Accumulate one MR×NR register tile over a `kc`-deep packed
    /// A-panel / B-panel pair. Checked entry point for the tier's
    /// `#[target_feature]` microkernel.
    #[inline]
    pub fn dense_tile(&self, a: &[f32], b: &[f32], kc: usize, acc: &mut [f32; MR * NR]) {
        assert!(a.len() >= kc * MR, "dense_tile: A panel shorter than kc*MR");
        assert!(b.len() >= kc * NR, "dense_tile: B panel shorter than kc*NR");
        // SAFETY: the pointers cover the asserted kc*MR / kc*NR / MR*NR
        // element ranges the kernel reads/writes, and the target features
        // the function was compiled for were runtime-verified before this
        // tier could be selected (see module docs).
        unsafe { (self.dense_tile)(a.as_ptr(), b.as_ptr(), kc, acc.as_mut_ptr()) }
    }

    /// `acc[i] += v * p[i]` over a decoded weight's position range.
    /// Checked entry point for the tier's `#[target_feature]` axpy.
    #[inline]
    pub fn sparse_axpy(&self, v: f32, p: &[f32], acc: &mut [f32]) {
        assert!(p.len() >= acc.len(), "sparse_axpy: positions shorter than accumulator");
        // SAFETY: both pointers are valid for `acc.len()` reads (and
        // writes, for `acc`) per the assert, and the tier's features were
        // runtime-verified before selection (see module docs).
        unsafe { (self.sparse_axpy)(v, p.as_ptr(), acc.as_mut_ptr(), acc.len()) }
    }
}

/// Is `t` executable on this CPU?
pub fn supported(t: Tier) -> bool {
    match t {
        Tier::Scalar => true,
        #[cfg(target_arch = "x86_64")]
        Tier::Sse41 => std::arch::is_x86_feature_detected!("sse4.1"),
        #[cfg(target_arch = "x86_64")]
        Tier::Avx2 => std::arch::is_x86_feature_detected!("avx2"),
        #[cfg(target_arch = "x86_64")]
        Tier::Fma => {
            std::arch::is_x86_feature_detected!("avx2")
                && std::arch::is_x86_feature_detected!("fma")
        }
        #[cfg(target_arch = "aarch64")]
        Tier::Neon => std::arch::is_aarch64_feature_detected!("neon"),
        #[allow(unreachable_patterns)] // off-arch tiers fall through here
        _ => false,
    }
}

fn isa_for(t: Tier) -> &'static Isa {
    match t {
        #[cfg(target_arch = "x86_64")]
        Tier::Sse41 => &SSE41,
        #[cfg(target_arch = "x86_64")]
        Tier::Avx2 => &AVX2,
        #[cfg(target_arch = "x86_64")]
        Tier::Fma => &FMA,
        #[cfg(target_arch = "aarch64")]
        Tier::Neon => &NEON,
        // Scalar, plus off-arch tiers (unreachable: selection is gated
        // on `supported`, which rejects them).
        _ => &SCALAR,
    }
}

/// Widest tier this CPU supports (the "native" choice).
fn widest() -> Tier {
    for t in [Tier::Neon, Tier::Fma, Tier::Avx2, Tier::Sse41] {
        if supported(t) {
            return t;
        }
    }
    Tier::Scalar
}

/// Every tier this CPU can execute, narrowest (scalar) first — each as
/// its full kernel table, ready for cross-tier equivalence tests.
pub fn available() -> Vec<&'static Isa> {
    [Tier::Scalar, Tier::Sse41, Tier::Avx2, Tier::Fma, Tier::Neon]
        .into_iter()
        .filter(|&t| supported(t))
        .map(isa_for)
        .collect()
}

const UNINIT: u8 = u8::MAX;
static ACTIVE: AtomicU8 = AtomicU8::new(UNINIT);

/// Resolve the startup tier: `HPIPE_ISA` override if set, else the
/// widest detected tier. A *valid but unsupported* request falls back to
/// scalar — never silently to native — so a CI job forcing a tier the
/// runner lacks produces an obviously-degraded run, not a fake pass.
fn init_tier() -> Tier {
    match std::env::var("HPIPE_ISA") {
        Err(_) => widest(),
        Ok(s) => match Tier::parse(&s) {
            Ok(None) => widest(),
            Ok(Some(t)) if supported(t) => t,
            Ok(Some(t)) => {
                eprintln!(
                    "HPIPE_ISA={s}: tier `{}` is not supported on this CPU; \
                     falling back to scalar",
                    t.name()
                );
                Tier::Scalar
            }
            Err(()) => {
                eprintln!(
                    "HPIPE_ISA={s}: unknown tier (valid: \
                     scalar|sse4.1|avx2|fma|neon|native); using native"
                );
                widest()
            }
        },
    }
}

/// The active kernel table. Detection (plus the `HPIPE_ISA` override)
/// runs once, on first use; the result is cached process-wide.
pub fn active() -> &'static Isa {
    let v = ACTIVE.load(Ordering::Relaxed);
    let t = if v == UNINIT {
        let t = init_tier();
        ACTIVE.store(t as u8, Ordering::Relaxed);
        t
    } else {
        Tier::from_u8(v)
    };
    isa_for(t)
}

/// Force the active tier (benches and single-threaded harnesses only —
/// the setting is process-global, so concurrent tests use the explicit
/// `*_on` kernel variants instead). Errors if the CPU lacks the tier.
pub fn force(t: Tier) -> Result<(), String> {
    if !supported(t) {
        return Err(format!("isa tier `{}` not supported on this CPU", t.name()));
    }
    ACTIVE.store(t as u8, Ordering::Relaxed);
    Ok(())
}

/// One-line summary for serve output: active tier + everything detected.
pub fn describe() -> String {
    let avail: Vec<&str> = available().iter().map(|i| i.name()).collect();
    format!("{} (available: {})", active().name(), avail.join(" "))
}

// ---------------------------------------------------------------------
// Tier 0: scalar — the always-available baseline.
// ---------------------------------------------------------------------

static SCALAR: Isa = Isa {
    tier: Tier::Scalar,
    fused_dense: false,
    dense_tile: dense_tile_scalar,
    sparse_axpy: sparse_axpy_scalar,
};

/// # Safety
/// `a` must be valid for `kc*MR` reads, `b` for `kc*NR` reads, `acc` for
/// `MR*NR` reads and writes. (No CPU-feature requirement.)
unsafe fn dense_tile_scalar(a: *const f32, b: *const f32, kc: usize, acc: *mut f32) {
    // SAFETY: all offsets stay inside the ranges the caller guarantees.
    unsafe {
        for kk in 0..kc {
            for r in 0..MR {
                let av = *a.add(kk * MR + r);
                for j in 0..NR {
                    let o = acc.add(r * NR + j);
                    *o += av * *b.add(kk * NR + j);
                }
            }
        }
    }
}

/// # Safety
/// `p` must be valid for `len` reads and `acc` for `len` reads and
/// writes. (No CPU-feature requirement.)
unsafe fn sparse_axpy_scalar(v: f32, p: *const f32, acc: *mut f32, len: usize) {
    // SAFETY: all offsets are < len, inside the caller-guaranteed ranges.
    unsafe {
        for i in 0..len {
            *acc.add(i) += v * *p.add(i);
        }
    }
}

// ---------------------------------------------------------------------
// x86_64 tiers. The non-fused tiles issue separate vector multiply and
// add instructions, so every output element keeps the scalar chain's
// exact rounding sequence (bitwise-equal results); only the FMA dense
// tile fuses. NR = 16 spans four __m128 or two __m256 lanes per row.
// ---------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
static SSE41: Isa = Isa {
    tier: Tier::Sse41,
    fused_dense: false,
    dense_tile: dense_tile_sse41,
    sparse_axpy: sparse_axpy_sse41,
};

#[cfg(target_arch = "x86_64")]
static AVX2: Isa = Isa {
    tier: Tier::Avx2,
    fused_dense: false,
    dense_tile: dense_tile_avx2,
    sparse_axpy: sparse_axpy_avx2,
};

/// The FMA tier fuses the *dense* tile only; its sparse axpy is the
/// non-fused AVX2 one, keeping sparse results bitwise-equal to scalar on
/// every tier (the equivalence suite's sparse bar is exact equality).
#[cfg(target_arch = "x86_64")]
static FMA: Isa = Isa {
    tier: Tier::Fma,
    fused_dense: true,
    dense_tile: dense_tile_fma,
    sparse_axpy: sparse_axpy_avx2,
};

/// # Safety
/// Same pointer contract as [`dense_tile_scalar`]; the CPU must support
/// SSE4.1 (guaranteed by dispatch — see module docs).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "sse4.1")]
unsafe fn dense_tile_sse41(a: *const f32, b: *const f32, kc: usize, acc: *mut f32) {
    use core::arch::x86_64::*;
    const L: usize = 4; // __m128 lanes per NR row
    // SAFETY: all loads/stores stay inside the caller-guaranteed kc*MR /
    // kc*NR / MR*NR ranges; unaligned load/store intrinsics are used.
    unsafe {
        let mut accv = [_mm_setzero_ps(); MR * L];
        for (i, av) in accv.iter_mut().enumerate() {
            *av = _mm_loadu_ps(acc.add(i * 4));
        }
        for kk in 0..kc {
            let mut bv = [_mm_setzero_ps(); L];
            for (j, b_j) in bv.iter_mut().enumerate() {
                *b_j = _mm_loadu_ps(b.add(kk * NR + j * 4));
            }
            for r in 0..MR {
                let av = _mm_set1_ps(*a.add(kk * MR + r));
                for j in 0..L {
                    let o = &mut accv[r * L + j];
                    // separate mul + add: scalar rounding chain per lane
                    *o = _mm_add_ps(*o, _mm_mul_ps(av, bv[j]));
                }
            }
        }
        for (i, av) in accv.iter().enumerate() {
            _mm_storeu_ps(acc.add(i * 4), *av);
        }
    }
}

/// # Safety
/// Same pointer contract as [`sparse_axpy_scalar`]; the CPU must support
/// SSE4.1 (guaranteed by dispatch — see module docs).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "sse4.1")]
unsafe fn sparse_axpy_sse41(v: f32, p: *const f32, acc: *mut f32, len: usize) {
    use core::arch::x86_64::*;
    // SAFETY: vector body covers len/4*4 elements, scalar tail the rest;
    // every offset is < len.
    unsafe {
        let vv = _mm_set1_ps(v);
        let mut i = 0usize;
        while i + 4 <= len {
            let av = _mm_loadu_ps(acc.add(i));
            let pv = _mm_loadu_ps(p.add(i));
            _mm_storeu_ps(acc.add(i), _mm_add_ps(av, _mm_mul_ps(vv, pv)));
            i += 4;
        }
        while i < len {
            *acc.add(i) += v * *p.add(i);
            i += 1;
        }
    }
}

/// # Safety
/// Same pointer contract as [`dense_tile_scalar`]; the CPU must support
/// AVX2 (guaranteed by dispatch — see module docs).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn dense_tile_avx2(a: *const f32, b: *const f32, kc: usize, acc: *mut f32) {
    use core::arch::x86_64::*;
    const L: usize = 2; // __m256 lanes per NR row
    // SAFETY: all loads/stores stay inside the caller-guaranteed ranges.
    unsafe {
        let mut accv = [_mm256_setzero_ps(); MR * L];
        for (i, av) in accv.iter_mut().enumerate() {
            *av = _mm256_loadu_ps(acc.add(i * 8));
        }
        for kk in 0..kc {
            let b0 = _mm256_loadu_ps(b.add(kk * NR));
            let b1 = _mm256_loadu_ps(b.add(kk * NR + 8));
            for r in 0..MR {
                let av = _mm256_set1_ps(*a.add(kk * MR + r));
                let o0 = &mut accv[r * L];
                // separate mul + add: scalar rounding chain per lane
                *o0 = _mm256_add_ps(*o0, _mm256_mul_ps(av, b0));
                let o1 = &mut accv[r * L + 1];
                *o1 = _mm256_add_ps(*o1, _mm256_mul_ps(av, b1));
            }
        }
        for (i, av) in accv.iter().enumerate() {
            _mm256_storeu_ps(acc.add(i * 8), *av);
        }
    }
}

/// # Safety
/// Same pointer contract as [`sparse_axpy_scalar`]; the CPU must support
/// AVX2 (guaranteed by dispatch — see module docs).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn sparse_axpy_avx2(v: f32, p: *const f32, acc: *mut f32, len: usize) {
    use core::arch::x86_64::*;
    // SAFETY: vector body covers len/8*8 elements, scalar tail the rest.
    unsafe {
        let vv = _mm256_set1_ps(v);
        let mut i = 0usize;
        while i + 8 <= len {
            let av = _mm256_loadu_ps(acc.add(i));
            let pv = _mm256_loadu_ps(p.add(i));
            // no FMA here, on any tier: sparse results stay bitwise
            _mm256_storeu_ps(acc.add(i), _mm256_add_ps(av, _mm256_mul_ps(vv, pv)));
            i += 8;
        }
        while i < len {
            *acc.add(i) += v * *p.add(i);
            i += 1;
        }
    }
}

/// # Safety
/// Same pointer contract as [`dense_tile_scalar`]; the CPU must support
/// AVX2 and FMA (guaranteed by dispatch — see module docs).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn dense_tile_fma(a: *const f32, b: *const f32, kc: usize, acc: *mut f32) {
    use core::arch::x86_64::*;
    const L: usize = 2;
    // SAFETY: all loads/stores stay inside the caller-guaranteed ranges.
    unsafe {
        let mut accv = [_mm256_setzero_ps(); MR * L];
        for (i, av) in accv.iter_mut().enumerate() {
            *av = _mm256_loadu_ps(acc.add(i * 8));
        }
        for kk in 0..kc {
            let b0 = _mm256_loadu_ps(b.add(kk * NR));
            let b1 = _mm256_loadu_ps(b.add(kk * NR + 8));
            for r in 0..MR {
                let av = _mm256_set1_ps(*a.add(kk * MR + r));
                // fused multiply-add: one rounding per step, so this
                // tier reports fused_dense and is ulp- (not bit-)
                // compared against scalar
                accv[r * L] = _mm256_fmadd_ps(av, b0, accv[r * L]);
                accv[r * L + 1] = _mm256_fmadd_ps(av, b1, accv[r * L + 1]);
            }
        }
        for (i, av) in accv.iter().enumerate() {
            _mm256_storeu_ps(acc.add(i * 8), *av);
        }
    }
}

// ---------------------------------------------------------------------
// aarch64: NEON. Dense fuses (vfmaq); sparse stays mul+add for the
// bitwise sparse guarantee.
// ---------------------------------------------------------------------

#[cfg(target_arch = "aarch64")]
static NEON: Isa = Isa {
    tier: Tier::Neon,
    fused_dense: true,
    dense_tile: dense_tile_neon,
    sparse_axpy: sparse_axpy_neon,
};

/// # Safety
/// Same pointer contract as [`dense_tile_scalar`]; the CPU must support
/// NEON (guaranteed by dispatch — see module docs).
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn dense_tile_neon(a: *const f32, b: *const f32, kc: usize, acc: *mut f32) {
    use core::arch::aarch64::*;
    const L: usize = 4; // float32x4 lanes per NR row
    // SAFETY: all loads/stores stay inside the caller-guaranteed ranges.
    unsafe {
        let mut accv = [vdupq_n_f32(0.0); MR * L];
        for (i, av) in accv.iter_mut().enumerate() {
            *av = vld1q_f32(acc.add(i * 4));
        }
        for kk in 0..kc {
            let mut bv = [vdupq_n_f32(0.0); L];
            for (j, b_j) in bv.iter_mut().enumerate() {
                *b_j = vld1q_f32(b.add(kk * NR + j * 4));
            }
            for r in 0..MR {
                let av = vdupq_n_f32(*a.add(kk * MR + r));
                for j in 0..L {
                    // fused multiply-add (fused_dense tier)
                    accv[r * L + j] = vfmaq_f32(accv[r * L + j], av, bv[j]);
                }
            }
        }
        for (i, av) in accv.iter().enumerate() {
            vst1q_f32(acc.add(i * 4), *av);
        }
    }
}

/// # Safety
/// Same pointer contract as [`sparse_axpy_scalar`]; the CPU must support
/// NEON (guaranteed by dispatch — see module docs).
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn sparse_axpy_neon(v: f32, p: *const f32, acc: *mut f32, len: usize) {
    use core::arch::aarch64::*;
    // SAFETY: vector body covers len/4*4 elements, scalar tail the rest.
    unsafe {
        let vv = vdupq_n_f32(v);
        let mut i = 0usize;
        while i + 4 <= len {
            let av = vld1q_f32(acc.add(i));
            let pv = vld1q_f32(p.add(i));
            // separate mul + add: sparse results stay bitwise on NEON too
            vst1q_f32(acc.add(i), vaddq_f32(av, vmulq_f32(vv, pv)));
            i += 4;
        }
        while i < len {
            *acc.add(i) += v * *p.add(i);
            i += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_is_always_available_and_first() {
        let tiers = available();
        assert!(!tiers.is_empty());
        assert_eq!(tiers[0].tier(), Tier::Scalar);
        assert!(!tiers[0].fused_dense());
        // ascending width, no duplicates
        for w in tiers.windows(2) {
            assert!(w[0].tier() < w[1].tier());
        }
    }

    #[test]
    fn parse_covers_every_documented_spelling() {
        assert_eq!(Tier::parse(""), Ok(None));
        assert_eq!(Tier::parse("native"), Ok(None));
        assert_eq!(Tier::parse("scalar"), Ok(Some(Tier::Scalar)));
        assert_eq!(Tier::parse("sse4.1"), Ok(Some(Tier::Sse41)));
        assert_eq!(Tier::parse("avx2"), Ok(Some(Tier::Avx2)));
        assert_eq!(Tier::parse("fma"), Ok(Some(Tier::Fma)));
        assert_eq!(Tier::parse("neon"), Ok(Some(Tier::Neon)));
        assert_eq!(Tier::parse("sse2"), Err(()));
        assert_eq!(Tier::parse("AVX2"), Err(()));
        // round-trip: every tier's name parses back to itself
        for t in [Tier::Scalar, Tier::Sse41, Tier::Avx2, Tier::Fma, Tier::Neon] {
            assert_eq!(Tier::parse(t.name()), Ok(Some(t)));
        }
    }

    #[test]
    fn active_tier_is_supported_and_describe_mentions_it() {
        let isa = active();
        assert!(supported(isa.tier()));
        assert!(describe().contains(isa.name()));
    }

    #[test]
    fn sparse_axpy_is_bitwise_scalar_on_every_tier() {
        // quick smoke at the dispatch layer; the full cross-tier
        // property suite lives in rust/tests/isa_tiers.rs
        let p: Vec<f32> = (0..37).map(|i| (i as f32) * 0.37 - 5.0).collect();
        let v = 1.7f32;
        let mut want: Vec<f32> = (0..37).map(|i| (i as f32) * 0.11).collect();
        let base = want.clone();
        SCALAR.sparse_axpy(v, &p, &mut want);
        for isa in available() {
            let mut got = base.clone();
            isa.sparse_axpy(v, &p, &mut got);
            assert_eq!(got, want, "tier {}", isa.name());
        }
    }

    #[test]
    fn dense_tile_tiers_match_scalar_within_contract() {
        let kc = 19usize;
        let a: Vec<f32> = (0..kc * MR).map(|i| ((i * 7 % 23) as f32) * 0.21 - 2.0).collect();
        let b: Vec<f32> = (0..kc * NR).map(|i| ((i * 5 % 31) as f32) * 0.13 - 1.9).collect();
        let seed: Vec<f32> = (0..MR * NR).map(|i| (i as f32) * 0.01).collect();
        let mut want = [0.0f32; MR * NR];
        want.copy_from_slice(&seed);
        SCALAR.dense_tile(&a, &b, kc, &mut want);
        for isa in available() {
            let mut got = [0.0f32; MR * NR];
            got.copy_from_slice(&seed);
            isa.dense_tile(&a, &b, kc, &mut got);
            if isa.fused_dense() {
                crate::util::prop::assert_ulp_close(&got, &want, 8)
                    .map_err(|e| format!("tier {}: {e}", isa.name()))
                    .unwrap();
            } else {
                assert_eq!(got, want, "tier {}", isa.name());
            }
        }
    }
}
