//! Compiled execution engine — the software hot path.
//!
//! HPIPE's central argument (§III) is that *specializing compute per
//! layer ahead of time* — custom-tailored units plus zero-skipping over
//! RLE weight streams — beats generic one-op-at-a-time processing. This
//! module is that principle applied to the software reproduction's own
//! hot path: an [`ExecutionPlan`] is built **once** per graph and then
//! executed per image with none of the interpreter's per-run costs.
//!
//! What building a plan does:
//!
//! * resolves topological order and **pre-binds every operand to a
//!   buffer slot index** — no `BTreeMap<String, Tensor>` lookups and no
//!   per-node output clones at runtime;
//! * **folds constants**: any node whose inputs are all constants is
//!   evaluated at build time with the reference-interpreter kernels;
//! * **fuses** `Conv2D`/`DepthwiseConv2d`/`MatMul` → `BiasAdd` → `Relu`/
//!   `Relu6` chains into single steps (bias-initialized accumulators,
//!   activation applied on writeback);
//! * selects a **specialized kernel per node**: im2col + register-tiled
//!   GEMM for dense convolutions ([`kernels`]), and a pre-decoded sparse
//!   kernel ([`sparse`]) for weights at or above the sparsity threshold
//!   — the software analog of the paper's zero-skipping PEs;
//! * **prepacks every compute node's weights** ([`PlanOptions::packed`],
//!   on by default): dense weights are repacked into microkernel-native
//!   [`kernels::PackedB`] panels and RLE streams are pre-decoded into
//!   flat [`sparse::PackedRle`] nonzero arrays — the software analog of
//!   baking each layer's weights into its own M20K banks (§V-A), so the
//!   execution hot path never runs the runlength decoder and never
//!   re-walks an unpacked weight layout;
//! * assigns outputs to a **buffer arena** with liveness-based reuse, so
//!   steady-state serving performs zero heap allocations per image
//!   (feeds are copied into their slots; everything else is overwritten
//!   in place across runs via [`ExecutionPlan::run_with`]);
//! * compiles for a **native batch dimension** ([`PlanOptions::batch`]):
//!   a batch-B plan's arena slots hold `B ×` activations and every step
//!   executes the whole batch at once — dense convs im2col all B images
//!   into one k-blocked GEMM with shared weight tiles, and the sparse
//!   kernels walk each RLE weight stream *once*, broadcasting every
//!   surviving weight across the batch's activation planes. That is the
//!   software analog of weight-reuse-across-batch: the dominant memory
//!   optimization for CNN accelerators, applied to our own weight
//!   streams instead of running a batch-1 plan B times.
//!
//! Role split: [`crate::interp`] stays the *correctness oracle* — naive,
//! obviously-right loops that transform passes and this executor are
//! checked against (`rust/tests/exec_equiv.rs` asserts bit-close
//! equivalence on randomized graphs across sparsity levels). The
//! executor is the *serving path*: `runtime::LoadedModel`, the
//! coordinator and the benches all run through plans.
//!
//! # Pipelined execution
//!
//! HPIPE's §III dataflow runs *every* layer at once: each layer owns
//! dedicated hardware, activations stream between layers through bounded
//! line buffers, and batch-1 throughput is set by the slowest stage, not
//! by the sum of all stages. [`pipeline::PipelinePlan`] is the software
//! twin of that dataflow for throughput-oriented serving:
//!
//! * the plan's steps are split into `N` **contiguous stages** by a
//!   linear-partition DP that minimizes the bottleneck stage — the same
//!   objective as the paper's balance-to-the-slowest-stage DSP
//!   allocation (Algorithm 1), with per-step costs from the compile-side
//!   cycle model (`compile::throughput`, the numbers the `sim` stations
//!   consume), so *sparse-aware* costs drive the cut placement;
//! * one **worker thread per stage** executes its step range per image,
//!   with multiple images in flight — stage `j` runs image `i + 1`
//!   while stage `j + 1` runs image `i`;
//! * at each cut, the values that cross it (computed by arena liveness
//!   over the cut) are copied into **double-buffered boundary
//!   messages** exchanged over SPSC channels — the software analog of
//!   the paper's stage-boundary line buffers, replacing the single
//!   shared arena that assumes one in-flight image. Bounded channels
//!   provide the paper's coarse backpressure.
//!
//! The single-image latency path stays on the sequential
//! [`ExecutionPlan`]; the pipeline is engaged by `runtime::LoadedModel`
//! for batch serving when configured with `threads > 1`.
//!
//! # Profile-guided autotuning
//!
//! The model-driven cuts above are a prediction; [`profile`] measures
//! what each step actually costs (median-of-K wall times through the
//! sequential plan) and [`tune`] re-runs the same bottleneck-partition
//! DP over those measurements, sizes the stage count to the machine's
//! core budget, and spends leftover cores on the measured-dominant
//! stage's worker team ([`PipelinePlan::from_profile`]). Calibration is
//! per plan — and therefore per group-batch size — so batched serving
//! stops reusing the B=1 cuts. `runtime::LoadedModel::autotuned` is the
//! calibrate-then-serve entry point; the static model-driven path stays
//! the default.
//!
//! # Kernel tiers (explicit SIMD dispatch)
//!
//! The packed microkernels run at the CPU's real lane width: [`isa`]
//! detects vector features once per process (overridable with
//! `HPIPE_ISA=scalar|sse4.1|avx2|fma|neon|native`) and routes the dense
//! MR×NR tile and the sparse position-axis axpy through per-tier
//! `#[target_feature]` implementations. Both operand streams are packed
//! — weights at plan build time ([`kernels::PackedB`],
//! [`sparse::PackedRle`]), activations at run time into MR-row A-panels
//! ([`kernels::pack_a`] / [`kernels::im2col_a`]) or the K-major
//! transpose ([`sparse::transpose_k_major`]) — so every tier streams
//! contiguous memory. The scalar tier is the always-available baseline
//! and the correctness anchor: sparse kernels and non-fused dense tiers
//! are bit-identical to it on any CPU, fused dense tiers (FMA/NEON) stay
//! within 8 ulp, and the CI isa-matrix job re-runs the whole suite under
//! each forced tier to hold that contract.

pub mod isa;
pub mod kernels;
pub mod pipeline;
pub mod profile;
pub mod sparse;
pub mod store;
pub mod tune;

pub use kernels::{Act, ConvGeom};
pub use pipeline::{PipelinePlan, StageFault, StageMetrics};
pub use profile::{profile_plan, ProfileOptions, StepProfile};
pub use store::WeightStore;
pub use tune::{choose_cuts, TuneEntry, TuneOptions, TuneReport, TunedCuts};

use crate::graph::{Graph, GraphError, Op, Tensor};
use crate::sparsity::rle::{encode_conv, encode_matmul, ConvRle};
use std::collections::{BTreeMap, HashMap, HashSet};
use std::sync::Arc;

/// Knobs for plan construction.
#[derive(Clone, Copy, Debug)]
pub struct PlanOptions {
    /// Use the RLE sparse kernel for Conv2D/MatMul weights whose zero
    /// fraction is at least this value. `> 1.0` forces dense; `0.0`
    /// forces sparse everywhere.
    pub sparse_threshold: f64,
    /// Fuse Conv/MatMul → BiasAdd → Relu/Relu6 chains into single steps.
    pub fuse: bool,
    /// `n_channel_splits` used when encoding RLE streams. Software
    /// execution is serial, so 1 (no lockstep padding) is the fastest
    /// choice; higher values mirror the hardware encoding.
    pub splits: usize,
    /// Batch dimension the plan is compiled for: arena slots hold
    /// `batch ×` activations, and every kernel processes the whole batch
    /// per step — one im2col'd GEMM / one RLE weight-stream walk feeds
    /// all images, instead of the plan being run `batch` times. The
    /// graph's placeholders must have leading (batch) dim 1; feeds then
    /// carry `[batch, ...]` tensors.
    pub batch: usize,
    /// Prepack weights at plan build time: dense conv / matmul weights
    /// into register-tile panels ([`kernels::PackedB`]) and RLE streams
    /// into flat pre-decoded nonzero arrays ([`sparse::PackedRle`]), so
    /// the hot loop runs the register-tiled microkernels and never
    /// touches the runlength decoder. `false` restores the PR 3 axpy /
    /// stream-walking kernels — kept purely as the benchmark baseline
    /// (`benches/exec_engine.rs` gates packed ≥ baseline).
    pub packed: bool,
}

impl Default for PlanOptions {
    fn default() -> Self {
        PlanOptions {
            sparse_threshold: 0.5,
            fuse: true,
            splits: 1,
            batch: 1,
            packed: true,
        }
    }
}

impl PlanOptions {
    /// Never use the sparse kernel (baseline for ablations).
    pub fn dense_only() -> PlanOptions {
        PlanOptions {
            sparse_threshold: 2.0,
            ..Default::default()
        }
    }

    /// Always use the sparse kernel for Conv2D/MatMul.
    pub fn sparse_always() -> PlanOptions {
        PlanOptions {
            sparse_threshold: 0.0,
            ..Default::default()
        }
    }

    /// Default options at batch `b`.
    pub fn batched(b: usize) -> PlanOptions {
        PlanOptions {
            batch: b,
            ..Default::default()
        }
    }

    /// This configuration with the batch dim replaced.
    pub fn with_batch(self, b: usize) -> PlanOptions {
        PlanOptions { batch: b, ..self }
    }

    /// The PR 3 kernels (runtime RLE walking, axpy GEMM) — benchmark
    /// baseline for the prepacked register-tiled kernels.
    pub fn unpacked() -> PlanOptions {
        PlanOptions {
            packed: false,
            ..Default::default()
        }
    }
}

/// A pre-resolved operand: either a build-time constant or an arena slot.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Src {
    Const(usize),
    Slot(usize),
}

/// One executable step (a graph node, possibly with fused followers).
struct Step {
    /// Graph node name (of the fused chain's last node) — diagnostics.
    name: String,
    out: usize,
    inputs: Vec<Src>,
    kind: StepKind,
}

enum StepKind {
    DenseConv {
        geom: ConvGeom,
        w: usize,
        /// Plan-time packed weight panels, shared through the model's
        /// [`WeightStore`]; `None` only for the PR 3 baseline
        /// ([`PlanOptions::unpacked`]).
        packed: Option<Arc<kernels::PackedB>>,
        bias: Option<usize>,
        act: Act,
    },
    SparseConv {
        geom: ConvGeom,
        /// Encoded streams (kept for the cycle-cost model / baseline).
        rle: Arc<ConvRle>,
        /// Plan-time pre-decoded nonzeros; `None` only for the baseline.
        packed: Option<Arc<sparse::PackedRle>>,
        bias: Option<usize>,
        act: Act,
    },
    Depthwise {
        geom: ConvGeom,
        mult: usize,
        w: usize,
        bias: Option<usize>,
        act: Act,
    },
    DenseMatMul {
        n: usize,
        k: usize,
        co: usize,
        w: usize,
        packed: Option<Arc<kernels::PackedB>>,
        bias: Option<usize>,
        act: Act,
    },
    SparseMatMul {
        n: usize,
        k: usize,
        co: usize,
        rle: Arc<ConvRle>,
        packed: Option<Arc<sparse::PackedRle>>,
        bias: Option<usize>,
        act: Act,
    },
    MaxPool {
        geom: ConvGeom,
    },
    /// Per-channel affine (BiasAdd / Mul / AddC / folded FusedBatchNorm).
    Affine {
        ch: usize,
        a: Option<Vec<f32>>,
        b: Option<Vec<f32>>,
        act: Act,
    },
    Add,
    Unary {
        act: Act,
    },
    Mean {
        n: usize,
        h: usize,
        w: usize,
        c: usize,
    },
    Pad {
        n: usize,
        h: usize,
        w: usize,
        c: usize,
        pads: (usize, usize, usize, usize),
    },
    Softmax {
        n: usize,
        c: usize,
    },
}

/// Summary counters exposed for tests / benches / reports.
#[derive(Clone, Copy, Debug, Default)]
pub struct PlanStats {
    pub steps: usize,
    pub dense_convs: usize,
    pub sparse_convs: usize,
    pub dense_matmuls: usize,
    pub sparse_matmuls: usize,
    pub fused_chains: usize,
    pub folded_consts: usize,
    /// f32 elements across all arena slots (reused buffers counted once).
    pub arena_f32: usize,
    pub scratch_f32: usize,
}

/// A compiled, reusable execution plan for one graph at one batch size.
pub struct ExecutionPlan {
    steps: Vec<Step>,
    /// Const tensors, `Arc`-shared through the model's [`WeightStore`].
    /// Entries `< shared_consts` are store-backed (graph consts and
    /// build-time folds); entries `>= shared_consts` are plan-private
    /// batch-tiled copies.
    consts: Vec<Arc<Tensor>>,
    shared_consts: usize,
    slot_lens: Vec<usize>,
    scratch_len: usize,
    acc_len: usize,
    /// Batch dimension the plan was compiled for (see
    /// [`PlanOptions::batch`]); feed / output shapes carry it.
    batch: usize,
    /// (placeholder name, slot, expected batched shape).
    feeds: Vec<(String, usize, Vec<usize>)>,
    outputs: Vec<(Src, Vec<usize>)>,
    stats: PlanStats,
}

/// Reusable per-run buffers: the arena slots plus kernel scratch. Create
/// once with [`ExecutionPlan::new_context`]; every subsequent
/// [`ExecutionPlan::run_with`] is allocation-free.
pub struct ExecContext {
    slots: Vec<Vec<f32>>,
    scratch: Vec<f32>,
    acc: Vec<f32>,
}

impl ExecutionPlan {
    /// Build a plan with default options (batch 1).
    pub fn build(graph: &Graph) -> Result<ExecutionPlan, GraphError> {
        ExecutionPlan::build_with(graph, &PlanOptions::default())
    }

    /// Build a plan natively compiled for `batch` images per execution
    /// (default options otherwise).
    pub fn build_batched(graph: &Graph, batch: usize) -> Result<ExecutionPlan, GraphError> {
        ExecutionPlan::build_with(graph, &PlanOptions::batched(batch))
    }

    /// Build a plan. Fails on structural errors and on graphs whose
    /// compute-op weights / per-channel parameters are not constants
    /// (the interpreter remains the general-purpose fallback for those).
    /// With `opts.batch > 1` the plan is compiled *for that batch*:
    /// every placeholder must have leading dim 1, arena slots and
    /// liveness account for `batch ×` activations, and each step's
    /// kernel processes the whole batch (shared weight tiles / one RLE
    /// stream walk — see [`kernels`] and [`sparse`]).
    pub fn build_with(graph: &Graph, opts: &PlanOptions) -> Result<ExecutionPlan, GraphError> {
        let mut store = WeightStore::new();
        ExecutionPlan::build_with_store(graph, opts, &mut store)
    }

    /// [`Self::build_with`], sharing compiled weight state through
    /// `store`: const tensors, folded constants, packed panels and RLE
    /// streams are fetched get-or-insert, so every plan built against
    /// the same store (batch variants, the latency plan, calibration
    /// plans) references one copy of each — and a store prepopulated
    /// from an on-disk artifact skips the fold/encode/pack work
    /// entirely. Batch-tiled constants stay plan-private.
    pub fn build_with_store(
        graph: &Graph,
        opts: &PlanOptions,
        store: &mut WeightStore,
    ) -> Result<ExecutionPlan, GraphError> {
        let order = graph.topo_order()?;
        let shapes = graph.infer_shapes()?;
        let mut stats = PlanStats::default();

        let batch = opts.batch.max(1);
        if batch > 1 {
            for n in &graph.nodes {
                if let Op::Placeholder { shape } = &n.op {
                    if shape.first() != Some(&1) {
                        return Err(GraphError::Invalid(
                            n.name.clone(),
                            format!(
                                "batch-{batch} plan needs batch-1 placeholders, \
                                 got shape {shape:?}"
                            ),
                        ));
                    }
                }
            }
        }
        // Scale a per-image activation shape to the plan's batch. Every
        // non-const value flowing through the plan keeps a leading batch
        // dim of 1 per image (NHWC / [1, C]), so slots grow uniformly.
        let bshape = |s: &[usize]| -> Vec<usize> {
            let mut v = s.to_vec();
            if batch > 1 && !v.is_empty() {
                v[0] *= batch;
            }
            v
        };

        // ---- constants + constant folding ----
        // Both raw consts and fold results go through the store keyed
        // by node name: the fold decision (all inputs const) is
        // graph-deterministic and `fold_node` covers every compute op,
        // so a store hit is always the same value a fresh fold would
        // produce — and skips the interp-kernel evaluation.
        let mut consts: Vec<Arc<Tensor>> = Vec::new();
        let mut const_idx: HashMap<String, usize> = HashMap::new();
        for &i in &order {
            let n = &graph.nodes[i];
            match &n.op {
                Op::Const => {
                    let t = store.tensor_with(&n.name, || {
                        n.value.clone().ok_or_else(|| {
                            GraphError::Invalid(n.name.clone(), "Const without value".into())
                        })
                    })?;
                    const_idx.insert(n.name.clone(), consts.len());
                    consts.push(t);
                }
                Op::Placeholder { .. } => {}
                op => {
                    if !n.inputs.is_empty()
                        && n.inputs.iter().all(|s| const_idx.contains_key(s))
                    {
                        let t = store.tensor_with(&n.name, || {
                            let ins: Vec<&Tensor> =
                                n.inputs.iter().map(|s| &*consts[const_idx[s]]).collect();
                            Ok(fold_node(op, &ins).expect("every compute op folds"))
                        })?;
                        const_idx.insert(n.name.clone(), consts.len());
                        consts.push(t);
                        stats.folded_consts += 1;
                    }
                }
            }
        }
        // Everything below this index is store-shared; batch-tiled
        // copies appended later are plan-private.
        let shared_consts = consts.len();

        // ---- fusion scan ----
        let consumers = graph.consumers();
        let output_set: HashSet<&String> = graph.outputs.iter().collect();
        fn single_consumer<'a>(
            consumers: &'a HashMap<String, Vec<String>>,
            name: &str,
        ) -> Option<&'a String> {
            match consumers.get(name).map(|v| v.as_slice()) {
                Some([only]) => Some(only),
                _ => None,
            }
        }
        // intermediate node -> head; head -> (bias const name, act, tail)
        let mut absorbed: HashSet<String> = HashSet::new();
        let mut chains: HashMap<String, (Option<String>, Act, String)> = HashMap::new();
        if opts.fuse {
            for &i in &order {
                let n = &graph.nodes[i];
                if !n.op.is_compute() || const_idx.contains_key(&n.name) {
                    continue;
                }
                let mut tail = n.name.clone();
                let mut bias: Option<String> = None;
                let mut act = Act::None;
                let mut members: Vec<String> = Vec::new();
                if !output_set.contains(&tail) {
                    if let Some(c) = single_consumer(&consumers, &tail) {
                        let cn = graph.get(c).unwrap();
                        if matches!(cn.op, Op::BiasAdd)
                            && cn.inputs[0] == tail
                            && const_idx.contains_key(&cn.inputs[1])
                        {
                            bias = Some(cn.inputs[1].clone());
                            tail = c.clone();
                            members.push(c.clone());
                        }
                    }
                }
                if !output_set.contains(&tail) {
                    if let Some(r) = single_consumer(&consumers, &tail) {
                        let rn = graph.get(r).unwrap();
                        let a = match rn.op {
                            Op::Relu => Some(Act::Relu),
                            Op::Relu6 => Some(Act::Relu6),
                            _ => None,
                        };
                        if let Some(a) = a {
                            act = a;
                            tail = r.clone();
                            members.push(r.clone());
                        }
                    }
                }
                if tail != n.name {
                    stats.fused_chains += 1;
                    absorbed.extend(members);
                    chains.insert(n.name.clone(), (bias, act, tail));
                }
            }
        }

        // ---- emit proto steps ----
        struct Proto {
            name: String,
            out_name: String,
            out_shape: Vec<usize>,
            input_names: Vec<String>,
            kind: StepKind,
        }
        let invalid = |n: &str, m: &str| GraphError::Invalid(n.to_string(), m.to_string());
        let want_const = |const_idx: &HashMap<String, usize>,
                          node: &str,
                          input: &str|
         -> Result<usize, GraphError> {
            const_idx.get(input).copied().ok_or_else(|| {
                invalid(node, &format!("exec plan requires constant input '{input}'"))
            })
        };

        let mut protos: Vec<Proto> = Vec::new();
        let mut feeds: Vec<(String, usize, Vec<usize>)> = Vec::new();
        let mut placeholder_names: Vec<String> = Vec::new();
        for &i in &order {
            let n = &graph.nodes[i];
            if const_idx.contains_key(&n.name) || absorbed.contains(&n.name) {
                continue;
            }
            if let Op::Placeholder { .. } = n.op {
                placeholder_names.push(n.name.clone());
                continue;
            }
            let x_shape = |k: usize| -> Result<&Vec<usize>, GraphError> {
                let name = n.inputs.get(k).ok_or_else(|| {
                    invalid(&n.name, &format!("missing input {k}"))
                })?;
                shapes
                    .get(name)
                    .ok_or_else(|| GraphError::UnknownInput(n.name.clone(), name.clone()))
            };
            // Fused chain info (compute heads only).
            let (fused_bias, fused_act, tail) = match chains.get(&n.name) {
                Some((b, a, t)) => (b.clone(), *a, t.clone()),
                None => (None, Act::None, n.name.clone()),
            };
            let bias_idx = match &fused_bias {
                Some(bn) => Some(want_const(&const_idx, &n.name, bn)?),
                None => None,
            };
            let out_shape = bshape(&shapes[&tail]);
            let kind = match &n.op {
                Op::Conv2D { stride, padding } => {
                    let widx = want_const(&const_idx, &n.name, &n.inputs[1])?;
                    let w = &consts[widx];
                    let geom = ConvGeom::new(
                        &bshape(x_shape(0)?),
                        w.shape[0],
                        w.shape[1],
                        w.shape[3],
                        *stride,
                        *padding,
                    );
                    if w.sparsity() >= opts.sparse_threshold {
                        stats.sparse_convs += 1;
                        let rle = store.rle_with(
                            &format!("{}@rle{}", n.inputs[1], opts.splits),
                            || encode_conv(w, opts.splits),
                        );
                        // Pre-decode at plan build: the hot path never
                        // runs the runlength decoder (HPIPE bakes weight
                        // words into per-layer M20Ks the same way).
                        let packed = opts.packed.then(|| {
                            store.packed_rle_with(
                                &format!("{}@prle{}", n.inputs[1], opts.splits),
                                || sparse::pack_rle(&rle),
                            )
                        });
                        StepKind::SparseConv {
                            geom,
                            rle,
                            packed,
                            bias: bias_idx,
                            act: fused_act,
                        }
                    } else {
                        stats.dense_convs += 1;
                        let packed = opts.packed.then(|| {
                            store.packed_b_with(
                                &format!("{}@pb{}x{}", n.inputs[1], geom.patch_len(), geom.co),
                                || kernels::pack_b(w.as_slice(), geom.patch_len(), geom.co),
                            )
                        });
                        StepKind::DenseConv {
                            geom,
                            w: widx,
                            packed,
                            bias: bias_idx,
                            act: fused_act,
                        }
                    }
                }
                Op::DepthwiseConv2d { stride, padding } => {
                    let widx = want_const(&const_idx, &n.name, &n.inputs[1])?;
                    let w = &consts[widx];
                    let mult = w.shape[3];
                    let geom = ConvGeom::new(
                        &bshape(x_shape(0)?),
                        w.shape[0],
                        w.shape[1],
                        w.shape[2] * mult,
                        *stride,
                        *padding,
                    );
                    StepKind::Depthwise { geom, mult, w: widx, bias: bias_idx, act: fused_act }
                }
                Op::MatMul => {
                    let widx = want_const(&const_idx, &n.name, &n.inputs[1])?;
                    let w = &consts[widx];
                    let xs = x_shape(0)?;
                    // One GEMM over the whole batch's rows.
                    let (nrows, k, co) = (xs[0] * batch, w.shape[0], w.shape[1]);
                    if w.sparsity() >= opts.sparse_threshold {
                        stats.sparse_matmuls += 1;
                        let rle = store.rle_with(
                            &format!("{}@rleM{}", n.inputs[1], opts.splits),
                            || encode_matmul(w, opts.splits),
                        );
                        let packed = opts.packed.then(|| {
                            store.packed_rle_with(
                                &format!("{}@prleM{}", n.inputs[1], opts.splits),
                                || sparse::pack_rle(&rle),
                            )
                        });
                        StepKind::SparseMatMul {
                            n: nrows,
                            k,
                            co,
                            rle,
                            packed,
                            bias: bias_idx,
                            act: fused_act,
                        }
                    } else {
                        stats.dense_matmuls += 1;
                        let packed = opts.packed.then(|| {
                            store.packed_b_with(&format!("{}@pb{}x{}", n.inputs[1], k, co), || {
                                kernels::pack_b(w.as_slice(), k, co)
                            })
                        });
                        StepKind::DenseMatMul {
                            n: nrows,
                            k,
                            co,
                            w: widx,
                            packed,
                            bias: bias_idx,
                            act: fused_act,
                        }
                    }
                }
                Op::MaxPool { ksize, stride, padding } => {
                    let xs = bshape(x_shape(0)?);
                    let geom =
                        ConvGeom::new(&xs, ksize.0, ksize.1, xs[3], *stride, *padding);
                    StepKind::MaxPool { geom }
                }
                Op::BiasAdd => {
                    let bidx = want_const(&const_idx, &n.name, &n.inputs[1])?;
                    let b = consts[bidx].data.clone();
                    StepKind::Affine { ch: b.len(), a: None, b: Some(b), act: Act::None }
                }
                Op::Mul => {
                    let aidx = want_const(&const_idx, &n.name, &n.inputs[1])?;
                    let a = consts[aidx].data.clone();
                    StepKind::Affine { ch: a.len(), a: Some(a), b: None, act: Act::None }
                }
                Op::AddC => {
                    let bidx = want_const(&const_idx, &n.name, &n.inputs[1])?;
                    let b = consts[bidx].data.clone();
                    StepKind::Affine { ch: b.len(), a: None, b: Some(b), act: Act::None }
                }
                Op::FusedBatchNorm { epsilon } => {
                    // Fold the four parameter vectors into one affine at
                    // build time: a = γ/√(σ²+ε), b = β − μ·a.
                    let p = |k: usize| -> Result<&Tensor, GraphError> {
                        Ok(&consts[want_const(&const_idx, &n.name, &n.inputs[k])?])
                    };
                    let (scale, offset, mean, var) = (p(1)?, p(2)?, p(3)?, p(4)?);
                    let a: Vec<f32> = scale
                        .data
                        .iter()
                        .zip(&var.data)
                        .map(|(&s, &v)| s / (v + epsilon).sqrt())
                        .collect();
                    let b: Vec<f32> = offset
                        .data
                        .iter()
                        .zip(mean.data.iter().zip(&a))
                        .map(|(&o, (&m, &av))| o - m * av)
                        .collect();
                    StepKind::Affine { ch: a.len(), a: Some(a), b: Some(b), act: Act::None }
                }
                Op::Relu => StepKind::Unary { act: Act::Relu },
                Op::Relu6 => StepKind::Unary { act: Act::Relu6 },
                Op::Add => StepKind::Add,
                Op::Mean => {
                    let xs = x_shape(0)?;
                    // Per-image check (the interp oracle's global_mean
                    // reads batch 0 only); the plan's batch dim is
                    // handled by the kernel's per-image loop.
                    if xs[0] != 1 {
                        return Err(invalid(&n.name, "Mean expects per-image batch dim 1"));
                    }
                    StepKind::Mean { n: batch, h: xs[1], w: xs[2], c: xs[3] }
                }
                Op::Pad { pads } => {
                    let xs = x_shape(0)?;
                    let n = xs[0] * batch;
                    StepKind::Pad { n, h: xs[1], w: xs[2], c: xs[3], pads: *pads }
                }
                Op::Softmax => {
                    let xs = x_shape(0)?;
                    if xs.len() != 2 {
                        return Err(invalid(&n.name, "Softmax expects an [N, C] input"));
                    }
                    StepKind::Softmax { n: xs[0] * batch, c: xs[1] }
                }
                Op::Placeholder { .. } | Op::Const => unreachable!(),
            };
            let input_names: Vec<String> = match kind {
                StepKind::Add => vec![n.inputs[0].clone(), n.inputs[1].clone()],
                _ => vec![n.inputs[0].clone()],
            };
            protos.push(Proto {
                name: tail.clone(),
                out_name: tail,
                out_shape,
                input_names,
                kind,
            });
        }

        // ---- liveness + arena slot assignment ----
        let mut last_use: HashMap<String, usize> = HashMap::new();
        for (si, p) in protos.iter().enumerate() {
            for inp in &p.input_names {
                if !const_idx.contains_key(inp) {
                    last_use.insert(inp.clone(), si);
                }
            }
        }
        fn alloc(
            len: usize,
            slot_lens: &mut Vec<usize>,
            free: &mut HashMap<usize, Vec<usize>>,
        ) -> usize {
            if let Some(list) = free.get_mut(&len) {
                if let Some(s) = list.pop() {
                    return s;
                }
            }
            slot_lens.push(len);
            slot_lens.len() - 1
        }
        let mut slot_lens: Vec<usize> = Vec::new();
        let mut free: HashMap<usize, Vec<usize>> = HashMap::new();
        let mut slot_of: HashMap<String, usize> = HashMap::new();
        for name in &placeholder_names {
            let shape = bshape(&shapes[name]);
            let len = shape.iter().product();
            let slot = alloc(len, &mut slot_lens, &mut free);
            slot_of.insert(name.clone(), slot);
            feeds.push((name.clone(), slot, shape));
        }
        let resolve = |name: &String,
                       node: &str,
                       slot_of: &HashMap<String, usize>|
         -> Result<Src, GraphError> {
            if let Some(&c) = const_idx.get(name) {
                return Ok(Src::Const(c));
            }
            slot_of
                .get(name)
                .map(|&s| Src::Slot(s))
                .ok_or_else(|| GraphError::UnknownInput(node.to_string(), name.clone()))
        };
        // Per-image consts read by batched elementwise steps get tiled
        // across the batch; memoized so a const shared by several Adds
        // (or an output) is tiled once.
        let mut tiled: HashMap<usize, usize> = HashMap::new();
        let mut tile = |c: usize, consts: &mut Vec<Arc<Tensor>>| -> usize {
            *tiled.entry(c).or_insert_with(|| {
                consts.push(Arc::new(tile_batch(&consts[c], batch)));
                consts.len() - 1
            })
        };
        let mut steps: Vec<Step> = Vec::with_capacity(protos.len());
        for (si, p) in protos.into_iter().enumerate() {
            let mut inputs = p
                .input_names
                .iter()
                .map(|i| resolve(i, &p.name, &slot_of))
                .collect::<Result<Vec<_>, _>>()?;
            // A batched Add can read a (per-image) folded constant; tile
            // it across the batch so elementwise kernels line up.
            if batch > 1 && matches!(p.kind, StepKind::Add) {
                for src in inputs.iter_mut() {
                    if let Src::Const(c) = *src {
                        *src = Src::Const(tile(c, &mut consts));
                    }
                }
            }
            let out_len: usize = p.out_shape.iter().product();
            let out = alloc(out_len, &mut slot_lens, &mut free);
            slot_of.insert(p.out_name.clone(), out);
            // Free inputs whose last read was this step (outputs stay).
            let mut seen: Vec<&String> = Vec::new();
            for inp in &p.input_names {
                if last_use.get(inp) == Some(&si)
                    && !output_set.contains(inp)
                    && !seen.contains(&inp)
                {
                    seen.push(inp);
                    if let Some(&s) = slot_of.get(inp) {
                        free.entry(slot_lens[s]).or_default().push(s);
                    }
                }
            }
            steps.push(Step { name: p.name, out, inputs, kind: p.kind });
        }

        // ---- scratch sizing ----
        let mut scratch_len = 0usize;
        let mut acc_len = 0usize;
        for s in &steps {
            match &s.kind {
                // Packed dense paths stage A into MR-row panels (the
                // identity-patches case packs too — the pack IS the only
                // copy); the unpacked baseline keeps row-major im2col.
                StepKind::DenseConv { geom, packed: Some(_), .. } => {
                    scratch_len = scratch_len
                        .max(kernels::packed_a_len(geom.total_positions(), geom.patch_len()));
                }
                StepKind::DenseConv { geom, packed: None, .. }
                    if !geom.identity_patches() =>
                {
                    scratch_len = scratch_len.max(geom.patch_len() * geom.total_positions());
                }
                StepKind::DenseMatMul { n, k, packed: Some(_), .. } => {
                    scratch_len = scratch_len.max(kernels::packed_a_len(*n, *k));
                }
                StepKind::SparseConv { geom, .. } => {
                    scratch_len = scratch_len.max(geom.patch_len() * geom.total_positions());
                    acc_len = acc_len.max(geom.total_positions());
                }
                // K-major transpose scratch for the position-axis kernel.
                StepKind::SparseMatMul { n, k, packed: Some(_), .. } => {
                    scratch_len = scratch_len.max(k * n);
                }
                _ => {}
            }
        }

        // ---- outputs ----
        let mut outputs = Vec::with_capacity(graph.outputs.len());
        for name in &graph.outputs {
            let mut src = resolve(name, "<outputs>", &slot_of)?;
            let shape = shapes
                .get(name)
                .cloned()
                .ok_or_else(|| GraphError::UnknownInput("<outputs>".into(), name.clone()))?;
            // Constant outputs are tiled so every output of a batch-B
            // plan equals B sequential batch-1 runs concatenated.
            if batch > 1 {
                if let Src::Const(c) = src {
                    src = Src::Const(tile(c, &mut consts));
                }
            }
            outputs.push((src, bshape(&shape)));
        }

        stats.steps = steps.len();
        stats.arena_f32 = slot_lens.iter().sum();
        stats.scratch_f32 = scratch_len + acc_len;
        Ok(ExecutionPlan {
            steps,
            consts,
            shared_consts,
            slot_lens,
            scratch_len,
            acc_len,
            batch,
            feeds,
            outputs,
            stats,
        })
    }

    /// Batch dimension this plan was compiled for.
    pub fn batch(&self) -> usize {
        self.batch
    }

    pub fn stats(&self) -> PlanStats {
        self.stats
    }

    /// Bytes held in plan-*private* constants — the batch-tiled copies
    /// appended past the store-shared prefix. Together with
    /// [`Self::arena_bytes`] this is what an extra plan-family variant
    /// actually costs: O(arena), not O(weights).
    pub fn private_weight_bytes(&self) -> usize {
        self.consts[self.shared_consts..]
            .iter()
            .map(|t| t.data.len() * 4)
            .sum()
    }

    /// Bytes of per-context activation arena + kernel scratch.
    pub fn arena_bytes(&self) -> usize {
        (self.stats.arena_f32 + self.stats.scratch_f32) * 4
    }

    /// Allocate the per-run buffers once; reuse across runs for
    /// allocation-free steady state.
    pub fn new_context(&self) -> ExecContext {
        ExecContext {
            slots: self.slot_lens.iter().map(|&l| vec![0.0; l]).collect(),
            scratch: vec![0.0; self.scratch_len],
            acc: vec![0.0; self.acc_len],
        }
    }

    /// Execute into a reusable context. Allocation-free after the first
    /// call with a given context.
    pub fn run_with(
        &self,
        ctx: &mut ExecContext,
        feeds: &BTreeMap<String, Tensor>,
    ) -> Result<(), GraphError> {
        for (i, (name, _, shape)) in self.feeds.iter().enumerate() {
            let t = feeds.get(name).ok_or_else(|| {
                GraphError::Invalid(name.clone(), "missing feed".into())
            })?;
            if &t.shape != shape {
                return Err(GraphError::Shape(
                    name.clone(),
                    format!("feed shape {:?} != {:?}", t.shape, shape),
                ));
            }
            self.write_feed(ctx, i, &t.data)?;
        }
        self.execute_steps(ctx);
        Ok(())
    }

    /// Number of placeholder feeds; `feed_name(i)` gives the i-th name.
    pub fn num_feeds(&self) -> usize {
        self.feeds.len()
    }

    pub fn feed_name(&self, i: usize) -> &str {
        &self.feeds[i].0
    }

    /// Copy raw feed data straight into feed `i`'s arena slot — the
    /// zero-allocation path for callers that already hold a flat slice
    /// (length must match the placeholder's element count).
    pub fn write_feed(
        &self,
        ctx: &mut ExecContext,
        i: usize,
        data: &[f32],
    ) -> Result<(), GraphError> {
        let (name, slot, shape) = &self.feeds[i];
        let expect: usize = shape.iter().product();
        if data.len() != expect {
            return Err(GraphError::Shape(
                name.clone(),
                format!("feed length {} != shape {:?}", data.len(), shape),
            ));
        }
        ctx.slots[*slot].copy_from_slice(data);
        Ok(())
    }

    /// Run the plan's steps over whatever feed data is in the context
    /// (see [`Self::write_feed`]).
    pub fn execute_steps(&self, ctx: &mut ExecContext) {
        for step in &self.steps {
            self.exec_step(step, ctx);
        }
    }

    /// Borrow output `i` (data slice, shape) from a context after
    /// [`Self::run_with`].
    pub fn output<'a>(&'a self, ctx: &'a ExecContext, i: usize) -> (&'a [f32], &'a [usize]) {
        let (src, shape) = &self.outputs[i];
        let data: &[f32] = match *src {
            Src::Const(c) => &self.consts[c].data,
            Src::Slot(s) => &ctx.slots[s],
        };
        (data, shape)
    }

    pub fn num_outputs(&self) -> usize {
        self.outputs.len()
    }

    /// Convenience one-shot run: returns the graph outputs as tensors
    /// (matches `interp::run_outputs` output-for-output; the equivalence
    /// property test in `rust/tests/exec_equiv.rs` relies on this).
    pub fn run(&self, feeds: &BTreeMap<String, Tensor>) -> Result<Vec<Tensor>, GraphError> {
        let mut ctx = self.new_context();
        self.run_with(&mut ctx, feeds)?;
        Ok((0..self.outputs.len())
            .map(|i| {
                let (data, shape) = self.output(&ctx, i);
                Tensor::from_vec(shape, data.to_vec())
            })
            .collect())
    }

    fn exec_step(&self, step: &Step, ctx: &mut ExecContext) {
        let ExecContext { slots, scratch, acc } = ctx;
        let mut out = std::mem::take(&mut slots[step.out]);
        {
            let x = resolve_src(&self.consts, slots, step.inputs[0]);
            let bias = |b: &Option<usize>| -> Option<&[f32]> {
                b.map(|i| self.consts[i].as_slice())
            };
            match &step.kind {
                StepKind::DenseConv { geom, w, packed, bias: b, act } => match packed {
                    Some(pb) => kernels::conv2d_dense_packed(
                        x,
                        geom,
                        pb,
                        bias(b),
                        *act,
                        scratch,
                        &mut out,
                    ),
                    None => kernels::conv2d_dense(
                        x,
                        geom,
                        &self.consts[*w],
                        bias(b),
                        *act,
                        scratch,
                        &mut out,
                    ),
                },
                StepKind::SparseConv { geom, rle, packed, bias: b, act } => match packed {
                    Some(pr) => sparse::sparse_conv_packed(
                        x,
                        geom,
                        pr,
                        bias(b),
                        *act,
                        scratch,
                        &mut out,
                    ),
                    None => {
                        sparse::sparse_conv(x, geom, rle, bias(b), *act, scratch, acc, &mut out)
                    }
                },
                StepKind::Depthwise { geom, mult, w, bias: b, act } => {
                    kernels::depthwise_dense(
                        x,
                        geom,
                        *mult,
                        &self.consts[*w],
                        bias(b),
                        *act,
                        &mut out,
                    );
                }
                StepKind::DenseMatMul { n, k, co, w, packed, bias: b, act } => match packed {
                    Some(pb) => {
                        kernels::pack_a(x, *n, pb.k, scratch);
                        kernels::gemm_panels_bias_act(scratch, pb, *n, bias(b), *act, &mut out)
                    }
                    None => kernels::gemm_bias_act(
                        x,
                        self.consts[*w].as_slice(),
                        *n,
                        *k,
                        *co,
                        bias(b),
                        *act,
                        &mut out,
                    ),
                },
                StepKind::SparseMatMul { n, k, co, rle, packed, bias: b, act } => match packed {
                    Some(pr) => sparse::sparse_matmul_rows(
                        x,
                        *n,
                        *k,
                        *co,
                        pr,
                        bias(b),
                        *act,
                        scratch,
                        &mut out,
                    ),
                    None => sparse::sparse_matmul(x, *n, *k, *co, rle, bias(b), *act, &mut out),
                },
                StepKind::MaxPool { geom } => kernels::max_pool(x, geom, &mut out),
                StepKind::Affine { ch, a, b, act } => {
                    kernels::affine(
                        x,
                        *ch,
                        a.as_deref(),
                        b.as_deref(),
                        *act,
                        &mut out,
                    );
                }
                StepKind::Add => {
                    let y = resolve_src(&self.consts, slots, step.inputs[1]);
                    kernels::add(x, y, &mut out);
                }
                StepKind::Unary { act } => kernels::unary(x, *act, &mut out),
                StepKind::Mean { n, h, w, c } => {
                    kernels::global_mean(x, *n, *h, *w, *c, &mut out)
                }
                StepKind::Pad { n, h, w, c, pads } => {
                    kernels::pad(x, *n, *h, *w, *c, *pads, &mut out)
                }
                StepKind::Softmax { n, c } => kernels::softmax(x, *n, *c, &mut out),
            }
        }
        slots[step.out] = out;
    }

    /// Execute one step with an intra-stage worker team of `team`
    /// threads splitting the step's output rows — the software analog of
    /// raising `n_channel_splits` on the slowest stage (HPIPE Algorithm
    /// 1 gives the bottleneck layer more multipliers; we give it more
    /// cores). Only the M-decomposable packed kernels split (dense /
    /// sparse conv and matmul); every other step kind — and the PR 3
    /// baseline kernels — runs on the calling thread. Workers write
    /// disjoint output-row ranges and the per-element accumulation order
    /// is unchanged, so team execution is bit-identical to
    /// [`Self::exec_step`] (`rust/tests/exec_equiv.rs` asserts this).
    fn exec_step_team(&self, step: &Step, ctx: &mut ExecContext, team: usize) {
        if team <= 1 {
            return self.exec_step(step, ctx);
        }
        let bias =
            |b: &Option<usize>| -> Option<&[f32]> { b.map(|i| self.consts[i].as_slice()) };
        match &step.kind {
            StepKind::DenseConv { geom, packed: Some(pb), bias: b, act, .. } => {
                let ExecContext { slots, scratch, .. } = ctx;
                let mut out = std::mem::take(&mut slots[step.out]);
                {
                    let x = resolve_src(&self.consts, slots, step.inputs[0]);
                    let m = geom.total_positions();
                    if geom.identity_patches() {
                        kernels::pack_a(x, m, pb.k, scratch);
                    } else {
                        kernels::im2col_a(x, geom, scratch);
                    }
                    team_gemm_rows(&scratch[..], pb, m, bias(b), *act, team, &mut out[..m * geom.co]);
                }
                slots[step.out] = out;
            }
            StepKind::SparseConv { geom, packed: Some(pr), bias: b, act, .. } => {
                let ExecContext { slots, scratch, .. } = ctx;
                let mut out = std::mem::take(&mut slots[step.out]);
                {
                    let x = resolve_src(&self.consts, slots, step.inputs[0]);
                    let m = geom.total_positions();
                    kernels::im2col_t(x, geom, scratch);
                    team_sparse_rows(
                        &scratch[..],
                        m,
                        pr,
                        bias(b),
                        *act,
                        team,
                        &mut out[..m * geom.co],
                    );
                }
                slots[step.out] = out;
            }
            StepKind::DenseMatMul { n, packed: Some(pb), bias: b, act, .. } => {
                let ExecContext { slots, scratch, .. } = ctx;
                let mut out = std::mem::take(&mut slots[step.out]);
                {
                    let x = resolve_src(&self.consts, slots, step.inputs[0]);
                    kernels::pack_a(x, *n, pb.k, scratch);
                    team_gemm_rows(&scratch[..], pb, *n, bias(b), *act, team, &mut out[..*n * pb.n]);
                }
                slots[step.out] = out;
            }
            StepKind::SparseMatMul { n, k, co, packed: Some(pr), bias: b, act, .. } => {
                let ExecContext { slots, scratch, .. } = ctx;
                let mut out = std::mem::take(&mut slots[step.out]);
                {
                    let x = resolve_src(&self.consts, slots, step.inputs[0]);
                    // Same K-major transpose + position-axis kernel as the
                    // sparse conv team path — rows split across workers.
                    sparse::transpose_k_major(x, *n, *k, scratch);
                    team_sparse_rows(
                        &scratch[..],
                        *n,
                        pr,
                        bias(b),
                        *act,
                        team,
                        &mut out[..*n * *co],
                    );
                }
                slots[step.out] = out;
            }
            _ => self.exec_step(step, ctx),
        }
    }

    /// Names of executed steps in order (diagnostics / tests).
    pub fn step_names(&self) -> Vec<&str> {
        self.steps.iter().map(|s| s.name.as_str()).collect()
    }
}

/// Split a packed GEMM's output rows into `team` contiguous chunks, one
/// scoped worker thread per chunk. `ap` is the MR-row A-panel pack of
/// the whole row range; chunks are MR-aligned so every worker's range
/// starts on a panel boundary, and A-panels are independent in
/// [`kernels::gemm_panels_bias_act`], so workers share `ap` / `pb`
/// read-only and write disjoint `out` slices.
fn team_gemm_rows(
    ap: &[f32],
    pb: &kernels::PackedB,
    rows_total: usize,
    bias: Option<&[f32]>,
    act: Act,
    team: usize,
    out: &mut [f32],
) {
    use kernels::MR;
    let (k, co) = (pb.k, pb.n);
    let rows_per = rows_total.div_ceil(team).div_ceil(MR) * MR;
    std::thread::scope(|scope| {
        for (t, orows) in out[..rows_total * co].chunks_mut(rows_per * co).enumerate() {
            let m0 = t * rows_per; // multiple of MR: a panel boundary
            let rows = orows.len() / co;
            let asub = &ap[m0 * k..][..kernels::packed_a_len(rows, k)];
            scope.spawn(move || {
                kernels::gemm_panels_bias_act(asub, pb, rows, bias, act, orows);
            });
        }
    });
}

/// Split a packed sparse conv's output positions into `team` contiguous
/// ranges over the shared transposed patch matrix.
fn team_sparse_rows(
    patches_t: &[f32],
    m: usize,
    pr: &sparse::PackedRle,
    bias: Option<&[f32]>,
    act: Act,
    team: usize,
    out: &mut [f32],
) {
    let co = pr.co;
    let rows_per = m.div_ceil(team);
    std::thread::scope(|scope| {
        for (t, orows) in out[..m * co].chunks_mut(rows_per * co).enumerate() {
            let m0 = t * rows_per;
            let rows = orows.len() / co;
            scope.spawn(move || {
                sparse::sparse_packed_rows(patches_t, m, m0, m0 + rows, pr, bias, act, orows);
            });
        }
    });
}

fn resolve_src<'a>(consts: &'a [Arc<Tensor>], slots: &'a [Vec<f32>], s: Src) -> &'a [f32] {
    match s {
        Src::Const(i) => consts[i].as_slice(),
        Src::Slot(i) => &slots[i],
    }
}

/// Repeat a per-image constant `b` times along the leading dim, so it
/// lines up element-for-element with a batched activation slot.
fn tile_batch(t: &Tensor, b: usize) -> Tensor {
    let mut shape = if t.shape.is_empty() { vec![1] } else { t.shape.clone() };
    shape[0] *= b;
    let mut data = Vec::with_capacity(t.data.len() * b);
    for _ in 0..b {
        data.extend_from_slice(&t.data);
    }
    Tensor::from_vec(&shape, data)
}

/// Evaluate a node whose inputs are all constants, using the reference
/// interpreter's kernels. `None` for ops that are never folded.
fn fold_node(op: &Op, ins: &[&Tensor]) -> Option<Tensor> {
    use crate::interp as k;
    Some(match op {
        Op::Conv2D { stride, padding } => k::conv2d(ins[0], ins[1], *stride, *padding),
        Op::DepthwiseConv2d { stride, padding } => {
            k::depthwise_conv2d(ins[0], ins[1], *stride, *padding)
        }
        Op::MatMul => k::matmul(ins[0], ins[1]),
        Op::BiasAdd => k::bias_add(ins[0], ins[1]),
        Op::MaxPool { ksize, stride, padding } => {
            k::max_pool(ins[0], *ksize, *stride, *padding)
        }
        Op::Relu => k::map_unary(ins[0], |x| x.max(0.0)),
        Op::Relu6 => k::map_unary(ins[0], |x| x.clamp(0.0, 6.0)),
        Op::Add => k::zip_binary(ins[0], ins[1], |a, b| a + b),
        Op::Mean => k::global_mean(ins[0]),
        Op::FusedBatchNorm { epsilon } => {
            k::batch_norm(ins[0], ins[1], ins[2], ins[3], ins[4], *epsilon)
        }
        Op::Pad { pads } => k::pad(ins[0], *pads),
        Op::Mul => k::per_channel(ins[0], ins[1], |x, c| x * c),
        Op::AddC => k::per_channel(ins[0], ins[1], |x, c| x + c),
        Op::Softmax => k::softmax(ins[0]),
        Op::Placeholder { .. } | Op::Const => return None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Padding, Tensor};
    use crate::interp;
    use crate::nets::{tiny_cnn, NetConfig};
    use crate::sparsity::prune_graph;
    use crate::util::prop::assert_close;
    use crate::util::Rng;

    fn assert_matches_interp(g: &Graph, opts: &PlanOptions, seed: u64, tol: f32) {
        let plan = ExecutionPlan::build_with(g, opts).unwrap();
        let mut rng = Rng::new(seed);
        let feeds = g.random_feeds(&mut rng);
        let got = plan.run(&feeds).unwrap();
        let want = interp::run_outputs(g, &feeds).unwrap();
        assert_eq!(got.len(), want.len());
        for (a, b) in got.iter().zip(&want) {
            assert_eq!(a.shape, b.shape);
            assert_close(&a.data, &b.data, tol, tol).unwrap();
        }
    }

    #[test]
    fn tiny_cnn_dense_matches_interp() {
        let g = tiny_cnn(NetConfig::test_scale());
        assert_matches_interp(&g, &PlanOptions::dense_only(), 1, 1e-4);
    }

    #[test]
    fn tiny_cnn_sparse_matches_interp() {
        let mut g = tiny_cnn(NetConfig::test_scale());
        prune_graph(&mut g, 0.8);
        assert_matches_interp(&g, &PlanOptions::sparse_always(), 2, 1e-4);
        // multi-split encoding executes identically
        let opts = PlanOptions { splits: 4, ..PlanOptions::sparse_always() };
        assert_matches_interp(&g, &opts, 3, 1e-4);
    }

    #[test]
    fn fusion_reduces_steps_and_preserves_output() {
        let g = tiny_cnn(NetConfig::test_scale());
        let fused = ExecutionPlan::build(&g).unwrap();
        let unfused =
            ExecutionPlan::build_with(&g, &PlanOptions { fuse: false, ..Default::default() })
                .unwrap();
        assert!(fused.stats().fused_chains >= 3, "{:?}", fused.stats());
        assert!(fused.stats().steps < unfused.stats().steps);
        assert_matches_interp(&g, &PlanOptions::default(), 4, 1e-4);
    }

    #[test]
    fn arena_reuses_buffers() {
        let g = tiny_cnn(NetConfig::test_scale());
        let plan = ExecutionPlan::build(&g).unwrap();
        // Upper bound if every step had a private buffer:
        let private: usize = {
            let shapes = g.infer_shapes().unwrap();
            g.nodes
                .iter()
                .filter(|n| !matches!(n.op, Op::Const))
                .map(|n| shapes[&n.name].iter().product::<usize>())
                .sum()
        };
        assert!(
            plan.stats().arena_f32 < private,
            "arena {} !< private {}",
            plan.stats().arena_f32,
            private
        );
    }

    #[test]
    fn run_with_is_repeatable() {
        let mut g = tiny_cnn(NetConfig::test_scale());
        prune_graph(&mut g, 0.6);
        let plan = ExecutionPlan::build(&g).unwrap();
        let mut ctx = plan.new_context();
        let mut rng = Rng::new(9);
        let feeds_a = g.random_feeds(&mut rng);
        let feeds_b = g.random_feeds(&mut rng);
        plan.run_with(&mut ctx, &feeds_a).unwrap();
        let first: Vec<f32> = plan.output(&ctx, 0).0.to_vec();
        plan.run_with(&mut ctx, &feeds_b).unwrap();
        plan.run_with(&mut ctx, &feeds_a).unwrap();
        // context reuse must not leak state between runs
        assert_eq!(plan.output(&ctx, 0).0, &first[..]);
    }

    #[test]
    fn constant_folding_precomputes_const_subgraphs() {
        let mut g = Graph::new();
        let mut rng = Rng::new(5);
        g.op("input", Op::Placeholder { shape: vec![1, 4, 4, 2] }, &[]);
        g.constant("cx", Tensor::randn(&[1, 4, 4, 2], &mut rng, 1.0));
        g.constant("w", Tensor::randn(&[1, 1, 2, 2], &mut rng, 1.0));
        // const-only chain: conv(cx, w) -> relu -> folds entirely
        g.op(
            "cconv",
            Op::Conv2D { stride: (1, 1), padding: Padding::Same },
            &["cx", "w"],
        );
        g.op("crelu", Op::Relu, &["cconv"]);
        // live chain mixes the folded const back in
        g.op("sum", Op::Add, &["input", "crelu"]);
        g.outputs = vec!["sum".into()];
        let plan = ExecutionPlan::build(&g).unwrap();
        assert_eq!(plan.stats().folded_consts, 2, "{:?}", plan.stats());
        // only the Add executes at runtime
        assert_eq!(plan.stats().steps, 1);
        assert_matches_interp(&g, &PlanOptions::default(), 6, 1e-5);
    }

    #[test]
    fn missing_feed_is_error() {
        let g = tiny_cnn(NetConfig::test_scale());
        let plan = ExecutionPlan::build(&g).unwrap();
        assert!(plan.run(&BTreeMap::new()).is_err());
    }

    #[test]
    fn non_const_weights_rejected() {
        let mut g = Graph::new();
        g.op("x", Op::Placeholder { shape: vec![1, 4, 4, 2] }, &[]);
        g.op("wdyn", Op::Placeholder { shape: vec![1, 1, 2, 2] }, &[]);
        g.op(
            "conv",
            Op::Conv2D { stride: (1, 1), padding: Padding::Same },
            &["x", "wdyn"],
        );
        g.outputs = vec!["conv".into()];
        assert!(matches!(
            ExecutionPlan::build(&g),
            Err(GraphError::Invalid(_, _))
        ));
    }
}
