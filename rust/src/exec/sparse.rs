//! Sparse-aware convolution kernels that execute HPIPE's runlength-encoded
//! weight streams (§V-B of the paper).
//!
//! The hardware streams one `WeightEntry` per multiplier per cycle:
//! the runlength decoder advances the (k_y, c_i) row counter, the X-mux
//! picks the k_w position, and only *nonzero* weights ever reach a DSP.
//! The software analog is weight-stationary: every surviving weight is
//! broadcast across all output positions of its output channel, over the
//! transposed im2col buffer ([K, n·M], see [`super::kernels::im2col_t`])
//! so each axpy is contiguous over the whole batch — total work scales
//! with the nonzero count, exactly as in the zero-skipping PEs.
//!
//! # Plan-time pre-decode ([`PackedRle`], ISSUE 4)
//!
//! The hardware never "decodes" at runtime in any meaningful sense: the
//! weight buffer words sit in per-layer M20Ks in exactly the order the
//! PEs consume them. The PR 1–3 software kernels, by contrast, re-ran
//! the runlength decoder (split interleaving, gap accumulation, pad-entry
//! skipping) on every plan execution. [`pack_rle`] moves all of that to
//! **plan build time**: each stream is walked once through the shared
//! decoder ([`crate::sparsity::rle::ConvRle::nonzeros`] — the only
//! runlength decoder in the codebase) and flattened into plain
//! `(patch-row k, lane, value)` arrays. On the hot path the packed
//! kernels just stream those arrays — no branches, no counters, no pad
//! entries.
//!
//! The packed layout groups [`OCB`] consecutive output channels into a
//! *bundle* whose entries are sorted by patch row `k`: one patch-matrix
//! row load feeds up to `OCB` channel accumulators (the "several output
//! channels per patch-matrix pass" multi-accumulator scheme — the
//! software analog of a PE column sharing one activation broadcast), and
//! ascending-`k` order makes the patch-row walk sequential and
//! prefetch-friendly. [`sparse_packed_rows`] additionally tiles the
//! output positions in [`MT`]-wide blocks held in stack accumulators, so
//! the patch-matrix working set per pass is `K × MT` floats instead of
//! `K × n·M`, and so that an intra-stage worker team can take disjoint
//! position ranges of the same convolution (the software analog of
//! raising `n_channel_splits` on the slowest stage).
//!
//! Per output element the accumulation order is the bundle's entry order
//! — fixed at plan build, independent of batch, tile placement or team
//! split — so sparse results are *bit-identical* across batch sizes,
//! pipelines and worker teams (the equivalence suite relies on this).
//! The inner axpy goes through the `exec::isa` dispatch table (ISSUE 7),
//! and every tier's sparse axpy — including the FMA and NEON tables —
//! uses separate vector multiply and add instructions, so each output
//! element keeps the scalar rounding chain and the bit-identity extends
//! across *dispatch tiers* too: sparse results never depend on the CPU.
//!
//! The PR 3 stream-walking kernels ([`sparse_conv`], [`sparse_matmul`])
//! are kept as the benchmark baseline behind
//! `PlanOptions { packed: false, .. }`; they are the only runtime
//! consumers of the shared decoder, and only when that baseline is
//! explicitly requested.

use super::kernels::{im2col_t, Act, ConvGeom};
use crate::sparsity::rle::ConvRle;

/// Output channels per packed bundle (accumulator lanes per pass).
pub const OCB: usize = 4;
/// Output positions per accumulator tile (floats held on the stack per
/// lane; OCB·MT f32 accumulators ≈ 2 KiB).
pub const MT: usize = 128;

/// Plan-time pre-decoded RLE streams: every nonzero flattened to a
/// `(patch-row, lane, value)` triple, grouped into [`OCB`]-channel
/// bundles sorted by patch row. Built once per plan by [`pack_rle`];
/// never touched by the runlength decoder again.
#[derive(Clone, Debug)]
pub struct PackedRle {
    /// Output channels (bundles cover `[b*OCB, min((b+1)*OCB, co))`).
    pub co: usize,
    /// GEMM K dimension the patch rows index into (kh·kw·ci).
    pub k: usize,
    /// Entry range of bundle `b`: `starts[b]..starts[b+1]`.
    starts: Vec<usize>,
    /// Patch-row index of each entry: k = (ky·kw + kx)·ci + ic.
    ks: Vec<u32>,
    /// Lane (output channel − bundle base) of each entry.
    lanes: Vec<u8>,
    vals: Vec<f32>,
}

impl PackedRle {
    pub fn n_bundles(&self) -> usize {
        self.starts.len() - 1
    }

    /// Total pre-decoded nonzeros (equals the stream's real nonzeros).
    pub fn nonzeros(&self) -> usize {
        self.ks.len()
    }

    // Raw stream access (artifact serialization).

    pub fn starts(&self) -> &[usize] {
        &self.starts
    }

    pub fn ks(&self) -> &[u32] {
        &self.ks
    }

    pub fn lanes(&self) -> &[u8] {
        &self.lanes
    }

    pub fn vals(&self) -> &[f32] {
        &self.vals
    }

    /// Reassemble a `PackedRle` from stored parts (artifact load),
    /// validating every structural invariant the kernels rely on:
    /// equal-length entry arrays, a monotone `starts` covering all
    /// entries with one range per OCB-channel bundle, lanes inside the
    /// bundle width, and patch-row indices inside `k`. A violation
    /// means a corrupt artifact and is reported, never executed.
    pub fn from_parts(
        co: usize,
        k: usize,
        starts: Vec<usize>,
        ks: Vec<u32>,
        lanes: Vec<u8>,
        vals: Vec<f32>,
    ) -> Result<PackedRle, String> {
        let nnz = ks.len();
        if lanes.len() != nnz || vals.len() != nnz {
            return Err(format!(
                "PackedRle[{co}x{k}]: entry arrays disagree ({nnz} ks, {} lanes, {} vals)",
                lanes.len(),
                vals.len()
            ));
        }
        if starts.len() != co.div_ceil(OCB) + 1 {
            return Err(format!(
                "PackedRle[{co}x{k}]: {} bundle starts, expected {}",
                starts.len(),
                co.div_ceil(OCB) + 1
            ));
        }
        if starts.first() != Some(&0) || starts.last() != Some(&nnz) {
            return Err(format!("PackedRle[{co}x{k}]: starts do not span 0..{nnz}"));
        }
        if starts.windows(2).any(|w| w[0] > w[1]) {
            return Err(format!("PackedRle[{co}x{k}]: starts not monotone"));
        }
        if ks.iter().any(|&e| e as usize >= k) {
            return Err(format!("PackedRle[{co}x{k}]: patch-row index out of range"));
        }
        for b in 0..starts.len() - 1 {
            let ocs = (co - (b * OCB).min(co)).min(OCB);
            if lanes[starts[b]..starts[b + 1]].iter().any(|&l| (l as usize) >= ocs) {
                return Err(format!("PackedRle[{co}x{k}]: lane out of bundle {b} width"));
            }
        }
        Ok(PackedRle { co, k, starts, ks, lanes, vals })
    }
}

/// Pre-decode an RLE weight stream at plan build time. This is the only
/// place execution-bound streams meet the runlength decoder.
pub fn pack_rle(rle: &ConvRle) -> PackedRle {
    let (ci, kw, co) = (rle.ci, rle.kw, rle.co);
    let k_dim = rle.kh * kw * ci;
    let mut starts = vec![0usize];
    let mut ks: Vec<u32> = Vec::new();
    let mut lanes: Vec<u8> = Vec::new();
    let mut vals: Vec<f32> = Vec::new();
    let mut oc0 = 0usize;
    while oc0 < co {
        let ocs = (co - oc0).min(OCB);
        let mut entries: Vec<(u32, u8, f32)> = Vec::new();
        for lane in 0..ocs {
            for nz in rle.nonzeros(oc0 + lane) {
                let (ky, ic) = (nz.row / ci, nz.row % ci);
                let k = (ky * kw + nz.x) * ci + ic;
                entries.push((k as u32, lane as u8, nz.value));
            }
        }
        // (k, lane) is unique per entry, so this order — and therefore
        // every per-channel accumulation order — is deterministic.
        entries.sort_by_key(|&(k, lane, _)| (k, lane));
        for (k, lane, v) in entries {
            ks.push(k);
            lanes.push(lane);
            vals.push(v);
        }
        starts.push(ks.len());
        oc0 += ocs;
    }
    PackedRle { co, k: k_dim, starts, ks, lanes, vals }
}

/// Core of the packed sparse conv: accumulate output positions
/// `[m0, m1)` of every output channel from the pre-decoded streams.
///
/// `patches_t` is the K-major [K, m_total] transposed patch matrix of
/// the *whole* execution; `out_rows` holds rows `m0..m1` of the NHWC
/// output, i.e. `(m1 - m0) · co` floats. Workers of an intra-stage team
/// call this with disjoint `[m0, m1)` ranges and disjoint `out_rows`
/// slices; single-threaded callers pass the full range.
#[allow(clippy::too_many_arguments)] // kernel ABI: geometry + range + fused epilogue
pub fn sparse_packed_rows(
    patches_t: &[f32],
    m_total: usize,
    m0: usize,
    m1: usize,
    pr: &PackedRle,
    bias: Option<&[f32]>,
    act: Act,
    out_rows: &mut [f32],
) {
    sparse_packed_rows_on(
        super::isa::active(),
        patches_t,
        m_total,
        m0,
        m1,
        pr,
        bias,
        act,
        out_rows,
    );
}

/// [`sparse_packed_rows`] pinned to an explicit dispatch tier — the
/// entry point cross-tier equivalence tests use, since the active tier
/// is process-global and test binaries are multi-threaded.
#[allow(clippy::too_many_arguments)] // kernel ABI: geometry + range + fused epilogue
pub fn sparse_packed_rows_on(
    isa: &super::isa::Isa,
    patches_t: &[f32],
    m_total: usize,
    m0: usize,
    m1: usize,
    pr: &PackedRle,
    bias: Option<&[f32]>,
    act: Act,
    out_rows: &mut [f32],
) {
    let co = pr.co;
    debug_assert!(m1 <= m_total);
    debug_assert!(out_rows.len() >= (m1 - m0) * co);
    let mut t0 = m0;
    while t0 < m1 {
        let t1 = (t0 + MT).min(m1);
        let tw = t1 - t0;
        for b in 0..pr.n_bundles() {
            let oc0 = b * OCB;
            let ocs = (co - oc0).min(OCB);
            let mut acc = [[0.0f32; MT]; OCB];
            for (lane, accl) in acc.iter_mut().enumerate().take(ocs) {
                let init = bias.map_or(0.0, |bv| bv[oc0 + lane]);
                accl[..tw].fill(init);
            }
            let (s, e) = (pr.starts[b], pr.starts[b + 1]);
            let walk = pr.ks[s..e]
                .iter()
                .zip(&pr.lanes[s..e])
                .zip(&pr.vals[s..e]);
            for ((&k, &lane), &v) in walk {
                let prow = &patches_t[k as usize * m_total + t0..][..tw];
                let accl = &mut acc[lane as usize][..tw];
                // non-fused on every tier: bitwise across CPUs
                isa.sparse_axpy(v, prow, accl);
            }
            // Scatter the tile's lanes back to row-major NHWC.
            for (lane, accl) in acc.iter().enumerate().take(ocs) {
                for (t, &av) in accl[..tw].iter().enumerate() {
                    out_rows[(t0 - m0 + t) * co + oc0 + lane] = act.apply(av);
                }
            }
        }
        t0 = t1;
    }
}

/// Sparse Conv2D from pre-decoded streams (+ fused bias / activation),
/// over all `g.n` images: im2col_t once, then one [`sparse_packed_rows`]
/// pass over every output position. No runlength decoding happens here.
pub fn sparse_conv_packed(
    x: &[f32],
    g: &ConvGeom,
    pr: &PackedRle,
    bias: Option<&[f32]>,
    act: Act,
    patches_t: &mut [f32],
    out: &mut [f32],
) {
    crate::util::fault::point("kernel.sparse_conv", 0);
    debug_assert_eq!(pr.co, g.co);
    debug_assert_eq!(pr.k, g.patch_len());
    let m = g.total_positions();
    im2col_t(x, g, patches_t);
    sparse_packed_rows(patches_t, m, 0, m, pr, bias, act, out);
}

/// Transpose a row-major [n, ci] activation into the K-major [ci, n]
/// scratch layout [`sparse_packed_rows`] axpys over: `xt[k·n + i] =
/// x[i·ci + k]`. The matmul analog of [`im2col_t`], so sparse matmuls
/// ride the same vectorized position-axis kernel as sparse convs.
pub fn transpose_k_major(x: &[f32], n: usize, ci: usize, xt: &mut [f32]) {
    debug_assert!(x.len() >= n * ci);
    let xt = &mut xt[..ci * n];
    for (i, xrow) in x.chunks_exact(ci).enumerate().take(n) {
        for (k, &v) in xrow.iter().enumerate() {
            xt[k * n + i] = v;
        }
    }
}

/// Sparse MatMul through the position-axis tile kernel: transpose the
/// [n, ci] activation K-major into `xt`, then one [`sparse_packed_rows`]
/// pass over all `n` rows — vector lanes run across the batch's rows,
/// exactly like the conv path. Per-(row, channel) accumulation order is
/// the bundle entry order either way, so this is bit-identical to
/// [`sparse_matmul_packed`] (the row-major baseline, kept for callers
/// without transpose scratch) on every dispatch tier.
#[allow(clippy::too_many_arguments)] // kernel ABI: dims + scratch + fused epilogue
pub fn sparse_matmul_rows(
    x: &[f32],
    n: usize,
    ci: usize,
    co: usize,
    pr: &PackedRle,
    bias: Option<&[f32]>,
    act: Act,
    xt: &mut [f32],
    out: &mut [f32],
) {
    crate::util::fault::point("kernel.sparse_matmul", 0);
    debug_assert_eq!(pr.co, co);
    debug_assert_eq!(pr.k, ci);
    transpose_k_major(x, n, ci, xt);
    sparse_packed_rows(xt, n, 0, n, pr, bias, act, out);
}

/// Sparse MatMul from pre-decoded streams (+ fused bias / activation)
/// over `n` rows of `x` ([n, ci] row-major). The [`OCB`] lanes of each
/// bundle are the multi-accumulators: one pass over a row's entries
/// feeds up to OCB output channels while the row stays in L1. Callers
/// may hand disjoint row ranges (`x` / `out` sub-slices) to a worker
/// team — rows are independent. The hot path now prefers
/// [`sparse_matmul_rows`] (vector lanes across rows); this row-major
/// walk survives as the transpose-free baseline and oracle.
#[allow(clippy::too_many_arguments)] // kernel ABI: dims + fused epilogue
pub fn sparse_matmul_packed(
    x: &[f32],
    n: usize,
    ci: usize,
    co: usize,
    pr: &PackedRle,
    bias: Option<&[f32]>,
    act: Act,
    out: &mut [f32],
) {
    crate::util::fault::point("kernel.sparse_matmul", 0);
    debug_assert_eq!(pr.co, co);
    debug_assert_eq!(pr.k, ci);
    for b in 0..pr.n_bundles() {
        let oc0 = b * OCB;
        let ocs = (co - oc0).min(OCB);
        let (s, e) = (pr.starts[b], pr.starts[b + 1]);
        for i in 0..n {
            let xrow = &x[i * ci..][..ci];
            let mut acc = [0.0f32; OCB];
            for (lane, a) in acc.iter_mut().enumerate().take(ocs) {
                *a = bias.map_or(0.0, |bv| bv[oc0 + lane]);
            }
            let walk = pr.ks[s..e]
                .iter()
                .zip(&pr.lanes[s..e])
                .zip(&pr.vals[s..e]);
            for ((&k, &lane), &v) in walk {
                acc[lane as usize] += v * xrow[k as usize];
            }
            let orow = &mut out[i * co + oc0..][..ocs];
            for (o, &a) in orow.iter_mut().zip(&acc[..ocs]) {
                *o = act.apply(a);
            }
        }
    }
}

/// Sparse Conv2D (+ fused bias / activation) walking RLE weight streams
/// at runtime — the **PR 3 baseline kernel**, kept for the
/// packed-vs-baseline benchmark (`PlanOptions { packed: false, .. }`).
/// The production hot path uses [`sparse_conv_packed`] instead.
///
/// `patches_t` must hold at least `patch_len * total_positions`
/// elements, `acc` at least `total_positions`.
#[allow(clippy::too_many_arguments)] // kernel ABI: geometry + scratch + fused epilogue
pub fn sparse_conv(
    x: &[f32],
    g: &ConvGeom,
    rle: &ConvRle,
    bias: Option<&[f32]>,
    act: Act,
    patches_t: &mut [f32],
    acc: &mut [f32],
    out: &mut [f32],
) {
    debug_assert_eq!(rle.ci, g.ci);
    debug_assert_eq!(rle.co, g.co);
    let m = g.total_positions();
    im2col_t(x, g, patches_t);
    for oc in 0..g.co {
        let accv = &mut acc[..m];
        accv.fill(match bias {
            Some(b) => b[oc],
            None => 0.0,
        });
        for nz in rle.nonzeros(oc) {
            let (ky, ic) = (nz.row / g.ci, nz.row % g.ci);
            let k = (ky * g.kw + nz.x) * g.ci + ic;
            let prow = &patches_t[k * m..][..m];
            let v = nz.value;
            for (a, &p) in accv.iter_mut().zip(prow) {
                *a += v * p;
            }
        }
        // Scatter the accumulated output channel back to NHWC.
        for (mi, &a) in accv.iter().enumerate() {
            out[mi * g.co + oc] = act.apply(a);
        }
    }
}

/// Sparse MatMul (+ fused bias / activation) walking RLE streams of the
/// (Ci, Co) weight matrix at runtime — the **PR 3 baseline kernel**
/// (see [`sparse_conv`]); the hot path uses [`sparse_matmul_packed`].
#[allow(clippy::too_many_arguments)] // kernel ABI: dims + fused epilogue
pub fn sparse_matmul(
    x: &[f32],
    n: usize,
    ci: usize,
    co: usize,
    rle: &ConvRle,
    bias: Option<&[f32]>,
    act: Act,
    out: &mut [f32],
) {
    debug_assert_eq!(rle.ci, ci);
    debug_assert_eq!(rle.co, co);
    debug_assert_eq!(rle.kh, 1);
    debug_assert_eq!(rle.kw, 1);
    for oc in 0..co {
        let init = match bias {
            Some(b) => b[oc],
            None => 0.0,
        };
        for i in 0..n {
            out[i * co + oc] = init;
        }
        for nz in rle.nonzeros(oc) {
            let ic = nz.row;
            let v = nz.value;
            for i in 0..n {
                out[i * co + oc] += v * x[i * ci + ic];
            }
        }
        for i in 0..n {
            let o = &mut out[i * co + oc];
            *o = act.apply(*o);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Tensor;
    use crate::sparsity::prune::prune_tensor;
    use crate::sparsity::rle::{encode_conv, encode_matmul};
    use crate::util::prop::Cases;
    use crate::util::Rng;

    /// Naive reference matmul (ascending-k accumulation; zero weights
    /// contribute nothing, matching the packed kernels' skipped terms).
    fn naive_matmul(x: &[f32], w: &[f32], n: usize, ci: usize, co: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; n * co];
        for i in 0..n {
            for j in 0..co {
                let mut acc = 0.0f32;
                for k in 0..ci {
                    let wv = w[k * co + j];
                    if wv != 0.0 {
                        acc += x[i * ci + k] * wv;
                    }
                }
                out[i * co + j] = acc;
            }
        }
        out
    }

    #[test]
    fn packed_matmul_matches_naive_across_shapes_and_sparsity() {
        Cases::new(30).seed(0x5AC7).run(|rng, size| {
            // Odd shapes: co not a multiple of OCB, n crossing nothing.
            let n = 1 + size % 9;
            let ci = 1 + (size * 11 + rng.below(7)) % 67;
            let co = 1 + (size * 5 + rng.below(6)) % 23;
            let sparsity = *rng.choose(&[0.0, 0.5, 0.9]);
            let x = Tensor::randn(&[n, ci], rng, 1.0);
            let mut w = Tensor::randn(&[ci, co], rng, 1.0);
            prune_tensor(&mut w, sparsity);
            let rle = encode_matmul(&w, 1 + rng.below(3));
            let pr = pack_rle(&rle);
            assert_eq!(pr.nonzeros(), rle.total_nonzeros());
            let mut got = vec![0.0f32; n * co];
            sparse_matmul_packed(x.as_slice(), n, ci, co, &pr, None, Act::None, &mut got);
            let want = naive_matmul(x.as_slice(), w.as_slice(), n, ci, co);
            for (g, w_) in got.iter().zip(&want) {
                let tol = 1e-5 + 1e-5 * w_.abs();
                if (g - w_).abs() > tol {
                    return Err(format!(
                        "n={n} ci={ci} co={co} sp={sparsity}: {g} vs {w_}"
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn packed_conv_matches_baseline_kernel() {
        Cases::new(20).seed(0x5C0).run(|rng, size| {
            let (h, w) = (4 + size % 5, 4 + (size * 2) % 5);
            let ci = 1 + rng.below(6);
            let co = 1 + rng.below(9);
            let (kh, kw) = (1 + rng.below(3), 1 + rng.below(3));
            let n = 1 + rng.below(3);
            let sparsity = *rng.choose(&[0.0, 0.5, 0.9]);
            let shape = [n, h, w, ci];
            let x = Tensor::randn(&shape, rng, 1.0);
            let mut wt = Tensor::randn(&[kh, kw, ci, co], rng, 1.0);
            prune_tensor(&mut wt, sparsity);
            let g = ConvGeom::new(
                &shape,
                kh,
                kw,
                co,
                (1, 1),
                crate::graph::Padding::Same,
            );
            let rle = encode_conv(&wt, 1 + rng.below(3));
            let pr = pack_rle(&rle);
            let m = g.total_positions();
            let bias: Vec<f32> = (0..co).map(|_| rng.normal_f32(0.0, 0.5)).collect();
            let mut patches = vec![0.0f32; g.patch_len() * m];
            let mut got = vec![0.0f32; m * co];
            sparse_conv_packed(
                x.as_slice(),
                &g,
                &pr,
                Some(&bias),
                Act::Relu,
                &mut patches,
                &mut got,
            );
            let mut acc = vec![0.0f32; m];
            let mut want = vec![0.0f32; m * co];
            sparse_conv(
                x.as_slice(),
                &g,
                &rle,
                Some(&bias),
                Act::Relu,
                &mut patches,
                &mut acc,
                &mut want,
            );
            // Packed entries are k-sorted (stream order differs), so the
            // comparison is tolerance-based, not bitwise.
            for (a, b) in got.iter().zip(&want) {
                let tol = 1e-4 + 1e-4 * b.abs();
                if (a - b).abs() > tol {
                    return Err(format!("sp={sparsity} kh={kh} kw={kw}: {a} vs {b}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn packed_rows_split_matches_full_pass_bitwise() {
        // The intra-stage team splits one conv's output positions across
        // workers; per-element accumulation order is unchanged, so the
        // split must reproduce the full pass bit for bit — including
        // ranges that straddle MT tile boundaries.
        let mut rng = Rng::new(0x5B17);
        let (m, ci, co) = (MT + 37, 48usize, 10usize);
        let mut w = Tensor::randn(&[ci, co], &mut rng, 1.0);
        prune_tensor(&mut w, 0.7);
        let pr = pack_rle(&encode_matmul(&w, 2));
        // synthetic K-major patch matrix
        let patches: Vec<f32> = (0..ci * m).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let mut full = vec![0.0f32; m * co];
        sparse_packed_rows(&patches, m, 0, m, &pr, None, Act::None, &mut full);
        for split in [1usize, 40, MT, MT + 1] {
            let mut parts = vec![0.0f32; m * co];
            let mut m0 = 0usize;
            for chunk in parts.chunks_mut(split * co) {
                let rows = chunk.len() / co;
                sparse_packed_rows(&patches, m, m0, m0 + rows, &pr, None, Act::None, chunk);
                m0 += rows;
            }
            assert_eq!(full, parts, "split={split}");
        }
    }

    #[test]
    fn matmul_rows_matches_row_major_baseline_bitwise() {
        // The transposed position-axis path and the row-major walk visit
        // each (row, channel)'s bundle entries in the same order, so they
        // must agree bit for bit — on every dispatch tier (sparse axpys
        // never fuse). Odd co (not a multiple of OCB) and n straddling an
        // MT tile boundary on purpose.
        use crate::exec::isa;
        let mut rng = Rng::new(0x3A77);
        let (n, ci, co) = (MT + 9, 33usize, 11usize);
        let mut w = Tensor::randn(&[ci, co], &mut rng, 1.0);
        prune_tensor(&mut w, 0.8);
        let pr = pack_rle(&encode_matmul(&w, 2));
        let x = Tensor::randn(&[n, ci], &mut rng, 1.0);
        let bias: Vec<f32> = (0..co).map(|_| rng.normal_f32(0.0, 0.5)).collect();
        let mut want = vec![0.0f32; n * co];
        sparse_matmul_packed(x.as_slice(), n, ci, co, &pr, Some(&bias), Act::Relu, &mut want);
        let mut xt = vec![0.0f32; ci * n];
        for tier in isa::available() {
            transpose_k_major(x.as_slice(), n, ci, &mut xt);
            let mut got = vec![0.0f32; n * co];
            sparse_packed_rows_on(tier, &xt, n, 0, n, &pr, Some(&bias), Act::Relu, &mut got);
            assert_eq!(got, want, "tier {}", tier.name());
        }
    }
}
