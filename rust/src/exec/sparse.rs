//! Sparse-aware convolution kernels that execute HPIPE's runlength-encoded
//! weight streams directly (§V-B of the paper).
//!
//! The hardware streams one `WeightEntry` per multiplier per cycle:
//! the runlength decoder advances the (k_y, c_i) row counter, the X-mux
//! picks the k_w position, and only *nonzero* weights ever reach a DSP.
//! The software analog here is weight-stationary: for every decoded
//! nonzero we axpy its contribution across all output positions of its
//! output channel. With the transposed im2col buffer ([K, n·M], see
//! [`super::kernels::im2col_t`]) each axpy is contiguous over the whole
//! batch's output positions, so the per-MAC cost matches the dense GEMM
//! inner loop and total work scales with the nonzero count — zero
//! weights are skipped at runtime exactly as in the zero-skipping PEs,
//! and lockstep pad entries (value 0.0) only advance the row counter.
//! Batch is where the weight traffic amortizes: each RLE stream is
//! decoded **once per plan execution**, not once per image, and every
//! surviving weight is broadcast across all `n` activation planes.

use super::kernels::{im2col_t, Act, ConvGeom};
use crate::sparsity::rle::ConvRle;

/// Sparse Conv2D (+ fused bias / activation) from RLE weight streams,
/// over all `g.n` images in one weight-stream walk.
///
/// `patches_t` must hold at least `patch_len * total_positions`
/// elements, `acc` at least `total_positions`.
#[allow(clippy::too_many_arguments)] // kernel ABI: geometry + scratch + fused epilogue
pub fn sparse_conv(
    x: &[f32],
    g: &ConvGeom,
    rle: &ConvRle,
    bias: Option<&[f32]>,
    act: Act,
    patches_t: &mut [f32],
    acc: &mut [f32],
    out: &mut [f32],
) {
    debug_assert_eq!(rle.ci, g.ci);
    debug_assert_eq!(rle.co, g.co);
    let m = g.total_positions();
    im2col_t(x, g, patches_t);
    for oc in 0..g.co {
        let accv = &mut acc[..m];
        accv.fill(match bias {
            Some(b) => b[oc],
            None => 0.0,
        });
        for (split, stream) in rle.streams[oc].iter().enumerate() {
            // Runlength decode: the first entry's runlength is its
            // absolute split-local row, later entries advance from the
            // previous one (mirrors sparsity::rle::decode_conv).
            let mut local_row = 0usize;
            let mut first = true;
            for e in &stream.entries {
                if first {
                    local_row = e.runlength as usize;
                    first = false;
                } else {
                    local_row += e.runlength as usize;
                }
                if e.value == 0.0 {
                    continue; // lockstep / runlength pad entry
                }
                let row = local_row * rle.splits + split;
                let (ky, ic) = (row / g.ci, row % g.ci);
                let k = (ky * g.kw + e.x as usize) * g.ci + ic;
                let prow = &patches_t[k * m..][..m];
                let v = e.value;
                for (a, &p) in accv.iter_mut().zip(prow) {
                    *a += v * p;
                }
            }
        }
        // Scatter the accumulated output channel back to NHWC.
        for (mi, &a) in accv.iter().enumerate() {
            out[mi * g.co + oc] = act.apply(a);
        }
    }
}

/// Sparse MatMul (+ fused bias / activation) from RLE streams of the
/// (Ci, Co) weight matrix (encoded as a 1x1 conv, so rows are plain
/// input-channel indices). Weight-stationary like [`sparse_conv`]: each
/// stream is decoded once per execution and every surviving weight is
/// broadcast across all `n` rows (the batch), so decode cost amortizes
/// over the batch instead of being paid per image.
#[allow(clippy::too_many_arguments)] // kernel ABI: dims + fused epilogue
pub fn sparse_matmul(
    x: &[f32],
    n: usize,
    ci: usize,
    co: usize,
    rle: &ConvRle,
    bias: Option<&[f32]>,
    act: Act,
    out: &mut [f32],
) {
    debug_assert_eq!(rle.ci, ci);
    debug_assert_eq!(rle.co, co);
    debug_assert_eq!(rle.kh, 1);
    debug_assert_eq!(rle.kw, 1);
    for oc in 0..co {
        let init = match bias {
            Some(b) => b[oc],
            None => 0.0,
        };
        for i in 0..n {
            out[i * co + oc] = init;
        }
        for (split, stream) in rle.streams[oc].iter().enumerate() {
            let mut local_row = 0usize;
            let mut first = true;
            for e in &stream.entries {
                if first {
                    local_row = e.runlength as usize;
                    first = false;
                } else {
                    local_row += e.runlength as usize;
                }
                if e.value == 0.0 {
                    continue;
                }
                let ic = local_row * rle.splits + split;
                let v = e.value;
                for i in 0..n {
                    out[i * co + oc] += v * x[i * ci + ic];
                }
            }
        }
        for i in 0..n {
            let o = &mut out[i * co + oc];
            *o = act.apply(*o);
        }
    }
}
