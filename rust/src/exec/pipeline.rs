//! Layer-pipelined execution — the software twin of HPIPE's dataflow.
//!
//! HPIPE gives every layer its own hardware and runs all layers
//! concurrently; batch-1 throughput comes from *inter-layer* parallelism
//! (§III). [`PipelinePlan`] reproduces that execution model in software:
//! the steps of a compiled [`ExecutionPlan`] are statically partitioned
//! into `N` contiguous stages balanced by estimated per-step cycle cost
//! (the same per-layer model the cycle simulator's stations consume —
//! see [`ExecutionPlan::step_costs`]), one worker thread runs each
//! stage, and images stream between stages over bounded SPSC channels so
//! several images are in flight at once.
//!
//! The streamed unit is one *plan execution*: for a batch-B plan each
//! in-flight item is a whole B-image batch, boundary messages carry
//! batched tensors, and a cut is crossed once per batch rather than once
//! per image — the weight-amortization of the batched kernels composes
//! with the stage parallelism of the pipeline.
//!
//! The sequential executor's single shared buffer arena cannot hold more
//! than one in-flight item, so at every stage boundary the values that
//! cross the cut are copied into a *boundary message* — a small set of
//! double-buffered tensors that replace the shared arena at the cut.
//! Each stage owns a private context holding only the arena slots its
//! steps touch (stage-local arena); build-time debug asserts verify that
//! no step reads a slot that neither its own stage produced nor a
//! boundary message delivered.
//!
//! Backpressure mirrors the paper's bounded line buffers: each cut owns
//! [`PIPE_DEPTH`] boundary messages recycled through a return channel, so
//! a fast producer stage blocks once both buffers are outstanding.
//!
//! # Worker lifetimes: scoped vs persistent
//!
//! By default workers are scoped to each `run_*` call: a batch pays one
//! thread spawn and one stage-context allocation per stage, amortized
//! across its images, and the pipeline needs no `'static` plumbing or
//! shutdown protocol. [`PipelinePlan::enable_persistent_pool`] switches
//! `run_batch` to **persistent stage workers**: one thread per stage
//! spawned once, parked on a per-stage job channel between calls, with
//! the stage context (warm buffers) and the inter-stage boundary
//! channels surviving across batches — the per-run spawn cost
//! disappears, which is what lets breaker/recovery probes stay cheap.
//! Fault isolation changes shape but not contract: a scoped worker
//! aborts a run by dropping its channels, a persistent worker instead
//! records the fault and keeps forwarding *abort-flagged* boundary
//! messages so every stage still processes exactly `n` items per job
//! and the channels stay aligned for the next call (and a faulted
//! worker rebuilds its context, so a retry sees pristine buffers).
//! `run_stream` always uses scoped workers.
//!
//! # Intra-stage worker teams
//!
//! When layers outnumber stages unevenly, the balance DP can only cut at
//! step boundaries and one stage dominates the interval. HPIPE's answer
//! is `n_channel_splits`: give the slowest layer more multipliers until
//! stages re-balance (Algorithm 1). The software analog here is a
//! **worker team** ([`PipelinePlan::from_plan_team`]): the conv / matmul
//! steps of the *dominant* stage (argmax of the modeled stage costs) are
//! executed with their output rows split across `team` scoped threads
//! (`ExecutionPlan::exec_step_team`), shrinking the bottleneck stage's
//! wall time instead of its step count. `team == 1` (the default) is
//! exactly the PR 3 single-thread-per-stage behavior; any team size
//! produces bit-identical outputs because workers write disjoint row
//! ranges with unchanged per-element accumulation order — dense team
//! splits land on MR-panel boundaries of the packed A stream, and the
//! `exec::isa` dispatch tiers preserve that order too (sparse kernels on
//! every tier, dense on every non-fused tier), so team × pipeline × SIMD
//! tier all compose without moving a result bit.

use super::profile::StepProfile;
use super::{ConvGeom, ExecContext, ExecutionPlan, PlanOptions, Src, Step, StepKind};
use crate::arch::StageGeometry;
use crate::compile::throughput::{stage_cycles, WeightSummary, LINE_OVERHEAD};
use crate::graph::{Graph, GraphError, Op, Padding, Tensor};
use crate::util::partition::{partition_min_bottleneck, range_costs};
use crate::util::timer::{epoch_ns, ScopedNs};
use std::collections::{BTreeMap, BTreeSet};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

/// Boundary messages in flight per cut: double buffering, exactly like
/// the two-deep stage-boundary line buffers the simulator models.
pub const PIPE_DEPTH: usize = 2;

/// One boundary handoff: the arena slots crossing a cut, copied out of
/// the producer stage's context for one image. `abort` is the
/// persistent-pool fault protocol: a faulted stage keeps the item
/// stream aligned by forwarding messages flagged abort (carrying no
/// data) instead of dropping its channels.
struct Msg {
    img: usize,
    abort: bool,
    bufs: Vec<Vec<f32>>,
}

/// A panic caught inside one stage worker, reported as data instead of
/// unwinding across the thread scope: the stage that faulted, the item
/// (plan execution) it was processing, and the rendered panic message.
/// Converts into [`GraphError::StageFault`] at the `run_*` boundary.
#[derive(Clone, Debug)]
pub struct StageFault {
    pub stage: usize,
    pub item: usize,
    pub msg: String,
}

impl From<StageFault> for GraphError {
    fn from(f: StageFault) -> GraphError {
        GraphError::StageFault { stage: f.stage, item: f.item, msg: f.msg }
    }
}

/// First fault wins: once a stage faults, its dropped channels cascade
/// clean shutdown through the neighbors, and any later fault is an echo
/// of that cascade, not the cause.
fn record_fault(
    slot: &Mutex<Option<StageFault>>,
    stage: usize,
    item: usize,
    payload: Box<dyn std::any::Any + Send>,
) {
    let mut guard = slot.lock().unwrap_or_else(|e| e.into_inner());
    if guard.is_none() {
        *guard = Some(StageFault {
            stage,
            item,
            msg: crate::util::fault::panic_message(payload.as_ref()),
        });
    }
}

fn conv_geo(g: &ConvGeom) -> StageGeometry {
    StageGeometry {
        in_w: g.w,
        in_c: g.ci,
        out_w: g.wo,
        out_h: g.ho,
        out_c: g.co,
        kh: g.kh,
        kw: g.kw,
        stride: g.stride.0,
    }
}

impl ExecutionPlan {
    /// Estimated cycles per step, for pipeline balancing.
    ///
    /// Compute steps (conv / depthwise / matmul / pool) reuse the
    /// compile-side per-layer cycle model (`compile::throughput`) — the
    /// same numbers the cycle simulator's stations run on — so sparse
    /// layers weigh less than dense ones, exactly as their software
    /// kernels do. Steps already carrying RLE streams are charged the
    /// encoder's real lock-step stream lengths. Element-wise streaming
    /// steps have no channel-parallel hardware analog in software, so
    /// they are charged one cycle per output element; they are noise
    /// next to any convolution either way.
    pub fn step_costs(&self) -> Vec<u64> {
        self.steps.iter().map(|s| self.step_cost(s)).collect()
    }

    fn step_cost(&self, step: &Step) -> u64 {
        let elems = |slot: usize| self.slot_lens[slot] as u64;
        // The cycle model is per image; batched steps do the whole
        // batch's work per execution, so model-based costs scale by the
        // geometry's batch dim (element-count costs are already batched
        // through the slot lengths / stored dims).
        match &step.kind {
            StepKind::DenseConv { geom, w, .. } => {
                let summary = WeightSummary::from_conv(&self.consts[*w]);
                let op = Op::Conv2D { stride: geom.stride, padding: Padding::Same };
                geom.n as u64 * stage_cycles(&op, &conv_geo(geom), 1, Some(&summary), true)
            }
            StepKind::SparseConv { geom, rle, .. } => {
                (geom.n * geom.ho) as u64 * (rle.total_cycles() as u64 + LINE_OVERHEAD)
            }
            StepKind::Depthwise { geom, .. } => {
                let op = Op::DepthwiseConv2d { stride: geom.stride, padding: Padding::Same };
                geom.n as u64 * stage_cycles(&op, &conv_geo(geom), 1, None, true)
            }
            StepKind::DenseMatMul { n, k, co, w, .. } => {
                let summary = WeightSummary::from_matmul(&self.consts[*w]);
                let geo = StageGeometry {
                    in_w: *k,
                    in_c: *k,
                    out_w: *co,
                    out_h: *n,
                    out_c: *co,
                    kh: 1,
                    kw: 1,
                    stride: 1,
                };
                // stage_cycles charges one weight pass regardless of row
                // count; `n` holds batch × rows, so scale like the
                // sparse arm below does.
                *n as u64 * stage_cycles(&Op::MatMul, &geo, 1, Some(&summary), true)
            }
            StepKind::SparseMatMul { n, rle, .. } => {
                *n as u64 * (rle.total_cycles() as u64 + LINE_OVERHEAD)
            }
            StepKind::MaxPool { geom } => {
                let op = Op::MaxPool {
                    ksize: (geom.kh, geom.kw),
                    stride: geom.stride,
                    padding: Padding::Same,
                };
                geom.n as u64 * stage_cycles(&op, &conv_geo(geom), 1, None, true)
            }
            StepKind::Mean { n, h, w, c } => (n * h * w * c) as u64 + LINE_OVERHEAD,
            StepKind::Softmax { n, c } => (n * c) as u64 + LINE_OVERHEAD,
            StepKind::Affine { .. }
            | StepKind::Add
            | StepKind::Unary { .. }
            | StepKind::Pad { .. } => elems(step.out) + LINE_OVERHEAD,
        }
    }
}

/// Read/write history of one arena slot across the plan's step sequence.
/// Feeds count as writes at step −1; graph outputs as reads at step `n`.
#[derive(Default)]
struct SlotUse {
    writes: Vec<i64>,
    reads: Vec<i64>,
}

impl SlotUse {
    /// The step whose write a read at `r` observes.
    fn producer(&self, r: i64) -> Option<i64> {
        self.writes.iter().copied().filter(|&w| w < r).max()
    }

    /// True when the value in this slot at cut `c` is still needed by a
    /// step (or output) at or after `c`.
    fn live_across(&self, c: i64) -> bool {
        self.reads
            .iter()
            .any(|&r| r >= c && matches!(self.producer(r), Some(w) if w < c))
    }
}

fn slot_uses(plan: &ExecutionPlan) -> Vec<SlotUse> {
    let mut uses: Vec<SlotUse> = Vec::with_capacity(plan.slot_lens.len());
    uses.resize_with(plan.slot_lens.len(), SlotUse::default);
    for (_, slot, _) in &plan.feeds {
        uses[*slot].writes.push(-1);
    }
    for (i, step) in plan.steps.iter().enumerate() {
        for src in &step.inputs {
            if let Src::Slot(s) = *src {
                uses[s].reads.push(i as i64);
            }
        }
        uses[step.out].writes.push(i as i64);
    }
    let end = plan.steps.len() as i64;
    for (src, _) in &plan.outputs {
        if let Src::Slot(s) = *src {
            uses[s].reads.push(end);
        }
    }
    uses
}

/// A statically partitioned, multi-threaded pipeline over an
/// [`ExecutionPlan`] (see the module docs for the execution model).
pub struct PipelinePlan {
    /// Everything immutable after construction, shared with persistent
    /// pool workers (scoped workers borrow it; pool workers hold the
    /// `Arc` so they can outlive a single `run_*` call).
    shared: Arc<PipeShared>,
    /// Inter-run idle accounting: time between one `run_*` call's last
    /// stage-exit and the next call's first stage-entry. Shareable
    /// across a model's plan family ([`Self::share_idle_tracker`]) so a
    /// tail routed through a smaller variant keeps the fabric "fed".
    idle: Arc<IdleTracker>,
    /// Persistent stage workers ([`Self::enable_persistent_pool`]);
    /// `None` = scoped workers per call. The mutex also serializes
    /// pooled `run_batch` calls (one job in flight at a time).
    pool: Mutex<Option<Pool>>,
}

/// The immutable cut of a [`PipelinePlan`]: the plan, its partition,
/// and the per-stage activity counters (atomics, so "immutable" here
/// means structurally). Shared by reference with scoped workers and by
/// `Arc` with persistent pool workers.
struct PipeShared {
    plan: ExecutionPlan,
    /// Half-open step ranges, one per stage, in plan order.
    ranges: Vec<(usize, usize)>,
    /// Estimated cycle cost of each stage (sum of its step costs).
    stage_costs: Vec<u64>,
    /// `xfer[j]`: arena slots whose values cross the cut between stage
    /// `j` and `j + 1` (sorted).
    xfer: Vec<Vec<usize>>,
    /// Arena slots each stage's private context allocates (sorted).
    stage_slots: Vec<Vec<usize>>,
    /// Per-stage (scratch, acc) sizes — sized to the stage's own steps.
    stage_scratch: Vec<(usize, usize)>,
    /// Intra-stage worker-team size for the dominant stage's conv /
    /// matmul steps; 1 = exact PR 3 behavior (no splitting).
    team: usize,
    /// Plan-global indices of the steps executed with the worker team
    /// (the splittable steps of the bottleneck stage; empty if team==1).
    team_steps: Vec<usize>,
    /// Per-stage busy / stall / items counters, accumulated across every
    /// `run_*` call (see [`PipelinePlan::stage_metrics`]).
    counters: Vec<StageCounters>,
}

/// One pooled `run_batch` call, broadcast to every persistent stage
/// worker. The input is `Arc`-shared (workers are `'static`, so they
/// cannot borrow the caller's slice); the fault slot and abort flag are
/// per-job so one call's fault never bleeds into the next.
#[derive(Clone)]
struct Job {
    groups: usize,
    per_group: usize,
    input: Arc<Vec<f32>>,
    fault: Arc<Mutex<Option<StageFault>>>,
    abort: Arc<AtomicBool>,
}

/// Persistent stage workers: one thread per stage except the last
/// (which stays on the calling thread, warm context included), parked
/// on `job_txs` between calls. Dropping the pool closes the job
/// channels, which is the worker shutdown signal.
struct Pool {
    job_txs: Vec<SyncSender<Job>>,
    /// The caller-side endpoints of the final cut.
    last_data_rx: Receiver<Msg>,
    last_recycle_tx: SyncSender<Msg>,
    /// The final stage's warm context (caller thread).
    last_ctx: ExecContext,
    workers: Vec<JoinHandle<()>>,
}

impl Drop for Pool {
    fn drop(&mut self) {
        // closing the job channels is the shutdown signal: workers park
        // in `job_rx.recv()` between jobs and exit on disconnect
        self.job_txs.clear();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Gap accounting between pipeline runs. The per-stage busy/stall
/// counters only see time *inside* a `run_*` call; the serving-level
/// stall — the pipeline sitting empty between one batch's last
/// stage-exit and the next batch's first stage-entry — lives here.
/// Timestamps are [`epoch_ns`] values (`Instant`s cannot live in
/// atomics); `last_exit_ns == 0` means no run has completed yet, so the
/// window before the first batch is never charged as idle.
#[derive(Default)]
struct IdleTracker {
    last_exit_ns: AtomicU64,
    idle_ns: AtomicU64,
}

/// Cumulative per-stage activity counters. `busy` covers step execution,
/// `stall` covers time blocked on channel receives (waiting for an
/// upstream item or for a downstream stage to recycle a boundary
/// buffer); copies and sends in between are uncounted noise.
#[derive(Default)]
struct StageCounters {
    busy: AtomicU64,
    stall: AtomicU64,
    items: AtomicU64,
}

/// Snapshot of one stage's cumulative activity (see
/// [`PipelinePlan::stage_metrics`]). Occupancy — busy over busy+stall —
/// is the software twin of a hardware stage's duty cycle: a perfectly
/// balanced pipeline keeps every stage near 1.0, and the tuner's cut
/// quality shows up directly here.
#[derive(Clone, Copy, Debug, Default)]
pub struct StageMetrics {
    pub busy_ns: u64,
    pub stall_ns: u64,
    pub items: u64,
}

impl StageMetrics {
    /// Fraction of accounted time this stage spent executing steps.
    pub fn occupancy(&self) -> f64 {
        let total = self.busy_ns + self.stall_ns;
        if total == 0 {
            return 0.0;
        }
        self.busy_ns as f64 / total as f64
    }
}

impl PipelinePlan {
    /// Build a plan and partition it into (at most) `stages` stages.
    pub fn build(
        graph: &Graph,
        opts: &PlanOptions,
        stages: usize,
    ) -> Result<PipelinePlan, GraphError> {
        Ok(PipelinePlan::from_plan(
            ExecutionPlan::build_with(graph, opts)?,
            stages,
        ))
    }

    /// [`Self::build`] with an intra-stage worker team for the dominant
    /// stage (see [`Self::from_plan_team`]).
    pub fn build_team(
        graph: &Graph,
        opts: &PlanOptions,
        stages: usize,
        team: usize,
    ) -> Result<PipelinePlan, GraphError> {
        Ok(PipelinePlan::from_plan_team(
            ExecutionPlan::build_with(graph, opts)?,
            stages,
            team,
        ))
    }

    /// Partition an existing plan into (at most) `stages` stages. The
    /// stage count is clamped to the number of steps; a 1-stage pipeline
    /// degenerates to sequential execution on the calling thread.
    pub fn from_plan(plan: ExecutionPlan, stages: usize) -> PipelinePlan {
        PipelinePlan::from_plan_team(plan, stages, 1)
    }

    /// [`Self::from_plan`] plus an intra-stage worker team: when
    /// `team > 1`, the cost model's dominant stage (argmax of the
    /// balanced stage costs) executes its conv / matmul steps with their
    /// output rows split across `team` scoped worker threads — the
    /// software analog of raising `n_channel_splits` on the slowest
    /// stage. With `stages == 1` the single stage is trivially dominant,
    /// so every splittable step runs on the team (data-parallel
    /// sequential execution). `team == 1` is exactly PR 3 behavior.
    pub fn from_plan_team(plan: ExecutionPlan, stages: usize, team: usize) -> PipelinePlan {
        let costs = plan.step_costs();
        PipelinePlan::from_costs(plan, &costs, stages, team)
    }

    /// Profile-guided construction: stage cuts come from *measured*
    /// per-step wall times ([`StepProfile`], captured by
    /// [`super::profile::profile_plan`]) instead of the compile-side
    /// cycle model — the software form of re-running Algorithm 1 on
    /// observed layer behavior. The dominant stage (and therefore the
    /// worker team's target) is the stage that measured slowest, not the
    /// one the model predicted. Panics if the profile was captured on a
    /// plan with a different step count (profile / plan mismatch).
    pub fn from_profile(
        plan: ExecutionPlan,
        profile: &StepProfile,
        stages: usize,
        team: usize,
    ) -> PipelinePlan {
        assert_eq!(
            profile.costs_ns.len(),
            plan.steps.len(),
            "StepProfile has {} step costs but the plan has {} steps",
            profile.costs_ns.len(),
            plan.steps.len()
        );
        PipelinePlan::from_costs(plan, &profile.costs_ns, stages, team)
    }

    /// Rebuild a pipeline from a stored per-step cost vector — the
    /// artifact-cache restore path: a saved artifact records the costs
    /// that produced its cuts (model-driven or measured), and reloading
    /// replays them through the same partition DP, reproducing the
    /// exact stage ranges and team placement without re-profiling.
    /// Panics if `costs` was captured on a plan with a different step
    /// count (the artifact layer validates before calling).
    pub fn from_static_costs(
        plan: ExecutionPlan,
        costs: &[u64],
        stages: usize,
        team: usize,
    ) -> PipelinePlan {
        assert_eq!(
            costs.len(),
            plan.steps.len(),
            "stored cost vector has {} entries but the plan has {} steps",
            costs.len(),
            plan.steps.len()
        );
        PipelinePlan::from_costs(plan, costs, stages, team)
    }

    /// Shared core of the model-driven and profile-guided constructors:
    /// cut the plan by an arbitrary per-step `u64` cost vector. The cost
    /// source only moves the cuts and the team's target stage — per-item
    /// results are bit-identical to the sequential plan for *any* cost
    /// vector (`rust/tests/exec_equiv.rs` pins this invariance).
    fn from_costs(plan: ExecutionPlan, costs: &[u64], stages: usize, team: usize) -> PipelinePlan {
        let ranges = partition_min_bottleneck(costs, stages.max(1));
        let k = ranges.len();
        let stage_costs = range_costs(costs, &ranges);

        let uses = slot_uses(&plan);
        let xfer: Vec<Vec<usize>> = (1..k)
            .map(|j| {
                let c = ranges[j].0 as i64;
                (0..plan.slot_lens.len())
                    .filter(|&s| uses[s].live_across(c))
                    .collect()
            })
            .collect();

        // Stage-local arena: each stage allocates only the slots its
        // steps touch plus its boundary slots (and feeds / outputs at
        // the ends of the pipeline).
        let mut stage_slots: Vec<Vec<usize>> = Vec::with_capacity(k);
        let mut stage_scratch: Vec<(usize, usize)> = Vec::with_capacity(k);
        for (j, &(a, b)) in ranges.iter().enumerate() {
            let mut slots: BTreeSet<usize> = BTreeSet::new();
            if j == 0 {
                slots.extend(plan.feeds.iter().map(|(_, s, _)| *s));
            }
            if j > 0 {
                slots.extend(xfer[j - 1].iter().copied());
            }
            if j + 1 < k {
                slots.extend(xfer[j].iter().copied());
            }
            if j + 1 == k {
                slots.extend(plan.outputs.iter().filter_map(|(src, _)| match *src {
                    Src::Slot(s) => Some(s),
                    Src::Const(_) => None,
                }));
            }
            let (mut scratch, mut acc) = (0usize, 0usize);
            for step in &plan.steps[a..b] {
                slots.insert(step.out);
                for src in &step.inputs {
                    if let Src::Slot(s) = *src {
                        slots.insert(s);
                    }
                }
                match &step.kind {
                    StepKind::DenseConv { geom, .. } if !geom.identity_patches() => {
                        scratch = scratch.max(geom.patch_len() * geom.total_positions());
                    }
                    StepKind::SparseConv { geom, .. } => {
                        scratch = scratch.max(geom.patch_len() * geom.total_positions());
                        acc = acc.max(geom.total_positions());
                    }
                    _ => {}
                }
            }
            stage_slots.push(slots.into_iter().collect());
            stage_scratch.push((scratch, acc));
        }

        // Stage-locality invariants (the arena-reentrancy audit): every
        // value a stage reads was produced in-stage, fed in (stage 0),
        // or delivered by the incoming boundary; every outgoing boundary
        // value exists in the sending stage's context.
        #[cfg(debug_assertions)]
        for (j, &(a, b)) in ranges.iter().enumerate() {
            for (i, step) in plan.steps[a..b].iter().enumerate() {
                for src in &step.inputs {
                    if let Src::Slot(s) = *src {
                        let r = (a + i) as i64;
                        let w = uses[s].producer(r).unwrap_or(i64::MIN);
                        let local = w >= a as i64
                            || (j == 0 && w == -1)
                            || (j > 0 && xfer[j - 1].contains(&s));
                        debug_assert!(
                            local,
                            "step '{}' reads slot {s} that is not stage-local to stage {j}",
                            step.name
                        );
                    }
                }
            }
            if j + 1 < k {
                for &s in &xfer[j] {
                    debug_assert!(
                        stage_slots[j].contains(&s),
                        "boundary slot {s} missing from stage {j}'s arena"
                    );
                }
            }
        }

        // Intra-stage team: mark the splittable (packed conv / matmul)
        // steps of the stage the cost model says dominates.
        let team = team.max(1);
        let mut team_steps: Vec<usize> = Vec::new();
        if team > 1 {
            let bottleneck = stage_costs
                .iter()
                .enumerate()
                .max_by_key(|&(_, &c)| c)
                .map(|(j, _)| j)
                .unwrap_or(0);
            let (a, b) = ranges[bottleneck];
            for (i, step) in plan.steps[a..b].iter().enumerate() {
                let splittable = matches!(
                    step.kind,
                    StepKind::DenseConv { packed: Some(_), .. }
                        | StepKind::SparseConv { packed: Some(_), .. }
                        | StepKind::DenseMatMul { packed: Some(_), .. }
                        | StepKind::SparseMatMul { packed: Some(_), .. }
                );
                if splittable {
                    team_steps.push(a + i);
                }
            }
        }

        let counters = (0..k).map(|_| StageCounters::default()).collect();
        PipelinePlan {
            shared: Arc::new(PipeShared {
                plan,
                ranges,
                stage_costs,
                xfer,
                stage_slots,
                stage_scratch,
                team,
                team_steps,
                counters,
            }),
            idle: Arc::new(IdleTracker::default()),
            pool: Mutex::new(None),
        }
    }

    /// The underlying sequential plan (single-image latency path).
    pub fn plan(&self) -> &ExecutionPlan {
        &self.shared.plan
    }

    pub fn num_stages(&self) -> usize {
        self.shared.ranges.len()
    }

    /// Intra-stage worker-team size (1 = no splitting).
    pub fn team(&self) -> usize {
        self.shared.team
    }

    /// Plan-global indices of the steps the worker team splits.
    pub fn team_steps(&self) -> &[usize] {
        &self.shared.team_steps
    }

    /// Half-open step ranges, one per stage.
    pub fn stage_ranges(&self) -> &[(usize, usize)] {
        &self.shared.ranges
    }

    /// Per-stage costs in the units the plan was cut with (the balanced
    /// partition sums): modeled cycles for [`Self::from_plan_team`],
    /// measured nanoseconds for [`Self::from_profile`].
    pub fn stage_costs(&self) -> &[u64] {
        &self.shared.stage_costs
    }

    /// Cumulative per-stage busy / stall / items counters across every
    /// `run_*` call since construction (or the last
    /// [`Self::reset_stage_metrics`]). Stall time is time blocked on the
    /// inter-stage channels; the busy:stall ratio is per-stage occupancy
    /// — the signal the serve metrics surface and the tuner's cuts are
    /// judged by.
    pub fn stage_metrics(&self) -> Vec<StageMetrics> {
        self.shared
            .counters
            .iter()
            .map(|c| StageMetrics {
                busy_ns: c.busy.load(Ordering::Relaxed),
                stall_ns: c.stall.load(Ordering::Relaxed),
                items: c.items.load(Ordering::Relaxed),
            })
            .collect()
    }

    /// Zero the cumulative stage counters (e.g. after warmup runs).
    /// Also clears the inter-run idle tracker, so a serve window's
    /// [`Self::pipeline_idle_ns`] covers only the gaps inside it.
    pub fn reset_stage_metrics(&self) {
        for c in &self.shared.counters {
            c.busy.store(0, Ordering::Relaxed);
            c.stall.store(0, Ordering::Relaxed);
            c.items.store(0, Ordering::Relaxed);
        }
        self.idle.idle_ns.store(0, Ordering::Relaxed);
        self.idle.last_exit_ns.store(0, Ordering::Relaxed);
    }

    /// Cumulative time the pipeline sat empty *between* `run_*` calls:
    /// the gap from one call's last stage-exit to the next call's first
    /// stage-entry, summed since construction or the last
    /// [`Self::reset_stage_metrics`]. The drain/execute-overlap signal:
    /// a coordinator that pre-drains the next batch while this one
    /// executes collapses this toward zero, a drain-then-run loop pays
    /// the full batcher wait here. Plans sharing a tracker
    /// ([`Self::share_idle_tracker`]) report one fabric-wide number.
    pub fn pipeline_idle_ns(&self) -> u64 {
        self.idle.idle_ns.load(Ordering::Relaxed)
    }

    /// Share `other`'s idle tracker: runs through either plan extend the
    /// same between-runs timeline. Used by the runtime's plan family so
    /// a ragged tail served by a smaller batch variant counts as keeping
    /// the fabric fed rather than as main-pipeline idle time.
    pub fn share_idle_tracker(&mut self, other: &PipelinePlan) {
        self.idle = Arc::clone(&other.idle);
    }

    /// Arena slots copied across the cut between stage `j` and `j + 1`.
    pub fn boundary_slots(&self, j: usize) -> &[usize] {
        &self.shared.xfer[j]
    }

    /// Spawn the persistent stage-worker pool: one named thread per
    /// stage except the last, parked on a job channel between
    /// [`Self::run_batch`] calls, with warm stage contexts and the
    /// boundary channels surviving across batches. Idempotent; a no-op
    /// for single-stage pipelines (there is nothing to keep warm — the
    /// caller thread already does all the work). Scoped and pooled
    /// execution are bit-identical; the pool exists so per-run spawn
    /// cost disappears and recovery probes are cheap.
    pub fn enable_persistent_pool(&self) {
        let k = self.shared.ranges.len();
        let mut guard = self.pool.lock().unwrap_or_else(|e| e.into_inner());
        if k < 2 || guard.is_some() {
            return;
        }
        let mut job_txs = Vec::with_capacity(k - 1);
        let mut workers = Vec::with_capacity(k - 1);
        let mut incoming: Option<(Receiver<Msg>, SyncSender<Msg>)> = None;
        for j in 0..k - 1 {
            let (data_tx, data_rx) = sync_channel::<Msg>(PIPE_DEPTH);
            let (recycle_tx, recycle_rx) = sync_channel::<Msg>(PIPE_DEPTH);
            for _ in 0..PIPE_DEPTH {
                recycle_tx.send(self.shared.new_msg(j)).expect("seeding recycle channel");
            }
            let (job_tx, job_rx) = sync_channel::<Job>(1);
            let inc = incoming.take();
            let shared = Arc::clone(&self.shared);
            let worker = std::thread::Builder::new()
                .name(format!("hpipe-stage-{j}"))
                .spawn(move || pool_worker(shared, j, job_rx, inc, data_tx, recycle_rx))
                .expect("spawning persistent stage worker");
            job_txs.push(job_tx);
            workers.push(worker);
            incoming = Some((data_rx, recycle_tx));
        }
        let (last_data_rx, last_recycle_tx) = incoming.expect("k >= 2 leaves a final cut");
        *guard = Some(Pool {
            job_txs,
            last_data_rx,
            last_recycle_tx,
            last_ctx: self.shared.stage_context(k - 1),
            workers,
        });
    }

    /// Tear the persistent pool down (joins the workers); `run_batch`
    /// reverts to scoped workers. Idempotent.
    pub fn disable_persistent_pool(&self) {
        *self.pool.lock().unwrap_or_else(|e| e.into_inner()) = None;
    }

    /// True when a persistent stage-worker pool is live.
    pub fn persistent_pool_active(&self) -> bool {
        self.pool.lock().unwrap_or_else(|e| e.into_inner()).is_some()
    }

    /// Run a stream of plan executions through the pipeline (for a
    /// batch-B plan each item's feed tensors carry B images); per item,
    /// the feed map is validated like [`ExecutionPlan::run_with`] and
    /// the graph outputs are returned in order. Output `i` of item `k`
    /// is bit-identical to a sequential `plan.run(&images[k])`.
    ///
    /// If a stage worker panics, the whole stream returns
    /// [`GraphError::StageFault`] (no partial results) and the plan
    /// remains usable for subsequent runs — see [`Self::run_inner`].
    pub fn run_stream(
        &self,
        images: &[BTreeMap<String, Tensor>],
    ) -> Result<Vec<Vec<Tensor>>, GraphError> {
        let plan = &self.shared.plan;
        for feeds in images {
            for (name, _, shape) in &plan.feeds {
                let t = feeds.get(name).ok_or_else(|| {
                    GraphError::Invalid(name.clone(), "missing feed".into())
                })?;
                if &t.shape != shape {
                    return Err(GraphError::Shape(
                        name.clone(),
                        format!("feed shape {:?} != {:?}", t.shape, shape),
                    ));
                }
            }
        }
        let mut results: Vec<Vec<Tensor>> = Vec::with_capacity(images.len());
        let feed = |img: usize, ctx: &mut ExecContext| {
            for (i, (name, _, _)) in plan.feeds.iter().enumerate() {
                let t = &images[img][name];
                plan.write_feed(ctx, i, &t.data).expect("feed validated");
            }
        };
        let mut collect = |_img: usize, ctx: &ExecContext| {
            let outs = (0..plan.num_outputs())
                .map(|i| {
                    let (data, shape) = plan.output(ctx, i);
                    Tensor::from_vec(shape, data.to_vec())
                })
                .collect();
            results.push(outs);
        };
        self.run_inner(images.len(), &feed, &mut collect)?;
        Ok(results)
    }

    /// Flat serving path: `input` holds `n_images` images contiguously
    /// for a single-placeholder plan. The images are streamed through
    /// the pipeline in **groups of the plan's batch** — each boundary
    /// handoff carries one whole batched tensor set, one cross-cut copy
    /// per batch instead of per image — so `n_images` must be a multiple
    /// of [`ExecutionPlan::batch`]. Returns every graph output, each
    /// concatenated over all images (the pipelined counterpart of a
    /// sequence of whole-batch plan executions). A stage-worker panic
    /// fails the whole call with [`GraphError::StageFault`], leaving the
    /// plan reusable (the caller decides whether to retry or degrade).
    pub fn run_batch(&self, input: &[f32], n_images: usize) -> Result<Vec<Vec<f32>>, GraphError> {
        let plan = &self.shared.plan;
        if plan.num_feeds() != 1 {
            return Err(GraphError::Invalid(
                "<pipeline>".into(),
                format!("run_batch needs exactly 1 feed, plan has {}", plan.num_feeds()),
            ));
        }
        let b = plan.batch();
        if n_images == 0 || n_images % b != 0 {
            return Err(GraphError::Invalid(
                "<pipeline>".into(),
                format!("{n_images} images do not fill whole batches of {b}"),
            ));
        }
        let groups = n_images / b;
        let per_group: usize = plan.feeds[0].2.iter().product();
        if input.len() != per_group * groups {
            return Err(GraphError::Shape(
                plan.feeds[0].0.clone(),
                format!("input length {} != {groups} batches of {per_group}", input.len()),
            ));
        }
        let mut outs: Vec<Vec<f32>> = vec![Vec::new(); plan.num_outputs()];
        let mut collect = |_grp: usize, ctx: &ExecContext| {
            for (i, out) in outs.iter_mut().enumerate() {
                let (data, _) = plan.output(ctx, i);
                if out.capacity() == 0 {
                    out.reserve_exact(data.len() * groups);
                }
                out.extend_from_slice(data);
            }
        };
        let mut guard = self.pool.lock().unwrap_or_else(|e| e.into_inner());
        if let Some(pool) = guard.as_mut() {
            self.run_pooled(pool, input, groups, per_group, &mut collect)?;
        } else {
            drop(guard);
            let feed = |grp: usize, ctx: &mut ExecContext| {
                plan.write_feed(ctx, 0, &input[grp * per_group..(grp + 1) * per_group])
                    .expect("feed validated");
            };
            self.run_inner(groups, &feed, &mut collect)?;
        }
        Ok(outs)
    }

    /// `run_batch` through the persistent pool: broadcast one [`Job`]
    /// to every parked worker, then play the final stage on the calling
    /// thread against the pool's warm context. The fault protocol keeps
    /// all channels aligned (see the module docs), so after an `Err`
    /// the pool is immediately reusable — a faulted stage rebuilds its
    /// context before parking, which is what makes a bitwise retry or
    /// recovery probe sound.
    fn run_pooled(
        &self,
        pool: &mut Pool,
        input: &[f32],
        groups: usize,
        per_group: usize,
        collect: &mut dyn FnMut(usize, &ExecContext),
    ) -> Result<(), StageFault> {
        let sh = &self.shared;
        let entry = epoch_ns();
        let last_exit = self.idle.last_exit_ns.load(Ordering::Relaxed);
        if last_exit != 0 && entry > last_exit {
            self.idle.idle_ns.fetch_add(entry - last_exit, Ordering::Relaxed);
        }
        let fault: Arc<Mutex<Option<StageFault>>> = Arc::new(Mutex::new(None));
        let job = Job {
            groups,
            per_group,
            input: Arc::new(input.to_vec()),
            fault: Arc::clone(&fault),
            abort: Arc::new(AtomicBool::new(false)),
        };
        for tx in &pool.job_txs {
            tx.send(job.clone()).expect("persistent stage worker is parked on its job channel");
        }
        let j = sh.ranges.len() - 1;
        let ctr = &sh.counters[j];
        let mut aborted = false;
        for grp in 0..groups {
            let msg = {
                let _t = ScopedNs::new(&ctr.stall);
                pool.last_data_rx.recv().expect("persistent stage worker alive")
            };
            debug_assert_eq!(msg.img, grp, "pooled final stage images out of order");
            if msg.abort {
                aborted = true;
            } else if !aborted {
                sh.copy_in(j, &msg, &mut pool.last_ctx);
            }
            let _ = pool.last_recycle_tx.send(msg);
            if aborted {
                continue;
            }
            let ran = {
                let _t = ScopedNs::new(&ctr.busy);
                catch_unwind(AssertUnwindSafe(|| {
                    crate::util::fault::point("pipeline.stage", j);
                    sh.run_range(j, &mut pool.last_ctx);
                }))
            };
            match ran {
                Ok(()) => {
                    collect(grp, &pool.last_ctx);
                    ctr.items.fetch_add(1, Ordering::Relaxed);
                }
                Err(payload) => {
                    record_fault(&fault, j, grp, payload);
                    job.abort.store(true, Ordering::Release);
                    aborted = true;
                }
            }
        }
        self.idle.last_exit_ns.store(epoch_ns(), Ordering::Relaxed);
        let faulted = fault.lock().unwrap_or_else(|e| e.into_inner()).take();
        match faulted {
            Some(f) => {
                // pristine buffers for the retry / probe that follows
                pool.last_ctx = sh.stage_context(j);
                Err(f)
            }
            None => Ok(()),
        }
    }

    /// Core streaming loop. Spawns one worker per stage except the last,
    /// which runs on the calling thread (so `collect` needs no `Send`);
    /// images are handed between stages through bounded channels with
    /// [`PIPE_DEPTH`] recycled boundary messages per cut.
    ///
    /// # Fault isolation
    ///
    /// Each stage's step execution runs under `catch_unwind`. A panic
    /// does not cross the thread scope: the faulted worker records a
    /// [`StageFault`] (first fault wins) and returns, dropping its
    /// channel endpoints — which unblocks and cleanly shuts down every
    /// neighbor (a blocked `send`/`recv` on a dropped channel returns
    /// `Err`, never wedges). All per-run state (stage contexts, boundary
    /// messages) is scoped to this call, so the plan itself stays
    /// reusable after a fault.
    fn run_inner<F>(
        &self,
        n_images: usize,
        feed: &F,
        collect: &mut dyn FnMut(usize, &ExecContext),
    ) -> Result<(), StageFault>
    where
        F: Fn(usize, &mut ExecContext) + Sync,
    {
        let sh = &*self.shared;
        let k = sh.ranges.len();
        // Inter-run idle: the gap since the previous run's exit (on this
        // plan or any plan sharing the tracker) is the time the fabric
        // sat unfed. First entry after construction/reset charges none.
        let entry = epoch_ns();
        let last_exit = self.idle.last_exit_ns.load(Ordering::Relaxed);
        if last_exit != 0 && entry > last_exit {
            self.idle.idle_ns.fetch_add(entry - last_exit, Ordering::Relaxed);
        }
        let fault_slot: Mutex<Option<StageFault>> = Mutex::new(None);
        std::thread::scope(|scope| {
            let fault_slot = &fault_slot;
            let mut incoming: Option<(Receiver<Msg>, SyncSender<Msg>)> = None;
            for j in 0..k - 1 {
                let (data_tx, data_rx) = sync_channel::<Msg>(PIPE_DEPTH);
                let (recycle_tx, recycle_rx) = sync_channel::<Msg>(PIPE_DEPTH);
                for _ in 0..PIPE_DEPTH {
                    // cannot fail: recycle_rx is alive in this scope
                    recycle_tx.send(sh.new_msg(j)).expect("seeding recycle channel");
                }
                let inc = incoming.take();
                scope.spawn(move || {
                    let ctr = &sh.counters[j];
                    let mut ctx = sh.stage_context(j);
                    for img in 0..n_images {
                        if let Some((rx, back)) = &inc {
                            let msg = {
                                let _t = ScopedNs::new(&ctr.stall);
                                match rx.recv() {
                                    Ok(m) => m,
                                    // upstream aborted (its fault is
                                    // already recorded): unwind quietly
                                    Err(_) => return,
                                }
                            };
                            debug_assert_eq!(msg.img, img, "stage {j} images out of order");
                            sh.copy_in(j, &msg, &mut ctx);
                            let _ = back.send(msg);
                        }
                        let ran = {
                            let _t = ScopedNs::new(&ctr.busy);
                            catch_unwind(AssertUnwindSafe(|| {
                                if j == 0 {
                                    feed(img, &mut ctx);
                                }
                                crate::util::fault::point("pipeline.stage", j);
                                sh.run_range(j, &mut ctx);
                            }))
                        };
                        if let Err(payload) = ran {
                            record_fault(fault_slot, j, img, payload);
                            return;
                        }
                        let mut msg = {
                            let _t = ScopedNs::new(&ctr.stall);
                            match recycle_rx.recv() {
                                Ok(m) => m,
                                Err(_) => return, // downstream aborted
                            }
                        };
                        msg.img = img;
                        sh.copy_out(j, &ctx, &mut msg);
                        if data_tx.send(msg).is_err() {
                            return; // downstream aborted
                        }
                        ctr.items.fetch_add(1, Ordering::Relaxed);
                    }
                });
                incoming = Some((data_rx, recycle_tx));
            }
            let j = k - 1;
            let inc = incoming.take();
            let ctr = &sh.counters[j];
            let mut ctx = sh.stage_context(j);
            for img in 0..n_images {
                if let Some((rx, back)) = &inc {
                    let msg = {
                        let _t = ScopedNs::new(&ctr.stall);
                        match rx.recv() {
                            Ok(m) => m,
                            Err(_) => break, // upstream aborted
                        }
                    };
                    debug_assert_eq!(msg.img, img, "final stage images out of order");
                    sh.copy_in(j, &msg, &mut ctx);
                    let _ = back.send(msg);
                }
                let ran = {
                    let _t = ScopedNs::new(&ctr.busy);
                    catch_unwind(AssertUnwindSafe(|| {
                        if j == 0 {
                            feed(img, &mut ctx);
                        }
                        crate::util::fault::point("pipeline.stage", j);
                        sh.run_range(j, &mut ctx);
                    }))
                };
                if let Err(payload) = ran {
                    record_fault(fault_slot, j, img, payload);
                    break;
                }
                collect(img, &ctx);
                ctr.items.fetch_add(1, Ordering::Relaxed);
            }
            // On early exit the final stage's channel endpoints (`inc`)
            // drop as this closure returns — before the scope joins —
            // unblocking any still-running upstream workers.
        });
        self.idle.last_exit_ns.store(epoch_ns(), Ordering::Relaxed);
        match fault_slot.into_inner().unwrap_or_else(|e| e.into_inner()) {
            Some(f) => Err(f),
            None => Ok(()),
        }
    }
}

/// Body of one persistent stage worker (stages `0..k-1`; the last stage
/// runs on the calling thread). Parked on `job_rx` between jobs; exits
/// when the pool drops the job channel. Within a job it is the scoped
/// worker loop with one difference — faults do not tear channels down.
/// The faulted (or abort-notified) worker forwards abort-flagged
/// messages for the job's remaining items, so every stage handles
/// exactly `job.groups` items and the recycle rings stay aligned for
/// the next job; a faulted worker also rebuilds its warm context so a
/// retry runs on pristine buffers.
fn pool_worker(
    shared: Arc<PipeShared>,
    j: usize,
    job_rx: Receiver<Job>,
    inc: Option<(Receiver<Msg>, SyncSender<Msg>)>,
    data_tx: SyncSender<Msg>,
    recycle_rx: Receiver<Msg>,
) {
    let ctr = &shared.counters[j];
    let mut ctx = shared.stage_context(j);
    while let Ok(job) = job_rx.recv() {
        let mut aborted = false;
        for grp in 0..job.groups {
            if let Some((rx, back)) = &inc {
                let msg = {
                    let _t = ScopedNs::new(&ctr.stall);
                    match rx.recv() {
                        Ok(m) => m,
                        Err(_) => return, // pool torn down mid-job
                    }
                };
                debug_assert_eq!(msg.img, grp, "pooled stage {j} images out of order");
                if msg.abort {
                    aborted = true;
                } else if !aborted {
                    shared.copy_in(j, &msg, &mut ctx);
                }
                let _ = back.send(msg);
            }
            if !aborted && job.abort.load(Ordering::Acquire) {
                // another stage faulted: the job is already lost — skip
                // the compute, keep forwarding aligned abort messages
                aborted = true;
            }
            if !aborted {
                let ran = {
                    let _t = ScopedNs::new(&ctr.busy);
                    catch_unwind(AssertUnwindSafe(|| {
                        if j == 0 {
                            let (a, b) = (grp * job.per_group, (grp + 1) * job.per_group);
                            shared
                                .plan
                                .write_feed(&mut ctx, 0, &job.input[a..b])
                                .expect("feed validated");
                        }
                        crate::util::fault::point("pipeline.stage", j);
                        shared.run_range(j, &mut ctx);
                    }))
                };
                if let Err(payload) = ran {
                    record_fault(&job.fault, j, grp, payload);
                    job.abort.store(true, Ordering::Release);
                    aborted = true;
                }
            }
            let mut msg = {
                let _t = ScopedNs::new(&ctr.stall);
                match recycle_rx.recv() {
                    Ok(m) => m,
                    Err(_) => return, // pool torn down mid-job
                }
            };
            msg.img = grp;
            msg.abort = aborted;
            if !aborted {
                shared.copy_out(j, &ctx, &mut msg);
            }
            if data_tx.send(msg).is_err() {
                return; // pool torn down mid-job
            }
            if !aborted {
                ctr.items.fetch_add(1, Ordering::Relaxed);
            }
        }
        if aborted {
            // pristine buffers for the retry / probe that follows
            ctx = shared.stage_context(j);
        }
    }
}

impl PipeShared {
    /// A fresh boundary message for cut `j`, buffers pre-sized to the
    /// crossing slots.
    fn new_msg(&self, j: usize) -> Msg {
        Msg {
            img: 0,
            abort: false,
            bufs: self.xfer[j]
                .iter()
                .map(|&s| vec![0.0f32; self.plan.slot_lens[s]])
                .collect(),
        }
    }

    /// A private context for stage `j`: full-size buffers for the
    /// stage-local arena slots, empty placeholders for the rest.
    fn stage_context(&self, j: usize) -> ExecContext {
        let mut slots: Vec<Vec<f32>> = vec![Vec::new(); self.plan.slot_lens.len()];
        for &s in &self.stage_slots[j] {
            slots[s] = vec![0.0; self.plan.slot_lens[s]];
        }
        let (scratch, acc) = self.stage_scratch[j];
        ExecContext {
            slots,
            scratch: vec![0.0; scratch],
            acc: vec![0.0; acc],
        }
    }

    fn copy_in(&self, j: usize, msg: &Msg, ctx: &mut ExecContext) {
        for (buf, &slot) in msg.bufs.iter().zip(&self.xfer[j - 1]) {
            debug_assert_eq!(
                buf.len(),
                ctx.slots[slot].len(),
                "boundary slot {slot} is not stage-local to stage {j}"
            );
            ctx.slots[slot].copy_from_slice(buf);
        }
    }

    fn copy_out(&self, j: usize, ctx: &ExecContext, msg: &mut Msg) {
        for (buf, &slot) in msg.bufs.iter_mut().zip(&self.xfer[j]) {
            debug_assert_eq!(
                buf.len(),
                ctx.slots[slot].len(),
                "boundary slot {slot} is not stage-local to stage {j}"
            );
            buf.copy_from_slice(&ctx.slots[slot]);
        }
    }

    fn run_range(&self, j: usize, ctx: &mut ExecContext) {
        let (a, b) = self.ranges[j];
        for (i, step) in self.plan.steps[a..b].iter().enumerate() {
            debug_assert_eq!(
                ctx.slots[step.out].len(),
                self.plan.slot_lens[step.out],
                "output slot {} of step '{}' is not stage-local to stage {j}",
                step.out,
                step.name
            );
            if self.team > 1 && self.team_steps.contains(&(a + i)) {
                self.plan.exec_step_team(step, ctx, self.team);
            } else {
                self.plan.exec_step(step, ctx);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::interp;
    use crate::nets::{tiny_cnn, NetConfig};
    use crate::sparsity::prune_graph;
    use crate::util::Rng;

    #[test]
    fn more_stages_never_raise_the_bottleneck() {
        let g = tiny_cnn(NetConfig::test_scale());
        let costs = ExecutionPlan::build(&g).unwrap().step_costs();
        let bottleneck = |k: usize| -> u64 {
            partition_min_bottleneck(&costs, k)
                .iter()
                .map(|&(a, b)| costs[a..b].iter().sum::<u64>())
                .max()
                .unwrap()
        };
        let (b1, b2, b4) = (bottleneck(1), bottleneck(2), bottleneck(4));
        assert!(b2 <= b1, "{b2} > {b1}");
        assert!(b4 <= b2, "{b4} > {b2}");
    }

    #[test]
    fn boundaries_carry_live_values_only() {
        let g = tiny_cnn(NetConfig::test_scale());
        let pipe = PipelinePlan::build(&g, &PlanOptions::default(), 3).unwrap();
        assert_eq!(pipe.num_stages(), 3);
        for j in 0..pipe.num_stages() - 1 {
            let x = pipe.boundary_slots(j);
            assert!(!x.is_empty(), "cut {j} carries nothing");
            // far fewer slots cross a cut than the arena holds
            assert!(x.len() < pipe.plan().stats().steps.max(2));
        }
    }

    #[test]
    fn pipeline_matches_sequential_across_stage_counts() {
        let mut g = tiny_cnn(NetConfig::test_scale());
        prune_graph(&mut g, 0.6);
        let seq = ExecutionPlan::build(&g).unwrap();
        let mut rng = Rng::new(0x91FE);
        let images: Vec<BTreeMap<String, Tensor>> =
            (0..6).map(|_| g.random_feeds(&mut rng)).collect();
        for stages in [1usize, 2, 3, 4] {
            let pipe = PipelinePlan::build(&g, &PlanOptions::default(), stages).unwrap();
            let got = pipe.run_stream(&images).unwrap();
            assert_eq!(got.len(), images.len());
            for (i, feeds) in images.iter().enumerate() {
                let want = seq.run(feeds).unwrap();
                assert_eq!(got[i].len(), want.len());
                for (a, b) in got[i].iter().zip(&want) {
                    assert_eq!(a.shape, b.shape);
                    // same kernels in the same order: bit-identical
                    assert_eq!(a.data, b.data, "stages={stages} image={i}");
                }
            }
        }
    }

    #[test]
    fn run_batch_matches_interpreter() {
        let g = tiny_cnn(NetConfig::test_scale());
        let pipe = PipelinePlan::build(&g, &PlanOptions::default(), 2).unwrap();
        let per: usize = pipe.plan().feeds[0].2.iter().product();
        let mut rng = Rng::new(0xBA7C);
        let input: Vec<f32> = (0..3 * per).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let out = pipe.run_batch(&input, 3).unwrap().remove(0);
        let probs = out.len() / 3;
        for i in 0..3 {
            let mut feeds = BTreeMap::new();
            let image = input[i * per..(i + 1) * per].to_vec();
            feeds.insert(
                "input".to_string(),
                Tensor::from_vec(&pipe.plan().feeds[0].2, image),
            );
            let want = interp::run_outputs(&g, &feeds).unwrap();
            for (a, b) in out[i * probs..(i + 1) * probs].iter().zip(&want[0].data) {
                assert!((a - b).abs() < 1e-4, "{a} vs {b}");
            }
        }
    }

    #[test]
    fn run_batch_rejects_bad_lengths() {
        let g = tiny_cnn(NetConfig::test_scale());
        let pipe = PipelinePlan::build(&g, &PlanOptions::default(), 2).unwrap();
        assert!(pipe.run_batch(&[0.0; 7], 1).is_err());
    }

    #[test]
    fn team_pipeline_matches_sequential_bitwise() {
        // Worker teams split output rows with unchanged per-element
        // accumulation order, so results must be bit-identical to the
        // sequential plan across stage counts and team sizes.
        let mut g = tiny_cnn(NetConfig::test_scale());
        prune_graph(&mut g, 0.6);
        let seq = ExecutionPlan::build(&g).unwrap();
        let mut rng = Rng::new(0x7EA2);
        let images: Vec<BTreeMap<String, Tensor>> =
            (0..4).map(|_| g.random_feeds(&mut rng)).collect();
        for (stages, team) in [(1usize, 2usize), (2, 2), (3, 3)] {
            let pipe =
                PipelinePlan::from_plan_team(ExecutionPlan::build(&g).unwrap(), stages, team);
            assert_eq!(pipe.team(), team);
            assert!(
                !pipe.team_steps().is_empty(),
                "stages={stages}: no splittable steps in the dominant stage"
            );
            let got = pipe.run_stream(&images).unwrap();
            for (i, fm) in images.iter().enumerate() {
                let want = seq.run(fm).unwrap();
                for (a, b) in got[i].iter().zip(&want) {
                    assert_eq!(a.data, b.data, "stages={stages} team={team} image={i}");
                }
            }
        }
    }

    #[test]
    fn team_defaults_to_pr3_behavior() {
        let g = tiny_cnn(NetConfig::test_scale());
        let pipe = PipelinePlan::build(&g, &PlanOptions::default(), 2).unwrap();
        assert_eq!(pipe.team(), 1);
        assert!(pipe.team_steps().is_empty());
    }

    #[test]
    fn from_profile_cuts_follow_measured_costs() {
        // A synthetic profile that inverts the model's view: the LAST
        // step is claimed to dominate. The measured cut must isolate it,
        // and the team must target the measured-dominant stage.
        let g = tiny_cnn(NetConfig::test_scale());
        let plan = ExecutionPlan::build(&g).unwrap();
        let n = plan.steps.len();
        assert!(n >= 3);
        let mut costs = vec![1u64; n];
        costs[n - 1] = 1000;
        let profile = StepProfile::synthetic(&plan, costs);
        let pipe = PipelinePlan::from_profile(plan, &profile, 2, 2);
        assert_eq!(pipe.stage_ranges(), &[(0, n - 1), (n - 1, n)]);
        // the team targets the measured bottleneck (stage 1), so every
        // team step lives in its range
        for &s in pipe.team_steps() {
            assert!(s >= n - 1, "team step {s} outside the measured-dominant stage");
        }
        // and a measured-cut pipeline still computes the right answer
        let seq = ExecutionPlan::build(&g).unwrap();
        let mut rng = Rng::new(0x9F0F);
        let images: Vec<BTreeMap<String, Tensor>> =
            (0..3).map(|_| g.random_feeds(&mut rng)).collect();
        let got = pipe.run_stream(&images).unwrap();
        for (i, fm) in images.iter().enumerate() {
            let want = seq.run(fm).unwrap();
            for (a, b) in got[i].iter().zip(&want) {
                assert_eq!(a.data, b.data, "image {i}");
            }
        }
    }

    #[test]
    #[should_panic(expected = "step costs")]
    fn from_profile_rejects_mismatched_profiles() {
        let g = tiny_cnn(NetConfig::test_scale());
        let plan = ExecutionPlan::build(&g).unwrap();
        let short = StepProfile {
            batch: 1,
            runs: 1,
            names: vec!["bogus".into()],
            costs_ns: vec![1],
        };
        let _ = PipelinePlan::from_profile(plan, &short, 2, 1);
    }

    #[test]
    fn stage_metrics_accumulate_and_reset() {
        let g = tiny_cnn(NetConfig::test_scale());
        let pipe = PipelinePlan::build(&g, &PlanOptions::default(), 3).unwrap();
        let mut rng = Rng::new(0x0CC);
        let images: Vec<BTreeMap<String, Tensor>> =
            (0..5).map(|_| g.random_feeds(&mut rng)).collect();
        pipe.run_stream(&images).unwrap();
        let m = pipe.stage_metrics();
        assert_eq!(m.len(), pipe.num_stages());
        for (j, s) in m.iter().enumerate() {
            assert_eq!(s.items, images.len() as u64, "stage {j}");
            assert!(s.busy_ns > 0, "stage {j} recorded no busy time");
            assert!((0.0..=1.0).contains(&s.occupancy()));
        }
        // stage 0 never stalls on an upstream; its only stall source is
        // buffer recycling
        pipe.reset_stage_metrics();
        for s in pipe.stage_metrics() {
            assert_eq!((s.busy_ns, s.stall_ns, s.items), (0, 0, 0));
        }
    }

    #[test]
    fn inter_run_idle_accumulates_shares_and_resets() {
        let g = tiny_cnn(NetConfig::test_scale());
        let pipe = PipelinePlan::build(&g, &PlanOptions::default(), 2).unwrap();
        let mut rng = Rng::new(0x1D1E);
        let images: Vec<BTreeMap<String, Tensor>> =
            (0..2).map(|_| g.random_feeds(&mut rng)).collect();
        // the window before the first run is never charged as idle
        pipe.run_stream(&images).unwrap();
        assert_eq!(pipe.pipeline_idle_ns(), 0, "first run must not charge startup");
        // a deliberate gap between runs is charged
        std::thread::sleep(std::time::Duration::from_millis(3));
        pipe.run_stream(&images).unwrap();
        let idle = pipe.pipeline_idle_ns();
        assert!(idle >= 3_000_000, "a 3ms gap must be visible, got {idle}ns");
        // a plan sharing the tracker extends the same timeline: its run
        // immediately after ours adds (at most) a tiny gap, and both
        // plans report the one fabric-wide number
        let mut variant = PipelinePlan::build(&g, &PlanOptions::default(), 1).unwrap();
        variant.share_idle_tracker(&pipe);
        variant.run_stream(&images).unwrap();
        assert_eq!(variant.pipeline_idle_ns(), pipe.pipeline_idle_ns());
        // reset zeroes the shared tracker and re-arms the no-prior-run
        // sentinel, so the next run starts a fresh window
        pipe.reset_stage_metrics();
        assert_eq!(variant.pipeline_idle_ns(), 0);
        std::thread::sleep(std::time::Duration::from_millis(1));
        pipe.run_stream(&images).unwrap();
        assert_eq!(pipe.pipeline_idle_ns(), 0, "post-reset first run charges nothing");
    }

    #[test]
    fn persistent_pool_matches_scoped_workers_bitwise() {
        let mut g = tiny_cnn(NetConfig::test_scale());
        prune_graph(&mut g, 0.6);
        let scoped = PipelinePlan::from_plan_team(ExecutionPlan::build(&g).unwrap(), 3, 2);
        let pooled = PipelinePlan::from_plan_team(ExecutionPlan::build(&g).unwrap(), 3, 2);
        pooled.enable_persistent_pool();
        assert!(pooled.persistent_pool_active());
        assert!(!scoped.persistent_pool_active());
        let per: usize = pooled.plan().feeds[0].2.iter().product();
        let mut rng = Rng::new(0x9001);
        let input: Vec<f32> = (0..4 * per).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let want = scoped.run_batch(&input, 4).unwrap();
        // repeated pooled runs: same threads, warm contexts, identical bits
        for run in 0..3 {
            let got = pooled.run_batch(&input, 4).unwrap();
            assert_eq!(got, want, "pooled run {run} diverged from scoped workers");
        }
        pooled.disable_persistent_pool();
        assert!(!pooled.persistent_pool_active());
        // after teardown the scoped path serves the same bits
        assert_eq!(pooled.run_batch(&input, 4).unwrap(), want);
    }

    #[test]
    fn persistent_pool_is_idempotent_and_skips_single_stage() {
        let g = tiny_cnn(NetConfig::test_scale());
        let one = PipelinePlan::build(&g, &PlanOptions::default(), 1).unwrap();
        one.enable_persistent_pool();
        assert!(
            !one.persistent_pool_active(),
            "a single-stage pipeline has no workers to keep warm"
        );
        let multi = PipelinePlan::build(&g, &PlanOptions::default(), 2).unwrap();
        multi.enable_persistent_pool();
        multi.enable_persistent_pool(); // second call: no second pool
        assert!(multi.persistent_pool_active());
    }

    #[test]
    fn stage_fault_converts_to_graph_error() {
        let f = StageFault { stage: 1, item: 3, msg: "boom".into() };
        let e: GraphError = f.into();
        let s = e.to_string();
        assert!(
            s.contains("stage 1") && s.contains("item 3") && s.contains("boom"),
            "{s}"
        );
    }

    #[test]
    fn stage_contexts_are_stage_local() {
        let g = tiny_cnn(NetConfig::test_scale());
        let pipe = PipelinePlan::build(&g, &PlanOptions::default(), 3).unwrap();
        let total: usize = pipe.plan().slot_lens.iter().sum();
        for j in 0..pipe.num_stages() {
            let ctx = pipe.shared.stage_context(j);
            let held: usize = ctx.slots.iter().map(|s| s.len()).sum();
            assert!(held <= total);
            // every boundary slot the stage participates in is allocated
            if j > 0 {
                for &s in pipe.boundary_slots(j - 1) {
                    assert_eq!(ctx.slots[s].len(), pipe.plan().slot_lens[s]);
                }
            }
        }
    }
}
