//! Profile-guided autotuner: measured step costs drive stage cuts, team
//! sizing and batch-aware repartitioning.
//!
//! This is the profile-guided variant of HPIPE's Algorithm 1. The paper
//! loops "over the slowest operations and increment[s] n_channel_splits
//! until we hit the DSP Target" — a model-driven allocation that wins
//! because per-layer specialization matches resources to each layer's
//! cost. The software pipeline inherited the *model-driven* half of that
//! (cuts from `ExecutionPlan::step_costs`); this module closes the loop
//! with the *measured* half:
//!
//! 1. **Re-cut from measurements** — the same minimum-bottleneck
//!    partition DP ([`crate::util::partition`]) the static path uses,
//!    run over a [`StepProfile`]'s median wall times instead of modeled
//!    cycles ([`super::PipelinePlan::from_profile`]).
//! 2. **Size the stage count to the machine** — candidate stage counts
//!    are capped by the core budget (default:
//!    `std::thread::available_parallelism`), and the smallest count
//!    whose measured bottleneck reaches the plateau is chosen: deeper
//!    cuts that cannot lower the bottleneck only add handoff copies.
//! 3. **Spend leftover cores on the measured bottleneck** — when the
//!    dominant stage still out-costs the runner-up by
//!    [`TEAM_IMBALANCE`]×, the spare cores become its intra-stage worker
//!    team (the paper's `n_channel_splits` loop, not just its move).
//! 4. **Batch-aware cuts** — profiles are captured per plan, and a plan
//!    is compiled per group-batch size, so every group size gets its own
//!    cuts ([`crate::runtime::LoadedModel::autotuned`] caches one
//!    [`TuneEntry`] per group instead of reusing the B=1 cuts).
//!
//! The policy core ([`choose_cuts`]) is pure and deterministic — known
//! costs map to known cuts — so it is unit-testable without timers.

use super::profile::{profile_plan, ProfileOptions, StepProfile};
use super::ExecutionPlan;
use crate::util::partition::{bottlenecks_up_to, partition_min_bottleneck, range_costs};
use crate::util::Json;

/// Dominant-stage cost must exceed the runner-up by this factor before
/// spare cores are spent on an intra-stage team: below it, splitting the
/// bottleneck's rows just shifts the bottleneck to the runner-up.
pub const TEAM_IMBALANCE: f64 = 1.25;

/// Plateau tolerance for the stage-count search (2%): the smallest stage
/// count whose bottleneck is within this of the deepest candidate's wins
/// — extra stages past the plateau cannot raise throughput but each one
/// adds a boundary copy and a thread.
const PLATEAU_DIV: u64 = 50;

/// Scoped-thread spawn/join overhead a team worker must amortize
/// (tens of µs on commodity cores, taken pessimistically). The team is
/// capped at `heaviest measured step / TEAM_SPAWN_NS`: each worker's
/// share of the step it splits must dwarf the cost of spawning it, or
/// "more parallelism" measures slower than sequential — the exact
/// mismatch a measurement-driven tuner exists to rule out.
const TEAM_SPAWN_NS: u64 = 50_000;

/// Core budget actually available to worker threads.
pub fn detected_cores() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Knobs for a calibration run.
#[derive(Clone, Copy, Debug, Default)]
pub struct TuneOptions {
    /// Core budget; 0 = detect via `available_parallelism`.
    pub cores: usize,
    /// Profiling pass configuration (warmup / median-of-K runs).
    pub profile: ProfileOptions,
}

impl TuneOptions {
    /// The effective core budget (detects when `cores == 0`).
    pub fn budget(&self) -> usize {
        if self.cores == 0 {
            detected_cores()
        } else {
            self.cores
        }
    }
}

/// The tuner's decision for one measured cost vector: where to cut, how
/// many stages, and how large a worker team the bottleneck stage gets.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TunedCuts {
    /// Half-open step ranges, one per stage.
    pub ranges: Vec<(usize, usize)>,
    /// Chosen stage count (`ranges.len()`).
    pub stages: usize,
    /// Intra-stage worker-team size for the measured-dominant stage.
    pub team: usize,
    /// Measured cost of each stage (sums over `ranges`).
    pub stage_costs_ns: Vec<u64>,
    /// The measured bottleneck (max of `stage_costs_ns`).
    pub bottleneck_ns: u64,
}

/// Deterministic cut policy: measured per-step costs + a core budget →
/// stage ranges, stage count and team size. See the module docs for the
/// three rules; this function is pure so synthetic-profile tests can pin
/// known costs → known cuts.
pub fn choose_cuts(costs: &[u64], cores: usize) -> TunedCuts {
    choose_cuts_capped(costs, cores, usize::MAX)
}

/// [`choose_cuts`] with an explicit stage cap. The serving path caps
/// stages at the groups in flight per batch execution: a pipeline
/// deeper than the items it is ever fed per call never fills, it only
/// pays fill/drain and boundary copies. Cores freed by the cap flow
/// into the team instead.
pub fn choose_cuts_capped(costs: &[u64], cores: usize, max_stages: usize) -> TunedCuts {
    let cores = cores.max(1);
    let kmax = cores.min(costs.len()).min(max_stages).max(1);
    // One DP fill yields the optimal bottleneck for every candidate
    // stage count; the plateau scan is a table lookup.
    let per_k = bottlenecks_up_to(costs, kmax);
    let plateau = {
        let b = *per_k.last().expect("bottlenecks_up_to is non-empty");
        b + b / PLATEAU_DIV
    };
    let k = per_k
        .iter()
        .position(|&b| b <= plateau)
        .map(|idx| idx + 1)
        .unwrap_or(per_k.len());
    let ranges = partition_min_bottleneck(costs, k);
    let stages = ranges.len();
    let stage_costs_ns = range_costs(costs, &ranges);
    let bottleneck_ns = stage_costs_ns.iter().copied().max().unwrap_or(0);
    // A team worker splits one step at a time, so the heaviest measured
    // step bounds how many workers can amortize their spawn cost.
    let work_cap = ((costs.iter().copied().max().unwrap_or(0) / TEAM_SPAWN_NS).min(1 << 16)
        as usize)
        .max(1);
    let team = if stages == 1 {
        // One stage: every splittable step belongs to the "dominant"
        // stage, so the core budget becomes the team — as far as the
        // measured step weights can keep that many workers fed.
        cores.min(work_cap)
    } else {
        let runner_up = {
            let mut sorted = stage_costs_ns.clone();
            sorted.sort_unstable();
            sorted[sorted.len() - 2]
        };
        let imbalance = bottleneck_ns as f64 / runner_up.max(1) as f64;
        // Team threads run inside the bottleneck stage's thread, so the
        // concurrency peak is (stages - 1) + team.
        let spare = cores - stages + 1;
        if imbalance >= TEAM_IMBALANCE {
            spare.min(imbalance.ceil() as usize).min(work_cap).max(1)
        } else {
            1
        }
    };
    TunedCuts { ranges, stages, team, stage_costs_ns, bottleneck_ns }
}

/// Profile one plan and choose its cuts — the per-group-size unit of
/// calibration work (`runtime::LoadedModel::autotuned` caches one of
/// these per distinct group-batch size).
pub fn tune_plan(plan: &ExecutionPlan, opts: &TuneOptions) -> (StepProfile, TunedCuts) {
    let profile = profile_plan(plan, &opts.profile);
    let cuts = choose_cuts(&profile.costs_ns, opts.budget());
    (profile, cuts)
}

/// Rescale measured per-step costs from one plan batch to another. Every
/// step's work is linear in the batch dim (a batch-B conv walks B images'
/// patches; element-wise steps stream B times the elements), so a
/// profile captured at `from_batch` predicts a `to_batch` variant by
/// scaling — the calibration-reuse half of the plan family: variants are
/// *sized* from the one profile the model already paid for instead of
/// re-profiling each batch size. Costs round up and never collapse to 0.
pub fn scale_costs(costs_ns: &[u64], from_batch: usize, to_batch: usize) -> Vec<u64> {
    let (from, to) = (from_batch.max(1) as u128, to_batch.max(1) as u128);
    costs_ns
        .iter()
        .map(|&c| (((c as u128 * to) + from - 1) / from).min(u64::MAX as u128) as u64)
        .map(|c| c.max(1))
        .collect()
}

/// Team size for one ragged-tail plan-family variant, reusing an
/// already-captured profile. A tail run is a single group in flight —
/// there is never a second item to overlap with — so pipeline stages
/// cannot help and the whole core budget flows into the intra-stage
/// team ([`choose_cuts_capped`] at `max_stages == 1`), as far as the
/// scaled step weights can amortize the worker spawns.
pub fn variant_team(profile: &StepProfile, variant_batch: usize, cores: usize) -> usize {
    let scaled = scale_costs(&profile.costs_ns, profile.batch, variant_batch);
    choose_cuts_capped(&scaled, cores, 1).team
}

/// One calibrated group-batch size: the measurements, the decision, and
/// the cuts the cycle model would have picked at the same stage count
/// (so reports show where measurement disagreed with the model).
#[derive(Clone, Debug)]
pub struct TuneEntry {
    /// Group-batch size the profiled plan was compiled for.
    pub group: usize,
    pub profile: StepProfile,
    pub cuts: TunedCuts,
    /// `partition_min_bottleneck` over the *modeled* step costs at
    /// `cuts.stages` — the static path's cut for comparison.
    pub model_ranges: Vec<(usize, usize)>,
}

impl TuneEntry {
    /// Build an entry for a plan: profile it, choose cuts, and record
    /// the model's counterfactual cut at the same stage count.
    pub fn calibrate(plan: &ExecutionPlan, opts: &TuneOptions) -> TuneEntry {
        let (profile, cuts) = tune_plan(plan, opts);
        let model_ranges = partition_min_bottleneck(&plan.step_costs(), cuts.stages);
        TuneEntry { group: plan.batch(), profile, cuts, model_ranges }
    }

    pub fn to_json(&self) -> Json {
        let ranges_json = |rs: &[(usize, usize)]| {
            let mut arr = Json::Arr(vec![]);
            for &(a, b) in rs {
                arr.push(Json::from(vec![a, b]));
            }
            arr
        };
        Json::from_pairs(vec![
            ("group", Json::from(self.group)),
            ("stages", Json::from(self.cuts.stages)),
            ("team", Json::from(self.cuts.team)),
            ("bottleneck_ns", Json::from(self.cuts.bottleneck_ns as f64)),
            (
                "stage_ns",
                Json::Arr(
                    self.cuts.stage_costs_ns.iter().map(|&c| Json::from(c as f64)).collect(),
                ),
            ),
            ("ranges", ranges_json(&self.cuts.ranges)),
            ("model_ranges", ranges_json(&self.model_ranges)),
            (
                "matches_model_cuts",
                Json::from(self.cuts.ranges == self.model_ranges),
            ),
            ("profile", self.profile.to_json()),
        ])
    }

    /// Inverse of [`Self::to_json`] — restores a calibration entry from
    /// a saved artifact so loading never re-profiles.
    pub fn from_json(j: &Json) -> Result<TuneEntry, String> {
        let ranges_from = |j: &Json, what: &str| -> Result<Vec<(usize, usize)>, String> {
            j.as_arr()
                .ok_or_else(|| format!("tune entry: missing {what}"))?
                .iter()
                .map(|r| {
                    let v = r.usize_vec().filter(|v| v.len() == 2);
                    v.map(|v| (v[0], v[1])).ok_or_else(|| format!("tune entry: bad {what}"))
                })
                .collect()
        };
        let group = j.get("group").as_usize().ok_or("tune entry: missing group")?;
        let stages = j.get("stages").as_usize().ok_or("tune entry: missing stages")?;
        let team = j.get("team").as_usize().ok_or("tune entry: missing team")?;
        let bottleneck_ns = j
            .get("bottleneck_ns")
            .as_f64()
            .filter(|v| v.is_finite() && *v >= 0.0)
            .ok_or("tune entry: missing bottleneck_ns")? as u64;
        let stage_costs_ns = j
            .get("stage_ns")
            .as_arr()
            .ok_or("tune entry: missing stage_ns")?
            .iter()
            .map(|v| {
                v.as_f64()
                    .filter(|v| v.is_finite() && *v >= 0.0)
                    .map(|v| v as u64)
                    .ok_or_else(|| "tune entry: bad stage_ns".to_string())
            })
            .collect::<Result<Vec<u64>, String>>()?;
        let ranges = ranges_from(j.get("ranges"), "ranges")?;
        let model_ranges = ranges_from(j.get("model_ranges"), "model_ranges")?;
        if ranges.len() != stages || stage_costs_ns.len() != stages {
            return Err("tune entry: stage count disagrees with ranges".into());
        }
        let profile = StepProfile::from_json(j.get("profile"))?;
        Ok(TuneEntry {
            group,
            profile,
            cuts: TunedCuts { ranges, stages, team, stage_costs_ns, bottleneck_ns },
            model_ranges,
        })
    }
}

/// Whole-model calibration report: every group-batch size profiled while
/// tuning one model, plus the configuration that was chosen to serve.
/// Exportable as JSON (`hpipe tune --out`, the bench artifacts) and
/// printable as a summary table.
#[derive(Clone, Debug)]
pub struct TuneReport {
    /// Model (or workload) name the calibration ran for.
    pub model: String,
    /// Core budget the choices were made against.
    pub cores: usize,
    /// Serving batch the model was loaded with.
    pub batch: usize,
    /// Group-batch size whose entry was chosen for serving.
    pub chosen_group: usize,
    /// One entry per distinct profiled group size, ascending.
    pub entries: Vec<TuneEntry>,
}

impl TuneReport {
    /// The entry calibrated at `group`, if that group size was profiled.
    pub fn entry(&self, group: usize) -> Option<&TuneEntry> {
        self.entries.iter().find(|e| e.group == group)
    }

    /// The entry serving traffic (the chosen group's calibration).
    pub fn chosen(&self) -> Option<&TuneEntry> {
        self.entry(self.chosen_group)
    }

    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("model", Json::from(self.model.as_str())),
            ("cores", Json::from(self.cores)),
            ("batch", Json::from(self.batch)),
            ("chosen_group", Json::from(self.chosen_group)),
            (
                "entries",
                Json::Arr(self.entries.iter().map(|e| e.to_json()).collect()),
            ),
        ])
    }

    /// Inverse of [`Self::to_json`] — restores the whole calibration
    /// cache from a saved artifact (an autotuned model's cold start
    /// then skips profiling entirely).
    pub fn from_json(j: &Json) -> Result<TuneReport, String> {
        Ok(TuneReport {
            model: j.get("model").as_str().ok_or("tune report: missing model")?.to_string(),
            cores: j.get("cores").as_usize().ok_or("tune report: missing cores")?,
            batch: j.get("batch").as_usize().ok_or("tune report: missing batch")?,
            chosen_group: j
                .get("chosen_group")
                .as_usize()
                .ok_or("tune report: missing chosen_group")?,
            entries: j
                .get("entries")
                .as_arr()
                .ok_or("tune report: missing entries")?
                .iter()
                .map(TuneEntry::from_json)
                .collect::<Result<Vec<_>, String>>()?,
        })
    }

    /// Human-readable calibration summary.
    pub fn print(&self) {
        println!(
            "tune report: model={} cores={} batch={} chosen_group={}",
            self.model, self.cores, self.batch, self.chosen_group
        );
        for e in &self.entries {
            let marker = if e.group == self.chosen_group { " <- serving" } else { "" };
            println!(
                "  group {:>3}: stages={} team={} bottleneck={:.3}ms stage_ms={:?} \
                 model_cuts_agree={}{marker}",
                e.group,
                e.cuts.stages,
                e.cuts.team,
                e.cuts.bottleneck_ns as f64 / 1e6,
                e.cuts
                    .stage_costs_ns
                    .iter()
                    .map(|&c| (c as f64 / 1e4).round() / 100.0)
                    .collect::<Vec<_>>(),
                e.cuts.ranges == e.model_ranges,
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nets::{tiny_cnn, NetConfig};
    use crate::sparsity::prune_graph;

    /// A measured cost in the magnitude real conv steps profile at
    /// (milliseconds-ish), so the spawn-amortization cap never binds in
    /// tests that pin the stage/imbalance logic.
    const MS: u64 = 1_000_000;

    /// Known costs → known cuts: the deterministic-tuner contract.
    #[test]
    fn skewed_costs_isolate_the_bottleneck_and_team_it() {
        let cuts = choose_cuts(&[10 * MS, MS, MS, MS], 4);
        // two stages suffice (the bottleneck step caps every deeper cut)
        assert_eq!(cuts.ranges, vec![(0, 1), (1, 4)]);
        assert_eq!(cuts.stages, 2);
        assert_eq!(cuts.stage_costs_ns, vec![10 * MS, 3 * MS]);
        assert_eq!(cuts.bottleneck_ns, 10 * MS);
        // 10 vs 3: imbalance 3.33 → spend the spare cores as a team of 3
        assert_eq!(cuts.team, 3);
    }

    #[test]
    fn balanced_costs_use_all_cores_as_stages_with_no_team() {
        let cuts = choose_cuts(&[4 * MS, 4 * MS, 4 * MS, 4 * MS], 4);
        assert_eq!(cuts.ranges, vec![(0, 1), (1, 2), (2, 3), (3, 4)]);
        assert_eq!(cuts.team, 1, "balanced stages must not spawn a team");
        // fewer cores clamp the stage count
        let cuts2 = choose_cuts(&[4 * MS, 4 * MS, 4 * MS, 4 * MS], 2);
        assert_eq!(cuts2.stages, 2);
        assert_eq!(cuts2.bottleneck_ns, 8 * MS);
        assert_eq!(cuts2.team, 1);
    }

    #[test]
    fn single_step_gets_the_whole_budget_as_a_team() {
        let cuts = choose_cuts(&[8 * MS], 4);
        assert_eq!(cuts.ranges, vec![(0, 1)]);
        assert_eq!(cuts.stages, 1);
        assert_eq!(cuts.team, 4);
    }

    #[test]
    fn one_core_means_sequential() {
        let cuts = choose_cuts(&[5 * MS, 9 * MS, 2 * MS], 1);
        assert_eq!(cuts.stages, 1);
        assert_eq!(cuts.team, 1);
    }

    #[test]
    fn team_is_capped_by_spare_cores() {
        // bottleneck 100 vs runner-up 4 wants a huge team, but only
        // cores - stages + 1 threads are spare
        let cuts = choose_cuts(&[100 * MS, 2 * MS, 2 * MS], 3);
        assert_eq!(cuts.stages, 2);
        assert_eq!(cuts.team, 2);
    }

    #[test]
    fn tiny_measured_steps_never_spawn_teams() {
        // the heaviest step measures ~8µs: a worker's spawn would cost
        // more than the work it takes on, so the budget stays unused
        // rather than oversubscribed (the stages==1 branch included)
        let cuts = choose_cuts(&[8_000], 16);
        assert_eq!((cuts.stages, cuts.team), (1, 1));
        // skewed multi-stage case: imbalance asks for 4 workers, but
        // the 120µs bottleneck step only amortizes 2 spawns
        let cuts = choose_cuts(&[120_000, 10_000, 10_000, 10_000], 8);
        assert_eq!(cuts.stages, 2);
        assert_eq!(cuts.team, 2, "team capped by spawn amortization");
    }

    #[test]
    fn stage_cap_limits_depth_and_redirects_cores_to_the_team() {
        let balanced = [4 * MS, 4 * MS, 4 * MS, 4 * MS];
        // uncapped, 4 balanced steps on 4 cores become 4 stages...
        assert_eq!(choose_cuts(&balanced, 4).stages, 4);
        // ...but with only 2 items ever in flight, depth is capped and
        // the imbalance check runs on the capped cut
        let capped = choose_cuts_capped(&balanced, 4, 2);
        assert_eq!(capped.stages, 2);
        assert_eq!(capped.team, 1, "balanced capped stages need no team");
        // a cap of 1 degenerates to the whole budget as a team
        let solo = choose_cuts_capped(&balanced, 4, 1);
        assert_eq!((solo.stages, solo.team), (1, 4));
    }

    #[test]
    fn plateau_prefers_fewer_stages() {
        // the second step dominates any cut; 2 stages already reach the
        // floor, so 4 cores must not produce 4 stages of handoffs
        let cuts = choose_cuts(&[MS, 40 * MS, MS, MS], 4);
        assert_eq!(cuts.bottleneck_ns, 40 * MS);
        assert!(cuts.stages <= 3, "stages {} past the plateau", cuts.stages);
    }

    #[test]
    fn scale_costs_is_linear_ceiling_and_never_zero() {
        // scaling 8 -> 2 quarters the work, rounding up
        assert_eq!(scale_costs(&[8 * MS, 4 * MS, 3], 8, 2), vec![2 * MS, MS, 1]);
        // upscaling multiplies
        assert_eq!(scale_costs(&[MS, 2 * MS], 2, 8), vec![4 * MS, 8 * MS]);
        // identity batch is a no-op (modulo the >= 1 floor)
        assert_eq!(scale_costs(&[5, 7], 4, 4), vec![5, 7]);
        // a measured 0 still carries unit weight so the partition DP
        // never sees an all-zero interval
        assert_eq!(scale_costs(&[0], 1, 1), vec![1]);
    }

    #[test]
    fn variant_team_spends_the_budget_like_a_one_stage_cut() {
        use crate::exec::StepProfile;
        let g = tiny_cnn(NetConfig::test_scale());
        let plan = ExecutionPlan::build(&g).unwrap();
        let n = plan.steps.len();
        // heavyweight steps: a tail variant is one group in flight, so
        // the budget becomes a team exactly as a max_stages=1 cut would
        let profile = StepProfile::synthetic(&plan, vec![8 * MS; n]);
        let scaled = scale_costs(&profile.costs_ns, profile.batch, 2);
        assert_eq!(
            variant_team(&profile, 2, 4),
            choose_cuts_capped(&scaled, 4, 1).team
        );
        assert!(variant_team(&profile, 2, 4) > 1, "ms-scale steps amortize a team");
        // featherweight steps never spawn a team, tail or not
        let tiny = StepProfile::synthetic(&plan, vec![100; n]);
        assert_eq!(variant_team(&tiny, 4, 16), 1);
    }

    #[test]
    fn tune_plan_profiles_and_chooses_consistently() {
        let mut g = tiny_cnn(NetConfig::test_scale());
        prune_graph(&mut g, 0.6);
        let plan = ExecutionPlan::build(&g).unwrap();
        let opts = TuneOptions {
            cores: 4,
            profile: ProfileOptions { warmup: 0, runs: 1, ..Default::default() },
        };
        let (profile, cuts) = tune_plan(&plan, &opts);
        assert_eq!(profile.costs_ns.len(), plan.steps.len());
        assert!(cuts.stages >= 1 && cuts.stages <= 4);
        assert_eq!(cuts.stages, cuts.ranges.len());
        assert_eq!(choose_cuts(&profile.costs_ns, 4), cuts, "policy must be deterministic");
    }

    #[test]
    fn tune_report_json_shape() {
        let g = tiny_cnn(NetConfig::test_scale());
        let plan = ExecutionPlan::build(&g).unwrap();
        let opts = TuneOptions {
            cores: 2,
            profile: ProfileOptions { warmup: 0, runs: 1, ..Default::default() },
        };
        let entry = TuneEntry::calibrate(&plan, &opts);
        let report = TuneReport {
            model: "tinycnn".into(),
            cores: 2,
            batch: 1,
            chosen_group: 1,
            entries: vec![entry],
        };
        assert!(report.chosen().is_some());
        let parsed = Json::parse(&report.to_json().pretty()).unwrap();
        assert_eq!(parsed.get("model").as_str(), Some("tinycnn"));
        let entries = parsed.get("entries").as_arr().unwrap();
        assert_eq!(entries.len(), 1);
        assert_eq!(entries[0].get("group").as_usize(), Some(1));
        assert!(entries[0].get("profile").get("steps").as_arr().is_some());
        assert!(entries[0].get("ranges").as_arr().is_some());
    }

    /// The artifact cache restores calibration through `from_json`; the
    /// decision-bearing fields must survive a serialize/parse cycle
    /// exactly, or a cached cold start would serve different cuts.
    #[test]
    fn tune_report_json_roundtrips() {
        let g = tiny_cnn(NetConfig::test_scale());
        let plan = ExecutionPlan::build(&g).unwrap();
        let opts = TuneOptions {
            cores: 2,
            profile: ProfileOptions { warmup: 0, runs: 1, ..Default::default() },
        };
        let report = TuneReport {
            model: "tinycnn".into(),
            cores: 2,
            batch: 4,
            chosen_group: 1,
            entries: vec![TuneEntry::calibrate(&plan, &opts)],
        };
        let parsed = Json::parse(&report.to_json().pretty()).unwrap();
        let restored = TuneReport::from_json(&parsed).unwrap();
        assert_eq!(restored.model, report.model);
        assert_eq!(restored.cores, report.cores);
        assert_eq!(restored.batch, report.batch);
        assert_eq!(restored.chosen_group, report.chosen_group);
        assert_eq!(restored.entries.len(), 1);
        assert_eq!(restored.entries[0].cuts, report.entries[0].cuts);
        assert_eq!(restored.entries[0].group, report.entries[0].group);
        assert_eq!(restored.entries[0].model_ranges, report.entries[0].model_ranges);
        assert_eq!(
            restored.entries[0].profile.costs_ns,
            report.entries[0].profile.costs_ns
        );
        assert_eq!(restored.entries[0].profile.names, report.entries[0].profile.names);
    }
}
