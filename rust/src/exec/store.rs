//! Refcounted shared weight store.
//!
//! HPIPE compiles each layer's weights into that layer's own M20K banks
//! exactly once; every consumer of the layer reads the same banks. The
//! software reproduction historically did the opposite: each
//! [`super::ExecutionPlan`] — the primary batched plan, the batch-1
//! latency plan, every plan-family variant, and each of the autotuner's
//! calibration plans — recomputed and privately owned its weight
//! constants, RLE streams and packed panels. A model with plan-family
//! variants therefore paid O(weights) per variant.
//!
//! [`WeightStore`] fixes that: it is a get-or-insert cache of
//! `Arc`-backed compiled weight state, keyed by graph const name (plus
//! encoding parameters for derived forms). Threaded through
//! [`super::ExecutionPlan::build_with_store`], every plan built against
//! the same store shares one copy of:
//!
//! * each const tensor (including build-time folded constants — the
//!   fold decision is graph-deterministic, so a prepopulated store also
//!   skips the fold computation);
//! * each dense packed-panel matrix ([`kernels::PackedB`]);
//! * each RLE encoding ([`ConvRle`]) and its pre-decoded flat form
//!   ([`sparse::PackedRle`]).
//!
//! Batch-*tiled* constants stay plan-private (they depend on the plan's
//! batch dimension); they are the O(arena) part a variant legitimately
//! adds. The store is also the unit of artifact persistence: the
//! `artifact` module serializes a store to `plan.bin` and prepopulates
//! one at load so no `pack_b` / `pack_rle` / fold work runs on a cache
//! hit. Sharing across batch variants is valid because every stored
//! form is batch-independent: panels depend on (weights, k, n), RLE on
//! (weights, splits), and sparse-vs-dense selection on the sparsity
//! threshold alone.

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::graph::{GraphError, Tensor};
use crate::sparsity::rle::ConvRle;

use super::{kernels, sparse};

/// Shared, refcounted compiled-weight state (see module docs). Cloning
/// a store clones the `Arc` handles, not the weights.
#[derive(Clone, Default)]
pub struct WeightStore {
    tensors: BTreeMap<String, Arc<Tensor>>,
    packed_b: BTreeMap<String, Arc<kernels::PackedB>>,
    rle: BTreeMap<String, Arc<ConvRle>>,
    packed_rle: BTreeMap<String, Arc<sparse::PackedRle>>,
}

impl WeightStore {
    pub fn new() -> WeightStore {
        WeightStore::default()
    }

    /// Get-or-insert a const tensor. `make` runs only on a miss (a
    /// prepopulated store never re-clones or re-folds).
    pub fn tensor_with(
        &mut self,
        key: &str,
        make: impl FnOnce() -> Result<Tensor, GraphError>,
    ) -> Result<Arc<Tensor>, GraphError> {
        if let Some(t) = self.tensors.get(key) {
            return Ok(t.clone());
        }
        let t = Arc::new(make()?);
        self.tensors.insert(key.to_string(), t.clone());
        Ok(t)
    }

    /// Get-or-insert a dense packed-panel matrix.
    pub fn packed_b_with(
        &mut self,
        key: &str,
        make: impl FnOnce() -> kernels::PackedB,
    ) -> Arc<kernels::PackedB> {
        self.packed_b
            .entry(key.to_string())
            .or_insert_with(|| Arc::new(make()))
            .clone()
    }

    /// Get-or-insert an RLE weight encoding.
    pub fn rle_with(&mut self, key: &str, make: impl FnOnce() -> ConvRle) -> Arc<ConvRle> {
        self.rle
            .entry(key.to_string())
            .or_insert_with(|| Arc::new(make()))
            .clone()
    }

    /// Get-or-insert a pre-decoded RLE stream.
    pub fn packed_rle_with(
        &mut self,
        key: &str,
        make: impl FnOnce() -> sparse::PackedRle,
    ) -> Arc<sparse::PackedRle> {
        self.packed_rle
            .entry(key.to_string())
            .or_insert_with(|| Arc::new(make()))
            .clone()
    }

    // -- direct inserts (artifact deserialization) --

    pub fn insert_tensor(&mut self, key: &str, t: Tensor) {
        self.tensors.insert(key.to_string(), Arc::new(t));
    }

    pub fn insert_packed_b(&mut self, key: &str, p: kernels::PackedB) {
        self.packed_b.insert(key.to_string(), Arc::new(p));
    }

    pub fn insert_rle(&mut self, key: &str, r: ConvRle) {
        self.rle.insert(key.to_string(), Arc::new(r));
    }

    pub fn insert_packed_rle(&mut self, key: &str, p: sparse::PackedRle) {
        self.packed_rle.insert(key.to_string(), Arc::new(p));
    }

    // -- read access (artifact serialization / introspection) --

    pub fn tensors(&self) -> impl Iterator<Item = (&str, &Arc<Tensor>)> {
        self.tensors.iter().map(|(k, v)| (k.as_str(), v))
    }

    pub fn packed_bs(&self) -> impl Iterator<Item = (&str, &Arc<kernels::PackedB>)> {
        self.packed_b.iter().map(|(k, v)| (k.as_str(), v))
    }

    pub fn rles(&self) -> impl Iterator<Item = (&str, &Arc<ConvRle>)> {
        self.rle.iter().map(|(k, v)| (k.as_str(), v))
    }

    pub fn packed_rles(&self) -> impl Iterator<Item = (&str, &Arc<sparse::PackedRle>)> {
        self.packed_rle.iter().map(|(k, v)| (k.as_str(), v))
    }

    /// Total entries across all four kinds.
    pub fn len(&self) -> usize {
        self.tensors.len() + self.packed_b.len() + self.rle.len() + self.packed_rle.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Resident bytes held by the store — the *shared* side of a
    /// model's `resident_weight_bytes` (plan-private tiled consts and
    /// arenas are accounted per plan).
    pub fn total_bytes(&self) -> usize {
        let tensors: usize = self.tensors.values().map(|t| t.data.len() * 4).sum();
        let panels: usize = self.packed_b.values().map(|p| p.len() * 4).sum();
        // One RLE entry is (u32 runlength, u8 lane, f32 value).
        let rle: usize = self
            .rle
            .values()
            .map(|r| {
                r.streams
                    .iter()
                    .flat_map(|oc| oc.iter())
                    .map(|s| s.entries.len() * 9)
                    .sum::<usize>()
            })
            .sum();
        let prle: usize = self
            .packed_rle
            .values()
            .map(|p| (p.n_bundles() + 1) * 8 + p.nonzeros() * 9)
            .sum();
        tensors + panels + rle + prle
    }

    /// `(key, Arc strong count)` for every entry — lets tests assert
    /// that N plans sharing the store hold exactly one copy of each
    /// weight (every count == N users + 1 for the store itself).
    pub fn refcounts(&self) -> Vec<(String, usize)> {
        let mut out: Vec<(String, usize)> = Vec::with_capacity(self.len());
        out.extend(self.tensors.iter().map(|(k, v)| (format!("tensor:{k}"), Arc::strong_count(v))));
        out.extend(
            self.packed_b.iter().map(|(k, v)| (format!("packed_b:{k}"), Arc::strong_count(v))),
        );
        out.extend(self.rle.iter().map(|(k, v)| (format!("rle:{k}"), Arc::strong_count(v))));
        out.extend(
            self.packed_rle
                .iter()
                .map(|(k, v)| (format!("packed_rle:{k}"), Arc::strong_count(v))),
        );
        out
    }
}
