//! Plan artifacts: compile once, serve anywhere.
//!
//! HPIPE's compiler emits a fully elaborated per-layer datapath — every
//! weight already baked into its layer's M20K banks — and the bitstream
//! is the reusable artifact: synthesis runs once, the board boots from
//! the file. This module is the software analog. A [`ModelArtifact`]
//! captures everything [`crate::runtime::LoadedModel`] computes at
//! compile time that is expensive or measured:
//!
//! * the shared [`WeightStore`] — const tensors (including fold
//!   results), dense packed panels ([`PackedB`]), RLE encodings
//!   ([`ConvRle`]) and pre-decoded streams ([`PackedRle`]);
//! * the pipeline shape of the primary plan and every plan-family
//!   variant: stage count, team size, and the per-step costs the
//!   partition DP consumed (static model costs or autotune-measured
//!   medians — replaying them through the DP reproduces the exact cuts);
//! * the autotuner's [`TuneReport`](crate::exec::TuneReport), so a
//!   cache hit skips calibration profiling entirely.
//!
//! On disk an artifact is a directory holding `plan.json` (structure,
//! offsets, hashes — same dependency-free [`Json`] idiom as
//! `graph.json`) and `plan.bin` (one flat little-endian blob for all
//! weight bytes, same pattern as `weights.bin`).
//!
//! **Invalidation.** `plan.json` records a [`cache_key`]: an FNV-1a 64
//! hash over the graphdef bytes ([`graphdef::to_parts`]), the
//! [`PlanOptions`] knobs, the serving configuration (batch, plan
//! family, threads, team, autotune), and the crate version. The loader
//! recomputes the key from the *request* and rejects on mismatch, so a
//! changed graph, config, or crate silently falls back to a fresh
//! compile — a stale artifact can never serve. `plan.bin` is guarded by
//! its own content hash (`bin_hash`), which catches truncation and
//! bit-flips before any weight byte is trusted; every decoded structure
//! additionally passes through the validating `from_parts`
//! constructors. The ISA tier is recorded for inspection only — SIMD
//! dispatch re-runs on the loading machine, because an artifact
//! compiled on an AVX2 box must serve correctly from a NEON one.
//!
//! **Failure contract.** Every load failure — missing file, bad JSON,
//! wrong format, key mismatch, hash mismatch, out-of-range offset,
//! invalid packed state — returns [`GraphError::Artifact`] and nothing
//! else. Callers (the runtime's plan cache) treat that as "compile
//! fresh"; a rejected artifact is never partially applied.

use std::fs;
use std::path::Path;
use std::sync::Arc;

use crate::exec::kernels::PackedB;
use crate::exec::sparse::PackedRle;
use crate::exec::{PlanOptions, TuneReport, WeightStore};
use crate::graph::{graphdef, Graph, GraphError, Tensor};
use crate::sparsity::rle::{ConvRle, SplitStream, WeightEntry};
use crate::util::Json;

/// Format tag every `plan.json` must lead with.
pub const FORMAT: &str = "hpipe-plan-artifact-v1";

fn bad(msg: impl Into<String>) -> GraphError {
    GraphError::Artifact(msg.into())
}

// ---------------------------------------------------------------------------
// Content hashing
// ---------------------------------------------------------------------------

/// Incremental FNV-1a 64 — small, dependency-free, and stable across
/// platforms; collision resistance is not a goal (artifacts are a local
/// cache, not a trust boundary — `from_parts` validation is the guard).
pub struct Fnv1a64(u64);

impl Fnv1a64 {
    pub fn new() -> Fnv1a64 {
        Fnv1a64(0xcbf2_9ce4_8422_2325)
    }

    pub fn update(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

impl Default for Fnv1a64 {
    fn default() -> Self {
        Fnv1a64::new()
    }
}

/// Hash one byte slice (used for `bin_hash`).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = Fnv1a64::new();
    h.update(bytes);
    h.finish()
}

/// Everything besides the graph that shapes a compiled model — the
/// non-graph half of the invalidation key.
#[derive(Clone, Debug)]
pub struct CacheSpec {
    pub opts: PlanOptions,
    /// Serving batch (the model's `batch`, not the group size — group
    /// size is derived and changes with `threads`, which is hashed too).
    pub batch: usize,
    /// Requested plan-family tail sizes (order-insensitive: sorted and
    /// deduplicated before hashing).
    pub family: Vec<usize>,
    pub threads: usize,
    pub team: usize,
    pub autotune: bool,
    /// Effective autotune core budget (0 when autotune is off) — the
    /// budget changes the chosen cuts, so it must invalidate too.
    pub tune_cores: usize,
}

/// The artifact invalidation key: FNV-1a 64 over the graphdef bytes,
/// every [`PlanOptions`] knob, the serving configuration, and the crate
/// version. Two requests with equal keys compile to interchangeable
/// plans; anything that could change the compiled state changes the key.
pub fn cache_key(graph: &Graph, spec: &CacheSpec) -> u64 {
    let (json, blob) = graphdef::to_parts(graph);
    let mut h = Fnv1a64::new();
    h.update(json.as_bytes());
    h.update(&[0]);
    h.update(&blob);
    let mut family = spec.family.clone();
    family.sort_unstable();
    family.dedup();
    // sparse_threshold hashes by bit pattern: -0.0 vs 0.0 or NaN payloads
    // must not alias distinct configurations.
    let tail = format!(
        "|st={:016x}|fuse={}|splits={}|packed={}|batch={}|threads={}|team={}|autotune={}|cores={}|family={:?}|crate={}",
        spec.opts.sparse_threshold.to_bits(),
        spec.opts.fuse,
        spec.opts.splits,
        spec.opts.packed,
        spec.batch,
        spec.threads,
        spec.team,
        spec.autotune,
        spec.tune_cores,
        family,
        env!("CARGO_PKG_VERSION"),
    );
    h.update(tail.as_bytes());
    h.finish()
}

// ---------------------------------------------------------------------------
// Little-endian blob IO
// ---------------------------------------------------------------------------

struct BlobWriter {
    buf: Vec<u8>,
}

impl BlobWriter {
    fn new() -> BlobWriter {
        BlobWriter { buf: Vec::new() }
    }

    fn offset(&self) -> usize {
        self.buf.len()
    }

    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
}

struct BlobReader<'a> {
    buf: &'a [u8],
    pos: usize,
    end: usize,
}

impl<'a> BlobReader<'a> {
    /// A reader over `buf[offset..offset + len]`; rejects out-of-range
    /// sections up front so a lying manifest can't walk off the blob.
    fn section(buf: &'a [u8], offset: usize, len: usize) -> Result<BlobReader<'a>, GraphError> {
        let end = offset
            .checked_add(len)
            .filter(|&e| e <= buf.len())
            .ok_or_else(|| bad(format!("blob section {offset}+{len} exceeds {}", buf.len())))?;
        Ok(BlobReader { buf, pos: offset, end })
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], GraphError> {
        let next = self
            .pos
            .checked_add(n)
            .filter(|&e| e <= self.end)
            .ok_or_else(|| bad("blob section truncated"))?;
        let s = &self.buf[self.pos..next];
        self.pos = next;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, GraphError> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, GraphError> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64, GraphError> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    fn f32(&mut self) -> Result<f32, GraphError> {
        let b = self.take(4)?;
        Ok(f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn f32_vec(&mut self, n: usize) -> Result<Vec<f32>, GraphError> {
        let b = self.take(n.checked_mul(4).ok_or_else(|| bad("f32 count overflow"))?)?;
        Ok(b.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
    }

    fn done(&self) -> Result<(), GraphError> {
        if self.pos != self.end {
            return Err(bad(format!("blob section has {} trailing bytes", self.end - self.pos)));
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Artifact model
// ---------------------------------------------------------------------------

/// The restorable shape of one pipeline (primary plan or a plan-family
/// variant): the batch its plan was compiled for, the stage/team split,
/// and the per-step costs the partitioner consumed. Replaying
/// `costs_ns` through
/// [`PipelinePlan::from_static_costs`](crate::exec::PipelinePlan::from_static_costs)
/// reproduces the exact cuts — measured autotune costs and modeled
/// static costs restore through the same door.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PipelineSpec {
    pub batch: usize,
    pub stages: usize,
    pub team: usize,
    pub costs_ns: Vec<u64>,
}

impl PipelineSpec {
    fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("batch", Json::from(self.batch)),
            ("stages", Json::from(self.stages)),
            ("team", Json::from(self.team)),
            (
                "costs_ns",
                Json::Arr(self.costs_ns.iter().map(|&c| Json::Num(c as f64)).collect()),
            ),
        ])
    }

    fn from_json(j: &Json) -> Result<PipelineSpec, GraphError> {
        let field = |k: &str| j.get(k).as_usize().ok_or_else(|| bad(format!("pipeline: bad {k}")));
        let costs = j.get("costs_ns").as_arr().ok_or_else(|| bad("pipeline: missing costs_ns"))?;
        let costs_ns = costs
            .iter()
            .map(|c| match c.as_f64() {
                Some(ns) if ns.is_finite() && ns >= 0.0 => Ok(ns as u64),
                _ => Err(bad("pipeline: cost out of range")),
            })
            .collect::<Result<Vec<u64>, GraphError>>()?;
        let spec = PipelineSpec {
            batch: field("batch")?,
            stages: field("stages")?,
            team: field("team")?,
            costs_ns,
        };
        if spec.batch == 0 || spec.stages == 0 || spec.team == 0 {
            return Err(bad("pipeline: zero batch/stages/team"));
        }
        if spec.stages > spec.costs_ns.len() {
            return Err(bad("pipeline: more stages than steps"));
        }
        Ok(spec)
    }
}

/// A fully compiled model, detached from any process: everything
/// [`crate::runtime::LoadedModel::from_artifact`] needs to rebuild its
/// plans without packing, encoding, folding, or profiling.
pub struct ModelArtifact {
    /// The [`cache_key`] this artifact was compiled under.
    pub key: u64,
    /// ISA tier active at compile time — informational only; load
    /// re-dispatches on the local CPU.
    pub isa: String,
    pub batch: usize,
    pub threads: usize,
    pub team: usize,
    /// Primary serving pipeline (its `batch` is the group size).
    pub primary: PipelineSpec,
    /// Ragged-tail plan-family variants, ascending batch.
    pub variants: Vec<PipelineSpec>,
    /// Whether the model carries a separate batch-1 latency plan.
    pub has_latency: bool,
    /// Autotune calibration report, if the model was autotuned.
    pub tune: Option<TuneReport>,
    /// The shared weight store backing every plan above.
    pub store: WeightStore,
}

// ---------------------------------------------------------------------------
// Save
// ---------------------------------------------------------------------------

/// Write `art` to `dir/plan.json` + `dir/plan.bin`. The store manifest
/// and blob iterate `BTreeMap`s, so byte output is deterministic for a
/// given artifact.
pub fn save(dir: &Path, art: &ModelArtifact) -> Result<(), GraphError> {
    let mut blob = BlobWriter::new();

    let mut tensors = Json::Arr(vec![]);
    for (key, t) in art.store.tensors() {
        let offset = blob.offset();
        for &x in &t.data {
            blob.f32(x);
        }
        tensors.push(Json::from_pairs(vec![
            ("key", Json::from(key)),
            ("shape", Json::from(t.shape.clone())),
            ("offset", Json::from(offset)),
            ("len", Json::from(blob.offset() - offset)),
        ]));
    }

    let mut packed_b = Json::Arr(vec![]);
    for (key, p) in art.store.packed_bs() {
        let offset = blob.offset();
        for &x in p.data() {
            blob.f32(x);
        }
        packed_b.push(Json::from_pairs(vec![
            ("key", Json::from(key)),
            ("k", Json::from(p.k)),
            ("n", Json::from(p.n)),
            ("offset", Json::from(offset)),
            ("len", Json::from(blob.offset() - offset)),
        ]));
    }

    let mut rle = Json::Arr(vec![]);
    for (key, r) in art.store.rles() {
        let offset = blob.offset();
        for oc in &r.streams {
            for s in oc {
                blob.u32(s.entries.len() as u32);
                blob.u32(s.nonzeros as u32);
                for e in &s.entries {
                    blob.u32(e.runlength);
                    blob.u8(e.x);
                    blob.f32(e.value);
                }
            }
        }
        rle.push(Json::from_pairs(vec![
            ("key", Json::from(key)),
            ("kh", Json::from(r.kh)),
            ("kw", Json::from(r.kw)),
            ("ci", Json::from(r.ci)),
            ("co", Json::from(r.co)),
            ("splits", Json::from(r.splits)),
            ("offset", Json::from(offset)),
            ("len", Json::from(blob.offset() - offset)),
        ]));
    }

    let mut packed_rle = Json::Arr(vec![]);
    for (key, p) in art.store.packed_rles() {
        let offset = blob.offset();
        for &s in p.starts() {
            blob.u64(s as u64);
        }
        for &k in p.ks() {
            blob.u32(k);
        }
        for &l in p.lanes() {
            blob.u8(l);
        }
        for &v in p.vals() {
            blob.f32(v);
        }
        packed_rle.push(Json::from_pairs(vec![
            ("key", Json::from(key)),
            ("co", Json::from(p.co)),
            ("k", Json::from(p.k)),
            ("nnz", Json::from(p.nonzeros())),
            ("n_starts", Json::from(p.starts().len())),
            ("offset", Json::from(offset)),
            ("len", Json::from(blob.offset() - offset)),
        ]));
    }

    let store = Json::from_pairs(vec![
        ("tensors", tensors),
        ("packed_b", packed_b),
        ("rle", rle),
        ("packed_rle", packed_rle),
    ]);

    let mut root = Json::obj();
    root.set("format", Json::from(FORMAT))
        .set("key", Json::from(format!("{:016x}", art.key).as_str()))
        .set("bin_hash", Json::from(format!("{:016x}", fnv1a64(&blob.buf)).as_str()))
        .set("isa", Json::from(art.isa.as_str()))
        .set("batch", Json::from(art.batch))
        .set("threads", Json::from(art.threads))
        .set("team", Json::from(art.team))
        .set("has_latency", Json::from(art.has_latency))
        .set("primary", art.primary.to_json())
        .set(
            "variants",
            Json::Arr(art.variants.iter().map(|v| v.to_json()).collect()),
        )
        .set("tune", art.tune.as_ref().map(|t| t.to_json()).unwrap_or(Json::Null))
        .set("store", store);

    fs::create_dir_all(dir).map_err(|e| bad(format!("creating {}: {e}", dir.display())))?;
    fs::write(dir.join("plan.json"), root.pretty())
        .map_err(|e| bad(format!("writing plan.json: {e}")))?;
    fs::write(dir.join("plan.bin"), &blob.buf)
        .map_err(|e| bad(format!("writing plan.bin: {e}")))?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Load
// ---------------------------------------------------------------------------

fn hex_u64(j: &Json, field: &str) -> Result<u64, GraphError> {
    let s = j.get(field).as_str().ok_or_else(|| bad(format!("missing {field}")))?;
    u64::from_str_radix(s, 16).map_err(|_| bad(format!("{field} is not a hex hash")))
}

fn entry_usize(e: &Json, field: &str) -> Result<usize, GraphError> {
    e.get(field).as_usize().ok_or_else(|| bad(format!("store entry: bad {field}")))
}

fn entry_key(e: &Json) -> Result<&str, GraphError> {
    e.get("key").as_str().ok_or_else(|| bad("store entry: missing key"))
}

/// Load and validate the artifact at `dir`, rejecting anything whose
/// key differs from `expect_key` (the key recomputed from the *current*
/// graph + config — the invalidation check). All failures are
/// [`GraphError::Artifact`]; the caller falls back to a fresh compile.
pub fn load(dir: &Path, expect_key: u64) -> Result<ModelArtifact, GraphError> {
    let text = fs::read_to_string(dir.join("plan.json"))
        .map_err(|e| bad(format!("reading {}: {e}", dir.join("plan.json").display())))?;
    let root = Json::parse(&text).map_err(|e| bad(format!("plan.json: {e}")))?;
    if root.get("format").as_str() != Some(FORMAT) {
        return Err(bad("unrecognized plan artifact format"));
    }
    let key = hex_u64(&root, "key")?;
    if key != expect_key {
        return Err(bad(format!(
            "stale artifact: key {key:016x} != expected {expect_key:016x} \
             (graph, options, or crate version changed)"
        )));
    }
    let bin_path = dir.join("plan.bin");
    let blob: Vec<u8> = if bin_path.exists() {
        fs::read(&bin_path).map_err(|e| bad(format!("reading plan.bin: {e}")))?
    } else {
        Vec::new()
    };
    let bin_hash = hex_u64(&root, "bin_hash")?;
    let got = fnv1a64(&blob);
    if got != bin_hash {
        return Err(bad(format!(
            "plan.bin content hash {got:016x} != recorded {bin_hash:016x} \
             (truncated or corrupted)"
        )));
    }

    let mut store = WeightStore::new();
    let jstore = root.get("store");
    let arr = |field: &str| -> Result<&[Json], GraphError> {
        jstore.get(field).as_arr().ok_or_else(|| bad(format!("store: missing {field}")))
    };

    for e in arr("tensors")? {
        let key = entry_key(e)?;
        let shape = e.get("shape").usize_vec().ok_or_else(|| bad("tensor entry: bad shape"))?;
        let n: usize = shape.iter().product();
        let mut r = BlobReader::section(&blob, entry_usize(e, "offset")?, entry_usize(e, "len")?)?;
        let data = r.f32_vec(n)?;
        r.done()?;
        store.insert_tensor(key, Tensor::from_vec(&shape, data));
    }

    for e in arr("packed_b")? {
        let key = entry_key(e)?;
        let (k, n) = (entry_usize(e, "k")?, entry_usize(e, "n")?);
        let len = entry_usize(e, "len")?;
        let mut r = BlobReader::section(&blob, entry_usize(e, "offset")?, len)?;
        let data = r.f32_vec(len / 4)?;
        r.done()?;
        let p = PackedB::from_parts(k, n, data).map_err(|e| bad(format!("{key}: {e}")))?;
        store.insert_packed_b(key, p);
    }

    for e in arr("rle")? {
        let key = entry_key(e)?;
        let (kh, kw) = (entry_usize(e, "kh")?, entry_usize(e, "kw")?);
        let (ci, co) = (entry_usize(e, "ci")?, entry_usize(e, "co")?);
        let splits = entry_usize(e, "splits")?;
        if splits == 0 {
            return Err(bad(format!("{key}: zero splits")));
        }
        let mut r = BlobReader::section(&blob, entry_usize(e, "offset")?, entry_usize(e, "len")?)?;
        let mut streams = Vec::with_capacity(co);
        for _ in 0..co {
            let mut per_split = Vec::with_capacity(splits);
            for _ in 0..splits {
                let n_entries = r.u32()? as usize;
                let nonzeros = r.u32()? as usize;
                if nonzeros > n_entries {
                    return Err(bad(format!("{key}: stream nonzeros exceed entries")));
                }
                let mut entries = Vec::with_capacity(n_entries);
                for _ in 0..n_entries {
                    let runlength = r.u32()?;
                    let x = r.u8()?;
                    let value = r.f32()?;
                    if (x as usize) >= kw.max(1) {
                        return Err(bad(format!("{key}: entry x out of kernel width")));
                    }
                    entries.push(WeightEntry { runlength, x, value });
                }
                per_split.push(SplitStream { entries, nonzeros });
            }
            streams.push(per_split);
        }
        r.done()?;
        store.insert_rle(key, ConvRle { kh, kw, ci, co, splits, streams });
    }

    for e in arr("packed_rle")? {
        let key = entry_key(e)?;
        let (co, k) = (entry_usize(e, "co")?, entry_usize(e, "k")?);
        let (nnz, n_starts) = (entry_usize(e, "nnz")?, entry_usize(e, "n_starts")?);
        let mut r = BlobReader::section(&blob, entry_usize(e, "offset")?, entry_usize(e, "len")?)?;
        let mut starts = Vec::with_capacity(n_starts);
        for _ in 0..n_starts {
            starts.push(r.u64()? as usize);
        }
        let mut ks = Vec::with_capacity(nnz);
        for _ in 0..nnz {
            ks.push(r.u32()?);
        }
        let lanes = r.take(nnz)?.to_vec();
        let mut vals = Vec::with_capacity(nnz);
        for _ in 0..nnz {
            vals.push(r.f32()?);
        }
        r.done()?;
        let p = PackedRle::from_parts(co, k, starts, ks, lanes, vals)
            .map_err(|e| bad(format!("{key}: {e}")))?;
        store.insert_packed_rle(key, p);
    }

    let primary = PipelineSpec::from_json(&root.get("primary"))?;
    let variants = root
        .get("variants")
        .as_arr()
        .ok_or_else(|| bad("missing variants"))?
        .iter()
        .map(PipelineSpec::from_json)
        .collect::<Result<Vec<_>, GraphError>>()?;
    let tune = match root.get("tune") {
        Json::Null => None,
        j => Some(TuneReport::from_json(j).map_err(bad)?),
    };
    let field = |k: &str| root.get(k).as_usize().ok_or_else(|| bad(format!("missing {k}")));

    Ok(ModelArtifact {
        key,
        isa: root.get("isa").as_str().unwrap_or("unknown").to_string(),
        batch: field("batch")?,
        threads: field("threads")?,
        team: field("team")?,
        primary,
        variants,
        has_latency: root.get("has_latency").as_bool().unwrap_or(false),
        tune,
        store,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::ExecutionPlan;
    use crate::nets::{tiny_cnn, NetConfig};
    use crate::sparsity::prune_graph;

    fn temp_dir(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("hpipe_artifact_{tag}_{}", std::process::id()))
    }

    fn spec() -> CacheSpec {
        CacheSpec {
            opts: PlanOptions::default(),
            batch: 4,
            family: vec![2],
            threads: 2,
            team: 1,
            autotune: false,
            tune_cores: 0,
        }
    }

    fn build_artifact() -> (Graph, ModelArtifact) {
        let mut g = tiny_cnn(NetConfig::test_scale());
        prune_graph(&mut g, 0.6);
        let mut store = WeightStore::new();
        let plan =
            ExecutionPlan::build_with_store(&g, &PlanOptions::batched(2), &mut store).unwrap();
        let costs = plan.step_costs();
        let art = ModelArtifact {
            key: cache_key(&g, &spec()),
            isa: crate::exec::isa::active().name().to_string(),
            batch: 4,
            threads: 2,
            team: 1,
            primary: PipelineSpec { batch: 2, stages: 2, team: 1, costs_ns: costs },
            variants: vec![],
            has_latency: true,
            tune: None,
            store,
        };
        (g, art)
    }

    #[test]
    fn key_is_sensitive_to_graph_options_and_family_order_insensitive() {
        let g = tiny_cnn(NetConfig::test_scale());
        let base = cache_key(&g, &spec());
        // same request hashes the same
        assert_eq!(base, cache_key(&g, &spec()));
        // family order must not matter
        let mut s = spec();
        s.family = vec![2, 3];
        let mut s2 = spec();
        s2.family = vec![3, 2];
        assert_eq!(cache_key(&g, &s), cache_key(&g, &s2));
        // but the set does
        assert_ne!(cache_key(&g, &s), base);
        // options matter
        let mut s3 = spec();
        s3.opts.sparse_threshold = 0.9;
        assert_ne!(cache_key(&g, &s3), base);
        // the graph matters
        let mut g2 = tiny_cnn(NetConfig::test_scale());
        prune_graph(&mut g2, 0.5);
        assert_ne!(cache_key(&g2, &spec()), base);
    }

    #[test]
    fn save_load_roundtrips_store_and_specs() {
        let (_, art) = build_artifact();
        let dir = temp_dir("rt");
        save(&dir, &art).unwrap();
        let back = load(&dir, art.key).unwrap();
        assert_eq!(back.key, art.key);
        assert_eq!(back.primary, art.primary);
        assert_eq!(back.has_latency, art.has_latency);
        assert_eq!(back.store.len(), art.store.len());
        assert_eq!(back.store.total_bytes(), art.store.total_bytes());
        for ((ka, ta), (kb, tb)) in art.store.tensors().zip(back.store.tensors()) {
            assert_eq!(ka, kb);
            assert_eq!(ta.shape, tb.shape);
            assert_eq!(ta.data, tb.data);
        }
        for ((ka, pa), (kb, pb)) in art.store.packed_bs().zip(back.store.packed_bs()) {
            assert_eq!(ka, kb);
            assert_eq!(pa.data(), pb.data());
        }
        for ((ka, pa), (kb, pb)) in art.store.packed_rles().zip(back.store.packed_rles()) {
            assert_eq!(ka, kb);
            assert_eq!(pa.vals(), pb.vals());
            assert_eq!(pa.ks(), pb.ks());
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn wrong_key_truncation_and_bitflip_all_reject_typed() {
        let (_, art) = build_artifact();
        let dir = temp_dir("corrupt");
        save(&dir, &art).unwrap();

        // stale key
        let err = load(&dir, art.key ^ 1).unwrap_err();
        assert!(matches!(err, GraphError::Artifact(_)), "stale key: {err:?}");

        // truncation
        let bin = std::fs::read(dir.join("plan.bin")).unwrap();
        std::fs::write(dir.join("plan.bin"), &bin[..bin.len() / 2]).unwrap();
        let err = load(&dir, art.key).unwrap_err();
        assert!(matches!(err, GraphError::Artifact(_)), "truncation: {err:?}");

        // single bit flip
        let mut flipped = bin.clone();
        flipped[bin.len() / 3] ^= 0x10;
        std::fs::write(dir.join("plan.bin"), &flipped).unwrap();
        let err = load(&dir, art.key).unwrap_err();
        assert!(matches!(err, GraphError::Artifact(_)), "bit flip: {err:?}");

        // garbage JSON
        std::fs::write(dir.join("plan.bin"), &bin).unwrap();
        std::fs::write(dir.join("plan.json"), "{ not json").unwrap();
        let err = load(&dir, art.key).unwrap_err();
        assert!(matches!(err, GraphError::Artifact(_)), "bad json: {err:?}");

        std::fs::remove_dir_all(&dir).unwrap();
    }
}
