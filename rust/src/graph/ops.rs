//! The operation set of the HPIPE network compiler.
//!
//! §V of the paper: "We have implemented and verified modules that can
//! execute the TensorFlow Placeholder, Conv2D, DepthwiseConv2D, MatMul,
//! BiasAdd, MaxPool, Relu, Relu6, Add, and Mean operations." We mirror
//! that op set, plus the ops that exist only *during* compilation:
//! `Const` (weight tensors), `FusedBatchNorm` and `Pad` (both folded away
//! by the transform passes), and the `Mul`/`AddC` pair a batch norm is
//! split into on its way to being folded.

use crate::util::Json;

/// Spatial padding specification for Conv2D / DepthwiseConv2d / MaxPool.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Padding {
    /// TensorFlow SAME: output dim = ceil(in / stride).
    Same,
    /// TensorFlow VALID: no padding.
    Valid,
    /// Explicit (top, bottom, left, right) — produced by pad-merging.
    Explicit(usize, usize, usize, usize),
}

impl Padding {
    /// Resolve to concrete (top, bottom, left, right) amounts for a given
    /// input size, kernel size and stride (TF SAME semantics).
    pub fn resolve(
        &self,
        in_h: usize,
        in_w: usize,
        kh: usize,
        kw: usize,
        sh: usize,
        sw: usize,
    ) -> (usize, usize, usize, usize) {
        match *self {
            Padding::Valid => (0, 0, 0, 0),
            Padding::Explicit(t, b, l, r) => (t, b, l, r),
            Padding::Same => {
                let pad_along = |input: usize, k: usize, s: usize| -> usize {
                    let out = input.div_ceil(s);
                    ((out - 1) * s + k).saturating_sub(input)
                };
                let ph = pad_along(in_h, kh, sh);
                let pw = pad_along(in_w, kw, sw);
                (ph / 2, ph - ph / 2, pw / 2, pw - pw / 2)
            }
        }
    }

    pub fn to_json(&self) -> Json {
        match *self {
            Padding::Same => Json::from("SAME"),
            Padding::Valid => Json::from("VALID"),
            Padding::Explicit(t, b, l, r) => Json::from(vec![t, b, l, r]),
        }
    }

    pub fn from_json(j: &Json) -> Option<Padding> {
        match j {
            Json::Str(s) if s == "SAME" => Some(Padding::Same),
            Json::Str(s) if s == "VALID" => Some(Padding::Valid),
            Json::Arr(_) => {
                let v = j.usize_vec()?;
                if v.len() == 4 {
                    Some(Padding::Explicit(v[0], v[1], v[2], v[3]))
                } else {
                    None
                }
            }
            _ => None,
        }
    }
}

/// One graph operation. Weight/constant inputs are separate `Const` nodes
/// referenced by name, exactly like a TensorFlow graphdef.
#[derive(Clone, Debug, PartialEq)]
pub enum Op {
    /// Network input; attribute is the NHWC shape (batch always 1 in the
    /// pipeline — HPIPE is a batch-1 architecture).
    Placeholder { shape: Vec<usize> },
    /// Weight / constant tensor (value stored on the node).
    Const,
    /// 2D convolution. inputs = [activations, weights(HWIO)].
    Conv2D { stride: (usize, usize), padding: Padding },
    /// Depthwise 2D convolution. inputs = [activations, weights(HWIM)].
    DepthwiseConv2d { stride: (usize, usize), padding: Padding },
    /// inputs = [activations(N,Ci), weights(Ci,Co)].
    MatMul,
    /// inputs = [activations, bias(C)].
    BiasAdd,
    MaxPool { ksize: (usize, usize), stride: (usize, usize), padding: Padding },
    Relu,
    Relu6,
    /// Elementwise residual add of two producer activations.
    Add,
    /// Mean over spatial dims (global average pool): NHWC -> N,C.
    Mean,
    /// inputs = [x, scale(C), offset(C), mean(C), variance(C)].
    FusedBatchNorm { epsilon: f32 },
    /// Standalone spatial zero-padding (top, bottom, left, right).
    Pad { pads: (usize, usize, usize, usize) },
    /// Per-channel multiply by a Const (BN decomposition artifact).
    Mul,
    /// Per-channel add of a Const (BN decomposition artifact). Distinct
    /// from `Add` (which merges two activation paths) and `BiasAdd`
    /// (which this is folded into).
    AddC,
    /// Final classifier softmax (host-side in HPIPE; kept for parity with
    /// the TF graph and the JAX model).
    Softmax,
}

impl Op {
    /// The TF-style op-type string used in graphdef JSON.
    pub fn type_name(&self) -> &'static str {
        match self {
            Op::Placeholder { .. } => "Placeholder",
            Op::Const => "Const",
            Op::Conv2D { .. } => "Conv2D",
            Op::DepthwiseConv2d { .. } => "DepthwiseConv2dNative",
            Op::MatMul => "MatMul",
            Op::BiasAdd => "BiasAdd",
            Op::MaxPool { .. } => "MaxPool",
            Op::Relu => "Relu",
            Op::Relu6 => "Relu6",
            Op::Add => "Add",
            Op::Mean => "Mean",
            Op::FusedBatchNorm { .. } => "FusedBatchNorm",
            Op::Pad { .. } => "Pad",
            Op::Mul => "Mul",
            Op::AddC => "AddC",
            Op::Softmax => "Softmax",
        }
    }

    /// Does this op consume weights through a Const input that occupies
    /// DSPs when mapped to hardware? (The compiler's balancer only
    /// considers these.)
    pub fn is_compute(&self) -> bool {
        matches!(
            self,
            Op::Conv2D { .. } | Op::DepthwiseConv2d { .. } | Op::MatMul
        )
    }

    /// Ops that buffer input lines in hardware (have an Input Activation
    /// Buffer per §V) vs. ops that stream through combinationally.
    pub fn buffers_input(&self) -> bool {
        matches!(
            self,
            Op::Conv2D { .. }
                | Op::DepthwiseConv2d { .. }
                | Op::MaxPool { .. }
                | Op::MatMul
                | Op::Add
                | Op::Placeholder { .. }
                | Op::Mean
        )
    }

    pub fn attrs_to_json(&self) -> Json {
        let mut a = Json::obj();
        match self {
            Op::Placeholder { shape } => {
                a.set("shape", Json::from(shape.clone()));
            }
            Op::Conv2D { stride, padding } | Op::DepthwiseConv2d { stride, padding } => {
                a.set("stride", Json::from(vec![stride.0, stride.1]));
                a.set("padding", padding.to_json());
            }
            Op::MaxPool { ksize, stride, padding } => {
                a.set("ksize", Json::from(vec![ksize.0, ksize.1]));
                a.set("stride", Json::from(vec![stride.0, stride.1]));
                a.set("padding", padding.to_json());
            }
            Op::FusedBatchNorm { epsilon } => {
                a.set("epsilon", Json::from(*epsilon as f64));
            }
            Op::Pad { pads } => {
                a.set(
                    "pads",
                    Json::from(vec![pads.0, pads.1, pads.2, pads.3]),
                );
            }
            _ => {}
        }
        a
    }

    pub fn from_json(type_name: &str, attrs: &Json) -> Option<Op> {
        let stride = || -> Option<(usize, usize)> {
            let v = attrs.get("stride").usize_vec()?;
            Some((v[0], v[1]))
        };
        let padding = || Padding::from_json(attrs.get("padding"));
        Some(match type_name {
            "Placeholder" => Op::Placeholder {
                shape: attrs.get("shape").usize_vec()?,
            },
            "Const" => Op::Const,
            "Conv2D" => Op::Conv2D {
                stride: stride()?,
                padding: padding()?,
            },
            "DepthwiseConv2dNative" => Op::DepthwiseConv2d {
                stride: stride()?,
                padding: padding()?,
            },
            "MatMul" => Op::MatMul,
            "BiasAdd" => Op::BiasAdd,
            "MaxPool" => {
                let k = attrs.get("ksize").usize_vec()?;
                Op::MaxPool {
                    ksize: (k[0], k[1]),
                    stride: stride()?,
                    padding: padding()?,
                }
            }
            "Relu" => Op::Relu,
            "Relu6" => Op::Relu6,
            "Add" => Op::Add,
            "Mean" => Op::Mean,
            "FusedBatchNorm" => Op::FusedBatchNorm {
                epsilon: attrs.get("epsilon").as_f64()? as f32,
            },
            "Pad" => {
                let p = attrs.get("pads").usize_vec()?;
                Op::Pad {
                    pads: (p[0], p[1], p[2], p[3]),
                }
            }
            "Mul" => Op::Mul,
            "AddC" => Op::AddC,
            "Softmax" => Op::Softmax,
            _ => return None,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_padding_resolution() {
        // 224x224 input, 7x7 kernel, stride 2 (ResNet-50 conv1):
        // out = 112, pad_total = (112-1)*2 + 7 - 224 = 5 -> (2,3)
        let p = Padding::Same.resolve(224, 224, 7, 7, 2, 2);
        assert_eq!(p, (2, 3, 2, 3));
        // 3x3 stride 1: symmetric 1.
        assert_eq!(Padding::Same.resolve(56, 56, 3, 3, 1, 1), (1, 1, 1, 1));
        // 1x1 never pads.
        assert_eq!(Padding::Same.resolve(56, 56, 1, 1, 1, 1), (0, 0, 0, 0));
    }

    #[test]
    fn valid_padding_is_zero() {
        assert_eq!(Padding::Valid.resolve(10, 10, 3, 3, 1, 1), (0, 0, 0, 0));
    }

    #[test]
    fn padding_json_roundtrip() {
        for p in [
            Padding::Same,
            Padding::Valid,
            Padding::Explicit(1, 2, 3, 4),
        ] {
            assert_eq!(Padding::from_json(&p.to_json()), Some(p));
        }
    }

    #[test]
    fn op_json_roundtrip() {
        let ops = vec![
            Op::Placeholder { shape: vec![1, 224, 224, 3] },
            Op::Const,
            Op::Conv2D { stride: (2, 2), padding: Padding::Same },
            Op::DepthwiseConv2d { stride: (1, 1), padding: Padding::Explicit(1, 1, 1, 1) },
            Op::MatMul,
            Op::BiasAdd,
            Op::MaxPool { ksize: (3, 3), stride: (2, 2), padding: Padding::Same },
            Op::Relu,
            Op::Relu6,
            Op::Add,
            Op::Mean,
            Op::FusedBatchNorm { epsilon: 1e-3 },
            Op::Pad { pads: (0, 1, 0, 1) },
            Op::Mul,
            Op::AddC,
            Op::Softmax,
        ];
        for op in ops {
            let back = Op::from_json(op.type_name(), &op.attrs_to_json()).unwrap();
            assert_eq!(back, op);
        }
    }

    #[test]
    fn compute_classification() {
        assert!(Op::Conv2D { stride: (1, 1), padding: Padding::Same }.is_compute());
        assert!(Op::MatMul.is_compute());
        assert!(!Op::Relu.is_compute());
        assert!(!Op::BiasAdd.is_compute());
    }
}
