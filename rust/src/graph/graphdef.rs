//! Graphdef serialization: `graph.json` + `weights.bin`.
//!
//! This is the interchange format between the Rust compiler and the JAX
//! model builder (`python/compile/model.py`): a JSON structural
//! description plus a flat little-endian f32 blob holding every Const
//! tensor, referenced by (offset, len) so a 25M-parameter ResNet does not
//! get pretty-printed into JSON. Small constants (≤ [`INLINE_LIMIT`]
//! elements) are inlined for readability.

use super::{Graph, Node, Op, Tensor};
use crate::util::error::{Context, Result};
use crate::util::Json;
use crate::bail;
use std::fs;
use std::path::Path;

/// Constants with at most this many elements are stored inline in JSON.
pub const INLINE_LIMIT: usize = 16;

/// Serialize a graph to `dir/graph.json` (+ `dir/weights.bin` if any
/// Const tensor exceeds the inline limit).
pub fn save(graph: &Graph, dir: &Path) -> Result<()> {
    fs::create_dir_all(dir)?;
    let (json, blob) = to_parts(graph);
    fs::write(dir.join("graph.json"), json)?;
    if !blob.is_empty() {
        fs::write(dir.join("weights.bin"), &blob)?;
    }
    Ok(())
}

/// Serialize a graph to its in-memory `(graph.json text, weights.bin
/// blob)` pair — the exact bytes [`save`] writes. Separated from the
/// filesystem so the artifact cache can content-hash a graph without
/// touching disk.
pub fn to_parts(graph: &Graph) -> (String, Vec<u8>) {
    let mut blob: Vec<u8> = Vec::new();
    let mut nodes = Json::Arr(vec![]);
    for n in &graph.nodes {
        let mut jn = Json::obj();
        jn.set("name", Json::from(n.name.as_str()))
            .set("op", Json::from(n.op.type_name()))
            .set("attrs", n.op.attrs_to_json())
            .set(
                "inputs",
                Json::Arr(n.inputs.iter().map(|s| Json::from(s.as_str())).collect()),
            );
        if let Some(t) = &n.value {
            let mut jt = Json::obj();
            jt.set("shape", Json::from(t.shape.clone()));
            if t.len() <= INLINE_LIMIT {
                jt.set(
                    "data",
                    Json::Arr(t.data.iter().map(|&x| Json::Num(x as f64)).collect()),
                );
            } else {
                jt.set("offset", Json::from(blob.len() / 4))
                    .set("len", Json::from(t.len()));
                for &x in &t.data {
                    blob.extend_from_slice(&x.to_le_bytes());
                }
            }
            jn.set("tensor", jt);
        }
        nodes.push(jn);
    }
    let mut root = Json::obj();
    root.set("format", Json::from("hpipe-graphdef-v1"))
        .set("nodes", nodes)
        .set(
            "outputs",
            Json::Arr(graph.outputs.iter().map(|s| Json::from(s.as_str())).collect()),
        );
    (root.pretty(), blob)
}

/// Load a graph from a directory written by [`save`] (or by the Python
/// side's `graphio.py`, which emits the same format).
pub fn load(dir: &Path) -> Result<Graph> {
    let text = fs::read_to_string(dir.join("graph.json"))
        .with_context(|| format!("reading {}", dir.join("graph.json").display()))?;
    let blob_path = dir.join("weights.bin");
    let blob: Vec<u8> = if blob_path.exists() {
        fs::read(&blob_path)?
    } else {
        Vec::new()
    };
    from_parts(&text, &blob)
}

/// Parse a graph from its in-memory `(graph.json text, weights.bin
/// blob)` pair — the inverse of [`to_parts`].
pub fn from_parts(text: &str, blob: &[u8]) -> Result<Graph> {
    let root = Json::parse(text)?;
    if root.get("format").as_str() != Some("hpipe-graphdef-v1") {
        bail!("unrecognized graphdef format");
    }

    let mut graph = Graph::new();
    for jn in root.get("nodes").as_arr().context("nodes array")? {
        let name = jn.get("name").as_str().context("node name")?.to_string();
        let op_type = jn.get("op").as_str().context("op type")?;
        let op = Op::from_json(op_type, jn.get("attrs"))
            .with_context(|| format!("node '{name}': unknown op '{op_type}'"))?;
        let inputs: Vec<String> = jn
            .get("inputs")
            .as_arr()
            .context("inputs")?
            .iter()
            .map(|v| v.as_str().map(|s| s.to_string()))
            .collect::<Option<_>>()
            .context("input names")?;
        let value = match jn.get("tensor") {
            Json::Null => None,
            jt => {
                let shape = jt.get("shape").usize_vec().context("tensor shape")?;
                let n: usize = shape.iter().product();
                let data: Vec<f32> = if let Some(inline) = jt.get("data").f32_vec() {
                    inline
                } else {
                    let offset = jt.get("offset").as_usize().context("tensor offset")? * 4;
                    let len = jt.get("len").as_usize().context("tensor len")? * 4;
                    if offset + len > blob.len() {
                        bail!("tensor '{name}' out of range of weights.bin");
                    }
                    blob[offset..offset + len]
                        .chunks_exact(4)
                        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                        .collect()
                };
                if data.len() != n {
                    bail!(
                        "tensor '{name}': shape {shape:?} needs {n} elements, got {}",
                        data.len()
                    );
                }
                Some(Tensor::from_vec(&shape, data))
            }
        };
        graph.add(Node { name, op, inputs, value });
    }
    graph.outputs = root
        .get("outputs")
        .as_arr()
        .context("outputs")?
        .iter()
        .map(|v| v.as_str().map(|s| s.to_string()))
        .collect::<Option<_>>()
        .context("output names")?;
    graph.validate()?;
    Ok(graph)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Padding;
    use crate::util::Rng;

    fn build() -> Graph {
        let mut g = Graph::new();
        let mut rng = Rng::new(3);
        g.op("input", Op::Placeholder { shape: vec![1, 6, 6, 2] }, &[]);
        g.constant("w", Tensor::randn(&[3, 3, 2, 4], &mut rng, 0.2));
        g.constant("b", Tensor::from_vec(&[4], vec![0.1, -0.2, 0.3, 0.0]));
        g.op(
            "conv",
            Op::Conv2D { stride: (1, 1), padding: Padding::Same },
            &["input", "w"],
        );
        g.op("bias", Op::BiasAdd, &["conv", "b"]);
        g.op("relu", Op::Relu, &["bias"]);
        g.outputs = vec!["relu".into()];
        g
    }

    #[test]
    fn roundtrip_preserves_everything() {
        let g = build();
        let dir = std::env::temp_dir().join(format!("hpipe_gdef_{}", std::process::id()));
        save(&g, &dir).unwrap();
        let g2 = load(&dir).unwrap();
        assert_eq!(g.nodes.len(), g2.nodes.len());
        for (a, b) in g.nodes.iter().zip(&g2.nodes) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.op, b.op);
            assert_eq!(a.inputs, b.inputs);
            assert_eq!(a.value, b.value);
        }
        assert_eq!(g.outputs, g2.outputs);
        // large tensor went to the blob, small bias stayed inline
        let json = fs::read_to_string(dir.join("graph.json")).unwrap();
        assert!(json.contains("\"offset\""));
        assert!(json.contains("\"data\""));
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn inline_limit_boundary_is_exact() {
        // len == INLINE_LIMIT must stay inline; one element more must
        // hit the blob. The artifact cache content-hashes `to_parts`
        // output, so this boundary is load-bearing beyond readability.
        for len in [INLINE_LIMIT - 1, INLINE_LIMIT, INLINE_LIMIT + 1] {
            let mut g = Graph::new();
            let mut rng = Rng::new(len as u64);
            g.op("input", Op::Placeholder { shape: vec![1, 2, 2, 1] }, &[]);
            g.constant("c", Tensor::randn(&[len], &mut rng, 1.0));
            g.op("relu", Op::Relu, &["input"]);
            g.outputs = vec!["relu".into()];
            let (json, blob) = to_parts(&g);
            if len <= INLINE_LIMIT {
                assert!(blob.is_empty(), "len {len} must serialize inline");
                assert!(json.contains("\"data\""));
            } else {
                assert_eq!(blob.len(), len * 4, "len {len} must go to the blob");
                assert!(json.contains("\"offset\""));
            }
            let g2 = from_parts(&json, &blob).unwrap();
            assert_eq!(g.get("c").unwrap().value, g2.get("c").unwrap().value);
        }
    }

    #[test]
    fn multi_output_and_zero_element_consts_roundtrip() {
        let mut g = Graph::new();
        let mut rng = Rng::new(11);
        g.op("input", Op::Placeholder { shape: vec![1, 4, 4, 2] }, &[]);
        g.constant("empty", Tensor::from_vec(&[0], vec![]));
        g.constant("w", Tensor::randn(&[1, 1, 2, 2], &mut rng, 0.5));
        g.op(
            "conv",
            Op::Conv2D { stride: (1, 1), padding: Padding::Same },
            &["input", "w"],
        );
        g.op("relu", Op::Relu, &["conv"]);
        g.outputs = vec!["conv".into(), "relu".into()];
        let (json, blob) = to_parts(&g);
        let g2 = from_parts(&json, &blob).unwrap();
        assert_eq!(g2.outputs, vec!["conv".to_string(), "relu".to_string()]);
        let e = g2.get("empty").unwrap().value.clone().unwrap();
        assert_eq!(e.shape, vec![0]);
        assert!(e.data.is_empty());
        for (a, b) in g.nodes.iter().zip(&g2.nodes) {
            assert_eq!(a.value, b.value, "node '{}' tensor drifted", a.name);
        }
    }

    #[test]
    fn corrupt_offset_rejected() {
        let g = build();
        let dir = std::env::temp_dir().join(format!("hpipe_gdef_bad_{}", std::process::id()));
        save(&g, &dir).unwrap();
        // truncate the blob
        fs::write(dir.join("weights.bin"), [0u8; 8]).unwrap();
        assert!(load(&dir).is_err());
        fs::remove_dir_all(&dir).unwrap();
    }
}
