//! Dense NHWC tensors (f32) and the 16-bit fixed-point format HPIPE uses.
//!
//! All activations are NHWC ([batch, height, width, channels]) and all
//! convolution weights are HWIO ([kh, kw, cin, cout]) — matching both the
//! TensorFlow layouts the paper's compiler imports and the layouts our
//! JAX model (python/compile/model.py) exports, so weight blobs can be
//! shared byte-for-byte between the two sides.

use crate::util::Rng;

/// A dense f32 tensor with row-major (last-dim fastest) layout.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor {
            shape: shape.to_vec(),
            data: vec![0.0; n],
        }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {:?} does not match data length {}",
            shape,
            data.len()
        );
        Tensor {
            shape: shape.to_vec(),
            data,
        }
    }

    pub fn scalar(v: f32) -> Tensor {
        Tensor {
            shape: vec![],
            data: vec![v],
        }
    }

    /// Random-normal tensor (He init scaled by fan-in for conv weights).
    pub fn randn(shape: &[usize], rng: &mut Rng, std: f32) -> Tensor {
        let mut t = Tensor::zeros(shape);
        for x in t.data.iter_mut() {
            *x = rng.normal_f32(0.0, std);
        }
        t
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Strides for row-major layout.
    pub fn strides(&self) -> Vec<usize> {
        let mut s = vec![1usize; self.shape.len()];
        for i in (0..self.shape.len().saturating_sub(1)).rev() {
            s[i] = s[i + 1] * self.shape[i + 1];
        }
        s
    }

    #[inline]
    pub fn at4(&self, n: usize, h: usize, w: usize, c: usize) -> f32 {
        debug_assert_eq!(self.shape.len(), 4);
        let (sh, sw, sc) = (self.shape[1], self.shape[2], self.shape[3]);
        debug_assert!(n < self.shape[0] && h < sh && w < sw && c < sc);
        self.data[((n * sh + h) * sw + w) * sc + c]
    }

    #[inline]
    pub fn at4_mut(&mut self, n: usize, h: usize, w: usize, c: usize) -> &mut f32 {
        debug_assert_eq!(self.shape.len(), 4);
        let (sh, sw, sc) = (self.shape[1], self.shape[2], self.shape[3]);
        debug_assert!(n < self.shape[0] && h < sh && w < sw && c < sc);
        &mut self.data[((n * sh + h) * sw + w) * sc + c]
    }

    #[inline]
    pub fn at2(&self, i: usize, j: usize) -> f32 {
        debug_assert_eq!(self.shape.len(), 2);
        self.data[i * self.shape[1] + j]
    }

    /// Contiguous row-major view of the data — the accessor the exec
    /// kernels use (they index raw slices with precomputed geometry).
    #[inline]
    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    /// Mutable contiguous view.
    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Concatenate tensors along the leading (batch) dim: `k` tensors of
    /// shape `[n, ...]` become one `[k·n, ...]` tensor. The builder for
    /// batched-plan feeds (`exec::PlanOptions::batch`): per-image feed
    /// tensors stack into the `[B, ...]` block a batch-B plan consumes.
    /// Panics on an empty list or mismatched trailing dims.
    pub fn concat_batch(parts: &[&Tensor]) -> Tensor {
        assert!(!parts.is_empty(), "concat_batch of no tensors");
        let first = parts[0];
        assert!(!first.shape.is_empty(), "concat_batch needs a leading dim");
        let mut shape = first.shape.clone();
        let mut data = Vec::with_capacity(first.data.len() * parts.len());
        let mut lead = 0usize;
        for t in parts {
            assert_eq!(t.shape[1..], first.shape[1..], "concat_batch trailing dims differ");
            lead += t.shape[0];
            data.extend_from_slice(&t.data);
        }
        shape[0] = lead;
        Tensor::from_vec(&shape, data)
    }

    /// Zero-pad a flat row-major block of images up to `batch` images:
    /// the feed builder for running a ragged tail of `k < batch` images
    /// through a batch-`batch` plan (`input.len()` must be a multiple of
    /// `per_image` and at most `batch · per_image`). The flat-block
    /// companion of [`Self::concat_batch`], shared by the serving path's
    /// pad fallback and the equivalence tests so "padded baseline" means
    /// one thing everywhere. Padding with zeros is sound because batched
    /// kernels never mix accumulation across images — the real images'
    /// outputs are bitwise those of the unpadded batch.
    pub fn pad_batch(input: &[f32], per_image: usize, batch: usize) -> Vec<f32> {
        assert!(per_image > 0, "pad_batch needs a positive image size");
        assert_eq!(input.len() % per_image, 0, "pad_batch input is not whole images");
        assert!(
            input.len() <= batch * per_image,
            "pad_batch cannot shrink {} elements into batch {batch}",
            input.len()
        );
        let mut padded = Vec::with_capacity(batch * per_image);
        padded.extend_from_slice(input);
        padded.resize(batch * per_image, 0.0);
        padded
    }

    /// Reshape without moving data (element count must match).
    pub fn reshape(&self, shape: &[usize]) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        Tensor {
            shape: shape.to_vec(),
            data: self.data.clone(),
        }
    }

    /// Fraction of exactly-zero elements (sparsity after pruning).
    pub fn sparsity(&self) -> f64 {
        if self.data.is_empty() {
            return 0.0;
        }
        self.data.iter().filter(|&&x| x == 0.0).count() as f64 / self.data.len() as f64
    }

    /// Max |x| over the tensor — used to pick fixed-point scales.
    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }
}

/// HPIPE's 16-bit fixed-point representation (§VI: "we ran all of our
/// experiments with a 16-bit fixed point precision"). A `FixedFormat`
/// carries the number of fractional bits; values are stored as i16 and
/// accumulated in i64, modelling the S10 DSP block's wide accumulator so
/// quantization error comes only from input/weight rounding and the final
/// requantize — exactly as in the hardware.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FixedFormat {
    /// Total bits including sign (16 for the paper's experiments).
    pub bits: u32,
    /// Fractional bits; integer bits = bits - 1 - frac.
    pub frac: u32,
}

impl FixedFormat {
    pub fn q(bits: u32, frac: u32) -> FixedFormat {
        assert!(bits >= 2 && frac < bits);
        FixedFormat { bits, frac }
    }

    /// Pick the format with the most fractional bits that still
    /// represents `max_abs` without saturation.
    pub fn for_range(bits: u32, max_abs: f32) -> FixedFormat {
        let mut int_bits = 0u32;
        while ((1i64 << int_bits) as f32) <= max_abs && int_bits < bits - 1 {
            int_bits += 1;
        }
        FixedFormat {
            bits,
            frac: bits - 1 - int_bits,
        }
    }

    pub fn scale(&self) -> f32 {
        (1i64 << self.frac) as f32
    }

    pub fn max_val(&self) -> i64 {
        (1i64 << (self.bits - 1)) - 1
    }

    pub fn min_val(&self) -> i64 {
        -(1i64 << (self.bits - 1))
    }

    /// Quantize with round-to-nearest and saturation.
    #[inline]
    pub fn quantize(&self, x: f32) -> i64 {
        let v = (x * self.scale()).round() as i64;
        v.clamp(self.min_val(), self.max_val())
    }

    #[inline]
    pub fn dequantize(&self, v: i64) -> f32 {
        v as f32 / self.scale()
    }

    /// Round-trip a float through this format.
    #[inline]
    pub fn roundtrip(&self, x: f32) -> f32 {
        self.dequantize(self.quantize(x))
    }
}

/// A tensor quantized to a fixed-point format (values stored widened to
/// i64 so intermediate accumulations never overflow in the model).
#[derive(Clone, Debug)]
pub struct FixedTensor {
    pub shape: Vec<usize>,
    pub data: Vec<i64>,
    pub format: FixedFormat,
}

impl FixedTensor {
    pub fn quantize(t: &Tensor, format: FixedFormat) -> FixedTensor {
        FixedTensor {
            shape: t.shape.clone(),
            data: t.data.iter().map(|&x| format.quantize(x)).collect(),
            format,
        }
    }

    pub fn dequantize(&self) -> Tensor {
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().map(|&v| self.format.dequantize(v)).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_and_indexing() {
        let mut t = Tensor::zeros(&[1, 3, 4, 2]);
        *t.at4_mut(0, 2, 3, 1) = 5.0;
        assert_eq!(t.at4(0, 2, 3, 1), 5.0);
        assert_eq!(t.at4(0, 2, 3, 0), 0.0);
        assert_eq!(t.len(), 24);
    }

    #[test]
    fn slice_accessors_are_row_major_views() {
        let mut t = Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(t.as_slice(), &[1.0, 2.0, 3.0, 4.0]);
        t.as_mut_slice()[3] = 9.0;
        assert_eq!(t.at2(1, 1), 9.0);
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic]
    fn at4_out_of_bounds_panics_in_debug() {
        let t = Tensor::zeros(&[1, 2, 2, 2]);
        let _ = t.at4(0, 0, 0, 2);
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic]
    fn at4_mut_out_of_bounds_panics_in_debug() {
        let mut t = Tensor::zeros(&[1, 2, 2, 2]);
        *t.at4_mut(0, 2, 0, 0) = 1.0;
    }

    #[test]
    fn concat_batch_stacks_leading_dim() {
        let a = Tensor::from_vec(&[1, 2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let b = Tensor::from_vec(&[1, 2, 2], vec![5.0, 6.0, 7.0, 8.0]);
        let c = Tensor::concat_batch(&[&a, &b, &a]);
        assert_eq!(c.shape, vec![3, 2, 2]);
        assert_eq!(&c.data[..4], &a.data[..]);
        assert_eq!(&c.data[4..8], &b.data[..]);
        assert_eq!(&c.data[8..], &a.data[..]);
    }

    #[test]
    #[should_panic(expected = "trailing dims differ")]
    fn concat_batch_rejects_mismatched_shapes() {
        let a = Tensor::zeros(&[1, 2, 2]);
        let b = Tensor::zeros(&[1, 2, 3]);
        let _ = Tensor::concat_batch(&[&a, &b]);
    }

    #[test]
    fn pad_batch_zero_fills_to_the_plan_batch() {
        let two_images = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let padded = Tensor::pad_batch(&two_images, 3, 4);
        assert_eq!(padded.len(), 12);
        assert_eq!(&padded[..6], &two_images[..]);
        assert!(padded[6..].iter().all(|&v| v == 0.0));
        // already-full input passes through unchanged
        assert_eq!(Tensor::pad_batch(&two_images, 3, 2), two_images);
    }

    #[test]
    #[should_panic(expected = "not whole images")]
    fn pad_batch_rejects_partial_images() {
        let _ = Tensor::pad_batch(&[1.0, 2.0], 3, 4);
    }

    #[test]
    fn strides_row_major() {
        let t = Tensor::zeros(&[2, 3, 4]);
        assert_eq!(t.strides(), vec![12, 4, 1]);
    }

    #[test]
    #[should_panic(expected = "does not match")]
    fn from_vec_checks_len() {
        Tensor::from_vec(&[2, 2], vec![1.0; 5]);
    }

    #[test]
    fn sparsity_counts_zeros() {
        let t = Tensor::from_vec(&[4], vec![0.0, 1.0, 0.0, 2.0]);
        assert_eq!(t.sparsity(), 0.5);
    }

    #[test]
    fn fixed_format_for_range() {
        // max_abs 5.9 needs 3 integer bits -> 16-1-3 = 12 frac bits
        let f = FixedFormat::for_range(16, 5.9);
        assert_eq!(f.frac, 12);
        // pure-fractional data keeps 15 frac bits
        let f = FixedFormat::for_range(16, 0.7);
        assert_eq!(f.frac, 15);
    }

    #[test]
    fn quantize_roundtrip_error_bounded() {
        let f = FixedFormat::q(16, 12);
        let step = 1.0 / f.scale();
        for &x in &[0.0f32, 0.1, -3.7, 5.25, -7.999] {
            assert!((f.roundtrip(x) - x).abs() <= step / 2.0 + 1e-9, "x={x}");
        }
    }

    #[test]
    fn quantize_saturates() {
        let f = FixedFormat::q(16, 12);
        assert_eq!(f.quantize(1e9), f.max_val());
        assert_eq!(f.quantize(-1e9), f.min_val());
    }

    #[test]
    fn fixed_tensor_roundtrip() {
        let mut rng = Rng::new(1);
        let t = Tensor::randn(&[32], &mut rng, 1.0);
        let f = FixedFormat::for_range(16, t.max_abs());
        let q = FixedTensor::quantize(&t, f);
        let back = q.dequantize();
        for (a, b) in t.data.iter().zip(&back.data) {
            assert!((a - b).abs() <= 1.0 / f.scale());
        }
    }
}
