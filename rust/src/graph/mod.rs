//! Graph intermediate representation.
//!
//! A [`Graph`] is a TensorFlow-graphdef-like DAG: named nodes, each with
//! an [`Op`], string input references, and (for `Const` nodes) a weight
//! tensor. The HPIPE compiler (transform passes, pruner, balancer,
//! generator), the reference interpreter, the pipeline simulator and the
//! JAX model builder all consume this one IR.

pub mod graphdef;
pub mod ops;
pub mod tensor;

pub use ops::{Op, Padding};
pub use tensor::{FixedFormat, FixedTensor, Tensor};

use std::collections::{BTreeMap, HashMap, HashSet};

/// One node in the graph.
#[derive(Clone, Debug)]
pub struct Node {
    pub name: String,
    pub op: Op,
    /// Names of producer nodes, in operand order.
    pub inputs: Vec<String>,
    /// Weight/constant payload (Const nodes only).
    pub value: Option<Tensor>,
}

/// The network graph: a DAG of [`Node`]s plus designated outputs.
#[derive(Clone, Debug, Default)]
pub struct Graph {
    pub nodes: Vec<Node>,
    pub outputs: Vec<String>,
    index: HashMap<String, usize>,
}

/// Errors raised by graph construction / validation.
#[derive(Debug, Clone)]
pub enum GraphError {
    Duplicate(String),
    UnknownInput(String, String),
    Cycle(String),
    Shape(String, String),
    Invalid(String, String),
    /// A pipeline stage worker panicked mid-run; the panic was caught
    /// and isolated (`exec::pipeline`), the plan stays reusable, and the
    /// run that carried `item` reports this instead of crashing.
    StageFault {
        stage: usize,
        item: usize,
        msg: String,
    },
    /// A plan artifact failed validation (missing, corrupt, truncated,
    /// or stale cache key). Always recoverable: the caller falls back
    /// to a fresh compile — a bad artifact is never executed.
    Artifact(String),
}

impl std::fmt::Display for GraphError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            GraphError::Duplicate(n) => write!(f, "duplicate node name '{n}'"),
            GraphError::UnknownInput(n, i) => {
                write!(f, "node '{n}' references unknown input '{i}'")
            }
            GraphError::Cycle(n) => write!(f, "graph contains a cycle involving '{n}'"),
            GraphError::Shape(n, msg) => write!(f, "shape error at node '{n}': {msg}"),
            GraphError::Invalid(n, msg) => write!(f, "node '{n}': {msg}"),
            GraphError::StageFault { stage, item, msg } => {
                write!(f, "pipeline stage {stage} faulted on item {item}: {msg}")
            }
            GraphError::Artifact(msg) => write!(f, "plan artifact rejected: {msg}"),
        }
    }
}

impl std::error::Error for GraphError {}

impl Graph {
    pub fn new() -> Graph {
        Graph::default()
    }

    /// Add a node; returns its name for chaining convenience.
    pub fn add(&mut self, node: Node) -> String {
        assert!(
            !self.index.contains_key(&node.name),
            "duplicate node name '{}'",
            node.name
        );
        self.index.insert(node.name.clone(), self.nodes.len());
        let name = node.name.clone();
        self.nodes.push(node);
        name
    }

    /// Shorthand for adding an op node.
    pub fn op(&mut self, name: &str, op: Op, inputs: &[&str]) -> String {
        self.add(Node {
            name: name.to_string(),
            op,
            inputs: inputs.iter().map(|s| s.to_string()).collect(),
            value: None,
        })
    }

    /// Shorthand for adding a Const node carrying a tensor.
    pub fn constant(&mut self, name: &str, value: Tensor) -> String {
        self.add(Node {
            name: name.to_string(),
            op: Op::Const,
            inputs: vec![],
            value: Some(value),
        })
    }

    pub fn get(&self, name: &str) -> Option<&Node> {
        self.index.get(name).map(|&i| &self.nodes[i])
    }

    pub fn get_mut(&mut self, name: &str) -> Option<&mut Node> {
        let i = *self.index.get(name)?;
        Some(&mut self.nodes[i])
    }

    pub fn node_index(&self, name: &str) -> Option<usize> {
        self.index.get(name).copied()
    }

    /// Random-normal feed tensors for every Placeholder — the shared
    /// test/bench helper for driving interpreters and execution plans.
    pub fn random_feeds(&self, rng: &mut crate::util::Rng) -> BTreeMap<String, Tensor> {
        let mut feeds = BTreeMap::new();
        for n in &self.nodes {
            if let Op::Placeholder { shape } = &n.op {
                feeds.insert(n.name.clone(), Tensor::randn(shape, rng, 1.0));
            }
        }
        feeds
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Rebuild the name index (after structural surgery by passes).
    pub fn reindex(&mut self) {
        self.index.clear();
        for (i, n) in self.nodes.iter().enumerate() {
            self.index.insert(n.name.clone(), i);
        }
    }

    /// consumers[name] = names of nodes that read `name`.
    pub fn consumers(&self) -> HashMap<String, Vec<String>> {
        let mut m: HashMap<String, Vec<String>> = HashMap::new();
        for n in &self.nodes {
            for i in &n.inputs {
                m.entry(i.clone()).or_default().push(n.name.clone());
            }
        }
        m
    }

    /// Topological order of node indices (inputs before consumers).
    pub fn topo_order(&self) -> Result<Vec<usize>, GraphError> {
        let mut indegree = vec![0usize; self.nodes.len()];
        let mut edges: Vec<Vec<usize>> = vec![Vec::new(); self.nodes.len()];
        for (i, n) in self.nodes.iter().enumerate() {
            for input in &n.inputs {
                let j = *self
                    .index
                    .get(input)
                    .ok_or_else(|| GraphError::UnknownInput(n.name.clone(), input.clone()))?;
                edges[j].push(i);
                indegree[i] += 1;
            }
        }
        // Kahn's algorithm with a deterministic (index-ordered) frontier.
        let mut frontier: Vec<usize> = (0..self.nodes.len())
            .filter(|&i| indegree[i] == 0)
            .collect();
        let mut order = Vec::with_capacity(self.nodes.len());
        while let Some(i) = frontier.pop() {
            order.push(i);
            for &c in &edges[i] {
                indegree[c] -= 1;
                if indegree[c] == 0 {
                    frontier.push(c);
                }
            }
        }
        if order.len() != self.nodes.len() {
            let stuck = (0..self.nodes.len())
                .find(|&i| indegree[i] > 0)
                .map(|i| self.nodes[i].name.clone())
                .unwrap_or_default();
            return Err(GraphError::Cycle(stuck));
        }
        Ok(order)
    }

    /// Remove nodes not reachable (backwards) from any output.
    pub fn prune_dead(&mut self) {
        let mut live: HashSet<String> = HashSet::new();
        let mut stack: Vec<String> = self.outputs.clone();
        while let Some(name) = stack.pop() {
            if live.insert(name.clone()) {
                if let Some(n) = self.get(&name) {
                    stack.extend(n.inputs.iter().cloned());
                }
            }
        }
        self.nodes.retain(|n| live.contains(&n.name));
        self.reindex();
    }

    /// Infer output shapes for every node. NHWC activations; weight
    /// layouts as documented on [`Op`]. Also validates operand ranks.
    pub fn infer_shapes(&self) -> Result<BTreeMap<String, Vec<usize>>, GraphError> {
        let order = self.topo_order()?;
        let mut shapes: BTreeMap<String, Vec<usize>> = BTreeMap::new();
        for i in order {
            let n = &self.nodes[i];
            let input_shape = |k: usize| -> Result<Vec<usize>, GraphError> {
                let name = n.inputs.get(k).ok_or_else(|| {
                    GraphError::Invalid(n.name.clone(), format!("missing input {k}"))
                })?;
                shapes
                    .get(name)
                    .cloned()
                    .ok_or_else(|| GraphError::UnknownInput(n.name.clone(), name.clone()))
            };
            let err = |msg: String| GraphError::Shape(n.name.clone(), msg);
            let shape = match &n.op {
                Op::Placeholder { shape } => shape.clone(),
                Op::Const => n
                    .value
                    .as_ref()
                    .ok_or_else(|| err("Const node without value".into()))?
                    .shape
                    .clone(),
                Op::Conv2D { stride, padding } => {
                    let x = input_shape(0)?;
                    let w = input_shape(1)?;
                    if x.len() != 4 || w.len() != 4 {
                        return Err(err(format!("Conv2D ranks: x{x:?} w{w:?}")));
                    }
                    if x[3] != w[2] {
                        return Err(err(format!(
                            "Conv2D channel mismatch: input C={} weights Ci={}",
                            x[3], w[2]
                        )));
                    }
                    let (t, b, l, r) = padding.resolve(x[1], x[2], w[0], w[1], stride.0, stride.1);
                    let ho = (x[1] + t + b - w[0]) / stride.0 + 1;
                    let wo = (x[2] + l + r - w[1]) / stride.1 + 1;
                    vec![x[0], ho, wo, w[3]]
                }
                Op::DepthwiseConv2d { stride, padding } => {
                    let x = input_shape(0)?;
                    let w = input_shape(1)?;
                    if x.len() != 4 || w.len() != 4 {
                        return Err(err(format!("DepthwiseConv2d ranks: x{x:?} w{w:?}")));
                    }
                    if x[3] != w[2] {
                        return Err(err(format!(
                            "Depthwise channel mismatch: input C={} weights Ci={}",
                            x[3], w[2]
                        )));
                    }
                    let (t, b, l, r) = padding.resolve(x[1], x[2], w[0], w[1], stride.0, stride.1);
                    let ho = (x[1] + t + b - w[0]) / stride.0 + 1;
                    let wo = (x[2] + l + r - w[1]) / stride.1 + 1;
                    vec![x[0], ho, wo, x[3] * w[3]]
                }
                Op::MatMul => {
                    let x = input_shape(0)?;
                    let w = input_shape(1)?;
                    if x.len() != 2 || w.len() != 2 || x[1] != w[0] {
                        return Err(err(format!("MatMul shapes: x{x:?} w{w:?}")));
                    }
                    vec![x[0], w[1]]
                }
                Op::BiasAdd => {
                    let x = input_shape(0)?;
                    let b = input_shape(1)?;
                    if b.len() != 1 || b[0] != *x.last().unwrap() {
                        return Err(err(format!("BiasAdd bias {b:?} vs x {x:?}")));
                    }
                    x
                }
                Op::MaxPool { ksize, stride, padding } => {
                    let x = input_shape(0)?;
                    if x.len() != 4 {
                        return Err(err(format!("MaxPool rank: {x:?}")));
                    }
                    let (t, b, l, r) =
                        padding.resolve(x[1], x[2], ksize.0, ksize.1, stride.0, stride.1);
                    let ho = (x[1] + t + b - ksize.0) / stride.0 + 1;
                    let wo = (x[2] + l + r - ksize.1) / stride.1 + 1;
                    vec![x[0], ho, wo, x[3]]
                }
                Op::Relu | Op::Relu6 | Op::Softmax => input_shape(0)?,
                Op::Mul | Op::AddC => {
                    let x = input_shape(0)?;
                    let c = input_shape(1)?;
                    if c.len() != 1 || c[0] != *x.last().unwrap() {
                        return Err(err(format!("per-channel const {c:?} vs x {x:?}")));
                    }
                    x
                }
                Op::Add => {
                    let a = input_shape(0)?;
                    let b = input_shape(1)?;
                    if a != b {
                        return Err(err(format!("Add operand mismatch: {a:?} vs {b:?}")));
                    }
                    a
                }
                Op::Mean => {
                    let x = input_shape(0)?;
                    if x.len() != 4 {
                        return Err(err(format!("Mean rank: {x:?}")));
                    }
                    vec![x[0], x[3]]
                }
                Op::FusedBatchNorm { .. } => {
                    let x = input_shape(0)?;
                    for k in 1..5 {
                        let c = input_shape(k)?;
                        if c.len() != 1 || c[0] != *x.last().unwrap() {
                            return Err(err(format!("BN param {k} shape {c:?} vs x {x:?}")));
                        }
                    }
                    x
                }
                Op::Pad { pads } => {
                    let x = input_shape(0)?;
                    if x.len() != 4 {
                        return Err(err(format!("Pad rank: {x:?}")));
                    }
                    vec![x[0], x[1] + pads.0 + pads.1, x[2] + pads.2 + pads.3, x[3]]
                }
            };
            shapes.insert(n.name.clone(), shape);
        }
        Ok(shapes)
    }

    /// Full structural validation: names resolve, acyclic, shapes infer,
    /// outputs exist.
    pub fn validate(&self) -> Result<(), GraphError> {
        let mut seen = HashSet::new();
        for n in &self.nodes {
            if !seen.insert(&n.name) {
                return Err(GraphError::Duplicate(n.name.clone()));
            }
        }
        for out in &self.outputs {
            if !self.index.contains_key(out) {
                return Err(GraphError::UnknownInput("<outputs>".into(), out.clone()));
            }
        }
        self.infer_shapes()?;
        Ok(())
    }

    /// Total parameter count over Const nodes feeding compute ops.
    pub fn param_count(&self) -> usize {
        self.nodes
            .iter()
            .filter_map(|n| n.value.as_ref().map(|v| v.len()))
            .sum()
    }

    /// Multiply-accumulate count for one inference (dense; zero-skipping
    /// is accounted separately by the sparsity-aware throughput model).
    pub fn macs(&self) -> Result<u64, GraphError> {
        let shapes = self.infer_shapes()?;
        let mut total: u64 = 0;
        for n in &self.nodes {
            match &n.op {
                Op::Conv2D { .. } => {
                    let out = &shapes[&n.name];
                    let w = &shapes[&n.inputs[1]];
                    // out H*W positions × kh*kw*ci per output channel × co
                    total += (out[1] * out[2] * w[0] * w[1] * w[2] * w[3]) as u64;
                }
                Op::DepthwiseConv2d { .. } => {
                    let out = &shapes[&n.name];
                    let w = &shapes[&n.inputs[1]];
                    total += (out[1] * out[2] * out[3] * w[0] * w[1]) as u64;
                }
                Op::MatMul => {
                    let w = &shapes[&n.inputs[1]];
                    total += (w[0] * w[1]) as u64;
                }
                _ => {}
            }
        }
        Ok(total)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    /// input -> conv3x3(8) -> bias -> relu -> maxpool -> graph used by
    /// several tests below.
    fn small_graph() -> Graph {
        let mut g = Graph::new();
        let mut rng = Rng::new(0);
        g.op("input", Op::Placeholder { shape: vec![1, 8, 8, 3] }, &[]);
        g.constant("w0", Tensor::randn(&[3, 3, 3, 8], &mut rng, 0.1));
        g.op(
            "conv0",
            Op::Conv2D { stride: (1, 1), padding: Padding::Same },
            &["input", "w0"],
        );
        g.constant("b0", Tensor::zeros(&[8]));
        g.op("bias0", Op::BiasAdd, &["conv0", "b0"]);
        g.op("relu0", Op::Relu, &["bias0"]);
        g.op(
            "pool0",
            Op::MaxPool { ksize: (2, 2), stride: (2, 2), padding: Padding::Valid },
            &["relu0"],
        );
        g.outputs = vec!["pool0".into()];
        g
    }

    #[test]
    fn shape_inference_small() {
        let g = small_graph();
        let s = g.infer_shapes().unwrap();
        assert_eq!(s["conv0"], vec![1, 8, 8, 8]);
        assert_eq!(s["pool0"], vec![1, 4, 4, 8]);
        g.validate().unwrap();
    }

    #[test]
    fn topo_order_respects_edges() {
        let g = small_graph();
        let order = g.topo_order().unwrap();
        let pos: HashMap<&str, usize> = order
            .iter()
            .enumerate()
            .map(|(p, &i)| (g.nodes[i].name.as_str(), p))
            .collect();
        assert!(pos["input"] < pos["conv0"]);
        assert!(pos["w0"] < pos["conv0"]);
        assert!(pos["conv0"] < pos["bias0"]);
        assert!(pos["relu0"] < pos["pool0"]);
    }

    #[test]
    fn cycle_detected() {
        let mut g = Graph::new();
        g.op("a", Op::Relu, &["b"]);
        g.op("b", Op::Relu, &["a"]);
        assert!(matches!(g.topo_order(), Err(GraphError::Cycle(_))));
    }

    #[test]
    fn unknown_input_detected() {
        let mut g = Graph::new();
        g.op("a", Op::Relu, &["ghost"]);
        assert!(matches!(
            g.topo_order(),
            Err(GraphError::UnknownInput(_, _))
        ));
    }

    #[test]
    fn channel_mismatch_detected() {
        let mut g = Graph::new();
        let mut rng = Rng::new(0);
        g.op("input", Op::Placeholder { shape: vec![1, 8, 8, 3] }, &[]);
        g.constant("w", Tensor::randn(&[3, 3, 4, 8], &mut rng, 0.1)); // Ci=4 != 3
        g.op(
            "conv",
            Op::Conv2D { stride: (1, 1), padding: Padding::Same },
            &["input", "w"],
        );
        assert!(matches!(g.infer_shapes(), Err(GraphError::Shape(_, _))));
    }

    #[test]
    fn depthwise_shapes() {
        let mut g = Graph::new();
        let mut rng = Rng::new(0);
        g.op("input", Op::Placeholder { shape: vec![1, 14, 14, 32] }, &[]);
        g.constant("w", Tensor::randn(&[3, 3, 32, 1], &mut rng, 0.1));
        g.op(
            "dw",
            Op::DepthwiseConv2d { stride: (2, 2), padding: Padding::Same },
            &["input", "w"],
        );
        let s = g.infer_shapes().unwrap();
        assert_eq!(s["dw"], vec![1, 7, 7, 32]);
    }

    #[test]
    fn mean_and_matmul_shapes() {
        let mut g = Graph::new();
        let mut rng = Rng::new(0);
        g.op("input", Op::Placeholder { shape: vec![1, 7, 7, 64] }, &[]);
        g.op("gap", Op::Mean, &["input"]);
        g.constant("fc_w", Tensor::randn(&[64, 10], &mut rng, 0.1));
        g.op("fc", Op::MatMul, &["gap", "fc_w"]);
        let s = g.infer_shapes().unwrap();
        assert_eq!(s["gap"], vec![1, 64]);
        assert_eq!(s["fc"], vec![1, 10]);
    }

    #[test]
    fn prune_dead_removes_unreachable() {
        let mut g = small_graph();
        g.constant("orphan", Tensor::zeros(&[4]));
        assert!(g.get("orphan").is_some());
        g.prune_dead();
        assert!(g.get("orphan").is_none());
        assert!(g.get("conv0").is_some());
        g.validate().unwrap();
    }

    #[test]
    fn macs_count_conv() {
        let g = small_graph();
        // conv0: 8*8 positions × 3*3*3 × 8 = 13824
        assert_eq!(g.macs().unwrap(), 8 * 8 * 3 * 3 * 3 * 8);
    }

    #[test]
    fn duplicate_name_panics() {
        let mut g = Graph::new();
        g.op("x", Op::Relu, &[]);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            g.op("x", Op::Relu, &[]);
        }));
        assert!(r.is_err());
    }
}
