//! Reference interpreter for the graph IR.
//!
//! Executes a [`Graph`] on f32 tensors with straightforward (unoptimized)
//! loops. This is the *oracle* the rest of the system is checked against:
//! transform passes must preserve its output, the fixed-point executor
//! ([`fixed`]) is compared against it to quantify quantization error
//! (Table III), and the compiled execution engine ([`crate::exec`]) must
//! match it bit-close on every graph (`rust/tests/exec_equiv.rs`). Keep
//! these loops naive — their obviousness is the point.

pub mod fixed;

use crate::graph::{Graph, GraphError, Op, Padding, Tensor};
use std::collections::BTreeMap;

/// Run the graph on the given feeds (placeholder name -> tensor).
/// Returns the value of every node (keyed by name).
pub fn run(
    graph: &Graph,
    feeds: &BTreeMap<String, Tensor>,
) -> Result<BTreeMap<String, Tensor>, GraphError> {
    let order = graph.topo_order()?;
    let mut env: BTreeMap<String, Tensor> = BTreeMap::new();
    for i in order {
        let n = &graph.nodes[i];
        let input = |k: usize| -> &Tensor { &env[&n.inputs[k]] };
        let out = match &n.op {
            Op::Placeholder { shape } => {
                let t = feeds.get(&n.name).ok_or_else(|| {
                    GraphError::Invalid(n.name.clone(), "missing feed".into())
                })?;
                if t.shape != *shape {
                    return Err(GraphError::Shape(
                        n.name.clone(),
                        format!("feed shape {:?} != {:?}", t.shape, shape),
                    ));
                }
                t.clone()
            }
            Op::Const => n.value.clone().ok_or_else(|| {
                GraphError::Invalid(n.name.clone(), "Const without value".into())
            })?,
            Op::Conv2D { stride, padding } => conv2d(input(0), input(1), *stride, *padding),
            Op::DepthwiseConv2d { stride, padding } => {
                depthwise_conv2d(input(0), input(1), *stride, *padding)
            }
            Op::MatMul => matmul(input(0), input(1)),
            Op::BiasAdd => bias_add(input(0), input(1)),
            Op::MaxPool { ksize, stride, padding } => {
                max_pool(input(0), *ksize, *stride, *padding)
            }
            Op::Relu => map_unary(input(0), |x| x.max(0.0)),
            Op::Relu6 => map_unary(input(0), |x| x.clamp(0.0, 6.0)),
            Op::Add => zip_binary(input(0), input(1), |a, b| a + b),
            Op::Mean => global_mean(input(0)),
            Op::FusedBatchNorm { epsilon } => batch_norm(
                input(0),
                input(1),
                input(2),
                input(3),
                input(4),
                *epsilon,
            ),
            Op::Pad { pads } => pad(input(0), *pads),
            Op::Mul => per_channel(input(0), input(1), |x, c| x * c),
            Op::AddC => per_channel(input(0), input(1), |x, c| x + c),
            Op::Softmax => softmax(input(0)),
        };
        env.insert(n.name.clone(), out);
    }
    Ok(env)
}

/// Run and return only the designated graph outputs.
pub fn run_outputs(
    graph: &Graph,
    feeds: &BTreeMap<String, Tensor>,
) -> Result<Vec<Tensor>, GraphError> {
    let env = run(graph, feeds)?;
    Ok(graph
        .outputs
        .iter()
        .map(|o| env[o].clone())
        .collect())
}

// ---------------- op kernels (shared with the fixed-point executor where
// the integer version differs only in arithmetic) ----------------

pub fn conv2d(x: &Tensor, w: &Tensor, stride: (usize, usize), padding: Padding) -> Tensor {
    let (h, wi, ci) = (x.shape[1], x.shape[2], x.shape[3]);
    let (kh, kw, _wci, co) = (w.shape[0], w.shape[1], w.shape[2], w.shape[3]);
    let (t, b, l, r) = padding.resolve(h, wi, kh, kw, stride.0, stride.1);
    let ho = (h + t + b - kh) / stride.0 + 1;
    let wo = (wi + l + r - kw) / stride.1 + 1;
    let mut out = Tensor::zeros(&[1, ho, wo, co]);
    for oy in 0..ho {
        for ox in 0..wo {
            for oc in 0..co {
                let mut acc = 0f32;
                for ky in 0..kh {
                    let iy = (oy * stride.0 + ky) as isize - t as isize;
                    if !(0..h as isize).contains(&iy) {
                        continue;
                    }
                    for kx in 0..kw {
                        let ix = (ox * stride.1 + kx) as isize - l as isize;
                        if !(0..wi as isize).contains(&ix) {
                            continue;
                        }
                        for ic in 0..ci {
                            acc += x.at4(0, iy as usize, ix as usize, ic)
                                * w.data[((ky * kw + kx) * ci + ic) * co + oc];
                        }
                    }
                }
                *out.at4_mut(0, oy, ox, oc) = acc;
            }
        }
    }
    out
}

pub fn depthwise_conv2d(
    x: &Tensor,
    w: &Tensor,
    stride: (usize, usize),
    padding: Padding,
) -> Tensor {
    let (h, wi, ci) = (x.shape[1], x.shape[2], x.shape[3]);
    let (kh, kw, _, m) = (w.shape[0], w.shape[1], w.shape[2], w.shape[3]);
    let (t, b, l, r) = padding.resolve(h, wi, kh, kw, stride.0, stride.1);
    let ho = (h + t + b - kh) / stride.0 + 1;
    let wo = (wi + l + r - kw) / stride.1 + 1;
    let mut out = Tensor::zeros(&[1, ho, wo, ci * m]);
    for oy in 0..ho {
        for ox in 0..wo {
            for ic in 0..ci {
                for im in 0..m {
                    let mut acc = 0f32;
                    for ky in 0..kh {
                        let iy = (oy * stride.0 + ky) as isize - t as isize;
                        if !(0..h as isize).contains(&iy) {
                            continue;
                        }
                        for kx in 0..kw {
                            let ix = (ox * stride.1 + kx) as isize - l as isize;
                            if !(0..wi as isize).contains(&ix) {
                                continue;
                            }
                            acc += x.at4(0, iy as usize, ix as usize, ic)
                                * w.data[((ky * kw + kx) * ci + ic) * m + im];
                        }
                    }
                    *out.at4_mut(0, oy, ox, ic * m + im) = acc;
                }
            }
        }
    }
    out
}

pub fn matmul(x: &Tensor, w: &Tensor) -> Tensor {
    let (n, ci) = (x.shape[0], x.shape[1]);
    let co = w.shape[1];
    let mut out = Tensor::zeros(&[n, co]);
    for i in 0..n {
        for j in 0..co {
            let mut acc = 0f32;
            for k in 0..ci {
                acc += x.at2(i, k) * w.at2(k, j);
            }
            out.data[i * co + j] = acc;
        }
    }
    out
}

pub fn bias_add(x: &Tensor, b: &Tensor) -> Tensor {
    per_channel(x, b, |v, c| v + c)
}

pub fn per_channel(x: &Tensor, c: &Tensor, f: impl Fn(f32, f32) -> f32) -> Tensor {
    let ch = *x.shape.last().unwrap();
    assert_eq!(c.shape, vec![ch]);
    let mut out = x.clone();
    for (i, v) in out.data.iter_mut().enumerate() {
        *v = f(*v, c.data[i % ch]);
    }
    out
}

pub fn map_unary(x: &Tensor, f: impl Fn(f32) -> f32) -> Tensor {
    let mut out = x.clone();
    for v in out.data.iter_mut() {
        *v = f(*v);
    }
    out
}

pub fn zip_binary(a: &Tensor, b: &Tensor, f: impl Fn(f32, f32) -> f32) -> Tensor {
    assert_eq!(a.shape, b.shape);
    let mut out = a.clone();
    for (v, &x) in out.data.iter_mut().zip(&b.data) {
        *v = f(*v, x);
    }
    out
}

pub fn max_pool(
    x: &Tensor,
    ksize: (usize, usize),
    stride: (usize, usize),
    padding: Padding,
) -> Tensor {
    let (h, w, c) = (x.shape[1], x.shape[2], x.shape[3]);
    let (t, b, l, r) = padding.resolve(h, w, ksize.0, ksize.1, stride.0, stride.1);
    let ho = (h + t + b - ksize.0) / stride.0 + 1;
    let wo = (w + l + r - ksize.1) / stride.1 + 1;
    let mut out = Tensor::zeros(&[1, ho, wo, c]);
    for oy in 0..ho {
        for ox in 0..wo {
            for ch in 0..c {
                let mut m = f32::NEG_INFINITY;
                for ky in 0..ksize.0 {
                    let iy = (oy * stride.0 + ky) as isize - t as isize;
                    if !(0..h as isize).contains(&iy) {
                        continue;
                    }
                    for kx in 0..ksize.1 {
                        let ix = (ox * stride.1 + kx) as isize - l as isize;
                        if !(0..w as isize).contains(&ix) {
                            continue;
                        }
                        m = m.max(x.at4(0, iy as usize, ix as usize, ch));
                    }
                }
                // TF MaxPool SAME pads with -inf (padding never wins);
                // a window fully in padding cannot occur for valid params.
                *out.at4_mut(0, oy, ox, ch) = m;
            }
        }
    }
    out
}

pub fn global_mean(x: &Tensor) -> Tensor {
    let (h, w, c) = (x.shape[1], x.shape[2], x.shape[3]);
    let mut out = Tensor::zeros(&[1, c]);
    for ch in 0..c {
        let mut acc = 0f64;
        for y in 0..h {
            for xx in 0..w {
                acc += x.at4(0, y, xx, ch) as f64;
            }
        }
        out.data[ch] = (acc / (h * w) as f64) as f32;
    }
    out
}

pub fn batch_norm(
    x: &Tensor,
    scale: &Tensor,
    offset: &Tensor,
    mean: &Tensor,
    variance: &Tensor,
    epsilon: f32,
) -> Tensor {
    let ch = *x.shape.last().unwrap();
    let mut out = x.clone();
    for (i, v) in out.data.iter_mut().enumerate() {
        let c = i % ch;
        *v = (*v - mean.data[c]) / (variance.data[c] + epsilon).sqrt() * scale.data[c]
            + offset.data[c];
    }
    out
}

pub fn pad(x: &Tensor, pads: (usize, usize, usize, usize)) -> Tensor {
    let (t, b, l, r) = pads;
    let (h, w, c) = (x.shape[1], x.shape[2], x.shape[3]);
    let mut out = Tensor::zeros(&[1, h + t + b, w + l + r, c]);
    for y in 0..h {
        for xx in 0..w {
            for ch in 0..c {
                *out.at4_mut(0, y + t, xx + l, ch) = x.at4(0, y, xx, ch);
            }
        }
    }
    out
}

pub fn softmax(x: &Tensor) -> Tensor {
    let n = x.shape[0];
    let c = x.shape[1];
    let mut out = x.clone();
    for i in 0..n {
        let row = &mut out.data[i * c..(i + 1) * c];
        let m = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        let mut sum = 0f32;
        for v in row.iter_mut() {
            *v = (*v - m).exp();
            sum += *v;
        }
        for v in row.iter_mut() {
            *v /= sum;
        }
    }
    out
}

/// argmax over the last dim of a [N, C] tensor — classification decision.
pub fn argmax(x: &Tensor) -> Vec<usize> {
    let c = *x.shape.last().unwrap();
    x.data
        .chunks_exact(c)
        .map(|row| {
            row.iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i)
                .unwrap()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{assert_close, Cases};
    use crate::util::Rng;

    #[test]
    fn conv2d_identity_kernel() {
        // 1x1 kernel with identity channel map reproduces the input.
        let mut x = Tensor::zeros(&[1, 3, 3, 2]);
        for (i, v) in x.data.iter_mut().enumerate() {
            *v = i as f32;
        }
        let mut w = Tensor::zeros(&[1, 1, 2, 2]);
        w.data[0] = 1.0; // ci0 -> co0
        w.data[3] = 1.0; // ci1 -> co1
        let y = conv2d(&x, &w, (1, 1), Padding::Valid);
        assert_eq!(y.data, x.data);
    }

    #[test]
    fn conv2d_known_answer() {
        // 2x2 input, 2x2 all-ones kernel, VALID -> sum of all elements.
        let x = Tensor::from_vec(&[1, 2, 2, 1], vec![1.0, 2.0, 3.0, 4.0]);
        let w = Tensor::from_vec(&[2, 2, 1, 1], vec![1.0; 4]);
        let y = conv2d(&x, &w, (1, 1), Padding::Valid);
        assert_eq!(y.shape, vec![1, 1, 1, 1]);
        assert_eq!(y.data[0], 10.0);
    }

    #[test]
    fn conv2d_same_padding_edges() {
        // 3x3 ones kernel over 2x2 ones input with SAME: corner windows
        // see 4 ones.
        let x = Tensor::from_vec(&[1, 2, 2, 1], vec![1.0; 4]);
        let w = Tensor::from_vec(&[3, 3, 1, 1], vec![1.0; 9]);
        let y = conv2d(&x, &w, (1, 1), Padding::Same);
        assert_eq!(y.shape, vec![1, 2, 2, 1]);
        assert_eq!(y.data, vec![4.0; 4]);
    }

    #[test]
    fn conv2d_stride() {
        let x = Tensor::from_vec(
            &[1, 4, 4, 1],
            (0..16).map(|i| i as f32).collect(),
        );
        let w = Tensor::from_vec(&[1, 1, 1, 1], vec![1.0]);
        let y = conv2d(&x, &w, (2, 2), Padding::Valid);
        assert_eq!(y.shape, vec![1, 2, 2, 1]);
        assert_eq!(y.data, vec![0.0, 2.0, 8.0, 10.0]);
    }

    #[test]
    fn depthwise_preserves_channels_independently() {
        let mut rng = Rng::new(5);
        let x = Tensor::randn(&[1, 5, 5, 3], &mut rng, 1.0);
        let w = Tensor::randn(&[3, 3, 3, 1], &mut rng, 1.0);
        let y = depthwise_conv2d(&x, &w, (1, 1), Padding::Same);
        assert_eq!(y.shape, vec![1, 5, 5, 3]);
        // channel 0 of output == conv of channel 0 alone
        let x0 = Tensor::from_vec(
            &[1, 5, 5, 1],
            (0..25).map(|i| x.data[i * 3]).collect(),
        );
        let w0 = Tensor::from_vec(
            &[3, 3, 1, 1],
            (0..9).map(|i| w.data[i * 3]).collect(),
        );
        let y0 = conv2d(&x0, &w0, (1, 1), Padding::Same);
        let y_ch0: Vec<f32> = (0..25).map(|i| y.data[i * 3]).collect();
        assert_close(&y_ch0, &y0.data, 1e-5, 1e-5).unwrap();
    }

    #[test]
    fn matmul_known() {
        let x = Tensor::from_vec(&[1, 2], vec![1.0, 2.0]);
        let w = Tensor::from_vec(&[2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let y = matmul(&x, &w);
        assert_eq!(y.data, vec![9.0, 12.0, 15.0]);
    }

    #[test]
    fn maxpool_basic() {
        let x = Tensor::from_vec(
            &[1, 2, 2, 1],
            vec![1.0, 5.0, 3.0, 2.0],
        );
        let y = max_pool(&x, (2, 2), (2, 2), Padding::Valid);
        assert_eq!(y.data, vec![5.0]);
    }

    #[test]
    fn batch_norm_matches_formula() {
        let x = Tensor::from_vec(&[1, 1, 1, 2], vec![3.0, -1.0]);
        let scale = Tensor::from_vec(&[2], vec![2.0, 0.5]);
        let offset = Tensor::from_vec(&[2], vec![1.0, 0.0]);
        let mean = Tensor::from_vec(&[2], vec![1.0, -2.0]);
        let var = Tensor::from_vec(&[2], vec![4.0, 1.0]);
        let y = batch_norm(&x, &scale, &offset, &mean, &var, 0.0);
        assert_close(&y.data, &[3.0, 0.5], 1e-6, 1e-6).unwrap();
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let x = Tensor::from_vec(&[2, 3], vec![1.0, 2.0, 3.0, -1.0, 0.0, 100.0]);
        let y = softmax(&x);
        for row in y.data.chunks_exact(3) {
            let s: f32 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
        }
        assert_eq!(argmax(&y), vec![2, 2]);
    }

    #[test]
    fn pad_places_values() {
        let x = Tensor::from_vec(&[1, 1, 1, 1], vec![7.0]);
        let y = pad(&x, (1, 0, 0, 2));
        assert_eq!(y.shape, vec![1, 2, 3, 1]);
        assert_eq!(y.at4(0, 1, 0, 0), 7.0);
        assert_eq!(y.data.iter().filter(|&&v| v != 0.0).count(), 1);
    }

    /// Property: conv2d is linear in the input.
    #[test]
    fn prop_conv_linearity() {
        Cases::new(24).run(|rng, size| {
            let c = size.clamp(1, 4);
            let x1 = Tensor::randn(&[1, 5, 5, c], rng, 1.0);
            let x2 = Tensor::randn(&[1, 5, 5, c], rng, 1.0);
            let w = Tensor::randn(&[3, 3, c, 2], rng, 1.0);
            let sum = zip_binary(&x1, &x2, |a, b| a + b);
            let y_sum = conv2d(&sum, &w, (1, 1), Padding::Same);
            let y1 = conv2d(&x1, &w, (1, 1), Padding::Same);
            let y2 = conv2d(&x2, &w, (1, 1), Padding::Same);
            let y12 = zip_binary(&y1, &y2, |a, b| a + b);
            assert_close(&y_sum.data, &y12.data, 1e-4, 1e-4)
        });
    }

    /// Property: global mean after relu is bounded by max activation.
    #[test]
    fn prop_mean_bounds() {
        Cases::new(16).run(|rng, size| {
            let c = size.clamp(1, 8);
            let x = Tensor::randn(&[1, 4, 4, c], rng, 2.0);
            let r = map_unary(&x, |v| v.max(0.0));
            let m = global_mean(&r);
            let maxv = r.max_abs();
            if m.data.iter().all(|&v| v >= 0.0 && v <= maxv + 1e-6) {
                Ok(())
            } else {
                Err(format!("mean out of bounds: {:?} max {maxv}", m.data))
            }
        });
    }

    #[test]
    fn whole_graph_run() {
        let mut g = crate::graph::Graph::new();
        let mut rng = Rng::new(0);
        g.op("input", Op::Placeholder { shape: vec![1, 4, 4, 2] }, &[]);
        g.constant("w", Tensor::randn(&[3, 3, 2, 4], &mut rng, 0.5));
        g.op(
            "conv",
            Op::Conv2D { stride: (1, 1), padding: Padding::Same },
            &["input", "w"],
        );
        g.op("relu", Op::Relu, &["conv"]);
        g.op("gap", Op::Mean, &["relu"]);
        g.outputs = vec!["gap".into()];
        let mut feeds = BTreeMap::new();
        feeds.insert("input".to_string(), Tensor::randn(&[1, 4, 4, 2], &mut rng, 1.0));
        let outs = run_outputs(&g, &feeds).unwrap();
        assert_eq!(outs[0].shape, vec![1, 4]);
        assert!(outs[0].data.iter().all(|&v| v >= 0.0));
    }

    #[test]
    fn missing_feed_is_error() {
        let mut g = crate::graph::Graph::new();
        g.op("input", Op::Placeholder { shape: vec![1, 2, 2, 1] }, &[]);
        g.outputs = vec!["input".into()];
        assert!(run_outputs(&g, &BTreeMap::new()).is_err());
    }
}
