//! Fixed-point graph executor.
//!
//! Models HPIPE's 16-bit fixed-point datapath (§VI, Table III): weights
//! and activations are quantized to per-operation [`FixedFormat`]s, the
//! multiply-accumulate chain runs in exact integer arithmetic (the S10
//! DSP block's wide accumulator — products and partial sums never round),
//! and each module's output is requantized to the next stage's activation
//! format. The "precision annotations file" of Fig 4 maps to
//! [`PrecisionConfig`]: a default format plus per-node overrides.

use super::{argmax, run as run_f32};
use crate::graph::{FixedFormat, Graph, GraphError, Op, Padding, Tensor};
use std::collections::BTreeMap;

/// Per-network precision assignment (the Fig 4 annotations file).
#[derive(Clone, Debug)]
pub struct PrecisionConfig {
    /// Default activation/weight format (paper: 16-bit fixed point).
    pub default: FixedFormat,
    /// Per-node overrides, keyed by node name.
    pub overrides: BTreeMap<String, FixedFormat>,
    /// If true, choose the fractional split per tensor from its observed
    /// range (calibration); `default.bits` still bounds total width.
    pub calibrate: bool,
}

impl PrecisionConfig {
    pub fn uniform(bits: u32, frac: u32) -> PrecisionConfig {
        PrecisionConfig {
            default: FixedFormat::q(bits, frac),
            overrides: BTreeMap::new(),
            calibrate: true,
        }
    }

    /// The paper's configuration: 16-bit, range-calibrated per tensor.
    pub fn paper_16bit() -> PrecisionConfig {
        PrecisionConfig::uniform(16, 8)
    }

    fn format_for(&self, name: &str, max_abs: f32) -> FixedFormat {
        if let Some(f) = self.overrides.get(name) {
            return *f;
        }
        if self.calibrate {
            FixedFormat::for_range(self.default.bits, max_abs)
        } else {
            self.default
        }
    }
}

/// A tensor in the integer domain: values plus the format they carry.
#[derive(Clone, Debug)]
struct QTensor {
    shape: Vec<usize>,
    data: Vec<i64>,
    frac: u32,
}

impl QTensor {
    fn quantize(t: &Tensor, f: FixedFormat) -> QTensor {
        QTensor {
            shape: t.shape.clone(),
            data: t.data.iter().map(|&x| f.quantize(x)).collect(),
            frac: f.frac,
        }
    }

    fn dequantize(&self) -> Tensor {
        let s = (1i64 << self.frac) as f32;
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().map(|&v| v as f32 / s).collect(),
        }
    }

    /// Requantize to a target format with round-to-nearest + saturation.
    fn requantize(&self, f: FixedFormat) -> QTensor {
        let data = self
            .data
            .iter()
            .map(|&v| requant_val(v, self.frac, f))
            .collect();
        QTensor {
            shape: self.shape.clone(),
            data,
            frac: f.frac,
        }
    }
}

#[inline]
fn requant_val(v: i64, from_frac: u32, to: FixedFormat) -> i64 {
    let shifted = if to.frac >= from_frac {
        v << (to.frac - from_frac)
    } else {
        let shift = from_frac - to.frac;
        // round-to-nearest (ties away from zero), like the RTL's rounder
        let half = 1i64 << (shift - 1);
        if v >= 0 {
            (v + half) >> shift
        } else {
            -((-v + half) >> shift)
        }
    };
    shifted.clamp(to.min_val(), to.max_val())
}

/// Result of a fixed-point run: dequantized node values plus per-node
/// error relative to the f32 oracle.
pub struct FixedRun {
    pub outputs: Vec<Tensor>,
    /// max |fixed - f32| over each output tensor.
    pub max_abs_error: f32,
    /// did argmax of the first output agree with f32? (classification)
    pub argmax_match: bool,
}

/// Execute the graph in the fixed-point domain and compare against the
/// f32 interpreter.
pub fn run_fixed(
    graph: &Graph,
    feeds: &BTreeMap<String, Tensor>,
    cfg: &PrecisionConfig,
) -> Result<FixedRun, GraphError> {
    let order = graph.topo_order()?;
    // f32 oracle pass: provides calibration ranges and the error baseline.
    let f32_env = run_f32(graph, feeds)?;

    let mut env: BTreeMap<String, QTensor> = BTreeMap::new();
    for i in order {
        let n = &graph.nodes[i];
        let fmt = cfg.format_for(&n.name, f32_env[&n.name].max_abs().max(1e-6));
        let input = |k: usize| -> &QTensor { &env[&n.inputs[k]] };
        let q = match &n.op {
            Op::Placeholder { .. } => QTensor::quantize(&f32_env[&n.name], fmt),
            Op::Const => QTensor::quantize(n.value.as_ref().unwrap(), fmt),
            Op::Conv2D { stride, padding } => {
                qconv2d(input(0), input(1), *stride, *padding, false).requantize(fmt)
            }
            Op::DepthwiseConv2d { stride, padding } => {
                qconv2d(input(0), input(1), *stride, *padding, true).requantize(fmt)
            }
            Op::MatMul => qmatmul(input(0), input(1)).requantize(fmt),
            Op::BiasAdd | Op::AddC => {
                qaligned_channel_add(input(0), input(1)).requantize(fmt)
            }
            Op::Mul => qchannel_mul(input(0), input(1)).requantize(fmt),
            Op::Add => qadd(input(0), input(1)).requantize(fmt),
            Op::Relu => qmap(input(0), |v| v.max(0)).requantize(fmt),
            Op::Relu6 => {
                let six = 6i64 << input(0).frac;
                qmap(input(0), move |v| v.clamp(0, six)).requantize(fmt)
            }
            Op::MaxPool { ksize, stride, padding } => {
                qmaxpool(input(0), *ksize, *stride, *padding).requantize(fmt)
            }
            Op::Mean => qmean(input(0)).requantize(fmt),
            Op::Pad { pads } => qpad(input(0), *pads),
            Op::FusedBatchNorm { .. } => {
                // BN survives only in un-transformed graphs: run in float
                // (hardware never sees it — the compiler folds it away).
                QTensor::quantize(&f32_env[&n.name], fmt)
            }
            Op::Softmax => QTensor::quantize(&super::softmax(&input(0).dequantize()), fmt),
        };
        env.insert(n.name.clone(), q);
    }

    let outputs: Vec<Tensor> = graph
        .outputs
        .iter()
        .map(|o| env[o].dequantize())
        .collect();
    let mut max_err = 0f32;
    for (out, name) in outputs.iter().zip(&graph.outputs) {
        for (a, b) in out.data.iter().zip(&f32_env[name].data) {
            max_err = max_err.max((a - b).abs());
        }
    }
    let argmax_match = graph
        .outputs
        .first()
        .map(|name| {
            let fx = &outputs[0];
            let fl = &f32_env[name];
            fx.rank() == 2 && argmax(fx) == argmax(fl)
        })
        .unwrap_or(true);
    Ok(FixedRun {
        outputs,
        max_abs_error: max_err,
        argmax_match,
    })
}

// --------- integer op kernels (exact i64 accumulation) ---------

fn qconv2d(
    x: &QTensor,
    w: &QTensor,
    stride: (usize, usize),
    padding: Padding,
    depthwise: bool,
) -> QTensor {
    let (h, wi, ci) = (x.shape[1], x.shape[2], x.shape[3]);
    let (kh, kw, wci, cm) = (w.shape[0], w.shape[1], w.shape[2], w.shape[3]);
    let (t, b, l, r) = padding.resolve(h, wi, kh, kw, stride.0, stride.1);
    let ho = (h + t + b - kh) / stride.0 + 1;
    let wo = (wi + l + r - kw) / stride.1 + 1;
    let co = if depthwise { ci * cm } else { cm };
    let mut out = vec![0i64; ho * wo * co];
    let idx_x = |y: usize, xx: usize, c: usize| (y * wi + xx) * ci + c;
    for oy in 0..ho {
        for ox in 0..wo {
            for oc in 0..co {
                let mut acc = 0i64;
                for ky in 0..kh {
                    let iy = (oy * stride.0 + ky) as isize - t as isize;
                    if !(0..h as isize).contains(&iy) {
                        continue;
                    }
                    for kx in 0..kw {
                        let ix = (ox * stride.1 + kx) as isize - l as isize;
                        if !(0..wi as isize).contains(&ix) {
                            continue;
                        }
                        if depthwise {
                            let (ic, im) = (oc / cm, oc % cm);
                            acc += x.data[idx_x(iy as usize, ix as usize, ic)]
                                * w.data[((ky * kw + kx) * wci + ic) * cm + im];
                        } else {
                            for ic in 0..ci {
                                acc += x.data[idx_x(iy as usize, ix as usize, ic)]
                                    * w.data[((ky * kw + kx) * wci + ic) * cm + oc];
                            }
                        }
                    }
                }
                out[(oy * wo + ox) * co + oc] = acc;
            }
        }
    }
    QTensor {
        shape: vec![1, ho, wo, co],
        data: out,
        frac: x.frac + w.frac,
    }
}

fn qmatmul(x: &QTensor, w: &QTensor) -> QTensor {
    let (n, ci) = (x.shape[0], x.shape[1]);
    let co = w.shape[1];
    let mut out = vec![0i64; n * co];
    for i in 0..n {
        for j in 0..co {
            let mut acc = 0i64;
            for k in 0..ci {
                acc += x.data[i * ci + k] * w.data[k * co + j];
            }
            out[i * co + j] = acc;
        }
    }
    QTensor {
        shape: vec![n, co],
        data: out,
        frac: x.frac + w.frac,
    }
}

/// Channel-wise add with fraction alignment (BiasAdd / AddC).
fn qaligned_channel_add(x: &QTensor, c: &QTensor) -> QTensor {
    let ch = *x.shape.last().unwrap();
    let frac = x.frac.max(c.frac);
    let xs = frac - x.frac;
    let cs = frac - c.frac;
    let data = x
        .data
        .iter()
        .enumerate()
        .map(|(i, &v)| (v << xs) + (c.data[i % ch] << cs))
        .collect();
    QTensor {
        shape: x.shape.clone(),
        data,
        frac,
    }
}

fn qchannel_mul(x: &QTensor, c: &QTensor) -> QTensor {
    let ch = *x.shape.last().unwrap();
    let data = x
        .data
        .iter()
        .enumerate()
        .map(|(i, &v)| v * c.data[i % ch])
        .collect();
    QTensor {
        shape: x.shape.clone(),
        data,
        frac: x.frac + c.frac,
    }
}

fn qadd(a: &QTensor, b: &QTensor) -> QTensor {
    let frac = a.frac.max(b.frac);
    let sa = frac - a.frac;
    let sb = frac - b.frac;
    let data = a
        .data
        .iter()
        .zip(&b.data)
        .map(|(&x, &y)| (x << sa) + (y << sb))
        .collect();
    QTensor {
        shape: a.shape.clone(),
        data,
        frac,
    }
}

fn qmap(x: &QTensor, f: impl Fn(i64) -> i64) -> QTensor {
    QTensor {
        shape: x.shape.clone(),
        data: x.data.iter().map(|&v| f(v)).collect(),
        frac: x.frac,
    }
}

fn qmaxpool(
    x: &QTensor,
    ksize: (usize, usize),
    stride: (usize, usize),
    padding: Padding,
) -> QTensor {
    let (h, w, c) = (x.shape[1], x.shape[2], x.shape[3]);
    let (t, b, l, r) = padding.resolve(h, w, ksize.0, ksize.1, stride.0, stride.1);
    let ho = (h + t + b - ksize.0) / stride.0 + 1;
    let wo = (w + l + r - ksize.1) / stride.1 + 1;
    let mut out = vec![0i64; ho * wo * c];
    for oy in 0..ho {
        for ox in 0..wo {
            for ch in 0..c {
                let mut m = i64::MIN;
                for ky in 0..ksize.0 {
                    let iy = (oy * stride.0 + ky) as isize - t as isize;
                    if !(0..h as isize).contains(&iy) {
                        continue;
                    }
                    for kx in 0..ksize.1 {
                        let ix = (ox * stride.1 + kx) as isize - l as isize;
                        if !(0..w as isize).contains(&ix) {
                            continue;
                        }
                        m = m.max(x.data[((iy as usize * w) + ix as usize) * c + ch]);
                    }
                }
                out[(oy * wo + ox) * c + ch] = m;
            }
        }
    }
    QTensor {
        shape: vec![1, ho, wo, c],
        data: out,
        frac: x.frac,
    }
}

fn qmean(x: &QTensor) -> QTensor {
    let (h, w, c) = (x.shape[1], x.shape[2], x.shape[3]);
    let n = (h * w) as i64;
    let mut out = vec![0i64; c];
    for y in 0..h {
        for xx in 0..w {
            for ch in 0..c {
                out[ch] += x.data[((y * w) + xx) * c + ch];
            }
        }
    }
    // divide with rounding; result keeps the input fraction (hardware
    // implements this with a multiply by reciprocal into the DSP).
    for v in out.iter_mut() {
        let x = *v;
        *v = if x >= 0 { (x + n / 2) / n } else { -((-x + n / 2) / n) };
    }
    QTensor {
        shape: vec![1, c],
        data: out,
        frac: x.frac,
    }
}

fn qpad(x: &QTensor, pads: (usize, usize, usize, usize)) -> QTensor {
    let (t, b, l, r) = pads;
    let (h, w, c) = (x.shape[1], x.shape[2], x.shape[3]);
    let (nh, nw) = (h + t + b, w + l + r);
    let mut out = vec![0i64; nh * nw * c];
    for y in 0..h {
        for xx in 0..w {
            for ch in 0..c {
                out[((y + t) * nw + (xx + l)) * c + ch] = x.data[((y * w) + xx) * c + ch];
            }
        }
    }
    QTensor {
        shape: vec![1, nh, nw, c],
        data: out,
        frac: x.frac,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn tiny_graph(rng: &mut Rng) -> (Graph, BTreeMap<String, Tensor>) {
        let mut g = Graph::new();
        g.op("input", Op::Placeholder { shape: vec![1, 6, 6, 3] }, &[]);
        g.constant("w0", Tensor::randn(&[3, 3, 3, 8], rng, 0.3));
        g.op(
            "conv0",
            Op::Conv2D { stride: (1, 1), padding: Padding::Same },
            &["input", "w0"],
        );
        g.constant("b0", Tensor::randn(&[8], rng, 0.1));
        g.op("bias0", Op::BiasAdd, &["conv0", "b0"]);
        g.op("relu0", Op::Relu, &["bias0"]);
        g.op("gap", Op::Mean, &["relu0"]);
        g.constant("fw", Tensor::randn(&[8, 4], rng, 0.3));
        g.op("fc", Op::MatMul, &["gap", "fw"]);
        g.outputs = vec!["fc".into()];
        let mut feeds = BTreeMap::new();
        feeds.insert("input".to_string(), Tensor::randn(&[1, 6, 6, 3], rng, 1.0));
        (g, feeds)
    }

    #[test]
    fn sixteen_bit_error_is_small() {
        let mut rng = Rng::new(21);
        let (g, feeds) = tiny_graph(&mut rng);
        let r = run_fixed(&g, &feeds, &PrecisionConfig::paper_16bit()).unwrap();
        assert!(r.max_abs_error < 0.02, "err={}", r.max_abs_error);
        assert!(r.argmax_match);
    }

    #[test]
    fn precision_ladder_monotone() {
        // More bits -> error should (weakly) shrink across a wide ladder.
        let mut rng = Rng::new(22);
        let (g, feeds) = tiny_graph(&mut rng);
        let errs: Vec<f32> = [6u32, 8, 12, 16]
            .iter()
            .map(|&bits| {
                run_fixed(&g, &feeds, &PrecisionConfig::uniform(bits, 4))
                    .unwrap()
                    .max_abs_error
            })
            .collect();
        assert!(errs[0] > errs[3], "ladder: {errs:?}");
        assert!(errs[1] >= errs[3] * 0.5, "ladder: {errs:?}");
    }

    #[test]
    fn per_node_override_applies() {
        let mut rng = Rng::new(23);
        let (g, feeds) = tiny_graph(&mut rng);
        let mut cfg = PrecisionConfig::paper_16bit();
        // crush the first conv to 4 bits: error must blow up vs 16-bit
        cfg.overrides
            .insert("conv0".into(), FixedFormat::q(4, 2));
        cfg.overrides.insert("w0".into(), FixedFormat::q(4, 2));
        let degraded = run_fixed(&g, &feeds, &cfg).unwrap();
        let clean = run_fixed(&g, &feeds, &PrecisionConfig::paper_16bit()).unwrap();
        assert!(degraded.max_abs_error > clean.max_abs_error * 4.0);
    }

    #[test]
    fn requantize_round_and_saturate() {
        // 1.75 at frac=8 -> frac=1: rounds to 2.0
        let f = FixedFormat::q(16, 1);
        assert_eq!(requant_val(448, 8, f), 4); // 1.75*256=448 -> 4/2=2.0
        // saturation at 8-bit
        let f8 = FixedFormat::q(8, 0);
        assert_eq!(requant_val(1000 << 4, 4, f8), 127);
        assert_eq!(requant_val(-(1000i64 << 4), 4, f8), -128);
    }

    #[test]
    fn exact_when_values_on_grid() {
        // Integers on the grid: fixed-point run must be bit-exact.
        let mut g = Graph::new();
        g.op("input", Op::Placeholder { shape: vec![1, 2, 2, 1] }, &[]);
        g.constant("w", Tensor::from_vec(&[1, 1, 1, 1], vec![2.0]));
        g.op(
            "conv",
            Op::Conv2D { stride: (1, 1), padding: Padding::Valid },
            &["input", "w"],
        );
        g.outputs = vec!["conv".into()];
        let mut feeds = BTreeMap::new();
        feeds.insert(
            "input".to_string(),
            Tensor::from_vec(&[1, 2, 2, 1], vec![1.0, -2.0, 3.0, 0.5]),
        );
        let r = run_fixed(&g, &feeds, &PrecisionConfig::paper_16bit()).unwrap();
        assert_eq!(r.max_abs_error, 0.0);
        assert_eq!(r.outputs[0].data, vec![2.0, -4.0, 6.0, 1.0]);
    }
}
