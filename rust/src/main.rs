//! `hpipe` — the HPIPE network compiler / simulator / server CLI.
//!
//! Subcommands:
//!   compile   --net <name> [--sparsity F] [--dsp-target N] [--device D]
//!             [--out DIR] [--full-scale] [--per-layer]
//!             [--plan-cache DIR [--model DIR] [--threads N]
//!             [--team N] [--autotune]]                  compile a plan
//!   simulate  --net <name> [...same...] [--images N]   cycle simulation
//!   serve     --model DIR [--requests N] [--batch N] [--threads N]
//!             [--team N] [--autotune] [--deadline-ms N] [--queue-cap N]
//!             [--shed] [--no-overlap] [--plan-family none|CSV]
//!             [--recover-after-ms N] [--no-recover] [--fault-budget N]
//!             [--plan-cache DIR] [--json FILE]       exec serving demo
//!                            (--batch N serves through *natively
//!                            batched* plans — one weight-stream walk
//!                            feeds the whole batch; threads > 1
//!                            streams batched groups through the layer
//!                            pipeline; team > 1 splits the dominant
//!                            stage's conv rows across an intra-stage
//!                            worker team — the software
//!                            `n_channel_splits` knob. --autotune
//!                            replaces both knobs with calibration:
//!                            warmup images are profiled through the
//!                            sequential plan and *measured* step costs
//!                            cut the stages, size the team from stage
//!                            imbalance + core count, and re-cut per
//!                            group-batch size. --deadline-ms N gives
//!                            every request a drop-dead time: requests
//!                            whose batch has not started executing by
//!                            then are answered `Expired`, never run.
//!                            --queue-cap N bounds the admission queue;
//!                            --shed refuses (`Shed`) on a full queue
//!                            instead of blocking the client.
//!                            Drain/execute overlap is ON by default: a
//!                            feeder thread accumulates batch i+1 while
//!                            batch i executes, so pipeline stages go
//!                            straight from one batch's last image to
//!                            the next batch's first; --no-overlap
//!                            restores the sequential drain-then-run
//!                            loop. --plan-family controls ragged-tail
//!                            routing: a drained tail of k < batch
//!                            requests runs on the smallest batch
//!                            variant that fits (k=1 takes the
//!                            latency plan) instead of being
//!                            zero-padded to the full batch — same
//!                            bits, strictly less compute. Default
//!                            family is {B/4, B/2}; `--plan-family
//!                            2,4` picks explicit sizes and
//!                            `--plan-family none` disables variants
//!                            (tails pad to the batch again).
//!                            --recover-after-ms N sets the circuit
//!                            breakers' cool-down before a tripped
//!                            site probes the pipelined path again
//!                            (default 50 ms; failed probes double
//!                            it); --no-recover makes a trip sticky
//!                            until reload. --fault-budget N flags any
//!                            model whose cumulative stage faults
//!                            exceed N with a structured
//!                            FAULT-BUDGET-EXCEEDED warning. --json
//!                            dumps the machine-readable ServeReport,
//!                            including shed / expired / rejected /
//!                            faults / degraded / recoveries counters,
//!                            a per-model `models[]` health array
//!                            ({faults, retries, trips, recoveries,
//!                            degraded_now, time_degraded_ns,
//!                            over_budget}), the inter-batch
//!                            `pipeline_idle_ns`, and the tail_batches
//!                            / padded_images tail accounting.)
//!
//! ## Sustained vs bench-loop throughput
//!
//! The `exec_engine` bench reports *bench-loop* img/s: back-to-back
//! plan executions with the next batch always materialized in memory —
//! an upper bound that hides every serving-side gap. `serve` (and the
//! sustained section of the `e2e_serving` bench) reports *sustained*
//! img/s: a live request mix with arrival jitter, ragged tails and
//! deadlines, where the pipeline only stays busy if draining the next
//! batch overlaps executing the current one. The gap between the two
//! is measured by `pipeline_idle_ns` — time from one batch's last
//! stage-exit to the next batch's first stage-entry — which the
//! overlap path exists to collapse; the sustained gate in
//! `benches/e2e_serving.rs` holds overlap ≥ drain-then-run and
//! family-routed tails ≥ padded tails under `BENCH_SMOKE=1`.
//!
//! ## Artifacts & the plan cache
//!
//! HPIPE compiles a network once into a bitstream and then serves it
//! forever; the software analog is the **plan artifact**: the fully
//! compiled serving state — packed dense panels, pre-decoded RLE
//! streams, pipeline cuts, team sizes, autotune calibration — written
//! to `DIR/<model>/plan.json` + `plan.bin` so the next process start
//! skips the fold/encode/pack/profile pipeline entirely.
//!
//! `hpipe compile --plan-cache DIR --model artifacts [--threads N]
//! [--team N] [--autotune]` pre-compiles every manifest model (each at
//! its manifest batch size) into `DIR`; `hpipe serve --plan-cache DIR`
//! restores them (serve anywhere) — the serve flags must match the
//! compile flags, because the artifact is keyed by a content hash of
//! the graphdef bytes, the plan options, the batch / plan-family set,
//! the threads / team / autotune configuration and the crate version.
//! Any mismatch, truncation or corruption is a *typed* rejection
//! (`GraphError::Artifact`) that falls back to a fresh compile — a
//! stale cache can cost time, never correctness. The SIMD tier is
//! recorded for diagnostics but re-dispatched at load, so artifacts
//! move freely between machines with different vector units.
//!
//! With a plan cache, per-model fault/breaker history persists across
//! restarts (`faults.json` next to the artifact): breakers always
//! start closed, but the report's `restored_faults` shows what
//! previous runs endured. `serve --json` reports `cold_start_ns`,
//! `plan_cache_hit`, and per-model `shared_weight_bytes` /
//! `private_weight_bytes` — the latter split proves plan-family
//! variants share one refcounted copy of every weight (variants cost
//! O(arena), not O(weights)).
//!
//! ## Environment variables
//!
//! `HPIPE_ISA=scalar|sse4.1|avx2|fma|neon|native` pins the SIMD kernel
//! dispatch tier (`exec::isa`) for the whole process. Unset or `native`
//! picks the widest tier the CPU supports; a recognised but unsupported
//! tier warns and falls back to `scalar` (never silently to native); an
//! unrecognised value warns, lists the valid spellings and uses native.
//! All tiers compute the same results — sparse kernels and non-fused
//! dense tiers bitwise, FMA/NEON dense within a few ulp — so the knob
//! exists for benchmarking and for CI's per-tier test matrix, not for
//! accuracy. `serve` prints the detected features and active tier, and
//! records the tier in the ServeReport (`--json`) so throughput numbers
//! stay comparable across machines.
//!
//! ## Failure semantics (serve)
//!
//! Every accepted request is answered exactly once — a classification
//! or a typed `RequestError` — and a fault never takes the server with
//! it. The self-healing ladder, rung by rung:
//!
//! 1. **Isolate**: a panic in a pipeline stage worker is caught inside
//!    the stage (`exec::PipelinePlan`), reported as a typed
//!    `GraphError::StageFault` for the affected batch, and the plan
//!    stays reusable — channels are never poisoned.
//! 2. **Retry**: the runtime retries the faulted batch once on the same
//!    pipelined plan (a transient fault costs one retry, not the run).
//! 3. **Trip**: if the retry also faults, the *faulting site's* circuit
//!    breaker opens (`util::breaker`, one per pipeline stage — HPIPE's
//!    per-layer-hardware granularity). Only that pipe is bypassed:
//!    batches run the sequential batch-1 plan, bitwise-identical to the
//!    oracle, while the tail variants keep their own breakers and their
//!    pipelined paths (and vice versa).
//! 4. **Probe & recover**: after the cool-down (`--recover-after-ms`,
//!    default 50 ms) the next batch runs *both* paths: the sequential
//!    oracle answers the clients, and one HalfOpen probe runs the
//!    pipelined plan against it. Bitwise match closes the breaker (the
//!    model un-degrades, counted in `recoveries`); a faulting or
//!    mismatching probe re-opens it with the cool-down doubled (capped
//!    exponential back-off). The probe can never change an answer —
//!    clients get oracle bits either way. `--no-recover` disables
//!    probing entirely: a trip is sticky until reload (PR 6 behavior).
//!
//! Per-model accounting — `{faults, retries, trips, recoveries,
//! degraded_now, time_degraded_ns}` — lands in `ServeReport.models[]`;
//! `--fault-budget N` adds a loud `FAULT-BUDGET-EXCEEDED` stderr line
//! for any model over budget. Bad inputs (wrong length, non-finite
//! values) and expired deadlines are refused with typed errors before
//! execution; a panic anywhere else in batch execution fails only that
//! batch. Sender hangup — even mid-batch — flushes the partial batch
//! and still emits the final report.
//!   tune      --net <name> [--sparsity F] [--batch N] [--cores N]
//!             [--runs K] [--out FILE]    profile-guided calibration:
//!                            print (and optionally dump as JSON) the
//!                            TuneReport — measured per-step costs,
//!                            chosen stage cuts, team size and
//!                            per-group-size repartitioning
//!   accuracy  --net <name> [--bits N]          fixed-point vs f32 study
//!
//! `hpipe compile --net resnet50 --sparsity 0.85 --dsp-target 5000
//!  --full-scale` reproduces the paper's main configuration.
//!
//! Sample `hpipe tune --net tinycnn --batch 8 --cores 4` output:
//!
//! ```text
//! tune report: model=tinycnn cores=4 batch=8 chosen_group=4
//!   group   4: stages=2 team=2 bottleneck=0.392ms stage_ms=[0.39, 0.31] \
//!              model_cuts_agree=false <- serving
//!   group   8: stages=2 team=2 bottleneck=0.781ms stage_ms=[0.78, 0.64] \
//!              model_cuts_agree=false
//! ```

use hpipe::arch::device_by_name;
use hpipe::compile::{codegen, compile, CompileOptions};
use hpipe::graph::Tensor;
use hpipe::interp::fixed::{run_fixed, PrecisionConfig};
use hpipe::nets::{build_named, NetConfig};
use hpipe::sim::simulate;
use hpipe::sparsity::prune_graph;
use hpipe::transform::optimize;
use hpipe::util::cli::Args;
use hpipe::util::error::{Context, Result};
use hpipe::util::timer::Table;
use hpipe::util::Rng;
use std::path::PathBuf;

fn main() -> Result<()> {
    let args = Args::from_env();
    match args.subcommand.as_deref() {
        Some("compile") => cmd_compile(&args),
        Some("simulate") => cmd_simulate(&args),
        Some("serve") => cmd_serve(&args),
        Some("tune") => cmd_tune(&args),
        Some("accuracy") => cmd_accuracy(&args),
        _ => {
            eprintln!(
                "usage: hpipe <compile|simulate|serve|tune|accuracy> [--flags]\n\
                 see `rust/src/main.rs` docs for the flag list"
            );
            std::process::exit(2);
        }
    }
}

fn build_plan(args: &Args) -> Result<(hpipe::graph::Graph, hpipe::compile::AcceleratorPlan)> {
    let net = args.str("net", "resnet50");
    let cfg = if args.bool("full-scale") {
        NetConfig::imagenet()
    } else {
        NetConfig::test_scale()
    };
    let mut g = build_named(&net, cfg)
        .with_context(|| format!("unknown network '{net}'"))?;
    let sparsity = args.f64("sparsity", if net == "resnet50" { 0.85 } else { 0.0 });
    if sparsity > 0.0 {
        let report = prune_graph(&mut g, sparsity);
        println!(
            "pruned to {:.1}% sparsity across {} layers",
            report.overall_sparsity() * 100.0,
            report.layers.len()
        );
    }
    let (g, log) = optimize(&g);
    println!(
        "transforms: {} BNs folded, {} pads merged",
        log.batch_norms_split, log.pads_merged
    );
    let device = device_by_name(&args.str("device", "s10_2800"))
        .context("unknown device")?
        .clone();
    let dsp_target = args.usize("dsp-target", 5000);
    let bits = args.usize("bits", 16) as u32;
    let opts = CompileOptions::new(device, dsp_target).with_precision(bits);
    let plan = compile(&g, &net, &opts)?;
    Ok((g, plan))
}

fn cmd_compile(args: &Args) -> Result<()> {
    let t0 = std::time::Instant::now();
    let (g, plan) = build_plan(args)?;
    let elapsed = t0.elapsed();
    let (alm_u, m20k_u, dsp_u) = plan.totals.utilization(&plan.device);
    println!("\n=== {} on {} ===", plan.net_name, plan.device.name);
    println!(
        "stages: {}   compile time: {elapsed:?} (paper: \"a few seconds\")",
        plan.stages.len()
    );
    println!(
        "ALMs {} ({:.0}%)  mem-ALMs {}  regs {}  M20Ks {} ({:.0}%)  DSPs {} ({:.0}%)",
        plan.totals.alms,
        alm_u * 100.0,
        plan.totals.mem_alms,
        plan.totals.registers,
        plan.totals.m20ks,
        m20k_u * 100.0,
        plan.totals.dsps,
        dsp_u * 100.0
    );
    println!(
        "fmax {:.0} MHz  interval {} cycles  throughput {:.0} img/s  latency ≈ {:.2} ms",
        plan.fmax_mhz,
        plan.interval_cycles(),
        plan.throughput_img_s(),
        plan.latency_estimate_ms()
    );
    println!("bottleneck stage: {}", plan.stages[plan.bottleneck].name);
    if let Some(out) = args.opt("out") {
        let dir = PathBuf::from(out);
        let report = codegen::generate(&plan, &g, &dir)?;
        println!(
            "generated {} modules, {} mem-init files ({} weight entries) in {}",
            report.modules,
            report.mem_init_files,
            report.weight_entries,
            dir.display()
        );
    }
    if args.bool("per-layer") {
        let mut tab = Table::new(&["stage", "op", "splits", "mults", "cycles", "dsps", "m20ks"]);
        for s in &plan.stages {
            tab.row(&[
                s.name.clone(),
                s.op.type_name().to_string(),
                s.splits.to_string(),
                s.mults.to_string(),
                s.cycles.to_string(),
                s.resources.dsps.to_string(),
                s.resources.m20ks.to_string(),
            ]);
        }
        tab.print();
    }
    // --plan-cache DIR: additionally pre-compile the *serving* plans
    // for every manifest model into on-disk artifacts, so a later
    // `hpipe serve --plan-cache DIR` (same flags) cold-starts from
    // disk instead of re-running fold/encode/pack/profile
    if let Some(cache) = args.opt("plan-cache") {
        let cache = PathBuf::from(cache);
        let model_dir = PathBuf::from(args.str("model", "artifacts"));
        let mut rt = hpipe::runtime::Runtime::cpu(&model_dir)?
            .with_threads(args.usize("threads", 1))
            .with_team(args.usize("team", 1))
            .with_plan_cache(&cache);
        if args.bool("autotune") {
            rt = rt.with_autotune(hpipe::exec::TuneOptions::default());
        }
        let t1 = std::time::Instant::now();
        let loaded = rt.load_manifest()?;
        println!(
            "plan cache: {} model(s) ready in {} after {:?} ({} restored, {} compiled+saved)",
            loaded.len(),
            cache.display(),
            t1.elapsed(),
            rt.cache_hits,
            rt.cache_misses
        );
        for name in &loaded {
            if let Some(m) = rt.model(name) {
                let (shared, private) = m.weight_bytes();
                println!("  {name}: resident weights {shared} B shared + {private} B private");
            }
        }
    }
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let (_, plan) = build_plan(args)?;
    let images = args.usize("images", 16);
    let t0 = std::time::Instant::now();
    let r = simulate(&plan, images)?;
    println!(
        "simulated {images} images ({} total cycles) in {:?}",
        r.total_cycles,
        t0.elapsed()
    );
    println!(
        "latency (image 0): {} cycles = {:.3} ms @ {:.0} MHz",
        r.first_image_latency(),
        r.latency_ms(plan.fmax_mhz),
        plan.fmax_mhz
    );
    println!(
        "steady-state interval: {} cycles -> {:.0} img/s (analytic bottleneck: {} cycles)",
        r.steady_interval(),
        r.throughput_img_s(plan.fmax_mhz),
        plan.interval_cycles()
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let dir = PathBuf::from(args.str("model", "artifacts"));
    // --plan-family none|CSV: absent = default family ({B/4, B/2}),
    // "none" = tails pad to the full batch, CSV = explicit sizes
    let plan_family = match args.opt("plan-family") {
        None => None,
        Some("none") => Some(Vec::new()),
        Some(csv) => {
            let sizes: Vec<usize> = csv
                .split(',')
                .filter(|s| !s.trim().is_empty())
                .map(|s| {
                    s.trim()
                        .parse()
                        .with_context(|| format!("--plan-family size '{s}'"))
                })
                .collect::<Result<_>>()?;
            Some(sizes)
        }
    };
    let cfg = hpipe::coordinator::ServeConfig {
        requests: args.usize("requests", 64),
        max_batch: args.usize("batch", 8),
        threads: args.usize("threads", 1),
        team: args.usize("team", 1),
        autotune: args.bool("autotune"),
        deadline_ms: args.opt("deadline-ms").and_then(|s| s.parse().ok()),
        queue_cap: args.usize("queue-cap", 0),
        shed: args.bool("shed"),
        overlap: !args.bool("no-overlap"),
        plan_family,
        recover_after_ms: args.opt("recover-after-ms").and_then(|s| s.parse().ok()),
        no_recover: args.bool("no-recover"),
        fault_budget: args.opt("fault-budget").and_then(|s| s.parse().ok()),
        plan_cache: args.opt("plan-cache").map(PathBuf::from),
    };
    let mut report = hpipe::coordinator::serve_demo(&dir, &cfg)?;
    report.print();
    if let Some(path) = args.opt("json") {
        std::fs::write(path, report.to_json().pretty())
            .with_context(|| format!("writing serve report to {path}"))?;
        println!("wrote serve report to {path}");
    }
    Ok(())
}

/// Profile-guided calibration without serving: build the network, run
/// the autotuner's measurement + cut policy, and print (or dump) the
/// resulting `TuneReport`.
fn cmd_tune(args: &Args) -> Result<()> {
    use hpipe::exec::{ProfileOptions, TuneOptions};
    use hpipe::runtime::LoadedModel;
    let net = args.str("net", "tinycnn");
    let batch = args.usize("batch", 8);
    let sparsity = args.f64("sparsity", 0.0);
    let mut g = build_named(&net, NetConfig::test_scale())
        .with_context(|| format!("unknown network '{net}'"))?;
    if sparsity > 0.0 {
        prune_graph(&mut g, sparsity);
    }
    let (g, _) = optimize(&g);
    let opts = TuneOptions {
        cores: args.usize("cores", 0),
        profile: ProfileOptions {
            runs: args.usize("runs", 5),
            ..Default::default()
        },
    };
    let t0 = std::time::Instant::now();
    let model = LoadedModel::autotuned(&net, &g, batch, &opts)?;
    let report = model.tune_report().expect("autotuned model carries a report");
    println!("calibrated '{net}' (batch {batch}) in {:?}", t0.elapsed());
    report.print();
    if let Some(path) = args.opt("out") {
        std::fs::write(path, report.to_json().pretty())
            .with_context(|| format!("writing tune report to {path}"))?;
        println!("wrote tune report to {path}");
    }
    Ok(())
}

fn cmd_accuracy(args: &Args) -> Result<()> {
    let net = args.str("net", "tinycnn");
    let bits = args.usize("bits", 16) as u32;
    let trials = args.usize("trials", 20);
    let g = build_named(&net, NetConfig::test_scale()).context("unknown network")?;
    let mut rng = Rng::new(0xACC);
    let mut agree = 0usize;
    let mut max_err = 0f32;
    for _ in 0..trials {
        let mut feeds = std::collections::BTreeMap::new();
        let in_shape = match &g.get("input").unwrap().op {
            hpipe::graph::Op::Placeholder { shape } => shape.clone(),
            _ => hpipe::bail!("no input"),
        };
        feeds.insert("input".to_string(), Tensor::randn(&in_shape, &mut rng, 1.0));
        let r = run_fixed(&g, &feeds, &PrecisionConfig::uniform(bits, bits / 2))?;
        if r.argmax_match {
            agree += 1;
        }
        max_err = max_err.max(r.max_abs_error);
    }
    println!(
        "{net} @ {bits}-bit fixed point: argmax agreement {agree}/{trials}, max |err| {max_err:.5}"
    );
    Ok(())
}
