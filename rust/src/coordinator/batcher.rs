//! Dynamic batcher.
//!
//! HPIPE's headline metric is batch-1 latency (the FPGA pipeline needs no
//! batching to be efficient — that's the whole point of Fig 8). The host
//! coordinator still batches *transfers* when multiple requests are
//! queued, like the PCIe DMA engine would: take what's waiting, up to
//! `max_batch`, waiting at most `max_wait` for stragglers.
//!
//! [`feed_batches`] is the feeder half of the coordinator's
//! drain/execute overlap: it runs `drain_batch` + payload screening +
//! concatenation on its own thread and hands finished
//! [`PreparedBatch`]es to the execution side through a bounded channel,
//! so batch i+1 accumulates while batch i is inside the pipeline.

use super::{Request, RequestError};
use std::sync::mpsc::{Receiver, RecvTimeoutError, SyncSender};
use std::time::{Duration, Instant};

/// Batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_micros(200),
        }
    }
}

/// Drain up to `max_batch` items from the channel, blocking for the
/// first one and then waiting at most `max_wait` for more. The second
/// return is the hangup flag: `true` once every sender has dropped, so
/// the serving loop can flush whatever partial batch formed mid-drain
/// and then end cleanly — disconnect-mid-batch must lose nothing.
pub fn drain_batch<T>(rx: &Receiver<T>, policy: BatchPolicy) -> (Vec<T>, bool) {
    let mut batch = Vec::with_capacity(policy.max_batch);
    // block for the first element
    match rx.recv() {
        Ok(item) => batch.push(item),
        Err(_) => return (batch, true),
    }
    crate::util::fault::point("batcher.drain", 0);
    let deadline = Instant::now() + policy.max_wait;
    while batch.len() < policy.max_batch {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(item) => batch.push(item),
            Err(RecvTimeoutError::Timeout) => break,
            Err(RecvTimeoutError::Disconnected) => return (batch, true),
        }
    }
    (batch, false)
}

/// [`drain_batch`] without the hangup flag: an empty vec then means the
/// channel has disconnected and drained dry.
pub fn next_batch<T>(rx: &Receiver<T>, policy: BatchPolicy) -> Vec<T> {
    drain_batch(rx, policy).0
}

/// Feed-channel depth for the drain/execute overlap: one batch in
/// flight inside the pipeline, one prepared and waiting. Deeper buffers
/// only add queueing latency — the pipeline can't run more than one
/// batch at a time anyway — while 2 is exactly what keeps stage workers
/// going straight from one batch's last image to the next's first.
pub const FEED_DEPTH: usize = 2;

/// A drained, screened, concatenated batch ready for execution: the
/// surviving requests plus their payloads already flattened into the
/// plan-feed layout (the concatenation cost paid on the feeder thread,
/// off the execution critical path). Deadlines are deliberately *not*
/// screened here — "expired" means "has not started executing by the
/// deadline", so only the execution side can decide it.
pub struct PreparedBatch {
    pub reqs: Vec<Request>,
    pub flat: Vec<f32>,
}

/// What the feeder saw over its whole run, folded into the serve report
/// when the feeder thread joins.
#[derive(Clone, Copy, Debug, Default)]
pub struct FeedStats {
    /// Requests drained off the admission queue.
    pub drained: usize,
    /// Malformed payloads answered `Failed` without reaching execution.
    pub rejected: usize,
}

/// Screen one payload: `Some(reason)` when it must be refused before
/// execution (a NaN must not poison the batch it would have shared a
/// plan execution with). Shared by the feeder and the non-overlapped
/// serving loop so both paths refuse identically.
pub fn malformed(data: &[f32], per_image: usize) -> Option<String> {
    if data.len() != per_image {
        return Some(format!(
            "payload length {} != {per_image} elements",
            data.len()
        ));
    }
    if let Some(pos) = data.iter().position(|v| !v.is_finite()) {
        return Some(format!("non-finite input value at index {pos}"));
    }
    None
}

/// The feeder loop: drain, screen, concatenate, hand off — until the
/// request channel hangs up (the final partial batch is still handed
/// off first, so disconnect-mid-batch loses nothing). Runs on its own
/// thread; the bounded `out` channel is the backpressure that stops it
/// racing ahead of execution by more than [`FEED_DEPTH`] batches. If
/// the execution side is gone, surviving requests are answered `Failed`
/// rather than dropped silently.
pub fn feed_batches(
    rx: &Receiver<Request>,
    out: &SyncSender<PreparedBatch>,
    policy: BatchPolicy,
    per_image: usize,
) -> FeedStats {
    let mut stats = FeedStats::default();
    loop {
        let (drained, disconnected) = drain_batch(rx, policy);
        stats.drained += drained.len();
        let mut reqs = Vec::with_capacity(drained.len());
        let mut flat = Vec::with_capacity(drained.len() * per_image);
        for req in drained {
            match malformed(&req.data, per_image) {
                Some(msg) => {
                    stats.rejected += 1;
                    let _ = req.reply.send(Err(RequestError::Failed(msg)));
                }
                None => {
                    flat.extend_from_slice(&req.data);
                    reqs.push(req);
                }
            }
        }
        if !reqs.is_empty() {
            if let Err(dead) = out.send(PreparedBatch { reqs, flat }) {
                for req in dead.0.reqs {
                    let _ = req
                        .reply
                        .send(Err(RequestError::Failed("serving loop gone".into())));
                }
                break;
            }
        }
        if disconnected {
            break;
        }
    }
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    #[test]
    fn collects_waiting_items_up_to_max() {
        let (tx, rx) = channel();
        for i in 0..10 {
            tx.send(i).unwrap();
        }
        let b = next_batch(
            &rx,
            BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) },
        );
        assert_eq!(b, vec![0, 1, 2, 3]);
        let b2 = next_batch(
            &rx,
            BatchPolicy { max_batch: 100, max_wait: Duration::from_millis(1) },
        );
        assert_eq!(b2.len(), 6);
    }

    #[test]
    fn returns_empty_on_disconnect() {
        let (tx, rx) = channel::<u32>();
        drop(tx);
        let b = next_batch(&rx, BatchPolicy::default());
        assert!(b.is_empty());
    }

    #[test]
    fn single_item_when_nothing_else_arrives() {
        let (tx, rx) = channel();
        tx.send(42).unwrap();
        let b = next_batch(
            &rx,
            BatchPolicy { max_batch: 8, max_wait: Duration::from_micros(100) },
        );
        assert_eq!(b, vec![42]);
    }

    /// The timeout path: a partial batch must form and flush when the
    /// channel goes *quiet* (sender still connected) before `max_batch`
    /// items arrive — `recv_timeout` hitting `Timeout`, not
    /// `Disconnected`. A batcher that waited for a full batch or for
    /// hangup would stall every straggler forever.
    #[test]
    fn partial_batch_flushes_on_quiet_channel() {
        let (tx, rx) = channel();
        for i in 0..3 {
            tx.send(i).unwrap();
        }
        let t0 = Instant::now();
        let b = next_batch(
            &rx,
            BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(5) },
        );
        let waited = t0.elapsed();
        // flushed the 3 waiting items without the other 5...
        assert_eq!(b, vec![0, 1, 2]);
        // ...after giving stragglers the grace window but not (say) 100x
        // it — the sender is still alive, so only the timeout can have
        // ended the wait.
        assert!(waited >= Duration::from_millis(5), "returned early: {waited:?}");
        assert!(waited < Duration::from_millis(500), "stalled: {waited:?}");
        // the sender is in fact still usable afterwards
        tx.send(99).unwrap();
        let b2 = next_batch(
            &rx,
            BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1) },
        );
        assert_eq!(b2, vec![99]);
    }

    fn mk(id: u64, data: Vec<f32>, reply: &std::sync::mpsc::Sender<super::super::Reply>) -> Request {
        Request {
            id,
            data,
            submitted: Instant::now(),
            deadline: None,
            reply: reply.clone(),
        }
    }

    /// The feeder drains + screens + concatenates on its own thread and
    /// still flushes the final partial batch on hangup — the overlap
    /// half of disconnect-mid-batch-loses-nothing.
    #[test]
    fn feeder_screens_concatenates_and_flushes_on_hangup() {
        use std::sync::mpsc::{channel, sync_channel};
        let (tx, rx) = channel::<Request>();
        let (rtx, rrx) = channel();
        let (ftx, frx) = sync_channel::<PreparedBatch>(FEED_DEPTH);
        let per = 4;
        tx.send(mk(0, vec![1.0; per], &rtx)).unwrap();
        tx.send(mk(1, vec![9.0; per - 1], &rtx)).unwrap(); // wrong length
        tx.send(mk(2, vec![2.0; per], &rtx)).unwrap();
        drop(tx);
        let policy = BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(5) };
        let stats = std::thread::spawn(move || feed_batches(&rx, &ftx, policy, per))
            .join()
            .unwrap();
        assert_eq!(stats.drained, 3);
        assert_eq!(stats.rejected, 1);
        let batches: Vec<PreparedBatch> = frx.iter().collect();
        let total: usize = batches.iter().map(|b| b.reqs.len()).sum();
        assert_eq!(total, 2, "both well-formed requests were handed off");
        for b in &batches {
            assert_eq!(b.flat.len(), b.reqs.len() * per, "flat matches the batch");
        }
        // the malformed one was answered, not silently dropped
        let failed: Vec<_> = rrx.try_iter().collect();
        assert_eq!(failed.len(), 1);
        assert!(matches!(failed[0], Err(RequestError::Failed(_))));
    }

    /// Executor-side hangup: the feeder must answer (not drop) requests
    /// it can no longer hand off, then stop.
    #[test]
    fn feeder_answers_requests_when_executor_is_gone() {
        use std::sync::mpsc::{channel, sync_channel};
        let (tx, rx) = channel::<Request>();
        let (rtx, rrx) = channel();
        let (ftx, frx) = sync_channel::<PreparedBatch>(FEED_DEPTH);
        drop(frx); // execution side already gone
        tx.send(mk(0, vec![1.0; 4], &rtx)).unwrap();
        drop(tx);
        let policy = BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1) };
        feed_batches(&rx, &ftx, policy, 4);
        let replies: Vec<_> = rrx.try_iter().collect();
        assert_eq!(replies.len(), 1);
        assert!(matches!(replies[0], Err(RequestError::Failed(_))));
    }

    #[test]
    fn malformed_screens_length_and_finiteness() {
        assert!(malformed(&[1.0, 2.0], 2).is_none());
        assert!(malformed(&[1.0], 2).unwrap().contains("length"));
        assert!(malformed(&[1.0, f32::NAN], 2).unwrap().contains("non-finite"));
        assert!(malformed(&[f32::INFINITY, 0.0], 2).unwrap().contains("index 0"));
    }

    /// Disconnect *mid-batch*: items were queued, then the sender hung
    /// up. The partial batch must come back together with the hangup
    /// flag in one call — dropping the items (or reporting the hangup
    /// one `next_batch` later, after a pointless block on `recv`) would
    /// either lose accepted requests or stall shutdown.
    #[test]
    fn disconnect_mid_batch_flushes_items_and_flags_hangup() {
        let (tx, rx) = channel();
        for i in 0..3 {
            tx.send(i).unwrap();
        }
        drop(tx);
        let (b, hangup) = drain_batch(
            &rx,
            BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(50) },
        );
        assert_eq!(b, vec![0, 1, 2]);
        assert!(hangup, "sender is gone; the drain must say so");
        // and a fully drained, disconnected channel reports the same
        let (b2, hangup2) = drain_batch(&rx, BatchPolicy::default());
        assert!(b2.is_empty());
        assert!(hangup2);
    }
}
