//! Dynamic batcher.
//!
//! HPIPE's headline metric is batch-1 latency (the FPGA pipeline needs no
//! batching to be efficient — that's the whole point of Fig 8). The host
//! coordinator still batches *transfers* when multiple requests are
//! queued, like the PCIe DMA engine would: take what's waiting, up to
//! `max_batch`, waiting at most `max_wait` for stragglers.

use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

/// Batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_micros(200),
        }
    }
}

/// Drain up to `max_batch` items from the channel, blocking for the
/// first one and then waiting at most `max_wait` for more. The second
/// return is the hangup flag: `true` once every sender has dropped, so
/// the serving loop can flush whatever partial batch formed mid-drain
/// and then end cleanly — disconnect-mid-batch must lose nothing.
pub fn drain_batch<T>(rx: &Receiver<T>, policy: BatchPolicy) -> (Vec<T>, bool) {
    let mut batch = Vec::with_capacity(policy.max_batch);
    // block for the first element
    match rx.recv() {
        Ok(item) => batch.push(item),
        Err(_) => return (batch, true),
    }
    crate::util::fault::point("batcher.drain", 0);
    let deadline = Instant::now() + policy.max_wait;
    while batch.len() < policy.max_batch {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(item) => batch.push(item),
            Err(RecvTimeoutError::Timeout) => break,
            Err(RecvTimeoutError::Disconnected) => return (batch, true),
        }
    }
    (batch, false)
}

/// [`drain_batch`] without the hangup flag: an empty vec then means the
/// channel has disconnected and drained dry.
pub fn next_batch<T>(rx: &Receiver<T>, policy: BatchPolicy) -> Vec<T> {
    drain_batch(rx, policy).0
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    #[test]
    fn collects_waiting_items_up_to_max() {
        let (tx, rx) = channel();
        for i in 0..10 {
            tx.send(i).unwrap();
        }
        let b = next_batch(
            &rx,
            BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) },
        );
        assert_eq!(b, vec![0, 1, 2, 3]);
        let b2 = next_batch(
            &rx,
            BatchPolicy { max_batch: 100, max_wait: Duration::from_millis(1) },
        );
        assert_eq!(b2.len(), 6);
    }

    #[test]
    fn returns_empty_on_disconnect() {
        let (tx, rx) = channel::<u32>();
        drop(tx);
        let b = next_batch(&rx, BatchPolicy::default());
        assert!(b.is_empty());
    }

    #[test]
    fn single_item_when_nothing_else_arrives() {
        let (tx, rx) = channel();
        tx.send(42).unwrap();
        let b = next_batch(
            &rx,
            BatchPolicy { max_batch: 8, max_wait: Duration::from_micros(100) },
        );
        assert_eq!(b, vec![42]);
    }

    /// The timeout path: a partial batch must form and flush when the
    /// channel goes *quiet* (sender still connected) before `max_batch`
    /// items arrive — `recv_timeout` hitting `Timeout`, not
    /// `Disconnected`. A batcher that waited for a full batch or for
    /// hangup would stall every straggler forever.
    #[test]
    fn partial_batch_flushes_on_quiet_channel() {
        let (tx, rx) = channel();
        for i in 0..3 {
            tx.send(i).unwrap();
        }
        let t0 = Instant::now();
        let b = next_batch(
            &rx,
            BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(5) },
        );
        let waited = t0.elapsed();
        // flushed the 3 waiting items without the other 5...
        assert_eq!(b, vec![0, 1, 2]);
        // ...after giving stragglers the grace window but not (say) 100x
        // it — the sender is still alive, so only the timeout can have
        // ended the wait.
        assert!(waited >= Duration::from_millis(5), "returned early: {waited:?}");
        assert!(waited < Duration::from_millis(500), "stalled: {waited:?}");
        // the sender is in fact still usable afterwards
        tx.send(99).unwrap();
        let b2 = next_batch(
            &rx,
            BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1) },
        );
        assert_eq!(b2, vec![99]);
    }

    /// Disconnect *mid-batch*: items were queued, then the sender hung
    /// up. The partial batch must come back together with the hangup
    /// flag in one call — dropping the items (or reporting the hangup
    /// one `next_batch` later, after a pointless block on `recv`) would
    /// either lose accepted requests or stall shutdown.
    #[test]
    fn disconnect_mid_batch_flushes_items_and_flags_hangup() {
        let (tx, rx) = channel();
        for i in 0..3 {
            tx.send(i).unwrap();
        }
        drop(tx);
        let (b, hangup) = drain_batch(
            &rx,
            BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(50) },
        );
        assert_eq!(b, vec![0, 1, 2]);
        assert!(hangup, "sender is gone; the drain must say so");
        // and a fully drained, disconnected channel reports the same
        let (b2, hangup2) = drain_batch(&rx, BatchPolicy::default());
        assert!(b2.is_empty());
        assert!(hangup2);
    }
}
