//! Serving metrics: latency percentiles + throughput accounting.

use std::time::Duration;

/// Latency recorder with percentile queries (exact, sorted on demand —
/// request counts here are thousands, not millions).
#[derive(Debug, Default, Clone)]
pub struct LatencyStats {
    samples_us: Vec<u64>,
    sorted: bool,
}

impl LatencyStats {
    pub fn record(&mut self, d: Duration) {
        self.samples_us.push(d.as_micros() as u64);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.samples_us.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples_us.is_empty()
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.samples_us.sort_unstable();
            self.sorted = true;
        }
    }

    /// Percentile in [0, 100].
    pub fn percentile(&mut self, p: f64) -> Duration {
        assert!((0.0..=100.0).contains(&p));
        if self.samples_us.is_empty() {
            return Duration::ZERO;
        }
        self.ensure_sorted();
        let idx = ((p / 100.0) * (self.samples_us.len() - 1) as f64).round() as usize;
        Duration::from_micros(self.samples_us[idx])
    }

    pub fn mean(&self) -> Duration {
        if self.samples_us.is_empty() {
            return Duration::ZERO;
        }
        let sum: u64 = self.samples_us.iter().sum();
        Duration::from_micros(sum / self.samples_us.len() as u64)
    }
}

/// Whole-run serving report.
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub requests: usize,
    pub batches: usize,
    pub wall: Duration,
    pub latency: LatencyStats,
    /// Mean occupancy of executed batches (batched efficiency).
    pub mean_batch: f64,
    /// Classification agreement with the reference interpreter, if the
    /// cross-check was run: (matches, total).
    pub interp_agreement: Option<(usize, usize)>,
}

impl ServeReport {
    pub fn throughput(&self) -> f64 {
        self.requests as f64 / self.wall.as_secs_f64().max(1e-9)
    }

    pub fn print(&mut self) {
        println!(
            "served {} requests in {:?} ({:.0} req/s), {} batches (mean occupancy {:.2})",
            self.requests,
            self.wall,
            self.throughput(),
            self.batches,
            self.mean_batch
        );
        println!(
            "latency p50 {:?}  p95 {:?}  p99 {:?}  mean {:?}",
            self.latency.percentile(50.0),
            self.latency.percentile(95.0),
            self.latency.percentile(99.0),
            self.latency.mean()
        );
        if let Some((ok, total)) = self.interp_agreement {
            println!("interp cross-check: {ok}/{total} argmax agreement");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_ordered() {
        let mut s = LatencyStats::default();
        for us in [5u64, 1, 9, 3, 7, 2, 8, 4, 6, 10] {
            s.record(Duration::from_micros(us));
        }
        assert_eq!(s.percentile(0.0), Duration::from_micros(1));
        assert_eq!(s.percentile(100.0), Duration::from_micros(10));
        assert!(s.percentile(50.0) <= s.percentile(95.0));
        assert_eq!(s.mean(), Duration::from_micros(5));
    }

    #[test]
    fn empty_stats_are_zero() {
        let mut s = LatencyStats::default();
        assert_eq!(s.percentile(99.0), Duration::ZERO);
        assert_eq!(s.mean(), Duration::ZERO);
        assert!(s.is_empty());
    }
}
