//! Serving metrics: latency percentiles + throughput accounting, with a
//! machine-readable JSON form and per-stage pipeline occupancy.

use crate::exec::StageMetrics;
use crate::util::Json;
use std::time::Duration;

/// Latency recorder with percentile queries (exact, sorted on demand —
/// request counts here are thousands, not millions).
#[derive(Debug, Default, Clone)]
pub struct LatencyStats {
    samples_us: Vec<u64>,
    sorted: bool,
}

impl LatencyStats {
    pub fn record(&mut self, d: Duration) {
        self.samples_us.push(d.as_micros() as u64);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.samples_us.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples_us.is_empty()
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.samples_us.sort_unstable();
            self.sorted = true;
        }
    }

    /// Percentile in [0, 100], standard nearest-rank convention: the
    /// value at rank `⌈p/100 · n⌉` (1-based), so p50 over an even count
    /// is the lower-middle sample and p100 is the maximum. (`p = 0` has
    /// no defined nearest rank; it is clamped to rank 1, the minimum.)
    pub fn percentile(&mut self, p: f64) -> Duration {
        assert!((0.0..=100.0).contains(&p));
        if self.samples_us.is_empty() {
            return Duration::ZERO;
        }
        self.ensure_sorted();
        let n = self.samples_us.len();
        // The epsilon absorbs f64 artifacts where (p/100)·n lands a hair
        // above the exact integer rank (e.g. 0.07 · 100 = 7.0000…01,
        // which must rank 7, not 8); it is far larger than the true
        // representation error for any realistic n, and far smaller
        // than any intentional fractional rank.
        let rank = ((p / 100.0) * n as f64 - 1e-9).ceil() as usize;
        Duration::from_micros(self.samples_us[rank.clamp(1, n) - 1])
    }

    pub fn mean(&self) -> Duration {
        if self.samples_us.is_empty() {
            return Duration::ZERO;
        }
        let sum: u64 = self.samples_us.iter().sum();
        Duration::from_micros(sum / self.samples_us.len() as u64)
    }
}

/// Per-model health in the serve report: the runtime's
/// [`crate::runtime::FaultStats`] plus the fault-budget verdict.
/// Faults are no longer only summed into a global count — "which model
/// is sick, and did it heal?" is the question an operator asks.
#[derive(Debug, Clone, Default)]
pub struct ModelHealth {
    pub name: String,
    /// Stage faults (every failed pipelined attempt, probes included).
    pub faults: u64,
    /// Faulted runs retried before bypassing the pipe.
    pub retries: u64,
    /// Circuit-breaker trips: entries into the sequential bypass.
    pub trips: u64,
    /// Successful cool-down probes: sites that closed again.
    pub recoveries: u64,
    /// True when some site is still bypassed at report time.
    pub degraded_now: bool,
    /// Total time any site spent bypassed, in nanoseconds.
    pub time_degraded_ns: u64,
    /// True when `faults` exceeded the per-model `--fault-budget`.
    pub over_budget: bool,
    /// Bytes of weight state held in the model's shared store (const
    /// tensors, packed panels, RLE streams) — one copy no matter how
    /// many plans (primary, latency, family variants) reference it.
    pub shared_weight_bytes: usize,
    /// Bytes of per-plan private state (activation arenas plus any
    /// weight state a plan does not draw from the shared store) summed
    /// across the model's plans. Family variants should move this by
    /// O(arena), not O(weights).
    pub private_weight_bytes: usize,
    /// Faults carried over from previous runs of this model, restored
    /// from the plan cache's `faults.json` (0 without `--plan-cache`).
    pub restored_faults: u64,
}

impl ModelHealth {
    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("model", Json::from(self.name.clone())),
            ("faults", Json::from(self.faults as f64)),
            ("retries", Json::from(self.retries as f64)),
            ("trips", Json::from(self.trips as f64)),
            ("recoveries", Json::from(self.recoveries as f64)),
            ("degraded_now", Json::from(self.degraded_now)),
            ("time_degraded_ns", Json::from(self.time_degraded_ns as f64)),
            ("over_budget", Json::from(self.over_budget)),
            (
                "shared_weight_bytes",
                Json::from(self.shared_weight_bytes as f64),
            ),
            (
                "private_weight_bytes",
                Json::from(self.private_weight_bytes as f64),
            ),
            ("restored_faults", Json::from(self.restored_faults as f64)),
        ])
    }
}

/// Whole-run serving report.
#[derive(Debug, Clone, Default)]
pub struct ServeReport {
    pub requests: usize,
    pub batches: usize,
    pub wall: Duration,
    pub latency: LatencyStats,
    /// Mean occupancy of executed batches (batched efficiency).
    pub mean_batch: f64,
    /// Classification agreement with the reference interpreter, if the
    /// cross-check was run: (matches, total).
    pub interp_agreement: Option<(usize, usize)>,
    /// Per-stage busy / stall / items counters of the primary serving
    /// model's pipeline (empty when it ran purely sequentially).
    pub stages: Vec<StageMetrics>,
    /// Time the primary model's pipeline sat *empty between runs* —
    /// from a group's last stage-exit to the next group's first
    /// stage-entry. The stage busy/stall counters can't see this (they
    /// only tick while a run is in flight); this is the inter-batch
    /// stall the drain/execute overlap exists to collapse.
    pub pipeline_idle_ns: u64,
    /// Executed batches that were ragged tails (k < the primary model's
    /// batch) served through a batched plan — a family variant, or the
    /// padded-to-batch fallback when no family is loaded.
    pub tail_batches: u64,
    /// Zero images padded onto those tail batches: the wasted compute
    /// the plan family shrinks (compare against a family-disabled run).
    pub padded_images: u64,
    /// Requests refused at admission because the bounded queue was full
    /// (shed-on-full policy; 0 under the blocking policy).
    pub shed: usize,
    /// Requests dropped before execution because their deadline had
    /// already passed when their batch formed.
    pub expired: usize,
    /// Requests refused with a typed error before execution (wrong
    /// input length, non-finite values).
    pub rejected: usize,
    /// Stage faults observed across the run's models (isolated panics;
    /// each failed pipelined attempt counts one). Kept as a total for
    /// report compatibility; `models` has the per-model breakdown.
    pub faults: usize,
    /// Models with any breaker site still open (sequential bypass) at
    /// the end of the run — with recovery on, a model that tripped and
    /// healed mid-run does NOT count here (see `models[].trips`).
    pub degraded: usize,
    /// Breaker recoveries across the run's models: sites that tripped,
    /// cooled down, probed bitwise-clean and closed again.
    pub recoveries: u64,
    /// Per-model fault/recovery health, in model-name order.
    pub models: Vec<ModelHealth>,
    /// Wall time from runtime construction to all models loaded and
    /// ready to serve, in nanoseconds — the number the plan-artifact
    /// cache exists to shrink (compiled-fresh vs restored-from-disk).
    pub cold_start_ns: u64,
    /// True when every served model was restored from the plan cache
    /// (no model compiled fresh this run). Always false without
    /// `--plan-cache`.
    pub plan_cache_hit: bool,
    /// Fault history carried over from previous runs, summed across
    /// models (see `models[].restored_faults`).
    pub restored_faults: u64,
    /// Active SIMD kernel dispatch tier (`exec::isa`), e.g. "fma" —
    /// recorded so perf numbers are comparable across runners.
    pub isa: String,
}

impl ServeReport {
    pub fn throughput(&self) -> f64 {
        self.requests as f64 / self.wall.as_secs_f64().max(1e-9)
    }

    /// Machine-readable form (written next to `BENCH_exec.json` by the
    /// e2e bench and by `hpipe serve --json`).
    pub fn to_json(&mut self) -> Json {
        let us = |d: Duration| Json::from(d.as_micros() as f64);
        let mut latency = Json::obj();
        latency
            .set("p50_us", us(self.latency.percentile(50.0)))
            .set("p95_us", us(self.latency.percentile(95.0)))
            .set("p99_us", us(self.latency.percentile(99.0)))
            .set("mean_us", us(self.latency.mean()))
            .set("samples", Json::from(self.latency.len()));
        let mut stages = Json::Arr(vec![]);
        for (j, s) in self.stages.iter().enumerate() {
            stages.push(Json::from_pairs(vec![
                ("stage", Json::from(j)),
                ("busy_ns", Json::from(s.busy_ns as f64)),
                ("stall_ns", Json::from(s.stall_ns as f64)),
                ("items", Json::from(s.items as f64)),
                ("occupancy", Json::from(s.occupancy())),
            ]));
        }
        let mut root = Json::obj();
        root.set("requests", Json::from(self.requests))
            .set("batches", Json::from(self.batches))
            .set("wall_us", us(self.wall))
            .set("throughput_rps", Json::from(self.throughput()))
            .set("mean_batch", Json::from(self.mean_batch))
            .set("latency", latency)
            .set("stages", stages)
            .set("pipeline_idle_ns", Json::from(self.pipeline_idle_ns as f64))
            .set("tail_batches", Json::from(self.tail_batches as f64))
            .set("padded_images", Json::from(self.padded_images as f64))
            .set("shed", Json::from(self.shed))
            .set("expired", Json::from(self.expired))
            .set("rejected", Json::from(self.rejected))
            .set("faults", Json::from(self.faults))
            .set("degraded", Json::from(self.degraded))
            .set("recoveries", Json::from(self.recoveries as f64))
            .set(
                "models",
                Json::Arr(self.models.iter().map(ModelHealth::to_json).collect()),
            )
            .set("cold_start_ns", Json::from(self.cold_start_ns as f64))
            .set("plan_cache_hit", Json::from(self.plan_cache_hit))
            .set("restored_faults", Json::from(self.restored_faults as f64))
            .set("isa", Json::from(self.isa.clone()));
        if let Some((ok, total)) = self.interp_agreement {
            root.set(
                "interp_agreement",
                Json::from_pairs(vec![
                    ("matches", Json::from(ok)),
                    ("total", Json::from(total)),
                ]),
            );
        }
        root
    }

    pub fn print(&mut self) {
        println!(
            "served {} requests in {:?} ({:.0} req/s), {} batches (mean occupancy {:.2})",
            self.requests,
            self.wall,
            self.throughput(),
            self.batches,
            self.mean_batch
        );
        println!(
            "latency p50 {:?}  p95 {:?}  p99 {:?}  mean {:?}",
            self.latency.percentile(50.0),
            self.latency.percentile(95.0),
            self.latency.percentile(99.0),
            self.latency.mean()
        );
        if !self.stages.is_empty() {
            let occ: Vec<String> = self
                .stages
                .iter()
                .map(|s| format!("{:.0}%", s.occupancy() * 100.0))
                .collect();
            println!(
                "pipeline stage occupancy: [{}]  inter-batch idle {:?}",
                occ.join(" "),
                Duration::from_nanos(self.pipeline_idle_ns)
            );
        }
        if self.tail_batches > 0 {
            println!(
                "ragged tails: {} tail batches, {} padded images",
                self.tail_batches, self.padded_images
            );
        }
        if self.shed + self.expired + self.rejected + self.faults + self.degraded > 0
            || self.recoveries > 0
        {
            println!(
                "robustness: {} shed, {} expired, {} rejected, {} stage faults, \
                 {} recoveries, {} models degraded now",
                self.shed, self.expired, self.rejected, self.faults, self.recoveries,
                self.degraded
            );
        }
        if self.cold_start_ns > 0 {
            println!(
                "cold start: {:?} ({}){}",
                Duration::from_nanos(self.cold_start_ns),
                if self.plan_cache_hit {
                    "plan cache hit"
                } else {
                    "compiled fresh"
                },
                if self.restored_faults > 0 {
                    format!(", {} faults restored from history", self.restored_faults)
                } else {
                    String::new()
                }
            );
        }
        for h in &self.models {
            if h.shared_weight_bytes + h.private_weight_bytes > 0 {
                println!(
                    "  model {}: resident weights {} B shared + {} B private",
                    h.name, h.shared_weight_bytes, h.private_weight_bytes
                );
            }
            if h.faults + h.trips + h.recoveries == 0 && !h.degraded_now {
                continue;
            }
            println!(
                "  model {}: {} faults, {} retries, {} trips, {} recoveries, \
                 degraded_now={}, time degraded {:?}{}",
                h.name,
                h.faults,
                h.retries,
                h.trips,
                h.recoveries,
                h.degraded_now,
                Duration::from_nanos(h.time_degraded_ns),
                if h.over_budget { "  [OVER FAULT BUDGET]" } else { "" }
            );
        }
        if !self.isa.is_empty() {
            println!("kernel isa tier: {}", self.isa);
        }
        if let Some((ok, total)) = self.interp_agreement {
            println!("interp cross-check: {ok}/{total} argmax agreement");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_ordered() {
        let mut s = LatencyStats::default();
        for us in [5u64, 1, 9, 3, 7, 2, 8, 4, 6, 10] {
            s.record(Duration::from_micros(us));
        }
        assert_eq!(s.percentile(0.0), Duration::from_micros(1));
        assert_eq!(s.percentile(100.0), Duration::from_micros(10));
        assert!(s.percentile(50.0) <= s.percentile(95.0));
        assert_eq!(s.mean(), Duration::from_micros(5));
    }

    /// Pin the nearest-rank convention: rank ⌈p/100 · n⌉, 1-based.
    #[test]
    fn percentile_uses_ceil_rank() {
        // even count: p50 is the LOWER middle sample (rank 5 of 10),
        // where the old `.round()` indexing picked the upper one
        let mut even = LatencyStats::default();
        for us in 1..=10u64 {
            even.record(Duration::from_micros(us));
        }
        assert_eq!(even.percentile(50.0), Duration::from_micros(5));
        assert_eq!(even.percentile(90.0), Duration::from_micros(9));
        assert_eq!(even.percentile(91.0), Duration::from_micros(10));
        assert_eq!(even.percentile(10.0), Duration::from_micros(1));
        assert_eq!(even.percentile(10.1), Duration::from_micros(2));
        // f64 artifacts must not bump the rank: over 100 samples,
        // 0.07 · 100 computes as 7.0000…01 but p7 is still rank 7
        let mut hundred = LatencyStats::default();
        for us in 1..=100u64 {
            hundred.record(Duration::from_micros(us));
        }
        assert_eq!(hundred.percentile(7.0), Duration::from_micros(7));
        assert_eq!(hundred.percentile(55.0), Duration::from_micros(55));
        assert_eq!(hundred.percentile(7.5), Duration::from_micros(8));
        // odd count: p50 is the exact middle
        let mut odd = LatencyStats::default();
        for us in 1..=5u64 {
            odd.record(Duration::from_micros(us));
        }
        assert_eq!(odd.percentile(50.0), Duration::from_micros(3));
        // single sample: every percentile is that sample
        let mut one = LatencyStats::default();
        one.record(Duration::from_micros(42));
        for p in [0.0, 50.0, 99.0, 100.0] {
            assert_eq!(one.percentile(p), Duration::from_micros(42));
        }
    }

    #[test]
    fn empty_stats_are_zero() {
        let mut s = LatencyStats::default();
        assert_eq!(s.percentile(99.0), Duration::ZERO);
        assert_eq!(s.mean(), Duration::ZERO);
        assert!(s.is_empty());
    }

    #[test]
    fn report_json_is_machine_readable() {
        let mut r = ServeReport {
            requests: 6,
            batches: 2,
            wall: Duration::from_millis(3),
            mean_batch: 3.0,
            interp_agreement: Some((6, 6)),
            stages: vec![
                StageMetrics { busy_ns: 900, stall_ns: 100, items: 6 },
                StageMetrics { busy_ns: 500, stall_ns: 500, items: 6 },
            ],
            ..Default::default()
        };
        for us in [10u64, 20, 30, 40, 50, 60] {
            r.latency.record(Duration::from_micros(us));
        }
        r.shed = 1;
        r.expired = 2;
        r.faults = 3;
        r.recoveries = 2;
        r.models = vec![ModelHealth {
            name: "tinycnn_b8".into(),
            faults: 3,
            retries: 2,
            trips: 1,
            recoveries: 2,
            degraded_now: false,
            time_degraded_ns: 5_000,
            over_budget: true,
            shared_weight_bytes: 4_096,
            private_weight_bytes: 512,
            restored_faults: 7,
        }];
        r.isa = "avx2".into();
        r.cold_start_ns = 42_000;
        r.plan_cache_hit = true;
        r.restored_faults = 7;
        r.pipeline_idle_ns = 1_234_567;
        r.tail_batches = 4;
        r.padded_images = 9;
        let parsed = Json::parse(&r.to_json().pretty()).unwrap();
        assert_eq!(parsed.get("pipeline_idle_ns").as_f64(), Some(1_234_567.0));
        assert_eq!(parsed.get("tail_batches").as_f64(), Some(4.0));
        assert_eq!(parsed.get("padded_images").as_f64(), Some(9.0));
        assert_eq!(parsed.get("isa").as_str(), Some("avx2"));
        assert_eq!(parsed.get("requests").as_usize(), Some(6));
        assert_eq!(parsed.get("shed").as_usize(), Some(1));
        assert_eq!(parsed.get("expired").as_usize(), Some(2));
        assert_eq!(parsed.get("rejected").as_usize(), Some(0));
        assert_eq!(parsed.get("faults").as_usize(), Some(3));
        assert_eq!(parsed.get("degraded").as_usize(), Some(0));
        assert_eq!(parsed.get("recoveries").as_f64(), Some(2.0));
        let models = parsed.get("models").as_arr().unwrap();
        assert_eq!(models.len(), 1);
        assert_eq!(models[0].get("model").as_str(), Some("tinycnn_b8"));
        assert_eq!(models[0].get("trips").as_f64(), Some(1.0));
        assert_eq!(models[0].get("recoveries").as_f64(), Some(2.0));
        assert_eq!(models[0].get("degraded_now").as_bool(), Some(false));
        assert_eq!(models[0].get("time_degraded_ns").as_f64(), Some(5_000.0));
        assert_eq!(models[0].get("over_budget").as_bool(), Some(true));
        assert_eq!(models[0].get("shared_weight_bytes").as_f64(), Some(4_096.0));
        assert_eq!(models[0].get("private_weight_bytes").as_f64(), Some(512.0));
        assert_eq!(models[0].get("restored_faults").as_f64(), Some(7.0));
        assert_eq!(parsed.get("cold_start_ns").as_f64(), Some(42_000.0));
        assert_eq!(parsed.get("plan_cache_hit").as_bool(), Some(true));
        assert_eq!(parsed.get("restored_faults").as_f64(), Some(7.0));
        assert_eq!(parsed.get("latency").get("p50_us").as_f64(), Some(30.0));
        let stages = parsed.get("stages").as_arr().unwrap();
        assert_eq!(stages.len(), 2);
        assert_eq!(stages[0].get("occupancy").as_f64(), Some(0.9));
        assert_eq!(
            parsed.get("interp_agreement").get("matches").as_usize(),
            Some(6)
        );
        assert!(parsed.get("throughput_rps").as_f64().unwrap() > 0.0);
    }
}
