//! Layer-3 serving coordinator.
//!
//! The host side of HPIPE: client threads submit images over a queue
//! (the PCIe analog), the coordinator drains the queue through the
//! dynamic batcher, and hands each drained batch to a **natively
//! batched** [`crate::exec::ExecutionPlan`] through the runtime — one
//! plan execution per batch (shared weight streams across the batch's
//! images), no interpreter and no run-N-times loop anywhere near the
//! hot path — returning classifications with latency accounting. `serve_demo` is
//! the end-to-end driver used by `hpipe serve`,
//! `examples/serve_batch.rs` and the e2e bench; it also cross-validates
//! the executor's results against the Rust reference interpreter (the
//! correctness oracle) on the same graphdef.
//!
//! Failure semantics: every accepted request gets an answer — a
//! [`ClassResult`] or a typed [`RequestError`] — never silence. Expired
//! deadlines and malformed payloads are refused before execution, a
//! bounded queue sheds or blocks at admission ([`submit`]), stage
//! faults are isolated inside the runtime's *self-healing* ladder
//! (`LoadedModel::run_all`: retry once, trip the faulting site's
//! circuit breaker, bypass sequentially, probe after cool-down and
//! close again), and a panic anywhere else in batch execution is
//! caught here and answered as `RequestError::Failed` for that batch
//! only. Sender hangup — even mid-batch — flushes the partial batch
//! and ends the loop with a final [`ServeReport`], which carries
//! per-model fault/recovery health and flags any model whose faults
//! exceeded the configured budget.

pub mod batcher;
pub mod metrics;

use crate::exec::TuneOptions;
use crate::graph::graphdef;
use crate::interp;
use crate::runtime::Runtime;
use crate::util::breaker::BreakerConfig;
use crate::util::error::{Context, Result};
use crate::util::Rng;
use batcher::{drain_batch, feed_batches, malformed, BatchPolicy, PreparedBatch, FEED_DEPTH};
use metrics::{LatencyStats, ModelHealth, ServeReport};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::{Path, PathBuf};
use std::sync::mpsc::{channel, sync_channel, Sender, SyncSender, TrySendError};
use std::time::{Duration, Instant};

/// One inference request.
pub struct Request {
    pub id: u64,
    pub data: Vec<f32>,
    pub submitted: Instant,
    /// Drop-dead time: if the batch containing this request has not
    /// started executing by then, the coordinator answers
    /// `Err(RequestError::Expired)` instead of running it (late answers
    /// are worthless to a deadline-bound client, and skipping them
    /// sheds exactly the load that made them late).
    pub deadline: Option<Instant>,
    pub reply: Sender<Reply>,
}

/// What a client gets back on its reply channel: a classification, or
/// a typed refusal. Accepted requests always get exactly one of these.
pub type Reply = Result<ClassResult, RequestError>;

/// Why a request was answered without a classification.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum RequestError {
    /// The deadline passed before the request's batch reached
    /// execution; the coordinator dropped it unrun.
    Expired,
    /// The bounded admission queue was full under the shed policy; the
    /// request never entered the queue.
    Shed,
    /// Execution refused or failed the request (wrong payload length,
    /// non-finite values, or an isolated execution fault).
    Failed(String),
}

impl std::fmt::Display for RequestError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RequestError::Expired => write!(f, "deadline expired before execution"),
            RequestError::Shed => write!(f, "shed: request queue full"),
            RequestError::Failed(msg) => write!(f, "request failed: {msg}"),
        }
    }
}

impl std::error::Error for RequestError {}

/// One inference response.
#[derive(Debug, Clone)]
pub struct ClassResult {
    pub id: u64,
    pub probs: Vec<f32>,
    pub latency: std::time::Duration,
}

impl ClassResult {
    /// Index of the largest probability, under IEEE total order: a NaN
    /// in the output gives a deterministic (if meaningless) answer
    /// instead of panicking the serving thread mid-reply.
    pub fn argmax(&self) -> usize {
        self.probs
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i)
            .unwrap_or(0)
    }
}

/// Admission policy for the bounded request queue (see [`submit`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QueuePolicy {
    /// Block the submitter until the queue has room: lossless
    /// backpressure, the client's own latency absorbs the overload.
    Block,
    /// Refuse immediately when the queue is full: the client gets
    /// `Err(RequestError::Shed)` on the request's reply channel and the
    /// request never enters the queue — bounded memory, bounded tail.
    Shed,
}

/// Submit a request through a bounded queue under `policy`. Returns
/// `true` when the request was enqueued; `false` when it was shed (the
/// shed notice is delivered on the request's own reply channel) or the
/// serving loop is already gone.
pub fn submit(tx: &SyncSender<Request>, req: Request, policy: QueuePolicy) -> bool {
    match policy {
        QueuePolicy::Block => tx.send(req).is_ok(),
        QueuePolicy::Shed => match tx.try_send(req) {
            Ok(()) => true,
            Err(TrySendError::Full(req)) => {
                let _ = req.reply.send(Err(RequestError::Shed));
                false
            }
            Err(TrySendError::Disconnected(_)) => false,
        },
    }
}

/// The serving loop: owns the runtime and runs on the thread that
/// created it; clients talk to it through channels. Models loaded with
/// `threads > 1` fan each drained batch out across their layer-pipeline
/// stage threads internally (`exec::PipelinePlan`). With `overlap` on
/// (the default) a feeder thread accumulates batch i+1 — drain, screen,
/// concatenate — while batch i executes, so stage workers go straight
/// from one batch's last image to the next batch's first instead of
/// idling through the drain window.
pub struct Coordinator {
    pub runtime: Runtime,
    pub policy: BatchPolicy,
    pub classes: usize,
    /// Drain/execute overlap. `false` restores the sequential
    /// drain-then-run loop (the escape hatch, `serve --no-overlap`).
    pub overlap: bool,
    /// Per-model fault budget (`--fault-budget`): a model whose
    /// cumulative stage-fault count exceeds this gets a loud structured
    /// `FAULT-BUDGET-EXCEEDED` warning on stderr and an `over_budget`
    /// flag in the report. `None` = unlimited.
    pub fault_budget: Option<u64>,
}

/// Per-run serving counters, threaded through both loop shapes.
#[derive(Default)]
struct ServeState {
    latency: LatencyStats,
    requests: usize,
    batches: usize,
    occupancy: usize,
    expired: usize,
    rejected: usize,
}

impl Coordinator {
    pub fn new(runtime: Runtime, policy: BatchPolicy) -> Coordinator {
        Coordinator {
            runtime,
            policy,
            classes: 10,
            overlap: true,
            fault_budget: None,
        }
    }

    /// Serve until the request channel disconnects — even mid-batch:
    /// the partial batch that formed when the last sender hung up is
    /// flushed before the loop ends, and the final [`ServeReport`] is
    /// always produced. Every drained request is answered exactly once,
    /// as a [`ClassResult`] or a typed [`RequestError`].
    pub fn run(&self, rx: std::sync::mpsc::Receiver<Request>) -> Result<ServeReport> {
        let per_image: usize = {
            let m = self
                .runtime
                .best_batch_model(1)
                .context("no batch-1 model loaded")?;
            m.input_shape.iter().product::<usize>() / m.input_shape[0]
        };
        // zero the primary model's cumulative pipeline counters (and
        // the shared inter-run idle tracker) so the report's occupancy
        // covers this run only
        if let Some(m) = self.runtime.best_batch_model(self.policy.max_batch) {
            m.pipeline().reset_stage_metrics();
        }
        let mut state = ServeState::default();
        let t0 = Instant::now();
        if self.overlap {
            self.run_overlapped(rx, per_image, &mut state)?;
        } else {
            self.run_drain_then_run(rx, per_image, &mut state)?;
        }
        // fold the models' fault + ragged-tail accounting into the
        // report — per model, not only summed: which model faulted,
        // whether its breakers tripped and healed, how long it spent
        // bypassed, and whether it blew its fault budget
        let mut faults = 0usize;
        let mut degraded = 0usize;
        let mut recoveries = 0u64;
        let mut tail_batches = 0u64;
        let mut padded_images = 0u64;
        let mut restored_faults = 0u64;
        let mut models = Vec::new();
        for m in self.runtime.models() {
            let fs = m.fault_stats();
            faults += fs.faults as usize;
            if fs.degraded {
                degraded += 1;
            }
            recoveries += fs.recoveries;
            let (shared_weight_bytes, private_weight_bytes) = m.weight_bytes();
            let restored = m.restored_faults();
            restored_faults += restored.faults;
            let health = ModelHealth {
                name: m.name.clone(),
                faults: fs.faults,
                retries: fs.retries,
                trips: fs.trips,
                recoveries: fs.recoveries,
                degraded_now: fs.degraded,
                time_degraded_ns: fs.time_degraded_ns,
                over_budget: self.fault_budget.is_some_and(|b| fs.faults > b),
                shared_weight_bytes,
                private_weight_bytes,
                restored_faults: restored.faults,
            };
            if health.over_budget {
                // loud and structured: greppable in logs, parseable by
                // whatever supervises the fleet
                let line = health.to_json().to_string();
                eprintln!("FAULT-BUDGET-EXCEEDED {line}");
            }
            models.push(health);
            let ts = m.tail_stats();
            tail_batches += ts.tail_runs;
            padded_images += ts.padded_images;
        }
        let primary = self.runtime.best_batch_model(self.policy.max_batch);
        Ok(ServeReport {
            requests: state.requests,
            batches: state.batches,
            wall: t0.elapsed(),
            latency: state.latency,
            mean_batch: state.occupancy as f64 / state.batches.max(1) as f64,
            interp_agreement: None,
            // per-stage busy/stall counters of the primary serving
            // model's pipeline; empty when it serves sequentially (the
            // counters would be all-zero noise, not a stalled pipeline)
            stages: primary
                .filter(|m| m.serves_pipelined())
                .map(|m| m.pipeline().stage_metrics())
                .unwrap_or_default(),
            pipeline_idle_ns: primary
                .map(|m| m.pipeline().pipeline_idle_ns())
                .unwrap_or(0),
            tail_batches,
            padded_images,
            shed: 0, // shedding happens at `submit`; the demo fills this in
            expired: state.expired,
            rejected: state.rejected,
            faults,
            degraded,
            recoveries,
            models,
            // serve_demo overwrites this with the measured load span;
            // a directly-driven coordinator reports 0 (unknown)
            cold_start_ns: 0,
            plan_cache_hit: self.runtime.cache_hits > 0 && self.runtime.cache_misses == 0,
            restored_faults,
            isa: crate::exec::isa::active().name().to_string(),
        })
    }

    /// Overlapped serving (the default): a feeder thread drains,
    /// screens and concatenates batch i+1 while this thread executes
    /// batch i, the two joined by a [`FEED_DEPTH`]-bounded channel.
    /// Hangup still flushes everything: the feeder hands off its final
    /// partial batch, its channel closes, the executor drains what's
    /// buffered, and the feeder's drain/reject counts fold in at join.
    fn run_overlapped(
        &self,
        rx: std::sync::mpsc::Receiver<Request>,
        per_image: usize,
        state: &mut ServeState,
    ) -> Result<()> {
        let policy = self.policy;
        let (feed_tx, feed_rx) = sync_channel::<PreparedBatch>(FEED_DEPTH);
        std::thread::scope(|s| {
            let feeder = s.spawn(move || feed_batches(&rx, &feed_tx, policy, per_image));
            let mut exec_result = Ok(());
            for prepared in feed_rx {
                if let Err(e) =
                    self.execute_and_reply(prepared.reqs, prepared.flat, per_image, state)
                {
                    exec_result = Err(e);
                    break;
                }
            }
            // on an executor error the for-loop drops `feed_rx`, the
            // feeder's next send fails, it answers those requests and
            // returns — the join cannot deadlock
            let stats = feeder.join().unwrap_or_default();
            state.requests += stats.drained;
            state.rejected += stats.rejected;
            exec_result
        })
    }

    /// The pre-overlap serving loop (`overlap = false`): drain a batch,
    /// run it to completion, drain the next. Kept as the escape hatch
    /// and as the baseline the sustained-throughput gate measures
    /// overlap against.
    fn run_drain_then_run(
        &self,
        rx: std::sync::mpsc::Receiver<Request>,
        per_image: usize,
        state: &mut ServeState,
    ) -> Result<()> {
        loop {
            let (drained, disconnected) = drain_batch(&rx, self.policy);
            state.requests += drained.len();
            let mut reqs = Vec::with_capacity(drained.len());
            let mut flat = Vec::with_capacity(drained.len() * per_image);
            for req in drained {
                match malformed(&req.data, per_image) {
                    Some(msg) => {
                        state.rejected += 1;
                        let _ = req.reply.send(Err(RequestError::Failed(msg)));
                    }
                    None => {
                        flat.extend_from_slice(&req.data);
                        reqs.push(req);
                    }
                }
            }
            self.execute_and_reply(reqs, flat, per_image, state)?;
            if disconnected {
                break;
            }
        }
        Ok(())
    }

    /// Execute one screened batch and answer every request in it.
    /// Deadlines are judged *here* — "expired" means the batch had not
    /// started executing by the deadline, so the check belongs at the
    /// last moment before execution, on both loop shapes (on the
    /// overlapped path a batch may also age in the feed channel). Full
    /// `model.batch`-sized chunks run straight off the prepared block;
    /// a ragged tail of k images routes through the plan family
    /// ([`crate::runtime::LoadedModel::run_tail`]: latency plan at k=1,
    /// smallest fitting variant otherwise, padded-to-batch only when
    /// the family is disabled).
    fn execute_and_reply(
        &self,
        reqs: Vec<Request>,
        flat: Vec<f32>,
        per_image: usize,
        state: &mut ServeState,
    ) -> Result<()> {
        let now = Instant::now();
        let (batch, flat) = if reqs.iter().any(|r| r.deadline.is_some_and(|d| now >= d)) {
            let mut kept = Vec::with_capacity(reqs.len());
            let mut rebuilt = Vec::with_capacity(flat.len());
            for req in reqs {
                if req.deadline.is_some_and(|d| now >= d) {
                    state.expired += 1;
                    let _ = req.reply.send(Err(RequestError::Expired));
                } else {
                    rebuilt.extend_from_slice(&req.data);
                    kept.push(req);
                }
            }
            (kept, rebuilt)
        } else {
            (reqs, flat)
        };
        if batch.is_empty() {
            return Ok(());
        }
        let model = self
            .runtime
            .best_batch_model(self.policy.max_batch)
            .context("no model loaded")?;
        // Safety net around execution: the runtime's degrade ladder
        // already absorbs pipelined stage faults, so anything that
        // still escapes (a panic on the sequential path, a typed
        // error) fails only this batch — every request in it gets
        // `Err(RequestError::Failed)` and serving continues.
        let full = model.batch * per_image;
        let exec = catch_unwind(AssertUnwindSafe(
            || -> std::result::Result<(Vec<f32>, usize), crate::graph::GraphError> {
                let mut outputs: Vec<f32> = Vec::new();
                let mut probs_per = 0usize;
                for chunk in flat.chunks(full) {
                    let images = chunk.len() / per_image;
                    let out = if chunk.len() == full {
                        model.run(chunk)?
                    } else {
                        let mut outs = model.run_tail(chunk, images)?;
                        if outs.len() != 1 {
                            return Err(crate::graph::GraphError::Invalid(
                                model.name.clone(),
                                format!("{} outputs; serving needs exactly one", outs.len()),
                            ));
                        }
                        outs.pop().expect("exactly one output")
                    };
                    probs_per = out.len() / images.max(1);
                    outputs.extend(out);
                }
                Ok((outputs, probs_per))
            },
        ));
        let outcome = match exec {
            Ok(Ok(v)) => Ok(v),
            Ok(Err(e)) => Err(e.to_string()),
            Err(payload) => Err(crate::util::fault::panic_message(payload.as_ref())),
        };
        match outcome {
            Ok((outputs, probs_per)) => {
                let now = Instant::now();
                for (i, req) in batch.iter().enumerate() {
                    let lat = now - req.submitted;
                    state.latency.record(lat);
                    let probs = outputs[i * probs_per..(i + 1) * probs_per].to_vec();
                    let _ = req.reply.send(Ok(ClassResult {
                        id: req.id,
                        probs,
                        latency: lat,
                    }));
                }
            }
            Err(msg) => {
                for req in &batch {
                    let _ = req.reply.send(Err(RequestError::Failed(msg.clone())));
                }
            }
        }
        state.occupancy += batch.len();
        state.batches += 1;
        Ok(())
    }
}

/// Configuration for [`serve_demo`]. `threads` / `team` are the static
/// pipeline knobs; `autotune` replaces both with the profile-guided
/// calibrator (measured cuts, measured team, per-group-size
/// repartitioning) during model load. `deadline_ms` / `queue_cap` /
/// `shed` are the robustness knobs: per-request deadlines, a bounded
/// admission queue, and the shed-vs-block overload policy. `overlap` /
/// `plan_family` are the always-fed knobs: drain/execute overlap and
/// ragged-tail batch variants.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    pub requests: usize,
    pub max_batch: usize,
    pub threads: usize,
    pub team: usize,
    pub autotune: bool,
    /// Per-request deadline in milliseconds from submission; requests
    /// whose batch has not started executing by then are answered
    /// `Err(RequestError::Expired)` instead of run. `None` = no
    /// deadline.
    pub deadline_ms: Option<u64>,
    /// Admission-queue capacity (bounded `sync_channel`); 0 sizes the
    /// queue to hold every demo request, i.e. no backpressure.
    pub queue_cap: usize,
    /// On a full queue, shed (refuse with `RequestError::Shed`) instead
    /// of blocking the client thread.
    pub shed: bool,
    /// Drain/execute overlap (default on): a feeder thread accumulates
    /// the next batch while the current one executes. `false` = the
    /// sequential drain-then-run loop (`serve --no-overlap`).
    pub overlap: bool,
    /// Ragged-tail plan family sizes: `None` = the default family
    /// ({B/4, B/2}); `Some(vec![])` disables tail variants (tails pad
    /// to the full batch); explicit sizes are used as given.
    pub plan_family: Option<Vec<usize>>,
    /// Cool-down before a tripped breaker site may probe the pipelined
    /// path again, in milliseconds (`--recover-after-ms`); `None` keeps
    /// the default (50 ms). Repeated failed probes double it.
    pub recover_after_ms: Option<u64>,
    /// Disable auto-recovery (`--no-recover`): a tripped site stays on
    /// the sequential bypass until reload — PR 6's sticky degrade.
    pub no_recover: bool,
    /// Per-model fault budget (`--fault-budget`): exceeds → loud
    /// structured warning + `over_budget` in the report. `None` =
    /// unlimited.
    pub fault_budget: Option<u64>,
    /// Plan-artifact cache directory (`--plan-cache DIR`): load
    /// compiled plans from versioned on-disk artifacts when the cache
    /// key matches, compile-and-save on miss, and persist per-model
    /// fault history across restarts. `None` disables the cache.
    pub plan_cache: Option<PathBuf>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            requests: 64,
            max_batch: 8,
            threads: 1,
            team: 1,
            autotune: false,
            deadline_ms: None,
            queue_cap: 0,
            shed: false,
            overlap: true,
            plan_family: None,
            recover_after_ms: None,
            no_recover: false,
            fault_budget: None,
            plan_cache: None,
        }
    }
}

/// End-to-end serving demo (the mandated E2E driver):
/// 1. load the TinyCNN graphdef artifacts and compile execution plans
///    (`threads > 1` partitions them into that many pipeline stages for
///    batch requests — the throughput-oriented serving mode — and
///    `team > 1` splits the dominant stage's conv rows across an
///    intra-stage worker team; `autotune` instead calibrates each model
///    at load: warmup images run through the sequential plan, measured
///    step costs cut the stages and size the team),
/// 2. spawn a client thread that submits `cfg.requests` synthetic images,
/// 3. serve them through the batcher + compiled executor,
/// 4. cross-check classifications against the Rust reference
///    interpreter running the same graphdef.
pub fn serve_demo(artifacts_dir: &Path, cfg: &ServeConfig) -> Result<ServeReport> {
    // cold start = runtime construction through "every model is loaded
    // and ready to serve" — the span the plan-artifact cache shrinks
    let cold_start = Instant::now();
    let mut runtime = Runtime::cpu(artifacts_dir)?
        .with_threads(cfg.threads)
        .with_team(cfg.team);
    if cfg.autotune {
        runtime = runtime.with_autotune(TuneOptions::default());
    }
    if let Some(sizes) = &cfg.plan_family {
        runtime = runtime.with_plan_family(sizes);
    }
    if let Some(dir) = &cfg.plan_cache {
        runtime = runtime.with_plan_cache(dir);
    }
    let mut breaker_cfg = match cfg.recover_after_ms {
        Some(ms) => BreakerConfig::with_cooldown_ms(ms),
        None => BreakerConfig::default(),
    };
    breaker_cfg.recover = !cfg.no_recover;
    runtime = runtime.with_recovery(breaker_cfg);
    let loaded = runtime.load_manifest()?;
    let cold_start_ns = cold_start.elapsed().as_nanos() as u64;
    if cfg.plan_cache.is_some() {
        println!(
            "plan cache: {} hit(s), {} miss(es), cold start {:?}",
            runtime.cache_hits,
            runtime.cache_misses,
            Duration::from_nanos(cold_start_ns)
        );
    }
    println!(
        "runtime: platform={} threads={} team={} autotune={} overlap={} loaded {:?}",
        runtime.platform(),
        runtime.threads,
        runtime.team,
        cfg.autotune,
        cfg.overlap,
        loaded
    );
    if let Some(m) = runtime.best_batch_model(cfg.max_batch) {
        println!(
            "plan family: batch={} tail variants {:?}",
            m.batch,
            m.variant_batches()
        );
    }
    println!(
        "kernel isa: {} (override with HPIPE_ISA=scalar|sse4.1|avx2|fma|neon|native)",
        crate::exec::isa::describe()
    );
    if cfg.autotune {
        for name in &loaded {
            if let Some(report) = runtime.model(name).and_then(|m| m.tune_report()) {
                report.print();
            }
        }
    }
    let (n_requests, max_batch) = (cfg.requests, cfg.max_batch);

    let graph = graphdef::load(&runtime.artifacts_dir.join("tinycnn"))
        .context("loading tinycnn graphdef")?;
    let input_shape = match &graph.get("input").context("input node")?.op {
        crate::graph::Op::Placeholder { shape } => shape.clone(),
        _ => crate::bail!("input is not a placeholder"),
    };
    let per_image: usize = input_shape.iter().product();

    let policy = BatchPolicy {
        max_batch,
        ..Default::default()
    };
    let mut coordinator = Coordinator::new(runtime, policy);
    coordinator.overlap = cfg.overlap;
    coordinator.fault_budget = cfg.fault_budget;

    // client thread, submitting through a bounded admission queue
    let cap = if cfg.queue_cap > 0 { cfg.queue_cap } else { n_requests.max(1) };
    let (tx, rx) = sync_channel::<Request>(cap);
    let (result_tx, result_rx) = channel::<Reply>();
    let qpolicy = if cfg.shed { QueuePolicy::Shed } else { QueuePolicy::Block };
    let deadline_ms = cfg.deadline_ms;
    let mut rng = Rng::new(0xE2E);
    let inputs: Vec<Vec<f32>> = (0..n_requests)
        .map(|_| (0..per_image).map(|_| rng.normal_f32(0.0, 1.0)).collect())
        .collect();
    let inputs_for_client = inputs.clone();
    let client = std::thread::spawn(move || {
        let mut shed = 0usize;
        for (i, data) in inputs_for_client.into_iter().enumerate() {
            let now = Instant::now();
            let req = Request {
                id: i as u64,
                data,
                submitted: now,
                deadline: deadline_ms.map(|ms| now + Duration::from_millis(ms)),
                reply: result_tx.clone(),
            };
            if !submit(&tx, req, qpolicy) {
                shed += 1;
            }
            // mild pacing: a burst every few requests exercises batching
            if i % 4 == 3 {
                std::thread::sleep(std::time::Duration::from_micros(300));
            }
        }
        shed
        // tx drops here -> coordinator drains and exits
    });

    let mut report = coordinator.run(rx)?;
    report.shed = client.join().unwrap_or(0);
    report.cold_start_ns = cold_start_ns;
    if cfg.plan_cache.is_some() {
        // write fault/breaker history next to the plan artifacts so the
        // next cold start reports what this run endured
        coordinator.runtime.persist_faults();
    }

    // collect the replies — every submitted request must have exactly
    // one, a classification or a typed refusal — and cross-check the
    // classifications against the reference interpreter
    let replies: Vec<Reply> = result_rx.try_iter().collect();
    crate::ensure!(
        replies.len() == n_requests,
        "lost responses: {} replies for {n_requests} requests",
        replies.len()
    );
    let mut results: Vec<ClassResult> = replies.into_iter().filter_map(|r| r.ok()).collect();
    results.sort_by_key(|r| r.id);
    let mut agree = 0usize;
    let check = results.len().min(32); // interp is slow; spot-check 32
    for r in results.iter().take(check) {
        let mut feeds = std::collections::BTreeMap::new();
        feeds.insert(
            "input".to_string(),
            crate::graph::Tensor::from_vec(&input_shape, inputs[r.id as usize].clone()),
        );
        let outs = interp::run_outputs(&graph, &feeds)?;
        if interp::argmax(&outs[0])[0] == r.argmax() {
            agree += 1;
        }
    }
    report.interp_agreement = Some((agree, check));
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nets::{tiny_cnn, NetConfig};

    fn mk(id: u64, data: Vec<f32>, deadline: Option<Instant>, reply: &Sender<Reply>) -> Request {
        Request {
            id,
            data,
            submitted: Instant::now(),
            deadline,
            reply: reply.clone(),
        }
    }

    fn test_coordinator(max_wait_ms: u64) -> (Coordinator, usize) {
        let mut runtime = Runtime::cpu(Path::new(".")).unwrap();
        let g = tiny_cnn(NetConfig::test_scale());
        runtime.load_graph("tinycnn_b1", &g, 1).unwrap();
        let per = runtime
            .model("tinycnn_b1")
            .unwrap()
            .input_shape
            .iter()
            .product();
        let policy = BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(max_wait_ms),
        };
        (Coordinator::new(runtime, policy), per)
    }

    #[test]
    fn class_result_argmax() {
        let r = ClassResult {
            id: 0,
            probs: vec![0.1, 0.7, 0.2],
            latency: std::time::Duration::ZERO,
        };
        assert_eq!(r.argmax(), 1);
    }

    #[test]
    fn argmax_survives_nan_and_empty_probs() {
        let nan = ClassResult {
            id: 0,
            probs: vec![0.1, f32::NAN, 0.2],
            latency: std::time::Duration::ZERO,
        };
        let _ = nan.argmax(); // must not panic; the order is total
        let empty = ClassResult {
            id: 0,
            probs: vec![],
            latency: std::time::Duration::ZERO,
        };
        assert_eq!(empty.argmax(), 0);
    }

    #[test]
    fn shed_policy_refuses_when_queue_full() {
        let (tx, _rx) = sync_channel::<Request>(1);
        let (rtx, rrx) = channel::<Reply>();
        assert!(submit(&tx, mk(0, vec![], None, &rtx), QueuePolicy::Shed));
        // queue full: the second submit is refused, and the refusal
        // arrives on the request's own reply channel
        assert!(!submit(&tx, mk(1, vec![], None, &rtx), QueuePolicy::Shed));
        match rrx.try_recv().unwrap() {
            Err(RequestError::Shed) => {}
            other => panic!("expected shed notice, got {other:?}"),
        }
    }

    /// Regression (alongside `batcher::partial_batch_flushes_on_quiet_
    /// channel`): the sender hanging up while a batch is mid-formation
    /// must flush that partial batch, answer every drained request, and
    /// end the loop with a final report — not panic or hang.
    #[test]
    fn sender_hangup_mid_batch_flushes_and_reports() {
        let (coordinator, per) = test_coordinator(200);
        let (tx, rx) = sync_channel::<Request>(8);
        let (rtx, rrx) = channel::<Reply>();
        for id in 0..3 {
            tx.send(mk(id, vec![0.5; per], None, &rtx)).unwrap();
        }
        // hangup while the batcher's straggler window is still open:
        // drain_batch sees Disconnected mid-drain, not an empty batch
        drop(tx);
        drop(rtx);
        let report = coordinator.run(rx).unwrap();
        assert_eq!(report.requests, 3);
        assert_eq!(report.batches, 1);
        let replies: Vec<Reply> = rrx.try_iter().collect();
        assert_eq!(replies.len(), 3, "hangup mid-batch must not lose answers");
        assert!(replies.iter().all(|r| r.is_ok()));
    }

    /// The `--no-overlap` escape hatch: the sequential drain-then-run
    /// loop must keep the exact answer-every-request semantics.
    #[test]
    fn drain_then_run_escape_hatch_still_serves() {
        let (mut coordinator, per) = test_coordinator(200);
        coordinator.overlap = false;
        let (tx, rx) = sync_channel::<Request>(8);
        let (rtx, rrx) = channel::<Reply>();
        for id in 0..3 {
            tx.send(mk(id, vec![0.5; per], None, &rtx)).unwrap();
        }
        drop(tx);
        drop(rtx);
        let report = coordinator.run(rx).unwrap();
        assert_eq!(report.requests, 3);
        assert_eq!(report.batches, 1);
        let replies: Vec<Reply> = rrx.try_iter().collect();
        assert_eq!(replies.len(), 3);
        assert!(replies.iter().all(|r| r.is_ok()));
    }

    /// Overlapped and non-overlapped serving must classify a ragged
    /// mix identically (bitwise: same plans, same kernels per image).
    #[test]
    fn overlap_and_drain_then_run_agree_bitwise() {
        let run_with = |overlap: bool| -> Vec<ClassResult> {
            let (mut coordinator, per) = test_coordinator(200);
            coordinator.overlap = overlap;
            let (tx, rx) = sync_channel::<Request>(8);
            let (rtx, rrx) = channel::<Reply>();
            for id in 0..5u64 {
                let v = (id as f32 + 1.0) * 0.1;
                tx.send(mk(id, vec![v; per], None, &rtx)).unwrap();
            }
            drop(tx);
            drop(rtx);
            coordinator.run(rx).unwrap();
            let mut out: Vec<ClassResult> =
                rrx.try_iter().map(|r| r.expect("all healthy")).collect();
            out.sort_by_key(|r| r.id);
            out
        };
        let (a, b) = (run_with(true), run_with(false));
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.probs, y.probs, "request {}", x.id);
        }
    }

    /// A drained tail of k < B requests routes through the plan family
    /// (smallest variant ≥ k), visible in the report's tail counters.
    #[test]
    fn ragged_tail_is_family_routed_not_padded_to_batch() {
        let mut runtime = Runtime::cpu(Path::new(".")).unwrap();
        let g = tiny_cnn(NetConfig::test_scale());
        runtime.load_graph("tinycnn_b8", &g, 8).unwrap(); // family {2, 4}
        let per = runtime
            .model("tinycnn_b8")
            .unwrap()
            .input_shape
            .iter()
            .product::<usize>()
            / 8;
        let policy = BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(100) };
        let coordinator = Coordinator::new(runtime, policy);
        let (tx, rx) = sync_channel::<Request>(8);
        let (rtx, rrx) = channel::<Reply>();
        for id in 0..3 {
            tx.send(mk(id, vec![0.5; per], None, &rtx)).unwrap();
        }
        drop(tx);
        drop(rtx);
        let report = coordinator.run(rx).unwrap();
        assert_eq!(report.requests, 3);
        assert_eq!(report.batches, 1);
        // k=3 rode the batch-4 variant: one tail run, one padded image
        assert_eq!(report.tail_batches, 1);
        assert_eq!(report.padded_images, 1);
        let replies: Vec<Reply> = rrx.try_iter().collect();
        assert_eq!(replies.len(), 3);
        assert!(replies.iter().all(|r| r.is_ok()));
    }

    #[test]
    fn admission_control_answers_expired_and_malformed() {
        let (coordinator, per) = test_coordinator(20);
        let (tx, rx) = sync_channel::<Request>(8);
        let (rtx, rrx) = channel::<Reply>();
        // already expired when its batch forms
        tx.send(mk(0, vec![0.5; per], Some(Instant::now()), &rtx))
            .unwrap();
        // wrong payload length
        tx.send(mk(1, vec![0.5; per - 1], None, &rtx)).unwrap();
        // non-finite value
        let mut nan = vec![0.5; per];
        nan[0] = f32::NAN;
        tx.send(mk(2, nan, None, &rtx)).unwrap();
        // a healthy request sharing the same drained batch still runs
        tx.send(mk(3, vec![0.5; per], None, &rtx)).unwrap();
        drop(tx);
        drop(rtx);
        let report = coordinator.run(rx).unwrap();
        assert_eq!(report.requests, 4);
        assert_eq!(report.expired, 1);
        assert_eq!(report.rejected, 2);
        let (mut ok, mut expired, mut failed) = (0, 0, 0);
        for r in rrx.try_iter() {
            match r {
                Ok(res) => {
                    assert_eq!(res.id, 3);
                    ok += 1;
                }
                Err(RequestError::Expired) => expired += 1,
                Err(RequestError::Failed(_)) => failed += 1,
                Err(RequestError::Shed) => panic!("nothing was shed"),
            }
        }
        assert_eq!((ok, expired, failed), (1, 1, 2));
    }
}
