//! Layer-3 serving coordinator.
//!
//! The host side of HPIPE: client threads submit images over a queue
//! (the PCIe analog), the coordinator drains the queue through the
//! dynamic batcher, and hands each drained batch to a **natively
//! batched** [`crate::exec::ExecutionPlan`] through the runtime — one
//! plan execution per batch (shared weight streams across the batch's
//! images), no interpreter and no run-N-times loop anywhere near the
//! hot path — returning classifications with latency accounting. `serve_demo` is
//! the end-to-end driver used by `hpipe serve`,
//! `examples/serve_batch.rs` and the e2e bench; it also cross-validates
//! the executor's results against the Rust reference interpreter (the
//! correctness oracle) on the same graphdef.

pub mod batcher;
pub mod metrics;

use crate::exec::TuneOptions;
use crate::graph::graphdef;
use crate::interp;
use crate::runtime::Runtime;
use crate::util::error::{Context, Result};
use crate::util::Rng;
use batcher::{next_batch, BatchPolicy};
use metrics::{LatencyStats, ServeReport};
use std::path::Path;
use std::sync::mpsc::{channel, Sender};
use std::time::Instant;

/// One inference request.
pub struct Request {
    pub id: u64,
    pub data: Vec<f32>,
    pub submitted: Instant,
    pub reply: Sender<ClassResult>,
}

/// One inference response.
#[derive(Debug, Clone)]
pub struct ClassResult {
    pub id: u64,
    pub probs: Vec<f32>,
    pub latency: std::time::Duration,
}

impl ClassResult {
    pub fn argmax(&self) -> usize {
        self.probs
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap_or(0)
    }
}

/// The serving loop: owns the runtime and runs on the thread that
/// created it; clients talk to it through channels. Models loaded with
/// `threads > 1` fan each drained batch out across their layer-pipeline
/// stage threads internally (`exec::PipelinePlan`), so the coordinator
/// itself stays single-threaded while batch execution is not.
pub struct Coordinator {
    pub runtime: Runtime,
    pub policy: BatchPolicy,
    pub classes: usize,
}

impl Coordinator {
    pub fn new(runtime: Runtime, policy: BatchPolicy) -> Coordinator {
        Coordinator {
            runtime,
            policy,
            classes: 10,
        }
    }

    /// Serve until the request channel disconnects. Returns the report.
    pub fn run(&self, rx: std::sync::mpsc::Receiver<Request>) -> Result<ServeReport> {
        let per_image: usize = {
            let m = self
                .runtime
                .best_batch_model(1)
                .context("no batch-1 model loaded")?;
            m.input_shape.iter().product::<usize>() / m.input_shape[0]
        };
        // zero the primary model's cumulative pipeline counters so the
        // report's occupancy covers this run only
        if let Some(m) = self.runtime.best_batch_model(self.policy.max_batch) {
            m.pipeline().reset_stage_metrics();
        }
        let mut latency = LatencyStats::default();
        let mut requests = 0usize;
        let mut batches = 0usize;
        let mut occupancy = 0usize;
        let t0 = Instant::now();
        loop {
            let batch = next_batch(&rx, self.policy);
            if batch.is_empty() {
                break;
            }
            let model = self
                .runtime
                .best_batch_model(batch.len())
                .context("no model loaded")?;
            // concatenate request payloads; the executable may be smaller
            // than the drained batch — chunk, and each full chunk is one
            // whole-batch plan execution straight off the request block
            // (only a short tail chunk pays a copy, zero-padded up to
            // the plan's batch)
            let mut flat = Vec::with_capacity(batch.len() * per_image);
            for r in &batch {
                flat.extend_from_slice(&r.data);
            }
            let mut outputs: Vec<f32> = Vec::new();
            let mut probs_per = 0usize;
            let full = model.batch * per_image;
            for chunk in flat.chunks(full) {
                let out = if chunk.len() == full {
                    model.run(chunk)?
                } else {
                    let mut c = chunk.to_vec();
                    c.resize(full, 0.0);
                    model.run(&c)?
                };
                probs_per = out.len() / model.batch.max(1);
                outputs.extend(out);
            }
            let now = Instant::now();
            for (i, req) in batch.iter().enumerate() {
                let lat = now - req.submitted;
                latency.record(lat);
                let probs = outputs[i * probs_per..(i + 1) * probs_per].to_vec();
                let _ = req.reply.send(ClassResult {
                    id: req.id,
                    probs,
                    latency: lat,
                });
            }
            requests += batch.len();
            occupancy += batch.len();
            batches += 1;
        }
        Ok(ServeReport {
            requests,
            batches,
            wall: t0.elapsed(),
            latency,
            mean_batch: occupancy as f64 / batches.max(1) as f64,
            interp_agreement: None,
            // per-stage busy/stall counters of the primary serving
            // model's pipeline; empty when it serves sequentially (the
            // counters would be all-zero noise, not a stalled pipeline)
            stages: self
                .runtime
                .best_batch_model(self.policy.max_batch)
                .filter(|m| m.serves_pipelined())
                .map(|m| m.pipeline().stage_metrics())
                .unwrap_or_default(),
        })
    }
}

/// Configuration for [`serve_demo`]. `threads` / `team` are the static
/// pipeline knobs; `autotune` replaces both with the profile-guided
/// calibrator (measured cuts, measured team, per-group-size
/// repartitioning) during model load.
#[derive(Clone, Copy, Debug)]
pub struct ServeConfig {
    pub requests: usize,
    pub max_batch: usize,
    pub threads: usize,
    pub team: usize,
    pub autotune: bool,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig { requests: 64, max_batch: 8, threads: 1, team: 1, autotune: false }
    }
}

/// End-to-end serving demo (the mandated E2E driver):
/// 1. load the TinyCNN graphdef artifacts and compile execution plans
///    (`threads > 1` partitions them into that many pipeline stages for
///    batch requests — the throughput-oriented serving mode — and
///    `team > 1` splits the dominant stage's conv rows across an
///    intra-stage worker team; `autotune` instead calibrates each model
///    at load: warmup images run through the sequential plan, measured
///    step costs cut the stages and size the team),
/// 2. spawn a client thread that submits `cfg.requests` synthetic images,
/// 3. serve them through the batcher + compiled executor,
/// 4. cross-check classifications against the Rust reference
///    interpreter running the same graphdef.
pub fn serve_demo(artifacts_dir: &Path, cfg: &ServeConfig) -> Result<ServeReport> {
    let mut runtime = Runtime::cpu(artifacts_dir)?
        .with_threads(cfg.threads)
        .with_team(cfg.team);
    if cfg.autotune {
        runtime = runtime.with_autotune(TuneOptions::default());
    }
    let loaded = runtime.load_manifest()?;
    println!(
        "runtime: platform={} threads={} team={} autotune={} loaded {:?}",
        runtime.platform(),
        runtime.threads,
        runtime.team,
        cfg.autotune,
        loaded
    );
    if cfg.autotune {
        for name in &loaded {
            if let Some(report) = runtime.model(name).and_then(|m| m.tune_report()) {
                report.print();
            }
        }
    }
    let (n_requests, max_batch) = (cfg.requests, cfg.max_batch);

    let graph = graphdef::load(&runtime.artifacts_dir.join("tinycnn"))
        .context("loading tinycnn graphdef")?;
    let input_shape = match &graph.get("input").context("input node")?.op {
        crate::graph::Op::Placeholder { shape } => shape.clone(),
        _ => crate::bail!("input is not a placeholder"),
    };
    let per_image: usize = input_shape.iter().product();

    let policy = BatchPolicy {
        max_batch,
        ..Default::default()
    };
    let coordinator = Coordinator::new(runtime, policy);

    // client thread
    let (tx, rx) = channel::<Request>();
    let (result_tx, result_rx) = channel::<ClassResult>();
    let mut rng = Rng::new(0xE2E);
    let inputs: Vec<Vec<f32>> = (0..n_requests)
        .map(|_| (0..per_image).map(|_| rng.normal_f32(0.0, 1.0)).collect())
        .collect();
    let inputs_for_client = inputs.clone();
    let client = std::thread::spawn(move || {
        for (i, data) in inputs_for_client.into_iter().enumerate() {
            let _ = tx.send(Request {
                id: i as u64,
                data,
                submitted: Instant::now(),
                reply: result_tx.clone(),
            });
            // mild pacing: a burst every few requests exercises batching
            if i % 4 == 3 {
                std::thread::sleep(std::time::Duration::from_micros(300));
            }
        }
        // tx drops here -> coordinator drains and exits
    });

    let mut report = coordinator.run(rx)?;
    client.join().ok();

    // collect results and cross-check against the reference interpreter
    let mut results: Vec<ClassResult> = result_rx.try_iter().collect();
    results.sort_by_key(|r| r.id);
    let mut agree = 0usize;
    let check = results.len().min(32); // interp is slow; spot-check 32
    for r in results.iter().take(check) {
        let mut feeds = std::collections::BTreeMap::new();
        feeds.insert(
            "input".to_string(),
            crate::graph::Tensor::from_vec(&input_shape, inputs[r.id as usize].clone()),
        );
        let outs = interp::run_outputs(&graph, &feeds)?;
        if interp::argmax(&outs[0])[0] == r.argmax() {
            agree += 1;
        }
    }
    report.interp_agreement = Some((agree, check));
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn class_result_argmax() {
        let r = ClassResult {
            id: 0,
            probs: vec![0.1, 0.7, 0.2],
            latency: std::time::Duration::ZERO,
        };
        assert_eq!(r.argmax(), 1);
    }
}
