//! FPGA device database, per-module resource cost model, and the
//! frequency heuristic — the "hardware" side of the compiler.
//!
//! We have no Stratix 10 or Quartus, so these models stand in for the
//! device (DESIGN.md §Hardware-Adaptation). Capacities are the real
//! datasheet numbers; per-module costs are parametric forms calibrated so
//! the compiled ResNet-50 / MobileNet plans land near Table II of the
//! paper. The microarchitectural structure they encode is the paper's:
//!
//! * a convolution stage instantiates one DSP chain per **output column**
//!   (Fig 6's data lines 1..W share one decoded weight/x-index/runlength
//!   stream — the §III "share address computations for a large number of
//!   output activations" insight), each chain `n_channel_splits` (`s`)
//!   multipliers deep = `ceil(W·s/2)` DSP blocks;
//! * `s` weight buffers + input activation buffers + X-muxes;
//! * soft logic per multiplier (X-mux, pad mux) plus a per-stage
//!   controller (runlength decoder, backpressure).

use crate::graph::Op;

/// An FPGA (or comparison) device's capacities.
#[derive(Clone, Debug, PartialEq)]
pub struct Device {
    pub name: &'static str,
    /// Adaptive logic modules (Intel) / LUT-FF pairs (Xilinx-equivalent).
    pub alms: usize,
    /// 20kb block RAMs (M20K for S10; BRAM36-equivalents halved for Xilinx).
    pub m20ks: usize,
    /// DSP blocks. One Intel S10 DSP = two 18x18 multipliers.
    pub dsps: usize,
    /// Multipliers per DSP block (2 for Intel, 1 for Xilinx 27x18).
    pub mults_per_dsp: usize,
    /// Peak achievable clock for a well-pipelined design (MHz).
    pub base_fmax: f64,
}

/// Stratix 10 GX 2800 — the paper's device.
pub const S10_2800: Device = Device {
    name: "Stratix 10 GX 2800",
    alms: 933_120,
    m20ks: 11_721,
    dsps: 5_760,
    mults_per_dsp: 2,
    base_fmax: 730.0,
};

/// Stratix 10 GX 1650 — Table IV note: MobileNet-V2 "could fit on an S10
/// 1650 and utilize 94% of the DSPs".
pub const S10_1650: Device = Device {
    name: "Stratix 10 GX 1650",
    alms: 550_540,
    m20ks: 5_851,
    dsps: 3_145,
    mults_per_dsp: 2,
    base_fmax: 730.0,
};

/// Arria 10 GX 1150 — Brainwave's and DLA's published platform.
pub const A10_1150: Device = Device {
    name: "Arria 10 GX 1150",
    alms: 427_200,
    m20ks: 2_713,
    dsps: 1_518,
    mults_per_dsp: 2,
    base_fmax: 450.0,
};

/// Xilinx Zynq ZU9 (ZCU102) — Lu et al. and Wu et al.'s platform.
pub const ZU9: Device = Device {
    name: "Xilinx Zynq ZU9",
    alms: 274_080,
    m20ks: 1_824,
    dsps: 2_520,
    mults_per_dsp: 1,
    base_fmax: 650.0,
};

/// Agilex AGF 027 — the §VII future-work target: "future Agilex FPGAs
/// including 2x performance for 8-bit vector dot products [28]". Modeled
/// as 4 int8 multipliers per DSP when the compiled precision is ≤ 9 bits.
pub const AGILEX_027: Device = Device {
    name: "Agilex AGF 027",
    alms: 912_800,
    m20ks: 13_272,
    dsps: 8_528,
    mults_per_dsp: 2,
    base_fmax: 800.0,
};

pub fn device_by_name(name: &str) -> Option<&'static Device> {
    match name {
        "s10_2800" => Some(&S10_2800),
        "s10_1650" => Some(&S10_1650),
        "a10_1150" => Some(&A10_1150),
        "zu9" => Some(&ZU9),
        "agilex_027" => Some(&AGILEX_027),
        _ => None,
    }
}

impl Device {
    /// 18x18-equivalent multipliers one DSP provides at a weight
    /// precision: Agilex packs two 8-bit dot-product lanes per 18x18
    /// lane (§VII / [28]); Stratix 10 always gives `mults_per_dsp`.
    pub fn mults_per_dsp_at(&self, bits: u32) -> usize {
        if bits <= 9 && self.name.starts_with("Agilex") {
            self.mults_per_dsp * 2
        } else {
            self.mults_per_dsp
        }
    }
}

/// Resource usage of one pipeline stage (or a whole accelerator when
/// summed).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Resources {
    pub alms: usize,
    /// Subset of `alms` used as memory LABs (Table II "ALMs for Memory").
    pub mem_alms: usize,
    pub registers: usize,
    pub hyper_registers: usize,
    pub m20ks: usize,
    pub dsps: usize,
}

impl Resources {
    pub fn add(&mut self, o: &Resources) {
        self.alms += o.alms;
        self.mem_alms += o.mem_alms;
        self.registers += o.registers;
        self.hyper_registers += o.hyper_registers;
        self.m20ks += o.m20ks;
        self.dsps += o.dsps;
    }

    pub fn fits(&self, d: &Device) -> bool {
        self.alms <= d.alms && self.m20ks <= d.m20ks && self.dsps <= d.dsps
    }

    pub fn utilization(&self, d: &Device) -> (f64, f64, f64) {
        (
            self.alms as f64 / d.alms as f64,
            self.m20ks as f64 / d.m20ks as f64,
            self.dsps as f64 / d.dsps as f64,
        )
    }
}

/// Tunable constants of the cost model. Defaults calibrated against
/// Table II (see `benches/table2_resources.rs` which prints the fit).
#[derive(Clone, Debug)]
pub struct CostModel {
    /// Fixed controller ALMs per stage.
    pub ctrl_alms: usize,
    /// ALMs per multiplier (X-mux slice, pad mux, operand registers).
    pub alms_per_mult: usize,
    /// Extra ALMs per mux input beyond 1 (k_w wide X-muxes cost more).
    pub alms_per_mult_muxin: usize,
    /// ALMs per weight-buffer split (runlength decoder + addressing).
    pub alms_per_split: usize,
    /// Registers per ALM (pipelining density; Table II ResNet: ~2.4).
    pub regs_per_alm: f64,
    /// Hyper-registers per ALM (S10 HyperFlex; Table II ResNet: ~0.63).
    pub hregs_per_alm: f64,
    /// Bits per weight-buffer entry (16b value + runlength + x-index).
    pub weight_entry_bits: usize,
    /// Usable bits per M20K.
    pub m20k_bits: usize,
    /// Fraction of small buffers that go to MLABs (memory ALMs) instead
    /// of M20Ks.
    pub mlab_bits_per_alm: usize,
    /// Activation buffer depth in lines (k_h + double-buffer margin).
    pub act_buffer_margin_lines: usize,
    /// Activation precision (bits).
    pub act_bits: usize,
}

impl Default for CostModel {
    fn default() -> Self {
        CostModel {
            ctrl_alms: 900,
            alms_per_mult: 26,
            alms_per_mult_muxin: 7,
            alms_per_split: 110,
            regs_per_alm: 2.4,
            hregs_per_alm: 0.63,
            weight_entry_bits: 24,
            m20k_bits: 20_480,
            mlab_bits_per_alm: 20,
            act_buffer_margin_lines: 2,
            act_bits: 16,
        }
    }
}

/// Static per-stage workload description the cost/throughput models need
/// (extracted from the graph by the compiler).
#[derive(Clone, Debug)]
pub struct StageGeometry {
    /// Input line width × channels (elements per input line).
    pub in_w: usize,
    pub in_c: usize,
    /// Output line width / height / channels.
    pub out_w: usize,
    pub out_h: usize,
    pub out_c: usize,
    pub kh: usize,
    pub kw: usize,
    pub stride: usize,
}

/// Estimate the resource cost of one compute stage.
///
/// `mults` = W·s for conv/depthwise (one chain per output column),
/// `s` for MatMul. `weight_entries` = padded RLE entries (the weight
/// buffer footprint). Non-compute stages use [`stage_cost_simple`].
pub fn conv_stage_cost(
    cm: &CostModel,
    geo: &StageGeometry,
    splits: usize,
    mults: usize,
    weight_entries: usize,
    mults_per_dsp: usize,
) -> Resources {
    let alms_mux = mults * (cm.alms_per_mult + cm.alms_per_mult_muxin * geo.kw.saturating_sub(1));
    let alms = cm.ctrl_alms + alms_mux + cm.alms_per_split * splits;

    // Weight buffer: entries spread across `splits` independent streams;
    // M20Ks are dual-ported, so two streams can share one block. The
    // floor is capacity (total bits), the ceiling driver is banking
    // (ceil(splits/2) independent read ports).
    let weight_bits = weight_entries * cm.weight_entry_bits;
    let weight_m20ks = weight_bits
        .div_ceil(cm.m20k_bits)
        .max(splits.max(1).div_ceil(2));

    // Input activation buffers: k_h + margin lines of the input,
    // partitioned across splits (each split's buffer holds its rows),
    // again two splits per dual-ported M20K.
    let act_lines = geo.kh + cm.act_buffer_margin_lines;
    let act_bits = act_lines * geo.in_w * geo.in_c * cm.act_bits;
    let per_split_bits = act_bits / splits.max(1);
    // Small buffers (< 1/2 M20K) go to MLABs.
    let (act_m20ks, mem_alms) = if per_split_bits * 2 < cm.m20k_bits {
        (0, splits * per_split_bits.div_ceil(cm.mlab_bits_per_alm))
    } else {
        (
            act_bits
                .div_ceil(cm.m20k_bits)
                .max(splits.max(1).div_ceil(2)),
            0,
        )
    };

    let total_alms = alms + mem_alms;
    Resources {
        alms: total_alms,
        mem_alms,
        registers: (total_alms as f64 * cm.regs_per_alm) as usize,
        hyper_registers: (total_alms as f64 * cm.hregs_per_alm) as usize,
        m20ks: weight_m20ks + act_m20ks,
        dsps: mults.div_ceil(mults_per_dsp.max(1)),
    }
}

/// Cost of a non-compute stage (MaxPool, Add, BiasAdd, Relu, Mean,
/// Placeholder). Buffering stages pay for their line buffers; streaming
/// stages are a few hundred ALMs of control.
pub fn stage_cost_simple(
    cm: &CostModel,
    op: &Op,
    geo: &StageGeometry,
    buffer_lines: usize,
) -> Resources {
    let buffers = op.buffers_input();
    let alms_ctrl = match op {
        Op::MaxPool { .. } => cm.ctrl_alms / 2 + geo.in_c * 2, // comparator tree
        Op::Add => cm.ctrl_alms / 3 + geo.in_c,                // adder + 2 buffers
        Op::Mean => cm.ctrl_alms / 3 + geo.in_c * 2,
        Op::BiasAdd | Op::Relu | Op::Relu6 | Op::Softmax => 120 + geo.in_c / 2,
        Op::Placeholder { .. } => cm.ctrl_alms / 2,
        _ => cm.ctrl_alms / 4,
    };
    let (m20ks, mem_alms) = if buffers {
        let n_bufs = if matches!(op, Op::Add) { 2 } else { 1 };
        let bits = buffer_lines.max(1) * geo.in_w * geo.in_c * cm.act_bits * n_bufs;
        if bits * 2 < cm.m20k_bits {
            (0, bits.div_ceil(cm.mlab_bits_per_alm))
        } else {
            (bits.div_ceil(cm.m20k_bits), 0)
        }
    } else {
        (0, 0)
    };
    let alms = alms_ctrl + mem_alms;
    Resources {
        alms,
        mem_alms,
        registers: (alms as f64 * cm.regs_per_alm) as usize,
        hyper_registers: (alms as f64 * cm.hregs_per_alm) as usize,
        m20ks,
        dsps: 0,
    }
}

/// Frequency heuristic (§VI-D): the compiler pipelines control/data
/// fanout, so achieved Fmax degrades smoothly with the widest fanout
/// (the biggest stage's multiplier count — the shared weight stream
/// fans out to every column chain) and with overall device fill (routing
/// congestion). Constants fit to Table II's 580/430/390 MHz.
#[derive(Clone, Debug)]
pub struct FreqModel {
    pub base_mhz: f64,
    /// MHz lost per log2 of the widest stage's multiplier fanout.
    pub per_log2_fanout: f64,
    /// MHz lost per unit ALM utilization (routing congestion).
    pub per_alm_util: f64,
    /// Flat penalty whenever depthwise stages are present, plus a
    /// proportional term (the paper notes the pipelining heuristics "were
    /// mostly tuned on Resnet", leaving MobileNet frequencies lower).
    pub depthwise_penalty: f64,
    pub depthwise_frac_penalty: f64,
}

impl Default for FreqModel {
    fn default() -> Self {
        FreqModel {
            base_mhz: 730.0,
            per_log2_fanout: 9.0,
            per_alm_util: 105.0,
            depthwise_penalty: 140.0,
            depthwise_frac_penalty: 30.0,
        }
    }
}

impl FreqModel {
    /// Estimate Fmax for a compiled accelerator.
    ///
    /// `max_stage_mults`: widest compute stage; `alm_util`: fraction of
    /// device ALMs used; `dw_mult_frac`: fraction of multipliers in
    /// depthwise stages.
    pub fn fmax(
        &self,
        device: &Device,
        max_stage_mults: usize,
        alm_util: f64,
        dw_mult_frac: f64,
    ) -> f64 {
        let fanout = (max_stage_mults.max(1) as f64).log2();
        let dw = if dw_mult_frac > 0.0 {
            self.depthwise_penalty + self.depthwise_frac_penalty * dw_mult_frac
        } else {
            0.0
        };
        let f = self.base_mhz.min(device.base_fmax)
            - self.per_log2_fanout * fanout
            - self.per_alm_util * alm_util
            - dw;
        f.max(60.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Padding;

    fn geo() -> StageGeometry {
        StageGeometry {
            in_w: 56,
            in_c: 64,
            out_w: 56,
            out_h: 56,
            out_c: 64,
            kh: 3,
            kw: 3,
            stride: 1,
        }
    }

    #[test]
    fn device_lookup() {
        assert_eq!(device_by_name("s10_2800").unwrap().dsps, 5760);
        assert_eq!(device_by_name("zu9").unwrap().mults_per_dsp, 1);
        assert!(device_by_name("vu9p").is_none());
    }

    #[test]
    fn conv_cost_scales_with_mults() {
        let cm = CostModel::default();
        let g = geo();
        let small = conv_stage_cost(&cm, &g, 2, 56 * 2, 10_000, 2);
        let big = conv_stage_cost(&cm, &g, 8, 56 * 8, 10_000, 2);
        assert!(big.alms > small.alms);
        assert!(big.dsps > small.dsps);
        assert_eq!(big.dsps, (56 * 8usize).div_ceil(2));
    }

    #[test]
    fn weight_buffer_m20ks_track_entries() {
        let cm = CostModel::default();
        let g = geo();
        let few = conv_stage_cost(&cm, &g, 4, 8, 1_000, 2);
        let many = conv_stage_cost(&cm, &g, 4, 8, 400_000, 2);
        assert!(many.m20ks > few.m20ks);
        // 400k entries * 24b = 9.6Mb -> ≥ 469 M20Ks
        assert!(many.m20ks >= 400_000 * 24 / 20_480);
    }

    #[test]
    fn small_buffers_use_mlabs() {
        let cm = CostModel::default();
        let tiny = StageGeometry {
            in_w: 7,
            in_c: 4,
            out_w: 7,
            out_h: 7,
            out_c: 4,
            kh: 1,
            kw: 1,
            stride: 1,
        };
        let r = conv_stage_cost(&cm, &tiny, 1, 4, 16, 2);
        assert!(r.mem_alms > 0, "tiny activation buffer should be MLAB");
    }

    #[test]
    fn simple_stage_costs() {
        let cm = CostModel::default();
        let g = geo();
        let pool = stage_cost_simple(
            &cm,
            &Op::MaxPool { ksize: (3, 3), stride: (2, 2), padding: Padding::Same },
            &g,
            5,
        );
        assert!(pool.m20ks > 0 || pool.mem_alms > 0);
        assert_eq!(pool.dsps, 0);
        let relu = stage_cost_simple(&cm, &Op::Relu, &g, 0);
        assert_eq!(relu.m20ks, 0);
        assert!(relu.alms < pool.alms + 1000);
    }

    #[test]
    fn resources_add_and_fit() {
        let mut total = Resources::default();
        total.add(&Resources { alms: 500_000, mem_alms: 0, registers: 0, hyper_registers: 0, m20ks: 11_000, dsps: 5_000 });
        assert!(total.fits(&S10_2800));
        total.add(&Resources { dsps: 1_000, ..Default::default() });
        assert!(!total.fits(&S10_2800));
        let (_, _, d) = total.utilization(&S10_2800);
        assert!(d > 1.0);
    }

    #[test]
    fn freq_model_ordering() {
        let fm = FreqModel::default();
        // ResNet-like: big fanout, high ALM fill, no depthwise
        let resnet = fm.fmax(&S10_2800, 1024, 0.63, 0.0);
        // MobileNet-like: moderate fanout, lower fill, lots of depthwise
        let mbv1 = fm.fmax(&S10_2800, 1024, 0.40, 0.45);
        let mbv2 = fm.fmax(&S10_2800, 2048, 0.31, 0.55);
        assert!(resnet > mbv1, "{resnet} vs {mbv1}");
        assert!(mbv1 > mbv2, "{mbv1} vs {mbv2}");
        assert!((450.0..700.0).contains(&resnet), "resnet fmax {resnet}");
    }
}
