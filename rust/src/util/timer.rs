//! Criterion-less benchmark harness.
//!
//! `cargo bench` targets in this repo use `harness = false` and drive this
//! module: warm up, run timed iterations, report min/median/mean/p95 in a
//! stable text format that the EXPERIMENTS.md tables are built from.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub min: Duration,
    pub median: Duration,
    pub mean: Duration,
    pub p95: Duration,
}

impl BenchStats {
    pub fn print(&self) {
        println!(
            "bench {:<44} iters={:<6} min={:>12?} median={:>12?} mean={:>12?} p95={:>12?}",
            self.name, self.iters, self.min, self.median, self.mean, self.p95
        );
    }

    /// Median in nanoseconds (convenience for derived metrics).
    pub fn median_ns(&self) -> f64 {
        self.median.as_nanos() as f64
    }
}

/// Benchmark a closure: `warmup` untimed runs then `iters` timed runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut samples: Vec<Duration> = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
    }
    samples.sort_unstable();
    let total: Duration = samples.iter().sum();
    let stats = BenchStats {
        name: name.to_string(),
        iters,
        min: samples[0],
        median: samples[samples.len() / 2],
        mean: total / iters as u32,
        p95: samples[(samples.len() as f64 * 0.95) as usize % samples.len()],
    };
    stats.print();
    stats
}

/// Time a single run of a closure and return (result, elapsed).
pub fn time_once<T, F: FnOnce() -> T>(f: F) -> (T, Duration) {
    let t0 = Instant::now();
    let r = f();
    (r, t0.elapsed())
}

/// Monotonic nanoseconds since a process-wide epoch (the first call).
/// Instants cannot be stored in an `AtomicU64`, so cross-thread
/// timestamp accounting — e.g. the pipeline's inter-run idle tracking,
/// where one `run_*` call's exit time is read by the next call possibly
/// on another thread — goes through this shared epoch instead. Never
/// returns 0, so 0 stays usable as an "unset" sentinel.
pub fn epoch_ns() -> u64 {
    use std::sync::OnceLock;
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    let ns = EPOCH.get_or_init(Instant::now).elapsed().as_nanos().min(u64::MAX as u128) as u64;
    ns.max(1)
}

/// Scoped monotonic timer: accumulates the enclosing scope's elapsed
/// nanoseconds into an atomic sink on drop. The atomic sink makes the
/// same instrument usable from the profiler's single-threaded
/// step-timing loop and from the pipeline's per-stage busy/stall
/// counters, where several worker threads record concurrently.
pub struct ScopedNs<'a> {
    t0: Instant,
    sink: &'a AtomicU64,
}

impl<'a> ScopedNs<'a> {
    pub fn new(sink: &'a AtomicU64) -> ScopedNs<'a> {
        ScopedNs { t0: Instant::now(), sink }
    }
}

impl Drop for ScopedNs<'_> {
    fn drop(&mut self) {
        let ns = self.t0.elapsed().as_nanos().min(u64::MAX as u128) as u64;
        self.sink.fetch_add(ns, Ordering::Relaxed);
    }
}

/// Pretty table printer for bench/report binaries: fixed-width columns.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn rows_ref(&self) -> &[Vec<String>] {
        &self.rows
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("| ");
            for (c, w) in cells.iter().zip(widths) {
                line.push_str(&format!("{c:<w$} | ", w = w));
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&"-".repeat(w + 2));
            sep.push('|');
        }
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_ordered_stats() {
        let s = bench("noop", 2, 16, || {
            std::hint::black_box(3 + 4);
        });
        assert!(s.min <= s.median);
        assert!(s.median <= s.p95);
        assert_eq!(s.iters, 16);
    }

    #[test]
    fn scoped_ns_accumulates() {
        let sink = AtomicU64::new(0);
        for _ in 0..2 {
            let _t = ScopedNs::new(&sink);
            std::hint::black_box(17 * 3);
        }
        // two scopes, both recorded (monotonic => nonzero on any clock
        // with ns resolution; at worst equal)
        let after_two = sink.load(Ordering::Relaxed);
        {
            let _t = ScopedNs::new(&sink);
            std::thread::sleep(Duration::from_millis(1));
        }
        assert!(sink.load(Ordering::Relaxed) >= after_two + 1_000_000);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["layer", "cycles"]);
        t.row(&["conv1".into(), "123".into()]);
        t.row(&["res2a_branch2a".into(), "7".into()]);
        let r = t.render();
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 4);
        // all lines same width
        assert!(lines.windows(2).all(|w| w[0].len() == w[1].len()));
        assert!(r.contains("res2a_branch2a"));
    }

    #[test]
    #[should_panic(expected = "column count mismatch")]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only one".into()]);
    }
}
