//! Deterministic fault injection for robustness tests.
//!
//! Injection *points* are compiled into the serving hot paths — pipeline
//! stage workers, packed kernels, the batcher drain — as calls to
//! [`point`]. In a default build (no `fault-inject` feature) every hook
//! is an empty `#[inline(always)]` function the optimizer erases, so the
//! happy path pays nothing. With `--features fault-inject` a test can
//! [`arm`] a *plan* describing which sites misbehave and how, and the
//! harness fires deterministically: same plan, same sites hit in the
//! same order, same faults — the robustness twin of the bitwise
//! equivalence gates.
//!
//! Plan grammar (comma-separated clauses):
//!
//! ```text
//! site[#idx]=N[+][:ACTION]        fire on the Nth hit (N+ = Nth and
//!                                 every later hit: a persistently
//!                                 broken site)
//! site[#idx]=N[:ACTION],heal      transient: fire on hits 1..=N, then
//!                                 permanently heal — the deterministic
//!                                 way to exercise trip -> cool-down ->
//!                                 probe -> recovery paths
//! site[#idx]=pP@SEED[:ACTION]     fire on each hit with probability P%
//!                                 from a seeded, site-keyed hash —
//!                                 deterministic per (seed, site, idx,
//!                                 hit count)
//! ```
//!
//! `site` names an injection point family ("pipeline.stage",
//! "kernel.gemm", "batcher.drain"); `#idx` restricts the clause to one
//! instance (e.g. one pipeline stage), omitted = any. `ACTION` is
//! `panic` (default — the injected fault is a worker panic) or
//! `sleepMS` (inject latency; how deadline expiry is exercised).
//! Hit counts are 1-based and tracked per (site, idx). A bare `heal`
//! segment modifies the clause before it (clauses are comma-separated,
//! so `heal` cannot be mistaken for a site).

/// Render a caught panic payload (the `Box<dyn Any>` from
/// `catch_unwind`) as a human-readable message.
pub fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "panic payload of unknown type".to_string()
    }
}

#[cfg(feature = "fault-inject")]
mod armed {
    use std::collections::HashMap;
    use std::sync::{Mutex, MutexGuard, Once};
    use std::time::Duration;

    #[derive(Clone, Copy, Debug, PartialEq, Eq)]
    enum Trigger {
        /// Fire on the `n`th hit; with `persistent`, on every hit ≥ n.
        Nth { n: u64, persistent: bool },
        /// Transient (`...,heal`): fire on hits 1..=n, then never again
        /// — a site that breaks, then permanently heals.
        FirstN { n: u64 },
        /// Fire with `percent`% probability per hit, drawn from a
        /// seeded, site-keyed hash (deterministic, not random).
        Seeded { percent: u64, seed: u64 },
    }

    #[derive(Clone, Copy, Debug, PartialEq, Eq)]
    enum Action {
        Panic,
        Sleep(u64),
    }

    #[derive(Clone, Debug)]
    struct Clause {
        site: String,
        idx: Option<usize>,
        trigger: Trigger,
        action: Action,
    }

    #[derive(Default)]
    struct State {
        clauses: Vec<Clause>,
        hits: HashMap<(String, usize), u64>,
        fired: u64,
    }

    static STATE: Mutex<Option<State>> = Mutex::new(None);

    fn lock() -> MutexGuard<'static, Option<State>> {
        // A poisoned lock here only means some thread panicked while the
        // state was armed (that is the whole point); the state is valid.
        STATE.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn parse_clause(text: &str) -> Clause {
        let bad = |why: &str| -> ! { panic!("fault clause '{text}': {why}") };
        let Some((lhs, rhs)) = text.split_once('=') else {
            bad("missing '='")
        };
        let (site, idx) = match lhs.split_once('#') {
            Some((s, i)) => match i.trim().parse::<usize>() {
                Ok(i) => (s, Some(i)),
                Err(_) => bad("index after '#' is not a number"),
            },
            None => (lhs, None),
        };
        let (trig, act) = match rhs.split_once(':') {
            Some((t, a)) => (t.trim(), Some(a.trim())),
            None => (rhs.trim(), None),
        };
        let trigger = if let Some(rest) = trig.strip_prefix('p') {
            let Some((p, seed)) = rest.split_once('@') else {
                bad("seeded trigger must be pP@SEED")
            };
            match (p.parse::<u64>(), seed.parse::<u64>()) {
                (Ok(percent), Ok(seed)) => Trigger::Seeded { percent, seed },
                _ => bad("seeded trigger must be pP@SEED with numeric P and SEED"),
            }
        } else if let Some(n) = trig.strip_suffix('+') {
            match n.parse::<u64>() {
                Ok(n) => Trigger::Nth { n, persistent: true },
                Err(_) => bad("hit count is not a number"),
            }
        } else {
            match trig.parse::<u64>() {
                Ok(n) => Trigger::Nth { n, persistent: false },
                Err(_) => bad("hit count is not a number"),
            }
        };
        let action = match act {
            None | Some("panic") => Action::Panic,
            Some(a) => match a.strip_prefix("sleep").and_then(|ms| ms.parse::<u64>().ok()) {
                Some(ms) => Action::Sleep(ms),
                None => bad("action must be 'panic' or 'sleepMS'"),
            },
        };
        Clause { site: site.trim().to_string(), idx, trigger, action }
    }

    /// Seeded, site-keyed hash for probabilistic triggers: FNV over the
    /// site name folded with a splitmix-style finalizer over (idx, hit).
    fn mix(seed: u64, site: &str, idx: usize, hit: u64) -> u64 {
        let mut h = 0xcbf2_9ce4_8422_2325u64 ^ seed;
        for b in site.bytes() {
            h = (h ^ b as u64).wrapping_mul(0x0100_0000_01b3);
        }
        let mut z = h
            ^ (idx as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
            ^ hit.wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Arm the harness with a fault plan (see the module docs for the
    /// grammar). Replaces any previous plan and zeroes all hit counts.
    /// Panics on a malformed plan — a typo in a chaos test must fail
    /// loudly, not silently inject nothing.
    pub fn arm(plan: &str) {
        let mut clauses: Vec<Clause> = Vec::new();
        for seg in plan.split(',').filter(|c| !c.trim().is_empty()) {
            if seg.trim() == "heal" {
                // `heal` is a modifier on the clause before it: turn its
                // Nth trigger into a transient fire-then-heal trigger.
                let Some(prev) = clauses.last_mut() else {
                    panic!("fault plan '{plan}': 'heal' with no preceding clause");
                };
                prev.trigger = match prev.trigger {
                    Trigger::Nth { n, .. } | Trigger::FirstN { n } => Trigger::FirstN { n },
                    Trigger::Seeded { .. } => {
                        panic!("fault plan '{plan}': 'heal' cannot follow a seeded clause")
                    }
                };
            } else {
                clauses.push(parse_clause(seg));
            }
        }
        *lock() = Some(State { clauses, ..Default::default() });
    }

    /// Remove the active fault plan; every [`point`] becomes a no-op.
    pub fn disarm() {
        *lock() = None;
    }

    /// Number of faults fired since the last [`arm`].
    pub fn fired() -> u64 {
        lock().as_ref().map_or(0, |s| s.fired)
    }

    /// An injection point. Counts a hit for `(site, idx)` and, when an
    /// armed clause matches, fires its action (panicking or sleeping
    /// *outside* the harness lock).
    pub fn point(site: &str, idx: usize) {
        let action = {
            let mut guard = lock();
            let Some(state) = guard.as_mut() else { return };
            let hit = state.hits.entry((site.to_string(), idx)).or_insert(0);
            *hit += 1;
            let hit = *hit;
            let matched = state.clauses.iter().find(|c| {
                c.site == site
                    && (c.idx.is_none() || c.idx == Some(idx))
                    && match c.trigger {
                        Trigger::Nth { n, persistent } => hit == n || (persistent && hit > n),
                        Trigger::FirstN { n } => hit <= n,
                        Trigger::Seeded { percent, seed } => {
                            mix(seed, site, idx, hit) % 100 < percent
                        }
                    }
            });
            match matched {
                Some(c) => {
                    state.fired += 1;
                    c.action
                }
                None => return,
            }
        };
        match action {
            Action::Panic => panic!("injected fault at {site}#{idx}"),
            Action::Sleep(ms) => std::thread::sleep(Duration::from_millis(ms)),
        }
    }

    static SILENCE: Once = Once::new();

    /// Install a panic hook that suppresses the default backtrace spew
    /// for *injected* panics (chaos tests fire hundreds of them by
    /// design) while leaving real panics loud. Idempotent.
    pub fn silence_expected_panics() {
        SILENCE.call_once(|| {
            let default = std::panic::take_hook();
            std::panic::set_hook(Box::new(move |info| {
                let injected = info
                    .payload()
                    .downcast_ref::<String>()
                    .is_some_and(|s| s.contains("injected fault"));
                if !injected {
                    default(info);
                }
            }));
        });
    }
}

#[cfg(feature = "fault-inject")]
pub use armed::{arm, disarm, fired, point, silence_expected_panics};

#[cfg(not(feature = "fault-inject"))]
mod disarmed {
    //! No-op hooks: the `fault-inject` feature is off, so every call
    //! site compiles to nothing.

    #[inline(always)]
    pub fn point(_site: &str, _idx: usize) {}

    #[inline(always)]
    pub fn arm(_plan: &str) {}

    #[inline(always)]
    pub fn disarm() {}

    #[inline(always)]
    pub fn fired() -> u64 {
        0
    }

    #[inline(always)]
    pub fn silence_expected_panics() {}
}

#[cfg(not(feature = "fault-inject"))]
pub use disarmed::{arm, disarm, fired, point, silence_expected_panics};

#[cfg(all(test, feature = "fault-inject"))]
mod tests {
    //! These tests use fictitious site names only: the harness state is
    //! process-global, and arming a *real* site here would fault
    //! unrelated lib tests running concurrently.

    use super::*;
    use std::panic::{catch_unwind, AssertUnwindSafe};

    // The harness is process-global: serialize the tests that arm it.
    static GATE: std::sync::Mutex<()> = std::sync::Mutex::new(());

    fn gate() -> std::sync::MutexGuard<'static, ()> {
        GATE.lock().unwrap_or_else(|e| e.into_inner())
    }

    #[test]
    fn nth_hit_fires_exactly_once() {
        let _g = gate();
        silence_expected_panics();
        arm("test.once#3=2");
        point("test.once", 3); // hit 1: no fire
        let r = catch_unwind(AssertUnwindSafe(|| point("test.once", 3)));
        let msg = panic_message(r.unwrap_err().as_ref());
        assert!(msg.contains("injected fault at test.once#3"), "{msg}");
        point("test.once", 3); // hit 3: no fire (not persistent)
        point("test.once", 7); // different idx: untouched
        assert_eq!(fired(), 1);
        disarm();
        point("test.once", 3); // disarmed: inert
        assert_eq!(fired(), 0);
    }

    #[test]
    fn persistent_clause_fires_from_nth_on() {
        let _g = gate();
        silence_expected_panics();
        arm("test.persist=2+");
        point("test.persist", 0);
        for _ in 0..3 {
            assert!(catch_unwind(AssertUnwindSafe(|| point("test.persist", 0))).is_err());
        }
        assert_eq!(fired(), 3);
        disarm();
    }

    #[test]
    fn sleep_action_injects_latency_not_panic() {
        let _g = gate();
        arm("test.slow=1+:sleep20");
        let t0 = std::time::Instant::now();
        point("test.slow", 0);
        assert!(t0.elapsed() >= std::time::Duration::from_millis(20));
        assert_eq!(fired(), 1);
        disarm();
    }

    #[test]
    fn seeded_trigger_is_deterministic() {
        let _g = gate();
        silence_expected_panics();
        let run = |seed: u64| -> Vec<bool> {
            arm(&format!("test.seeded=p30@{seed}"));
            let fired: Vec<bool> = (0..64)
                .map(|_| catch_unwind(AssertUnwindSafe(|| point("test.seeded", 1))).is_err())
                .collect();
            disarm();
            fired
        };
        let (a, b) = (run(7), run(7));
        assert_eq!(a, b, "same seed must fire the same hits");
        let n = a.iter().filter(|&&f| f).count();
        assert!(n > 0 && n < 64, "p30 over 64 hits fired {n} times");
        // a different seed produces a different (but still valid) pattern
        let c = run(8);
        assert_ne!(a, c, "different seeds should differ (64 hits)");
    }

    #[test]
    fn transient_clause_fires_first_n_then_heals_forever() {
        let _g = gate();
        silence_expected_panics();
        arm("test.transient#2=3:panic,heal");
        for hit in 1..=3 {
            let r = catch_unwind(AssertUnwindSafe(|| point("test.transient", 2)));
            assert!(r.is_err(), "transient clause must fire on hit {hit}");
        }
        for _ in 0..16 {
            point("test.transient", 2); // healed: inert forever after
        }
        assert_eq!(fired(), 3, "transient clause fires exactly N times");
        disarm();
    }

    #[test]
    fn transient_heal_composes_with_other_clauses_in_one_plan() {
        let _g = gate();
        silence_expected_panics();
        // a transient clause and a plain Nth clause side by side: the
        // heal modifier binds to its own clause only
        arm("test.mix#0=1,heal,test.mix#1=2");
        assert!(catch_unwind(AssertUnwindSafe(|| point("test.mix", 0))).is_err());
        point("test.mix", 0); // idx 0 healed after hit 1
        point("test.mix", 1); // hit 1 of idx 1: no fire
        assert!(catch_unwind(AssertUnwindSafe(|| point("test.mix", 1))).is_err());
        assert_eq!(fired(), 2);
        disarm();
    }

    #[test]
    #[should_panic(expected = "'heal' with no preceding clause")]
    fn dangling_heal_is_rejected() {
        arm("heal");
    }

    #[test]
    #[should_panic(expected = "missing '='")]
    fn malformed_plan_is_rejected() {
        // no gate: arm() panics before mutating shared hit counts matter
        arm("test.bad");
    }

    #[test]
    fn panic_message_renders_both_payload_kinds() {
        let s = catch_unwind(|| panic!("literal")).unwrap_err();
        assert_eq!(panic_message(s.as_ref()), "literal");
        let owned = catch_unwind(|| panic!("formatted {}", 7)).unwrap_err();
        assert_eq!(panic_message(owned.as_ref()), "formatted 7");
    }
}
