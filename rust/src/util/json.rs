//! Minimal JSON parser and writer.
//!
//! The offline vendor set has no `serde`, so graphdefs, compiler plans and
//! report files go through this hand-rolled implementation. It supports
//! the full JSON data model (objects keep insertion order), parses from
//! `&str`, and pretty-prints with stable formatting so emitted files are
//! diff-friendly.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys keep a sorted map for deterministic output;
/// insertion order is not semantically meaningful for any of our files.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Error with byte offset and message.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub offset: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---- constructors ----
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn from_pairs(pairs: Vec<(&str, Json)>) -> Json {
        let mut m = BTreeMap::new();
        for (k, v) in pairs {
            m.insert(k.to_string(), v);
        }
        Json::Obj(m)
    }

    // ---- accessors ----
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 {
                Some(n as usize)
            } else {
                None
            }
        })
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().and_then(|n| {
            if n.fract() == 0.0 {
                Some(n as i64)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Object field lookup; `Json::Null` for missing keys or non-objects.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(m) => m.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Insert into an object (panics if not an object — programmer error).
    pub fn set(&mut self, key: &str, val: Json) -> &mut Json {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), val);
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    pub fn push(&mut self, val: Json) -> &mut Json {
        match self {
            Json::Arr(a) => a.push(val),
            _ => panic!("Json::push on non-array"),
        }
        self
    }

    /// Convenience: numeric array -> Vec<usize>.
    pub fn usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()?
            .iter()
            .map(|v| v.as_usize())
            .collect::<Option<Vec<_>>>()
    }

    /// Convenience: numeric array -> Vec<f32>.
    pub fn f32_vec(&self) -> Option<Vec<f32>> {
        self.as_arr()?
            .iter()
            .map(|v| v.as_f64().map(|x| x as f32))
            .collect::<Option<Vec<_>>>()
    }

    // ---- parsing ----
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: src.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ---- writing ----
    /// Compact single-line encoding.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Pretty-printed with 2-space indentation.
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                if a.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(n: f64) -> Json {
        Json::Num(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Json {
        Json::Num(n as f64)
    }
}
impl From<i64> for Json {
    fn from(n: i64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32))
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            offset: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while let Some(b) = self.peek() {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &str, val: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(val)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(b) if b == b'-' || b.is_ascii_digit() => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let start = self.pos;
            // Fast path: run of plain bytes.
            while let Some(b) = self.peek() {
                if b == b'"' || b == b'\\' || b < 0x20 {
                    break;
                }
                self.pos += 1;
            }
            s.push_str(
                std::str::from_utf8(&self.bytes[start..self.pos])
                    .map_err(|_| self.err("invalid utf-8"))?,
            );
            match self.bump() {
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Surrogate pairs.
                        let c = if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("unpaired surrogate"));
                            }
                            let lo = self.hex4()?;
                            let combined = 0x10000
                                + ((cp - 0xD800) << 10)
                                + (lo.wrapping_sub(0xDC00));
                            char::from_u32(combined)
                        } else {
                            char::from_u32(cp)
                        };
                        s.push(c.ok_or_else(|| self.err("invalid codepoint"))?);
                    }
                    _ => return Err(self.err("bad escape")),
                },
                _ => return Err(self.err("unterminated string")),
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let b = self.bump().ok_or_else(|| self.err("eof in \\u"))?;
            let d = (b as char)
                .to_digit(16)
                .ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while self.peek().is_some_and(|b| b.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while self.peek().is_some_and(|b| b.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            while self.peek().is_some_and(|b| b.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("invalid utf-8"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(src: &str) -> Json {
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2, "roundtrip mismatch for {src}");
        v
    }

    #[test]
    fn scalars() {
        assert_eq!(roundtrip("null"), Json::Null);
        assert_eq!(roundtrip("true"), Json::Bool(true));
        assert_eq!(roundtrip("false"), Json::Bool(false));
        assert_eq!(roundtrip("42"), Json::Num(42.0));
        assert_eq!(roundtrip("-3.5e2"), Json::Num(-350.0));
        assert_eq!(roundtrip("\"hi\""), Json::Str("hi".into()));
    }

    #[test]
    fn structures() {
        let v = roundtrip(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#);
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").as_arr().unwrap()[2].get("b"), &Json::Null);
        assert_eq!(v.get("c").as_str(), Some("x"));
        assert_eq!(v.get("missing"), &Json::Null);
    }

    #[test]
    fn escapes() {
        let v = roundtrip(r#""a\n\t\"\\bA""#);
        assert_eq!(v.as_str(), Some("a\n\t\"\\bA"));
    }

    #[test]
    fn surrogate_pair() {
        let v = Json::parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str(), Some("😀"));
    }

    #[test]
    fn whitespace_tolerant() {
        let v = Json::parse(" {\n\t\"k\" :\r [ 1 , 2 ] } ").unwrap();
        assert_eq!(v.get("k").usize_vec(), Some(vec![1, 2]));
    }

    #[test]
    fn errors() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"abc").is_err());
    }

    #[test]
    fn integer_formatting() {
        assert_eq!(Json::Num(5.0).to_string(), "5");
        assert_eq!(Json::Num(5.5).to_string(), "5.5");
    }

    #[test]
    fn pretty_parses_back() {
        let mut o = Json::obj();
        o.set("xs", Json::from(vec![1usize, 2, 3]))
            .set("name", Json::from("resnet"))
            .set("nested", Json::from_pairs(vec![("k", Json::from(true))]));
        let p = o.pretty();
        assert_eq!(Json::parse(&p).unwrap(), o);
        assert!(p.contains("  \"name\""));
    }

    #[test]
    fn typed_vec_helpers() {
        let v = Json::parse("[1, 2, 3]").unwrap();
        assert_eq!(v.usize_vec(), Some(vec![1, 2, 3]));
        assert_eq!(v.f32_vec(), Some(vec![1.0, 2.0, 3.0]));
        let bad = Json::parse("[1, \"x\"]").unwrap();
        assert_eq!(bad.usize_vec(), None);
    }
}
