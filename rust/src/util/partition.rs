//! Shared minimum-bottleneck contiguous partitioner.
//!
//! The classic linear-partition DP: split a cost sequence into `k`
//! contiguous, non-empty parts minimizing the largest part sum. This is
//! the software analog of HPIPE's balance-to-the-slowest-stage resource
//! allocation (Algorithm 1's objective), and it is deliberately
//! cost-model-agnostic: `exec::pipeline` feeds it the compile-side cycle
//! model's per-step estimates, while `exec::tune` feeds it *measured*
//! per-step wall times — the profile-guided variant. Keeping one tested
//! implementation here replaces the private copy that used to live in
//! `exec::pipeline` next to the parallel bottleneck-chasing logic of
//! `compile::balance` / `baselines::partitioning`.

/// The DP tables: `dp[j][i]` is the minimal bottleneck covering the
/// first `i` costs with `j` parts; `cut[j][i]` is where part `j` starts
/// in that optimum. One fill serves both range reconstruction and the
/// all-part-counts bottleneck query.
#[allow(clippy::type_complexity)] // two parallel (k+1)×(n+1) tables
fn dp_tables(costs: &[u64], k: usize) -> (Vec<Vec<u64>>, Vec<Vec<usize>>) {
    let n = costs.len();
    let prefix = prefix_sums(costs);
    let mut dp = vec![vec![u64::MAX; n + 1]; k + 1];
    let mut cut = vec![vec![0usize; n + 1]; k + 1];
    dp[0][0] = 0;
    for j in 1..=k {
        for i in j..=n {
            for t in (j - 1)..i {
                if dp[j - 1][t] == u64::MAX {
                    continue;
                }
                let cand = dp[j - 1][t].max(prefix[i] - prefix[t]);
                if cand < dp[j][i] {
                    dp[j][i] = cand;
                    cut[j][i] = t;
                }
            }
        }
    }
    (dp, cut)
}

/// Contiguous partition of `costs` into `k` non-empty parts minimizing
/// the bottleneck (largest part sum). Returns `k` half-open index
/// ranges; `k` is clamped to `[1, costs.len()]` (an empty cost list
/// yields the single empty range).
pub fn partition_min_bottleneck(costs: &[u64], k: usize) -> Vec<(usize, usize)> {
    let n = costs.len();
    if n == 0 {
        return vec![(0, 0)];
    }
    let k = k.clamp(1, n);
    let (_, cut) = dp_tables(costs, k);
    let mut bounds = vec![0usize; k + 1];
    bounds[k] = n;
    let mut i = n;
    for j in (1..=k).rev() {
        i = cut[j][i];
        bounds[j - 1] = i;
    }
    bounds.windows(2).map(|w| (w[0], w[1])).collect()
}

/// Optimal bottleneck for *every* part count in one DP fill:
/// `result[j - 1]` is the minimal largest-part sum over `j` contiguous
/// non-empty parts, for `j` in `1..=k` (clamped to `costs.len()`). The
/// tuner's stage-count search reads this instead of re-running the DP
/// per candidate. An empty cost list yields `vec![0]`.
pub fn bottlenecks_up_to(costs: &[u64], k: usize) -> Vec<u64> {
    let n = costs.len();
    if n == 0 {
        return vec![0];
    }
    let k = k.clamp(1, n);
    let (dp, _) = dp_tables(costs, k);
    (1..=k).map(|j| dp[j][n]).collect()
}

/// Sum of each range's costs (the per-part totals of a partition).
pub fn range_costs(costs: &[u64], ranges: &[(usize, usize)]) -> Vec<u64> {
    ranges
        .iter()
        .map(|&(a, b)| costs[a..b].iter().sum())
        .collect()
}

/// The bottleneck (largest part sum) of a partition.
pub fn bottleneck(costs: &[u64], ranges: &[(usize, usize)]) -> u64 {
    range_costs(costs, ranges).into_iter().max().unwrap_or(0)
}

fn prefix_sums(costs: &[u64]) -> Vec<u64> {
    let mut prefix = vec![0u64; costs.len() + 1];
    for (i, &c) in costs.iter().enumerate() {
        prefix[i + 1] = prefix[i] + c;
    }
    prefix
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partition_is_contiguous_and_balanced() {
        let costs = [4u64, 4, 4, 4];
        assert_eq!(partition_min_bottleneck(&costs, 2), vec![(0, 2), (2, 4)]);
        assert_eq!(
            partition_min_bottleneck(&costs, 4),
            vec![(0, 1), (1, 2), (2, 3), (3, 4)]
        );
        // the dominant step gets a stage of its own
        let skewed = [10u64, 1, 1, 1];
        assert_eq!(partition_min_bottleneck(&skewed, 2), vec![(0, 1), (1, 4)]);
        // more parts than steps clamps
        assert_eq!(partition_min_bottleneck(&[3u64], 4), vec![(0, 1)]);
        // empty input degenerates to one empty range
        assert_eq!(partition_min_bottleneck(&[], 3), vec![(0, 0)]);
    }

    #[test]
    fn more_parts_never_raise_the_bottleneck() {
        let costs = [7u64, 2, 9, 1, 4, 4, 3, 8];
        let b =
            |k: usize| -> u64 { bottleneck(&costs, &partition_min_bottleneck(&costs, k)) };
        for k in 1..costs.len() {
            assert!(b(k + 1) <= b(k), "k={k}: {} > {}", b(k + 1), b(k));
        }
        // with one part per step the bottleneck is the largest step
        assert_eq!(b(costs.len()), 9);
        assert_eq!(b(1), costs.iter().sum::<u64>());
    }

    #[test]
    fn partition_is_optimal_on_small_inputs() {
        // brute-force all 2-part cuts and compare
        let costs = [5u64, 3, 8, 2, 6];
        let best_2 = (1..costs.len())
            .map(|c| {
                let left: u64 = costs[..c].iter().sum();
                let right: u64 = costs[c..].iter().sum();
                left.max(right)
            })
            .min()
            .unwrap();
        assert_eq!(
            bottleneck(&costs, &partition_min_bottleneck(&costs, 2)),
            best_2
        );
    }

    #[test]
    fn bottlenecks_up_to_matches_per_k_partitions() {
        let costs = [7u64, 2, 9, 1, 4, 4, 3, 8];
        let all = bottlenecks_up_to(&costs, costs.len());
        assert_eq!(all.len(), costs.len());
        for (j, &b) in all.iter().enumerate() {
            let direct = bottleneck(&costs, &partition_min_bottleneck(&costs, j + 1));
            assert_eq!(b, direct, "k={}", j + 1);
        }
        // clamped and empty edges
        assert_eq!(bottlenecks_up_to(&[5], 4), vec![5]);
        assert_eq!(bottlenecks_up_to(&[], 3), vec![0]);
    }

    #[test]
    fn range_costs_sum_to_total() {
        let costs = [1u64, 2, 3, 4, 5];
        for k in 1..=5 {
            let ranges = partition_min_bottleneck(&costs, k);
            assert_eq!(range_costs(&costs, &ranges).iter().sum::<u64>(), 15);
            assert_eq!(ranges.len(), k);
            // contiguous cover
            assert_eq!(ranges[0].0, 0);
            assert_eq!(ranges.last().unwrap().1, costs.len());
            for w in ranges.windows(2) {
                assert_eq!(w[0].1, w[1].0);
            }
        }
    }
}
