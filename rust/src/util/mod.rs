//! Shared infrastructure: JSON, PRNG, property testing, CLI, bench timing.
//!
//! These exist because the offline build environment vendors only the
//! `xla` crate's dependency closure — no serde/rand/clap/criterion — so
//! the repository carries its own minimal implementations.

pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod timer;

pub use json::Json;
pub use rng::Rng;
