//! Shared infrastructure: JSON, PRNG, property testing, CLI, bench
//! timing, error handling.
//!
//! These exist because the offline build environment has no crates.io
//! access — no serde/rand/clap/criterion/anyhow — so the repository
//! carries its own minimal implementations and builds dependency-free.

pub mod breaker;
pub mod cli;
pub mod error;
pub mod fault;
pub mod json;
pub mod partition;
pub mod prop;
pub mod rng;
pub mod timer;

pub use error::{Context, Error, Result};
pub use json::Json;
pub use rng::Rng;
