//! Minimal property-based testing harness.
//!
//! The offline vendor set has no `proptest`, so this module provides the
//! 20% that covers our needs: run a property over many randomly generated
//! cases from a deterministic seed, and on failure report the seed and
//! case index so the exact case can be replayed. A lightweight "shrink by
//! halving sizes" pass is available through [`Cases::sizes`].

use super::rng::Rng;

/// Configuration for a property run.
pub struct Cases {
    pub seed: u64,
    pub count: usize,
    /// Size ladder: each case gets a `size` hint cycled from this list,
    /// so properties see small, medium and large inputs.
    pub sizes: Vec<usize>,
}

impl Default for Cases {
    fn default() -> Self {
        Cases {
            seed: 0xC0FFEE,
            count: 64,
            sizes: vec![1, 2, 3, 5, 8, 16, 32],
        }
    }
}

impl Cases {
    pub fn new(count: usize) -> Self {
        Cases {
            count,
            ..Default::default()
        }
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Run `prop(rng, size)` for `count` cases; panic with replay info on
    /// the first failure (any Err return or panic inside the property).
    pub fn run<F>(&self, mut prop: F)
    where
        F: FnMut(&mut Rng, usize) -> Result<(), String>,
    {
        for case in 0..self.count {
            let case_seed = self.seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
            let mut rng = Rng::new(case_seed);
            let size = self.sizes[case % self.sizes.len()];
            if let Err(msg) = prop(&mut rng, size) {
                panic!(
                    "property failed at case {case} (seed {case_seed:#x}, size {size}): {msg}"
                );
            }
        }
    }
}

/// Assert two f32 slices are elementwise close (absolute + relative tol).
pub fn assert_close(actual: &[f32], expect: &[f32], atol: f32, rtol: f32) -> Result<(), String> {
    if actual.len() != expect.len() {
        return Err(format!(
            "length mismatch: {} vs {}",
            actual.len(),
            expect.len()
        ));
    }
    for (i, (&a, &e)) in actual.iter().zip(expect).enumerate() {
        let tol = atol + rtol * e.abs();
        if (a - e).abs() > tol || a.is_nan() != e.is_nan() {
            return Err(format!(
                "mismatch at [{i}]: actual={a} expect={e} tol={tol}"
            ));
        }
    }
    Ok(())
}

/// Monotone integer index of a float: consecutive representable floats
/// map to consecutive integers (±0.0 both map to 0), so ULP distance is
/// plain integer subtraction.
fn ulp_index(x: f32) -> i64 {
    let b = x.to_bits() as i32;
    if b >= 0 {
        b as i64
    } else {
        -((b & 0x7FFF_FFFF) as i64)
    }
}

/// Assert two f32 slices are elementwise within `ulps` units in the
/// last place. Much tighter than [`assert_close`]: it tolerates only
/// rounding-level drift (e.g. a kernel that reorders a handful of FP
/// additions), never algorithmic error. NaNs match NaNs; ±0.0 match.
pub fn assert_ulp_close(actual: &[f32], expect: &[f32], ulps: u32) -> Result<(), String> {
    if actual.len() != expect.len() {
        return Err(format!(
            "length mismatch: {} vs {}",
            actual.len(),
            expect.len()
        ));
    }
    for (i, (&a, &e)) in actual.iter().zip(expect).enumerate() {
        if a == e || (a.is_nan() && e.is_nan()) {
            continue;
        }
        if a.is_nan() != e.is_nan() {
            return Err(format!("mismatch at [{i}]: actual={a} expect={e}"));
        }
        let d = (ulp_index(a) - ulp_index(e)).unsigned_abs();
        if d > ulps as u64 {
            return Err(format!(
                "mismatch at [{i}]: actual={a} expect={e} ({d} ulps > {ulps})"
            ));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        Cases::new(10).run(|rng, size| {
            n += 1;
            let x = rng.below(size.max(1) * 10);
            if x < size * 10 {
                Ok(())
            } else {
                Err("impossible".into())
            }
        });
        assert_eq!(n, 10);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_context() {
        Cases::new(50).run(|rng, _| {
            if rng.below(10) < 9 {
                Ok(())
            } else {
                Err("found a 9".into())
            }
        });
    }

    #[test]
    fn assert_close_behaviour() {
        assert!(assert_close(&[1.0, 2.0], &[1.0, 2.0 + 1e-7], 1e-6, 1e-6).is_ok());
        assert!(assert_close(&[1.0], &[1.1], 1e-3, 1e-3).is_err());
        assert!(assert_close(&[1.0], &[1.0, 2.0], 1e-3, 1e-3).is_err());
    }

    #[test]
    fn ulp_close_behaviour() {
        // exact, signed zeros and NaNs
        assert!(assert_ulp_close(&[1.5, -0.0, f32::NAN], &[1.5, 0.0, f32::NAN], 0).is_ok());
        // one representable step away passes at 1 ulp, fails at 0
        let next = f32::from_bits(1.0f32.to_bits() + 1);
        assert!(assert_ulp_close(&[next], &[1.0], 1).is_ok());
        assert!(assert_ulp_close(&[next], &[1.0], 0).is_err());
        // across zero: -eps vs +eps is two indices apart
        let eps = f32::from_bits(1);
        assert!(assert_ulp_close(&[-eps], &[eps], 2).is_ok());
        assert!(assert_ulp_close(&[-eps], &[eps], 1).is_err());
        // genuinely different values fail
        assert!(assert_ulp_close(&[1.0], &[1.1], 64).is_err());
        assert!(assert_ulp_close(&[1.0], &[1.0, 2.0], 4).is_err());
    }

    #[test]
    fn deterministic_cases() {
        let mut first = Vec::new();
        Cases::new(5).run(|rng, _| {
            first.push(rng.next_u64());
            Ok(())
        });
        let mut second = Vec::new();
        Cases::new(5).run(|rng, _| {
            second.push(rng.next_u64());
            Ok(())
        });
        assert_eq!(first, second);
    }
}
